"""repro — TPU-native Unicode transcoding at line rate (public surface).

The supported API is exactly ``__all__`` below (DESIGN.md §11):

  * the four generic transcode entry points (``transcode`` / ``scan`` /
    ``ragged_transcode`` / ``ragged_scan``) — the per-pair wrappers in
    ``repro.core.transcode`` are deprecated shims over these;
  * the resumable streaming API (``transcode_stream`` / ``StreamState``);
  * ragged batch packing (``pack_documents``);
  * the result types (``TranscodeResult`` / ``RaggedTranscodeResult``);
  * the serving engine (``Engine`` / ``Request`` / ``Result`` /
    ``ResultCode``) with its ``submit``/``poll``/``drain`` surface.

Attributes resolve lazily (PEP 562): ``import repro`` stays cheap and
pulls no jax/kernel modules until a symbol is touched.
"""

from __future__ import annotations

import importlib

__all__ = [
    "transcode", "scan", "ragged_transcode", "ragged_scan",
    "transcode_stream", "pack_documents",
    "TranscodeResult", "RaggedTranscodeResult", "StreamState",
    "Engine", "Request", "Result", "ResultCode",
]

_EXPORTS = {
    "transcode": ("repro.core.transcode", "transcode"),
    "scan": ("repro.core.transcode", "scan"),
    "ragged_transcode": ("repro.core.transcode", "ragged_transcode"),
    "ragged_scan": ("repro.core.transcode", "ragged_scan"),
    "transcode_stream": ("repro.core.stream", "transcode_stream"),
    "StreamState": ("repro.core.stream", "StreamState"),
    "pack_documents": ("repro.core.packing", "pack_documents"),
    "TranscodeResult": ("repro.core.result", "TranscodeResult"),
    "RaggedTranscodeResult": ("repro.core.result", "RaggedTranscodeResult"),
    "Engine": ("repro.serve.engine", "Engine"),
    "Request": ("repro.serve.engine", "Request"),
    "Result": ("repro.serve.engine", "Result"),
    "ResultCode": ("repro.serve.engine", "ResultCode"),
}


def __getattr__(name: str):
    try:
        mod_name, attr = _EXPORTS[name]
    except KeyError:
        raise AttributeError(
            f"module {__name__!r} has no attribute {name!r}") from None
    value = getattr(importlib.import_module(mod_name), attr)
    globals()[name] = value      # cache: resolve each symbol once
    return value


def __dir__():
    return sorted(set(globals()) | set(__all__))
