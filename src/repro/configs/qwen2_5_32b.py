"""qwen2.5-32b: GQA with QKV bias [hf:Qwen/Qwen2.5-0.5B; hf].

64L d_model=5120 40H (GQA kv=8) d_ff=27648 vocab=152064, qkv_bias.
Full attention -> long_500k SKIPPED.
"""
import dataclasses
from repro.models.lm import LMConfig

ARCH_ID = "qwen2.5-32b"
FAMILY = "lm"

CONFIG = LMConfig(
    name=ARCH_ID, n_layers=64, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=27648, vocab=152064, qkv_bias=True, rope_theta=1000000.0)


def reduced():
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab=512, dtype="float32")
