"""h2o-danube-1.8b: llama+mistral mix with sliding-window attention
[arXiv:2401.16818; hf].  24L d_model=2560 32H (GQA kv=8) d_ff=6912
vocab=32000, SWA window 4096.  Sub-quadratic (SWA ring cache) ->
long_500k RUNS.
"""
import dataclasses
from repro.models.lm import LMConfig

ARCH_ID = "h2o-danube-1.8b"
FAMILY = "lm"

CONFIG = LMConfig(
    name=ARCH_ID, n_layers=24, d_model=2560, n_heads=32, n_kv_heads=8,
    d_ff=6912, vocab=32000, window=4096)


def reduced():
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab=512, window=16, dtype="float32")
