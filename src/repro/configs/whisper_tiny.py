"""whisper-tiny: enc-dec audio transformer [arXiv:2212.04356; unverified].

4L d_model=384 6H (kv=6) d_ff=1536 vocab=51865.  Conv audio frontend is a
STUB: input_specs provides precomputed 1500-frame mel embeddings.
Full attention -> long_500k SKIPPED (DESIGN.md §Arch-applicability).
"""
import dataclasses
from repro.models.encdec import EncDecConfig

ARCH_ID = "whisper-tiny"
FAMILY = "encdec"

CONFIG = EncDecConfig(
    name=ARCH_ID, n_layers=4, d_model=384, n_heads=6, n_kv_heads=6,
    d_ff=1536, vocab=51865, n_audio_frames=1500)


def reduced():
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, d_ff=128,
        vocab=512, n_audio_frames=32, dtype="float32")
