"""qwen2-vl-2b: VLM with M-RoPE + dynamic resolution [arXiv:2409.12191; hf].

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936, head_dim=128,
M-RoPE sections (16, 24, 24).  Vision frontend is a STUB: input_specs
provides precomputed patch embeddings.  Full attention -> long_500k
SKIPPED.
"""
import dataclasses
from repro.models.lm import LMConfig

ARCH_ID = "qwen2-vl-2b"
FAMILY = "vlm"

CONFIG = LMConfig(
    name=ARCH_ID, n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2,
    d_ff=8960, vocab=151936, head_dim=128, mrope_sections=(16, 24, 24),
    rope_theta=1000000.0)


def reduced():
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab=512, head_dim=16, mrope_sections=(2, 3, 3), dtype="float32")
