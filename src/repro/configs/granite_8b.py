"""granite-8b: llama-arch code model [arXiv:2405.04324; hf].

36L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=49152.
Full attention -> long_500k SKIPPED.
"""
import dataclasses
from repro.models.lm import LMConfig

ARCH_ID = "granite-8b"
FAMILY = "lm"

CONFIG = LMConfig(
    name=ARCH_ID, n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=49152)


def reduced():
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab=512, dtype="float32")
