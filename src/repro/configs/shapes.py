"""Assigned input shapes and per-(arch, shape) applicability.

Shape cells (LM-family; seq_len x global_batch):
  * train_4k    — seq 4096,   batch 256  -> train_step
  * prefill_32k — seq 32768,  batch 32   -> prefill_step
  * decode_32k  — 1 new token, KV cache 32768, batch 128 -> serve_step
  * long_500k   — 1 new token, context 524288, batch 1   -> serve_step,
                  sub-quadratic archs only (SSM / hybrid / SWA)

Skips (DESIGN.md §Arch-applicability): ``long_500k`` is skipped for pure
full-attention archs; all other cells run for all 10 archs.
"""

from __future__ import annotations

import dataclasses

SHAPES = {
    "train_4k": dict(kind="train", seq_len=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq_len=32768, global_batch=128),
    "long_500k": dict(kind="decode", seq_len=524288, global_batch=1),
}

# Archs whose context cost is sub-quadratic (run long_500k).
SUBQUADRATIC = {
    "h2o-danube-1.8b",      # SWA window 4096 (ring cache)
    "recurrentgemma-9b",    # RG-LRU + local attention
    "falcon-mamba-7b",      # SSM, constant state
}


def cells(arch_ids):
    """All (arch, shape, runnable, reason) cells — 40 total for the 10
    assigned archs."""
    out = []
    for a in arch_ids:
        for s in SHAPES:
            if s == "long_500k" and a not in SUBQUADRATIC:
                out.append((a, s, False, "full attention: O(S^2) at 512k"))
            else:
                out.append((a, s, True, ""))
    return out
