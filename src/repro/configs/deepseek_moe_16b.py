"""deepseek-moe-16b: fine-grained MoE, 2 shared + 64 routed top-6
[arXiv:2401.06066; hf].

28L d_model=2048 16H (kv=16, i.e. MHA) routed-expert d_ff=1408
vocab=102400; layer 0 is a dense MLP (d_ff=10944 per the paper).
Full attention -> long_500k SKIPPED.
"""
import dataclasses
from repro.models.lm import LMConfig

ARCH_ID = "deepseek-moe-16b"
FAMILY = "lm"

CONFIG = LMConfig(
    name=ARCH_ID, n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab=102400, pattern="moe", n_experts=64, top_k=6,
    n_shared=2, moe_d_ff=1408, first_dense=True, dense_d_ff=10944)


def reduced():
    return dataclasses.replace(
        CONFIG, n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_ff=32,
        vocab=512, n_experts=8, top_k=2, n_shared=1, moe_d_ff=32,
        dense_d_ff=128, capacity_factor=8.0, dtype="float32")
