"""Architecture registry: one module per assigned architecture.

``get_config(arch_id)`` returns the exact published configuration;
``reduced_config(arch_id)`` returns a structurally identical small config
for CPU smoke tests.  Input shapes live in ``repro.configs.shapes``.
"""

from __future__ import annotations

import dataclasses
import importlib

ARCH_IDS = (
    "whisper-tiny",
    "h2o-danube-1.8b",
    "granite-8b",
    "qwen3-8b",
    "qwen2.5-32b",
    "grok-1-314b",
    "deepseek-moe-16b",
    "recurrentgemma-9b",
    "falcon-mamba-7b",
    "qwen2-vl-2b",
    # paper-pipeline example model (not an assigned arch)
    "bytelm-100m",
)

_MODULES = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def get_module(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")


def get_config(arch_id: str):
    return get_module(arch_id).CONFIG


def reduced_config(arch_id: str):
    return get_module(arch_id).reduced()
