"""grok-1-314b: 8-expert top-2 MoE [hf:xai-org/grok-1; unverified].

64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072, MoE 8e top-2.
Full attention -> long_500k SKIPPED.
"""
import dataclasses
from repro.models.lm import LMConfig

ARCH_ID = "grok-1-314b"
FAMILY = "lm"

CONFIG = LMConfig(
    name=ARCH_ID, n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=32768, vocab=131072, pattern="moe", n_experts=8, top_k=2)


def reduced():
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab=512, n_experts=4, top_k=2, capacity_factor=8.0, dtype="float32")
