"""bytelm-100m: the paper-pipeline example model (not an assigned arch).

A ~100M-param byte-level LM trained directly on the output of the
UTF-8 ingest pipeline (repro.data.pipeline) -- the end-to-end driver
demonstrating the paper's technique as a first-class framework feature.
"""
import dataclasses
from repro.models.lm import LMConfig

ARCH_ID = "bytelm-100m"
FAMILY = "lm"

CONFIG = LMConfig(
    name=ARCH_ID, n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
    d_ff=2048, vocab=259)


def reduced():
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        dtype="float32")
