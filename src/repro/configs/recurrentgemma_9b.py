"""recurrentgemma-9b: Griffin hybrid, RG-LRU + local attention 1:2
[arXiv:2402.19427; unverified].

38L d_model=4096 16H (GQA kv=1, MQA) d_ff=12288 vocab=256000; pattern =
(rec, rec, local-attn) x12 + 2 rec; local window 2048.
Sub-quadratic -> long_500k RUNS (RG-LRU state + ring window cache).
"""
import dataclasses
from repro.models.lm import LMConfig

ARCH_ID = "recurrentgemma-9b"
FAMILY = "lm"

CONFIG = LMConfig(
    name=ARCH_ID, n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1,
    d_ff=12288, vocab=256000, pattern="griffin", local_window=2048)


def reduced():
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=64, n_heads=4, n_kv_heads=1, d_ff=128,
        vocab=512, local_window=16, dtype="float32")
