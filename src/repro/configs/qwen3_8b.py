"""qwen3-8b: qk_norm + GQA [hf:Qwen/Qwen3-8B; hf].

36L d_model=4096 32H (GQA kv=8) d_ff=12288 vocab=151936, head_dim=128,
qk_norm.  Full attention -> long_500k SKIPPED.
"""
import dataclasses
from repro.models.lm import LMConfig

ARCH_ID = "qwen3-8b"
FAMILY = "lm"

CONFIG = LMConfig(
    name=ARCH_ID, n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=12288, vocab=151936, head_dim=128, qk_norm=True,
    rope_theta=1000000.0)


def reduced():
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab=512, head_dim=16, dtype="float32")
