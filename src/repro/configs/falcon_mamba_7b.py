"""falcon-mamba-7b: attention-free Mamba-1 [arXiv:2410.05355; unverified].

64L d_model=4096 (no attention) vocab=65024, ssm_state=16, expand=2.
Attention-free -> long_500k RUNS (constant-size recurrent state).
"""
import dataclasses
from repro.models.lm import LMConfig

ARCH_ID = "falcon-mamba-7b"
FAMILY = "lm"

CONFIG = LMConfig(
    name=ARCH_ID, n_layers=64, d_model=4096, n_heads=1, n_kv_heads=1,
    d_ff=0, vocab=65024, pattern="mamba", ssm_state=16)


def reduced():
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, vocab=512, dtype="float32")
