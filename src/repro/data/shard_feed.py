"""Double-buffered host->device feeder for the sharded transcode path.

The sharded ragged launch (``repro.core.shard``) removes the single-
device compute bound; this module removes the transfer bound.  A wave's
input (one :class:`~repro.core.shard.ShardPlan`'s stacked per-shard
arrays) is staged with ``jax.device_put`` against a
``NamedSharding(mesh, P("data"))`` — row k of the stacked layout lands
on device k, the device-side half of the pipeline's deterministic host
sharding (``repro.data.pipeline``: host k owns slot k mod n_hosts, so
host k feeds device shard k).  Staging runs on a one-worker thread so
wave k+1's host->device copies overlap wave k's kernel execution:

    stage thread:   [H2D wave0]      [H2D wave1]      [H2D wave2]
    main thread:         [kernel wave0]   [kernel wave1]   [kernel wave2]
                         ^ waits only for the UNHIDDEN tail of each H2D

Per wave the feeder records the measured staging time (``transfer_s``),
the kernel time (``compute_s``) and the residual wait the main thread
actually paid after its kernel finished (``stall_s``).  The
transfer-hidden fraction — ``1 - sum(stall)/sum(transfer)`` over the
steady-state waves (the first wave has no kernel to hide behind) — is
the ``table_shard`` bench's gated metric.

Buffer donation: the launch callables built by
:func:`repro.core.shard.sharded_call` with ``donate=True`` donate the
staged input buffers to XLA — a wave's inputs are single-use, so their
device memory is recycled for the outputs instead of growing the
footprint by a wave per step.

Failure semantics (DESIGN.md §10): a stage-thread exception, a launch
exception, or a watchdog timeout on either is a **typed per-wave
error** — the wave's slot in ``results`` holds a :class:`WaveFailure`
(wave index, phase, cause) instead of an output, and the pipeline keeps
flowing: the NEXT wave's staging is already dispatched before the
failed wave is recorded, so one poisoned wave never stalls its
successors.  ``watchdog_s`` bounds a hung transfer or kernel on the
injectable clock (see :func:`repro.core.recovery.call_with_watchdog`);
a tripped watchdog abandons the hung work and records the wave as
failed.  A hung STAGE would wedge the one-worker staging pool (the
next wave's stage could never start), so a stage-watchdog trip also
respawns the pool on a fresh worker — the wedged thread is abandoned
with its executor and unblocks (releasing its staged buffers) whenever
the hung call finally returns.  ``run`` never orphans an in-flight
staging future — whatever
exits the loop (including an exception from the ``waves`` iterator
itself, or a launch error with ``isolate=False``), the pending future
is cancelled-or-drained in a ``finally`` so staged device buffers are
released and ``close()`` cannot block on work nobody will consume.
"""

from __future__ import annotations

import dataclasses
import time
from concurrent.futures import CancelledError, ThreadPoolExecutor
from concurrent.futures import TimeoutError as _FutureTimeout
from typing import List, NamedTuple, Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import recovery
from repro.testing import faults


class WaveStats(NamedTuple):
    """Per-wave feeder timings (seconds)."""

    transfer_s: float   # host->device staging (device_put + ready)
    compute_s: float    # kernel execution (launch + ready)
    stall_s: float      # residual staging wait paid AFTER compute


@dataclasses.dataclass(frozen=True)
class WaveFailure:
    """Typed per-wave error: what failed (``phase``: ``"stage"`` |
    ``"launch"``), on which wave, and why.  Occupies the failed wave's
    slot in ``run``'s results so wave order — and every subsequent
    wave — is preserved."""

    wave: int
    phase: str
    error: BaseException

    def __str__(self):
        return (f"wave {self.wave} failed in {self.phase}: "
                f"{type(self.error).__name__}: {self.error}")


class DoubleBufferedFeeder:
    """Stage wave k+1's host->device transfer against wave k's kernel.

    ``stage_fn(arrays) -> staged`` may be injected for tests; the
    default places each array with ``NamedSharding(mesh, P("data"))``
    (leading axis = shard axis) and blocks until the copies land.

    ``watchdog_s`` bounds each wave's staging wait and kernel launch on
    ``clock`` (None = unbounded); ``isolate=True`` (default) records
    stage/launch/watchdog failures as :class:`WaveFailure` results and
    keeps the pipeline flowing, ``isolate=False`` re-raises launch
    errors (stage errors still surface typed — the staging thread's
    exception was never deliverable any other way).
    """

    def __init__(self, mesh, stage_fn=None, clock=time.perf_counter,
                 watchdog_s: Optional[float] = None,
                 isolate: bool = True, poll_s: float = 0.005):
        self.mesh = mesh
        self.sharding = NamedSharding(mesh, P("data"))
        self._stage_fn = stage_fn or self._device_put
        self._clock = clock
        self._watchdog_s = watchdog_s
        self._isolate = bool(isolate)
        self._poll_s = poll_s
        # ONE worker: staging order must stay wave order, and a single
        # in-flight transfer is exactly the double buffer.
        self._pool = ThreadPoolExecutor(max_workers=1)
        self._inflight = None

    def _device_put(self, arrays):
        staged = tuple(jax.device_put(a, self.sharding) for a in arrays)
        jax.block_until_ready(staged)
        return staged

    def _timed_stage(self, arrays):
        t0 = self._clock()
        arrays = faults.fire(faults.FEED_STAGE, arrays)
        staged = self._stage_fn(arrays)
        return staged, self._clock() - t0

    def _submit(self, arrays):
        fut = self._pool.submit(self._timed_stage, arrays)
        self._inflight = fut
        return fut

    def _await_staged(self, fut):
        """Block on the staging future, bounded by the watchdog.  A trip
        abandons the stage (the worker thread keeps running; its result
        is dropped when the future is drained) and raises
        :class:`~repro.core.recovery.WatchdogTimeout`."""
        if self._watchdog_s is None:
            return fut.result()
        deadline = self._clock() + self._watchdog_s
        while True:
            try:
                return fut.result(timeout=self._poll_s)
            except _FutureTimeout:
                if self._clock() >= deadline:
                    raise recovery.WatchdogTimeout(
                        "host->device staging", self._watchdog_s)

    def _bounded_launch(self, launch, staged):
        def _go():
            out = launch(*staged)
            return jax.block_until_ready(out)

        if self._watchdog_s is None:
            return _go()
        return recovery.call_with_watchdog(
            _go, self._watchdog_s, clock=self._clock,
            poll_s=self._poll_s, what="wave kernel launch")

    def run(self, waves, launch) -> Tuple[list, List[WaveStats]]:
        """Pipeline ``launch(*staged)`` over ``waves`` (an iterable of
        tuples of host arrays).  Returns ``(results, per-wave stats)``
        in wave order; results are blocked-on (ready), and a failed
        wave's slot holds a :class:`WaveFailure` (module docstring:
        failure semantics)."""
        it = iter(waves)
        results: list = []
        stats: List[WaveStats] = []
        try:
            try:
                first = next(it)
            except StopIteration:
                return [], []
            fut = self._submit(first)
            wave = 0
            while fut is not None:
                t0 = self._clock()
                staged = failure = None
                transfer_s = 0.0
                try:
                    staged, transfer_s = self._await_staged(fut)
                except Exception as e:      # noqa: BLE001 — typed below
                    failure = WaveFailure(wave, "stage", e)
                    if isinstance(e, recovery.WatchdogTimeout):
                        # The hung stage has the ONE worker wedged; the
                        # next wave needs a fresh one (module docstring).
                        self._respawn_pool()
                stall_s = self._clock() - t0
                self._inflight = None
                # Dispatch the NEXT wave's copies before launching this
                # wave's kernel — the overlap window.  Doing it before
                # the failure is recorded is what isolates a poisoned
                # wave: its successors are already in flight.
                try:
                    fut = self._submit(next(it))
                except StopIteration:
                    fut = None
                compute_s = 0.0
                out = None
                if failure is None:
                    t0 = self._clock()
                    try:
                        out = self._bounded_launch(launch, staged)
                    except Exception as e:  # noqa: BLE001 — typed below
                        if not self._isolate:
                            raise
                        failure = WaveFailure(wave, "launch", e)
                    compute_s = self._clock() - t0
                results.append(out if failure is None else failure)
                stats.append(WaveStats(transfer_s, compute_s, stall_s))
                wave += 1
            return results, stats
        finally:
            # Whatever exits the loop — normal completion (no-op), a
            # raising ``waves`` iterator, or a launch error with
            # isolate=False — the in-flight staging future must not be
            # orphaned: cancel it if it hasn't started, drain it if it
            # has, so its staged buffers release and close() can't
            # block on it.
            self._drain_inflight()

    def _respawn_pool(self):
        """Abandon the pool (and its wedged worker) without joining it;
        stage subsequent waves on a fresh one-worker pool."""
        self._pool.shutdown(wait=False, cancel_futures=True)
        self._pool = ThreadPoolExecutor(max_workers=1)

    def _drain_inflight(self):
        fut, self._inflight = self._inflight, None
        if fut is None or fut.cancel():
            return
        try:
            # Already running: consume the result so the staged device
            # buffers are released.  Bounded by the watchdog when one
            # is set (a hung stage is abandoned, not waited out).
            fut.result(timeout=self._watchdog_s)
        except (Exception, CancelledError):   # noqa: BLE001 — drain only
            pass

    def close(self, wait: bool = True):
        """Shut the staging pool down.  Pending (not-yet-running) work
        is cancelled; ``wait=False`` additionally abandons a running
        hung stage instead of blocking on it — the mid-failure escape
        hatch."""
        fut, self._inflight = self._inflight, None
        if fut is not None:
            fut.cancel()
        self._pool.shutdown(wait=wait, cancel_futures=True)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


def hidden_fraction(stats: List[WaveStats]) -> float:
    """Fraction of measured host->device transfer time hidden behind
    kernel execution over the steady-state waves.

    Wave 0's transfer has no preceding kernel to hide behind, so it is
    excluded; each later wave's unhidden cost is the stall its consumer
    actually paid.  1.0 = every transfer fully overlapped; 0.0 = the
    pipeline serialized.  Returns 0.0 when there is no steady state
    (fewer than two waves) or no measurable transfer time.
    """
    tail = stats[1:]
    transfer = sum(s.transfer_s for s in tail)
    if transfer <= 0.0:
        return 0.0
    stall = sum(s.stall_s for s in tail)
    return max(0.0, min(1.0, 1.0 - stall / transfer))


def run_sharded_waves(mesh, plans, *, src: str, dst: str,
                      validate: bool = True, errors: str = "strict",
                      interpret=None,
                      watchdog_s: Optional[float] = None,
                      isolate: bool = True):
    """Drive a sequence of :class:`~repro.core.shard.ShardPlan` waves
    through the donated sharded launch with double-buffered staging.

    Returns ``(raw per-wave outputs, stats)``; each raw output is the
    per-shard ``(buffers, out_offsets, counts, statuses)`` stack —
    gather with :func:`repro.core.shard._gather_result` (or consume the
    per-shard results directly, e.g. the serve engine's ingress, which
    only needs counts/statuses per fragment).  A failed wave's slot is
    a :class:`WaveFailure` (``isolate=False`` re-raises launch errors
    instead).
    """
    from repro.core import shard as shard_mod
    from repro.kernels import runtime

    fn = shard_mod.sharded_call(mesh, src, dst, bool(validate), errors,
                                runtime.resolve_interpret(interpret),
                                donate=True)
    with DoubleBufferedFeeder(mesh, watchdog_s=watchdog_s,
                              isolate=isolate) as feeder:
        return feeder.run(
            ((p.data, p.offsets, p.lengths) for p in plans), fn)
