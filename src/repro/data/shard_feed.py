"""Double-buffered host->device feeder for the sharded transcode path.

The sharded ragged launch (``repro.core.shard``) removes the single-
device compute bound; this module removes the transfer bound.  A wave's
input (one :class:`~repro.core.shard.ShardPlan`'s stacked per-shard
arrays) is staged with ``jax.device_put`` against a
``NamedSharding(mesh, P("data"))`` — row k of the stacked layout lands
on device k, the device-side half of the pipeline's deterministic host
sharding (``repro.data.pipeline``: host k owns slot k mod n_hosts, so
host k feeds device shard k).  Staging runs on a one-worker thread so
wave k+1's host->device copies overlap wave k's kernel execution:

    stage thread:   [H2D wave0]      [H2D wave1]      [H2D wave2]
    main thread:         [kernel wave0]   [kernel wave1]   [kernel wave2]
                         ^ waits only for the UNHIDDEN tail of each H2D

Per wave the feeder records the measured staging time (``transfer_s``),
the kernel time (``compute_s``) and the residual wait the main thread
actually paid after its kernel finished (``stall_s``).  The
transfer-hidden fraction — ``1 - sum(stall)/sum(transfer)`` over the
steady-state waves (the first wave has no kernel to hide behind) — is
the ``table_shard`` bench's gated metric.

Buffer donation: the launch callables built by
:func:`repro.core.shard.sharded_call` with ``donate=True`` donate the
staged input buffers to XLA — a wave's inputs are single-use, so their
device memory is recycled for the outputs instead of growing the
footprint by a wave per step.
"""

from __future__ import annotations

import time
from concurrent.futures import ThreadPoolExecutor
from typing import List, NamedTuple, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


class WaveStats(NamedTuple):
    """Per-wave feeder timings (seconds)."""

    transfer_s: float   # host->device staging (device_put + ready)
    compute_s: float    # kernel execution (launch + ready)
    stall_s: float      # residual staging wait paid AFTER compute


class DoubleBufferedFeeder:
    """Stage wave k+1's host->device transfer against wave k's kernel.

    ``stage_fn(arrays) -> staged`` may be injected for tests; the
    default places each array with ``NamedSharding(mesh, P("data"))``
    (leading axis = shard axis) and blocks until the copies land.
    """

    def __init__(self, mesh, stage_fn=None, clock=time.perf_counter):
        self.mesh = mesh
        self.sharding = NamedSharding(mesh, P("data"))
        self._stage_fn = stage_fn or self._device_put
        self._clock = clock
        # ONE worker: staging order must stay wave order, and a single
        # in-flight transfer is exactly the double buffer.
        self._pool = ThreadPoolExecutor(max_workers=1)

    def _device_put(self, arrays):
        staged = tuple(jax.device_put(a, self.sharding) for a in arrays)
        jax.block_until_ready(staged)
        return staged

    def _timed_stage(self, arrays):
        t0 = self._clock()
        staged = self._stage_fn(arrays)
        return staged, self._clock() - t0

    def run(self, waves, launch) -> Tuple[list, List[WaveStats]]:
        """Pipeline ``launch(*staged)`` over ``waves`` (an iterable of
        tuples of host arrays).  Returns ``(results, per-wave stats)``;
        results are blocked-on (ready) in wave order."""
        it = iter(waves)
        try:
            first = next(it)
        except StopIteration:
            return [], []
        fut = self._pool.submit(self._timed_stage, first)
        results: list = []
        stats: List[WaveStats] = []
        while fut is not None:
            t0 = self._clock()
            staged, transfer_s = fut.result()
            stall_s = self._clock() - t0
            try:
                # Dispatch the NEXT wave's copies before launching this
                # wave's kernel — the overlap window.
                fut = self._pool.submit(self._timed_stage, next(it))
            except StopIteration:
                fut = None
            t0 = self._clock()
            out = launch(*staged)
            out = jax.block_until_ready(out)
            compute_s = self._clock() - t0
            results.append(out)
            stats.append(WaveStats(transfer_s, compute_s, stall_s))
        return results, stats

    def close(self):
        self._pool.shutdown(wait=True)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


def hidden_fraction(stats: List[WaveStats]) -> float:
    """Fraction of measured host->device transfer time hidden behind
    kernel execution over the steady-state waves.

    Wave 0's transfer has no preceding kernel to hide behind, so it is
    excluded; each later wave's unhidden cost is the stall its consumer
    actually paid.  1.0 = every transfer fully overlapped; 0.0 = the
    pipeline serialized.  Returns 0.0 when there is no steady state
    (fewer than two waves) or no measurable transfer time.
    """
    tail = stats[1:]
    transfer = sum(s.transfer_s for s in tail)
    if transfer <= 0.0:
        return 0.0
    stall = sum(s.stall_s for s in tail)
    return max(0.0, min(1.0, 1.0 - stall / transfer))


def run_sharded_waves(mesh, plans, *, src: str, dst: str,
                      validate: bool = True, errors: str = "strict",
                      interpret=None):
    """Drive a sequence of :class:`~repro.core.shard.ShardPlan` waves
    through the donated sharded launch with double-buffered staging.

    Returns ``(raw per-wave outputs, stats)``; each raw output is the
    per-shard ``(buffers, out_offsets, counts, statuses)`` stack —
    gather with :func:`repro.core.shard._gather_result` (or consume the
    per-shard results directly, e.g. the serve engine's ingress, which
    only needs counts/statuses per fragment).
    """
    from repro.core import shard as shard_mod
    from repro.kernels import runtime

    fn = shard_mod.sharded_call(mesh, src, dst, bool(validate), errors,
                                runtime.resolve_interpret(interpret),
                                donate=True)
    with DoubleBufferedFeeder(mesh) as feeder:
        return feeder.run(
            ((p.data, p.offsets, p.lengths) for p in plans), fn)
