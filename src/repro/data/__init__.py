from repro.data import synthetic, tokenizer, pipeline  # noqa: F401
