"""Tokenizers built on the transcoding core.

Two tokenizers are provided, both of which consume the *device-resident*
output of ``repro.core`` (validated bytes / code points) so the entire
ingest path — validate, transcode, tokenize, pack — runs as one jitted
program:

  * ``ByteTokenizer`` — byte-level LM vocabulary (256 byte values shifted
    past the special tokens).  The data pipeline ships raw UTF-8 and the
    validation kernel guarantees well-formedness.
  * ``CodepointTokenizer`` — code-point-level vocabulary for arbitrary
    ``vocab_size``: code points below the printable cutoff map directly,
    the rest fold via a multiplicative hash.  Used to exercise the large
    embedding tables of the assigned architectures with realistic token
    statistics.

Detokenization is the egress path: ids -> code points -> UTF-8/UTF-16 via
``repro.core.utf32`` (serving uses this to answer in either encoding).
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

PAD_ID = 0
BOS_ID = 1
EOS_ID = 2
N_SPECIAL = 3


@dataclasses.dataclass(frozen=True)
class ByteTokenizer:
    vocab_size: int = 256 + N_SPECIAL

    def encode(self, b: jnp.ndarray) -> jnp.ndarray:
        """uint8/int32 UTF-8 bytes -> int32 token ids."""
        return b.astype(jnp.int32) + N_SPECIAL

    def decode(self, ids: jnp.ndarray) -> jnp.ndarray:
        """token ids -> UTF-8 byte values (specials -> 0)."""
        b = ids.astype(jnp.int32) - N_SPECIAL
        return jnp.where(b >= 0, b, 0)


@dataclasses.dataclass(frozen=True)
class CodepointTokenizer:
    """Code points -> ids in [0, vocab_size) with a direct low range."""
    vocab_size: int
    direct: int = 0x3000  # BMP scripts below this map 1:1

    def encode(self, cp: jnp.ndarray) -> jnp.ndarray:
        cp = cp.astype(jnp.int32)
        direct = min(self.direct, self.vocab_size - N_SPECIAL - 1)
        # Knuth multiplicative hash in uint32 (wraps, no overflow)
        h = (cp.astype(jnp.uint32) * jnp.uint32(2654435761)).astype(jnp.uint32)
        folded = direct + (h % jnp.uint32(
            self.vocab_size - N_SPECIAL - direct)).astype(jnp.int32)
        ids = jnp.where(cp < direct, cp, folded)
        return ids + N_SPECIAL

    def decode(self, ids: jnp.ndarray) -> jnp.ndarray:
        """Best-effort inverse (exact only for the direct range)."""
        cp = ids.astype(jnp.int32) - N_SPECIAL
        return jnp.clip(cp, 0, 0x10FFFF)
