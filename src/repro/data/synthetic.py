"""Synthetic multilingual corpora mirroring the paper's Table 4 datasets.

The paper benchmarks on lipsum files whose defining property is the mix of
UTF-8 byte lengths per character (1/2/3/4).  We reproduce those mixes with
seeded generators drawing code points from the real Unicode blocks of each
language, so the transcoder benchmarks stress exactly the same code paths
(ASCII fast path, 2-byte Arabic/Hebrew/Russian, 3-byte CJK, 4-byte emoji).
"""

from __future__ import annotations

import dataclasses

import numpy as np

# Code-point pools per UTF-8 byte-length class, per script.
_ASCII = (0x20, 0x7E)
_POOLS = {
    "arabic2": (0x0621, 0x064A),
    "hebrew2": (0x05D0, 0x05EA),
    "cyrillic2": (0x0410, 0x044F),
    "latin2": (0x00C0, 0x00FF),
    "greek2": (0x0391, 0x03C9),
    "cjk3": (0x4E00, 0x9FA5),
    "kana3": (0x3041, 0x30FE),
    "hangul3": (0xAC00, 0xD7A3),
    "devanagari3": (0x0901, 0x0963),
    "thai3": (0x0E01, 0x0E5B),
    "emoji4": (0x1F300, 0x1F6FF),
}


@dataclasses.dataclass(frozen=True)
class LangProfile:
    """Byte-length percentages (Table 4a) + code-point pools per class."""
    name: str
    pct: tuple  # (1-byte, 2-byte, 3-byte, 4-byte), sums to 100
    pool2: str = "latin2"
    pool3: str = "cjk3"


# Table 4 (a), lipsum datasets: percentage of characters per UTF-8 length.
LANG_PROFILES = {
    "arabic": LangProfile("arabic", (22, 78, 0, 0), pool2="arabic2"),
    "chinese": LangProfile("chinese", (1, 0, 99, 0)),
    "emoji": LangProfile("emoji", (0, 0, 0, 100)),
    "hebrew": LangProfile("hebrew", (22, 78, 0, 0), pool2="hebrew2"),
    "hindi": LangProfile("hindi", (16, 0, 84, 0), pool3="devanagari3"),
    "japanese": LangProfile("japanese", (5, 0, 95, 0), pool3="kana3"),
    "korean": LangProfile("korean", (27, 1, 72, 0), pool3="hangul3"),
    "latin": LangProfile("latin", (100, 0, 0, 0)),
    "russian": LangProfile("russian", (19, 81, 0, 0), pool2="cyrillic2"),
}

# Table 4 (b), wikipedia-Mars: much more ASCII-heavy mixes.
WIKI_PROFILES = {
    "arabic": LangProfile("arabic", (75, 25, 0, 0), pool2="arabic2"),
    "chinese": LangProfile("chinese", (84, 1, 15, 0)),
    "czech": LangProfile("czech", (95, 5, 0, 0)),
    "english": LangProfile("english", (100, 0, 0, 0)),
    "french": LangProfile("french", (98, 2, 0, 0)),
    "greek": LangProfile("greek", (74, 26, 0, 0), pool2="greek2"),
    "hebrew": LangProfile("hebrew", (71, 29, 0, 0), pool2="hebrew2"),
    "hindi": LangProfile("hindi", (78, 0, 22, 0), pool3="devanagari3"),
    "japanese": LangProfile("japanese", (80, 1, 19, 0), pool3="kana3"),
    "korean": LangProfile("korean", (82, 1, 17, 0), pool3="hangul3"),
    "russian": LangProfile("russian", (70, 30, 0, 0), pool2="cyrillic2"),
    "thai": LangProfile("thai", (77, 0, 23, 0), pool3="thai3"),
}


def _sample_codepoints(profile: LangProfile, n_chars: int,
                       rng: np.random.Generator) -> np.ndarray:
    p = np.asarray(profile.pct, np.float64)
    p = p / p.sum()
    cls = rng.choice(4, size=n_chars, p=p)
    cp = np.empty(n_chars, np.int64)
    pools = [_ASCII, _POOLS[profile.pool2], _POOLS[profile.pool3],
             _POOLS["emoji4"]]
    for k in range(4):
        m = cls == k
        lo, hi = pools[k]
        cp[m] = rng.integers(lo, hi + 1, size=int(m.sum()))
    # space word boundaries roughly every 6 chars keeps text realistic
    # without disturbing the ASCII share materially for non-latin scripts.
    return cp


def generate_codepoints(lang: str, n_chars: int, seed: int = 0,
                        profiles=None) -> np.ndarray:
    profiles = profiles or LANG_PROFILES
    rng = np.random.default_rng(seed + hash(lang) % (1 << 31))
    return _sample_codepoints(profiles[lang], n_chars, rng)


def generate_utf8(lang: str, n_chars: int, seed: int = 0,
                  profiles=None) -> bytes:
    cp = generate_codepoints(lang, n_chars, seed, profiles)
    return "".join(map(chr, cp)).encode("utf-8")


def generate_utf16le(lang: str, n_chars: int, seed: int = 0,
                     profiles=None) -> bytes:
    cp = generate_codepoints(lang, n_chars, seed, profiles)
    return "".join(map(chr, cp)).encode("utf-16-le")


def utf8_array(lang: str, n_chars: int, seed: int = 0) -> np.ndarray:
    """uint8 numpy array of UTF-8 bytes (the benchmark/pipeline input)."""
    return np.frombuffer(generate_utf8(lang, n_chars, seed), np.uint8)


def utf16_units(lang: str, n_chars: int, seed: int = 0) -> np.ndarray:
    """uint16 numpy array of UTF-16LE code units."""
    return np.frombuffer(generate_utf16le(lang, n_chars, seed), np.uint16)
