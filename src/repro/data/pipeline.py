"""Sharded data pipeline: raw UTF-8 -> validated, packed token batches.

Design (DESIGN.md §2): the host ships **raw UTF-8 bytes** to the device —
2–4x less host-to-device bandwidth than pre-decoded UTF-32 — and the device
runs the paper's validation/transcoding as the first stage of the jitted
input program.  This is precisely the paper's system claim (transcoding at
line rate so ingest is never the bottleneck) applied to an accelerator.

Fault-tolerance properties (system prompt: straggler mitigation, elastic
restart):

  * **Deterministic sharding**: document k of global step s belongs to host
    ``(s * global_batch + k) % n_hosts``; any host can recompute any shard,
    so a restarted/replaced host rejoins at a global step boundary with
    ``skip_to(step)`` and no coordination.
  * **Stateless generators**: the synthetic corpus is a pure function of
    (seed, step, slot), so skip-ahead is O(1) — no replaying of the stream.
  * **Elastic re-shard**: changing ``n_hosts`` re-partitions the same
    global document sequence; the global batch content at a given step is
    invariant to the host count.
"""

from __future__ import annotations

import dataclasses
import functools

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import packing
from repro.core import transcode as tc
from repro.data import synthetic
from repro.testing import faults
from repro.data.tokenizer import BOS_ID, EOS_ID, PAD_ID, ByteTokenizer


# ---------------------------------------------------------------------------
# Batched transcoding entry points.
#
# Two batch geometries share one API (inputs: fixed-capacity [B, L]
# buffers of narrow dtype plus a [B] vector of logical lengths; outputs:
# a TranscodeResult of batched arrays — [B, cap] buffers, [B] counts,
# [B] statuses, per-document first-error offsets, -1 where valid):
#
#   * ``strategy="packed"`` (default) — the ragged packed path: the
#     [B, L] buffer is reinterpreted as ONE tile-aligned packed stream
#     (row-major flattening IS the packed layout once L is padded to a
#     tile multiple) and the single-pass kernel runs as ONE grid launch
#     for the whole batch (DESIGN.md §7/§9 — the default ragged strategy
#     is "onepass": one read + one decode, segment scan carried in
#     SMEM); the dense ragged output is re-padded to the [B, cap]
#     contract with one gather.  Callers that can consume the dense
#     layout directly should use ``tc.ragged_transcode`` on a
#     ``packing.pack_documents`` batch and skip both the padding and the
#     re-pad gather.
#   * ``strategy="vmap"`` — the padded reference: ``jax.vmap`` of the
#     single-document default (one-pass) transcoder over the document
#     axis (B grid dispatches, every document scans all of L).  A
#     per-document strategy name ("onepass" / "fused" / "blockparallel"
#     / "windowed") selects that transcoder under vmap, as before.
#
# The ``errors=`` policy threads through both, so a batch of partially-
# malformed documents can ingest losslessly (errors="replace": U+FFFD
# per maximal subpart) without a host round trip.

# Jitted vmap callables, keyed per (pair, strategy, validate,
# errors, capacity).  Capacity is part of the key: a [B, L] batch
# compiles per distinct L anyway (shapes are static), so an unkeyed
# entry would silently accumulate one trace per capacity inside a
# single cache slot with nothing bounding the set.  Keying + the LRU
# bound below make the retrace budget explicit and bounded.
_BATCH_CACHE: "dict" = {}
_BATCH_CACHE_MAX = 16


def _batched(src: str, dst: str, strategy: str, validate: bool, errors: str,
             capacity: int):
    key = (src, dst, strategy, validate, errors, capacity)
    fn = _BATCH_CACHE.get(key)
    if fn is None:
        fn = jax.jit(jax.vmap(
            lambda x, n: tc.transcode(x, dst, src_format=src, n_valid=n,
                                      strategy=strategy, validate=validate,
                                      errors=errors)))
        while len(_BATCH_CACHE) >= _BATCH_CACHE_MAX:
            _BATCH_CACHE.pop(next(iter(_BATCH_CACHE)))
        _BATCH_CACHE[key] = fn
    else:
        # LRU refresh: dicts iterate in insertion order, so re-inserting
        # on hit keeps hot entries at the back and evicts the coldest.
        _BATCH_CACHE.pop(key)
        _BATCH_CACHE[key] = fn
    return fn


_TILE = packing.TILE


def _rows_as_packed(docs):
    """[B, L] row buffers -> tile-aligned packed stream (zero repack).

    Pads the capacity axis to a tile multiple; row-major flattening then
    satisfies the packed-layout invariant (tile-aligned starts), so the
    offsets vector is just ``arange(B+1) * Lp``.
    """
    b, cap = docs.shape
    cap_p = -(-cap // _TILE) * _TILE
    if cap_p != cap:
        docs = jnp.pad(docs, ((0, 0), (0, cap_p - cap)))
    offsets = jnp.arange(b + 1, dtype=jnp.int32) * cap_p
    return docs.reshape(-1), offsets


def _repad(res, out_cap: int):
    """Dense ragged output -> the padded [B, cap] batch contract."""
    j = jnp.arange(out_cap, dtype=jnp.int32)[None, :]
    src = res.offsets[:-1, None] + j
    valid = j < res.counts[:, None]
    src = jnp.clip(src, 0, res.buffer.shape[0] - 1)
    out = jnp.where(valid, res.buffer[src], 0)
    return tc.TranscodeResult(out, res.counts, res.statuses)


@functools.partial(jax.jit, static_argnames=("src", "dst", "validate",
                                             "errors", "out_cap"))
def _packed_batch(docs, lengths, src, dst, validate, errors, out_cap):
    data, offsets = _rows_as_packed(docs)
    res = tc.ragged_transcode(data, offsets, lengths, src_format=src,
                              dst_format=dst, validate=validate,
                              errors=errors)
    return _repad(res, out_cap)


def batch_transcode(docs, lengths, *, in_encoding: str = "utf8",
                    out_encoding: str = "utf16", strategy: str = "packed",
                    validate: bool = True, errors: str = "strict",
                    n_shards=None):
    """Batched transcode for any matrix cell: [B, L] narrow buffers ->
    TranscodeResult([B, cap_factor*L], [B], [B]).

    ``strategy="packed"`` (default) reinterprets the row-major batch as
    ONE tile-aligned packed stream and runs a single ragged one-pass
    launch; ``strategy="sharded"`` splits that same packed stream across
    the data axis of a device mesh — one onepass launch per shard,
    bit-identical gather (DESIGN.md §12; ``n_shards`` applies only
    here); ``strategy="vmap"`` maps the single-document default
    (one-pass) transcoder over the document axis (a per-document
    strategy name selects that transcoder under vmap instead).
    """
    faults.fire(faults.PIPELINE_BATCH)   # chaos-suite hook (no-op in prod)
    src = tc.normalize_format(in_encoding)
    dst = tc.normalize_format(out_encoding)
    if (src, dst) not in tc.CAP_FACTOR:
        raise ValueError(f"unsupported format pair {src!r} -> {dst!r}")
    factor = tc.CAP_FACTOR[(src, dst)]
    if n_shards is not None and strategy != "sharded":
        raise ValueError("n_shards requires strategy='sharded'")
    docs = jnp.asarray(docs)
    lengths = jnp.asarray(lengths)
    if strategy == "sharded":
        # The host-side splitter needs concrete arrays, so this path is
        # eager end-to-end (the shard_map launch itself is jitted and
        # cached inside repro.core.shard).
        from repro.kernels import stages
        narrow = np.asarray(docs).astype(stages.get_codec(src).dtype)
        data, offsets = _rows_as_packed(jnp.asarray(narrow))
        res = tc.ragged_transcode(
            np.asarray(data), np.asarray(offsets), np.asarray(lengths),
            src_format=src, dst_format=dst, validate=validate,
            errors=errors, strategy="sharded", n_shards=n_shards)
        return _repad(res, factor * docs.shape[1])
    if strategy == "packed":
        from repro.kernels import stages
        narrow = docs.astype(stages.get_codec(src).dtype)
        return _packed_batch(narrow, lengths, src, dst, validate, errors,
                             factor * docs.shape[1])
    per_doc = tc.DEFAULT_STRATEGY if strategy == "vmap" else strategy
    return _batched(src, dst, per_doc, validate, errors,
                    docs.shape[1])(docs, lengths)


def batch_utf8_to_utf16(docs, lengths, *, strategy: str = "packed",
                        validate: bool = True, errors: str = "strict"):
    """Batched UTF-8 -> UTF-16: [B, L] byte buffers -> ([B, L], [B], [B])."""
    return batch_transcode(docs, lengths, in_encoding="utf8",
                           out_encoding="utf16", strategy=strategy,
                           validate=validate, errors=errors)


def batch_utf16_to_utf8(units, lengths, *, strategy: str = "packed",
                        validate: bool = True, errors: str = "strict"):
    """Batched UTF-16 -> UTF-8: [B, L] unit buffers -> ([B, 3L], [B], [B])."""
    return batch_transcode(units, lengths, in_encoding="utf16",
                           out_encoding="utf8", strategy=strategy,
                           validate=validate, errors=errors)


def batch_utf8_to_codepoints(docs, lengths, *, strategy: str = "packed",
                             validate: bool = True,
                             errors: str = "strict"):
    """Batched UTF-8 -> UTF-32 code points: the device-side decode the
    codepoint-consuming models ingest (one fused/ragged launch, not the
    host-side ``core/utf32.py`` helpers)."""
    return batch_transcode(docs, lengths, in_encoding="utf8",
                           out_encoding="utf32", strategy=strategy,
                           validate=validate, errors=errors)


@dataclasses.dataclass
class PipelineConfig:
    seq_len: int = 1024
    global_batch: int = 8
    langs: tuple = ("latin", "arabic", "chinese", "emoji")
    seed: int = 0
    host_id: int = 0
    n_hosts: int = 1
    validate: bool = True
    # "tokens" (default): byte-tokenized BOS/doc/EOS frames.
    # "codepoints": the batch additionally carries per-document UTF-32
    # code points, decoded ON DEVICE through the fused/ragged
    # UTF-8 -> UTF-32 matrix cell (one packed launch per batch — not the
    # host-side core/utf32.py helpers).
    emit: str = "tokens"


class TextPipeline:
    """Deterministic, restartable synthetic-text pipeline."""

    def __init__(self, cfg: PipelineConfig):
        if cfg.global_batch % cfg.n_hosts:
            raise ValueError("global_batch must divide evenly across hosts")
        self.cfg = cfg
        self.step = 0
        self._tok = ByteTokenizer()
        # Device ingest program: bytes -> validated token sequence.
        self._ingest = jax.jit(self._ingest_fn)

    # ------------------------------------------------------------------
    def skip_to(self, step: int) -> None:
        """O(1) restart at a global step boundary (fault tolerance)."""
        self.step = step

    @property
    def local_batch(self) -> int:
        return self.cfg.global_batch // self.cfg.n_hosts

    # ------------------------------------------------------------------
    def _doc_bytes(self, step: int, slot: int) -> np.ndarray:
        """Raw UTF-8 for global slot ``slot`` of global step ``step``."""
        cfg = self.cfg
        lang = cfg.langs[(step + slot) % len(cfg.langs)]
        # seq_len bytes of budget; CJK characters are 3 bytes, so ask for
        # seq_len chars and truncate at a character boundary below.
        doc = synthetic.utf8_array(
            lang, cfg.seq_len, seed=cfg.seed + step * cfg.global_batch + slot)
        doc = doc[: cfg.seq_len - 2]  # room for BOS/EOS
        # Truncate to a character boundary: drop trailing continuation
        # bytes and a trailing incomplete lead.
        end = len(doc)
        while end > 0 and (doc[end - 1] & 0xC0) == 0x80:
            end -= 1
        if end > 0 and doc[end - 1] >= 0xC0:
            end -= 1
        return doc[:end]

    def _ingest_fn(self, raw: jnp.ndarray, n_valid: jnp.ndarray):
        """Jitted device ingest: validate UTF-8, tokenize, frame, label."""
        cfg = self.cfg
        ok = tc.validate_utf8(raw, n_valid) if cfg.validate else jnp.bool_(True)
        ids = self._tok.encode(raw)
        pos = jnp.arange(cfg.seq_len)
        # [BOS] doc [EOS] [PAD...]
        tokens = jnp.where(
            pos == 0, BOS_ID,
            jnp.where(pos - 1 < n_valid, jnp.roll(ids, 1),
                      jnp.where(pos == n_valid + 1, EOS_ID, PAD_ID)))
        labels = jnp.roll(tokens, -1)
        labels = jnp.where(pos >= n_valid + 1, -1, labels)  # -1 = no loss
        return tokens, labels, ok

    # ------------------------------------------------------------------
    def next_batch(self):
        """Local (per-host) batch for the current global step."""
        cfg = self.cfg
        toks, labs, raws, lens = [], [], [], []
        # Deterministic host sharding, without touching other hosts'
        # slots: host h owns exactly the slots h, h+n_hosts, ... — the
        # stride iteration IS the shard, so host k never materializes
        # (or even names) host j's documents.
        for k in range(cfg.host_id, cfg.global_batch, cfg.n_hosts):
            doc = self._doc_bytes(self.step, k)
            raw = np.zeros(cfg.seq_len, np.uint8)
            raw[: len(doc)] = doc
            t, l, ok = self._ingest(jnp.asarray(raw), jnp.int32(len(doc)))
            if cfg.validate and not bool(ok):  # pragma: no cover
                raise ValueError(f"invalid UTF-8 document at step={self.step}")
            toks.append(t)
            labs.append(l)
            raws.append(raw)
            lens.append(len(doc))
        self.step += 1
        batch = {
            "tokens": jnp.stack(toks),
            "labels": jnp.stack(labs),
        }
        if cfg.emit == "codepoints":
            # Device-side decode to the UTF-32 interchange format: ONE
            # ragged packed launch for the whole local batch through the
            # fused UTF-8 -> UTF-32 matrix cell.
            res = batch_utf8_to_codepoints(
                np.stack(raws), np.asarray(lens, np.int32),
                validate=cfg.validate)
            batch["codepoints"] = res.buffer
            batch["cp_counts"] = res.count
        return batch

    def __iter__(self):
        while True:
            yield self.next_batch()
