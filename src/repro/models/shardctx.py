"""Activation/weight sharding-constraint context (hillclimb opt-1).

Dry-run profiling showed XLA resolving the FSDP-sharded contracting dim
of every weight by **all-reducing the activation** (GBs per layer) rather
than all-gathering the weight (MBs): 468 GB/device/step of collective
traffic on h2o-danube train_4k, 88% of it activation all-reduces
(EXPERIMENTS.md §Perf, iteration 1).

When enabled, layers wrap each weight in ``with_sharding_constraint``
that keeps the tensor-parallel axis and *clears the FSDP axes* — i.e. an
explicit ZeRO-3 "re-gather before use".  XLA then emits one small weight
all-gather per layer (overlappable with the previous layer's compute
inside the scan) instead of giant activation all-reduces.

Enabled only under a mesh context (the dry-run / production path); unit
tests and CPU smoke tests run with the context off and see no
constraints at all.
"""

from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import PartitionSpec as P

_state = threading.local()


def _cfg():
    return getattr(_state, "cfg", None)


@contextlib.contextmanager
def use(tp_axis="model", tp_size=16, dp_axes=("data",), dp_size=16):
    """Enable weight re-gather constraints within a mesh context."""
    prev = _cfg()
    _state.cfg = {"tp": tp_axis, "tp_n": tp_size,
                  "dp": dp_axes, "dp_n": dp_size}
    try:
        yield
    finally:
        _state.cfg = prev


def act(x, pattern):
    """Constrain an activation: pattern entries are 'tp' | 'dp' | None.

    Divisibility-checked; no-op when the context is off.  Used to pin MoE
    dispatch tensors so XLA distributes the expert all-reduce instead of
    materialising it at global size.
    """
    cfg = _cfg()
    if cfg is None:
        return x
    dims = []
    for i, p in enumerate(pattern):
        if p == "tp" and cfg["tp"] is not None and \
                x.shape[i] % cfg["tp_n"] == 0 and x.shape[i] >= cfg["tp_n"]:
            dims.append(cfg["tp"])
        elif p == "dp" and x.shape[i] % cfg["dp_n"] == 0 \
                and x.shape[i] >= cfg["dp_n"]:
            dp = cfg["dp"]
            dims.append(dp if len(dp) > 1 else dp[0])
        else:
            dims.append(None)
    return jax.lax.with_sharding_constraint(x, P(*dims))


# tp-dim rules mirroring repro.train.sharding (column/row/embed/MoE)
_COLUMN = {"wq", "wk", "wv", "wi", "wg", "in_proj", "wa", "wx", "x_proj"}
_ROW = {"wo", "out_proj", "dt_proj"}


def gather(name: str, w):
    """Constrain ``w`` to TP-only sharding (FSDP axes cleared).

    With tp_axis=None (pure-DP layout) every weight is constrained fully
    replicated — an explicit ZeRO-3 all-gather before use.
    """
    cfg = _cfg()
    if cfg is None or w.ndim < 2:
        return w
    tp, tp_n = cfg["tp"], cfg["tp_n"]
    dims = [None] * w.ndim
    body = list(w.shape)
    if tp is None:
        return jax.lax.with_sharding_constraint(w, P(*dims))

    def ok(i):
        return body[i] % tp_n == 0 and body[i] >= tp_n

    if name == "table":
        if ok(0):
            dims[0] = tp
    elif w.ndim == 3 and name in ("wi", "wg", "wo"):   # MoE experts
        # Size threshold (§Perf grok iteration 1, refuted): re-gathering
        # multi-GB expert stacks costs more than FSDP partial sums.
        # Keep the stored (EP/TP + FSDP) sharding for stacks > 256 MB.
        if w.size * 2 > 256 * 2**20:
            return w
        if ok(0):
            dims[0] = tp
        else:
            j = 2 if name != "wo" else 1
            if ok(j):
                dims[j] = tp
    elif name in _COLUMN and w.ndim == 2:
        if ok(1):
            dims[1] = tp
    elif name in _ROW and w.ndim == 2:
        if ok(0):
            dims[0] = tp
    else:
        return w
    return jax.lax.with_sharding_constraint(w, P(*dims))
