"""Encoder-decoder transformer (Whisper-style).

The audio conv frontend is a STUB per the assignment: ``input_specs()``
feeds precomputed mel-frame embeddings of shape (B, n_frames, d_model)
directly to the encoder.  Encoder layers are bidirectional; decoder layers
are causal self-attention + cross-attention over the encoder output.
Cross-attention KV is computed once per sequence and cached for decode.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import common as C


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    name: str
    n_layers: int              # decoder layers (encoder matches)
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    n_audio_frames: int = 1500
    dtype: str = "bfloat16"
    remat: bool = True

    @property
    def hd(self):
        return self.d_model // self.n_heads

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    def attn_cfg(self):
        return C.AttnConfig(
            d_model=self.d_model, n_heads=self.n_heads,
            n_kv_heads=self.n_kv_heads, head_dim=self.hd)


def _cross_attention(p, cfg: EncDecConfig, x, enc_kv):
    """Bidirectional attention of x over precomputed encoder (k, v)."""
    b, s, _ = x.shape
    h, hd = cfg.n_heads, cfg.hd
    k, v = enc_kv
    q = jnp.einsum("bsd,de->bse", x, p["wq"],
                   preferred_element_type=jnp.float32)
    q = q.reshape(b, s, h, hd).astype(x.dtype)
    se = k.shape[1]
    qpos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    kpos = jnp.zeros((b, se), jnp.int32)   # kpos=0 <= qpos: full visibility
    y = C.chunked_attention(q, k, v, qpos, kpos)
    out = jnp.einsum("bsf,fd->bsd", y.reshape(b, s, -1), p["wo"],
                     preferred_element_type=jnp.float32)
    return out.astype(x.dtype)


def _init_cross(key, cfg: EncDecConfig, dt):
    ks = jax.random.split(key, 4)
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    return {
        "wq": C._dense_init(ks[0], (d, h * hd), dt),
        "wk": C._dense_init(ks[1], (d, kv * hd), dt),
        "wv": C._dense_init(ks[2], (d, kv * hd), dt),
        "wo": C._dense_init(ks[3], (h * hd, d), dt),
    }


class EncDecLM:
    def __init__(self, cfg: EncDecConfig):
        self.cfg = cfg

    def init(self, key):
        cfg = self.cfg
        dt = cfg.jdtype
        k_enc, k_dec, k_emb, k_pos = jax.random.split(key, 4)

        def enc_layer(k):
            k1, k2 = jax.random.split(k)
            return {
                "ln1": C.init_rmsnorm(cfg.d_model, dt),
                "attn": C.init_attn(k1, cfg.attn_cfg(), dt),
                "ln2": C.init_rmsnorm(cfg.d_model, dt),
                "mlp": C.init_mlp(k2, cfg.d_model, cfg.d_ff, dt),
            }

        def dec_layer(k):
            k1, k2, k3 = jax.random.split(k, 3)
            return {
                "ln1": C.init_rmsnorm(cfg.d_model, dt),
                "attn": C.init_attn(k1, cfg.attn_cfg(), dt),
                "lnx": C.init_rmsnorm(cfg.d_model, dt),
                "xattn": _init_cross(k2, cfg, dt),
                "ln2": C.init_rmsnorm(cfg.d_model, dt),
                "mlp": C.init_mlp(k3, cfg.d_model, cfg.d_ff, dt),
            }

        return {
            "embed": C.init_embedding(k_emb, cfg.vocab, cfg.d_model, dt),
            "enc_pos": C._dense_init(k_pos, (cfg.n_audio_frames,
                                             cfg.d_model), dt, scale=0.02),
            "enc": jax.vmap(enc_layer)(jax.random.split(k_enc, cfg.n_layers)),
            "dec": jax.vmap(dec_layer)(jax.random.split(k_dec, cfg.n_layers)),
            "ln_f": C.init_rmsnorm(cfg.d_model, dt),
        }

    def encode(self, params, frames):
        """frames: (B, T, d_model) stub mel embeddings -> encoder output."""
        cfg = self.cfg
        x = frames.astype(cfg.jdtype) + params["enc_pos"][None, : frames.shape[1]]
        b, t, _ = x.shape
        pos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))

        def body(x, lp):
            # bidirectional: query every position against every position by
            # zeroing the causal comparison (kpos=0)
            h = C.rmsnorm(lp["ln1"], x)
            q, k, v = C._project_qkv(lp["attn"], cfg.attn_cfg(), h, pos)
            y = C.chunked_attention(
                q, k, v, jnp.full_like(pos, t), pos)  # qpos=t: sees all
            y = jnp.einsum("bsf,fd->bsd", y.reshape(b, t, -1),
                           lp["attn"]["wo"],
                           preferred_element_type=jnp.float32).astype(x.dtype)
            x = x + y
            x = x + C.mlp(lp["mlp"], C.rmsnorm(lp["ln2"], x))
            return x, None

        if cfg.remat:
            body = jax.checkpoint(body)
        x, _ = lax.scan(body, x, params["enc"])
        return x

    def _enc_kv(self, params, enc_out):
        """Precompute per-decoder-layer cross-attention K/V."""
        cfg = self.cfg
        b, t, _ = enc_out.shape
        kv, hd = cfg.n_kv_heads, cfg.hd

        def proj(lp):
            k = jnp.einsum("bsd,de->bse", enc_out, lp["xattn"]["wk"],
                           preferred_element_type=jnp.float32)
            v = jnp.einsum("bsd,de->bse", enc_out, lp["xattn"]["wv"],
                           preferred_element_type=jnp.float32)
            return (k.reshape(b, t, kv, hd).astype(enc_out.dtype),
                    v.reshape(b, t, kv, hd).astype(enc_out.dtype))

        return jax.vmap(proj)(params["dec"])

    def apply(self, params, frames, tokens, state=None):
        """Returns (logits, new_state, aux).

        state: None (teacher forcing) or dict(kv_caches, enc_kv) for decode.
        """
        cfg = self.cfg
        if state is not None and "enc_kv" in state:
            enc_kv = state["enc_kv"]
        else:
            enc_out = self.encode(params, frames)
            enc_kv = self._enc_kv(params, enc_out)
        x = C.embed(params["embed"], tokens)
        b, s = tokens.shape
        if state is not None:
            pos0 = state["pos0"]
            pos = pos0 + jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32),
                                          (b, s))
        else:
            pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

        caches = state["caches"] if state is not None else None

        def body(x, xs):
            lp = xs[0] if caches is not None else xs[0]
            ekv = xs[1]
            cache = xs[2] if caches is not None else None
            h, nc = C.attention(lp["attn"], cfg.attn_cfg(),
                                C.rmsnorm(lp["ln1"], x), pos, cache)
            x = x + h
            x = x + _cross_attention(lp["xattn"], cfg,
                                     C.rmsnorm(lp["lnx"], x), ekv)
            x = x + C.mlp(lp["mlp"], C.rmsnorm(lp["ln2"], x))
            return x, nc

        if cfg.remat and state is None:
            body = jax.checkpoint(body)
        xs = (params["dec"], enc_kv, caches) if caches is not None else (
            params["dec"], enc_kv)
        x, new_caches = lax.scan(body, x, xs)
        x = C.rmsnorm(params["ln_f"], x)
        logits = C.unembed(params["embed"], x)
        new_state = None
        if state is not None:
            new_state = {"enc_kv": enc_kv, "caches": new_caches,
                         "pos0": pos0 + s}
        return logits, new_state, jnp.float32(0)

    def init_state(self, params, frames, batch, capacity):
        cfg = self.cfg
        enc_out = self.encode(params, frames)
        one = C.init_attn_cache(cfg.attn_cfg(), batch, capacity, cfg.jdtype)
        caches = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape), one)
        return {"enc_kv": self._enc_kv(params, enc_out), "caches": caches,
                "pos0": jnp.int32(0)}
