"""Generic decoder-only LM covering dense / MoE / Griffin-hybrid / Mamba.

A model is a sequence of **segments**; each segment is ``count`` structurally
identical layers whose parameters are stacked along a leading axis and
executed with ``lax.scan`` — the MaxText pattern that keeps trace/compile
time O(1) in depth (one layer traced per segment, not per layer).  Mixed
architectures (RecurrentGemma's 2-recurrent:1-attention pattern,
DeepSeekMoE's dense first layer) become short segment lists.

Layer kinds:
  * ``dense``   — GQA attention + SwiGLU MLP (llama/qwen/granite family)
  * ``moe``     — GQA attention + top-k MoE (grok, deepseek-moe)
  * ``griffin`` — composite period: RG-LRU block x2 + local attention
  * ``rec``     — single RG-LRU block (pattern remainders)
  * ``mamba``   — Mamba-1 selective-SSM block (attention-free)

Every kind threads an explicit per-layer state (KV cache / recurrent
state), so one code path serves train (state=None), prefill and decode.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import common as C


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    pattern: str = "dense"            # dense | moe | griffin | mamba
    qkv_bias: bool = False
    qk_norm: bool = False
    window: Optional[int] = None      # sliding-window attention (SWA)
    local_window: int = 2048          # griffin local-attention window
    rope_theta: float = 10000.0
    mrope_sections: Optional[tuple] = None
    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared: int = 0
    moe_d_ff: Optional[int] = None    # routed-expert hidden (deepseek: 1408)
    first_dense: bool = False         # deepseek: layer 0 is a dense MLP
    dense_d_ff: Optional[int] = None  # hidden of that dense layer (10944)
    capacity_factor: float = 1.25     # MoE; 8.0 in reduced configs => no drops
    # Mamba
    ssm_state: int = 16
    # misc
    dtype: str = "bfloat16"
    remat: bool = True
    remat_policy: str = "full"   # full | dots (save matmul outputs)

    @property
    def hd(self):
        return self.head_dim or self.d_model // self.n_heads

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    def attn_cfg(self, window=None):
        return C.AttnConfig(
            d_model=self.d_model, n_heads=self.n_heads,
            n_kv_heads=self.n_kv_heads, head_dim=self.hd,
            qkv_bias=self.qkv_bias, qk_norm=self.qk_norm,
            window=window if window is not None else self.window,
            rope_theta=self.rope_theta, mrope_sections=self.mrope_sections)

    def moe_cfg(self):
        return C.MoEConfig(
            d_model=self.d_model, d_ff=self.moe_d_ff or self.d_ff,
            n_experts=self.n_experts, top_k=self.top_k,
            n_shared=self.n_shared, capacity_factor=self.capacity_factor)

    def mamba_cfg(self):
        return C.MambaConfig(d_model=self.d_model, d_state=self.ssm_state)

    def segments(self) -> Sequence[Tuple[str, int]]:
        """(kind, count) list; counts sum to n_layers (griffin periods
        count 3 layers each)."""
        if self.pattern == "dense":
            return (("dense", self.n_layers),)
        if self.pattern == "moe":
            if self.first_dense:
                return (("dense", 1), ("moe", self.n_layers - 1))
            return (("moe", self.n_layers),)
        if self.pattern == "griffin":
            periods, rem = divmod(self.n_layers, 3)
            segs = [("griffin", periods)]
            if rem:
                segs.append(("rec", rem))
            return tuple(segs)
        if self.pattern == "mamba":
            return (("mamba", self.n_layers),)
        raise ValueError(self.pattern)


# ---------------------------------------------------------------------------
# per-kind init / apply / state-init


def _init_layer(key, cfg: LMConfig, kind: str):
    dt = cfg.jdtype
    d = cfg.d_model
    ks = jax.random.split(key, 8)
    if kind == "dense":
        d_ff = cfg.dense_d_ff if (cfg.pattern == "moe" and cfg.dense_d_ff) \
            else cfg.d_ff
        return {
            "ln1": C.init_rmsnorm(d, dt),
            "attn": C.init_attn(ks[0], cfg.attn_cfg(), dt),
            "ln2": C.init_rmsnorm(d, dt),
            "mlp": C.init_mlp(ks[1], d, d_ff, dt),
        }
    if kind == "moe":
        return {
            "ln1": C.init_rmsnorm(d, dt),
            "attn": C.init_attn(ks[0], cfg.attn_cfg(), dt),
            "ln2": C.init_rmsnorm(d, dt),
            "moe": C.init_moe(ks[1], cfg.moe_cfg(), dt),
        }
    if kind == "griffin":
        sub = {}
        for j in range(2):
            sub[f"rec{j}"] = {
                "ln1": C.init_rmsnorm(d, dt),
                "rglru": C.init_rglru(ks[2 * j], d, dt),
                "ln2": C.init_rmsnorm(d, dt),
                "mlp": C.init_mlp(ks[2 * j + 1], d, cfg.d_ff, dt),
            }
        sub["attn"] = {
            "ln1": C.init_rmsnorm(d, dt),
            "attn": C.init_attn(ks[4], cfg.attn_cfg(cfg.local_window), dt),
            "ln2": C.init_rmsnorm(d, dt),
            "mlp": C.init_mlp(ks[5], d, cfg.d_ff, dt),
        }
        return sub
    if kind == "rec":
        return {
            "ln1": C.init_rmsnorm(d, dt),
            "rglru": C.init_rglru(ks[0], d, dt),
            "ln2": C.init_rmsnorm(d, dt),
            "mlp": C.init_mlp(ks[1], d, cfg.d_ff, dt),
        }
    if kind == "mamba":
        return {
            "ln": C.init_rmsnorm(d, dt),
            "mamba": C.init_mamba(ks[0], cfg.mamba_cfg(), dt),
        }
    raise ValueError(kind)


def _init_state(cfg: LMConfig, kind: str, batch, capacity):
    dt = cfg.jdtype
    if kind == "dense" or kind == "moe":
        cap = capacity if cfg.window is None else min(capacity, cfg.window)
        return C.init_attn_cache(cfg.attn_cfg(), batch, cap, dt)
    if kind == "griffin":
        cap = min(capacity, cfg.local_window)
        return {
            "rec0": jnp.zeros((batch, cfg.d_model), jnp.float32),
            "rec1": jnp.zeros((batch, cfg.d_model), jnp.float32),
            "attn": C.init_attn_cache(
                cfg.attn_cfg(cfg.local_window), batch, cap, dt),
        }
    if kind == "rec":
        return jnp.zeros((batch, cfg.d_model), jnp.float32)
    if kind == "mamba":
        return C.init_mamba_state(cfg.mamba_cfg(), batch)
    raise ValueError(kind)


def _apply_layer(cfg: LMConfig, kind: str, p, x, pos, state):
    """Returns (x, new_state, aux_loss)."""
    aux = jnp.float32(0)
    if kind in ("dense", "moe"):
        h, new_cache = C.attention(p["attn"], cfg.attn_cfg(),
                                   C.rmsnorm(p["ln1"], x), pos, state)
        x = x + h
        if kind == "dense":
            x = x + C.mlp(p["mlp"], C.rmsnorm(p["ln2"], x))
        else:
            y, aux = C.moe(p["moe"], cfg.moe_cfg(), C.rmsnorm(p["ln2"], x))
            x = x + y
        return x, new_cache, aux
    if kind == "griffin":
        new_state = {}
        for j in range(2):
            sp = p[f"rec{j}"]
            st = state[f"rec{j}"] if state is not None else None
            h, ns = C.rglru(sp["rglru"], C.rmsnorm(sp["ln1"], x), st)
            x = x + h
            x = x + C.mlp(sp["mlp"], C.rmsnorm(sp["ln2"], x))
            new_state[f"rec{j}"] = ns
        ap = p["attn"]
        st = state["attn"] if state is not None else None
        h, nc = C.attention(ap["attn"], cfg.attn_cfg(cfg.local_window),
                            C.rmsnorm(ap["ln1"], x), pos, st)
        x = x + h
        x = x + C.mlp(ap["mlp"], C.rmsnorm(ap["ln2"], x))
        new_state["attn"] = nc
        return x, (new_state if state is not None else None), aux
    if kind == "rec":
        h, ns = C.rglru(p["rglru"], C.rmsnorm(p["ln1"], x), state)
        x = x + h
        x = x + C.mlp(p["mlp"], C.rmsnorm(p["ln2"], x))
        return x, (ns if state is not None else None), aux
    if kind == "mamba":
        h, ns = C.mamba(p["mamba"], cfg.mamba_cfg(),
                        C.rmsnorm(p["ln"], x), state)
        x = x + h
        return x, (ns if state is not None else None), aux
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# The model


class DecoderLM:
    """Functional decoder LM.  ``params`` is a pytree; apply is pure."""

    def __init__(self, cfg: LMConfig):
        self.cfg = cfg

    # -- init ------------------------------------------------------------
    def init(self, key) -> dict:
        cfg = self.cfg
        keys = jax.random.split(key, len(cfg.segments()) + 2)
        params = {"embed": C.init_embedding(keys[0], cfg.vocab, cfg.d_model,
                                            cfg.jdtype),
                  "ln_f": C.init_rmsnorm(cfg.d_model, cfg.jdtype)}
        for i, (kind, count) in enumerate(cfg.segments()):
            lkeys = jax.random.split(keys[i + 1], count)
            stacked = jax.vmap(
                lambda k, kind=kind: _init_layer(k, cfg, kind))(lkeys)
            params[f"seg{i}_{kind}"] = stacked
        return params

    def init_state(self, batch: int, capacity: int):
        """Stacked per-segment decode state (KV caches / SSM states)."""
        cfg = self.cfg
        state = {}
        for i, (kind, count) in enumerate(cfg.segments()):
            one = _init_state(cfg, kind, batch, capacity)
            state[f"seg{i}_{kind}"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (count,) + a.shape), one)
        return state

    # -- apply -----------------------------------------------------------
    def apply(self, params, tokens, pos=None, state=None, logits: bool = True):
        """tokens: (B, S) int32 (or (B, S, D) pre-embedded for stubs).

        pos: (B, S) or (3, B, S) for M-RoPE; defaults to arange.
        state: None for training, else the pytree from ``init_state``.
        Returns (logits_or_hidden, new_state, aux_loss).
        """
        cfg = self.cfg
        if tokens.ndim == 2:
            x = C.embed(params["embed"], tokens)
        else:
            x = tokens.astype(cfg.jdtype)
        b, s = x.shape[0], x.shape[1]
        if pos is None:
            pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        aux = jnp.float32(0)
        new_state = {} if state is not None else None

        for i, (kind, count) in enumerate(cfg.segments()):
            seg_params = params[f"seg{i}_{kind}"]
            seg_state = state[f"seg{i}_{kind}"] if state is not None else None

            def body(carry, xs, kind=kind):
                x, aux = carry
                lp = xs[0] if seg_state is not None else xs
                ls = xs[1] if seg_state is not None else None
                x, ns, a = _apply_layer(cfg, kind, lp, x, pos, ls)
                return (x, aux + a), ns

            if cfg.remat and state is None:
                if cfg.remat_policy == "dots":
                    body = jax.checkpoint(
                        body, policy=jax.checkpoint_policies
                        .dots_with_no_batch_dims_saveable)
                else:
                    body = jax.checkpoint(body)
            xs = (seg_params, seg_state) if state is not None else seg_params
            (x, aux), seg_new = lax.scan(body, (x, aux), xs)
            if state is not None:
                new_state[f"seg{i}_{kind}"] = seg_new

        x = C.rmsnorm(params["ln_f"], x)
        out = C.unembed(params["embed"], x) if logits else x
        return out, new_state, aux

    # -- param count -------------------------------------------------------
    def param_count(self, params) -> int:
        return sum(p.size for p in jax.tree.leaves(params))
