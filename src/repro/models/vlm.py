"""Vision-language model (Qwen2-VL style): M-RoPE text backbone + stub
vision frontend.

Per the assignment the modality frontend is a STUB: ``input_specs()``
provides precomputed patch embeddings (B, n_patches, d_model).  This module
owns what is NOT stubbed — the M-RoPE position bookkeeping that
distinguishes the architecture: vision tokens get (temporal, height, width)
grid positions; text tokens get equal positions on all three streams,
continuing after the vision block.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.models import common as C
from repro.models.lm import DecoderLM, LMConfig


class VLM:
    """DecoderLM with multimodal position ids and embedding concat."""

    def __init__(self, cfg: LMConfig):
        assert cfg.mrope_sections is not None
        self.cfg = cfg
        self.lm = DecoderLM(cfg)

    def init(self, key):
        return self.lm.init(key)

    def mm_positions(self, batch, n_patches, grid_hw, n_text):
        """(3, B, n_patches + n_text) M-RoPE positions.

        Vision: temporal=0, height/width from the patch grid.  Text:
        all three streams equal, starting at max(grid)+1 (Qwen2-VL rule).
        """
        gh, gw = grid_hw
        assert gh * gw == n_patches
        t = jnp.zeros((n_patches,), jnp.int32)
        h = jnp.repeat(jnp.arange(gh, dtype=jnp.int32), gw)
        w = jnp.tile(jnp.arange(gw, dtype=jnp.int32), gh)
        text0 = max(gh, gw)
        tx = text0 + jnp.arange(n_text, dtype=jnp.int32)
        pos3 = jnp.stack([
            jnp.concatenate([t, tx]),
            jnp.concatenate([h, tx]),
            jnp.concatenate([w, tx]),
        ])  # (3, S)
        return jnp.broadcast_to(pos3[:, None, :],
                                (3, batch, n_patches + n_text))

    def apply(self, params, patch_embeds, tokens, state=None):
        """patch_embeds: (B, P, D) stub frontend output; tokens: (B, T)."""
        b, p, _ = patch_embeds.shape
        t = tokens.shape[1]
        x_txt = C.embed(params["embed"], tokens)
        x = jnp.concatenate([patch_embeds.astype(x_txt.dtype), x_txt], 1)
        # assume a near-square patch grid for the stub
        gh = int(p ** 0.5)
        gw = p // gh
        while gh * gw != p:
            gh -= 1
            gw = p // gh
        pos3 = self.mm_positions(b, p, (gh, gw), t)
        return self.lm.apply(params, x, pos=pos3, state=state)

    def apply_text(self, params, tokens, pos=None, state=None):
        """Text-only path (used by the dry-run LM shapes)."""
        b, s = tokens.shape
        if pos is None:
            p = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
            pos = jnp.broadcast_to(p, (3, b, s))
        return self.lm.apply(params, tokens, pos=pos, state=state)
