"""Shared model layers (pure JAX, pytree params, functional apply).

Every layer is a pair of functions: ``init_*(key, cfg) -> params`` and a
pure ``apply`` that threads explicit state (KV caches, SSM states) so the
same code serves training (no cache), prefill (build cache) and decode
(single-token update).  All matmul-heavy ops accumulate in float32
(``preferred_element_type``) regardless of the parameter dtype — the MXU
bf16xbf16->f32 contract.

Attention is **chunked** (online-softmax streaming over KV blocks): the
(S, S) score matrix is never materialised, which is what makes the 32k
prefill shapes compile within HBM. Sliding-window and causal masks are
applied per chunk.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import shardctx


def _dense_init(key, shape, dtype, scale=None):
    fan_in = shape[0] if len(shape) >= 2 else 1
    scale = scale if scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Normalisation


def init_rmsnorm(dim, dtype):
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm(params, x, eps=1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary embeddings (RoPE + sectioned M-RoPE)


def rope_freqs(head_dim, theta=10000.0):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2) / head_dim))


def apply_rope(x, pos, theta=10000.0):
    """x: (..., S, H, D); pos: broadcastable to (..., S) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    ang = pos[..., None].astype(jnp.float32) * freqs   # (..., S, D/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., : d // 2], x[..., d // 2:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], -1).astype(x.dtype)


def apply_mrope(x, pos3, sections, theta=10000.0):
    """Multimodal RoPE (Qwen2-VL): frequency bands split across
    (temporal, height, width) position streams.

    x: (..., S, H, D); pos3: (3, ..., S); sections: 3 ints summing to D/2.
    With pos3[0]==pos3[1]==pos3[2] (pure text) this equals standard RoPE.
    """
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (D/2,)
    band = jnp.concatenate(
        [jnp.full((s,), i, jnp.int32) for i, s in enumerate(sections)])
    pos = jnp.take_along_axis(
        jnp.moveaxis(pos3, 0, -1),                     # (..., S, 3)
        jnp.broadcast_to(band, pos3.shape[1:] + (d // 2,)), axis=-1)
    ang = pos.astype(jnp.float32) * freqs              # (..., S, D/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., : d // 2], x[..., d // 2:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], -1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Grouped-query attention with chunked (online-softmax) scoring


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    qk_norm: bool = False
    window: Optional[int] = None      # sliding-window size (None = full)
    rope_theta: float = 10000.0
    mrope_sections: Optional[tuple] = None  # (t, h, w) for M-RoPE


def init_attn(key, cfg: AttnConfig, dtype):
    ks = jax.random.split(key, 4)
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    p = {
        "wq": _dense_init(ks[0], (d, h * hd), dtype),
        "wk": _dense_init(ks[1], (d, kv * hd), dtype),
        "wv": _dense_init(ks[2], (d, kv * hd), dtype),
        "wo": _dense_init(ks[3], (h * hd, d), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dtype)
        p["bk"] = jnp.zeros((kv * hd,), dtype)
        p["bv"] = jnp.zeros((kv * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(hd, dtype)
        p["k_norm"] = init_rmsnorm(hd, dtype)
    return p


def _project_qkv(params, cfg: AttnConfig, x, pos):
    b, s, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    f32 = functools.partial(jnp.einsum, preferred_element_type=jnp.float32)
    q = f32("bsd,de->bse", x, shardctx.gather("wq", params["wq"]))
    k = f32("bsd,de->bse", x, shardctx.gather("wk", params["wk"]))
    v = f32("bsd,de->bse", x, shardctx.gather("wv", params["wv"]))
    if cfg.qkv_bias:
        q = q + params["bq"].astype(q.dtype)
        k = k + params["bk"].astype(k.dtype)
        v = v + params["bv"].astype(v.dtype)
    q = q.reshape(b, s, h, hd).astype(x.dtype)
    k = k.reshape(b, s, kv, hd).astype(x.dtype)
    v = v.reshape(b, s, kv, hd).astype(x.dtype)
    if cfg.qk_norm:
        q = rmsnorm(params["q_norm"], q)
        k = rmsnorm(params["k_norm"], k)
    if cfg.mrope_sections is not None:
        pos3 = pos if pos.ndim == 3 else jnp.broadcast_to(pos, (3,) + pos.shape)
        q = apply_mrope(q, pos3, cfg.mrope_sections, cfg.rope_theta)
        k = apply_mrope(k, pos3, cfg.mrope_sections, cfg.rope_theta)
    else:
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    return q, k, v


def chunked_attention(q, k, v, q_pos, k_pos, window=None, chunk=1024):
    """Online-softmax attention without materialising (Sq, Sk) scores.

    q: (B, Sq, H, D); k/v: (B, Sk, KV, D); q_pos/k_pos: (B, S*) int32.
    GQA: H must be a multiple of KV; heads are grouped for the dot.
    Mask: causal (k_pos <= q_pos) plus optional sliding window
    (q_pos - k_pos < window).  Positions < 0 in k_pos mark empty cache
    slots and are always masked.
    """
    b, sq, h, d = q.shape
    sk, kv = k.shape[1], k.shape[2]
    g = h // kv
    qg = q.reshape(b, sq, kv, g, d)
    scale = 1.0 / math.sqrt(d)

    nchunk = -(-sk // chunk)
    pad = nchunk * chunk - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=-1)
    kc = k.reshape(b, nchunk, chunk, kv, d)
    vc = v.reshape(b, nchunk, chunk, kv, d)
    pc = k_pos.reshape(b, nchunk, chunk)

    def step(carry, blk):
        m, l, acc = carry
        kb, vb, pb = blk
        s = jnp.einsum("bqkgd,bckd->bqkgc", qg, kb,
                       preferred_element_type=jnp.float32) * scale
        mask = pb[:, None, None, None, :] <= q_pos[:, :, None, None, None]
        mask &= pb[:, None, None, None, :] >= 0
        if window is not None:
            mask &= (q_pos[:, :, None, None, None]
                     - pb[:, None, None, None, :]) < window
        s = jnp.where(mask, s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, -1))
        # guard: fully-masked rows keep m = -inf; exp(-inf - -inf) -> use 0
        safe_m = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - safe_m[..., None])
        p = jnp.where(mask, p, 0.0)
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - safe_m), 0.0)
        l_new = l * corr + jnp.sum(p, -1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bqkgc,bckd->bqkgd", p.astype(vb.dtype), vb,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, sq, kv, g), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, sq, kv, g), jnp.float32)
    a0 = jnp.zeros((b, sq, kv, g, d), jnp.float32)
    (m, l, acc), _ = lax.scan(
        step, (m0, l0, a0),
        (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0), jnp.moveaxis(pc, 1, 0)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, sq, h, d).astype(q.dtype)


def attention(params, cfg: AttnConfig, x, pos, cache=None, chunk=1024):
    """Full attention block.  cache: None | dict(k, v, pos, cursor).

    Training/prefill: cache is None (self-attention over x) or an empty
    cache dict to fill.  Decode: x is (B, 1, D) and cache holds history.
    Returns (y, new_cache).
    """
    b, s, _ = x.shape
    q, k, v = _project_qkv(params, cfg, x, pos)
    tpos = pos[0] if pos.ndim == 3 else pos  # temporal stream for masking

    if cache is None:
        y = chunked_attention(q, k, v, tpos, tpos, cfg.window, chunk)
        new_cache = None
    else:
        # Decode layout: the KV cache is batch-sharded (one request set
        # per chip); pin q/k/v to the same layout so the chunked scan
        # slices the cache without resharding (the baseline all-gathered
        # every 1024-slot chunk — 137 GB/device/token on qwen2.5-32b
        # decode_32k; EXPERIMENTS.md §Perf iteration 3).  Single-token
        # steps only: pinning the 32k-prefill activations to the batch
        # axis regressed prefill 25x (§Perf lessons).
        if s == 1:
            q = shardctx.act(q, ("dp", None, None, None))
            k = shardctx.act(k, ("dp", None, None, None))
            v = shardctx.act(v, ("dp", None, None, None))
        ck, cv, cpos = cache["k"], cache["v"], cache["pos"]
        cur = cache["cursor"]                     # (B,) per-row cursors
        cap = ck.shape[1]
        # ring-buffer write (sliding window) or linear write (full cache)
        rows = jnp.arange(b)[:, None]
        slot = (cur[:, None] + jnp.arange(s)[None, :]) % cap   # (B, S)
        ck = ck.at[rows, slot].set(k)
        cv = cv.at[rows, slot].set(v)
        cpos = cpos.at[rows, slot].set(jnp.broadcast_to(tpos, (b, s)))
        y = chunked_attention(q, ck, cv, tpos, cpos, cfg.window, chunk)
        new_cache = {"k": ck, "v": cv, "pos": cpos, "cursor": cur + s}

    out = jnp.einsum("bsf,fd->bsd", y.reshape(b, s, -1),
                     shardctx.gather("wo", params["wo"]),
                     preferred_element_type=jnp.float32)
    return out.astype(x.dtype), new_cache


def init_attn_cache(cfg: AttnConfig, batch, capacity, dtype):
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, capacity, kv, hd), dtype),
        "v": jnp.zeros((batch, capacity, kv, hd), dtype),
        "pos": jnp.full((batch, capacity), -1, jnp.int32),
        "cursor": jnp.zeros((batch,), jnp.int32),
    }


# ---------------------------------------------------------------------------
# SwiGLU MLP


def init_mlp(key, d_model, d_ff, dtype):
    ks = jax.random.split(key, 3)
    return {
        "wi": _dense_init(ks[0], (d_model, d_ff), dtype),
        "wg": _dense_init(ks[1], (d_model, d_ff), dtype),
        "wo": _dense_init(ks[2], (d_ff, d_model), dtype),
    }


def mlp(params, x):
    f32 = functools.partial(jnp.einsum, preferred_element_type=jnp.float32)
    h = jax.nn.silu(f32("bsd,df->bsf", x, shardctx.gather("wg", params["wg"])))
    h = h * f32("bsd,df->bsf", x, shardctx.gather("wi", params["wi"]))
    return f32("bsf,fd->bsd", h.astype(x.dtype),
               shardctx.gather("wo", params["wo"])).astype(x.dtype)


# ---------------------------------------------------------------------------
# Mixture of Experts (top-k router, capacity-gather dispatch, optional
# shared experts — covers grok-1 (8e top-2) and deepseek-moe (2 shared +
# 64 routed top-6 fine-grained))


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    d_ff: int                 # per-expert hidden size
    n_experts: int
    top_k: int
    n_shared: int = 0         # shared (always-on) experts
    capacity_factor: float = 1.25
    min_capacity: int = 8     # floor so tiny decode batches never drop


def init_moe(key, cfg: MoEConfig, dtype):
    ks = jax.random.split(key, 5)
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    p = {
        "router": _dense_init(ks[0], (d, e), jnp.float32),
        "wi": _dense_init(ks[1], (e, d, f), dtype),
        "wg": _dense_init(ks[2], (e, d, f), dtype),
        "wo": _dense_init(ks[3], (e, f, d), dtype),
    }
    if cfg.n_shared:
        p["shared"] = init_mlp(ks[4], d, f * cfg.n_shared, dtype)
    return p


def moe(params, cfg: MoEConfig, x):
    """Capacity-based MoE: gather tokens per expert, batched expert matmul,
    weighted scatter back.  Static shapes throughout (drops overflow)."""
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.top_k
    cap = max(1, int(t * k / e * cfg.capacity_factor),
              min(t * k, cfg.min_capacity))

    xf = x.reshape(t, d)
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32),
                        params["router"])
    gates, idx = lax.top_k(jax.nn.softmax(logits, -1), k)   # (t, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # position of token-copy (t, k) within its expert's buffer
    onehot = jax.nn.one_hot(idx, e, dtype=jnp.int32)          # (t, k, e)
    flat_oh = onehot.reshape(t * k, e)
    pos_in_e = jnp.cumsum(flat_oh, 0) * flat_oh - 1           # (t*k, e)
    slot = jnp.max(pos_in_e, -1)                              # (t*k,)
    eid = idx.reshape(t * k)
    keep = slot < cap

    # scatter token ids into (e, cap) gather indices (t = sentinel)
    dest = jnp.where(keep, eid * cap + slot, e * cap)
    src_token = jnp.arange(t * k) // k
    gather_idx = jnp.full((e * cap + 1,), t, jnp.int32).at[dest].set(
        src_token, mode="drop")[:-1].reshape(e, cap)

    xg = jnp.concatenate([xf, jnp.zeros((1, d), xf.dtype)])[gather_idx]
    # Pin the dispatched tokens to the data axis: the expert row-matmul's
    # partial-sum all-reduce then moves 1/dp-sized shards instead of the
    # full (e, cap, d) tensor (EXPERIMENTS.md §Perf grok iteration).
    xg = shardctx.act(xg, (None, "dp", None))
    f32 = functools.partial(jnp.einsum, preferred_element_type=jnp.float32)
    h = jax.nn.silu(f32("ecd,edf->ecf", xg, shardctx.gather("wg", params["wg"])))
    h = h * f32("ecd,edf->ecf", xg, shardctx.gather("wi", params["wi"]))
    ye = f32("ecf,efd->ecd", h.astype(x.dtype),
             shardctx.gather("wo", params["wo"]))  # (e, cap, d)
    ye = shardctx.act(ye.astype(x.dtype), (None, "dp", None))

    # combine: each token-copy reads back its expert output, weighted.
    # 2-D advanced indexing (not reshape-then-gather): a flatten of the
    # dp-sharded cap dim would force an all-gather of ye.
    copy_val = ye[jnp.where(keep, eid, 0), jnp.where(keep, slot, 0)]
    w = gates.reshape(t * k)[:, None] * keep[:, None]
    out = jnp.zeros((t, d), jnp.float32).at[src_token].add(
        copy_val.astype(jnp.float32) * w)

    if cfg.n_shared:
        out = out + mlp(params["shared"], x).reshape(t, d).astype(jnp.float32)

    aux = _load_balance_loss(logits, idx, e)
    return out.reshape(b, s, d).astype(x.dtype), aux


def _load_balance_loss(logits, idx, e):
    """Switch-style auxiliary load-balancing loss."""
    probs = jax.nn.softmax(logits, -1)
    me = jnp.mean(probs, 0)
    ce = jnp.mean(jax.nn.one_hot(idx[:, 0], e, dtype=jnp.float32), 0)
    return e * jnp.sum(me * ce)


# ---------------------------------------------------------------------------
# RG-LRU (RecurrentGemma real-gated linear recurrent unit)


def init_rglru(key, d, dtype):
    ks = jax.random.split(key, 3)
    return {
        "lam": jnp.full((d,), 2.0, jnp.float32),  # softplus-param of decay
        "wa": _dense_init(ks[0], (d, d), dtype),  # recurrence gate
        "wx": _dense_init(ks[1], (d, d), dtype),  # input gate
    }


def rglru(params, x, state=None, c=8.0):
    """x: (B, S, D). Associative-scan linear recurrence.

    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
    a_t = exp(-c * softplus(lam) * sigmoid(r_t))
    Returns (y, last_state).
    """
    b, s, d = x.shape
    f32 = functools.partial(jnp.einsum, preferred_element_type=jnp.float32)
    r = jax.nn.sigmoid(f32("bsd,de->bse", x, shardctx.gather("wa", params["wa"])))
    i = jax.nn.sigmoid(f32("bsd,de->bse", x, shardctx.gather("wx", params["wx"])))
    log_a = -c * jax.nn.softplus(params["lam"]) * r         # (B,S,D) f32
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-9)) * (
        i * x.astype(jnp.float32))

    def comb(p, q):
        a1, u1 = p
        a2, u2 = q
        return a1 * a2, u1 * a2 + u2

    if state is not None:
        gated = gated.at[:, 0].add(a[:, 0] * state)
    a_sc, h = lax.associative_scan(comb, (a, gated), axis=1)
    return h.astype(x.dtype), h[:, -1]


# ---------------------------------------------------------------------------
# Mamba-1 selective SSM block


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_model: int
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2

    @property
    def d_inner(self):
        return self.expand * self.d_model


def init_mamba(key, cfg: MambaConfig, dtype):
    ks = jax.random.split(key, 7)
    d, di, n = cfg.d_model, cfg.d_inner, cfg.d_state
    dt_rank = max(1, d // 16)
    return {
        "in_proj": _dense_init(ks[0], (d, 2 * di), dtype),
        "conv_w": _dense_init(ks[1], (cfg.d_conv, di), dtype, scale=0.5),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": _dense_init(ks[2], (di, dt_rank + 2 * n), dtype),
        "dt_proj": _dense_init(ks[3], (dt_rank, di), dtype),
        "dt_bias": jnp.zeros((di,), jnp.float32),
        "A_log": jnp.log(jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32),
                                  (di, 1))),   # (di, n)
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": _dense_init(ks[4], (di, d), dtype),
    }


def mamba(params, cfg: MambaConfig, x, state=None):
    """x: (B, S, D) -> (y, new_state).

    state: None (training) or dict(conv: (B, d_conv-1, di), ssm: (B, di, n)).
    Selective scan via associative_scan (parallel in S).
    """
    b, s, d = x.shape
    di, n = cfg.d_inner, cfg.d_state
    dt_rank = params["dt_proj"].shape[0]
    f32 = functools.partial(jnp.einsum, preferred_element_type=jnp.float32)

    xz = f32("bsd,de->bse", x,
             shardctx.gather("in_proj", params["in_proj"])).astype(x.dtype)
    xi, z = xz[..., :di], xz[..., di:]

    # depthwise causal conv1d
    kw = cfg.d_conv
    if state is not None:
        xpad = jnp.concatenate([state["conv"].astype(xi.dtype), xi], 1)
        new_conv = xpad[:, -(kw - 1):].astype(jnp.float32)
    else:
        xpad = jnp.pad(xi, ((0, 0), (kw - 1, 0), (0, 0)))
        new_conv = xpad[:, -(kw - 1):].astype(jnp.float32)
    conv = sum(xpad[:, i: i + s] * params["conv_w"][i] for i in range(kw))
    xc = jax.nn.silu(conv + params["conv_b"])

    # input-dependent SSM parameters
    dbc = f32("bsi,ie->bse", xc, shardctx.gather("x_proj", params["x_proj"]))
    dt = jax.nn.softplus(
        f32("bsr,ri->bsi", dbc[..., :dt_rank].astype(x.dtype),
            params["dt_proj"]) + params["dt_bias"])            # (B,S,di)
    Bc = dbc[..., dt_rank: dt_rank + n]                        # (B,S,n)
    Cc = dbc[..., dt_rank + n:]                                # (B,S,n)

    A = -jnp.exp(params["A_log"])                              # (di,n)
    dA = jnp.exp(dt[..., None] * A)                            # (B,S,di,n)
    dBx = (dt * xc.astype(jnp.float32))[..., None] * Bc[:, :, None, :]

    if state is not None:
        dBx = dBx.at[:, 0].add(dA[:, 0] * state["ssm"])

    def comb(p, q):
        a1, u1 = p
        a2, u2 = q
        return a1 * a2, u1 * a2 + u2

    _, h = lax.associative_scan(comb, (dA, dBx), axis=1)       # (B,S,di,n)
    y = jnp.einsum("bsin,bsn->bsi", h, Cc) + params["D"] * xc.astype(jnp.float32)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = f32("bsi,id->bsd", y.astype(x.dtype),
              shardctx.gather("out_proj", params["out_proj"]))
    new_state = {"conv": new_conv, "ssm": h[:, -1]}
    return out.astype(x.dtype), new_state


def init_mamba_state(cfg: MambaConfig, batch):
    return {
        "conv": jnp.zeros((batch, cfg.d_conv - 1, cfg.d_inner), jnp.float32),
        "ssm": jnp.zeros((batch, cfg.d_inner, cfg.d_state), jnp.float32),
    }


# ---------------------------------------------------------------------------
# Embedding / unembedding


def init_embedding(key, vocab, d_model, dtype):
    return {"table": _dense_init(key, (vocab, d_model), dtype, scale=0.02)}


def embed(params, ids):
    return shardctx.gather("table", params["table"])[ids]


def unembed(params, x):
    return jnp.einsum("bsd,vd->bsv", x,
                      shardctx.gather("table", params["table"]),
                      preferred_element_type=jnp.float32)
