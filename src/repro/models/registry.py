"""Model registry: arch id -> (family, config, model instance)."""

from __future__ import annotations

from repro import configs as cfgmod
from repro.models.encdec import EncDecConfig, EncDecLM
from repro.models.lm import DecoderLM, LMConfig
from repro.models.vlm import VLM


def build(cfg):
    """Config object -> model instance."""
    if isinstance(cfg, EncDecConfig):
        return EncDecLM(cfg)
    assert isinstance(cfg, LMConfig)
    if cfg.mrope_sections is not None:
        return VLM(cfg)
    return DecoderLM(cfg)


def get(arch_id: str, reduced: bool = False):
    """Returns (family, cfg, model)."""
    mod = cfgmod.get_module(arch_id)
    cfg = mod.reduced() if reduced else mod.CONFIG
    return mod.FAMILY, cfg, build(cfg)
