from repro.models import common, lm, encdec, vlm, registry  # noqa: F401
