"""Three-term roofline analysis from AOT-compiled artifacts.

This container is CPU-only; TPU v5e is the *target*.  Wall-clock MFU
cannot be measured, so per (arch x shape x mesh) cell we derive:

    compute term    = HLO_FLOPs   / (chips * PEAK_FLOPS)
    memory term     = HLO_bytes   / (chips * HBM_BW)
    collective term = coll_bytes  / (chips * ICI_BW)

``cost_analysis()`` provides HLO_FLOPs and bytes-accessed.  Collective
bytes are NOT in cost_analysis: we parse the optimized HLO text and sum
the output sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute.  The dominant term is the bottleneck the
§Perf loop iterates on.

MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) gives the
useful-compute ratio (catches remat/redundant compute).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional

# TPU v5e hardware constants (per chip).
PEAK_FLOPS = 197e12     # bf16 FLOP/s
HBM_BW = 819e9          # bytes/s
ICI_BW = 50e9           # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """bytes of 'bf16[256,1024]' or a '(s, s, ...)' tuple prefix."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_WHILE_RE = re.compile(
    r"while\(.*?condition=%?([\w.\-]+), body=%?([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"')
_CALL_RE = re.compile(r"(?:to_apply|calls)=%?([\w.\-]+)")
_COMPDEF_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^)]*\))?.*\{\s*$")


def _parse_computations(hlo_text: str):
    """Split HLO text into {computation_name: [lines]}."""
    comps = {}
    cur = None
    entry = None
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if cur is None:
            m = _COMPDEF_RE.match(s)
            if m and ("->" in s or s.startswith("ENTRY")):
                cur = m.group(1)
                comps[cur] = []
                if raw.startswith("ENTRY") or s.startswith("ENTRY"):
                    entry = cur
        else:
            if s == "}":
                cur = None
            else:
                comps[cur].append(s)
    return comps, entry


def collective_bytes(hlo_text: str) -> dict:
    """Collective bytes from optimized HLO, with while-loop trip counts.

    XLA annotates each while with ``backend_config known_trip_count``; a
    collective inside a scanned layer loop is charged trip_count times
    (nested loops compose).  Without this, scanned models undercount
    collectives by ~n_layers x.
    """
    comps, entry = _parse_computations(hlo_text)
    if entry is None:                      # fallback: flat scan
        comps = {"_all": hlo_text.splitlines()}
        entry = "_all"

    def comp_cost(name, seen):
        if name not in comps or name in seen:
            return {k: 0.0 for k in _COLLECTIVES}, {k: 0 for k in _COLLECTIVES}
        seen = seen | {name}
        byts = {k: 0.0 for k in _COLLECTIVES}
        cnts = {k: 0 for k in _COLLECTIVES}
        for s in comps[name]:
            matched = False
            for kind in _COLLECTIVES:
                if f" {kind}(" in s or f" {kind}-start(" in s:
                    eq = s.find(" = ")
                    if eq >= 0:
                        op_pos = s.find(f" {kind}")
                        byts[kind] += _shape_bytes(s[eq + 3: op_pos])
                        cnts[kind] += 1
                    matched = True
                    break
            if matched:
                continue
            wm = _WHILE_RE.search(s)
            if wm:
                trip = 1
                tm = _TRIP_RE.search(s)
                if tm:
                    trip = int(tm.group(1))
                for sub in (wm.group(2), wm.group(1)):  # body, cond
                    b, c = comp_cost(sub, seen)
                    mult = trip if sub == wm.group(2) else 1
                    for k in _COLLECTIVES:
                        byts[k] += b[k] * mult
                        cnts[k] += c[k] * mult
                continue
            cm = _CALL_RE.search(s)
            if cm and (" call(" in s or " fusion(" in s or " async" in s):
                b, c = comp_cost(cm.group(1), seen)
                for k in _COLLECTIVES:
                    byts[k] += b[k]
                    cnts[k] += c[k]
        return byts, cnts

    byts, cnts = comp_cost(entry, frozenset())
    out = dict(byts)
    out["_counts"] = cnts
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: float
    coll_detail: dict
    model_flops: Optional[float] = None

    @property
    def t_compute(self):
        return self.hlo_flops / (self.chips * PEAK_FLOPS)

    @property
    def t_memory(self):
        return self.hlo_bytes / (self.chips * HBM_BW)

    @property
    def t_collective(self):
        return self.coll_bytes / (self.chips * ICI_BW)

    @property
    def bottleneck(self):
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self):
        if not self.model_flops or not self.hlo_flops:
            return None
        return self.model_flops / self.hlo_flops

    @property
    def t_ideal(self):
        """Useful-compute time: MODEL_FLOPS at peak on all chips."""
        if not self.model_flops:
            return None
        return self.model_flops / (self.chips * PEAK_FLOPS)

    @property
    def roofline_fraction(self):
        """t_ideal / max(term): fraction of roofline achieved assuming
        perfect compute/memory/collective overlap — the §Perf score.
        1.0 = the step takes exactly as long as the useful FLOPs at peak."""
        binding = max(self.t_compute, self.t_memory, self.t_collective)
        if not self.model_flops or binding == 0:
            return None
        return self.t_ideal / binding

    @property
    def balance(self):
        """max(term)/sum(terms): 1.0 = single dominant roof."""
        tot = self.t_compute + self.t_memory + self.t_collective
        if tot == 0:
            return None
        return max(self.t_compute, self.t_memory, self.t_collective) / tot

    def to_dict(self):
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "xla_flops": getattr(self, "xla_flops", None),
            "xla_bytes": getattr(self, "xla_bytes", None),
            "hlo_flops": self.hlo_flops, "hlo_bytes": self.hlo_bytes,
            "coll_bytes": self.coll_bytes,
            "coll_detail": self.coll_detail,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "useful_ratio": self.useful_ratio,
            "t_ideal_s": self.t_ideal,
            "roofline_fraction": self.roofline_fraction,
            "balance": self.balance,
        }


def analyze(arch, shape, mesh_name, chips, compiled, lowered=None,
            model_flops=None, jaxpr_cost=None):
    """Build a Roofline from a compiled AOT artifact.

    flops/bytes come from the jaxpr cost model (``repro.costmodel``) when
    provided — XLA's cost_analysis counts while bodies once and is kept
    only as the raw reference (``xla_*`` fields in to_dict callers).
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    xla_flops = float(ca.get("flops", 0.0))
    xla_bytes = float(ca.get("bytes accessed", 0.0))
    if jaxpr_cost is not None:
        flops, byts = jaxpr_cost.flops, jaxpr_cost.bytes
    else:
        flops, byts = xla_flops, xla_bytes
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = lowered.as_text() if lowered is not None else ""
    coll = collective_bytes(hlo)
    counts = coll.pop("_counts")
    # SPMD HLO shapes are per-device shards; the roofline formula divides
    # by chips, so scale the parsed per-device bytes up to global.
    total_coll = float(sum(coll.values())) * chips
    rl = Roofline(arch=arch, shape=shape, mesh=mesh_name, chips=chips,
                  hlo_flops=flops, hlo_bytes=byts, coll_bytes=total_coll,
                  coll_detail={**coll, "counts": counts},
                  model_flops=model_flops)
    rl.xla_flops = xla_flops   # raw reference values
    rl.xla_bytes = xla_bytes
    return rl


def count_params(shapes_tree) -> int:
    import jax
    return sum(int(_prod(l.shape)) for l in jax.tree.leaves(shapes_tree))


def _prod(t):
    r = 1
    for x in t:
        r *= x
    return r


def active_params(cfg, n_params: int) -> float:
    """MoE: active parameter count for 6*N_active*D."""
    try:
        pattern = cfg.pattern
    except AttributeError:
        return float(n_params)
    if pattern != "moe":
        return float(n_params)
    # fraction of expert params that are active: top_k (+shared) of n_experts
    e, k, sh = cfg.n_experts, cfg.top_k, cfg.n_shared
    d, f = cfg.d_model, (cfg.moe_d_ff or cfg.d_ff)
    per_expert = 3 * d * f
    expert_total = cfg.n_layers * e * per_expert
    expert_active = cfg.n_layers * (k + sh) * per_expert
    return float(n_params - expert_total + expert_active)
