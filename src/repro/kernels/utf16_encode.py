"""Pallas TPU kernel: UTF-16 -> UTF-8 candidate-byte production (paper §5).

One grid step processes a BLOCK-unit VMEM tile of UTF-16 code units,
classifying units, folding surrogate pairs and emitting the four
candidate UTF-8 bytes plus a per-lane byte length — exactly the state
the paper's pshufb compress-store consumes.  Global stream compaction
(cumsum + scatter over the whole buffer) happens outside the kernel in
XLA.

Since the codec-matrix refactor the per-tile bodies live in
:mod:`repro.kernels.stages`: the UTF-16 decode stage and the UTF-8
encode stage compose into ``encode_tile`` (re-exported here together
with ``analyze_tile`` and ``utf8_candidates`` for older import sites).
This module keeps only the standalone full-output kernel — the
pre-fusion contrast path of ``repro.kernels.ops``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import runtime
from repro.kernels.stages.utf16 import (  # noqa: F401  (re-export shims)
    analyze_tile, encode_tile)
from repro.kernels.stages.utf8 import (  # noqa: F401  (re-export shim)
    utf8_candidates)
from repro.kernels.stages.common import (  # noqa: F401  (re-export shims)
    shift_left_flat as _shift_left_flat,
    shift_right_flat as _shift_right_flat)

ROWS = 8
LANES = 128
BLOCK = ROWS * LANES


def utf16_encode_kernel(u_prev_ref, u_cur_ref, u_next_ref,
                        b0_ref, b1_ref, b2_ref, b3_ref, len_ref, err_ref):
    u = u_cur_ref[...].astype(jnp.int32)
    up = u_prev_ref[...].astype(jnp.int32)
    un = u_next_ref[...].astype(jnp.int32)

    b0, b1, b2, b3, L, err_map = encode_tile(u, up, un)

    b0_ref[...] = b0
    b1_ref[...] = b1
    b2_ref[...] = b2
    b3_ref[...] = b3
    len_ref[...] = L
    err_ref[0] = jnp.max(err_map.astype(jnp.int32))


@functools.partial(jax.jit, static_argnames=("interpret",))
def _call_jit(u3d, interpret):
    """u3d: int32 (nblk+2, ROWS, LANES) — zero tile at each end."""
    nblk = u3d.shape[0] - 2
    spec = lambda off: pl.BlockSpec(
        (1, ROWS, LANES), lambda i, off=off: (i + off, 0, 0))
    out2d = lambda: pl.BlockSpec((1, ROWS, LANES), lambda i: (i, 0, 0))
    tile = jax.ShapeDtypeStruct((nblk, ROWS, LANES), jnp.int32)
    return pl.pallas_call(
        utf16_encode_kernel,
        grid=(nblk,),
        in_specs=[spec(0), spec(1), spec(2)],
        out_specs=[out2d(), out2d(), out2d(), out2d(), out2d(),
                   pl.BlockSpec((1,), lambda i: (i,))],
        out_shape=[tile, tile, tile, tile, tile,
                   jax.ShapeDtypeStruct((nblk,), jnp.int32)],
        interpret=interpret,
    )(u3d, u3d, u3d)


def _call(u3d, interpret=None):
    return _call_jit(u3d, runtime.resolve_interpret(interpret))
