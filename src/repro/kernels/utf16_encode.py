"""Pallas TPU kernel: UTF-16 -> UTF-8 candidate-byte production (paper §5).

One grid step processes a BLOCK-unit VMEM tile of UTF-16 code units.  Per
lane we classify the unit (ASCII / 2-byte / 3-byte / surrogate half), fold
surrogate pairs into supplementary code points using one unit of lookahead
from the next tile (and one unit of lookbehind from the previous tile to
identify trailing halves), and emit the four candidate UTF-8 bytes plus a
per-lane byte length — exactly the state the paper's pshufb compress-store
consumes.  Global stream compaction (cumsum + scatter over the whole
buffer) happens outside the kernel in XLA.

The per-tile encode body lives in :func:`encode_tile` so that the fused
two-pass pipeline (``repro.kernels.fused_transcode``, DESIGN.md §5) can
re-run it inside its counting and writer kernels without shipping the four
full-capacity candidate arrays through HBM.

The paper's Algorithm 4 branches per 16-byte register on the maximal range
class.  TPU tiles are 1024 lanes and branching per tile would flush the
whole pipeline, so the kernel is branch-free: every lane computes all four
candidate encodings and selects by range (lane-parallel `where` trees are
one VPU op per node).  Surrogate-pair validation is fused (err flag per
tile), mirroring the paper's "validation at near-zero cost" claim.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import utf16 as u16core
from repro.kernels import runtime

ROWS = 8
LANES = 128
BLOCK = ROWS * LANES


def _shift_left_flat(cur, nxt, n):
    c = cur.reshape(-1)
    x = nxt.reshape(-1)
    return jnp.concatenate([c[n:], x[:n]]).reshape(cur.shape)


def _shift_right_flat(cur, prev, n):
    c = cur.reshape(-1)
    p = prev.reshape(-1)
    return jnp.concatenate([p[-n:], c[:-n]]).reshape(cur.shape)


def utf8_candidates(cp):
    """Candidate UTF-8 bytes + length for per-lane code points.

    Pure function of ``cp`` (paper Fig. 1 bit layout): returns
    ``(b0, b1, b2, b3, L)`` where ``L`` in 1..4 is the encoded length.
    Shared by the strict speculative path and the errors="replace" path
    (where U+FFFD lanes encode as EF BF BD).
    """
    c0 = cp & 0x3F
    c1 = (cp >> 6) & 0x3F
    c2 = (cp >> 12) & 0x3F
    c3 = (cp >> 18) & 0x07
    L = (
        1
        + (cp >= 0x80).astype(jnp.int32)
        + (cp >= 0x800).astype(jnp.int32)
        + (cp >= 0x10000).astype(jnp.int32)
    )
    z = jnp.zeros_like(cp)
    b0 = jnp.where(L == 1, cp,
         jnp.where(L == 2, 0xC0 | (cp >> 6),
         jnp.where(L == 3, 0xE0 | (cp >> 12), 0xF0 | c3)))
    b1 = jnp.where(L == 2, 0x80 | c0,
         jnp.where(L == 3, 0x80 | c1,
         jnp.where(L == 4, 0x80 | c2, z)))
    b2 = jnp.where(L == 3, 0x80 | c0,
         jnp.where(L == 4, 0x80 | c1, z))
    b3 = jnp.where(L == 4, 0x80 | c0, z)
    return b0, b1, b2, b3, L


def analyze_tile(u, up, un):
    """Unit analysis of one tile given its neighbour tiles.

    The body is the shared :func:`repro.core.utf16.analyze_units` (one
    unit of context each way), so the fused pipeline's unpaired-surrogate
    location and errors="replace" semantics match the pure-jnp reference
    bit for bit.  Returns the analysis dict (``starts`` / ``valid`` /
    ``cp`` / ``err``).
    """
    return u16core.analyze_units(
        u, _shift_left_flat(u, un, 1), _shift_right_flat(u, up, 1))


def encode_tile(u, up, un):
    """Encode one tile of UTF-16 units given its two neighbour tiles.

    All arguments are int32 arrays of identical (arbitrary) shape, treated
    as row-major flat unit streams by the shift helpers.  Returns
    ``(b0, b1, b2, b3, L, err_map)`` of the same shape: the four candidate
    UTF-8 bytes, the per-lane byte length (0 at non-lead trailing surrogate
    halves), and a per-position unpaired-surrogate error map (bool).
    Shared between :func:`utf16_encode_kernel` and the fused pipeline.
    """
    top6 = u >> 10
    is_hi = top6 == 0x36
    is_lo = top6 == 0x37

    nxt = _shift_left_flat(u, un, 1)
    prv = _shift_right_flat(u, up, 1)
    nxt_is_lo = (nxt >> 10) == 0x37
    prv_is_hi = (prv >> 10) == 0x36

    # Fold surrogate pairs (paper Fig. 4 surrogate construction, inverted).
    pair_cp = 0x10000 + ((u - 0xD800) << 10) + (nxt - 0xDC00)
    cp = jnp.where(is_hi, pair_cp, u)
    is_lead = ~(is_lo & prv_is_hi)

    b0, b1, b2, b3, L = utf8_candidates(cp)
    L = jnp.where(is_lead, L, 0)

    # Fused UTF-16 validation: unpaired surrogate halves.
    err_map = (is_hi & ~nxt_is_lo) | (is_lo & ~prv_is_hi)
    return b0, b1, b2, b3, L, err_map


def utf16_encode_kernel(u_prev_ref, u_cur_ref, u_next_ref,
                        b0_ref, b1_ref, b2_ref, b3_ref, len_ref, err_ref):
    u = u_cur_ref[...].astype(jnp.int32)
    up = u_prev_ref[...].astype(jnp.int32)
    un = u_next_ref[...].astype(jnp.int32)

    b0, b1, b2, b3, L, err_map = encode_tile(u, up, un)

    b0_ref[...] = b0
    b1_ref[...] = b1
    b2_ref[...] = b2
    b3_ref[...] = b3
    len_ref[...] = L
    err_ref[0] = jnp.max(err_map.astype(jnp.int32))


@functools.partial(jax.jit, static_argnames=("interpret",))
def _call_jit(u3d, interpret):
    """u3d: int32 (nblk+2, ROWS, LANES) — zero tile at each end."""
    nblk = u3d.shape[0] - 2
    spec = lambda off: pl.BlockSpec(
        (1, ROWS, LANES), lambda i, off=off: (i + off, 0, 0))
    out2d = lambda: pl.BlockSpec((1, ROWS, LANES), lambda i: (i, 0, 0))
    tile = jax.ShapeDtypeStruct((nblk, ROWS, LANES), jnp.int32)
    return pl.pallas_call(
        utf16_encode_kernel,
        grid=(nblk,),
        in_specs=[spec(0), spec(1), spec(2)],
        out_specs=[out2d(), out2d(), out2d(), out2d(), out2d(),
                   pl.BlockSpec((1,), lambda i: (i,))],
        out_shape=[tile, tile, tile, tile, tile,
                   jax.ShapeDtypeStruct((nblk,), jnp.int32)],
        interpret=interpret,
    )(u3d, u3d, u3d)


def _call(u3d, interpret=None):
    return _call_jit(u3d, runtime.resolve_interpret(interpret))
