"""Ragged packed-batch transcode: one Pallas launch for a whole batch,
any cell of the codec matrix.

The padded-vmap batch path (``data/pipeline.py`` ``strategy="vmap"``)
maps the single-document fused pipeline over a ``[B, L]`` buffer: B
separate grid dispatches, and every document — however short — scans all
``ceil(L/1024)`` of its tiles.  The packed path removes both costs.
Documents are concatenated into ONE tile-aligned narrow buffer
(``repro.core.packing``), and the *same* generic count/write tile bodies
(``repro.kernels.stages.driver``) run as a single grid launch over the
packed stream.  Per-document bookkeeping is all per-tile scalars:

  Ownership map    ``packing.tile_ownership`` (on device): tile ->
                   owning document (a searchsorted over the [B+1] offset
                   vector), the tile's document-end offset (the live
                   mask), and same-document neighbour flags.

  Count pass       One grid launch over all tiles of all documents.
                   The kernels differ from the single-stream ones in
                   precisely two multiplies: neighbour-tile inflow is
                   zeroed when the neighbour belongs to a different
                   document (``xp * same_prev`` / ``xn * same_next`` —
                   a character must never claim elements across a
                   document boundary), and the live mask compares
                   against the tile's own document end.

  Segment scan     The per-tile totals feed the SAME nblk-element
                   exclusive cumsum as the single-stream pipeline
                   (``compaction.tile_base_offsets``): because documents
                   are packed in order, the dense global scan IS the
                   per-document segment scan.

  Write pass       One grid launch; each tile compacts in VMEM and
                   stores at ``base[tile]`` exactly as the single-stream
                   writer.

  Per-doc reduce   counts = segment_sum(totals), error flags
                   segment_max, first-error offsets segment_min (the
                   NO_ERR_SENTINEL is int32 max, which is also
                   ``segment_min``'s empty-segment fill — zero-length
                   documents come out valid for free); statuses get the
                   document-relative offset with the same
                   ``status_from_first`` fold as the single-doc path.

Status/errors semantics are exactly :class:`repro.core.result.
TranscodeResult`'s, per document.  Every document's output slice is
bit-identical to running the single-document fused transcoder on that
document alone (pinned by ``tests/test_differential.py``).

The description above is the two-launch (``strategy="fused"``) form.
The DEFAULT is now ``strategy="onepass"`` (DESIGN.md §9): the count and
write bodies run in ONE grid launch off ONE decode per tile, with the
inter-tile/segment scan carried as a scalar in SMEM scratch across the
sequential grid — because documents are packed in order, the global
running offset IS the per-document segment scan, and the per-document
ownership masks (cross-document inflow zeroing + per-tile live ends) are
exactly the "per-tile ownership resets" the carry needs.  Per-tile
``(total, err, ferr)`` scalars still leave the kernel — they are the
*product* (the per-document segment reductions consume them), not
inter-pass coordination.  The per-tile three-way class dispatch (ASCII
copy / narrowed ≤2-byte body / general, DESIGN.md §9) rides along, so an
ASCII document packed next to a CJK document keeps its copy path and a
dense 2-byte document keeps the narrowed class, tile by tile.
"""

from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import compaction, packing
from repro.core import result as R
from repro.kernels import fused_transcode as ft
from repro.kernels import runtime
from repro.kernels import stages
from repro.kernels.stages import driver as sdrv
from repro.testing import faults

ROWS = ft.ROWS
LANES = ft.LANES
BLOCK = ft.BLOCK
STAGE16 = ft.STAGE16
STAGE8 = ft.STAGE8

_IMAX = R.NO_ERR_SENTINEL

_PER_TILE_SPEC = ft._PER_TILE_SPEC
_tile_spec = ft._tile_spec

_check_errors = R.check_errors_policy


def _nblk(total: int) -> int:
    return max(1, -(-total // BLOCK))


def _mask_to_docs(data, tile_end, nblk):
    """Zero every lane at or past its owning document's end.

    The packed layout already zero-fills inter-document slack; this is
    the defensive equivalent of the single-document wrappers' padding
    mask (``where(idx < n, x, 0)``), so garbage beyond a document's
    logical length can never leak into its neighbours' analysis.
    """
    pad = nblk * BLOCK - data.shape[0]
    d = jnp.concatenate([data, jnp.zeros((pad,), data.dtype)])
    lane_end = jnp.repeat(tile_end, BLOCK)
    live = jnp.arange(nblk * BLOCK, dtype=jnp.int32) < lane_end
    return jnp.where(live, d, jnp.zeros_like(d))


def _doc_reduce(totals, errs, ferrs, tile_doc, offsets, validate):
    """Per-tile scalars -> per-document (counts, out_offsets, statuses)."""
    n_docs = offsets.shape[0] - 1
    counts = jax.ops.segment_sum(totals, tile_doc, num_segments=n_docs)
    out_offsets = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32),
         jnp.cumsum(counts).astype(jnp.int32)])
    if not validate:
        statuses = jnp.full((n_docs,), R.STATUS_OK, jnp.int32)
        return counts, out_offsets, statuses
    # segment_min fills empty segments with int32 max == NO_ERR_SENTINEL
    # and segment_max with int32 min: zero-tile (empty) documents reduce
    # to a clean status without special-casing.
    err_doc = jax.ops.segment_max(errs, tile_doc, num_segments=n_docs)
    ferr_doc = jax.ops.segment_min(ferrs, tile_doc, num_segments=n_docs)
    first_rel = jnp.where(ferr_doc == _IMAX, _IMAX, ferr_doc - offsets[:-1])
    statuses = R.status_from_first(first_rel, err_doc > 0)
    return counts, out_offsets, statuses


# ---------------------------------------------------------------------------
# Generic ragged kernels: the single-stream generic bodies plus the
# ownership masking (cross-document neighbour inflow zeroed).


def _rcount_kernel(*refs, src, dst, errors, validate):
    codec_s, codec_d = stages.get_codec(src), stages.get_codec(dst)
    nt = len(codec_s.tables)
    table_refs = refs[:nt]
    (end_ref, sp_ref, sn_ref, xp_ref, x_ref, xn_ref,
     tot_ref, err_ref, ferr_ref) = refs[nt:]
    x = x_ref[...].astype(jnp.int32)
    # Ownership masking: inflow from a neighbour tile of a DIFFERENT
    # document reads as zeros, exactly like the zero boundary tiles of
    # the single-stream pipeline.
    xp = xp_ref[...].astype(jnp.int32) * sp_ref[0]
    xn = xn_ref[...].astype(jnp.int32) * sn_ref[0]
    gidx = ft._gidx(x.shape)
    tot_ref[0], err_ref[0], ferr_ref[0] = sdrv.count_tile(
        codec_s, codec_d, x, xp, xn, gidx < end_ref[0], gidx,
        tuple(t[...] for t in table_refs), errors=errors, validate=validate)


def _rwrite_kernel(end_ref, sp_ref, sn_ref, base_ref,
                   xp_ref, x_ref, xn_ref, out_ref, *, src, dst, errors):
    codec_s, codec_d = stages.get_codec(src), stages.get_codec(dst)
    width = stages.stage_width(codec_s, codec_d)
    x = x_ref[...].astype(jnp.int32)
    xp = xp_ref[...].astype(jnp.int32) * sp_ref[0]
    xn = xn_ref[...].astype(jnp.int32) * sn_ref[0]
    stage = sdrv.write_stage(codec_s, codec_d, x, xp, xn,
                             ft._gidx(x.shape) < end_ref[0], errors=errors)
    out_ref[pl.ds(base_ref[0], width)] = stage.astype(codec_d.dtype)


def _launch_geometry(data, offsets, lengths, src):
    """ONE definition of the ragged launch setup, shared by every ragged
    kernel call (count/write/onepass): the ownership map, the masked +
    boundary-tiled data, and the matching in_specs/operand prefix.
    Desynchronizing these between the bodies would compute base offsets
    on a different tiling than the writer stores with.
    """
    codec_s = stages.get_codec(src)
    nblk = _nblk(data.shape[0])
    tile_doc, tile_end, same_prev, same_next = packing.tile_ownership(
        offsets, lengths, nblk, BLOCK)
    dm = _mask_to_docs(data, tile_end, nblk)
    d3, _ = runtime.tile_with_boundaries(dm, ROWS, LANES, boundary_tiles=2)
    in_specs = ft._table_specs(codec_s) + [
        _PER_TILE_SPEC, _PER_TILE_SPEC, _PER_TILE_SPEC,
        _tile_spec(0), _tile_spec(1), _tile_spec(2)]
    operands = (*[jnp.asarray(t) for t in codec_s.tables],
                tile_end, same_prev, same_next, d3, d3, d3)
    return nblk, tile_doc, tile_end, same_prev, same_next, in_specs, \
        operands


def _rcount_call(data, offsets, lengths, src, dst, errors, validate,
                 interpret):
    nblk, tile_doc, tile_end, same_prev, same_next, in_specs, operands = \
        _launch_geometry(data, offsets, lengths, src)
    kernel = functools.partial(_rcount_kernel, src=src, dst=dst,
                               errors=errors, validate=validate)
    per_tile = jax.ShapeDtypeStruct((nblk,), jnp.int32)
    totals, errs, ferrs = pl.pallas_call(
        kernel,
        grid=(nblk,),
        in_specs=in_specs,
        out_specs=[_PER_TILE_SPEC, _PER_TILE_SPEC, _PER_TILE_SPEC],
        out_shape=[per_tile, per_tile, per_tile],
        interpret=interpret,
    )(*operands)
    d3 = operands[-1]
    return nblk, d3, tile_doc, tile_end, same_prev, same_next, \
        totals, errs, ferrs


# ---------------------------------------------------------------------------
# Single-pass ragged kernel (strategy="onepass", the default): count +
# write in one grid launch off one decode, base offsets carried in SMEM.


def _ronepass_kernel(*refs, src, dst, errors, validate, ascii_skip):
    codec_s, codec_d = stages.get_codec(src), stages.get_codec(dst)
    width = stages.stage_width(codec_s, codec_d)
    nt = len(codec_s.tables)
    table_refs = refs[:nt]
    (end_ref, sp_ref, sn_ref, xp_ref, x_ref, xn_ref,
     out_ref, tot_ref, err_ref, ferr_ref, carry) = refs[nt:]
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        carry[0] = 0

    x = x_ref[...].astype(jnp.int32)
    # Ownership masking, exactly as the two-launch kernels: cross-
    # document neighbour inflow reads as zeros.  (The zeroed inflow also
    # lets the per-tile ASCII predicate pass at document starts — a
    # document boundary is a clean inflow by construction.)
    xp = xp_ref[...].astype(jnp.int32) * sp_ref[0]
    xn = xn_ref[...].astype(jnp.int32) * sn_ref[0]
    gidx = ft._gidx(x.shape)
    tot, err, ferr, stage = sdrv.onepass_tile(
        codec_s, codec_d, x, xp, xn, gidx < end_ref[0], gidx,
        tuple(t[...] for t in table_refs), errors=errors,
        validate=validate, ascii_skip=ascii_skip)

    # Documents are packed in order, so the global running offset IS the
    # per-document segment scan (dense output, no inter-doc padding).
    base = carry[0]
    out_ref[pl.ds(base, width)] = stage.astype(codec_d.dtype)
    carry[0] = base + tot
    # Per-tile scalars are the per-document reduction's INPUT (segment
    # sum/min/max downstream), not inter-pass coordination.
    tot_ref[0], err_ref[0], ferr_ref[0] = tot, err, ferr


@functools.partial(jax.jit, static_argnames=("src", "dst", "validate",
                                             "interpret", "errors"))
def _ragged_onepass_impl(data, offsets, lengths, src, dst, validate,
                         interpret, errors):
    codec_s, codec_d, factor = stages.get_pair(src, dst)
    width = stages.stage_width(codec_s, codec_d)
    nblk, tile_doc, _tile_end, _sp, _sn, in_specs, operands = \
        _launch_geometry(data, offsets, lengths, src)
    kernel = functools.partial(_ronepass_kernel, src=src, dst=dst,
                               errors=errors, validate=validate,
                               ascii_skip=True)
    per_tile = jax.ShapeDtypeStruct((nblk,), jnp.int32)
    outp, totals, errs, ferrs = pl.pallas_call(
        kernel,
        grid=(nblk,),
        in_specs=in_specs,
        out_specs=[pl.BlockSpec((nblk * width,), lambda i: (0,)),
                   _PER_TILE_SPEC, _PER_TILE_SPEC, _PER_TILE_SPEC],
        out_shape=[jax.ShapeDtypeStruct((nblk * width,), codec_d.dtype),
                   per_tile, per_tile, per_tile],
        scratch_shapes=[pltpu.SMEM((1,), jnp.int32)],
        interpret=interpret,
    )(*operands)
    total = jnp.sum(totals)
    cap = factor * nblk * BLOCK
    outp = outp[:cap]
    outp = jnp.where(jnp.arange(cap) < total, outp,
                     jnp.zeros((), codec_d.dtype))
    counts, out_offsets, statuses = _doc_reduce(
        totals, errs, ferrs, tile_doc, offsets, validate)
    return R.RaggedTranscodeResult(outp, out_offsets, counts, statuses)


@functools.partial(jax.jit, static_argnames=("src", "dst", "validate",
                                             "interpret", "errors"))
def _ragged_impl(data, offsets, lengths, src, dst, validate, interpret,
                 errors):
    codec_s, codec_d, factor = stages.get_pair(src, dst)
    width = stages.stage_width(codec_s, codec_d)
    nblk, d3, tile_doc, tile_end, same_prev, same_next, totals, errs, \
        ferrs = _rcount_call(data, offsets, lengths, src, dst, errors,
                             validate, interpret)
    base, total = compaction.tile_base_offsets(totals)
    outp = pl.pallas_call(
        functools.partial(_rwrite_kernel, src=src, dst=dst, errors=errors),
        grid=(nblk,),
        in_specs=[_PER_TILE_SPEC, _PER_TILE_SPEC, _PER_TILE_SPEC,
                  _PER_TILE_SPEC,
                  _tile_spec(0), _tile_spec(1), _tile_spec(2)],
        out_specs=pl.BlockSpec((nblk * width,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((nblk * width,), codec_d.dtype),
        interpret=interpret,
    )(tile_end, same_prev, same_next, base, d3, d3, d3)
    # Same capacity budget per document as the padded-vmap path (its
    # tile span); clear the write-window slack after the last tile.
    cap = factor * nblk * BLOCK
    outp = outp[:cap]
    outp = jnp.where(jnp.arange(cap) < total, outp,
                     jnp.zeros((), codec_d.dtype))
    counts, out_offsets, statuses = _doc_reduce(
        totals, errs, ferrs, tile_doc, offsets, validate)
    return R.RaggedTranscodeResult(outp, out_offsets, counts, statuses)


@functools.partial(jax.jit, static_argnames=("src", "dst", "interpret"))
def _ragged_scan_impl(data, offsets, lengths, src, dst, interpret):
    _nb, _d3, tile_doc, _te, _sp, _sn, totals, errs, ferrs = _rcount_call(
        data, offsets, lengths, src, dst, "strict", True, interpret)
    counts, _oo, statuses = _doc_reduce(
        totals, errs, ferrs, tile_doc, offsets, True)
    return counts, statuses


def _as_packed(data, offsets, lengths, dtype):
    data = jnp.asarray(data)
    if data.dtype != dtype:
        data = data.astype(dtype)
    offsets = jnp.asarray(offsets, jnp.int32)
    lengths = jnp.asarray(lengths, jnp.int32)
    if offsets.ndim != 1 or offsets.shape[0] < 2:
        raise ValueError("offsets must be [B+1] with B >= 1")
    if lengths.shape[0] != offsets.shape[0] - 1:
        raise ValueError(
            f"lengths [B] must match offsets [B+1]: "
            f"{lengths.shape[0]} vs {offsets.shape[0]}")
    # Layout invariants are cheap to check on the host and silently
    # corrupt per-document results when violated (a mid-tile start makes
    # tile_ownership assign the tile to the wrong document).  Skip under
    # tracing — jitted callers (e.g. the pipeline batch entries) build
    # offsets from static shapes, which satisfy the invariants by
    # construction.
    if not isinstance(offsets, jax.core.Tracer) \
            and not isinstance(lengths, jax.core.Tracer):
        off_h = np.asarray(offsets)
        len_h = np.asarray(lengths)
        spans = np.diff(off_h)
        if off_h[0] != 0 or (off_h % BLOCK).any() or (spans < 0).any():
            raise ValueError(
                f"offsets must start at 0, be non-decreasing and "
                f"tile-aligned (multiples of {BLOCK}); use "
                f"repro.core.packing.pack_documents")
        if off_h[-1] > data.shape[0]:
            raise ValueError(
                f"data ({data.shape[0]} elements) does not cover "
                f"offsets[-1] ({int(off_h[-1])}): truncated documents "
                f"would silently report as empty and valid")
        if (len_h < 0).any() or (len_h > spans).any():
            raise ValueError(
                "lengths must fit within their documents' offset spans")
    return data, offsets, lengths


def transcode_ragged(data, offsets, lengths, *, src: str, dst: str,
                     validate: bool = True, errors: str = "strict",
                     interpret=None, strategy: str = "onepass"):
    """Ragged packed-batch transcode for any (src, dst) matrix cell.

    ``data``/``offsets``/``lengths`` is the tile-aligned packed layout of
    :func:`repro.core.packing.pack_documents`.  Returns a
    :class:`repro.core.result.RaggedTranscodeResult`: a dense output
    stream in the destination's narrow dtype plus per-document
    ``(offsets, counts, statuses)`` — each document's slice is
    bit-identical to the single-document fused transcoder's
    ``buffer[:count]`` / ``count`` / ``status``.

    ``strategy="onepass"`` (default) runs the batch as ONE grid launch
    with the segment scan carried in SMEM (one read + one decode of the
    packed stream); ``strategy="fused"`` keeps the two-launch
    count/cumsum/write reference.  Both are bit-identical per document.
    """
    _check_errors(errors)
    faults.fire(faults.KERNEL_RAGGED)    # chaos-suite hook (no-op in prod)
    codec_s, _codec_d, _f = stages.get_pair(src, dst)
    data, offsets, lengths = _as_packed(data, offsets, lengths,
                                        codec_s.dtype)
    if strategy == "onepass":
        return _ragged_onepass_impl(data, offsets, lengths, src, dst,
                                    validate,
                                    runtime.resolve_interpret(interpret),
                                    errors)
    if strategy != "fused":
        raise ValueError(
            f"transcode_ragged: unknown strategy {strategy!r} "
            f"(expected 'onepass' or 'fused')")
    return _ragged_impl(data, offsets, lengths, src, dst, validate,
                        runtime.resolve_interpret(interpret), errors)


def scan_ragged(data, offsets, lengths, *, src: str, dst: str,
                interpret=None):
    """Counting pass only, per document: ``(counts, statuses)``.

    One read of the packed batch yields every document's destination
    capacity and first-error status — the multi-request
    ingestion-boundary query (serve ingress validates a whole wave of
    prompts with one launch).
    """
    faults.fire(faults.KERNEL_RAGGED_SCAN)  # chaos-suite hook (no-op)
    codec_s, _codec_d, _f = stages.get_pair(src, dst)
    data, offsets, lengths = _as_packed(data, offsets, lengths,
                                        codec_s.dtype)
    return _ragged_scan_impl(data, offsets, lengths, src, dst,
                             runtime.resolve_interpret(interpret))


# ---------------------------------------------------------------------------
# Thin per-pair instantiations (the pre-matrix public API).


def utf8_to_utf16_ragged(data, offsets, lengths, *, validate: bool = True,
                         errors: str = "strict", interpret=None,
                         strategy: str = "onepass"):
    """Ragged packed-batch UTF-8 -> UTF-16: one launch per batch."""
    return transcode_ragged(data, offsets, lengths, src="utf8", dst="utf16",
                            validate=validate, errors=errors,
                            interpret=interpret, strategy=strategy)


def utf8_scan_ragged(data, offsets, lengths, *, interpret=None):
    """Counting pass only, per document: ``(counts, statuses)``."""
    return scan_ragged(data, offsets, lengths, src="utf8", dst="utf16",
                       interpret=interpret)


def utf16_to_utf8_ragged(data, offsets, lengths, *, validate: bool = True,
                         errors: str = "strict", interpret=None,
                         strategy: str = "onepass"):
    """Ragged packed-batch UTF-16 -> UTF-8: one launch per batch."""
    return transcode_ragged(data, offsets, lengths, src="utf16", dst="utf8",
                            validate=validate, errors=errors,
                            interpret=interpret, strategy=strategy)


def utf16_scan_ragged(data, offsets, lengths, *, interpret=None):
    """Counting pass only, per document: ``(counts, statuses)``."""
    return scan_ragged(data, offsets, lengths, src="utf16", dst="utf8",
                       interpret=interpret)
