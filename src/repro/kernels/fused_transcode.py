"""Fused two-pass Pallas transcode pipeline (strategy ``"fused"``).

This is the hierarchical, in-kernel answer to the global cumsum+scatter
compaction of ``repro.core.transcode`` (DESIGN.md §5).  The block-parallel
strategy round-trips three full-capacity int32 candidate arrays
(cp / lead / units, 12 bytes per input byte) through HBM before XLA
compacts them — the TPU analogue of writing every speculative lane to
memory and shuffling afterwards.  Here nothing full-capacity and nothing
int32 ever leaves the kernels:

  Pass 1 (count)   Each grid step speculatively decodes its VMEM tile
                   (re-using :func:`repro.kernels.utf8_decode.decode_tile`
                   / :func:`repro.kernels.utf16_encode.encode_tile`) and
                   emits THREE scalars — the tile's total output length,
                   a fused validation flag, and the tile's first-error
                   offset.  Validation is *folded into this scan*
                   (DESIGN.md §4): the Keiser-Lemire nibble tables run
                   against the tile already resident in VMEM, and the
                   maximal-subpart analysis
                   (``repro.core.utf8.analyze_subparts``) locates the
                   first ill-formed sequence with Python
                   ``UnicodeDecodeError.start`` semantics.  No standalone
                   validation pass re-reads the input.  HBM egress: 12
                   bytes per 1024-element tile.

  Inter-tile scan  An ``nblk``-element exclusive cumsum over the per-tile
                   totals (``compaction.tile_base_offsets``) yields each
                   tile's base offset in the compact output.  This is the
                   only global coordination: nblk scalars, not N lanes.

  Pass 2 (write)   Each grid step re-decodes its tile (decode is cheap;
                   bandwidth is not), compacts it *inside VMEM* with an
                   intra-tile exclusive scan (``tile_exclusive_scan``) and
                   an in-register scatter — the hierarchical equivalent of
                   AVX-512 ``vpcompressb`` compress-store — and stores the
                   compact tile at ``base[tile]``.  Output lane j of the
                   final buffer is written exactly once, at
                   ``base[tile] + local_rank``.

Error semantics (the ``errors=`` policy, DESIGN.md §4):

  * ``errors="strict"``   — historical behavior: the output buffer holds
    the speculative transcode (bit-identical to ``blockparallel``), and
    the int32 ``status`` of the returned
    :class:`repro.core.result.TranscodeResult` carries the offset of the
    first invalid maximal subpart (-1 when valid).
  * ``errors="replace"``  — malformed input transcodes at full speed:
    every maximal subpart of an ill-formed sequence (W3C / CPython
    semantics) emits one U+FFFD, selected branch-free inside the same
    count/write kernels (the policy is a static compile-time switch; no
    data-dependent branch exists in either kernel).  ``status`` still
    reports where the first substitution happened.

The writer stores a full tile-width window at ``base[tile]``; the slack
beyond the tile's total is overwritten by the next tile's window (grid
steps execute in order), and the slack after the *last* tile is cleared
by the wrapper.  I/O dtypes are narrow end-to-end: UTF-8 bytes travel as
``uint8`` and UTF-16 units as ``uint16``; lanes widen to int32 only
inside VMEM.  Ingress HBM traffic drops 4x vs the int32 paths.

Interpreter-mode notes: the in-tile compaction is expressed as a jnp
scatter on VMEM-resident values and the writer output block is the whole
staging buffer revisited every grid step with a dynamic-offset store.
Both passes are plain ``pl.pallas_call``s and run under
``interpret=True`` on CPU (auto-detected, see ``repro.kernels.runtime``).
Compiled-TPU caveat: the whole-buffer output block implies full-buffer
VMEM residency, which bounds a single call to roughly VMEM-sized inputs
(~4 MB); larger documents must be chunked at that granularity, or the
writer re-expressed with a per-tile output block at a scalar-prefetched
base offset (PrefetchScalarGridSpec) plus the on-chip shuffle form of
the in-tile scatter — the planned shape for real-TPU deployment.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import compaction
from repro.core import result as R
from repro.core import tables as T
from repro.core import utf16 as u16mod
from repro.kernels import runtime
from repro.kernels import utf8_decode as kdec
from repro.kernels import utf8_validate as kval
from repro.kernels import utf16_encode as kenc

ROWS = 8
LANES = 128
BLOCK = ROWS * LANES
# Per-tile staging widths are sized for the SPECULATIVE worst case, not the
# valid-input worst case: on garbage input every byte of a tile can decode
# as a 4-byte lead with a supplementary code point (2 units), so a UTF-8
# tile can claim up to 2*BLOCK units.  A UTF-16 tile tops out at
# 3*BLOCK + 1 bytes: a 4-byte lane is normally followed in-tile by its
# 0-byte trailing-surrogate lane, EXCEPT in the last lane, whose pairing
# low surrogate lives in the next tile (1023 three-byte lanes + one
# 4-byte lane).  Undersizing these desynchronizes base offsets from
# blockparallel's global cumsum and overflows the windowed store.
# errors="replace" stays within the same bounds (a replacement lane is 1
# unit / 3 bytes, never more than the speculative maximum).
STAGE16 = 2 * BLOCK      # max UTF-16 units out of one 1024-byte UTF-8 tile
STAGE8 = 3 * BLOCK + 1   # max UTF-8 bytes out of one 1024-unit UTF-16 tile

_IMAX = R.NO_ERR_SENTINEL


def _tile(x):
    """Pad flat narrow array to whole tiles + one zero boundary tile/side."""
    return runtime.tile_with_boundaries(x, ROWS, LANES, boundary_tiles=2)


def _gidx(shape):
    """Global stream index of every lane in the current tile."""
    i = pl.program_id(0)
    return i * BLOCK + jnp.arange(BLOCK, dtype=jnp.int32).reshape(shape)


_check_errors = R.check_errors_policy


# Shared BlockSpecs: one definition of the tile geometry / neighbour-tile
# offset convention for the count and write passes of both directions —
# desynchronizing them would compute base offsets on a different tiling
# than the writer stores with.
def _tile_spec(off):
    """Current/prev/next tile of the (nblk+2, ROWS, LANES) padded array."""
    return pl.BlockSpec((1, ROWS, LANES), lambda i, off=off: (i + off, 0, 0))


_SCALAR_SPEC = pl.BlockSpec((1,), lambda i: (0,))     # broadcast scalar
_TABLE_SPEC = pl.BlockSpec((16,), lambda i: (0,))     # KL nibble table
_PER_TILE_SPEC = pl.BlockSpec((1,), lambda i: (i,))   # per-tile scalar out


# ---------------------------------------------------------------------------
# UTF-8 -> UTF-16
#
# The per-tile count/write bodies are free functions of VMEM-resident
# arrays so the ragged packed-batch kernels
# (``repro.kernels.ragged_transcode``) can run EXACTLY the same scan with
# a per-document live mask — one definition of the transcode per
# direction, two launch geometries (single stream / packed batch).


def count8_tile(b, bp, bn, live, gidx, t1h, t1l, t2h, *, errors, validate):
    """One counting/validating scan of a VMEM tile.

    ``live`` is the caller's in-stream mask (single stream: ``gidx < n``;
    ragged: ``gidx < doc_end``).  Returns the three per-tile scalars
    ``(total, err_flag, first_err_gidx)`` — first-error offsets are in
    *global* stream coordinates (callers subtract the document start).
    """
    need_analysis = validate or errors == "replace"
    a = kdec.analyze_tile(b, bp, bn) if need_analysis else None
    if errors == "replace":
        tot = jnp.sum(jnp.where(a["starts"] & live, a["units"], 0))
    else:
        _cp, is_lead, units, _err = kdec.decode_tile(b, bp, bn)
        tot = jnp.sum(jnp.where(is_lead & live, units, 0))

    if validate:
        # Fused validation, one scan: the paper-faithful Keiser-Lemire
        # nibble tables give the structural verdict, the maximal-subpart
        # map locates the first error at its lead byte (Python exc.start
        # semantics).  The detectors are equivalent on live bytes (the
        # fuzz suite pins both to CPython); KL rides along deliberately —
        # it is the paper's §4 validator, and OR-ing it in means a defect
        # in either detector degrades to a located (or offset-0) error
        # rather than a silently accepted invalid stream.
        kl = kval.kl_error_tile(b, bp, t1h, t1l, t2h) & live
        sub = a["err"] & live
        err = jnp.max((kl | sub).astype(jnp.int32))
        ferr = jnp.min(jnp.where(sub, gidx, _IMAX))
    else:
        err = jnp.int32(0)
        ferr = jnp.int32(_IMAX)
    return tot, err, ferr


def write8_stage(b, bp, bn, instream, *, errors):
    """Decode + in-tile compaction of one tile: the write-pass body.

    ``instream`` is the caller's in-stream mask of ``b``'s shape.
    Returns the compact int32 stage window (STAGE16 lanes); the caller
    stores it at the tile's base offset.
    """
    if errors == "replace":
        a = kdec.analyze_tile(b, bp, bn)
        cp = a["cp"]
        live = (a["starts"] & instream).reshape(-1)
        eff = jnp.where(live, a["units"].reshape(-1), 0)
    else:
        cp, is_lead, units, _err = kdec.decode_tile(b, bp, bn)
        live = (is_lead & instream).reshape(-1)
        eff = jnp.where(live, units.reshape(-1), 0)
    rank, _tot = compaction.tile_exclusive_scan(eff, rows=ROWS)
    _u, u0, u1, _bad = u16mod.encode_candidates(cp)
    # In-register compress-store (vpcompressb analogue): scatter the 1-2
    # code units of each live lane to base-relative rank inside VMEM.
    stage = jnp.zeros((STAGE16,), jnp.int32)
    stage = stage.at[jnp.where(live, rank, STAGE16)].set(
        u0.reshape(-1), mode="drop")
    stage = stage.at[jnp.where(live & (eff == 2), rank + 1, STAGE16)].set(
        u1.reshape(-1), mode="drop")
    return stage


def _count8_kernel(t1h_ref, t1l_ref, t2h_ref, n_ref, bp_ref, b_ref, bn_ref,
                   tot_ref, err_ref, ferr_ref, *, errors, validate):
    b = b_ref[...].astype(jnp.int32)
    bp = bp_ref[...].astype(jnp.int32)
    bn = bn_ref[...].astype(jnp.int32)
    gidx = _gidx(b.shape)
    tot_ref[0], err_ref[0], ferr_ref[0] = count8_tile(
        b, bp, bn, gidx < n_ref[0], gidx,
        t1h_ref[...], t1l_ref[...], t2h_ref[...],
        errors=errors, validate=validate)


def _write8_kernel(n_ref, base_ref, bp_ref, b_ref, bn_ref, out_ref, *,
                   errors):
    b = b_ref[...].astype(jnp.int32)
    bp = bp_ref[...].astype(jnp.int32)
    bn = bn_ref[...].astype(jnp.int32)
    stage = write8_stage(b, bp, bn, _gidx(b.shape) < n_ref[0], errors=errors)
    out_ref[pl.ds(base_ref[0], STAGE16)] = stage.astype(jnp.uint16)


def _count8_call(bm, n, errors, validate, interpret):
    """One counting/validating scan over the tiled bytes.

    Returns (totals, errs, ferrs): per-tile output totals, fused
    error flags and first-error offsets.
    """
    b3, nblk = _tile(bm)
    n1 = jnp.asarray(n, jnp.int32).reshape(1)
    kernel = functools.partial(_count8_kernel, errors=errors,
                               validate=validate)
    totals, errs, ferrs = pl.pallas_call(
        kernel,
        grid=(nblk,),
        in_specs=[_TABLE_SPEC, _TABLE_SPEC, _TABLE_SPEC, _SCALAR_SPEC,
                  _tile_spec(0), _tile_spec(1), _tile_spec(2)],
        out_specs=[_PER_TILE_SPEC, _PER_TILE_SPEC, _PER_TILE_SPEC],
        out_shape=[jax.ShapeDtypeStruct((nblk,), jnp.int32),
                   jax.ShapeDtypeStruct((nblk,), jnp.int32),
                   jax.ShapeDtypeStruct((nblk,), jnp.int32)],
        interpret=interpret,
    )(jnp.asarray(T.BYTE_1_HIGH), jnp.asarray(T.BYTE_1_LOW),
      jnp.asarray(T.BYTE_2_HIGH), n1, b3, b3, b3)
    return b3, nblk, totals, errs, ferrs


def _status(errs, ferrs, validate):
    if not validate:
        return jnp.int32(R.STATUS_OK)
    first = jnp.min(ferrs, initial=_IMAX)
    return R.status_from_first(first, jnp.max(errs, initial=0) > 0)


@functools.partial(jax.jit, static_argnames=("validate", "interpret",
                                             "ascii_fastpath", "masked",
                                             "errors"))
def _utf8_to_utf16_impl(b, n, validate, interpret, ascii_fastpath, masked,
                        errors):
    cap = b.shape[0]
    idx = jnp.arange(cap)
    bm = jnp.where(idx < n, b, 0).astype(jnp.uint8) if masked else b

    def general(bm):
        b3, nblk, totals, errs, ferrs = _count8_call(
            bm, n, errors, validate, interpret)
        n1 = jnp.asarray(n, jnp.int32).reshape(1)
        base, total = compaction.tile_base_offsets(totals)
        outp = pl.pallas_call(
            functools.partial(_write8_kernel, errors=errors),
            grid=(nblk,),
            in_specs=[_SCALAR_SPEC, _PER_TILE_SPEC,
                      _tile_spec(0), _tile_spec(1), _tile_spec(2)],
            # The whole compact buffer is one revisited block: each grid
            # step stores its tile at a data-dependent offset inside it.
            # Sized so the window store at the largest possible base
            # (STAGE16 per preceding tile, speculative worst case) fits.
            out_specs=pl.BlockSpec((nblk * STAGE16,), lambda i: (0,)),
            out_shape=jax.ShapeDtypeStruct((nblk * STAGE16,), jnp.uint16),
            interpret=interpret,
        )(n1, base, b3, b3, b3)
        # Keep the first `cap` lanes (matching blockparallel's drop-at-
        # capacity) and clear the write-window slack after the last tile.
        outp = outp[:cap]
        outp = jnp.where(jnp.arange(cap) < total, outp, 0)
        return R.TranscodeResult(outp, total, _status(errs, ferrs, validate))

    def ascii(bm):
        # Paper Algorithm 3 fast path: widening copy (uint8 -> uint16).
        return R.TranscodeResult(bm.astype(jnp.uint16),
                                 jnp.asarray(n, jnp.int32),
                                 jnp.int32(R.STATUS_OK))

    if not ascii_fastpath:
        return general(bm)
    return jax.lax.cond(jnp.all(bm < 0x80), ascii, general, bm)


def utf8_to_utf16_fused(b, n_valid=None, *, validate: bool = True,
                        errors: str = "strict", interpret=None,
                        ascii_fastpath: bool = True):
    """Fused two-pass UTF-8 -> UTF-16 transcode.

    Returns ``TranscodeResult(u16_buffer[uint16, capacity=len(b)], count,
    status)`` — under ``errors="strict"``, ``buffer[:count]`` and
    ``count`` are bit-identical to the block-parallel strategy and
    ``status`` carries the first invalid byte offset (-1 = valid); under
    ``errors="replace"`` every maximal subpart of an ill-formed sequence
    becomes U+FFFD (CPython ``errors="replace"`` semantics) at full
    speed.  Validation is fused into the counting scan: the input bytes
    are never read by a standalone validation pass.
    """
    _check_errors(errors)
    b = jnp.asarray(b)
    if b.dtype != jnp.uint8:
        b = b.astype(jnp.uint8)
    n = b.shape[0] if n_valid is None else n_valid
    return _utf8_to_utf16_impl(
        b, jnp.asarray(n, jnp.int32), validate,
        runtime.resolve_interpret(interpret), ascii_fastpath,
        n_valid is not None, errors)


@functools.partial(jax.jit, static_argnames=("interpret", "masked"))
def _utf8_scan_impl(b, n, interpret, masked):
    cap = b.shape[0]
    idx = jnp.arange(cap)
    bm = jnp.where(idx < n, b, 0).astype(jnp.uint8) if masked else b
    _b3, _nblk, totals, errs, ferrs = _count8_call(
        bm, n, "strict", True, interpret)
    return jnp.sum(totals), _status(errs, ferrs, True)


def utf8_scan_fused(b, n_valid=None, *, interpret=None):
    """Single-scan UTF-8 validation + UTF-16 length: (count, status).

    Runs ONLY the fused pipeline's counting pass — one read of the input
    bytes yields the simdutf-style verdict: ``status`` is -1 for valid
    streams, else the byte offset of the first invalid maximal subpart
    (Python ``UnicodeDecodeError.start``), and ``count`` is the UTF-16
    code units a transcode would produce.  This is the ingestion-boundary
    API (serve ingress): validation with error location at the cost of a
    capacity query.
    """
    b = jnp.asarray(b)
    if b.dtype != jnp.uint8:
        b = b.astype(jnp.uint8)
    n = b.shape[0] if n_valid is None else n_valid
    return _utf8_scan_impl(b, jnp.asarray(n, jnp.int32),
                           runtime.resolve_interpret(interpret),
                           n_valid is not None)


# ---------------------------------------------------------------------------
# UTF-16 -> UTF-8


def count16_tile(u, up, un, live, gidx, *, errors, validate):
    """One counting/validating scan of a UTF-16 VMEM tile.

    Same contract as :func:`count8_tile` (shared with the ragged packed
    kernels): returns ``(total, err_flag, first_err_gidx)`` with the
    first-error offset in global stream coordinates.
    """
    need_analysis = validate or errors == "replace"
    a = kenc.analyze_tile(u, up, un) if need_analysis else None
    if errors == "replace":
        _b0, _b1, _b2, _b3, L = kenc.utf8_candidates(a["cp"])
        tot = jnp.sum(jnp.where(a["starts"] & live, L, 0))
    else:
        _b0, _b1, _b2, _b3, L, _err_map = kenc.encode_tile(u, up, un)
        tot = jnp.sum(jnp.where((L > 0) & live, L, 0))

    if validate:
        sub = a["err"] & live
        err = jnp.max(sub.astype(jnp.int32))
        ferr = jnp.min(jnp.where(sub, gidx, _IMAX))
    else:
        err = jnp.int32(0)
        ferr = jnp.int32(_IMAX)
    return tot, err, ferr


def write16_stage(u, up, un, instream, *, errors):
    """Encode + in-tile compaction of one UTF-16 tile (write-pass body)."""
    if errors == "replace":
        a = kenc.analyze_tile(u, up, un)
        b0, b1, b2, b3, L = kenc.utf8_candidates(a["cp"])
        live = (a["starts"] & instream).reshape(-1)
    else:
        b0, b1, b2, b3, L, _err = kenc.encode_tile(u, up, un)
        live = ((L > 0) & instream).reshape(-1)
    eff = jnp.where(live, L.reshape(-1), 0)
    rank, _tot = compaction.tile_exclusive_scan(eff, rows=ROWS)
    # Variable 1-4 byte egress: ``compact_offsets`` semantics, in-tile.
    stage = jnp.zeros((STAGE8,), jnp.int32)
    stage = stage.at[jnp.where(live, rank, STAGE8)].set(
        b0.reshape(-1), mode="drop")
    stage = stage.at[jnp.where(live & (eff >= 2), rank + 1, STAGE8)].set(
        b1.reshape(-1), mode="drop")
    stage = stage.at[jnp.where(live & (eff >= 3), rank + 2, STAGE8)].set(
        b2.reshape(-1), mode="drop")
    stage = stage.at[jnp.where(live & (eff == 4), rank + 3, STAGE8)].set(
        b3.reshape(-1), mode="drop")
    return stage


def _count16_kernel(n_ref, up_ref, u_ref, un_ref,
                    tot_ref, err_ref, ferr_ref, *, errors, validate):
    u = u_ref[...].astype(jnp.int32)
    up = up_ref[...].astype(jnp.int32)
    un = un_ref[...].astype(jnp.int32)
    gidx = _gidx(u.shape)
    tot_ref[0], err_ref[0], ferr_ref[0] = count16_tile(
        u, up, un, gidx < n_ref[0], gidx, errors=errors, validate=validate)


def _write16_kernel(n_ref, base_ref, up_ref, u_ref, un_ref, out_ref, *,
                    errors):
    u = u_ref[...].astype(jnp.int32)
    up = up_ref[...].astype(jnp.int32)
    un = un_ref[...].astype(jnp.int32)
    stage = write16_stage(u, up, un, _gidx(u.shape) < n_ref[0],
                          errors=errors)
    out_ref[pl.ds(base_ref[0], STAGE8)] = stage.astype(jnp.uint8)


def _count16_call(um, n, errors, validate, interpret):
    u3, nblk = _tile(um)
    n1 = jnp.asarray(n, jnp.int32).reshape(1)
    kernel = functools.partial(_count16_kernel, errors=errors,
                               validate=validate)
    totals, errs, ferrs = pl.pallas_call(
        kernel,
        grid=(nblk,),
        in_specs=[_SCALAR_SPEC, _tile_spec(0), _tile_spec(1), _tile_spec(2)],
        out_specs=[_PER_TILE_SPEC, _PER_TILE_SPEC, _PER_TILE_SPEC],
        out_shape=[jax.ShapeDtypeStruct((nblk,), jnp.int32),
                   jax.ShapeDtypeStruct((nblk,), jnp.int32),
                   jax.ShapeDtypeStruct((nblk,), jnp.int32)],
        interpret=interpret,
    )(n1, u3, u3, u3)
    return u3, nblk, totals, errs, ferrs


@functools.partial(jax.jit, static_argnames=("validate", "interpret",
                                             "ascii_fastpath", "masked",
                                             "errors"))
def _utf16_to_utf8_impl(u, n, validate, interpret, ascii_fastpath, masked,
                        errors):
    cap_in = u.shape[0]
    cap = 3 * cap_in
    idx = jnp.arange(cap_in)
    um = jnp.where(idx < n, u, 0).astype(jnp.uint16) if masked else u

    def general(um):
        u3, nblk, totals, errs, ferrs = _count16_call(
            um, n, errors, validate, interpret)
        n1 = jnp.asarray(n, jnp.int32).reshape(1)
        base, total = compaction.tile_base_offsets(totals)
        outp = pl.pallas_call(
            functools.partial(_write16_kernel, errors=errors),
            grid=(nblk,),
            in_specs=[_SCALAR_SPEC, _PER_TILE_SPEC,
                      _tile_spec(0), _tile_spec(1), _tile_spec(2)],
            out_specs=pl.BlockSpec((nblk * STAGE8,), lambda i: (0,)),
            out_shape=jax.ShapeDtypeStruct((nblk * STAGE8,), jnp.uint8),
            interpret=interpret,
        )(n1, base, u3, u3, u3)
        outp = outp[:cap]
        outp = jnp.where(jnp.arange(cap) < total, outp, 0)
        return R.TranscodeResult(outp, total, _status(errs, ferrs, validate))

    def ascii(um):
        out = jnp.concatenate(
            [um.astype(jnp.uint8), jnp.zeros((cap - cap_in,), jnp.uint8)])
        return R.TranscodeResult(out, jnp.asarray(n, jnp.int32),
                                 jnp.int32(R.STATUS_OK))

    if not ascii_fastpath:
        return general(um)
    return jax.lax.cond(jnp.all(um < 0x80), ascii, general, um)


def utf16_to_utf8_fused(u, n_valid=None, *, validate: bool = True,
                        errors: str = "strict", interpret=None,
                        ascii_fastpath: bool = True):
    """Fused two-pass UTF-16 -> UTF-8 transcode.

    Returns ``TranscodeResult(byte_buffer[uint8, capacity=3*len(u)],
    count, status)`` — under ``errors="strict"`` bit-identical in
    ``buffer[:count]``/``count`` to the block-parallel strategy, with
    ``status`` carrying the unit offset of the first unpaired surrogate
    (-1 = valid); under ``errors="replace"`` every unpaired half encodes
    as U+FFFD (EF BF BD), CPython ``errors="replace"`` semantics.
    """
    _check_errors(errors)
    u = jnp.asarray(u)
    if u.dtype != jnp.uint16:
        u = u.astype(jnp.uint16)
    n = u.shape[0] if n_valid is None else n_valid
    return _utf16_to_utf8_impl(
        u, jnp.asarray(n, jnp.int32), validate,
        runtime.resolve_interpret(interpret), ascii_fastpath,
        n_valid is not None, errors)


@functools.partial(jax.jit, static_argnames=("interpret", "masked"))
def _utf16_scan_impl(u, n, interpret, masked):
    cap_in = u.shape[0]
    idx = jnp.arange(cap_in)
    um = jnp.where(idx < n, u, 0).astype(jnp.uint16) if masked else u
    _u3, _nblk, totals, errs, ferrs = _count16_call(
        um, n, "strict", True, interpret)
    return jnp.sum(totals), _status(errs, ferrs, True)


def utf16_scan_fused(u, n_valid=None, *, interpret=None):
    """Single-scan UTF-16 validation + UTF-8 length: (count, status).

    One counting-pass read of the units yields the UTF-8 byte length a
    transcode would produce and a status that is -1 for valid streams,
    else the unit offset of the first unpaired surrogate half.
    """
    u = jnp.asarray(u)
    if u.dtype != jnp.uint16:
        u = u.astype(jnp.uint16)
    n = u.shape[0] if n_valid is None else n_valid
    return _utf16_scan_impl(u, jnp.asarray(n, jnp.int32),
                            runtime.resolve_interpret(interpret),
                            n_valid is not None)
