"""Fused two-pass Pallas transcode pipeline (strategy ``"fused"``) —
pair-agnostic over the codec matrix.

This is the hierarchical, in-kernel answer to the global cumsum+scatter
compaction of ``repro.core.transcode`` (DESIGN.md §5), generalized from
two hardwired format pairs to the full decode×encode matrix of
``repro.kernels.stages`` (DESIGN.md §8).  Nothing full-capacity and
nothing int32 ever leaves the kernels:

  Pass 1 (count)   Each grid step speculatively decodes its VMEM tile
                   through the *source* codec's decode stage, lengths it
                   through the *destination* codec's encode stage, and
                   emits THREE scalars — the tile's total output length,
                   a fused validation flag, and the tile's first-error
                   offset.  Validation is *folded into this scan*
                   (DESIGN.md §4): the source's maximal-subpart analysis
                   locates the first ill-formed sequence with Python
                   ``UnicodeDecodeError.start`` semantics, the
                   destination's encode-error map folds in unencodable
                   scalars (Latin-1 egress), and the source's extra
                   detector (the Keiser-Lemire nibble tables for UTF-8)
                   rides along VMEM-resident.  No standalone validation
                   pass re-reads the input.  HBM egress: 12 bytes per
                   1024-element tile.

  Inter-tile scan  An ``nblk``-element exclusive cumsum over the per-tile
                   totals (``compaction.tile_base_offsets``) yields each
                   tile's base offset in the compact output.  This is the
                   only global coordination: nblk scalars, not N lanes.

  Pass 2 (write)   Each grid step re-decodes its tile (decode is cheap;
                   bandwidth is not), compacts it *inside VMEM* with an
                   intra-tile exclusive scan plus an in-register scatter
                   — the hierarchical equivalent of AVX-512
                   ``vpcompressb`` compress-store — and stores the
                   compact tile at ``base[tile]``.

Per-tile staging widths are sized for the SPECULATIVE worst case, derived
per pair by ``stages.driver.stage_units`` (the destination's unit length
at the source's largest fabricable code point).  The derivation replaced
hand-sized per-pair constants and fixed a real overflow: the old
UTF-16→UTF-8 bound of ``3*BLOCK + 1`` undersized surrogate-flood garbage,
where EVERY lane folds to a supplementary pair code point and claims 4
candidate bytes (``4*BLOCK`` per tile).

Error semantics (the ``errors=`` policy, DESIGN.md §4) and the
interpreter/compiled execution notes are unchanged from the two-pair
pipeline; see the strategy table in DESIGN.md §5 and the codec matrix in
§8.  I/O dtypes are narrow end-to-end (uint8/uint16/uint32 by format);
lanes widen to int32 only inside VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import compaction
from repro.core import result as R
from repro.kernels import runtime
from repro.kernels import stages
from repro.kernels.stages import driver as sdrv
from repro.testing import faults

ROWS = sdrv.ROWS
LANES = sdrv.LANES
BLOCK = sdrv.BLOCK

# Back-compat stage-width constants (now derived, not hand-sized): the
# worst-case UTF-16 units out of one UTF-8 tile and UTF-8 bytes out of
# one UTF-16 tile.
STAGE16 = stages.stage_width(stages.UTF8, stages.UTF16)   # 2 * BLOCK
STAGE8 = stages.stage_width(stages.UTF16, stages.UTF8)    # 4 * BLOCK

_IMAX = R.NO_ERR_SENTINEL


def _tile(x):
    """Pad flat narrow array to whole tiles + one zero boundary tile/side."""
    return runtime.tile_with_boundaries(x, ROWS, LANES, boundary_tiles=2)


def _gidx(shape):
    """Global stream index of every lane in the current tile."""
    i = pl.program_id(0)
    return i * BLOCK + jnp.arange(BLOCK, dtype=jnp.int32).reshape(shape)


_check_errors = R.check_errors_policy


# Shared BlockSpecs: one definition of the tile geometry / neighbour-tile
# offset convention for the count and write passes of every pair —
# desynchronizing them would compute base offsets on a different tiling
# than the writer stores with.
def _tile_spec(off):
    """Current/prev/next tile of the (nblk+2, ROWS, LANES) padded array."""
    return pl.BlockSpec((1, ROWS, LANES), lambda i, off=off: (i + off, 0, 0))


_SCALAR_SPEC = pl.BlockSpec((1,), lambda i: (0,))     # broadcast scalar
_PER_TILE_SPEC = pl.BlockSpec((1,), lambda i: (i,))   # per-tile scalar out


def _table_specs(src: stages.Codec):
    """Broadcast BlockSpecs for the source codec's validation tables."""
    return [pl.BlockSpec((len(t),), lambda i: (0,)) for t in src.tables]


# ---------------------------------------------------------------------------
# Generic kernels: ONE count body and ONE write body serve every
# (src, dst) cell of the codec matrix; the format pair is a static
# parameter resolved through the stages registry.  The per-tile bodies
# are free functions of VMEM-resident arrays so the ragged packed-batch
# kernels (``repro.kernels.ragged_transcode``) run EXACTLY the same scan
# with a per-document live mask — one definition of the transcode per
# pair, two launch geometries (single stream / packed batch).


def _count_kernel(*refs, src, dst, errors, validate):
    codec_s, codec_d = stages.get_codec(src), stages.get_codec(dst)
    nt = len(codec_s.tables)
    table_refs = refs[:nt]
    n_ref, xp_ref, x_ref, xn_ref, tot_ref, err_ref, ferr_ref = refs[nt:]
    x = x_ref[...].astype(jnp.int32)
    xp = xp_ref[...].astype(jnp.int32)
    xn = xn_ref[...].astype(jnp.int32)
    gidx = _gidx(x.shape)
    tot_ref[0], err_ref[0], ferr_ref[0] = sdrv.count_tile(
        codec_s, codec_d, x, xp, xn, gidx < n_ref[0], gidx,
        tuple(t[...] for t in table_refs), errors=errors, validate=validate)


def _write_kernel(n_ref, base_ref, xp_ref, x_ref, xn_ref, out_ref, *,
                  src, dst, errors):
    codec_s, codec_d = stages.get_codec(src), stages.get_codec(dst)
    width = stages.stage_width(codec_s, codec_d)
    x = x_ref[...].astype(jnp.int32)
    xp = xp_ref[...].astype(jnp.int32)
    xn = xn_ref[...].astype(jnp.int32)
    stage = sdrv.write_stage(codec_s, codec_d, x, xp, xn,
                             _gidx(x.shape) < n_ref[0], errors=errors)
    out_ref[pl.ds(base_ref[0], width)] = stage.astype(codec_d.dtype)


def _count_call(xm, n, src, dst, errors, validate, interpret):
    """One counting/validating scan over the tiled input.

    Returns (x3, nblk, totals, errs, ferrs): the padded tiles plus the
    per-tile output totals, fused error flags and first-error offsets.
    """
    codec_s = stages.get_codec(src)
    x3, nblk = _tile(xm)
    n1 = jnp.asarray(n, jnp.int32).reshape(1)
    kernel = functools.partial(_count_kernel, src=src, dst=dst,
                               errors=errors, validate=validate)
    per_tile = jax.ShapeDtypeStruct((nblk,), jnp.int32)
    totals, errs, ferrs = pl.pallas_call(
        kernel,
        grid=(nblk,),
        in_specs=_table_specs(codec_s) + [
            _SCALAR_SPEC, _tile_spec(0), _tile_spec(1), _tile_spec(2)],
        out_specs=[_PER_TILE_SPEC, _PER_TILE_SPEC, _PER_TILE_SPEC],
        out_shape=[per_tile, per_tile, per_tile],
        interpret=interpret,
    )(*[jnp.asarray(t) for t in codec_s.tables], n1, x3, x3, x3)
    return x3, nblk, totals, errs, ferrs


def _write_call(x3, nblk, base, n, src, dst, errors, interpret):
    codec_s, codec_d = stages.get_codec(src), stages.get_codec(dst)
    width = stages.stage_width(codec_s, codec_d)
    n1 = jnp.asarray(n, jnp.int32).reshape(1)
    return pl.pallas_call(
        functools.partial(_write_kernel, src=src, dst=dst, errors=errors),
        grid=(nblk,),
        in_specs=[_SCALAR_SPEC, _PER_TILE_SPEC,
                  _tile_spec(0), _tile_spec(1), _tile_spec(2)],
        # The whole compact buffer is one revisited block: each grid step
        # stores its tile at a data-dependent offset inside it.  Sized so
        # the window store at the largest possible base (the speculative
        # worst case per preceding tile) fits.
        out_specs=pl.BlockSpec((nblk * width,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((nblk * width,), codec_d.dtype),
        interpret=interpret,
    )(n1, base, x3, x3, x3)


def _status(errs, ferrs, validate):
    if not validate:
        return jnp.int32(R.STATUS_OK)
    first = jnp.min(ferrs, initial=_IMAX)
    return R.status_from_first(first, jnp.max(errs, initial=0) > 0)


# The single-document wrapper contract, defined ONCE and shared with the
# one-pass pipeline (repro.kernels.onepass_transcode) so the two Pallas
# strategies cannot drift on padding-mask, drop-at-capacity or
# whole-buffer-ASCII semantics (they are pinned bit-identical).


def _mask_padding(x, n, dtype, masked):
    """Zero the lanes at/past ``n`` when an explicit n_valid was given."""
    if not masked:
        return x
    idx = jnp.arange(x.shape[0])
    return jnp.where(idx < n, x, 0).astype(dtype)


def _ascii_copy_result(xm, n, cap, dst_dtype):
    """Paper Algorithm 3 whole-buffer fast path: ASCII values are
    numerically identical in every matrix format — a widening copy."""
    out = xm.astype(dst_dtype)
    if cap > xm.shape[0]:
        out = jnp.concatenate(
            [out, jnp.zeros((cap - xm.shape[0],), dst_dtype)])
    return R.TranscodeResult(out, jnp.asarray(n, jnp.int32),
                             jnp.int32(R.STATUS_OK))


def _clip_to_cap(outp, cap, total, dst_dtype):
    """Keep the first ``cap`` lanes (the cross-strategy drop-at-capacity
    rule) and clear the write-window slack past ``total``."""
    outp = outp[:cap]
    return jnp.where(jnp.arange(cap) < total, outp,
                     jnp.zeros((), dst_dtype))


@functools.partial(jax.jit, static_argnames=("src", "dst", "validate",
                                             "interpret", "ascii_fastpath",
                                             "masked", "errors"))
def _transcode_impl(x, n, src, dst, validate, interpret, ascii_fastpath,
                    masked, errors):
    codec_s, codec_d, factor = stages.get_pair(src, dst)
    cap = factor * x.shape[0]
    xm = _mask_padding(x, n, codec_s.dtype, masked)

    def general(xm):
        x3, nblk, totals, errs, ferrs = _count_call(
            xm, n, src, dst, errors, validate, interpret)
        base, total = compaction.tile_base_offsets(totals)
        outp = _write_call(x3, nblk, base, n, src, dst, errors, interpret)
        outp = _clip_to_cap(outp, cap, total, codec_d.dtype)
        return R.TranscodeResult(outp, total, _status(errs, ferrs, validate))

    def ascii(xm):
        return _ascii_copy_result(xm, n, cap, codec_d.dtype)

    if not ascii_fastpath:
        return general(xm)
    return jax.lax.cond(jnp.all(xm < 0x80), ascii, general, xm)


def transcode_fused(x, n_valid=None, *, src: str, dst: str,
                    validate: bool = True, errors: str = "strict",
                    interpret=None, ascii_fastpath: bool = True):
    """Fused two-pass transcode for any (src, dst) cell of the matrix.

    Returns ``TranscodeResult(buffer[dst dtype, capacity =
    cap_factor * len(x)], count, status)`` — under ``errors="strict"``,
    ``buffer[:count]`` and ``count`` are bit-identical to the
    block-parallel strategy and ``status`` carries the first invalid
    input offset (-1 = valid); under ``errors="replace"`` every maximal
    subpart of an ill-formed sequence becomes U+FFFD — and every
    Latin-1-unencodable code point becomes ``?`` — with CPython
    substitution semantics at full speed.  Validation is fused into the
    counting scan: the input is never read by a standalone pass.
    """
    _check_errors(errors)
    faults.fire(faults.KERNEL_FUSED)     # chaos-suite hook (no-op in prod)
    codec_s, _codec_d, _f = stages.get_pair(src, dst)
    x = jnp.asarray(x)
    if x.dtype != codec_s.dtype:
        x = x.astype(codec_s.dtype)
    n = x.shape[0] if n_valid is None else n_valid
    return _transcode_impl(
        x, jnp.asarray(n, jnp.int32), src, dst, validate,
        runtime.resolve_interpret(interpret), ascii_fastpath,
        n_valid is not None, errors)


@functools.partial(jax.jit, static_argnames=("src", "dst", "interpret",
                                             "masked"))
def _scan_impl(x, n, src, dst, interpret, masked):
    codec_s = stages.get_codec(src)
    idx = jnp.arange(x.shape[0])
    xm = jnp.where(idx < n, x, 0).astype(codec_s.dtype) if masked else x
    _x3, _nblk, totals, errs, ferrs = _count_call(
        xm, n, src, dst, "strict", True, interpret)
    return jnp.sum(totals), _status(errs, ferrs, True)


def scan_fused(x, n_valid=None, *, src: str, dst: str, interpret=None):
    """Single-scan validation + capacity query: ``(count, status)``.

    Runs ONLY the fused pipeline's counting pass — one read of the input
    yields the simdutf-style verdict: ``status`` is -1 for valid
    streams, else the input offset of the first invalid maximal subpart
    (Python ``UnicodeDecodeError.start``), and ``count`` is the number
    of destination units a transcode would produce.  This is the
    ingestion-boundary API (serve ingress): validation with error
    location at the cost of a capacity query.
    """
    faults.fire(faults.KERNEL_SCAN)      # chaos-suite hook (no-op in prod)
    codec_s, _codec_d, _f = stages.get_pair(src, dst)
    x = jnp.asarray(x)
    if x.dtype != codec_s.dtype:
        x = x.astype(codec_s.dtype)
    n = x.shape[0] if n_valid is None else n_valid
    return _scan_impl(x, jnp.asarray(n, jnp.int32), src, dst,
                      runtime.resolve_interpret(interpret),
                      n_valid is not None)


# ---------------------------------------------------------------------------
# Thin per-pair instantiations (the pre-matrix public API, and the tile
# bodies the ragged kernels compose with a per-document live mask).


def count8_tile(b, bp, bn, live, gidx, t1h, t1l, t2h, *, errors, validate):
    """UTF-8→UTF-16 cell of the generic count driver (back-compat)."""
    return sdrv.count_tile(stages.UTF8, stages.UTF16, b, bp, bn, live, gidx,
                           (t1h, t1l, t2h), errors=errors, validate=validate)


def write8_stage(b, bp, bn, instream, *, errors):
    """UTF-8→UTF-16 cell of the generic write driver (back-compat)."""
    return sdrv.write_stage(stages.UTF8, stages.UTF16, b, bp, bn, instream,
                            errors=errors)


def count16_tile(u, up, un, live, gidx, *, errors, validate):
    """UTF-16→UTF-8 cell of the generic count driver (back-compat)."""
    return sdrv.count_tile(stages.UTF16, stages.UTF8, u, up, un, live, gidx,
                           (), errors=errors, validate=validate)


def write16_stage(u, up, un, instream, *, errors):
    """UTF-16→UTF-8 cell of the generic write driver (back-compat)."""
    return sdrv.write_stage(stages.UTF16, stages.UTF8, u, up, un, instream,
                            errors=errors)


def utf8_to_utf16_fused(b, n_valid=None, *, validate: bool = True,
                        errors: str = "strict", interpret=None,
                        ascii_fastpath: bool = True):
    """Fused UTF-8 -> UTF-16 (the (utf8, utf16) matrix cell)."""
    return transcode_fused(b, n_valid, src="utf8", dst="utf16",
                           validate=validate, errors=errors,
                           interpret=interpret,
                           ascii_fastpath=ascii_fastpath)


def utf16_to_utf8_fused(u, n_valid=None, *, validate: bool = True,
                        errors: str = "strict", interpret=None,
                        ascii_fastpath: bool = True):
    """Fused UTF-16 -> UTF-8 (the (utf16, utf8) matrix cell)."""
    return transcode_fused(u, n_valid, src="utf16", dst="utf8",
                           validate=validate, errors=errors,
                           interpret=interpret,
                           ascii_fastpath=ascii_fastpath)


def utf8_scan_fused(b, n_valid=None, *, interpret=None):
    """Single-scan UTF-8 validation + UTF-16 length: (count, status)."""
    return scan_fused(b, n_valid, src="utf8", dst="utf16",
                      interpret=interpret)


def utf16_scan_fused(u, n_valid=None, *, interpret=None):
    """Single-scan UTF-16 validation + UTF-8 length: (count, status)."""
    return scan_fused(u, n_valid, src="utf16", dst="utf8",
                      interpret=interpret)
