"""Fused two-pass Pallas transcode pipeline (strategy ``"fused"``).

This is the hierarchical, in-kernel answer to the global cumsum+scatter
compaction of ``repro.core.transcode`` (DESIGN.md §5).  The block-parallel
strategy round-trips three full-capacity int32 candidate arrays
(cp / lead / units, 12 bytes per input byte) through HBM before XLA
compacts them — the TPU analogue of writing every speculative lane to
memory and shuffling afterwards.  Here nothing full-capacity and nothing
int32 ever leaves the kernels:

  Pass 1 (count)   Each grid step speculatively decodes its VMEM tile
                   (re-using :func:`repro.kernels.utf8_decode.decode_tile`
                   / :func:`repro.kernels.utf16_encode.encode_tile`) and
                   emits ONE scalar — the tile's total output length —
                   plus a fused validation flag.  HBM egress: 8 bytes per
                   1024-element tile.

  Inter-tile scan  An ``nblk``-element exclusive cumsum over the per-tile
                   totals (``compaction.tile_base_offsets``) yields each
                   tile's base offset in the compact output.  This is the
                   only global coordination: nblk scalars, not N lanes.

  Pass 2 (write)   Each grid step re-decodes its tile (decode is cheap;
                   bandwidth is not), compacts it *inside VMEM* with an
                   intra-tile exclusive scan (``tile_exclusive_scan``) and
                   an in-register scatter — the hierarchical equivalent of
                   AVX-512 ``vpcompressb`` compress-store — and stores the
                   compact tile at ``base[tile]``.  Output lane j of the
                   final buffer is written exactly once, at
                   ``base[tile] + local_rank``.

The writer stores a full tile-width window at ``base[tile]``; the slack
beyond the tile's total is overwritten by the next tile's window (grid
steps execute in order), and the slack after the *last* tile is cleared
by the wrapper.  I/O dtypes are narrow end-to-end: UTF-8 bytes travel as
``uint8`` and UTF-16 units as ``uint16``; lanes widen to int32 only
inside VMEM.  Ingress HBM traffic drops 4x vs the int32 paths.

Interpreter-mode notes: the in-tile compaction is expressed as a jnp
scatter on VMEM-resident values and the writer output block is the whole
staging buffer revisited every grid step with a dynamic-offset store.
Both passes are plain ``pl.pallas_call``s and run under
``interpret=True`` on CPU (auto-detected, see ``repro.kernels.runtime``).
Compiled-TPU caveat: the whole-buffer output block implies full-buffer
VMEM residency, which bounds a single call to roughly VMEM-sized inputs
(~4 MB); larger documents must be chunked at that granularity, or the
writer re-expressed with a per-tile output block at a scalar-prefetched
base offset (PrefetchScalarGridSpec) plus the on-chip shuffle form of
the in-tile scatter — the planned shape for real-TPU deployment.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import compaction
from repro.core import utf16 as u16mod
from repro.kernels import runtime
from repro.kernels import utf8_decode as kdec
from repro.kernels import utf16_encode as kenc

ROWS = 8
LANES = 128
BLOCK = ROWS * LANES
# Per-tile staging widths are sized for the SPECULATIVE worst case, not the
# valid-input worst case: on garbage input every byte of a tile can decode
# as a 4-byte lead with a supplementary code point (2 units), so a UTF-8
# tile can claim up to 2*BLOCK units.  A UTF-16 tile tops out at
# 3*BLOCK + 1 bytes: a 4-byte lane is normally followed in-tile by its
# 0-byte trailing-surrogate lane, EXCEPT in the last lane, whose pairing
# low surrogate lives in the next tile (1023 three-byte lanes + one
# 4-byte lane).  Undersizing these desynchronizes base offsets from
# blockparallel's global cumsum and overflows the windowed store.
STAGE16 = 2 * BLOCK      # max UTF-16 units out of one 1024-byte UTF-8 tile
STAGE8 = 3 * BLOCK + 1   # max UTF-8 bytes out of one 1024-unit UTF-16 tile


def _tile(x):
    """Pad flat narrow array to whole tiles + one zero boundary tile/side."""
    return runtime.tile_with_boundaries(x, ROWS, LANES, boundary_tiles=2)


def _gidx(shape):
    """Global stream index of every lane in the current tile."""
    i = pl.program_id(0)
    return i * BLOCK + jnp.arange(BLOCK, dtype=jnp.int32).reshape(shape)


# ---------------------------------------------------------------------------
# UTF-8 -> UTF-16


def _count8_kernel(n_ref, bp_ref, b_ref, bn_ref, tot_ref, err_ref):
    b = b_ref[...].astype(jnp.int32)
    bp = bp_ref[...].astype(jnp.int32)
    bn = bn_ref[...].astype(jnp.int32)
    _cp, is_lead, units, err_map = kdec.decode_tile(b, bp, bn)
    live = is_lead & (_gidx(b.shape) < n_ref[0])
    tot_ref[0] = jnp.sum(jnp.where(live, units, 0))
    err_ref[0] = jnp.max(err_map.astype(jnp.int32))


def _write8_kernel(n_ref, base_ref, bp_ref, b_ref, bn_ref, out_ref):
    b = b_ref[...].astype(jnp.int32)
    bp = bp_ref[...].astype(jnp.int32)
    bn = bn_ref[...].astype(jnp.int32)
    cp, is_lead, units, _err = kdec.decode_tile(b, bp, bn)
    live = (is_lead & (_gidx(b.shape) < n_ref[0])).reshape(-1)
    eff = jnp.where(live, units.reshape(-1), 0)
    rank, _tot = compaction.tile_exclusive_scan(eff, rows=ROWS)
    _u, u0, u1, _bad = u16mod.encode_candidates(cp)
    # In-register compress-store (vpcompressb analogue): scatter the 1-2
    # code units of each live lane to base-relative rank inside VMEM.
    stage = jnp.zeros((STAGE16,), jnp.int32)
    stage = stage.at[jnp.where(live, rank, STAGE16)].set(
        u0.reshape(-1), mode="drop")
    stage = stage.at[jnp.where(live & (eff == 2), rank + 1, STAGE16)].set(
        u1.reshape(-1), mode="drop")
    out_ref[pl.ds(base_ref[0], STAGE16)] = stage.astype(jnp.uint16)


@functools.partial(jax.jit, static_argnames=("validate", "interpret",
                                             "ascii_fastpath", "masked"))
def _utf8_to_utf16_impl(b, n, validate, interpret, ascii_fastpath, masked):
    cap = b.shape[0]
    idx = jnp.arange(cap)
    bm = jnp.where(idx < n, b, 0).astype(jnp.uint8) if masked else b

    def general(bm):
        b3, nblk = _tile(bm)
        n1 = jnp.asarray(n, jnp.int32).reshape(1)
        spec = lambda off: pl.BlockSpec(
            (1, ROWS, LANES), lambda i, off=off: (i + off, 0, 0))
        scalar = pl.BlockSpec((1,), lambda i: (0,))
        per_tile = pl.BlockSpec((1,), lambda i: (i,))
        totals, errs = pl.pallas_call(
            _count8_kernel,
            grid=(nblk,),
            in_specs=[scalar, spec(0), spec(1), spec(2)],
            out_specs=[per_tile, per_tile],
            out_shape=[jax.ShapeDtypeStruct((nblk,), jnp.int32),
                       jax.ShapeDtypeStruct((nblk,), jnp.int32)],
            interpret=interpret,
        )(n1, b3, b3, b3)
        base, total = compaction.tile_base_offsets(totals)
        outp = pl.pallas_call(
            _write8_kernel,
            grid=(nblk,),
            in_specs=[scalar, per_tile, spec(0), spec(1), spec(2)],
            # The whole compact buffer is one revisited block: each grid
            # step stores its tile at a data-dependent offset inside it.
            # Sized so the window store at the largest possible base
            # (STAGE16 per preceding tile, speculative worst case) fits.
            out_specs=pl.BlockSpec((nblk * STAGE16,), lambda i: (0,)),
            out_shape=jax.ShapeDtypeStruct((nblk * STAGE16,), jnp.uint16),
            interpret=interpret,
        )(n1, base, b3, b3, b3)
        # Keep the first `cap` lanes (matching blockparallel's drop-at-
        # capacity) and clear the write-window slack after the last tile.
        outp = outp[:cap]
        outp = jnp.where(jnp.arange(cap) < total, outp, 0)
        err = ((jnp.max(errs) > 0) | kdec.tail_lead_err(bm, n)) if validate \
            else jnp.bool_(False)
        return outp, total, err

    def ascii(bm):
        # Paper Algorithm 3 fast path: widening copy (uint8 -> uint16).
        return bm.astype(jnp.uint16), jnp.asarray(n, jnp.int32), \
            jnp.bool_(False)

    if not ascii_fastpath:
        return general(bm)
    return jax.lax.cond(jnp.all(bm < 0x80), ascii, general, bm)


def utf8_to_utf16_fused(b, n_valid=None, *, validate: bool = True,
                        interpret=None, ascii_fastpath: bool = True):
    """Fused two-pass UTF-8 -> UTF-16 transcode.

    Returns ``(u16_buffer[uint16, capacity=len(b)], count, err)`` —
    bit-identical in ``buffer[:count]``/``count``/``err`` to the
    block-parallel strategy, with narrow I/O and no full-capacity int32
    intermediates.
    """
    b = jnp.asarray(b)
    if b.dtype != jnp.uint8:
        b = b.astype(jnp.uint8)
    n = b.shape[0] if n_valid is None else n_valid
    return _utf8_to_utf16_impl(
        b, jnp.asarray(n, jnp.int32), validate,
        runtime.resolve_interpret(interpret), ascii_fastpath,
        n_valid is not None)


# ---------------------------------------------------------------------------
# UTF-16 -> UTF-8


def _count16_kernel(n_ref, up_ref, u_ref, un_ref, tot_ref, err_ref):
    u = u_ref[...].astype(jnp.int32)
    up = up_ref[...].astype(jnp.int32)
    un = un_ref[...].astype(jnp.int32)
    _b0, _b1, _b2, _b3, L, err_map = kenc.encode_tile(u, up, un)
    live = (L > 0) & (_gidx(u.shape) < n_ref[0])
    tot_ref[0] = jnp.sum(jnp.where(live, L, 0))
    err_ref[0] = jnp.max(err_map.astype(jnp.int32))


def _write16_kernel(n_ref, base_ref, up_ref, u_ref, un_ref, out_ref):
    u = u_ref[...].astype(jnp.int32)
    up = up_ref[...].astype(jnp.int32)
    un = un_ref[...].astype(jnp.int32)
    b0, b1, b2, b3, L, _err = kenc.encode_tile(u, up, un)
    live = ((L > 0) & (_gidx(u.shape) < n_ref[0])).reshape(-1)
    eff = jnp.where(live, L.reshape(-1), 0)
    rank, _tot = compaction.tile_exclusive_scan(eff, rows=ROWS)
    # Variable 1-4 byte egress: ``compact_offsets`` semantics, in-tile.
    stage = jnp.zeros((STAGE8,), jnp.int32)
    stage = stage.at[jnp.where(live, rank, STAGE8)].set(
        b0.reshape(-1), mode="drop")
    stage = stage.at[jnp.where(live & (eff >= 2), rank + 1, STAGE8)].set(
        b1.reshape(-1), mode="drop")
    stage = stage.at[jnp.where(live & (eff >= 3), rank + 2, STAGE8)].set(
        b2.reshape(-1), mode="drop")
    stage = stage.at[jnp.where(live & (eff == 4), rank + 3, STAGE8)].set(
        b3.reshape(-1), mode="drop")
    out_ref[pl.ds(base_ref[0], STAGE8)] = stage.astype(jnp.uint8)


@functools.partial(jax.jit, static_argnames=("validate", "interpret",
                                             "ascii_fastpath", "masked"))
def _utf16_to_utf8_impl(u, n, validate, interpret, ascii_fastpath, masked):
    cap_in = u.shape[0]
    cap = 3 * cap_in
    idx = jnp.arange(cap_in)
    um = jnp.where(idx < n, u, 0).astype(jnp.uint16) if masked else u

    def general(um):
        u3, nblk = _tile(um)
        n1 = jnp.asarray(n, jnp.int32).reshape(1)
        spec = lambda off: pl.BlockSpec(
            (1, ROWS, LANES), lambda i, off=off: (i + off, 0, 0))
        scalar = pl.BlockSpec((1,), lambda i: (0,))
        per_tile = pl.BlockSpec((1,), lambda i: (i,))
        totals, errs = pl.pallas_call(
            _count16_kernel,
            grid=(nblk,),
            in_specs=[scalar, spec(0), spec(1), spec(2)],
            out_specs=[per_tile, per_tile],
            out_shape=[jax.ShapeDtypeStruct((nblk,), jnp.int32),
                       jax.ShapeDtypeStruct((nblk,), jnp.int32)],
            interpret=interpret,
        )(n1, u3, u3, u3)
        base, total = compaction.tile_base_offsets(totals)
        outp = pl.pallas_call(
            _write16_kernel,
            grid=(nblk,),
            in_specs=[scalar, per_tile, spec(0), spec(1), spec(2)],
            out_specs=pl.BlockSpec((nblk * STAGE8,), lambda i: (0,)),
            out_shape=jax.ShapeDtypeStruct((nblk * STAGE8,), jnp.uint8),
            interpret=interpret,
        )(n1, base, u3, u3, u3)
        outp = outp[:cap]
        outp = jnp.where(jnp.arange(cap) < total, outp, 0)
        err = (jnp.max(errs) > 0) if validate else jnp.bool_(False)
        return outp, total, err

    def ascii(um):
        out = jnp.concatenate(
            [um.astype(jnp.uint8), jnp.zeros((cap - cap_in,), jnp.uint8)])
        return out, jnp.asarray(n, jnp.int32), jnp.bool_(False)

    if not ascii_fastpath:
        return general(um)
    return jax.lax.cond(jnp.all(um < 0x80), ascii, general, um)


def utf16_to_utf8_fused(u, n_valid=None, *, validate: bool = True,
                        interpret=None, ascii_fastpath: bool = True):
    """Fused two-pass UTF-16 -> UTF-8 transcode.

    Returns ``(byte_buffer[uint8, capacity=3*len(u)], count, err)`` —
    bit-identical in ``buffer[:count]``/``count``/``err`` to the
    block-parallel strategy, with narrow I/O and no full-capacity int32
    intermediates.
    """
    u = jnp.asarray(u)
    if u.dtype != jnp.uint16:
        u = u.astype(jnp.uint16)
    n = u.shape[0] if n_valid is None else n_valid
    return _utf16_to_utf8_impl(
        u, jnp.asarray(n, jnp.int32), validate,
        runtime.resolve_interpret(interpret), ascii_fastpath,
        n_valid is not None)
