"""Pallas TPU kernel: speculative block-parallel UTF-8 decode.

The compute core of the framework's beyond-paper strategy (DESIGN.md §3):
every byte position in a BLOCK-byte VMEM tile is decoded as if it led a
character — the (up to) three following bytes are folded in with the
branch-free bit surgery of paper Figs. 2-4 — and per-position masks select
the real characters.  Cross-tile context (3 bytes on each side) comes from
also mapping the previous and next tiles into VMEM; the array is padded
with a zero tile at each end.

Outputs per position: candidate code point, is-lead flag, and the number
of UTF-16 code units the character needs (0 for non-leads) — everything
global stream compaction (an XLA cumsum+scatter over the whole buffer)
needs to finish the transcode.  A per-tile structural-error flag fuses the
decoder's own validation.

The per-tile decode body lives in :func:`decode_tile` so that the fused
two-pass pipeline (``repro.kernels.fused_transcode``, DESIGN.md §5) can
re-run exactly the same speculative decode inside its counting and writer
kernels without materializing these full-capacity outputs in HBM.

This kernel deliberately contains no loop and no branch: it is pure VPU
arithmetic on (8, 128) tiles, the TPU-native answer to the paper's point
that transcoding should be straight-line SIMD work.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import utf8 as u8mod
from repro.kernels import runtime

ROWS = 8
LANES = 128
BLOCK = ROWS * LANES


def _shift_left_flat(cur, nxt, n):
    """cur[i+n] with bytes flowing in from the next tile."""
    c = cur.reshape(-1)
    x = nxt.reshape(-1)
    return jnp.concatenate([c[n:], x[:n]]).reshape(cur.shape)


def _shift_right_flat(cur, prev, n):
    c = cur.reshape(-1)
    p = prev.reshape(-1)
    return jnp.concatenate([p[-n:], c[:-n]]).reshape(cur.shape)


def _seq_len(b):
    """Sequence length from the lead byte, as a where-tree.

    The paper uses a 32-entry L1 table keyed by ``b >> 3``; on the TPU VPU a
    four-node compare/select tree is cheaper than a gather, so the table is
    *computed* (DESIGN.md §3: the paper's own compute-vs-lookup observation,
    with the tradeoff flipped).
    """
    return jnp.where(
        b < 0x80, 1,
        jnp.where(b < 0xC0, 0,
        jnp.where(b < 0xE0, 2,
        jnp.where(b < 0xF0, 3,
        jnp.where(b < 0xF8, 4, 0)))))


def decode_tile(b, bp, bn):
    """Speculatively decode one tile given its two neighbour tiles.

    All three arguments are int32 arrays of identical (arbitrary) shape;
    the shift helpers treat them as row-major flat byte streams.  Returns
    ``(cp, is_lead, units, err_map)`` of the same shape: candidate code
    point, lead-position flag (bool), UTF-16 code units emitted by the
    character (0 at non-leads), and a per-position structural/range error
    map (bool).  Shared between :func:`utf8_decode_kernel` and the fused
    pipeline's kernels.
    """
    b1 = _shift_left_flat(b, bn, 1)
    b2 = _shift_left_flat(b, bn, 2)
    b3 = _shift_left_flat(b, bn, 3)

    seq_len = _seq_len(b)
    is_cont = (b & 0xC0) == 0x80
    is_lead = seq_len > 0

    # Branch-free bit surgery (paper Figs. 2-4).
    cp1 = b
    cp2 = ((b & 0x1F) << 6) | (b1 & 0x3F)
    cp3 = ((b & 0x0F) << 12) | ((b1 & 0x3F) << 6) | (b2 & 0x3F)
    cp4 = (
        ((b & 0x07) << 18)
        | ((b1 & 0x3F) << 12)
        | ((b2 & 0x3F) << 6)
        | (b3 & 0x3F)
    )
    cp = jnp.where(
        seq_len == 1,
        cp1,
        jnp.where(seq_len == 2, cp2, jnp.where(seq_len == 3, cp3, cp4)),
    )
    cp = jnp.where(is_lead, cp, 0)

    # Structural self-validation: expected-continuation bookkeeping.
    seq_len_prev = _seq_len(bp)
    sl_p1 = _shift_right_flat(seq_len, seq_len_prev, 1)
    sl_p2 = _shift_right_flat(seq_len, seq_len_prev, 2)
    sl_p3 = _shift_right_flat(seq_len, seq_len_prev, 3)
    exp_cont = (sl_p1 >= 2) | (sl_p2 >= 3) | (sl_p3 >= 4)
    struct_err = (exp_cont != is_cont) | (b >= 0xF8)

    # Scalar-range validation (overlong / surrogate / too-large).
    # MIN_CP_FOR_LEN as a select tree (same compute-over-lookup adaptation).
    min_cp = jnp.where(seq_len == 2, 0x80,
             jnp.where(seq_len == 3, 0x800,
             jnp.where(seq_len == 4, 0x10000, 0)))
    range_err = is_lead & (
        (cp < min_cp) | ((cp >= 0xD800) & (cp < 0xE000)) | (cp > 0x10FFFF)
    )

    units = jnp.where(is_lead, 1 + (cp >= 0x10000).astype(jnp.int32), 0)
    return cp, is_lead, units, struct_err | range_err


def analyze_tile(b, bp, bn):
    """Maximal-subpart analysis of one tile given its neighbour tiles.

    Same shift convention as :func:`decode_tile`; the body is the shared
    :func:`repro.core.utf8.analyze_subparts`, so the fused pipeline's
    error location and errors="replace" semantics are bit-identical to
    the pure-jnp block-parallel reference.  Returns the analysis dict
    (``starts`` / ``valid`` / ``cp`` / ``units`` / ``err``).
    """
    return u8mod.analyze_subparts(
        b,
        _shift_left_flat(b, bn, 1),
        _shift_left_flat(b, bn, 2),
        _shift_left_flat(b, bn, 3),
        _shift_right_flat(b, bp, 1),
        _shift_right_flat(b, bp, 2),
        _shift_right_flat(b, bp, 3),
    )


def tail_lead_err(b, n):
    """Scalar bool: a multi-byte lead is truncated by the logical stream
    end.  The kernels cannot see this when ``n`` is tile-aligned (the
    missing continuation falls in the zero boundary tile the grid never
    scans as "cur"), so every wrapper checks it outside; harmless
    double-flagging otherwise.
    """
    idx = jnp.arange(b.shape[0])
    b = b.astype(jnp.int32)
    tail = (
        ((b >= 0xC0) & (idx >= n - 1))
        | ((b >= 0xE0) & (idx >= n - 2))
        | ((b >= 0xF0) & (idx >= n - 3))
    ) & (idx < n)
    return jnp.any(tail)


def utf8_decode_kernel(b_prev_ref, b_cur_ref, b_next_ref,
                       cp_ref, lead_ref, units_ref, err_ref):
    b = b_cur_ref[...].astype(jnp.int32)
    bp = b_prev_ref[...].astype(jnp.int32)
    bn = b_next_ref[...].astype(jnp.int32)

    cp, is_lead, units, err_map = decode_tile(b, bp, bn)

    cp_ref[...] = cp
    lead_ref[...] = is_lead.astype(jnp.int32)
    units_ref[...] = units
    err_ref[0] = jnp.max(err_map.astype(jnp.int32))


@functools.partial(jax.jit, static_argnames=("interpret",))
def _call_jit(b2d, interpret):
    """b2d: int32 (nblk+2, ROWS, LANES) — zero tile at each end."""
    nblk = b2d.shape[0] - 2
    spec = lambda off: pl.BlockSpec(
        (1, ROWS, LANES), lambda i, off=off: (i + off, 0, 0))
    out2d = lambda: pl.BlockSpec((1, ROWS, LANES), lambda i: (i, 0, 0))
    cp, lead, units, err = pl.pallas_call(
        utf8_decode_kernel,
        grid=(nblk,),
        in_specs=[spec(0), spec(1), spec(2)],
        out_specs=[out2d(), out2d(), out2d(),
                   pl.BlockSpec((1,), lambda i: (i,))],
        out_shape=[
            jax.ShapeDtypeStruct((nblk, ROWS, LANES), jnp.int32),
            jax.ShapeDtypeStruct((nblk, ROWS, LANES), jnp.int32),
            jax.ShapeDtypeStruct((nblk, ROWS, LANES), jnp.int32),
            jax.ShapeDtypeStruct((nblk,), jnp.int32),
        ],
        interpret=interpret,
    )(b2d, b2d, b2d)
    return cp, lead, units, err


def _call(b2d, interpret=None):
    return _call_jit(b2d, runtime.resolve_interpret(interpret))
