"""Pallas TPU kernel: speculative block-parallel UTF-8 decode.

The compute core of the framework's beyond-paper strategy (DESIGN.md §3):
every byte position in a BLOCK-byte VMEM tile is decoded as if it led a
character — the (up to) three following bytes are folded in with the
branch-free bit surgery of paper Figs. 2-4 — and per-position masks select
the real characters.  Cross-tile context (3 bytes on each side) comes from
also mapping the previous and next tiles into VMEM; the array is padded
with a zero tile at each end.

Since the codec-matrix refactor the per-tile bodies (``decode_tile``,
``analyze_tile``) live in :mod:`repro.kernels.stages.utf8` — the UTF-8
decode stage of the generic decode×encode driver — and are re-exported
here for the legacy per-position kernel below and for older import
sites.  This module keeps only what the stages package does not cover:
the standalone full-output kernel (per-position cp/lead/units arrays
through HBM, the pre-fusion contrast path of ``repro.kernels.ops``) and
the ``tail_lead_err`` wrapper check.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import runtime
from repro.kernels.stages.utf8 import (  # noqa: F401  (re-export shims)
    _seq_len, analyze_tile, decode_tile)
from repro.kernels.stages.common import (  # noqa: F401  (re-export shims)
    shift_left_flat as _shift_left_flat,
    shift_right_flat as _shift_right_flat)

ROWS = 8
LANES = 128
BLOCK = ROWS * LANES


def tail_lead_err(b, n):
    """Scalar bool: a multi-byte lead is truncated by the logical stream
    end.  The kernels cannot see this when ``n`` is tile-aligned (the
    missing continuation falls in the zero boundary tile the grid never
    scans as "cur"), so every wrapper checks it outside; harmless
    double-flagging otherwise.
    """
    idx = jnp.arange(b.shape[0])
    b = b.astype(jnp.int32)
    tail = (
        ((b >= 0xC0) & (idx >= n - 1))
        | ((b >= 0xE0) & (idx >= n - 2))
        | ((b >= 0xF0) & (idx >= n - 3))
    ) & (idx < n)
    return jnp.any(tail)


def utf8_decode_kernel(b_prev_ref, b_cur_ref, b_next_ref,
                       cp_ref, lead_ref, units_ref, err_ref):
    b = b_cur_ref[...].astype(jnp.int32)
    bp = b_prev_ref[...].astype(jnp.int32)
    bn = b_next_ref[...].astype(jnp.int32)

    cp, is_lead, units, err_map = decode_tile(b, bp, bn)

    cp_ref[...] = cp
    lead_ref[...] = is_lead.astype(jnp.int32)
    units_ref[...] = units
    err_ref[0] = jnp.max(err_map.astype(jnp.int32))


@functools.partial(jax.jit, static_argnames=("interpret",))
def _call_jit(b2d, interpret):
    """b2d: int32 (nblk+2, ROWS, LANES) — zero tile at each end."""
    nblk = b2d.shape[0] - 2
    spec = lambda off: pl.BlockSpec(
        (1, ROWS, LANES), lambda i, off=off: (i + off, 0, 0))
    out2d = lambda: pl.BlockSpec((1, ROWS, LANES), lambda i: (i, 0, 0))
    cp, lead, units, err = pl.pallas_call(
        utf8_decode_kernel,
        grid=(nblk,),
        in_specs=[spec(0), spec(1), spec(2)],
        out_specs=[out2d(), out2d(), out2d(),
                   pl.BlockSpec((1,), lambda i: (i,))],
        out_shape=[
            jax.ShapeDtypeStruct((nblk, ROWS, LANES), jnp.int32),
            jax.ShapeDtypeStruct((nblk, ROWS, LANES), jnp.int32),
            jax.ShapeDtypeStruct((nblk, ROWS, LANES), jnp.int32),
            jax.ShapeDtypeStruct((nblk,), jnp.int32),
        ],
        interpret=interpret,
    )(b2d, b2d, b2d)
    return cp, lead, units, err


def _call(b2d, interpret=None):
    return _call_jit(b2d, runtime.resolve_interpret(interpret))
