"""Pallas TPU kernel: fused flash attention (online softmax, causal/SWA).

§Perf cell A identified the remaining memory-roofline term of the
optimized danube train cell as attention score/softmax HBM traffic: XLA
does not fuse matmul→softmax→matmul, so the (Sq × chunk) score stripes
round-trip through HBM (arithmetic intensity ~d/4).  This kernel keeps
the score block strictly in VMEM: HBM traffic collapses to Q/K/V/O.

Design (TPU-native, not a CUDA port):
  * grid = (batch*heads, Sq/BQ); each step owns a (BQ, D) query tile in
    VMEM and loops over (BK, D) key/value tiles with ``jax.lax.fori_loop``
    INSIDE the kernel, carrying the online-softmax (m, l, acc) state in
    VREGs/VMEM — the standard flash recurrence mapped to MXU matmuls.
  * BQ/BK default to 128 so both matmul dims are MXU-aligned (128x128
    systolic array); D is the head dim (128 for all assigned archs).
  * causal + sliding-window masks are applied with lane-parallel
    ``jnp.where`` on the in-VMEM score block (no branch, @pl.when skips
    fully-masked KV tiles for the causal upper triangle).
  * GQA is handled by the wrapper: q heads are grouped so the kernel
    always sees matched (q, k, v) head streams.

Validated in interpret mode against the pure-jnp oracle
(``repro.models.common.chunked_attention``) over shape/window sweeps —
tests/test_flash_attention.py.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BQ = 128
BK = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, bq, bk, seq_k, window,
                  scale):
    qi = pl.program_id(1)
    q = q_ref[0, 0].astype(jnp.float32) * scale       # (BQ, D)
    q_pos = qi * bq + jax.lax.iota(jnp.int32, bq)     # (BQ,)

    nk = seq_k // bk

    def body(j, carry):
        m, l, acc = carry
        k = k_ref[0, j].astype(jnp.float32)           # (BK, D)
        v = v_ref[0, j].astype(jnp.float32)
        k_pos = j * bk + jax.lax.iota(jnp.int32, bk)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (BQ, BK)
        mask = k_pos[None, :] <= q_pos[:, None]
        if window is not None:
            mask &= (q_pos[:, None] - k_pos[None, :]) < window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, -1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, -1)
        acc_new = acc * corr[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    a0 = jnp.zeros((bq, q.shape[-1]), jnp.float32)
    # causal: KV tiles beyond this query tile contribute nothing
    hi = jnp.minimum(nk, (qi + 1) * bq // bk + (1 if bq % bk else 0))
    # sliding window: tiles entirely below the window are dead too
    if window is not None:
        lo = jnp.maximum(0, (qi * bq - window) // bk)
    else:
        lo = 0
    m, l, acc = jax.lax.fori_loop(lo, hi, body, (m0, l0, a0))
    o_ref[0, 0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("window", "bq", "bk", "interpret"))
def flash_attention(q, k, v, window=None, bq=BQ, bk=BK, interpret=True):
    """q: (B, Sq, H, D); k/v: (B, Sk, H, D) (same head count — GQA groups
    are expanded by the caller).  Causal; optional sliding window.
    Returns (B, Sq, H, D)."""
    b, sq, h, d = q.shape
    sk = k.shape[1]
    assert sq % bq == 0 and sk % bk == 0, (sq, sk)
    scale = 1.0 / math.sqrt(d)

    # (B*H, Sq/BQ, BQ, D) query tiles; KV as (B*H, Sk/BK, BK, D)
    qt = q.transpose(0, 2, 1, 3).reshape(b * h, sq // bq, bq, d)
    kt = k.transpose(0, 2, 1, 3).reshape(b * h, sk // bk, bk, d)
    vt = v.transpose(0, 2, 1, 3).reshape(b * h, sk // bk, bk, d)

    kernel = functools.partial(_flash_kernel, bq=bq, bk=bk, seq_k=sk,
                               window=window, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=(b * h, sq // bq),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, sk // bk, bk, d), lambda i, j: (i, 0, 0, 0)),
            pl.BlockSpec((1, sk // bk, bk, d), lambda i, j: (i, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq // bq, bq, d), q.dtype),
        interpret=interpret,
    )(qt, kt, vt)
    return out.reshape(b, h, sq, d).transpose(0, 2, 1, 3)
