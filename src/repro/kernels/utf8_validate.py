"""Pallas TPU kernel: Keiser-Lemire UTF-8 validation (paper §4, [3]).

One grid step validates one BLOCK-byte tile resident in VMEM.  The paper's
three nibble-table lookups AND together per byte pair; the only cross-tile
state is the previous 3 bytes, which we obtain by also mapping the
*previous* block into VMEM (the array is padded with one leading zero
block, so block 0 sees an all-ASCII predecessor — zeros can never create
an error).

TPU notes:
  * all arithmetic is int32 (VPU lane width);
  * the 16-entry nibble tables are embedded constants — the TPU analogue of
    the paper's L1-resident tables (they fit in VREGs after constant
    propagation);
  * tiles are (ROWS, 128) so the last dimension matches the VPU lane count
    and ROWS=8 matches the sublane count;
  * the per-tile result is a single int32 error flag, reduced by the
    wrapper.  No cross-tile sequential dependence -> trivially parallel
    grid, unlike the CPU algorithm's running "prev" registers.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import tables as T
from repro.kernels import runtime

ROWS = 8
LANES = 128
BLOCK = ROWS * LANES  # 1024 bytes per grid step


def _shift_right_flat(cur, prev, n):
    """cur[i-n] with bytes flowing in from the previous tile."""
    flat_cur = cur.reshape(-1)
    flat_prev = prev.reshape(-1)
    return jnp.concatenate([flat_prev[-n:], flat_cur[:-n]]).reshape(cur.shape)


def kl_error_tile(b, bp, byte_1_high, byte_1_low, byte_2_high):
    """Keiser-Lemire nibble-table error map for one VMEM tile.

    ``b``/``bp`` are the current and previous tiles (int32, identical
    shape); the three 16-entry nibble tables arrive as VMEM-resident
    values (Pallas kernels cannot capture traced constants — callers map
    ``repro.core.tables.BYTE_*`` in with a broadcast BlockSpec, exactly
    like :func:`utf8_validate_kernel` below).  Returns a bool error map:
    positions where the three ANDed nibble lookups disagree with the
    expected-continuation bit (paper §4).  Errors surface at the *second
    byte* of each bad pair — use
    :func:`repro.core.utf8.analyze_subparts` when the lead-relative
    (Python ``exc.start``) position is needed.

    This is the body the fused pipeline's count pass folds in
    (``repro.kernels.fused_transcode``): since PR 2 the standalone
    validation kernel below is no longer on the ``strategy="fused"`` hot
    path — validation rides along with the counting scan, so the input
    bytes are read exactly once more than the write pass needs.
    """
    prev1 = _shift_right_flat(b, bp, 1)
    prev2 = _shift_right_flat(b, bp, 2)
    prev3 = _shift_right_flat(b, bp, 3)
    sc = (
        jnp.take(byte_1_high, prev1 >> 4)
        & jnp.take(byte_1_low, prev1 & 0xF)
        & jnp.take(byte_2_high, b >> 4)
    )
    is_third = prev2 >= 0xE0
    is_fourth = prev3 >= 0xF0
    must_be_cont = (is_third | is_fourth).astype(jnp.int32) * T.TWO_CONTS
    return (sc ^ must_be_cont) != 0


def utf8_validate_kernel(t1h_ref, t1l_ref, t2h_ref,
                         b_prev_ref, b_cur_ref, err_ref):
    b = b_cur_ref[...].astype(jnp.int32)
    bp = b_prev_ref[...].astype(jnp.int32)

    prev1 = _shift_right_flat(b, bp, 1)
    prev2 = _shift_right_flat(b, bp, 2)
    prev3 = _shift_right_flat(b, bp, 3)

    # The paper's three 16-entry nibble tables, passed as VMEM-resident
    # inputs (their whole point is that they are tiny enough for L1; on TPU
    # they live in VMEM next to the tile and are re-read every grid step).
    byte_1_high = t1h_ref[...]
    byte_1_low = t1l_ref[...]
    byte_2_high = t2h_ref[...]

    sc = (
        jnp.take(byte_1_high, prev1 >> 4)
        & jnp.take(byte_1_low, prev1 & 0xF)
        & jnp.take(byte_2_high, b >> 4)
    )
    is_third = prev2 >= 0xE0
    is_fourth = prev3 >= 0xF0
    must_be_cont = (is_third | is_fourth).astype(jnp.int32) * T.TWO_CONTS
    err = sc ^ must_be_cont
    err_ref[0] = jnp.max(err)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _call_jit(b3d, interpret):
    """b3d: int32 (nblk+1, ROWS, LANES) — one leading zero tile."""
    nblk = b3d.shape[0] - 1
    table_spec = pl.BlockSpec((16,), lambda i: (0,))
    return pl.pallas_call(
        utf8_validate_kernel,
        grid=(nblk,),
        in_specs=[
            table_spec, table_spec, table_spec,
            # previous tile (the array is padded with a leading zero tile)
            pl.BlockSpec((1, ROWS, LANES), lambda i: (i, 0, 0)),
            # current tile
            pl.BlockSpec((1, ROWS, LANES), lambda i: (i + 1, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((nblk,), jnp.int32),
        interpret=interpret,
    )(jnp.asarray(T.BYTE_1_HIGH), jnp.asarray(T.BYTE_1_LOW),
      jnp.asarray(T.BYTE_2_HIGH), b3d, b3d)


def _call(b3d, interpret=None):
    return _call_jit(b3d, runtime.resolve_interpret(interpret))
