"""Pure-jnp oracles for every Pallas kernel in this package.

Each function reproduces one kernel's per-tile semantics as straight-line
jnp code on flat arrays, so tests can sweep shapes/dtypes and assert exact
(integer) agreement with the ``interpret=True`` kernel execution.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import tables as T


def _sr(x, n, fill=0):
    if n == 0:
        return x
    if n >= x.shape[0]:
        return jnp.full_like(x, fill)
    return jnp.concatenate([jnp.full((n,), fill, x.dtype), x[:-n]])


def _sl(x, n, fill=0):
    if n == 0:
        return x
    if n >= x.shape[0]:
        return jnp.full_like(x, fill)
    return jnp.concatenate([x[n:], jnp.full((n,), fill, x.dtype)])


def utf8_validate_ref(b: jnp.ndarray) -> jnp.ndarray:
    """Keiser-Lemire error maximum over a flat int32 byte array.

    Matches the kernel's semantics for an array with an implicit all-zero
    (ASCII) predecessor; returns the scalar max error value (0 == valid,
    ignoring tail truncation, which the wrapper checks).
    """
    b = b.astype(jnp.int32)
    prev1, prev2, prev3 = _sr(b, 1), _sr(b, 2), _sr(b, 3)
    sc = (
        jnp.take(jnp.asarray(T.BYTE_1_HIGH), prev1 >> 4)
        & jnp.take(jnp.asarray(T.BYTE_1_LOW), prev1 & 0xF)
        & jnp.take(jnp.asarray(T.BYTE_2_HIGH), b >> 4)
    )
    must = ((prev2 >= 0xE0) | (prev3 >= 0xF0)).astype(jnp.int32) * T.TWO_CONTS
    return jnp.max(sc ^ must, initial=0)


def utf8_decode_ref(b: jnp.ndarray):
    """Speculative per-position decode over a flat int32 byte array.

    Returns (cp, lead, units, err_any) with kernel semantics: cp is zero on
    non-lead lanes, lead/units are int32, err_any a scalar int (>0 invalid).
    """
    b = b.astype(jnp.int32)
    b1, b2, b3 = _sl(b, 1), _sl(b, 2), _sl(b, 3)
    seq_len = jnp.take(jnp.asarray(T.LEAD_LENGTH_32), b >> 3)
    is_cont = (b & 0xC0) == 0x80
    is_lead = seq_len > 0

    cp1 = b
    cp2 = ((b & 0x1F) << 6) | (b1 & 0x3F)
    cp3 = ((b & 0x0F) << 12) | ((b1 & 0x3F) << 6) | (b2 & 0x3F)
    cp4 = (((b & 0x07) << 18) | ((b1 & 0x3F) << 12)
           | ((b2 & 0x3F) << 6) | (b3 & 0x3F))
    cp = jnp.where(seq_len == 1, cp1,
         jnp.where(seq_len == 2, cp2,
         jnp.where(seq_len == 3, cp3, cp4)))
    cp = jnp.where(is_lead, cp, 0)

    exp_cont = (_sr(seq_len, 1) >= 2) | (_sr(seq_len, 2) >= 3) | (_sr(seq_len, 3) >= 4)
    struct_err = (exp_cont != is_cont) | (b >= 0xF8)
    min_cp = jnp.take(jnp.asarray(T.MIN_CP_FOR_LEN), seq_len)
    range_err = is_lead & (
        (cp < min_cp) | ((cp >= 0xD800) & (cp < 0xE000)) | (cp > 0x10FFFF)
    )
    units = jnp.where(is_lead, 1 + (cp >= 0x10000).astype(jnp.int32), 0)
    err = jnp.max((struct_err | range_err).astype(jnp.int32), initial=0)
    return cp, is_lead.astype(jnp.int32), units, err


def utf16_encode_ref(u: jnp.ndarray):
    """Per-unit UTF-16 -> UTF-8 candidate bytes over a flat int32 array.

    Returns (b0, b1, b2, b3, L, err_any) with kernel semantics.
    """
    u = u.astype(jnp.int32)
    is_hi = (u >> 10) == 0x36
    is_lo = (u >> 10) == 0x37
    nxt = _sl(u, 1)
    prv = _sr(u, 1)
    nxt_is_lo = (nxt >> 10) == 0x37
    prv_is_hi = (prv >> 10) == 0x36

    pair_cp = 0x10000 + ((u - 0xD800) << 10) + (nxt - 0xDC00)
    cp = jnp.where(is_hi, pair_cp, u)
    is_lead = ~(is_lo & prv_is_hi)

    c0 = cp & 0x3F
    c1 = (cp >> 6) & 0x3F
    c2 = (cp >> 12) & 0x3F
    c3 = (cp >> 18) & 0x07
    L = (1 + (cp >= 0x80).astype(jnp.int32)
         + (cp >= 0x800).astype(jnp.int32)
         + (cp >= 0x10000).astype(jnp.int32))
    z = jnp.zeros_like(cp)
    b0 = jnp.where(L == 1, cp,
         jnp.where(L == 2, 0xC0 | (cp >> 6),
         jnp.where(L == 3, 0xE0 | (cp >> 12), 0xF0 | c3)))
    b1 = jnp.where(L == 2, 0x80 | c0,
         jnp.where(L == 3, 0x80 | c1,
         jnp.where(L == 4, 0x80 | c2, z)))
    b2 = jnp.where(L == 3, 0x80 | c0,
         jnp.where(L == 4, 0x80 | c1, z))
    b3 = jnp.where(L == 4, 0x80 | c0, z)
    L = jnp.where(is_lead, L, 0)
    err = jnp.max(((is_hi & ~nxt_is_lo) | (is_lo & ~prv_is_hi)).astype(jnp.int32),
                  initial=0)
    return b0, b1, b2, b3, L, err
