"""Shared runtime helpers for the Pallas kernels: execution mode + tiling.

Every ``pl.pallas_call`` wrapper in this package takes ``interpret=None``
and resolves it here: on a TPU backend the kernel compiles (Mosaic), on
anything else (CPU CI containers, GPU hosts) it runs under the Pallas
interpreter, which executes the kernel body as ordinary traced jax ops.
Callers can still force either mode explicitly — the resolved value is a
static jit argument, so both variants cache independently.

``tile_with_boundaries`` is the one place the pad-to-VMEM-tiles + zero
boundary-tile convention lives; every kernel wrapper (ops.py and the
fused pipeline) shares it.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def default_interpret() -> bool:
    """True when Pallas kernels must run interpreted (no TPU present)."""
    try:
        return jax.default_backend() != "tpu"
    except RuntimeError:  # pragma: no cover - no backend at all
        return True


def resolve_interpret(interpret) -> bool:
    """Resolve an ``interpret=None`` kwarg to a concrete static bool."""
    if interpret is None:
        return default_interpret()
    return bool(interpret)


def tile_with_boundaries(x, rows: int, lanes: int, boundary_tiles: int = 2):
    """Pad flat ``x`` (dtype preserved) to whole (rows, lanes) tiles and
    add zero boundary tiles: one leading tile for kernels that only look
    back (``boundary_tiles=1``), one on each end for kernels with
    prev/next BlockSpecs (``boundary_tiles=2``).  Returns ``(x3, nblk)``.
    """
    block = rows * lanes
    n = x.shape[0]
    nblk = max(1, -(-n // block))
    pad = nblk * block - n
    x = jnp.concatenate([x, jnp.zeros((pad,), x.dtype)])
    x3 = x.reshape(nblk, rows, lanes)
    z = jnp.zeros((1, rows, lanes), x.dtype)
    if boundary_tiles == 1:
        return jnp.concatenate([z, x3], 0), nblk
    return jnp.concatenate([z, x3, z], 0), nblk
