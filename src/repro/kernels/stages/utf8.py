"""UTF-8 codec stages: tile decode (source side) + candidate-byte encode
(destination side).

The decode side is the speculative block-parallel decode of DESIGN.md §3
(every byte treated as a lead, paper Figs. 2-4 bit surgery) plus the
maximal-subpart analysis shared verbatim with the pure-jnp reference
(``repro.core.utf8.analyze_subparts``).  The encode side is the paper §5
candidate-byte production: per code point, the four candidate UTF-8 bytes
and the 1..4 byte length.  Both sides are pure functions of VMEM-resident
int32 lanes, so the generic count/write driver
(``repro.kernels.stages.driver``) can compose them with any other format's
stages.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import utf8 as u8mod
from repro.kernels.stages.common import shift_left_flat, shift_right_flat

# Largest code point the speculative decode can fabricate from garbage
# input: a 4-byte assembly with every data bit set ((0x07<<18)|...).
# The generic driver sizes per-tile stage windows from this.
MAX_SPECULATIVE_CP = 0x1FFFFF


def _seq_len(b):
    """Sequence length from the lead byte, as a where-tree.

    The paper uses a 32-entry L1 table keyed by ``b >> 3``; on the TPU VPU a
    four-node compare/select tree is cheaper than a gather, so the table is
    *computed* (DESIGN.md §3: the paper's own compute-vs-lookup observation,
    with the tradeoff flipped).
    """
    return jnp.where(
        b < 0x80, 1,
        jnp.where(b < 0xC0, 0,
        jnp.where(b < 0xE0, 2,
        jnp.where(b < 0xF0, 3,
        jnp.where(b < 0xF8, 4, 0)))))


def decode_tile(b, bp, bn):
    """Speculatively decode one tile given its two neighbour tiles.

    All three arguments are int32 arrays of identical (arbitrary) shape;
    the shift helpers treat them as row-major flat byte streams.  Returns
    ``(cp, is_lead, units, err_map)`` of the same shape: candidate code
    point, lead-position flag (bool), UTF-16 code units emitted by the
    character (0 at non-leads), and a per-position structural/range error
    map (bool).  Shared between the legacy standalone decode kernel and
    the generic fused driver.
    """
    b1 = shift_left_flat(b, bn, 1)
    b2 = shift_left_flat(b, bn, 2)
    b3 = shift_left_flat(b, bn, 3)

    seq_len = _seq_len(b)
    is_cont = (b & 0xC0) == 0x80
    is_lead = seq_len > 0

    # Branch-free bit surgery (paper Figs. 2-4).
    cp1 = b
    cp2 = ((b & 0x1F) << 6) | (b1 & 0x3F)
    cp3 = ((b & 0x0F) << 12) | ((b1 & 0x3F) << 6) | (b2 & 0x3F)
    cp4 = (
        ((b & 0x07) << 18)
        | ((b1 & 0x3F) << 12)
        | ((b2 & 0x3F) << 6)
        | (b3 & 0x3F)
    )
    cp = jnp.where(
        seq_len == 1,
        cp1,
        jnp.where(seq_len == 2, cp2, jnp.where(seq_len == 3, cp3, cp4)),
    )
    cp = jnp.where(is_lead, cp, 0)

    # Structural self-validation: expected-continuation bookkeeping.
    seq_len_prev = _seq_len(bp)
    sl_p1 = shift_right_flat(seq_len, seq_len_prev, 1)
    sl_p2 = shift_right_flat(seq_len, seq_len_prev, 2)
    sl_p3 = shift_right_flat(seq_len, seq_len_prev, 3)
    exp_cont = (sl_p1 >= 2) | (sl_p2 >= 3) | (sl_p3 >= 4)
    struct_err = (exp_cont != is_cont) | (b >= 0xF8)

    # Scalar-range validation (overlong / surrogate / too-large).
    # MIN_CP_FOR_LEN as a select tree (same compute-over-lookup adaptation).
    min_cp = jnp.where(seq_len == 2, 0x80,
             jnp.where(seq_len == 3, 0x800,
             jnp.where(seq_len == 4, 0x10000, 0)))
    range_err = is_lead & (
        (cp < min_cp) | ((cp >= 0xD800) & (cp < 0xE000)) | (cp > 0x10FFFF)
    )

    units = jnp.where(is_lead, 1 + (cp >= 0x10000).astype(jnp.int32), 0)
    return cp, is_lead, units, struct_err | range_err


def speculative_decode(b, bp, bn):
    """Decode-stage entry for the generic driver: ``(cp, is_lead)``."""
    cp, is_lead, _units, _err = decode_tile(b, bp, bn)
    return cp, is_lead


def analyze_tile(b, bp, bn):
    """Maximal-subpart analysis of one tile given its neighbour tiles.

    Same shift convention as :func:`decode_tile`; the body is the shared
    :func:`repro.core.utf8.analyze_subparts`, so the fused pipeline's
    error location and errors="replace" semantics are bit-identical to
    the pure-jnp block-parallel reference.  Returns the analysis dict
    (``starts`` / ``valid`` / ``cp`` / ``units`` / ``err``).
    """
    return u8mod.analyze_subparts(
        b,
        shift_left_flat(b, bn, 1),
        shift_left_flat(b, bn, 2),
        shift_left_flat(b, bn, 3),
        shift_right_flat(b, bp, 1),
        shift_right_flat(b, bp, 2),
        shift_right_flat(b, bp, 3),
    )


# ---------------------------------------------------------------------------
# ≤2-byte tile class (driver.onepass_tile dispatch, DESIGN.md §9): the
# restriction of the bodies above to tiles where every byte — and the
# 3-byte inflow window — is below 0xE0.  No 3-/4-byte candidate assembly,
# one lane of claim context instead of three.


def class2_pred(b, bp):
    """True when the tile (and its 3-lane inflow) holds only ASCII,
    2-byte leads, stray continuations and the C0/C1 overlongs — i.e. no
    byte that could start or extend a 3-/4-byte sequence.  Within that
    class :func:`decode2` / :func:`analyze2` are lanewise bit-identical
    to :func:`speculative_decode` / :func:`analyze_tile`.
    """
    tail = bp.reshape(-1)[-3:]
    return (jnp.all((b >= 0) & (b < 0xE0))
            & jnp.all((tail >= 0) & (tail < 0xE0)))


def decode2(b, bp, bn):
    """Class-specialized speculative decode: 1-/2-byte assembly only."""
    del bp
    b1 = shift_left_flat(b, bn, 1)
    cp = jnp.where(b < 0x80, b, ((b & 0x1F) << 6) | (b1 & 0x3F))
    is_lead = (b < 0x80) | (b >= 0xC0)
    return jnp.where(is_lead, cp, 0), is_lead


def analyze2(b, bp, bn):
    """Class-specialized maximal-subpart analysis.

    With every byte below 0xE0, strict lead lengths are 0/1/2, so of
    ``analyze_subparts``'s three claim terms only the 2-byte one
    survives and the first-continuation range is always the default
    80..BF.  Term-by-term restriction of
    :func:`repro.core.utf8.analyze_subparts`.
    """
    nxt1 = shift_left_flat(b, bn, 1)
    prv1 = shift_right_flat(b, bp, 1)

    # Strict lead length (C0/C1 overlongs are invalid leads -> 0).
    L = jnp.where(b < 0x80, 1,
        jnp.where((b >= 0xC2) & (b < 0xE0), 2, 0))
    is_cont = (b & 0xC0) == 0x80
    claimed = (prv1 >= 0xC2) & (prv1 <= 0xDF) & is_cont
    starts = ~claimed
    c1ok = (nxt1 & 0xC0) == 0x80
    valid = starts & ((L == 1) | ((L == 2) & c1ok))

    cp = jnp.where(L == 2, ((b & 0x1F) << 6) | (nxt1 & 0x3F), b)
    cp = jnp.where(valid, cp, jnp.where(starts, 0xFFFD, 0))
    return {
        "starts": starts,
        "valid": valid,
        "cp": cp,
        "units": starts.astype(jnp.int32),
        "err": starts & ~valid,
    }


# ---------------------------------------------------------------------------
# Encode side: code points -> candidate UTF-8 bytes (paper §5).


def unit_len(cp):
    """Encoded UTF-8 length per code point (1..4)."""
    return (
        1
        + (cp >= 0x80).astype(jnp.int32)
        + (cp >= 0x800).astype(jnp.int32)
        + (cp >= 0x10000).astype(jnp.int32)
    )


def py_unit_len(cp: int) -> int:
    """Host-side :func:`unit_len` for static stage-width computation."""
    return 1 + (cp >= 0x80) + (cp >= 0x800) + (cp >= 0x10000)


def utf8_candidates(cp):
    """Candidate UTF-8 bytes + length for per-lane code points.

    Pure function of ``cp`` (paper Fig. 1 bit layout): returns
    ``(b0, b1, b2, b3, L)`` where ``L`` in 1..4 is the encoded length.
    Shared by the strict speculative path and the errors="replace" path
    (where U+FFFD lanes encode as EF BF BD).
    """
    c0 = cp & 0x3F
    c1 = (cp >> 6) & 0x3F
    c2 = (cp >> 12) & 0x3F
    c3 = (cp >> 18) & 0x07
    L = unit_len(cp)
    z = jnp.zeros_like(cp)
    b0 = jnp.where(L == 1, cp,
         jnp.where(L == 2, 0xC0 | (cp >> 6),
         jnp.where(L == 3, 0xE0 | (cp >> 12), 0xF0 | c3)))
    b1 = jnp.where(L == 2, 0x80 | c0,
         jnp.where(L == 3, 0x80 | c1,
         jnp.where(L == 4, 0x80 | c2, z)))
    b2 = jnp.where(L == 3, 0x80 | c0,
         jnp.where(L == 4, 0x80 | c1, z))
    b3 = jnp.where(L == 4, 0x80 | c0, z)
    return b0, b1, b2, b3, L


def encode_units(cp):
    """Encode-stage entry for the generic driver: candidate unit planes."""
    b0, b1, b2, b3, _L = utf8_candidates(cp)
    return (b0, b1, b2, b3)
