"""Generic decode×encode tile driver: one count body, one write body,
any (source, destination) format pair.

The paper's pipeline — validate → decode to code points → re-encode →
compact — is format-symmetric; this module is that symmetry made
executable.  A :class:`Codec` bundles one format's personality on both
sides of the code-point intermediate:

  decode side   ``decode``  (speculative: every lane treated as a lead,
                returns per-lane candidate code point + lead mask) and
                ``analyze`` (maximal-subpart classification: unit starts,
                validity, replacement code points, error map — CPython
                ``UnicodeDecodeError.start`` / ``errors="replace"``
                semantics), plus optional VMEM-resident validation
                ``tables`` with an ``extra_err`` detector (the
                Keiser-Lemire nibble tables ride along for UTF-8).
  encode side   ``unit_len`` / ``encode`` (candidate unit planes per code
                point, paper §5), plus optional ``encode_bad`` for
                destinations that cannot represent every scalar (Latin-1).

:func:`count_tile` and :func:`write_stage` compose any pair of codecs
into the fused pipeline's two passes (DESIGN.md §5/§8); the per-pair tile
bodies that previously hardwired UTF-8→UTF-16 and UTF-16→UTF-8 are now
thin instantiations of these two functions.  Both are themselves thin
compositions of three primitives — :func:`decode_once` (ONE speculative
decode / maximal-subpart analysis of the tile), :func:`count_decoded`
(lengths + fused validation over the decoded lanes) and
:func:`stage_decoded` (in-tile compaction of the decoded lanes) — so the
single-pass pipeline (:func:`onepass_tile`, DESIGN.md §9) can run count
AND write off one decode instead of re-decoding the tile per pass.

Stage windows are sized from first principles instead of per-pair
constants: the speculative worst case is ``dst.py_unit_len(src.
max_speculative_cp)`` units per source lane (:func:`stage_units`).  This
derivation fixed a real overflow of the hand-sized UTF-16→UTF-8 bound —
garbage dense in high surrogates folds to pair code points above
U+10000 at *every* lane (4 candidate bytes each, 4·BLOCK total), past the
old ``3*BLOCK + 1`` stage.
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import compaction

ROWS = 8
LANES = 128
BLOCK = ROWS * LANES

# Sentinel for per-tile first-error min-reduction (int32 max; matches
# repro.core.result.NO_ERR_SENTINEL — re-declared here to keep the stages
# package import-light inside kernel bodies).
_IMAX = 2**31 - 1


class Codec(NamedTuple):
    """One format's decode/encode personality over the code-point
    intermediate (see module docstring)."""

    name: str
    dtype: Any                # narrow storage dtype (uint8/uint16/uint32)
    itemsize: int             # bytes per storage unit
    decode: Callable          # (x, xp, xn) -> (cp, is_lead)
    analyze: Callable         # (x, xp, xn) -> {starts, valid, cp, err}
    unit_len: Callable        # cp -> int32 units per code point
    encode: Callable          # cp -> tuple of candidate unit planes
    max_speculative_cp: int   # largest cp the speculative decode fabricates
    py_unit_len: Callable     # host-side unit_len (static stage sizing)
    tables: Tuple = ()        # VMEM-resident validation tables (np arrays)
    extra_err: Optional[Callable] = None   # (x, xp, *tables) -> bool map
    encode_bad: Optional[Callable] = None  # cp -> bool (unencodable)


def stage_units(src: Codec, dst: Codec) -> int:
    """Speculative worst-case destination units per source lane."""
    return int(dst.py_unit_len(src.max_speculative_cp))


def stage_width(src: Codec, dst: Codec) -> int:
    """Per-tile staging window width for the (src, dst) write pass."""
    return BLOCK * stage_units(src, dst)


def _encode_err(dst: Codec, a, live):
    """Encode-side error map over analyzed unit starts (Latin-1 egress)."""
    if dst.encode_bad is None:
        return a["err"] & live
    return (a["err"] | (dst.encode_bad(a["cp"]) & a["starts"])) & live


# How many trailing source units of the previous tile can still be part
# of a character (or error subpart) that reaches into the current tile:
# 3 bytes for UTF-8 (a 4-byte lead at the last position), 1 unit for
# UTF-16 (a high surrogate), 0 for the fixed-width formats.  The per-tile
# ASCII fast path checks this inflow window conservatively.
_MAX_LOOKBACK = 3


def decode_once(src: Codec, x, xp, xn, *, errors: str, validate: bool):
    """The ONE speculative decode / analysis of a tile.

    Returns ``(a, cp, lead)``: the maximal-subpart analysis map (``None``
    when neither validation nor replacement needs it), the per-lane code
    point, and the unit-start mask the counting and staging primitives
    consume.  Under ``errors="replace"`` the code points/starts come from
    the analysis (replacement-substituted); under ``"strict"`` from the
    raw speculative decode — exactly the historical count/write split,
    now computed once per tile instead of once per pass.
    """
    need_analysis = validate or errors == "replace"
    a = src.analyze(x, xp, xn) if need_analysis else None
    if errors == "replace":
        return a, a["cp"], a["starts"]
    cp, is_lead = src.decode(x, xp, xn)
    return a, cp, is_lead


def count_decoded(src: Codec, dst: Codec, a, cp, lead, x, xp, live, gidx,
                  tables, *, validate: bool):
    """Lengths + fused validation over an already-decoded tile.

    Returns the three per-tile scalars ``(total, err_flag,
    first_err_gidx)`` — first-error offsets are in *global* stream
    coordinates (callers subtract the document start).
    """
    tot = jnp.sum(jnp.where(lead & live, dst.unit_len(cp), 0))
    if validate:
        # Fused validation, one scan: the maximal-subpart map locates the
        # first decode error at its lead (Python exc.start semantics) and
        # the destination's encode_bad map folds in unencodable scalars.
        # An extra detector (the paper-faithful Keiser-Lemire nibble
        # tables for UTF-8) rides along deliberately: it feeds only the
        # flag, so a defect in either detector degrades to a located (or
        # offset-0) error rather than a silently accepted invalid stream.
        sub = _encode_err(dst, a, live)
        err = sub
        if src.extra_err is not None:
            err = err | (src.extra_err(x, xp, *tables) & live)
        err_flag = jnp.max(err.astype(jnp.int32))
        ferr = jnp.min(jnp.where(sub, gidx, _IMAX))
    else:
        err_flag = jnp.int32(0)
        ferr = jnp.int32(_IMAX)
    return tot, err_flag, ferr


def stage_decoded(src: Codec, dst: Codec, cp, lead, instream):
    """In-tile compaction of an already-decoded tile: the staging body.

    Returns the compact int32 stage window (``stage_width(src, dst)``
    lanes); the caller stores it at the tile's base offset.
    """
    live = (lead & instream).reshape(-1)
    eff = jnp.where(live, dst.unit_len(cp).reshape(-1), 0)
    rank, _tot = compaction.tile_exclusive_scan(eff, rows=ROWS)
    cands = dst.encode(cp)
    width = stage_width(src, dst)
    # In-register compress-store (vpcompressb analogue): scatter the
    # 1..stage_units candidate units of each live lane to base-relative
    # rank inside VMEM; lanes shorter than the plane index drop out.
    stage = jnp.zeros((width,), jnp.int32)
    for j, plane in enumerate(cands):
        sel = live if j == 0 else live & (eff >= j + 1)
        stage = stage.at[jnp.where(sel, rank + j, width)].set(
            plane.reshape(-1), mode="drop")
    return stage


def count_tile(src: Codec, dst: Codec, x, xp, xn, live, gidx, tables, *,
               errors: str, validate: bool):
    """One counting/validating scan of a VMEM tile, any format pair.

    ``live`` is the caller's in-stream mask (single stream: ``gidx < n``;
    ragged: ``gidx < doc_end``); ``tables`` are ``src.tables`` as
    VMEM-resident arrays.  Returns the three per-tile scalars
    ``(total, err_flag, first_err_gidx)``.
    """
    a, cp, lead = decode_once(src, x, xp, xn, errors=errors,
                              validate=validate)
    return count_decoded(src, dst, a, cp, lead, x, xp, live, gidx, tables,
                         validate=validate)


def write_stage(src: Codec, dst: Codec, x, xp, xn, instream, *,
                errors: str):
    """Decode + in-tile compaction of one tile: the write-pass body.

    ``instream`` is the caller's in-stream mask of ``x``'s shape.
    """
    _a, cp, lead = decode_once(src, x, xp, xn, errors=errors,
                               validate=False)
    return stage_decoded(src, dst, cp, lead, instream)


def ascii_tile_pred(x, xp):
    """Per-tile ASCII fast-path predicate (paper Algorithm 3 at tile
    granularity).

    True when every lane of the tile is plain ASCII AND the boundary
    inflow — the trailing ``_MAX_LOOKBACK`` lanes of the previous tile,
    which are the only lanes whose characters (or error subparts) can
    reach into this tile — is pure ASCII too.  The inflow guard is
    deliberately conservative: a previous tile ending in a lead or
    continuation byte sends the tile down the general path even though a
    pure-ASCII tile can never be claimed by it.  The lower bound matters:
    lanes are int32 here, so a garbage UTF-32 scalar like 0xFFFFFFFF
    wraps negative and must not ride the copy path.
    """
    tail = xp.reshape(-1)[-_MAX_LOOKBACK:]
    return jnp.all((x >= 0) & (x < 0x80)) & \
        jnp.all((tail >= 0) & (tail < 0x80))


def onepass_tile(src: Codec, dst: Codec, x, xp, xn, live, gidx, tables, *,
                 errors: str, validate: bool, ascii_skip: bool = True):
    """Count + stage one tile off a single decode: the one-pass body.

    Returns ``(total, err_flag, first_err_gidx, stage)`` — the count
    pass's three per-tile scalars plus the write pass's compact stage
    window, computed from ONE decode/analysis of the tile (the fused
    two-pass pipeline decodes every tile twice).  With ``ascii_skip``
    the whole body sits behind a per-tile ``lax.cond``: a pure-ASCII
    tile with pure-ASCII boundary inflow (:func:`ascii_tile_pred`)
    reduces to a widening copy — live lanes are a prefix of the tile and
    dead lanes are already zero, so the copy IS the compact stage — and
    mostly-ASCII documents with occasional multibyte spans no longer
    fall off the fast path globally.
    """
    width = stage_width(src, dst)

    def general(ops):
        x, xp, xn = ops
        a, cp, lead = decode_once(src, x, xp, xn, errors=errors,
                                  validate=validate)
        tot, err, ferr = count_decoded(src, dst, a, cp, lead, x, xp, live,
                                       gidx, tables, validate=validate)
        return tot, err, ferr, stage_decoded(src, dst, cp, lead, live)

    if not ascii_skip:
        return general((x, xp, xn))

    def ascii(ops):
        x, _xp, _xn = ops
        # ASCII lanes are 1 destination unit in every matrix format and
        # never claim (or get claimed by) a neighbour; dead lanes are
        # zeros, so the flat tile is already the compact stage window.
        tot = jnp.sum(live.astype(jnp.int32))
        flat = x.reshape(-1)
        if width > flat.shape[0]:
            flat = jnp.concatenate(
                [flat, jnp.zeros((width - flat.shape[0],), jnp.int32)])
        return tot, jnp.int32(0), jnp.int32(_IMAX), flat

    return jax.lax.cond(ascii_tile_pred(x, xp), ascii, general,
                        (x, xp, xn))
