"""Generic decode×encode tile driver: one count body, one write body,
any (source, destination) format pair.

The paper's pipeline — validate → decode to code points → re-encode →
compact — is format-symmetric; this module is that symmetry made
executable.  A :class:`Codec` bundles one format's personality on both
sides of the code-point intermediate:

  decode side   ``decode``  (speculative: every lane treated as a lead,
                returns per-lane candidate code point + lead mask) and
                ``analyze`` (maximal-subpart classification: unit starts,
                validity, replacement code points, error map — CPython
                ``UnicodeDecodeError.start`` / ``errors="replace"``
                semantics), plus optional VMEM-resident validation
                ``tables`` with an ``extra_err`` detector (the
                Keiser-Lemire nibble tables ride along for UTF-8).
  encode side   ``unit_len`` / ``encode`` (candidate unit planes per code
                point, paper §5), plus optional ``encode_bad`` for
                destinations that cannot represent every scalar (Latin-1).
  class side    ``max_lookback`` (how far a character can claim backward
                across a tile boundary — 3 source units for UTF-8, 1 for
                UTF-16, 0 for the fixed-width formats) and the optional
                ≤2-byte tile class (``class2_pred`` / ``decode2`` /
                ``analyze2``): a per-tile predicate plus specialized
                decode/analysis bodies with no 3-/4-unit assembly and no
                surrogate folding, for tiles whose every code point fits
                in 11 bits (DESIGN.md §9 tile-class dispatch).

:func:`count_tile` and :func:`write_stage` compose any pair of codecs
into the fused pipeline's two passes (DESIGN.md §5/§8); the per-pair tile
bodies that previously hardwired UTF-8→UTF-16 and UTF-16→UTF-8 are now
thin instantiations of these two functions.  Both are themselves thin
compositions of three primitives — :func:`decode_once` (ONE speculative
decode / maximal-subpart analysis of the tile), :func:`count_decoded`
(lengths + fused validation over the decoded lanes) and
:func:`stage_decoded` (in-tile compaction of the decoded lanes) — so the
single-pass pipeline (:func:`onepass_tile`, DESIGN.md §9) can run count
AND write off one decode instead of re-decoding the tile per pass.  Each
primitive has a class-specialized twin (:func:`decode_once2` /
:func:`count_decoded2` / :func:`stage_decoded2`) for the ≤2-byte tile
class.

Stage windows are sized from first principles instead of per-pair
constants: the speculative worst case is ``dst.py_unit_len(src.
max_speculative_cp)`` units per source lane (:func:`stage_units`).  This
derivation fixed a real overflow of the hand-sized UTF-16→UTF-8 bound —
garbage dense in high surrogates folds to pair code points above
U+10000 at *every* lane (4 candidate bytes each, 4·BLOCK total), past the
old ``3*BLOCK + 1`` stage.  The ≤2-byte class narrows the same
derivation to ``dst.py_unit_len(0x7FF)`` units per lane
(:func:`stage_units2`) — half the window for a UTF-8 destination, a
quarter of the speculative UTF-16-source worst case.
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import compaction

ROWS = 8
LANES = 128
BLOCK = ROWS * LANES

# Sentinel for per-tile first-error min-reduction (int32 max; matches
# repro.core.result.NO_ERR_SENTINEL — re-declared here to keep the stages
# package import-light inside kernel bodies).
_IMAX = 2**31 - 1


class Codec(NamedTuple):
    """One format's decode/encode personality over the code-point
    intermediate (see module docstring)."""

    name: str
    dtype: Any                # narrow storage dtype (uint8/uint16/uint32)
    itemsize: int             # bytes per storage unit
    decode: Callable          # (x, xp, xn) -> (cp, is_lead)
    analyze: Callable         # (x, xp, xn) -> {starts, valid, cp, err}
    unit_len: Callable        # cp -> int32 units per code point
    encode: Callable          # cp -> tuple of candidate unit planes
    max_speculative_cp: int   # largest cp the speculative decode fabricates
    py_unit_len: Callable     # host-side unit_len (static stage sizing)
    tables: Tuple = ()        # VMEM-resident validation tables (np arrays)
    extra_err: Optional[Callable] = None   # (x, xp, *tables) -> bool map
    encode_bad: Optional[Callable] = None  # cp -> bool (unencodable)
    # Source units of the previous tile that can still be part of a
    # character (or error subpart) reaching into the current tile: 3 for
    # UTF-8 (a 4-byte lead at the last position), 1 for UTF-16 (a high
    # surrogate), 0 for the fixed-width formats.  The per-tile ASCII and
    # ≤2-byte class predicates check exactly this inflow window, so
    # fixed-width sources no longer pay a UTF-8-sized 3-lane check.
    max_lookback: int = 3
    # ≤2-byte tile class (optional; None disables the class for this
    # source format — e.g. Latin-1, whose general path is already
    # 2-byte-max).  ``class2_pred(x, xp) -> bool`` must be True only when
    # decode2/analyze2 are lanewise bit-identical to decode/analyze on
    # the tile; ``class2_replaces`` marks sources whose class-2 analysis
    # can substitute U+FFFD (stage sizing must then cover its encoding).
    class2_pred: Optional[Callable] = None   # (x, xp) -> bool scalar
    decode2: Optional[Callable] = None       # (x, xp, xn) -> (cp, is_lead)
    analyze2: Optional[Callable] = None      # (x, xp, xn) -> analysis dict
    class2_replaces: bool = False


def stage_units(src: Codec, dst: Codec) -> int:
    """Speculative worst-case destination units per source lane."""
    return int(dst.py_unit_len(src.max_speculative_cp))


def stage_width(src: Codec, dst: Codec) -> int:
    """Per-tile staging window width for the (src, dst) write pass."""
    return BLOCK * stage_units(src, dst)


def stage_units2(src: Codec, dst: Codec) -> int:
    """Destination units per lane inside the ≤2-byte tile class.

    Every in-class code point fits in 11 bits, so the bound is
    ``dst.py_unit_len(0x7FF)`` — plus, for sources whose class-2 analysis
    can substitute U+FFFD (UTF-8 under ``errors="replace"``), enough room
    for the replacement character's encoding.  (For every enabled cell
    the two coincide: U+FFFD is 1 unit in all of UTF-8's destinations.)
    """
    u = int(dst.py_unit_len(0x7FF))
    if src.class2_replaces:
        u = max(u, int(dst.py_unit_len(0xFFFD)))
    return u


def _encode_err(dst: Codec, a, live):
    """Encode-side error map over analyzed unit starts (Latin-1 egress)."""
    if dst.encode_bad is None:
        return a["err"] & live
    return (a["err"] | (dst.encode_bad(a["cp"]) & a["starts"])) & live


def decode_once(src: Codec, x, xp, xn, *, errors: str, validate: bool):
    """The ONE speculative decode / analysis of a tile.

    Returns ``(a, cp, lead)``: the maximal-subpart analysis map (``None``
    when neither validation nor replacement needs it), the per-lane code
    point, and the unit-start mask the counting and staging primitives
    consume.  Under ``errors="replace"`` the code points/starts come from
    the analysis (replacement-substituted); under ``"strict"`` from the
    raw speculative decode — exactly the historical count/write split,
    now computed once per tile instead of once per pass.
    """
    need_analysis = validate or errors == "replace"
    a = src.analyze(x, xp, xn) if need_analysis else None
    if errors == "replace":
        return a, a["cp"], a["starts"]
    cp, is_lead = src.decode(x, xp, xn)
    return a, cp, is_lead


def decode_once2(src: Codec, x, xp, xn, *, errors: str, validate: bool):
    """Class-specialized :func:`decode_once` for a ≤2-byte tile.

    Same contract, but through ``src.decode2`` / ``src.analyze2``: no
    3-/4-unit candidate assembly, no surrogate folding, and a claim
    window of ONE previous lane instead of three.  Only valid on tiles
    where ``src.class2_pred`` holds.
    """
    need_analysis = validate or errors == "replace"
    a = src.analyze2(x, xp, xn) if need_analysis else None
    if errors == "replace":
        return a, a["cp"], a["starts"]
    cp, is_lead = src.decode2(x, xp, xn)
    return a, cp, is_lead


def count_decoded(src: Codec, dst: Codec, a, cp, lead, x, xp, live, gidx,
                  tables, *, validate: bool):
    """Lengths + fused validation over an already-decoded tile.

    Returns the three per-tile scalars ``(total, err_flag,
    first_err_gidx)`` — first-error offsets are in *global* stream
    coordinates (callers subtract the document start).
    """
    tot = jnp.sum(jnp.where(lead & live, dst.unit_len(cp), 0))
    if validate:
        # Fused validation, one scan: the maximal-subpart map locates the
        # first decode error at its lead (Python exc.start semantics) and
        # the destination's encode_bad map folds in unencodable scalars.
        # An extra detector (the paper-faithful Keiser-Lemire nibble
        # tables for UTF-8) rides along deliberately: it feeds only the
        # flag, so a defect in either detector degrades to a located (or
        # offset-0) error rather than a silently accepted invalid stream.
        sub = _encode_err(dst, a, live)
        err = sub
        if src.extra_err is not None:
            err = err | (src.extra_err(x, xp, *tables) & live)
        err_flag = jnp.max(err.astype(jnp.int32))
        ferr = jnp.min(jnp.where(sub, gidx, _IMAX))
    else:
        err_flag = jnp.int32(0)
        ferr = jnp.int32(_IMAX)
    return tot, err_flag, ferr


def count_decoded2(src: Codec, dst: Codec, a, cp, lead, live, gidx, *,
                   validate: bool):
    """Class-specialized :func:`count_decoded` for a ≤2-byte tile.

    The extra Keiser-Lemire detector is skipped (its three nibble-table
    gathers are the most expensive part of the count): within the class
    the maximal-subpart map flags every invalid stream on its own, and
    the first-error offset always came from the subpart map — so the
    sticky per-document ``(err, ferr)`` folds are unchanged even though
    a per-tile flag may fire in a different tile than KL would have.
    """
    tot = jnp.sum(jnp.where(lead & live, dst.unit_len(cp), 0))
    if validate:
        sub = _encode_err(dst, a, live)
        err_flag = jnp.max(sub.astype(jnp.int32))
        ferr = jnp.min(jnp.where(sub, gidx, _IMAX))
    else:
        err_flag = jnp.int32(0)
        ferr = jnp.int32(_IMAX)
    return tot, err_flag, ferr


def _compress_gather(eff, planes, width: int, narrow: bool = False):
    """In-tile compress-store as rank-search + gather (no scatter).

    The paper compacts with ``vpcompressb``; the first TPU formulation
    here scattered each candidate plane to its lane's exclusive unit rank
    (``stage.at[rank + j].set(plane)``).  Scatters are the slowest
    primitive on every backend that serializes them (XLA:CPU runs this
    interpret-mode CI ~100x slower per element than a gather), so the
    masked-store is re-expressed gather-side: output slot ``k`` finds its
    source lane by **binary search over the nondecreasing rank vector**
    (rightmost lane ``pos`` with ``rank[pos] <= k`` — log2(BLOCK) steps,
    each one compare + one gather), takes plane ``j = k - rank[pos]``,
    and gathers ``planes[j][pos]`` from a lane-major stack.  Slack slots
    (``k >= total``) read as zeros, exactly like the scatter's untouched
    initialization, so the result is bit-identical.

    ``eff`` is the per-lane effective unit count (0 at dead lanes);
    ``planes`` the candidate unit planes (flat, BLOCK lanes each);
    ``narrow`` stacks the gather source in uint16 — legal whenever every
    candidate unit fits 16 bits (the ≤2-byte class) — halving the
    traffic of the widest step.  Returns the int32 stage window.
    """
    rank, tot = compaction.tile_exclusive_scan(eff, rows=ROWS)
    nun = len(planes)
    flat = jnp.stack([p.reshape(-1) for p in planes], axis=-1).reshape(-1)
    if narrow:
        flat = flat.astype(jnp.uint16)
    k = jnp.arange(width, dtype=jnp.int32)
    pos = jnp.zeros((width,), jnp.int32)
    step = BLOCK >> 1
    while step:
        cand = pos + step
        ok = (cand < BLOCK) & (rank[jnp.minimum(cand, BLOCK - 1)] <= k)
        pos = jnp.where(ok, cand, pos)
        step >>= 1
    j = k - rank[pos]
    idx = jnp.clip(pos * nun + j, 0, BLOCK * nun - 1)
    val = flat[idx].astype(jnp.int32)
    return jnp.where(k < tot, val, 0)


def stage_decoded(src: Codec, dst: Codec, cp, lead, instream):
    """In-tile compaction of an already-decoded tile: the staging body.

    Returns the compact int32 stage window (``stage_width(src, dst)``
    lanes); the caller stores it at the tile's base offset.
    """
    live = (lead & instream).reshape(-1)
    eff = jnp.where(live, dst.unit_len(cp).reshape(-1), 0)
    nun = stage_units(src, dst)
    cands = dst.encode(cp)[:nun]
    return _compress_gather(eff, cands, stage_width(src, dst))


def stage_decoded2(src: Codec, dst: Codec, cp, lead, instream):
    """Class-specialized :func:`stage_decoded` for a ≤2-byte tile.

    Same compaction, but over ``stage_units2`` candidate planes and a
    ``BLOCK * stage_units2`` window (the class bounds every code point's
    encoding), with the gather source held in uint16 — the narrowest
    dtype the class allows — instead of int32.  The caller zero-pads the
    result up to the general window so the class branches of the
    dispatch ``lax.cond`` agree on shape.
    """
    live = (lead & instream).reshape(-1)
    eff = jnp.where(live, dst.unit_len(cp).reshape(-1), 0)
    nun = stage_units2(src, dst)
    cands = dst.encode(cp)[:nun]
    return _compress_gather(eff, cands, BLOCK * nun, narrow=True)


def count_tile(src: Codec, dst: Codec, x, xp, xn, live, gidx, tables, *,
               errors: str, validate: bool):
    """One counting/validating scan of a VMEM tile, any format pair.

    ``live`` is the caller's in-stream mask (single stream: ``gidx < n``;
    ragged: ``gidx < doc_end``); ``tables`` are ``src.tables`` as
    VMEM-resident arrays.  Returns the three per-tile scalars
    ``(total, err_flag, first_err_gidx)``.
    """
    a, cp, lead = decode_once(src, x, xp, xn, errors=errors,
                              validate=validate)
    return count_decoded(src, dst, a, cp, lead, x, xp, live, gidx, tables,
                         validate=validate)


def write_stage(src: Codec, dst: Codec, x, xp, xn, instream, *,
                errors: str):
    """Decode + in-tile compaction of one tile: the write-pass body.

    ``instream`` is the caller's in-stream mask of ``x``'s shape.
    """
    _a, cp, lead = decode_once(src, x, xp, xn, errors=errors,
                               validate=False)
    return stage_decoded(src, dst, cp, lead, instream)


def ascii_tile_pred(x, xp, lookback: int = 3):
    """Per-tile ASCII fast-path predicate (paper Algorithm 3 at tile
    granularity).

    True when every lane of the tile is plain ASCII AND the boundary
    inflow — the trailing ``lookback`` lanes of the previous tile
    (``src.max_lookback``: 3 for UTF-8, 1 for UTF-16, 0 for the
    fixed-width formats), which are the only lanes whose characters (or
    error subparts) can reach into this tile — is pure ASCII too.  The
    inflow guard is deliberately conservative: a previous tile ending in
    a lead or continuation byte sends the tile down the general path
    even though a pure-ASCII tile can never be claimed by it.  The lower
    bound matters: lanes are int32 here, so a garbage UTF-32 scalar like
    0xFFFFFFFF wraps negative and must not ride the copy path.
    """
    ok = jnp.all((x >= 0) & (x < 0x80))
    if lookback > 0:
        tail = xp.reshape(-1)[-lookback:]
        ok = ok & jnp.all((tail >= 0) & (tail < 0x80))
    return ok


def onepass_tile(src: Codec, dst: Codec, x, xp, xn, live, gidx, tables, *,
                 errors: str, validate: bool, ascii_skip: bool = True):
    """Count + stage one tile off a single decode: the one-pass body.

    Returns ``(total, err_flag, first_err_gidx, stage)`` — the count
    pass's three per-tile scalars plus the write pass's compact stage
    window, computed from ONE decode/analysis of the tile (the fused
    two-pass pipeline decodes every tile twice).  With ``ascii_skip``
    the whole body sits behind a nested per-tile ``lax.cond`` — the
    three-way tile-class dispatch of DESIGN.md §9:

      ASCII     pure-ASCII tile, pure-ASCII boundary inflow
                (:func:`ascii_tile_pred` over ``src.max_lookback``
                lanes): reduces to a widening copy — live lanes are a
                prefix and dead lanes already zero, so the copy IS the
                compact stage.
      ≤2-byte   every lane in the source's 11-bit class and the inflow
                window clean (``src.class2_pred``): the specialized
                decode2/analyze2 bodies (no 3-/4-unit assembly, no
                surrogate folding, no Keiser-Lemire gathers) feed a
                half-width uint16 compaction (:func:`stage_decoded2`),
                zero-padded up to the general window.
      general   everything else: the full speculative decode / subpart
                analysis / worst-case-width staging.

    Each class is lanewise bit-identical to the general body wherever
    its predicate admits a tile, so dispatch on/off (``ascii_skip``)
    never changes (buffer, count, status).
    """
    width = stage_width(src, dst)

    def general(ops):
        x, xp, xn = ops
        a, cp, lead = decode_once(src, x, xp, xn, errors=errors,
                                  validate=validate)
        tot, err, ferr = count_decoded(src, dst, a, cp, lead, x, xp, live,
                                       gidx, tables, validate=validate)
        return tot, err, ferr, stage_decoded(src, dst, cp, lead, live)

    if not ascii_skip:
        return general((x, xp, xn))

    def class2(ops):
        x, xp, xn = ops
        a, cp, lead = decode_once2(src, x, xp, xn, errors=errors,
                                   validate=validate)
        tot, err, ferr = count_decoded2(src, dst, a, cp, lead, live, gidx,
                                        validate=validate)
        stage = stage_decoded2(src, dst, cp, lead, live)
        if width > stage.shape[0]:
            stage = jnp.concatenate(
                [stage, jnp.zeros((width - stage.shape[0],), jnp.int32)])
        return tot, err, ferr, stage

    def ascii(ops):
        x, _xp, _xn = ops
        # ASCII lanes are 1 destination unit in every matrix format and
        # never claim (or get claimed by) a neighbour; dead lanes are
        # zeros, so the flat tile is already the compact stage window.
        tot = jnp.sum(live.astype(jnp.int32))
        flat = x.reshape(-1)
        if width > flat.shape[0]:
            flat = jnp.concatenate(
                [flat, jnp.zeros((width - flat.shape[0],), jnp.int32)])
        return tot, jnp.int32(0), jnp.int32(_IMAX), flat

    if src.class2_pred is None:
        inner = general
    else:
        def inner(ops):
            return jax.lax.cond(src.class2_pred(ops[0], ops[1]),
                                class2, general, ops)

    return jax.lax.cond(ascii_tile_pred(x, xp, src.max_lookback),
                        ascii, inner, (x, xp, xn))
