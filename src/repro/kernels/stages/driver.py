"""Generic decode×encode tile driver: one count body, one write body,
any (source, destination) format pair.

The paper's pipeline — validate → decode to code points → re-encode →
compact — is format-symmetric; this module is that symmetry made
executable.  A :class:`Codec` bundles one format's personality on both
sides of the code-point intermediate:

  decode side   ``decode``  (speculative: every lane treated as a lead,
                returns per-lane candidate code point + lead mask) and
                ``analyze`` (maximal-subpart classification: unit starts,
                validity, replacement code points, error map — CPython
                ``UnicodeDecodeError.start`` / ``errors="replace"``
                semantics), plus optional VMEM-resident validation
                ``tables`` with an ``extra_err`` detector (the
                Keiser-Lemire nibble tables ride along for UTF-8).
  encode side   ``unit_len`` / ``encode`` (candidate unit planes per code
                point, paper §5), plus optional ``encode_bad`` for
                destinations that cannot represent every scalar (Latin-1).

:func:`count_tile` and :func:`write_stage` compose any pair of codecs
into the fused pipeline's two passes (DESIGN.md §5/§8); the per-pair tile
bodies that previously hardwired UTF-8→UTF-16 and UTF-16→UTF-8 are now
thin instantiations of these two functions.

Stage windows are sized from first principles instead of per-pair
constants: the speculative worst case is ``dst.py_unit_len(src.
max_speculative_cp)`` units per source lane (:func:`stage_units`).  This
derivation fixed a real overflow of the hand-sized UTF-16→UTF-8 bound —
garbage dense in high surrogates folds to pair code points above
U+10000 at *every* lane (4 candidate bytes each, 4·BLOCK total), past the
old ``3*BLOCK + 1`` stage.
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax.numpy as jnp

from repro.core import compaction

ROWS = 8
LANES = 128
BLOCK = ROWS * LANES

# Sentinel for per-tile first-error min-reduction (int32 max; matches
# repro.core.result.NO_ERR_SENTINEL — re-declared here to keep the stages
# package import-light inside kernel bodies).
_IMAX = 2**31 - 1


class Codec(NamedTuple):
    """One format's decode/encode personality over the code-point
    intermediate (see module docstring)."""

    name: str
    dtype: Any                # narrow storage dtype (uint8/uint16/uint32)
    itemsize: int             # bytes per storage unit
    decode: Callable          # (x, xp, xn) -> (cp, is_lead)
    analyze: Callable         # (x, xp, xn) -> {starts, valid, cp, err}
    unit_len: Callable        # cp -> int32 units per code point
    encode: Callable          # cp -> tuple of candidate unit planes
    max_speculative_cp: int   # largest cp the speculative decode fabricates
    py_unit_len: Callable     # host-side unit_len (static stage sizing)
    tables: Tuple = ()        # VMEM-resident validation tables (np arrays)
    extra_err: Optional[Callable] = None   # (x, xp, *tables) -> bool map
    encode_bad: Optional[Callable] = None  # cp -> bool (unencodable)


def stage_units(src: Codec, dst: Codec) -> int:
    """Speculative worst-case destination units per source lane."""
    return int(dst.py_unit_len(src.max_speculative_cp))


def stage_width(src: Codec, dst: Codec) -> int:
    """Per-tile staging window width for the (src, dst) write pass."""
    return BLOCK * stage_units(src, dst)


def _encode_err(dst: Codec, a, live):
    """Encode-side error map over analyzed unit starts (Latin-1 egress)."""
    if dst.encode_bad is None:
        return a["err"] & live
    return (a["err"] | (dst.encode_bad(a["cp"]) & a["starts"])) & live


def count_tile(src: Codec, dst: Codec, x, xp, xn, live, gidx, tables, *,
               errors: str, validate: bool):
    """One counting/validating scan of a VMEM tile, any format pair.

    ``live`` is the caller's in-stream mask (single stream: ``gidx < n``;
    ragged: ``gidx < doc_end``); ``tables`` are ``src.tables`` as
    VMEM-resident arrays.  Returns the three per-tile scalars
    ``(total, err_flag, first_err_gidx)`` — first-error offsets are in
    *global* stream coordinates (callers subtract the document start).
    """
    need_analysis = validate or errors == "replace"
    a = src.analyze(x, xp, xn) if need_analysis else None
    if errors == "replace":
        tot = jnp.sum(jnp.where(a["starts"] & live, dst.unit_len(a["cp"]), 0))
    else:
        cp, is_lead = src.decode(x, xp, xn)
        tot = jnp.sum(jnp.where(is_lead & live, dst.unit_len(cp), 0))

    if validate:
        # Fused validation, one scan: the maximal-subpart map locates the
        # first decode error at its lead (Python exc.start semantics) and
        # the destination's encode_bad map folds in unencodable scalars.
        # An extra detector (the paper-faithful Keiser-Lemire nibble
        # tables for UTF-8) rides along deliberately: it feeds only the
        # flag, so a defect in either detector degrades to a located (or
        # offset-0) error rather than a silently accepted invalid stream.
        sub = _encode_err(dst, a, live)
        err = sub
        if src.extra_err is not None:
            err = err | (src.extra_err(x, xp, *tables) & live)
        err_flag = jnp.max(err.astype(jnp.int32))
        ferr = jnp.min(jnp.where(sub, gidx, _IMAX))
    else:
        err_flag = jnp.int32(0)
        ferr = jnp.int32(_IMAX)
    return tot, err_flag, ferr


def write_stage(src: Codec, dst: Codec, x, xp, xn, instream, *,
                errors: str):
    """Decode + in-tile compaction of one tile: the write-pass body.

    ``instream`` is the caller's in-stream mask of ``x``'s shape.
    Returns the compact int32 stage window (``stage_width(src, dst)``
    lanes); the caller stores it at the tile's base offset.
    """
    if errors == "replace":
        a = src.analyze(x, xp, xn)
        cp = a["cp"]
        live = (a["starts"] & instream).reshape(-1)
    else:
        cp, is_lead = src.decode(x, xp, xn)
        live = (is_lead & instream).reshape(-1)
    eff = jnp.where(live, dst.unit_len(cp).reshape(-1), 0)
    rank, _tot = compaction.tile_exclusive_scan(eff, rows=ROWS)
    cands = dst.encode(cp)
    width = stage_width(src, dst)
    # In-register compress-store (vpcompressb analogue): scatter the
    # 1..stage_units candidate units of each live lane to base-relative
    # rank inside VMEM; lanes shorter than the plane index drop out.
    stage = jnp.zeros((width,), jnp.int32)
    for j, plane in enumerate(cands):
        sel = live if j == 0 else live & (eff >= j + 1)
        stage = stage.at[jnp.where(sel, rank + j, width)].set(
            plane.reshape(-1), mode="drop")
    return stage
