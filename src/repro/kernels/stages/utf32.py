"""UTF-32 codec stages.

UTF-32 is the codepoint intermediate itself, so both stages are nearly
free: decoding is a per-lane scalar-range check (surrogates, > U+10FFFF,
negatives can never be characters), encoding is the identity.  The strict
decode substitutes U+FFFD for invalid scalars *in the buffer* — exactly
what errors="replace" would emit — so the speculative output is a
well-defined narrow value in every strategy while ``status`` still
reports the first offender's offset (CPython raises there; only the
location is oracle-pinned).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.utf32 import invalid_scalar

# Encodes nothing larger than a real scalar after FFFD substitution, but
# the *speculative* lane value is arbitrary 32-bit input; stage widths
# must assume the widest destination class.
MAX_SPECULATIVE_CP = 0x7FFFFFFF


def speculative_decode(x, xp, xn):
    """Decode-stage entry: every lane is a lead; invalid scalars carry
    U+FFFD (see module docstring)."""
    del xp, xn
    cp = jnp.where(invalid_scalar(x), 0xFFFD, x)
    return cp, jnp.ones(x.shape, bool)


def analyze_tile(x, xp, xn):
    """Unit analysis: each lane is its own unit; invalid scalars are
    ill-formed units replaced by U+FFFD."""
    del xp, xn
    bad = invalid_scalar(x)
    return {
        "starts": jnp.ones(x.shape, bool),
        "valid": ~bad,
        "cp": jnp.where(bad, 0xFFFD, x),
        "err": bad,
    }


# ---------------------------------------------------------------------------
# ≤2-byte tile class: scalars at or below 0x7FF are always valid (no
# surrogates, no overflow possible), so both class bodies are the
# identity — the range check itself is the class predicate.


def class2_pred(x, xp):
    del xp
    return jnp.all((x >= 0) & (x <= 0x7FF))


def decode2(x, xp, xn):
    del xp, xn
    return x, jnp.ones(x.shape, bool)


def analyze2(x, xp, xn):
    del xp, xn
    ones = jnp.ones(x.shape, bool)
    return {
        "starts": ones,
        "valid": ones,
        "cp": x,
        "units": ones.astype(jnp.int32),
        "err": jnp.zeros(x.shape, bool),
    }


# ---------------------------------------------------------------------------
# Encode side: identity.


def unit_len(cp):
    return jnp.ones(cp.shape, jnp.int32)


def py_unit_len(cp: int) -> int:
    return 1


def encode_units(cp):
    return (cp,)
