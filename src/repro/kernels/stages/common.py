"""Shared tile-context helpers for the codec stages.

Every decode stage sees its VMEM tile plus the two neighbour tiles and
derives lane-shifted views of the flat element stream from them; the two
helpers below are the single definition of that convention (previously
duplicated per kernel module).  All stage bodies treat their arguments as
row-major flat streams of int32 lanes.
"""

from __future__ import annotations

import jax.numpy as jnp


def shift_left_flat(cur, nxt, n):
    """``cur[i + n]`` with elements flowing in from the next tile."""
    c = cur.reshape(-1)
    x = nxt.reshape(-1)
    return jnp.concatenate([c[n:], x[:n]]).reshape(cur.shape)


def shift_right_flat(cur, prev, n):
    """``cur[i - n]`` with elements flowing in from the previous tile."""
    c = cur.reshape(-1)
    p = prev.reshape(-1)
    return jnp.concatenate([p[-n:], c[:-n]]).reshape(cur.shape)
