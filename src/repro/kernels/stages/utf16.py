"""UTF-16 codec stages: tile decode (surrogate-pair folding) + candidate
code-unit encode.

Decode side: per lane, classify the unit (BMP / surrogate half), fold
surrogate pairs into supplementary code points using one unit of
lookahead from the next tile (and one of lookbehind to identify consumed
trailing halves); the maximal-subpart analysis is the shared
``repro.core.utf16.analyze_units``.  Encode side: UTF-32 -> UTF-16
candidate production (``repro.core.utf16.encode_candidates`` bit layout).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import utf16 as u16core
from repro.kernels.stages.common import shift_left_flat, shift_right_flat
from repro.kernels.stages.utf8 import utf8_candidates

# Largest code point the speculative pair folding can fabricate from
# garbage (hi = 0xDBFF followed by any 16-bit unit): 0x10000 + 0xFFC00 +
# (0xFFFF - 0xDC00).  Note this exceeds 0x10FFFF — stage widths must size
# for it (see driver.stage_units; undersizing was a real overflow bug of
# the hand-sized per-pair stage constants on surrogate-flood garbage).
MAX_SPECULATIVE_CP = 0x111FFF


def speculative_decode(u, up, un):
    """Decode-stage entry: ``(cp, is_lead)`` for one tile.

    ``cp`` folds surrogate pairs (paper Fig. 4 surrogate construction,
    inverted); a low half claimed by the previous lane's high half is not
    a lead.
    """
    top6 = u >> 10
    is_hi = top6 == 0x36
    is_lo = top6 == 0x37

    nxt = shift_left_flat(u, un, 1)
    prv = shift_right_flat(u, up, 1)
    prv_is_hi = (prv >> 10) == 0x36

    pair_cp = 0x10000 + ((u - 0xD800) << 10) + (nxt - 0xDC00)
    cp = jnp.where(is_hi, pair_cp, u)
    is_lead = ~(is_lo & prv_is_hi)
    return cp, is_lead


def analyze_tile(u, up, un):
    """Unit analysis of one tile given its neighbour tiles.

    The body is the shared :func:`repro.core.utf16.analyze_units` (one
    unit of context each way), so the fused pipeline's unpaired-surrogate
    location and errors="replace" semantics match the pure-jnp reference
    bit for bit.  Returns the analysis dict (``starts`` / ``valid`` /
    ``cp`` / ``err``).
    """
    return u16core.analyze_units(
        u, shift_left_flat(u, un, 1), shift_right_flat(u, up, 1))


def encode_tile(u, up, un):
    """Legacy fused UTF-16-decode + UTF-8-encode body of one tile.

    Kept for the standalone ``utf16_encode`` kernel (the pre-stages
    composition of this module's decode with the UTF-8 encode stage).
    Returns ``(b0, b1, b2, b3, L, err_map)``; ``L`` is 0 at consumed
    trailing surrogate halves.
    """
    cp, is_lead = speculative_decode(u, up, un)
    b0, b1, b2, b3, L = utf8_candidates(cp)
    L = jnp.where(is_lead, L, 0)

    is_hi = (u >> 10) == 0x36
    is_lo = (u >> 10) == 0x37
    nxt_is_lo = (shift_left_flat(u, un, 1) >> 10) == 0x37
    prv_is_hi = (shift_right_flat(u, up, 1) >> 10) == 0x36
    err_map = (is_hi & ~nxt_is_lo) | (is_lo & ~prv_is_hi)
    return b0, b1, b2, b3, L, err_map


# ---------------------------------------------------------------------------
# ≤2-byte tile class: units below 0x800 carry no surrogate halves, so
# decode is the identity and analysis is all-valid.  No inflow check is
# needed: a unit below 0x800 is never a low surrogate, so a trailing
# high surrogate in the previous tile cannot claim into this tile (its
# unpaired-half error is flagged in ITS tile via one unit of lookahead).


def class2_pred(u, up):
    del up
    return jnp.all((u >= 0) & (u < 0x800))


def decode2(u, up, un):
    del up, un
    return u, jnp.ones(u.shape, bool)


def analyze2(u, up, un):
    del up, un
    ones = jnp.ones(u.shape, bool)
    return {
        "starts": ones,
        "valid": ones,
        "cp": u,
        "units": ones.astype(jnp.int32),
        "err": jnp.zeros(u.shape, bool),
    }


# ---------------------------------------------------------------------------
# Encode side: code points -> candidate UTF-16 units.


def unit_len(cp):
    """UTF-16 code units per code point (1 or 2)."""
    return 1 + (cp >= 0x10000).astype(jnp.int32)


def py_unit_len(cp: int) -> int:
    return 1 + (cp >= 0x10000)


def encode_units(cp):
    """Encode-stage entry: the two candidate code-unit planes."""
    _units, u0, u1, _bad = u16core.encode_candidates(cp)
    return (u0, u1)
