"""Codec stages: per-format decode/encode tile bodies + the generic
count/write driver (DESIGN.md §8).

The registry below is the single source of truth for which formats the
fused/ragged Pallas pipelines speak.  Every (src, dst) pair with
``src != dst`` is a valid composition of :func:`driver.count_tile` /
:func:`driver.write_stage`; the classic UTF-8→UTF-16 and UTF-16→UTF-8
kernels are just two cells of this matrix.
"""

from __future__ import annotations

from repro.core import tables as T
from repro.kernels import utf8_validate as kval
from repro.kernels.stages import driver
from repro.kernels.stages import latin1 as s_latin1
from repro.kernels.stages import utf16 as s_utf16
from repro.kernels.stages import utf32 as s_utf32
from repro.kernels.stages import utf8 as s_utf8
from repro.kernels.stages.driver import (  # noqa: F401  (re-export)
    BLOCK, LANES, ROWS, Codec, ascii_tile_pred, count_decoded,
    count_decoded2, count_tile, decode_once, decode_once2, onepass_tile,
    stage_decoded, stage_decoded2, stage_units, stage_units2, stage_width,
    write_stage)

import jax.numpy as jnp


def _kl_extra_err(b, bp, t1h, t1l, t2h):
    """Keiser-Lemire nibble-table detector (UTF-8 only, rides along with
    the maximal-subpart locator in the count pass's validation)."""
    return kval.kl_error_tile(b, bp, t1h, t1l, t2h)


UTF8 = Codec(
    name="utf8",
    dtype=jnp.uint8,
    itemsize=1,
    decode=s_utf8.speculative_decode,
    analyze=s_utf8.analyze_tile,
    unit_len=s_utf8.unit_len,
    encode=s_utf8.encode_units,
    max_speculative_cp=s_utf8.MAX_SPECULATIVE_CP,
    py_unit_len=s_utf8.py_unit_len,
    tables=(T.BYTE_1_HIGH, T.BYTE_1_LOW, T.BYTE_2_HIGH),
    extra_err=_kl_extra_err,
    max_lookback=3,
    class2_pred=s_utf8.class2_pred,
    decode2=s_utf8.decode2,
    analyze2=s_utf8.analyze2,
    # UTF-8's class-2 analysis substitutes U+FFFD for in-class garbage
    # (stray continuations, truncated 2-byte sequences, C0/C1), so the
    # class stage window must cover the replacement character's encoding.
    class2_replaces=True,
)

UTF16 = Codec(
    name="utf16",
    dtype=jnp.uint16,
    itemsize=2,
    decode=s_utf16.speculative_decode,
    analyze=s_utf16.analyze_tile,
    unit_len=s_utf16.unit_len,
    encode=s_utf16.encode_units,
    max_speculative_cp=s_utf16.MAX_SPECULATIVE_CP,
    py_unit_len=s_utf16.py_unit_len,
    # Only a trailing high surrogate can reach across a tile boundary.
    max_lookback=1,
    class2_pred=s_utf16.class2_pred,
    decode2=s_utf16.decode2,
    analyze2=s_utf16.analyze2,
)

UTF32 = Codec(
    name="utf32",
    dtype=jnp.uint32,
    itemsize=4,
    decode=s_utf32.speculative_decode,
    analyze=s_utf32.analyze_tile,
    unit_len=s_utf32.unit_len,
    encode=s_utf32.encode_units,
    max_speculative_cp=s_utf32.MAX_SPECULATIVE_CP,
    py_unit_len=s_utf32.py_unit_len,
    # Fixed-width source: characters never span a tile boundary.
    max_lookback=0,
    class2_pred=s_utf32.class2_pred,
    decode2=s_utf32.decode2,
    analyze2=s_utf32.analyze2,
)

LATIN1 = Codec(
    name="latin1",
    dtype=jnp.uint8,
    itemsize=1,
    decode=s_latin1.speculative_decode,
    analyze=s_latin1.analyze_tile,
    unit_len=s_latin1.unit_len,
    encode=s_latin1.encode_units,
    max_speculative_cp=s_latin1.MAX_SPECULATIVE_CP,
    py_unit_len=s_latin1.py_unit_len,
    encode_bad=s_latin1.encode_bad,
    # Fixed-width source; the general path is already 2-byte-max work,
    # so the ≤2-byte class is disabled (class2_pred=None).
    max_lookback=0,
)

CODECS = {c.name: c for c in (UTF8, UTF16, UTF32, LATIN1)}

# Output capacity per input element: the single definition lives next to
# the public dispatch (``repro.core.transcode``); the kernel registry and
# the block-parallel reference share it so their static buffer
# conventions can never drift apart.
from repro.core.transcode import CAP_FACTOR, PAIRS  # noqa: E402,F401


def get_codec(name: str) -> Codec:
    try:
        return CODECS[name]
    except KeyError:
        raise ValueError(
            f"unknown format {name!r}; supported: {sorted(CODECS)}")


def get_pair(src: str, dst: str):
    """Resolve a (src, dst) format pair to ``(src_codec, dst_codec,
    cap_factor)``; rejects src == dst and unknown names."""
    if (src, dst) not in CAP_FACTOR:
        raise ValueError(
            f"unsupported format pair {src!r} -> {dst!r}; "
            f"supported pairs: {list(PAIRS)}")
    return CODECS[src], CODECS[dst], CAP_FACTOR[(src, dst)]
