"""Latin-1 codec stages.

Decode side: every byte is a valid code point (a widening copy that can
never fail — the analysis is all-valid by construction).  Encode side:
``repro.core.latin1.encode_candidates`` — one byte per code point, with
CPython's ``?`` substitution for values above U+00FF (the offender's
offset still surfaces in ``status`` via the driver's encode-error map).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import latin1 as l1core

MAX_SPECULATIVE_CP = 0xFF


def speculative_decode(x, xp, xn):
    del xp, xn
    return x, jnp.ones(x.shape, bool)


def analyze_tile(x, xp, xn):
    del xp, xn
    ones = jnp.ones(x.shape, bool)
    return {
        "starts": ones,
        "valid": ones,
        "cp": x,
        "err": jnp.zeros(x.shape, bool),
    }


# ---------------------------------------------------------------------------
# Encode side.


def unit_len(cp):
    return jnp.ones(cp.shape, jnp.int32)


def py_unit_len(cp: int) -> int:
    return 1


def encode_units(cp):
    _len, byte, _bad = l1core.encode_candidates(cp)
    return (byte,)


encode_bad = l1core.encode_bad
