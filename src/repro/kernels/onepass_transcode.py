"""Single-pass Pallas transcode pipeline (strategy ``"onepass"``, the
dispatch default): ONE grid launch, ONE decode per source tile.

The fused two-pass pipeline (``repro.kernels.fused_transcode``) splits
the transcode into a count launch, a host-visible ``nblk``-element
cumsum, and a write launch that RE-decodes every tile — each byte is
fetched and decoded twice and every transcode pays two launch overheads.
The split existed only to materialize the inter-tile exclusive scan (a
tile cannot know its output base before all earlier tiles have counted).
On TPU, Pallas grid steps execute sequentially per core, so that scan
does not need a launch boundary at all: it is a scalar **carry in SMEM
scratch** (DESIGN.md §9).

Each grid step of the single launch:

  1. decodes/analyzes its VMEM tile ONCE (``stages.driver.decode_once``
     — the same generic body the fused passes instantiate),
  2. counts the tile's output units + fused validation scalars off the
     decoded lanes (``count_decoded``),
  3. reads the running output offset from the SMEM carry — the exclusive
     scan, one scalar add per tile instead of an inter-launch cumsum —
     and stores the compact stage window (``stage_decoded``, fed the
     *already-decoded* tile) at that base,
  4. advances the carry and folds the tile's error scalars into the
     sticky (err_flag, first_error) carry; the final
     ``(count, status)`` pair is emitted from the carry, so nothing
     per-tile ever round-trips to the host.

The whole-buffer ASCII ``lax.cond`` of the two-pass wrappers additionally
becomes a **per-tile three-way class dispatch** (paper Algorithm 3 at
tile granularity plus the ≤2-byte class, ``stages.driver.onepass_tile``,
DESIGN.md §9): a pure-ASCII tile with clean boundary inflow reduces to a
widening copy, a tile whose every code point fits 11 bits takes the
class-specialized ≤2-byte body (no 3-/4-unit assembly, no surrogate
folding, half-width uint16 staging), and only genuinely wide tiles pay
the general speculative decode.  Mostly-ASCII documents keep the copy
path tile by tile, and dense 2-byte scripts ride the narrowed class
instead of falling off it globally.  (The whole-buffer cond survives in
front of the launch — when the entire buffer is ASCII, skipping the
kernel dispatch outright is strictly cheaper than taking the skip tile
by tile.)

Results are bit-identical to ``strategy="fused"`` — (buffer, count,
status) across every matrix cell × ``errors=`` policy (pinned by
``tests/test_onepass.py`` and the differential fuzz) — and the whole
transcode traces to exactly ONE ``pallas_call``.

Sequential-grid assumption: the SMEM carry is only correct because grid
steps run in order on one core.  That holds for Mosaic's TPU lowering
(the grid is a sequential loop per core) and for the Pallas interpreter
(which executes the grid as a sequential scan carrying scratch buffers);
a parallel multi-core grid partition would need one carry per partition
plus a final fix-up pass — see DESIGN.md §9.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import result as R
from repro.kernels import fused_transcode as ft
from repro.kernels import runtime
from repro.kernels import stages
from repro.kernels.stages import driver as sdrv
from repro.testing import faults

ROWS = sdrv.ROWS
LANES = sdrv.LANES
BLOCK = sdrv.BLOCK

_IMAX = R.NO_ERR_SENTINEL

_check_errors = R.check_errors_policy

# SMEM carry layout (int32 x 3), initialized at grid step 0:
#   [0] running output offset  (the inter-tile exclusive scan)
#   [1] sticky error flag      (max over tiles)
#   [2] sticky first-error     (min over tiles; _IMAX = clean)
_CARRY = 3


def _onepass_kernel(*refs, src, dst, errors, validate, ascii_skip):
    codec_s, codec_d = stages.get_codec(src), stages.get_codec(dst)
    width = stages.stage_width(codec_s, codec_d)
    nt = len(codec_s.tables)
    table_refs = refs[:nt]
    n_ref, xp_ref, x_ref, xn_ref, out_ref, fin_ref, carry = refs[nt:]
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        carry[0] = 0
        carry[1] = 0
        carry[2] = _IMAX

    x = x_ref[...].astype(jnp.int32)
    xp = xp_ref[...].astype(jnp.int32)
    xn = xn_ref[...].astype(jnp.int32)
    gidx = ft._gidx(x.shape)
    tot, err, ferr, stage = sdrv.onepass_tile(
        codec_s, codec_d, x, xp, xn, gidx < n_ref[0], gidx,
        tuple(t[...] for t in table_refs), errors=errors,
        validate=validate, ascii_skip=ascii_skip)

    base = carry[0]
    out_ref[pl.ds(base, width)] = stage.astype(codec_d.dtype)
    carry[0] = base + tot
    carry[1] = jnp.maximum(carry[1], err)
    carry[2] = jnp.minimum(carry[2], ferr)
    # Written every step; the grid is sequential, so the last write is
    # the final (count, status) — no per-tile vectors leave the kernel.
    fin_ref[0] = carry[0]
    fin_ref[1] = R.status_from_first(carry[2], carry[1] > 0)


def _onepass_call(xm, n, src, dst, errors, validate, ascii_skip, interpret):
    """The single launch: returns ``(out_window, (count, status))``."""
    codec_s, codec_d, _f = stages.get_pair(src, dst)
    width = stages.stage_width(codec_s, codec_d)
    x3, nblk = ft._tile(xm)
    n1 = jnp.asarray(n, jnp.int32).reshape(1)
    kernel = functools.partial(_onepass_kernel, src=src, dst=dst,
                               errors=errors, validate=validate,
                               ascii_skip=ascii_skip)
    outp, fin = pl.pallas_call(
        kernel,
        grid=(nblk,),
        in_specs=ft._table_specs(codec_s) + [
            ft._SCALAR_SPEC, ft._tile_spec(0), ft._tile_spec(1),
            ft._tile_spec(2)],
        # The compact buffer is one revisited block (as in the fused
        # write pass): each grid step stores its stage window at the
        # carried, data-dependent base.  Sized so the store at the
        # largest possible base fits.  The (2,) block is the final
        # (count, status) pair off the carry.
        out_specs=[pl.BlockSpec((nblk * width,), lambda i: (0,)),
                   pl.BlockSpec((2,), lambda i: (0,))],
        out_shape=[jax.ShapeDtypeStruct((nblk * width,), codec_d.dtype),
                   jax.ShapeDtypeStruct((2,), jnp.int32)],
        scratch_shapes=[pltpu.SMEM((_CARRY,), jnp.int32)],
        interpret=interpret,
    )(*[jnp.asarray(t) for t in codec_s.tables], n1, x3, x3, x3)
    return outp, fin


@functools.partial(jax.jit, static_argnames=("src", "dst", "validate",
                                             "interpret", "ascii_fastpath",
                                             "masked", "errors"))
def _transcode_impl(x, n, src, dst, validate, interpret, ascii_fastpath,
                    masked, errors):
    codec_s, codec_d, factor = stages.get_pair(src, dst)
    cap = factor * x.shape[0]
    # Padding-mask / drop-at-capacity / whole-buffer-ASCII semantics are
    # the fused module's helpers — ONE definition of the wrapper
    # contract both Pallas strategies are pinned bit-identical on.
    xm = ft._mask_padding(x, n, codec_s.dtype, masked)

    def general(xm):
        outp, fin = _onepass_call(xm, n, src, dst, errors, validate,
                                  ascii_fastpath, interpret)
        total = fin[0]
        outp = ft._clip_to_cap(outp, cap, total, codec_d.dtype)
        return R.TranscodeResult(outp, total, fin[1])

    def ascii(xm):
        # When EVERY tile would take the per-tile skip, skipping the
        # launch itself is strictly cheaper.
        return ft._ascii_copy_result(xm, n, cap, codec_d.dtype)

    if not ascii_fastpath:
        return general(xm)
    # xm is the codec's (unsigned) storage dtype, so a single max
    # reduction decides ASCII-ness — measurably cheaper at the µs scale
    # of this path than materializing a comparison vector for jnp.all.
    return jax.lax.cond(jnp.max(xm, initial=0) < 0x80, ascii, general, xm)


def transcode_onepass(x, n_valid=None, *, src: str, dst: str,
                      validate: bool = True, errors: str = "strict",
                      interpret=None, ascii_fastpath: bool = True):
    """Single-pass transcode for any (src, dst) cell of the matrix.

    Bit-identical to :func:`repro.kernels.fused_transcode.
    transcode_fused` — same ``TranscodeResult`` buffer/count/status under
    every ``errors=`` policy — but the input is read and decoded ONCE in
    a single Pallas launch: the inter-tile output offsets are a scalar
    SMEM carry across the sequential grid instead of an inter-launch
    cumsum, and the count/status come off the carry rather than an
    ``nblk``-vector round trip.  ``ascii_fastpath`` controls both the
    whole-buffer cond and the per-tile ASCII skip.
    """
    _check_errors(errors)
    faults.fire(faults.KERNEL_ONEPASS)   # chaos-suite hook (no-op in prod)
    codec_s, _codec_d, _f = stages.get_pair(src, dst)
    x = jnp.asarray(x)
    if x.dtype != codec_s.dtype:
        x = x.astype(codec_s.dtype)
    n = x.shape[0] if n_valid is None else n_valid
    return _transcode_impl(
        x, jnp.asarray(n, jnp.int32), src, dst, validate,
        runtime.resolve_interpret(interpret), ascii_fastpath,
        n_valid is not None, errors)


def scan_onepass(x, n_valid=None, *, src: str, dst: str, interpret=None):
    """Single-scan validation + capacity query: ``(count, status)``.

    The fused pipeline's counting pass is ALREADY one launch over one
    read of the input (there is no write pass to fuse away), so the
    one-pass strategy's scan is the same kernel; this alias exists so
    ``strategy="onepass"`` is total over the public API.
    """
    return ft.scan_fused(x, n_valid, src=src, dst=dst, interpret=interpret)


# ---------------------------------------------------------------------------
# Thin per-pair instantiations (mirror the fused pipeline's public API).


def utf8_to_utf16_onepass(b, n_valid=None, *, validate: bool = True,
                          errors: str = "strict", interpret=None,
                          ascii_fastpath: bool = True):
    """Single-pass UTF-8 -> UTF-16 (the (utf8, utf16) matrix cell)."""
    return transcode_onepass(b, n_valid, src="utf8", dst="utf16",
                             validate=validate, errors=errors,
                             interpret=interpret,
                             ascii_fastpath=ascii_fastpath)


def utf16_to_utf8_onepass(u, n_valid=None, *, validate: bool = True,
                          errors: str = "strict", interpret=None,
                          ascii_fastpath: bool = True):
    """Single-pass UTF-16 -> UTF-8 (the (utf16, utf8) matrix cell)."""
    return transcode_onepass(u, n_valid, src="utf16", dst="utf8",
                             validate=validate, errors=errors,
                             interpret=interpret,
                             ascii_fastpath=ascii_fastpath)
