"""jit'd public wrappers around the Pallas kernels.

Each op pads the flat input to whole (ROWS, LANES) VMEM tiles (adding the
zero boundary tiles the kernels' prev/next BlockSpecs expect), invokes the
kernel, and strips the padding.  On this container kernels run with
``interpret=True`` (CPU execution of the kernel body); on a real TPU the
same code path compiles with ``interpret=False``.

The kernel-backed transcoders compose a Pallas compute stage (per-lane
classification + bit surgery + fused validation) with an XLA compaction
stage (cumsum + scatter) — the TPU-native split of the paper's
"decode-in-register, then pshufb-compress" structure.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import compaction
from repro.core import utf16 as u16mod
from repro.kernels import utf8_decode as kdec
from repro.kernels import utf8_validate as kval
from repro.kernels import utf16_encode as kenc

ROWS, LANES, BLOCK = kdec.ROWS, kdec.LANES, kdec.BLOCK


def _mask_padding(x, n_valid):
    x = x.astype(jnp.int32)
    if n_valid is None:
        return x, x.shape[0]
    idx = jnp.arange(x.shape[0])
    return jnp.where(idx < n_valid, x, 0), n_valid


def _tile(x, boundary_tiles: int):
    """Pad flat int32 x to whole BLOCK tiles + zero boundary tiles."""
    n = x.shape[0]
    nblk = max(1, -(-n // BLOCK))
    pad = nblk * BLOCK - n
    x = jnp.concatenate([x, jnp.zeros((pad,), jnp.int32)])
    x3 = x.reshape(nblk, ROWS, LANES)
    z = jnp.zeros((1, ROWS, LANES), jnp.int32)
    if boundary_tiles == 1:        # leading zero tile only (validate)
        return jnp.concatenate([z, x3], 0), nblk
    return jnp.concatenate([z, x3, z], 0), nblk  # both ends (decode/encode)


@functools.partial(jax.jit, static_argnames=("interpret",))
def validate_utf8(b, n_valid=None, interpret: bool = True):
    """Keiser-Lemire validation via the Pallas kernel.  Scalar bool."""
    b, n = _mask_padding(b, n_valid)
    b3, _ = _tile(b, boundary_tiles=1)
    errs = kval._call(b3, interpret=interpret)
    # Tail truncation (needs the logical length; checked outside the kernel).
    idx = jnp.arange(b.shape[0])
    tail_lead = (
        ((b >= 0xC0) & (idx >= n - 1))
        | ((b >= 0xE0) & (idx >= n - 2))
        | ((b >= 0xF0) & (idx >= n - 3))
    ) & (idx < n)
    return (jnp.max(errs) == 0) & ~jnp.any(tail_lead)


@functools.partial(jax.jit, static_argnames=("interpret",))
def decode_utf8(b, n_valid=None, interpret: bool = True):
    """Per-position speculative decode via the Pallas kernel.

    Returns (cp, lead, units, err) over the original buffer length.
    """
    b, n = _mask_padding(b, n_valid)
    cap = b.shape[0]
    b3, nblk = _tile(b, boundary_tiles=2)
    cp, lead, units, errs = kdec._call(b3, interpret=interpret)
    cp = cp.reshape(-1)[:cap]
    lead = lead.reshape(-1)[:cap]
    units = units.reshape(-1)[:cap]
    # A multi-byte lead truncated by the buffer end falls in the zero
    # boundary tile when n is tile-aligned — check the tail here.
    idx = jnp.arange(cap)
    tail_lead = (
        ((b >= 0xC0) & (idx >= n - 1))
        | ((b >= 0xE0) & (idx >= n - 2))
        | ((b >= 0xF0) & (idx >= n - 3))
    ) & (idx < n)
    return cp, lead, units, (jnp.max(errs) > 0) | jnp.any(tail_lead)


@functools.partial(jax.jit, static_argnames=("interpret", "validate"))
def utf8_to_utf16(b, n_valid=None, interpret: bool = True,
                  validate: bool = True):
    """Kernel-backed UTF-8 -> UTF-16 transcode.  (buffer, count, err)."""
    b, n = _mask_padding(b, n_valid)
    cap = b.shape[0]
    cp, lead, units, dec_err = decode_utf8(b, None, interpret=interpret)
    idx = jnp.arange(cap)
    mask = (lead > 0) & (idx < n)
    _, u0, u1, _bad = u16mod.encode_candidates(cp)
    vals = jnp.stack([u0, u1], -1)
    out, count = compaction.compact_offsets(vals, units, mask, cap)
    err = dec_err if validate else jnp.bool_(False)
    if validate:
        err = err | ~validate_utf8(b, n, interpret=interpret)
    return out, count, err


@functools.partial(jax.jit, static_argnames=("interpret", "validate"))
def utf16_to_utf8(u, n_valid=None, interpret: bool = True,
                  validate: bool = True):
    """Kernel-backed UTF-16 -> UTF-8 transcode.  (buffer, count, err)."""
    u, n = _mask_padding(u, n_valid)
    cap_in = u.shape[0]
    cap = 3 * cap_in
    u3, nblk = _tile(u, boundary_tiles=2)
    b0, b1, b2, b3, L, errs = kenc._call(u3, interpret=interpret)
    flat = lambda t: t.reshape(-1)[:cap_in]
    cand = jnp.stack([flat(b0), flat(b1), flat(b2), flat(b3)], -1)
    L = flat(L)
    idx = jnp.arange(cap_in)
    mask = (L > 0) & (idx < n)
    out, count = compaction.compact_offsets(cand, L, mask, cap)
    err = (jnp.max(errs) > 0) if validate else jnp.bool_(False)
    return out, count, err
