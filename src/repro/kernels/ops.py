"""jit'd public wrappers around the Pallas kernels.

Each op pads the flat input to whole (ROWS, LANES) VMEM tiles (adding the
zero boundary tiles the kernels' prev/next BlockSpecs expect), invokes the
kernel, and strips the padding.  Execution mode is auto-detected
(``repro.kernels.runtime``): kernels run interpreted on CPU hosts and
compiled on TPU; pass ``interpret=True/False`` to force either.

The kernel-backed transcoders compose a Pallas compute stage (per-lane
classification + bit surgery + fused validation) with an XLA compaction
stage (cumsum + scatter) — the TPU-native split of the paper's
"decode-in-register, then pshufb-compress" structure.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import compaction
from repro.core import utf16 as u16mod
from repro.kernels import runtime
from repro.kernels import utf8_decode as kdec
from repro.kernels import utf8_validate as kval
from repro.kernels import utf16_encode as kenc
from repro.kernels.fused_transcode import (  # noqa: F401  (re-export)
    utf8_to_utf16_fused, utf16_to_utf8_fused)

ROWS, LANES, BLOCK = kdec.ROWS, kdec.LANES, kdec.BLOCK


def _mask_padding(x, n_valid):
    x = x.astype(jnp.int32)
    if n_valid is None:
        return x, x.shape[0]
    idx = jnp.arange(x.shape[0])
    return jnp.where(idx < n_valid, x, 0), n_valid


def _tile(x, boundary_tiles: int):
    """Pad flat int32 x to whole BLOCK tiles + zero boundary tiles."""
    return runtime.tile_with_boundaries(x, ROWS, LANES, boundary_tiles)


def validate_utf8(b, n_valid=None, interpret=None):
    """Keiser-Lemire validation via the Pallas kernel.  Scalar bool."""
    return _validate_utf8_jit(b, n_valid, runtime.resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("interpret",))
def _validate_utf8_jit(b, n_valid, interpret):
    b, n = _mask_padding(b, n_valid)
    b3, _ = _tile(b, boundary_tiles=1)
    errs = kval._call(b3, interpret=interpret)
    # Tail truncation (needs the logical length; checked outside the kernel).
    return (jnp.max(errs) == 0) & ~kdec.tail_lead_err(b, n)


def decode_utf8(b, n_valid=None, interpret=None):
    """Per-position speculative decode via the Pallas kernel.

    Returns (cp, lead, units, err) over the original buffer length.
    """
    return _decode_utf8_jit(b, n_valid, runtime.resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("interpret",))
def _decode_utf8_jit(b, n_valid, interpret):
    b, n = _mask_padding(b, n_valid)
    cap = b.shape[0]
    b3, nblk = _tile(b, boundary_tiles=2)
    cp, lead, units, errs = kdec._call(b3, interpret=interpret)
    cp = cp.reshape(-1)[:cap]
    lead = lead.reshape(-1)[:cap]
    units = units.reshape(-1)[:cap]
    return cp, lead, units, (jnp.max(errs) > 0) | kdec.tail_lead_err(b, n)


def utf8_to_utf16(b, n_valid=None, interpret=None, validate: bool = True):
    """Kernel-backed UTF-8 -> UTF-16 transcode.  (buffer, count, err)."""
    return _utf8_to_utf16_jit(b, n_valid, runtime.resolve_interpret(interpret),
                              validate)


@functools.partial(jax.jit, static_argnames=("interpret", "validate"))
def _utf8_to_utf16_jit(b, n_valid, interpret, validate):
    b, n = _mask_padding(b, n_valid)
    cap = b.shape[0]
    cp, lead, units, dec_err = _decode_utf8_jit(b, None, interpret)
    idx = jnp.arange(cap)
    mask = (lead > 0) & (idx < n)
    _, u0, u1, _bad = u16mod.encode_candidates(cp)
    vals = jnp.stack([u0, u1], -1)
    out, count = compaction.compact_offsets(vals, units, mask, cap)
    err = dec_err if validate else jnp.bool_(False)
    if validate:
        err = err | ~_validate_utf8_jit(b, n, interpret)
    return out, count, err


def utf16_to_utf8(u, n_valid=None, interpret=None, validate: bool = True):
    """Kernel-backed UTF-16 -> UTF-8 transcode.  (buffer, count, err)."""
    return _utf16_to_utf8_jit(u, n_valid, runtime.resolve_interpret(interpret),
                              validate)


@functools.partial(jax.jit, static_argnames=("interpret", "validate"))
def _utf16_to_utf8_jit(u, n_valid, interpret, validate):
    u, n = _mask_padding(u, n_valid)
    cap_in = u.shape[0]
    cap = 3 * cap_in
    u3, nblk = _tile(u, boundary_tiles=2)
    b0, b1, b2, b3, L, errs = kenc._call(u3, interpret=interpret)
    flat = lambda t: t.reshape(-1)[:cap_in]
    cand = jnp.stack([flat(b0), flat(b1), flat(b2), flat(b3)], -1)
    L = flat(L)
    idx = jnp.arange(cap_in)
    mask = (L > 0) & (idx < n)
    out, count = compaction.compact_offsets(cand, L, mask, cap)
    err = (jnp.max(errs) > 0) if validate else jnp.bool_(False)
    return out, count, err
