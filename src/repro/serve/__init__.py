from repro.serve import kvcache, serve_step, engine  # noqa: F401
