"""Batched serving engine with transcode ingress/egress.

Requests arrive as raw UTF-8 (or UTF-16LE) byte strings.  The engine:

  1. **ingress** — validates + tokenizes the prompt bytes through
     ``repro.core`` (the paper's validation running at the API boundary,
     exactly its motivating deployment);
  2. batches admitted requests into fixed decode slots (padded prefill,
     per-row cursors), runs the jitted prefill + decode loop;
  3. **egress** — detokenizes to UTF-8 or UTF-16 through the vectorized
     encoder (``utf32_to_utf8`` / ``utf32_to_utf16``), so a Java/.NET
     client can request UTF-16 at no extra host cost.

Wave-based continuous batching: a wave admits up to ``max_batch``
requests; finished rows (EOS / max_new) are masked out and their slots
idle until the wave drains.  (True slot-level refill is a mechanical
extension — admission is already per-slot.)
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import transcode as tc
from repro.data.tokenizer import BOS_ID, EOS_ID, N_SPECIAL, ByteTokenizer
from repro.serve import kvcache, serve_step


@dataclasses.dataclass
class Request:
    prompt_bytes: bytes
    max_new: int = 32
    out_encoding: str = "utf-8"     # "utf-8" | "utf-16-le"


@dataclasses.dataclass
class Result:
    ok: bool
    text_bytes: bytes = b""
    error: str = ""


class Engine:
    def __init__(self, model, cfg, family: str, params, max_batch: int = 8,
                 max_prompt: int = 512, max_new: int = 128,
                 temperature: float = 0.0):
        self.model, self.cfg, self.family = model, cfg, family
        self.params = params
        self.max_batch, self.max_prompt, self.max_new = (
            max_batch, max_prompt, max_new)
        self.tok = ByteTokenizer()
        self._prefill = jax.jit(serve_step.make_prefill(model, family))
        self._decode = jax.jit(serve_step.make_decode(model, family,
                                                      temperature))
        self._ctx = max_prompt + max_new

    # ------------------------------------------------------------------
    def _ingress(self, req: Request):
        raw = np.frombuffer(req.prompt_bytes, np.uint8)
        if len(raw) == 0 or len(raw) > self.max_prompt - 1:
            return None, "empty or oversize prompt"
        ok = bool(tc.validate_utf8(jnp.asarray(raw.astype(np.int32)),
                                   len(raw)))
        if not ok:
            return None, "invalid UTF-8 prompt"
        ids = np.concatenate([[BOS_ID], raw.astype(np.int32) + N_SPECIAL])
        return ids, ""

    def _egress(self, token_ids: np.ndarray, encoding: str) -> bytes:
        byte_vals = token_ids - N_SPECIAL
        byte_vals = byte_vals[(byte_vals >= 0) & (byte_vals < 256)]
        b = jnp.asarray(byte_vals.astype(np.int32))
        if encoding == "utf-16-le":
            if len(byte_vals) == 0:
                return b""
            # Pinned to the eager pure-jnp strategy: egress buffers have a
            # new length per response, and the fused Pallas pipeline would
            # recompile per distinct shape.
            out, count, err = tc.transcode_utf8_to_utf16(
                b, len(byte_vals), strategy="blockparallel")
            units = np.asarray(out)[: int(count)].astype(np.uint16)
            return units.tobytes()
        return bytes(byte_vals.astype(np.uint8))

    # ------------------------------------------------------------------
    def serve(self, requests: List[Request]) -> List[Result]:
        results: List[Optional[Result]] = [None] * len(requests)
        wave: List[tuple] = []
        for i, r in enumerate(requests):
            ids, err = self._ingress(r)
            if ids is None:
                results[i] = Result(ok=False, error=err)
            else:
                wave.append((i, r, ids))

        for w0 in range(0, len(wave), self.max_batch):
            chunk = wave[w0: w0 + self.max_batch]
            self._run_wave(chunk, results)
        return results  # type: ignore[return-value]

    def _run_wave(self, chunk, results):
        b = len(chunk)
        if b == 0:
            return
        lens = np.array([len(ids) for _, _, ids in chunk], np.int32)
        s = int(lens.max())
        toks = np.zeros((b, s), np.int32)
        for j, (_, _, ids) in enumerate(chunk):
            toks[j, : len(ids)] = ids

        state = kvcache.init_state(self.model, self.cfg, b, self._ctx)
        last_logits, state = self._prefill(
            self.params, jnp.asarray(toks), jnp.asarray(lens), state)
        cur = jnp.argmax(last_logits, -1).astype(jnp.int32)

        pos = jnp.asarray(lens)
        out = np.full((b, self.max_new), -1, np.int64)
        done = np.zeros(b, bool)
        key = jax.random.PRNGKey(0)
        for t in range(self.max_new):
            out[:, t] = np.where(done, -1, np.asarray(cur))
            done |= np.asarray(cur) == EOS_ID
            if done.all():
                break
            key, sub = jax.random.split(key)
            cur, _, state = self._decode(
                self.params, cur[:, None], pos, state, sub)
            pos = pos + 1

        for j, (i, req, ids) in enumerate(chunk):
            gen = out[j]
            gen = gen[(gen >= 0) & (gen != EOS_ID)]
            results[i] = Result(
                ok=True, text_bytes=self._egress(gen, req.out_encoding))
