"""Continuously-batched serving engine with transcode ingress/egress.

Requests arrive as raw UTF-8, UTF-16LE, UTF-32LE or Latin-1 byte strings
(the full codec matrix, DESIGN.md §8) through a submit/poll surface:

  * :meth:`Engine.submit` — cheap host-side field validation, bounded
    admission (overload shed beyond ``queue_limit``), then the request is
    enqueued into a **length-bucketed** admission queue (tensor2tensor
    ``bucket_by_sequence_length``-style multiplicative boundaries,
    :func:`repro.core.packing.bucket_boundaries`) keyed by
    ``(encoding, errors)`` group.  Returns an int ticket.
  * :meth:`Engine.drain` — the slot-level decode loop.  Each of
    ``max_batch`` decode slots is refilled **the moment it frees** (EOS /
    token budget), mid-wave, from the queue whose head ticket is oldest:
    continuous batching, not wave batching.  A refilled slot inherits
    NOTHING from its predecessor — its KV-cache row is replaced
    wholesale by the freshly prefilled row, and deadlines, retry
    counters, poison isolation and typed :class:`ResultCode` outcomes
    all hold per-slot.
  * :meth:`Engine.poll` — settled :class:`Result` by ticket (or ``None``
    while queued / in flight).

The old ``Engine.serve(list) -> list`` survives as a thin synchronous
shim (submit all, drain, poll each) — continuous batching is not
expressible through a batch-in/batch-out call.

**Ingress** stays packed multi-request (the paper's validation running
at the API boundary): each refill takes up to ``max_batch`` same-bucket
prompts and runs ONE ragged launch — a counting scan
(fused validation + per-document error location) for UTF-8, a ragged
transcode to UTF-8 through the matrix cell for unit encodings — padded
to the bucket's geometry, so there is **one compilation per (bucket,
errors-policy) cell**, held in an LRU-bounded compile cache (the
``_BATCH_CACHE`` pattern of ``repro.data.pipeline``).  Prefill likewise
pads to the bucket bound, one compiled cell per bucket instead of one
per distinct prompt length.  The deadline/retry/shed/fallback machinery
rides the slot loop: transient launch failures retry with backoff, a
persistently failing group degrades per-document to the host ``codecs``
path, expired deadlines free their queue position with a typed
rejection, and egress failures poison only their own slot.

**Egress** detokenizes to any matrix format (UTF-8 / UTF-16LE /
UTF-32LE / Latin-1) through the vectorized encoders.

A per-ingress-group **circuit breaker** (:class:`_Breaker`) sits above
the retry ladder: ``breaker_threshold`` consecutive chunk-launch
failures open the group, open chunks route launch-free to the host
fallback, and after ``breaker_cooldown_s`` a half-open probe (one
launch, real traffic, no retries) decides between closing the breaker
and another cooldown — a persistently-down device path costs one probe
per cooldown instead of a retry+backoff storm per chunk.

Scheduling observability: ``Engine.events`` records the slot lifecycle
of the most recent :meth:`drain` as ``(kind, ticket, slot, step, wall)``
tuples (``kind`` in ``"admit"`` / ``"finish"`` / ``"reject"``, ``step``
the global decode-step counter) — the continuous-vs-wave benchmark and
the mid-wave-refill test both read it.  Breaker transitions append
``("breaker_open" | "breaker_half_open" | "breaker_closed", group,
-1, step, wall)`` to the same log (cleared per drain, so transition
assertions must read the drain that caused them).  ``Engine.latencies`` maps
recently settled tickets to their submit→settle wall time.  Both are
**bounded** — ``events`` is a ring buffer (``event_limit`` newest
entries) and ``latencies`` an insertion-ordered window (``latency_window``
newest settles, oldest evicted like the compile-cache LRU) — so a
long-running engine's memory footprint is flat no matter how many
tickets it serves.  Rolling nearest-rank percentiles over the latency
window are published as ``counters["latency_p50_ms"]`` /
``counters["latency_p99_ms"]`` on every settle.
"""

from __future__ import annotations

import bisect
import collections
import dataclasses
import enum
import time
from typing import Dict, List, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import packing
from repro.core import transcode as tc
from repro.data.tokenizer import BOS_ID, EOS_ID, N_SPECIAL, ByteTokenizer
from repro.serve import kvcache, serve_step
from repro.testing import faults


class ResultCode(str, enum.Enum):
    """Typed result codes (``Result.code``; failure-mode table in
    DESIGN.md §10).  ``ok`` stays the boolean verdict; the code names WHY
    a request did not serve — load-shedding and deadline misses are not
    the same failure as an invalid prompt, and callers (and the chaos
    suite) need to tell them apart without parsing message strings.

    String-valued for backward compatibility: every member compares equal
    to (and serializes as) the bare string literal it replaced, so
    ``result.code == "rejected_overload"`` keeps working.
    """

    OK = "ok"
    REJECTED_INVALID = "rejected_invalid"     # bad prompt/field (permanent)
    REJECTED_OVERLOAD = "rejected_overload"   # admission queue full (shed)
    REJECTED_DEADLINE = "rejected_deadline"   # per-request deadline expired
    FAILED_TRANSCODE = "failed_transcode"     # device path down, no fallback

    __str__ = str.__str__    # render the wire value, not the member name


# Backward-compatible module aliases (``eng.OK`` etc. predate the enum).
OK = ResultCode.OK
REJECTED_INVALID = ResultCode.REJECTED_INVALID
REJECTED_OVERLOAD = ResultCode.REJECTED_OVERLOAD
REJECTED_DEADLINE = ResultCode.REJECTED_DEADLINE
FAILED_TRANSCODE = ResultCode.FAILED_TRANSCODE


@dataclasses.dataclass
class Request:
    prompt_bytes: bytes
    # Per-request generation budget, clamped to the engine's ``max_new``.
    max_new: int = 32
    # "utf-8" | "utf-16-le" | "utf-32-le" | "latin-1" (full codec matrix)
    out_encoding: str = "utf-8"
    in_encoding: str = "utf-8"
    errors: str = "strict"          # "strict" | "replace"
    # Per-request deadline, in seconds from ``submit()`` (None = no
    # deadline).  A request whose deadline expires before its slot
    # admission is rejected with ``REJECTED_DEADLINE`` instead of holding
    # a slot — late answers are dropped work, not service.
    deadline_s: Optional[float] = None


@dataclasses.dataclass
class Result:
    ok: bool
    text_bytes: bytes = b""
    error: str = ""
    # Offset of the first invalid element in the prompt (bytes for utf-8,
    # code units for utf-16-le; Python ``UnicodeDecodeError.start``
    # semantics), -1 when the prompt was well-formed.  Populated for
    # strict rejections AND for replace-mode substitutions.
    error_offset: int = -1
    # Under errors="replace": the prompt actually served, as UTF-8, with
    # U+FFFD substituted per maximal subpart (empty otherwise).
    sanitized_prompt: bytes = b""
    # Typed outcome: OK for served requests, else which failure mode
    # rejected the request.
    code: ResultCode = ResultCode.OK


@dataclasses.dataclass
class _Slot:
    """One live decode slot (private): the request it serves, its prompt
    provenance, and the tokens generated so far."""

    ticket: int
    req: Request
    error_offset: int
    sanitized: bytes
    budget: int
    tokens: List[int] = dataclasses.field(default_factory=list)


class _Breaker:
    """Per-ingress-group circuit breaker (closed / open / half-open).

    The retry+backoff ladder is the right answer to a TRANSIENT launch
    failure; against a persistently-down device path it becomes a
    storm — every chunk pays ``max_retries`` launches plus backoff
    sleeps before falling back.  The breaker remembers: after
    ``threshold`` consecutive chunk-level failures the group goes
    **open** and chunks route straight to the host ``codecs`` fallback
    with **zero** device launches.  After ``cooldown_s`` on the
    injectable clock the next chunk is a **half-open probe**: ONE
    launch, no retries, carrying that chunk's real traffic — success
    closes the breaker (full service resumes), failure re-opens it for
    another cooldown.  Any full-path success resets the failure count.
    """

    __slots__ = ("threshold", "cooldown_s", "_clock", "state",
                 "failures", "opened_at")

    def __init__(self, threshold: int, cooldown_s: float, clock):
        self.threshold = threshold
        self.cooldown_s = cooldown_s
        self._clock = clock
        self.state = "closed"
        self.failures = 0
        self.opened_at: Optional[float] = None

    def route(self) -> str:
        """How the next chunk launch should run: ``"full"`` (closed —
        retry+backoff), ``"probe"`` (half-open — one launch, no
        retries) or ``"skip"`` (open — host fallback, no launch).
        Moves open -> half_open when the cooldown has elapsed."""
        if self.state == "open":
            if self._clock() - self.opened_at >= self.cooldown_s:
                self.state = "half_open"
                return "probe"
            return "skip"
        if self.state == "half_open":
            return "probe"
        return "full"

    def record(self, ok: bool) -> Optional[str]:
        """Record a routed launch outcome; returns the new state name
        when this outcome caused a transition, else ``None``."""
        if ok:
            self.failures = 0
            if self.state != "closed":
                self.state = "closed"
                self.opened_at = None
                return "closed"
            return None
        self.failures += 1
        if self.state == "half_open" or self.failures >= self.threshold:
            self.state = "open"
            self.opened_at = self._clock()
            return "open"
        return None


class Engine:
    def __init__(self, model, cfg, family: str, params, max_batch: int = 8,
                 max_prompt: int = 512, max_new: int = 128,
                 temperature: float = 0.0, queue_limit: Optional[int] = None,
                 max_retries: int = 2, backoff_base_s: float = 0.05,
                 clock=time.monotonic, sleep=time.sleep,
                 scheduler: str = "continuous",
                 bucket_min: int = 8, bucket_step: float = 1.5,
                 compile_cache_size: int = 32,
                 latency_window: int = 1024, event_limit: int = 4096,
                 ingress_shards: int = 1,
                 breaker_threshold: int = 3,
                 breaker_cooldown_s: float = 30.0):
        if scheduler not in ("continuous", "wave"):
            raise ValueError(
                f"scheduler must be 'continuous' or 'wave', got {scheduler!r}")
        if ingress_shards < 1:
            raise ValueError(
                f"ingress_shards must be >= 1, got {ingress_shards}")
        self.model, self.cfg, self.family = model, cfg, family
        self.params = params
        self.max_batch, self.max_prompt, self.max_new = (
            max_batch, max_prompt, max_new)
        # Admission bound: at most this many requests queued; the tail is
        # shed with REJECTED_OVERLOAD instead of growing an unbounded
        # work list (DESIGN.md §10).
        self.queue_limit = (4 * max_batch if queue_limit is None
                            else queue_limit)
        # Transient-failure policy: a failed transcode launch is retried
        # ``max_retries`` times with exponential backoff (base doubles
        # per attempt) before the group degrades to the host fallback.
        self.max_retries = max_retries
        self.backoff_base_s = backoff_base_s
        # Injectable for deterministic chaos tests — production uses the
        # monotonic clock and real sleep.
        self._clock, self._sleep = clock, sleep
        # Circuit breakers, one per ingress group, created lazily on
        # first use (see _Breaker): ``breaker_threshold`` consecutive
        # chunk failures open a group; ``breaker_cooldown_s`` (on the
        # injectable clock) gates the half-open probe.
        if breaker_threshold < 1:
            raise ValueError(
                f"breaker_threshold must be >= 1, got {breaker_threshold}")
        self.breaker_threshold = breaker_threshold
        self.breaker_cooldown_s = breaker_cooldown_s
        self._breakers: Dict[str, _Breaker] = {}
        # "continuous": a freed slot refills immediately, mid-wave.
        # "wave": refill only once ALL slots drain — the wave-batching
        # reference the table_serve benchmark compares against.
        self.scheduler = scheduler
        # Observability: how often the robustness paths actually fired.
        #   retries   — transient launch failures retried
        #   fallback  — prompts served via the host ``codecs`` path
        #   shed      — requests rejected at admission (overload)
        #   deadline  — requests expired before their slot admission
        #   breaker_open / breaker_half_open / breaker_closed — breaker
        #               state TRANSITIONS (not states); breaker_skip
        #               counts chunks routed to fallback launch-free
        #               while open, breaker_probe the half-open probes.
        self.counters = collections.Counter()
        # Length-bucket upper bounds (inclusive), shared by the admission
        # queues, the ingress pack geometry and the prefill padding.
        self._bounds = packing.bucket_boundaries(
            max_prompt, min_length=bucket_min, step=bucket_step)
        # Admission queues: (group, bucket_bound) -> deque of
        # (ticket, request, units).  ``group`` is "utf-8" or the
        # (encoding, errors) pair — the unit that shares one ragged
        # ingress launch.
        self._queues: Dict[tuple, collections.deque] = {}
        self._pending = 0
        self._next_ticket = 0
        self._results: Dict[int, Result] = {}
        self._submit_t: Dict[int, float] = {}
        self._deadlines: Dict[int, float] = {}
        # Settled-ticket latency (submit -> settle, seconds) and the slot
        # lifecycle of the most recent drain() (see module docstring).
        # Both are bounded: a long-running engine settles unboundedly many
        # tickets, so `latencies` keeps only the newest `latency_window`
        # entries (insertion-ordered eviction, like the compile cache) and
        # `events` is a ring buffer of the newest `event_limit` tuples.
        self.latencies: "collections.OrderedDict[int, float]" = \
            collections.OrderedDict()
        self._latency_window = latency_window
        # Sorted view of the latency window for O(1) rolling percentiles.
        self._lat_sorted: List[float] = []
        self.events: collections.deque = collections.deque(
            maxlen=event_limit)
        self._step = 0
        # LRU-bounded compile cache, one jitted cell per (kind, bucket,
        # errors-policy) — the ``_BATCH_CACHE`` pattern: hit refreshes
        # recency, insert beyond capacity evicts the coldest executable.
        self._cells: "collections.OrderedDict[tuple, object]" = \
            collections.OrderedDict()
        self._cell_limit = compile_cache_size
        self.tok = ByteTokenizer()
        self._decode = jax.jit(serve_step.make_decode(model, family,
                                                      temperature))
        self._ctx = max_prompt + max_new
        # Sharded ingress (DESIGN.md §12): with ingress_shards > 1 a
        # drain wave's ragged ingress launches fan out across a 1-D
        # data mesh — one onepass launch per shard — instead of one
        # single-device launch per chunk.  The shard_map executables are
        # cached inside repro.core.shard, not in self._cells.
        self.ingress_shards = ingress_shards
        self._ingress_mesh = None
        if ingress_shards > 1:
            from repro.launch import mesh as launch_mesh
            self._ingress_mesh = launch_mesh.make_transcode_mesh(
                ingress_shards)

    # ------------------------------------------------------------------
    # Compile cache.

    def _cell(self, key, build):
        """Jitted cell for ``key``, LRU-refreshed; built (and compiled on
        first call) at most once while it stays resident."""
        if key in self._cells:
            self._cells[key] = self._cells.pop(key)
            return self._cells[key]
        fn = build()
        self._cells[key] = fn
        while len(self._cells) > self._cell_limit:
            self._cells.popitem(last=False)
        return fn

    def _launch_with_retry(self, fn):
        """Run a transcode-launch thunk, retrying transient failures with
        exponential backoff; the final failure propagates to the caller
        (which degrades to the host fallback)."""
        delay = self.backoff_base_s
        for attempt in range(self.max_retries + 1):
            try:
                return fn()
            except Exception:
                if attempt == self.max_retries:
                    raise
                self.counters["retries"] += 1
                self._sleep(delay)
                delay *= 2

    # ------------------------------------------------------------------
    # Circuit breaker (one per ingress group; DESIGN.md §10).

    @staticmethod
    def _group_name(group) -> str:
        """Stable string key/event label for an ingress group ("utf-8"
        or an (encoding, errors) pair)."""
        return group if isinstance(group, str) else ":".join(group)

    def _breaker_route(self, group):
        """The group's breaker and its routing verdict for the next
        chunk launch ("full" / "probe" / "skip"); emits the open ->
        half_open transition and counts launch-free skips."""
        name = self._group_name(group)
        br = self._breakers.get(name)
        if br is None:
            br = self._breakers[name] = _Breaker(
                self.breaker_threshold, self.breaker_cooldown_s,
                self._clock)
            return br, "full"
        before = br.state
        mode = br.route()
        if br.state != before:          # open -> half_open (cooldown up)
            self._breaker_event(name, br.state)
        if mode == "skip":
            self.counters["breaker_skip"] += 1
        return br, mode

    def _breaker_record(self, group, br: _Breaker, ok: bool):
        transition = br.record(ok)
        if transition is not None:
            self._breaker_event(self._group_name(group), transition)

    def _breaker_event(self, name: str, state: str):
        self.counters[f"breaker_{state}"] += 1
        self.events.append((f"breaker_{state}", name, -1, self._step,
                            self._clock()))

    def _probe_launch(self, fn):
        """Half-open probe: exactly ONE launch, no retry, no backoff —
        the probe either closes the breaker or re-opens it.  It carries
        the chunk's real traffic, so a success IS served work."""
        self.counters["breaker_probe"] += 1
        faults.fire(faults.ENGINE_PROBE)
        return fn()

    # ------------------------------------------------------------------
    # Admission (submit / poll / drain / serve).

    # Unit widths and packed dtypes per non-UTF-8 ingress encoding; the
    # wire bytes split into units with an EXPLICIT little-endian dtype
    # ('<u2'/'<u4', host-endianness-independent — unlike a native-order
    # ``.view(np.uint16)``, whose meaning flips on a big-endian host).
    # The jnp byte-math twins (``tc.utf16le_bytes_to_units`` /
    # ``tc.utf32le_bytes_to_cps``) serve device-resident buffers; this
    # is the host-side pre-pack path, where a device round trip per
    # prompt would be pure overhead.
    _UNIT_INGRESS = {
        "utf-16-le": (2, np.uint16, "utf16", "unit"),
        "utf-32-le": (4, np.uint32, "utf32", "code point"),
        "latin-1": (1, np.uint8, "latin1", "byte"),
    }

    @staticmethod
    def _wire_units(raw: np.ndarray, width: int, np_dtype) -> np.ndarray:
        if width == 1:
            return raw.astype(np_dtype)
        le = np.frombuffer(raw.tobytes(), np.dtype(f"<u{width}"))
        return le.astype(np_dtype)

    def _bound(self, n: int) -> int:
        """Bucket upper bound for a sequence of ``n`` elements."""
        return self._bounds[min(bisect.bisect_left(self._bounds, n),
                                len(self._bounds) - 1)]

    def _settle(self, ticket: int, result: Result):
        self._results[ticket] = result
        self._deadlines.pop(ticket, None)
        t0 = self._submit_t.pop(ticket, None)
        if t0 is not None:
            lat = self._clock() - t0
            # Self-heal the sorted view if a consumer cleared/mutated the
            # public window externally (the serve benchmark does).
            if len(self._lat_sorted) != len(self.latencies):
                self._lat_sorted = sorted(self.latencies.values())
            self.latencies[ticket] = lat
            bisect.insort(self._lat_sorted, lat)
            while len(self.latencies) > self._latency_window:
                _t, old = self.latencies.popitem(last=False)
                del self._lat_sorted[bisect.bisect_left(self._lat_sorted,
                                                        old)]
            # Rolling nearest-rank percentiles over the bounded window.
            s = self._lat_sorted
            self.counters["latency_p50_ms"] = s[(len(s) - 1) // 2] * 1e3
            self.counters["latency_p99_ms"] = \
                s[(len(s) - 1) * 99 // 100] * 1e3

    def submit(self, request: Request) -> int:
        """Admit one request; returns its ticket (an int).

        Host-side field validation and overload shedding happen here,
        synchronously — a rejected request settles immediately and its
        result is already pollable.  Valid requests enter the
        length-bucketed admission queue and settle during :meth:`drain`.
        """
        ticket = self._next_ticket
        self._next_ticket += 1
        now = self._clock()
        self._submit_t[ticket] = now
        if request.deadline_s is not None:
            self._deadlines[ticket] = now + request.deadline_s

        def reject(error: str) -> int:
            self._settle(ticket, Result(ok=False, code=REJECTED_INVALID,
                                        error=error))
            return ticket

        if request.errors not in ("strict", "replace"):
            return reject(f"unknown errors policy: {request.errors}")
        raw = np.frombuffer(request.prompt_bytes, np.uint8)
        if request.in_encoding in self._UNIT_INGRESS:
            width, np_dtype, _src, _noun = \
                self._UNIT_INGRESS[request.in_encoding]
            if len(raw) % width:
                return reject(
                    f"odd {request.in_encoding} prompt byte length"
                    if width == 2 else
                    f"{request.in_encoding} prompt byte length not a "
                    f"multiple of {width}")
            units = self._wire_units(raw, width, np_dtype)
            if len(units) == 0 or len(units) > self.max_prompt:
                return reject("empty or oversize prompt")
            group = (request.in_encoding, request.errors)
        elif request.in_encoding == "utf-8":
            if len(raw) == 0 or len(raw) > self.max_prompt - 1:
                return reject("empty or oversize prompt")
            units, group = raw, "utf-8"
        else:
            return reject(f"unknown in_encoding: {request.in_encoding}")

        if self._pending >= self.queue_limit:
            self.counters["shed"] += 1
            self._settle(ticket, Result(
                ok=False, code=REJECTED_OVERLOAD,
                error=(f"admission queue full ({self.queue_limit} slots); "
                       f"request shed")))
            return ticket
        qkey = (group, self._bound(len(units)))
        self._queues.setdefault(qkey, collections.deque()).append(
            (ticket, request, units))
        self._pending += 1
        return ticket

    def poll(self, ticket: int) -> Optional[Result]:
        """Settled :class:`Result` for ``ticket`` (removing it), or
        ``None`` while the request is still queued / in flight."""
        return self._results.pop(ticket, None)

    def serve(self, requests: List[Request]) -> List[Result]:
        """Synchronous shim over submit/drain/poll (the legacy batch
        API): every request settles before this returns, in order."""
        tickets = [self.submit(r) for r in requests]
        self.drain()
        return [self.poll(t) for t in tickets]  # type: ignore[misc]

    # ------------------------------------------------------------------
    # The slot-level decode loop.

    def drain(self) -> None:
        """Run the continuous-batching loop until every queued request
        settles.  Resets :attr:`events` and the step counter."""
        B = self.max_batch
        self.events.clear()
        self._step = 0
        if not self._pending:
            return
        state = kvcache.init_state(self.model, self.cfg, B, self._ctx)
        slots: List[Optional[_Slot]] = [None] * B
        cur = np.zeros(B, np.int32)
        pos = np.zeros(B, np.int32)
        key = jax.random.PRNGKey(0)
        while self._pending or any(s is not None for s in slots):
            free = [j for j in range(B) if slots[j] is None]
            # Refill round: continuous mode refills any free slot the
            # moment one exists; wave mode only once the whole wave
            # drained.  Either way the round fills greedily.
            if free and self._pending and (self.scheduler == "continuous"
                                           or len(free) == B):
                while free and self._pending:
                    state = self._refill_once(free, slots, state, cur, pos)
            live = [j for j in range(B) if slots[j] is not None]
            if not live:
                continue
            # One decode step for the whole batch; free slots carry
            # garbage rows that the next refill replaces wholesale.
            self._step += 1
            key, sub = jax.random.split(key)
            nxt, _, state = self._decode(
                self.params, jnp.asarray(cur)[:, None], jnp.asarray(pos),
                state, sub)
            nxt = np.asarray(nxt)
            for j in live:
                pos[j] += 1
                cur[j] = nxt[j]
                self._push_token(slots, j, int(nxt[j]))

    def _refill_once(self, free, slots, state, cur, pos):
        """Admit up to ``len(free)`` requests from ONE (group, bucket)
        queue — one ragged ingress launch, one (or few) bucket-padded
        prefills — and scatter the prefilled rows into the free slots.
        Returns the updated batch state; ``free``/``slots``/``cur``/
        ``pos`` are updated in place."""
        ready = [k for k, q in self._queues.items() if q]
        if not ready:
            self._pending = 0      # defensive: counter out of sync
            return state
        # FIFO fairness across cells: serve the oldest head ticket.
        qkey = min(ready, key=lambda k: self._queues[k][0][0])
        group, bound = qkey
        q = self._queues[qkey]
        take = []
        while q and len(take) < len(free):
            ticket, req, units = q.popleft()
            self._pending -= 1
            if self._expired(ticket, req):
                continue
            take.append((ticket, req, units))
        if not q:
            del self._queues[qkey]
        if not take:
            return state
        admitted = self._ingress_chunk(group, bound, take)
        # Deadline re-check: ingress (retries, host fallback) can be the
        # slow path; an entry that expired during it must not take a slot.
        admitted = [e for e in admitted if not self._expired(e[0], e[1])]
        if not admitted:
            return state
        # Group by prefill bucket of the ACTUAL token length (replace-
        # sanitization and unit->UTF-8 expansion can cross input-bucket
        # bounds), prefill each group padded to its bound, and merge the
        # prefilled rows into the free slots.
        by_bucket: Dict[int, list] = {}
        for entry in admitted:
            by_bucket.setdefault(self._bound(len(entry[2])), []).append(entry)
        for pb in sorted(by_bucket):
            grp = by_bucket[pb]
            toks = np.zeros((self.max_batch, pb), np.int32)
            toks[:, 0] = BOS_ID          # dummy rows: one BOS token
            lens = np.ones(self.max_batch, np.int32)
            for r, (_t, _req, ids, _off, _san) in enumerate(grp):
                toks[r, : len(ids)] = ids
                lens[r] = len(ids)
            last_logits, pstate = self._prefill_call(toks, lens)
            first = np.asarray(jnp.argmax(last_logits, -1)).astype(np.int32)
            slot_idx = [free.pop(0) for _ in grp]
            state = self._merge_rows(state, pstate, slot_idx)
            wall = self._clock()
            for r, (ticket, req, ids, off, sanitized) in enumerate(grp):
                j = slot_idx[r]
                slots[j] = _Slot(ticket=ticket, req=req, error_offset=off,
                                 sanitized=sanitized,
                                 budget=max(1, min(req.max_new,
                                                   self.max_new)))
                cur[j] = first[r]
                pos[j] = lens[r]
                self.events.append(("admit", ticket, j, self._step, wall))
                # The prefill's argmax is the first generated token; a
                # 1-token budget (or an immediate EOS) finishes here,
                # before any decode step.
                self._push_token(slots, j, int(first[r]))
        return state

    def _expired(self, ticket: int, req: Request) -> bool:
        dl = self._deadlines.get(ticket)
        if dl is None or self._clock() < dl:
            return False
        self.counters["deadline"] += 1
        self._settle(ticket, Result(
            ok=False, code=REJECTED_DEADLINE,
            error=f"deadline of {req.deadline_s:g}s expired before decode"))
        self.events.append(("reject", ticket, -1, self._step, self._clock()))
        return True

    def _prefill_call(self, toks: np.ndarray, lens: np.ndarray):
        """Bucket-padded prefill into a FRESH full-batch scratch state
        (one compiled cell per bucket bound — the geometry is always
        ``(max_batch, bound)``)."""
        fn = self._cell(
            ("prefill", toks.shape[1]),
            lambda: jax.jit(serve_step.make_prefill(self.model, self.family)))
        scratch = kvcache.init_state(self.model, self.cfg, self.max_batch,
                                     self._ctx)
        return fn(self.params, jnp.asarray(toks), jnp.asarray(lens), scratch)

    def _merge_rows(self, state, pstate, slot_idx):
        """Scatter prefilled rows ``0..k-1`` of ``pstate`` into batch
        rows ``slot_idx`` of the live state.  Every state leaf carries
        the batch on axis 1 (``(stack, batch, ...)``), and rows are
        independent (per-row cursors/positions), so full-row replacement
        is exact — the refilled slot inherits nothing."""
        k = len(slot_idx)
        # Jitted per row count: the eager per-leaf ``.at[].set`` dispatch
        # costs more than a prefill, and refills are the continuous
        # scheduler's hot path.
        fn = self._cell(("merge", k), lambda: jax.jit(
            lambda big, small, sl: jax.tree.map(
                lambda b, s: b.at[:, sl].set(s[:, :k]), big, small)))
        return fn(state, pstate, jnp.asarray(np.asarray(slot_idx, np.int32)))

    def _push_token(self, slots, j: int, token: int):
        """Record one generated token for slot ``j``; finish the slot on
        EOS or budget exhaustion (egress + settle + free)."""
        s = slots[j]
        s.tokens.append(token)
        if token == EOS_ID or len(s.tokens) >= s.budget:
            self._finish_slot(slots, j)

    def _finish_slot(self, slots, j: int):
        s = slots[j]
        gen = np.asarray(s.tokens, np.int64)
        gen = gen[(gen >= 0) & (gen != EOS_ID)]
        # Per-slot poison isolation on egress: one request with a bad
        # out_encoding (or an egress-transcode failure) must not throw
        # away its batch-mates' finished generations.
        try:
            wire = self._egress(gen, s.req.out_encoding)
        except Exception as e:
            self._settle(s.ticket, Result(
                ok=False, code=FAILED_TRANSCODE,
                error=f"egress transcode failed: {e}",
                error_offset=s.error_offset, sanitized_prompt=s.sanitized))
        else:
            self._settle(s.ticket, Result(
                ok=True, text_bytes=wire,
                error_offset=s.error_offset, sanitized_prompt=s.sanitized))
        self.events.append(("finish", s.ticket, j, self._step,
                            self._clock()))
        slots[j] = None

    # ------------------------------------------------------------------
    # Packed chunk ingress (one ragged launch per refill chunk).

    def _ingress_chunk(self, group, bound: int, take):
        """Validate/transcode one same-bucket chunk of ``(ticket, req,
        units)``; rejections settle here, admitted entries return as
        ``(ticket, req, ids, error_offset, sanitized)``."""
        if group == "utf-8":
            return self._ingress_utf8_chunk(bound, take)
        encoding, policy = group
        return self._ingress_unit_chunk(encoding, policy, bound, take)

    def _doc_tiles(self, bound: int) -> int:
        """Tiles per packed ingress slot for a bucket bound."""
        return max(1, -(-bound // packing.TILE))

    def _ingress_utf8_chunk(self, bound: int, take):
        """ONE ragged counting-scan launch for the chunk: fused
        validation + per-document error location, no write pass — clean
        prompts (the common case) pay one packed read per chunk instead
        of one kernel dispatch per request."""
        dt = self._doc_tiles(bound)
        if self._ingress_mesh is not None:
            # Sharded fan-out: the wave's packed chunk splits across the
            # ingress mesh, one counting launch per shard (the shard_map
            # executable caches inside repro.core.shard).
            from repro.core import shard as shard_mod

            def _scan():
                faults.fire(faults.KERNEL_RAGGED_SCAN)
                pk = packing.pack_documents(
                    [u for _, _, u in take], dtype=np.uint8, doc_tiles=dt,
                    pad_to_docs=self.max_batch)
                return shard_mod.scan_ragged_sharded(
                    pk.data, pk.offsets, pk.lengths, src_format="utf8",
                    dst_format="utf16", mesh=self._ingress_mesh)
        else:
            cell = self._cell(
                ("scan_utf8", dt),
                lambda: jax.jit(lambda d, o, l: tc.ragged_scan(
                    d, o, l, src_format="utf8", dst_format="utf16")))

            def _scan():
                # The chaos hook fires HERE, per call: the jitted cell
                # body below only reaches the kernel wrapper's own hook
                # while tracing, and cached executables skip it entirely.
                faults.fire(faults.KERNEL_RAGGED_SCAN)
                pk = packing.pack_documents(
                    [u for _, _, u in take], dtype=np.uint8, doc_tiles=dt,
                    pad_to_docs=self.max_batch)
                return cell(pk.data, pk.offsets, pk.lengths)

        br, mode = self._breaker_route("utf-8")
        if mode == "skip":
            # Breaker open: the device path is known-down, so the chunk
            # routes straight to the host fallback — no launch, no
            # retry storm.
            return self._host_fallback_utf8(take)
        try:
            _counts, statuses = (self._probe_launch(_scan)
                                 if mode == "probe"
                                 else self._launch_with_retry(_scan))
        except Exception:
            # Device path down for this chunk after retries (or the
            # half-open probe failed): feed the breaker and degrade
            # per-document to the host ``codecs`` path so clean prompts
            # still serve and poison ones get typed errors.
            self._breaker_record("utf-8", br, ok=False)
            return self._host_fallback_utf8(take)
        self._breaker_record("utf-8", br, ok=True)
        statuses = np.asarray(statuses)
        admitted = []
        for k, (ticket, req, raw) in enumerate(take):
            off = int(statuses[k])
            if off < 0:
                ids = np.concatenate(
                    [[BOS_ID], raw.astype(np.int32) + N_SPECIAL])
                admitted.append((ticket, req, ids, -1, b""))
            elif req.errors != "replace":
                self._settle(ticket, Result(
                    ok=False, code=REJECTED_INVALID,
                    error=f"invalid UTF-8 prompt at byte {off}",
                    error_offset=off))
                self.events.append(("reject", ticket, -1, self._step,
                                    self._clock()))
            else:
                entry = self._sanitize_utf8(ticket, req, raw, off)
                if isinstance(entry, Result):
                    self._settle(ticket, entry)
                    self.events.append(("reject", ticket, -1, self._step,
                                        self._clock()))
                else:
                    admitted.append(entry)
        return admitted

    def _host_fallback_utf8(self, take):
        """Graceful degradation: validate/sanitize each UTF-8 prompt with
        CPython's codec machinery (bit-compatible semantics — the device
        kernels are pinned against it by the differential fuzz).  Slow
        path, but one flaky launch must not fail a whole packed chunk."""
        admitted = []
        for ticket, req, raw in take:
            self.counters["fallback"] += 1
            data = raw.tobytes()
            try:
                data.decode("utf-8")
                off = -1
            except UnicodeDecodeError as e:
                off = e.start
            if off < 0:
                ids = np.concatenate(
                    [[BOS_ID], raw.astype(np.int32) + N_SPECIAL])
                admitted.append((ticket, req, ids, -1, b""))
            elif req.errors != "replace":
                self._settle(ticket, Result(
                    ok=False, code=REJECTED_INVALID,
                    error=f"invalid UTF-8 prompt at byte {off}",
                    error_offset=off))
            else:
                clean = np.frombuffer(
                    data.decode("utf-8", "replace").encode("utf-8"),
                    np.uint8)
                if len(clean) == 0 or len(clean) > self.max_prompt - 1:
                    self._settle(ticket, Result(
                        ok=False, code=REJECTED_INVALID,
                        error="empty or oversize prompt after replacement",
                        error_offset=off))
                else:
                    ids = np.concatenate(
                        [[BOS_ID], clean.astype(np.int32) + N_SPECIAL])
                    admitted.append((ticket, req, ids, off, bytes(clean)))
        return admitted

    def _sanitize_utf8(self, ticket, req, raw, off):
        """Dirty prompt under replace: sanitize via a single-pass
        replace-transcode to UTF-16 (the default strategy), then encode
        the now-valid units back to UTF-8 for the byte tokenizer (dirty
        prompts are the rare case, so this stays per-request)."""
        buf = np.zeros(self.max_prompt, np.uint8)
        buf[: len(raw)] = raw

        def _device():
            u16, cu, _status = tc.transcode(
                jnp.asarray(buf), "utf16", src_format="utf8",
                n_valid=len(raw), errors="replace")
            # The units are valid by construction — skip the
            # re-validation scan on the way back to bytes.
            b8, cb, _ = tc.transcode(u16, "utf8", src_format="utf16",
                                     n_valid=cu, validate=False)
            return np.asarray(b8)[: int(cb)].astype(np.uint8)

        try:
            clean = self._launch_with_retry(_device)
        except Exception:
            self.counters["fallback"] += 1
            clean = np.frombuffer(
                raw.tobytes().decode("utf-8", "replace").encode("utf-8"),
                np.uint8)
        if len(clean) == 0 or len(clean) > self.max_prompt - 1:
            return Result(
                ok=False, code=REJECTED_INVALID,
                error="empty or oversize prompt after replacement",
                error_offset=off)
        ids = np.concatenate([[BOS_ID], clean.astype(np.int32) + N_SPECIAL])
        return (ticket, req, ids, off, bytes(clean))

    def _ingress_unit_chunk(self, encoding, policy, bound: int, take):
        """ONE ragged single-pass launch for a chunk of unit-encoded
        prompts (the (encoding, ``errors=``) pair is the compile cell):
        the launch validates + locates per document through that matrix
        cell AND produces the UTF-8 the byte tokenizer consumes, off one
        decode of the packed chunk.  Covers utf-16-le, utf-32-le and
        latin-1 ingress (latin-1 can never reject — every byte is a
        code point)."""
        width, np_dtype, src, noun = self._UNIT_INGRESS[encoding]
        dt = self._doc_tiles(bound)
        if self._ingress_mesh is not None:
            # Sharded fan-out, one onepass launch per shard; the gather
            # is bit-identical to the single-device cell, so everything
            # below consumes the result unchanged.
            def _launch():
                faults.fire(faults.KERNEL_RAGGED)   # per-call chaos hook
                pk = packing.pack_documents(
                    [u for _, _, u in take], dtype=np_dtype, doc_tiles=dt,
                    pad_to_docs=self.max_batch)
                return tc.ragged_transcode(
                    pk.data, pk.offsets, pk.lengths, src_format=src,
                    dst_format="utf8", errors=policy, strategy="sharded",
                    shard_mesh=self._ingress_mesh)
        else:
            cell = self._cell(
                ("unit", src, policy, dt),
                lambda: jax.jit(lambda d, o, l: tc.ragged_transcode(
                    d, o, l, src_format=src, dst_format="utf8",
                    errors=policy)))

            def _launch():
                faults.fire(faults.KERNEL_RAGGED)   # per-call chaos hook
                pk = packing.pack_documents(
                    [u for _, _, u in take], dtype=np_dtype, doc_tiles=dt,
                    pad_to_docs=self.max_batch)
                return cell(pk.data, pk.offsets, pk.lengths)

        group = (encoding, policy)
        br, mode = self._breaker_route(group)
        if mode == "skip":
            return self._host_fallback_unit(encoding, policy, take)
        try:
            res = (self._probe_launch(_launch) if mode == "probe"
                   else self._launch_with_retry(_launch))
        except Exception:
            self._breaker_record(group, br, ok=False)
            return self._host_fallback_unit(encoding, policy, take)
        self._breaker_record(group, br, ok=True)
        outs = packing.unpack_results(res.buffer, res.offsets, res.counts)
        statuses = np.asarray(res.statuses)
        admitted = []
        for k, (ticket, req, units) in enumerate(take):
            off = int(statuses[k])
            if policy != "replace" and off >= 0:
                self._settle(ticket, Result(
                    ok=False, code=REJECTED_INVALID,
                    error=f"invalid {encoding} prompt at {noun} {off}",
                    error_offset=off))
                self.events.append(("reject", ticket, -1, self._step,
                                    self._clock()))
                continue
            b8 = np.asarray(outs[k]).astype(np.uint8)
            if len(b8) == 0 or len(b8) > self.max_prompt - 1:
                self._settle(ticket, Result(
                    ok=False, code=REJECTED_INVALID,
                    error="empty or oversize prompt"))
                self.events.append(("reject", ticket, -1, self._step,
                                    self._clock()))
                continue
            ids = np.concatenate([[BOS_ID], b8.astype(np.int32) + N_SPECIAL])
            sanitized = bytes(b8) if (policy == "replace" and off >= 0) \
                else b""
            admitted.append((ticket, req, ids, off, sanitized))
        return admitted

    def _host_fallback_unit(self, encoding, policy, take):
        """Host ``codecs`` degradation for a unit-encoded chunk whose
        ragged launch failed after retries (mirrors the device cell's
        CPython-pinned semantics, including the first-error offset in
        source units)."""
        width, _np_dtype, _src, noun = self._UNIT_INGRESS[encoding]
        admitted = []
        for ticket, req, units in take:
            self.counters["fallback"] += 1
            wire = (units.astype(np.uint8).tobytes() if width == 1
                    else units.astype(f"<u{width}").tobytes())
            try:
                wire.decode(encoding)
                off = -1
            except UnicodeDecodeError as e:
                off = e.start // width
            if policy != "replace" and off >= 0:
                self._settle(ticket, Result(
                    ok=False, code=REJECTED_INVALID,
                    error=f"invalid {encoding} prompt at {noun} {off}",
                    error_offset=off))
                continue
            text = wire.decode(encoding, "replace" if off >= 0 else "strict")
            b8 = np.frombuffer(text.encode("utf-8"), np.uint8)
            if len(b8) == 0 or len(b8) > self.max_prompt - 1:
                self._settle(ticket, Result(
                    ok=False, code=REJECTED_INVALID,
                    error="empty or oversize prompt"))
                continue
            ids = np.concatenate([[BOS_ID], b8.astype(np.int32) + N_SPECIAL])
            sanitized = bytes(b8) if (policy == "replace" and off >= 0) \
                else b""
            admitted.append((ticket, req, ids, off, sanitized))
        return admitted

    # ------------------------------------------------------------------
    # Egress.

    def _egress(self, token_ids: np.ndarray, encoding: str) -> bytes:
        byte_vals = token_ids - N_SPECIAL
        byte_vals = byte_vals[(byte_vals >= 0) & (byte_vals < 256)]
        if encoding == "utf-8" or len(byte_vals) == 0:
            return bytes(byte_vals.astype(np.uint8))
        b = jnp.asarray(byte_vals.astype(np.int32))
        # Pinned to the eager pure-jnp strategy: egress buffers have a
        # new length per response, and the fused Pallas pipeline would
        # recompile per distinct shape.  Wire bytes come from the
        # explicit-LE jnp helpers, never a host ``.view()``.
        if encoding == "utf-16-le":
            out, count, _status = tc.transcode(
                b, "utf16", src_format="utf8", n_valid=len(byte_vals),
                strategy="blockparallel")
            wire = tc.units_to_utf16le_bytes(out[: int(count)])
        elif encoding == "utf-32-le":
            out, count, _status = tc.transcode(
                b, "utf32", src_format="utf8", n_valid=len(byte_vals),
                strategy="blockparallel")
            wire = tc.cps_to_utf32le_bytes(out[: int(count)])
        elif encoding == "latin-1":
            # A byte-LM can emit code points above U+00FF: substitute
            # CPython-style ('?') rather than fail the response.
            out, count, _status = tc.transcode(
                b, "latin1", src_format="utf8", n_valid=len(byte_vals),
                strategy="blockparallel", errors="replace")
            wire = out[: int(count)]
        else:
            raise ValueError(f"unknown out_encoding: {encoding}")
        return bytes(np.asarray(wire).astype(np.uint8))
