"""Batched serving engine with transcode ingress/egress.

Requests arrive as raw UTF-8, UTF-16LE, UTF-32LE or Latin-1 byte strings
(the full codec matrix, DESIGN.md §8).  The engine:

  1. **ingress** — *packed multi-request* validation through the ragged
     pipeline (the paper's validation running at the API boundary,
     exactly its motivating deployment).  All UTF-8 prompts of a wave
     are packed into ONE tile-aligned stream
     (``repro.core.packing.pack_documents`` with a fixed per-request
     tile span, so every wave shares one compilation) and a single
     ragged counting-scan launch (``ragged_scan_utf8``: fused
     validation + per-document error location, no write pass) yields
     every prompt's verdict at once — one kernel dispatch per wave
     instead of one per request.  Unit-encoded prompts (UTF-16LE,
     UTF-32LE, Latin-1) group per (encoding, ``errors=``) policy and run
     one ragged transcode to UTF-8 per group through that matrix cell —
     a SINGLE single-pass launch per group (the default ragged strategy
     is "onepass", DESIGN.md §9: one read + one decode of the packed
     wave, validation fused into the same scan).  Under
     ``errors="strict"`` invalid prompts are rejected with the offset of
     the first bad byte/unit surfaced in ``Result.error_offset``; under
     ``errors="replace"`` malformed prompts are sanitized (U+FFFD per
     maximal subpart, CPython semantics) and served at full speed, with
     the first substitution offset still reported.
  2. batches admitted requests into fixed decode slots (padded prefill,
     per-row cursors), runs the jitted prefill + decode loop;
  3. **egress** — detokenizes to any matrix format (UTF-8 / UTF-16LE /
     UTF-32LE / Latin-1) through the vectorized encoders, so a Java/.NET
     client can request UTF-16 — or a legacy system Latin-1 — at no
     extra host cost.

Wave-based continuous batching: a wave admits up to ``max_batch``
requests; finished rows (EOS / max_new) are masked out and their slots
idle until the wave drains.  (True slot-level refill is a mechanical
extension — admission is already per-slot.)
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import List, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import packing
from repro.core import transcode as tc
from repro.data.tokenizer import BOS_ID, EOS_ID, N_SPECIAL, ByteTokenizer
from repro.serve import kvcache, serve_step

# Typed result codes (``Result.code``; failure-mode table in DESIGN.md
# §10).  ``ok`` stays the boolean verdict; the code names WHY a request
# did not serve — load-shedding and deadline misses are not the same
# failure as an invalid prompt, and callers (and the chaos suite) need
# to tell them apart without parsing message strings.
OK = "ok"
REJECTED_INVALID = "rejected_invalid"       # bad prompt/field (permanent)
REJECTED_OVERLOAD = "rejected_overload"     # admission queue full (shed)
REJECTED_DEADLINE = "rejected_deadline"     # per-request deadline expired
FAILED_TRANSCODE = "failed_transcode"       # device path down, no fallback


@dataclasses.dataclass
class Request:
    prompt_bytes: bytes
    max_new: int = 32
    # "utf-8" | "utf-16-le" | "utf-32-le" | "latin-1" (full codec matrix)
    out_encoding: str = "utf-8"
    in_encoding: str = "utf-8"
    errors: str = "strict"          # "strict" | "replace"
    # Per-request deadline, in seconds from ``serve()`` admission (None =
    # no deadline).  A request whose deadline expires before its decode
    # wave starts is rejected with ``REJECTED_DEADLINE`` instead of
    # holding a slot — late answers are dropped work, not service.
    deadline_s: Optional[float] = None


@dataclasses.dataclass
class Result:
    ok: bool
    text_bytes: bytes = b""
    error: str = ""
    # Offset of the first invalid element in the prompt (bytes for utf-8,
    # code units for utf-16-le; Python ``UnicodeDecodeError.start``
    # semantics), -1 when the prompt was well-formed.  Populated for
    # strict rejections AND for replace-mode substitutions.
    error_offset: int = -1
    # Under errors="replace": the prompt actually served, as UTF-8, with
    # U+FFFD substituted per maximal subpart (empty otherwise).
    sanitized_prompt: bytes = b""
    # Typed outcome (module constants above): OK for served requests,
    # else which failure mode rejected the request.
    code: str = OK


class Engine:
    def __init__(self, model, cfg, family: str, params, max_batch: int = 8,
                 max_prompt: int = 512, max_new: int = 128,
                 temperature: float = 0.0, queue_limit: Optional[int] = None,
                 max_retries: int = 2, backoff_base_s: float = 0.05,
                 clock=time.monotonic, sleep=time.sleep):
        self.model, self.cfg, self.family = model, cfg, family
        self.params = params
        self.max_batch, self.max_prompt, self.max_new = (
            max_batch, max_prompt, max_new)
        # Admission bound: one serve() call accepts at most this many
        # requests; the tail is shed with REJECTED_OVERLOAD instead of
        # growing an unbounded work list (DESIGN.md §10).
        self.queue_limit = (4 * max_batch if queue_limit is None
                            else queue_limit)
        # Transient-failure policy: a failed transcode launch is retried
        # ``max_retries`` times with exponential backoff (base doubles
        # per attempt) before the group degrades to the host fallback.
        self.max_retries = max_retries
        self.backoff_base_s = backoff_base_s
        # Injectable for deterministic chaos tests — production uses the
        # monotonic clock and real sleep.
        self._clock, self._sleep = clock, sleep
        # Observability: how often the robustness paths actually fired.
        #   retries   — transient launch failures retried
        #   fallback  — prompts served via the host ``codecs`` path
        #   shed      — requests rejected at admission (overload)
        #   deadline  — requests expired before their decode wave
        self.counters = collections.Counter()
        self.tok = ByteTokenizer()
        self._prefill = jax.jit(serve_step.make_prefill(model, family))
        self._decode = jax.jit(serve_step.make_decode(model, family,
                                                      temperature))
        self._ctx = max_prompt + max_new

    def _launch_with_retry(self, fn):
        """Run a transcode-launch thunk, retrying transient failures with
        exponential backoff; the final failure propagates to the caller
        (which degrades to the host fallback)."""
        delay = self.backoff_base_s
        for attempt in range(self.max_retries + 1):
            try:
                return fn()
            except Exception:
                if attempt == self.max_retries:
                    raise
                self.counters["retries"] += 1
                self._sleep(delay)
                delay *= 2

    # ------------------------------------------------------------------
    # Packed multi-request ingress: per-request field checks stay on the
    # host; every prompt-byte scan goes through the ragged packed
    # pipeline in fixed-geometry groups (``max_batch`` slots x
    # ``_doc_tiles`` tiles each, short groups padded with zero-length
    # documents), so every wave shares one compilation.

    @property
    def _doc_tiles(self) -> int:
        """Tiles per packed ingress slot (covers ``max_prompt``)."""
        return max(1, -(-self.max_prompt // packing.TILE))

    # Unit widths and packed dtypes per non-UTF-8 ingress encoding; the
    # wire bytes split into units with an EXPLICIT little-endian dtype
    # ('<u2'/'<u4', host-endianness-independent — unlike a native-order
    # ``.view(np.uint16)``, whose meaning flips on a big-endian host).
    # The jnp byte-math twins (``tc.utf16le_bytes_to_units`` /
    # ``tc.utf32le_bytes_to_cps``) serve device-resident buffers; this
    # is the host-side pre-pack path, where a device round trip per
    # prompt would be pure overhead.
    _UNIT_INGRESS = {
        "utf-16-le": (2, np.uint16, "utf16", "unit"),
        "utf-32-le": (4, np.uint32, "utf32", "code point"),
        "latin-1": (1, np.uint8, "latin1", "byte"),
    }

    @staticmethod
    def _wire_units(raw: np.ndarray, width: int, np_dtype) -> np.ndarray:
        if width == 1:
            return raw.astype(np_dtype)
        le = np.frombuffer(raw.tobytes(), np.dtype(f"<u{width}"))
        return le.astype(np_dtype)

    def _ingress_batch(self, requests: List[Request], results):
        """Validate/transcode every prompt; rejections are written into
        ``results`` and admitted entries return in request order."""
        utf8_members = []           # (idx, req, raw bytes)
        # (encoding, errors policy) -> [(idx, req, units)] — each group
        # runs as ONE ragged transcode launch through its matrix cell.
        unit_members: dict = {}
        for i, req in enumerate(requests):
            if req.errors not in ("strict", "replace"):
                # Reject per-request rather than raising mid-batch: one
                # bad field must not take down the rest of the wave.
                results[i] = Result(
                    ok=False, code=REJECTED_INVALID,
                    error=f"unknown errors policy: {req.errors}")
                continue
            raw = np.frombuffer(req.prompt_bytes, np.uint8)
            if req.in_encoding in self._UNIT_INGRESS:
                width, np_dtype, src, _noun = \
                    self._UNIT_INGRESS[req.in_encoding]
                if len(raw) % width:
                    results[i] = Result(
                        ok=False, code=REJECTED_INVALID,
                        error=(f"odd {req.in_encoding} prompt byte length"
                               if width == 2 else
                               f"{req.in_encoding} prompt byte length not "
                               f"a multiple of {width}"))
                    continue
                units = self._wire_units(raw, width, np_dtype)
                if len(units) == 0 or len(units) > self.max_prompt:
                    results[i] = Result(
                        ok=False, code=REJECTED_INVALID,
                        error="empty or oversize prompt")
                    continue
                unit_members.setdefault((req.in_encoding, req.errors),
                                        []).append((i, req, units))
            elif req.in_encoding == "utf-8":
                if len(raw) == 0 or len(raw) > self.max_prompt - 1:
                    results[i] = Result(
                        ok=False, code=REJECTED_INVALID,
                        error="empty or oversize prompt")
                    continue
                utf8_members.append((i, req, raw))
            else:
                results[i] = Result(
                    ok=False, code=REJECTED_INVALID,
                    error=f"unknown in_encoding: {req.in_encoding}")
        admitted: dict = {}
        self._ingress_utf8_group(utf8_members, results, admitted)
        for (encoding, policy), members in unit_members.items():
            self._ingress_unit_group(encoding, policy, members, results,
                                     admitted)
        return [admitted[i] for i in sorted(admitted)]

    def _ingress_utf8_group(self, members, results, admitted):
        """One ragged counting-scan launch per ``max_batch`` prompts:
        fused validation + per-document error location, no write pass —
        clean prompts (the common case) pay one packed read per group
        instead of one kernel dispatch per request."""
        for g0 in range(0, len(members), self.max_batch):
            chunk = members[g0: g0 + self.max_batch]

            def _scan(chunk=chunk):
                pk = packing.pack_documents(
                    [raw for _, _, raw in chunk], dtype=np.uint8,
                    doc_tiles=self._doc_tiles, pad_to_docs=self.max_batch)
                return tc.ragged_scan_utf8(pk.data, pk.offsets, pk.lengths)

            try:
                _counts, statuses = self._launch_with_retry(_scan)
            except Exception:
                # Device path down for this group after retries: degrade
                # per-document to the host ``codecs`` path so clean
                # prompts still serve and poison ones get typed errors.
                self._host_fallback_utf8(chunk, results, admitted)
                continue
            statuses = np.asarray(statuses)
            for k, (i, req, raw) in enumerate(chunk):
                off = int(statuses[k])
                if off < 0:
                    ids = np.concatenate(
                        [[BOS_ID], raw.astype(np.int32) + N_SPECIAL])
                    admitted[i] = (i, req, ids, -1, b"")
                elif req.errors != "replace":
                    results[i] = Result(
                        ok=False, code=REJECTED_INVALID,
                        error=f"invalid UTF-8 prompt at byte {off}",
                        error_offset=off)
                else:
                    entry = self._sanitize_utf8(i, req, raw, off)
                    if isinstance(entry, Result):
                        results[i] = entry
                    else:
                        admitted[i] = entry

    def _host_fallback_utf8(self, chunk, results, admitted):
        """Graceful degradation: validate/sanitize each UTF-8 prompt with
        CPython's codec machinery (bit-compatible semantics — the device
        kernels are pinned against it by the differential fuzz).  Slow
        path, but one flaky launch must not fail a whole packed wave."""
        for i, req, raw in chunk:
            self.counters["fallback"] += 1
            data = raw.tobytes()
            try:
                data.decode("utf-8")
                off = -1
            except UnicodeDecodeError as e:
                off = e.start
            if off < 0:
                ids = np.concatenate(
                    [[BOS_ID], raw.astype(np.int32) + N_SPECIAL])
                admitted[i] = (i, req, ids, -1, b"")
            elif req.errors != "replace":
                results[i] = Result(
                    ok=False, code=REJECTED_INVALID,
                    error=f"invalid UTF-8 prompt at byte {off}",
                    error_offset=off)
            else:
                clean = np.frombuffer(
                    data.decode("utf-8", "replace").encode("utf-8"),
                    np.uint8)
                if len(clean) == 0 or len(clean) > self.max_prompt - 1:
                    results[i] = Result(
                        ok=False, code=REJECTED_INVALID,
                        error="empty or oversize prompt after replacement",
                        error_offset=off)
                else:
                    ids = np.concatenate(
                        [[BOS_ID], clean.astype(np.int32) + N_SPECIAL])
                    admitted[i] = (i, req, ids, off, bytes(clean))

    def _sanitize_utf8(self, i, req, raw, off):
        """Dirty prompt under replace: sanitize via a single-pass
        replace-transcode to UTF-16 (the default strategy), then encode
        the now-valid units back to UTF-8 for the byte tokenizer (dirty
        prompts are the rare case, so this stays per-request)."""
        buf = np.zeros(self.max_prompt, np.uint8)
        buf[: len(raw)] = raw

        def _device():
            u16, cu, _status = tc.transcode_utf8_to_utf16(
                jnp.asarray(buf), len(raw), errors="replace")
            # The units are valid by construction — skip the
            # re-validation scan on the way back to bytes.
            b8, cb, _ = tc.transcode_utf16_to_utf8(u16, cu, validate=False)
            return np.asarray(b8)[: int(cb)].astype(np.uint8)

        try:
            clean = self._launch_with_retry(_device)
        except Exception:
            self.counters["fallback"] += 1
            clean = np.frombuffer(
                raw.tobytes().decode("utf-8", "replace").encode("utf-8"),
                np.uint8)
        if len(clean) == 0 or len(clean) > self.max_prompt - 1:
            return Result(
                ok=False, code=REJECTED_INVALID,
                error="empty or oversize prompt after replacement",
                error_offset=off)
        ids = np.concatenate([[BOS_ID], clean.astype(np.int32) + N_SPECIAL])
        return (i, req, ids, off, bytes(clean))

    def _ingress_unit_group(self, encoding, policy, members, results,
                            admitted):
        """One ragged single-pass launch per ``max_batch`` unit-encoded
        prompts (grouped per (encoding, ``errors=``) — the pair and the
        policy are static kernel switches): the launch validates +
        locates per document through that matrix cell AND produces the
        UTF-8 the byte tokenizer consumes, off one decode of the packed
        wave.  Covers utf-16-le, utf-32-le and latin-1 ingress (latin-1
        can never reject — every byte is a code point)."""
        width, np_dtype, src, noun = self._UNIT_INGRESS[encoding]
        for g0 in range(0, len(members), self.max_batch):
            chunk = members[g0: g0 + self.max_batch]

            def _launch(chunk=chunk):
                pk = packing.pack_documents(
                    [u for _, _, u in chunk], dtype=np_dtype,
                    doc_tiles=self._doc_tiles, pad_to_docs=self.max_batch)
                return tc.ragged_transcode(
                    pk.data, pk.offsets, pk.lengths, src_format=src,
                    dst_format="utf8", errors=policy)

            try:
                res = self._launch_with_retry(_launch)
            except Exception:
                self._host_fallback_unit(encoding, policy, chunk, results,
                                         admitted)
                continue
            outs = packing.unpack_results(res.buffer, res.offsets,
                                          res.counts)
            statuses = np.asarray(res.statuses)
            for k, (i, req, units) in enumerate(chunk):
                off = int(statuses[k])
                if policy != "replace" and off >= 0:
                    results[i] = Result(
                        ok=False, code=REJECTED_INVALID,
                        error=f"invalid {encoding} prompt at {noun} {off}",
                        error_offset=off)
                    continue
                b8 = np.asarray(outs[k]).astype(np.uint8)
                if len(b8) == 0 or len(b8) > self.max_prompt - 1:
                    results[i] = Result(
                        ok=False, code=REJECTED_INVALID,
                        error="empty or oversize prompt")
                    continue
                ids = np.concatenate(
                    [[BOS_ID], b8.astype(np.int32) + N_SPECIAL])
                sanitized = bytes(b8) if (policy == "replace" and off >= 0) \
                    else b""
                admitted[i] = (i, req, ids, off, sanitized)

    def _host_fallback_unit(self, encoding, policy, chunk, results,
                            admitted):
        """Host ``codecs`` degradation for a unit-encoded group whose
        ragged launch failed after retries (mirrors the device cell's
        CPython-pinned semantics, including the first-error offset in
        source units)."""
        width, _np_dtype, _src, noun = self._UNIT_INGRESS[encoding]
        for i, req, units in chunk:
            self.counters["fallback"] += 1
            wire = (units.astype(np.uint8).tobytes() if width == 1
                    else units.astype(f"<u{width}").tobytes())
            try:
                wire.decode(encoding)
                off = -1
            except UnicodeDecodeError as e:
                off = e.start // width
            if policy != "replace" and off >= 0:
                results[i] = Result(
                    ok=False, code=REJECTED_INVALID,
                    error=f"invalid {encoding} prompt at {noun} {off}",
                    error_offset=off)
                continue
            text = wire.decode(encoding, "replace" if off >= 0 else "strict")
            b8 = np.frombuffer(text.encode("utf-8"), np.uint8)
            if len(b8) == 0 or len(b8) > self.max_prompt - 1:
                results[i] = Result(
                    ok=False, code=REJECTED_INVALID,
                    error="empty or oversize prompt")
                continue
            ids = np.concatenate([[BOS_ID], b8.astype(np.int32) + N_SPECIAL])
            sanitized = bytes(b8) if (policy == "replace" and off >= 0) \
                else b""
            admitted[i] = (i, req, ids, off, sanitized)

    def _egress(self, token_ids: np.ndarray, encoding: str) -> bytes:
        byte_vals = token_ids - N_SPECIAL
        byte_vals = byte_vals[(byte_vals >= 0) & (byte_vals < 256)]
        if encoding == "utf-8" or len(byte_vals) == 0:
            return bytes(byte_vals.astype(np.uint8))
        b = jnp.asarray(byte_vals.astype(np.int32))
        # Pinned to the eager pure-jnp strategy: egress buffers have a
        # new length per response, and the fused Pallas pipeline would
        # recompile per distinct shape.  Wire bytes come from the
        # explicit-LE jnp helpers, never a host ``.view()``.
        if encoding == "utf-16-le":
            out, count, _status = tc.transcode_utf8_to_utf16(
                b, len(byte_vals), strategy="blockparallel")
            wire = tc.units_to_utf16le_bytes(out[: int(count)])
        elif encoding == "utf-32-le":
            out, count, _status = tc.utf8_to_utf32(
                b, len(byte_vals), strategy="blockparallel")
            wire = tc.cps_to_utf32le_bytes(out[: int(count)])
        elif encoding == "latin-1":
            # A byte-LM can emit code points above U+00FF: substitute
            # CPython-style ('?') rather than fail the response.
            out, count, _status = tc.utf8_to_latin1(
                b, len(byte_vals), errors="replace",
                strategy="blockparallel")
            wire = out[: int(count)]
        else:
            raise ValueError(f"unknown out_encoding: {encoding}")
        return bytes(np.asarray(wire).astype(np.uint8))

    # ------------------------------------------------------------------
    def serve(self, requests: List[Request]) -> List[Result]:
        results: List[Optional[Result]] = [None] * len(requests)
        t0 = self._clock()
        # Bounded admission: shed the tail beyond ``queue_limit`` with a
        # typed overload rejection BEFORE any transcode work — an
        # overloaded engine must refuse cheaply, not queue unboundedly.
        admitted_reqs = requests
        if len(requests) > self.queue_limit:
            self.counters["shed"] += len(requests) - self.queue_limit
            for i in range(self.queue_limit, len(requests)):
                results[i] = Result(
                    ok=False, code=REJECTED_OVERLOAD,
                    error=(f"admission queue full "
                           f"({self.queue_limit} slots); request shed"))
            admitted_reqs = requests[: self.queue_limit]
        # Packed multi-request ingress: one ragged launch per group of
        # ``max_batch`` prompts (rejections land in ``results`` here).
        wave = self._ingress_batch(admitted_reqs, results)

        # Per-request deadlines are relative to serve() admission and
        # checked right before each decode wave: expired requests free
        # their slot instead of producing a late (= useless) answer.
        deadlines = {i: t0 + req.deadline_s
                     for i, req in enumerate(admitted_reqs)
                     if req.deadline_s is not None}
        for w0 in range(0, len(wave), self.max_batch):
            chunk = wave[w0: w0 + self.max_batch]
            live = []
            for entry in chunk:
                i = entry[0]
                dl = deadlines.get(i)
                if dl is not None and self._clock() >= dl:
                    self.counters["deadline"] += 1
                    results[i] = Result(
                        ok=False, code=REJECTED_DEADLINE,
                        error=(f"deadline of {entry[1].deadline_s:g}s "
                               f"expired before decode"))
                else:
                    live.append(entry)
            self._run_wave(live, results)
        return results  # type: ignore[return-value]

    def _run_wave(self, chunk, results):
        b = len(chunk)
        if b == 0:
            return
        lens = np.array([len(ids) for _, _, ids, _, _ in chunk], np.int32)
        s = int(lens.max())
        toks = np.zeros((b, s), np.int32)
        for j, (_, _, ids, _, _) in enumerate(chunk):
            toks[j, : len(ids)] = ids

        state = kvcache.init_state(self.model, self.cfg, b, self._ctx)
        last_logits, state = self._prefill(
            self.params, jnp.asarray(toks), jnp.asarray(lens), state)
        cur = jnp.argmax(last_logits, -1).astype(jnp.int32)

        pos = jnp.asarray(lens)
        out = np.full((b, self.max_new), -1, np.int64)
        done = np.zeros(b, bool)
        key = jax.random.PRNGKey(0)
        for t in range(self.max_new):
            out[:, t] = np.where(done, -1, np.asarray(cur))
            done |= np.asarray(cur) == EOS_ID
            if done.all():
                break
            key, sub = jax.random.split(key)
            cur, _, state = self._decode(
                self.params, cur[:, None], pos, state, sub)
            pos = pos + 1

        for j, (i, req, ids, off, sanitized) in enumerate(chunk):
            gen = out[j]
            gen = gen[(gen >= 0) & (gen != EOS_ID)]
            # Per-document poison isolation on egress: one request with a
            # bad out_encoding (or an egress-transcode failure) must not
            # throw away its wave-mates' finished generations.
            try:
                wire = self._egress(gen, req.out_encoding)
            except Exception as e:
                results[i] = Result(
                    ok=False, code=FAILED_TRANSCODE,
                    error=f"egress transcode failed: {e}",
                    error_offset=off, sanitized_prompt=sanitized)
                continue
            results[i] = Result(
                ok=True, text_bytes=wire,
                error_offset=off, sanitized_prompt=sanitized)
