"""Prefill and decode step functions (the units the dry-run lowers).

``make_prefill``/``make_decode`` return pure functions suitable for
jit/pjit.  Prompts in a batch may have different lengths: padding lanes
carry position -1 which the attention mask treats as empty, and per-row
cache cursors advance by the padded length so slot layout stays uniform.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import common as C


def _positions(family, tokens, lens=None, offset=None):
    b, s = tokens.shape
    base = jnp.arange(s, dtype=jnp.int32)[None, :]
    if offset is not None:
        pos = base + offset[:, None]
    else:
        pos = jnp.broadcast_to(base, (b, s))
    if lens is not None:
        pos = jnp.where(base < lens[:, None], pos, -1)  # padding -> masked
    if family == "vlm":
        pos = jnp.broadcast_to(pos, (3, b, s))
    return pos


def make_prefill(model, family: str):
    """prefill(params, tokens, lens, state) -> (last_logits, state).

    tokens: (B, S) padded prompts; lens: (B,) true lengths.
    last_logits: (B, vocab) at each prompt's final real token.
    """
    lm = getattr(model, "lm", model)

    def prefill(params, tokens, lens, state):
        pos = _positions(family, tokens, lens=lens)
        logits, state, _ = lm.apply(params, tokens, pos=pos, state=state)
        last = jnp.take_along_axis(
            logits, (lens - 1)[:, None, None].astype(jnp.int32), axis=1)
        return last[:, 0], state

    return prefill


def make_decode(model, family: str, temperature: float = 0.0):
    """decode(params, tok, pos, state, key) -> (next_tok, logits, state).

    tok: (B, 1) current token; pos: (B,) its position.
    Greedy when temperature == 0, else temperature sampling.
    """
    lm = getattr(model, "lm", model)

    def decode(params, tok, pos, state, key):
        p = pos[:, None]
        if family == "vlm":
            p = jnp.broadcast_to(p, (3,) + p.shape)
        logits, state, _ = lm.apply(params, tok, pos=p, state=state)
        logits = logits[:, 0]                      # (B, V)
        if temperature > 0:
            nxt = jax.random.categorical(key, logits / temperature, -1)
        else:
            nxt = jnp.argmax(logits, -1)
        return nxt.astype(jnp.int32), logits, state

    return decode


def make_encdec_steps(model):
    """Whisper-style: (prefill, decode) against a fixed encoder output."""

    def prefill(params, frames, tokens, capacity):
        b, s = tokens.shape
        state = model.init_state(params, frames, b, capacity)
        logits, state, _ = model.apply(params, frames, tokens, state=state)
        return logits[:, -1], state

    def decode(params, tok, state):
        logits, state, _ = model.apply(params, None, tok, state=state)
        return jnp.argmax(logits[:, 0], -1).astype(jnp.int32), logits[:, 0], state

    return prefill, decode
