"""Decode-state management: full KV, sliding-window ring, recurrent states.

The state *kinds* live with the layers (``repro.models.common``); this
module provides sizing/placement policy:

  * full-attention archs    -> linear KV cache of ``capacity`` slots;
  * SWA archs (h2o-danube)  -> **ring buffer** of ``window`` slots — the
    cursor wraps, old positions are overwritten and masked by position,
    so a 500k-token stream decodes in O(window) memory;
  * griffin hybrids         -> RG-LRU state (B, D) f32 + a ring cache of
    ``local_window`` for the 1-in-3 local-attention layers;
  * mamba                   -> (conv, ssm) states, O(1) in context length.

``state_bytes`` is the planner used by the serving engine and by the
roofline analysis to compute per-device cache residency.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.encdec import EncDecConfig
from repro.models.lm import LMConfig


def capacity_for(cfg, context_len: int) -> int:
    """Slots the per-layer attention cache actually needs."""
    if isinstance(cfg, EncDecConfig):
        return context_len
    if cfg.pattern == "mamba":
        return 1  # no attention cache at all
    if cfg.pattern == "griffin":
        return min(context_len, cfg.local_window)
    if cfg.window is not None:
        return min(context_len, cfg.window)
    return context_len


def init_state(model, cfg, batch: int, context_len: int):
    """Decode state pytree for ``model`` sized for ``context_len``."""
    cap = capacity_for(cfg, context_len)
    lm = getattr(model, "lm", model)
    return lm.init_state(batch, cap)


def state_bytes(cfg, batch: int, context_len: int) -> int:
    """Planner: bytes of decode state per replica."""
    dtype_bytes = 2 if cfg.dtype == "bfloat16" else 4
    cap = capacity_for(cfg, context_len)
    if isinstance(cfg, EncDecConfig):
        kv = cfg.n_kv_heads * cfg.hd
        return cfg.n_layers * batch * cap * kv * 2 * dtype_bytes
    total = 0
    for kind, count in cfg.segments():
        if kind in ("dense", "moe"):
            kv = cfg.n_kv_heads * cfg.hd
            total += count * batch * cap * kv * 2 * dtype_bytes
            total += count * batch * cap * 4  # pos
        elif kind == "griffin":
            kv = cfg.n_kv_heads * cfg.hd
            total += count * (batch * cap * kv * 2 * dtype_bytes
                              + 2 * batch * cfg.d_model * 4)
        elif kind == "rec":
            total += count * batch * cfg.d_model * 4
        elif kind == "mamba":
            mc = cfg.mamba_cfg()
            total += count * batch * (
                (mc.d_conv - 1) * mc.d_inner + mc.d_inner * mc.d_state) * 4
    return total
