"""Latin-1 (ISO-8859-1) primitives for the codec matrix.

Latin-1 is the degenerate corner of the matrix and the paper-family's
favourite fast path (simdutf ships Latin-1 endpoints next to the UTF
ones): every byte IS a code point, so decoding is a widening copy and can
never fail, and encoding is a narrowing copy that fails exactly on code
points above U+00FF.  Following CPython's ``errors="replace"`` *encode*
semantics, unrepresentable code points substitute ``?`` (0x3F) — note the
asymmetry with the decode-side substitution character U+FFFD, which is
itself not Latin-1-representable.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# CPython's encode-side substitution character ('?'), applied per
# unrepresentable code point under errors="replace".
SUB_BYTE = 0x3F


def encode_bad(cp: jax.Array) -> jax.Array:
    """Per-position bool: code point has no Latin-1 encoding."""
    return (cp < 0) | (cp > 0xFF)


def encode_candidates(cp: jax.Array):
    """Per code point, produce ``(length, byte, bad)``.

    ``length`` is always 1; ``byte`` is the code point itself or the
    ``?`` substitute where unrepresentable (the caller's ``status``
    carries the offender's offset — CPython ``UnicodeEncodeError.start``
    semantics mapped to source elements).
    """
    bad = encode_bad(cp)
    byte = jnp.where(bad, SUB_BYTE, cp)
    return jnp.ones_like(cp), byte, bad
