"""Non-SIMD baselines the paper benchmarks against.

* ``hoehrmann``: the classic finite-state UTF-8 decoder (Hoehrmann 2010,
  the paper's "finite" competitor) — a faithful DFA port running as a
  scalar Python/numpy loop.
* ``python_codecs``: CPython's C-implemented codec machinery, standing in
  for ICU (an optimized scalar/partially-vectorized industrial library).
"""

from __future__ import annotations

import numpy as np

# Hoehrmann's DFA tables (http://bjoern.hoehrmann.de/utf-8/decoder/dfa/).
_UTF8D = np.array([
    # byte -> character class (0..11)
    0,0,0,0,0,0,0,0,0,0,0,0,0,0,0,0, 0,0,0,0,0,0,0,0,0,0,0,0,0,0,0,0,
    0,0,0,0,0,0,0,0,0,0,0,0,0,0,0,0, 0,0,0,0,0,0,0,0,0,0,0,0,0,0,0,0,
    0,0,0,0,0,0,0,0,0,0,0,0,0,0,0,0, 0,0,0,0,0,0,0,0,0,0,0,0,0,0,0,0,
    0,0,0,0,0,0,0,0,0,0,0,0,0,0,0,0, 0,0,0,0,0,0,0,0,0,0,0,0,0,0,0,0,
    1,1,1,1,1,1,1,1,1,1,1,1,1,1,1,1, 9,9,9,9,9,9,9,9,9,9,9,9,9,9,9,9,
    7,7,7,7,7,7,7,7,7,7,7,7,7,7,7,7, 7,7,7,7,7,7,7,7,7,7,7,7,7,7,7,7,
    8,8,2,2,2,2,2,2,2,2,2,2,2,2,2,2, 2,2,2,2,2,2,2,2,2,2,2,2,2,2,2,2,
    10,3,3,3,3,3,3,3,3,3,3,3,3,4,3,3, 11,6,6,6,5,8,8,8,8,8,8,8,8,8,8,8,
    # state transition table (states 0, 12, 24, ... x class)
    0,12,24,36,60,96,84,12,12,12,48,72, 12,12,12,12,12,12,12,12,12,12,12,12,
    12, 0,12,12,12,12,12, 0,12, 0,12,12, 12,24,12,12,12,12,12,24,12,24,12,12,
    12,12,12,12,12,12,12,24,12,12,12,12, 12,24,12,12,12,12,12,12,12,24,12,12,
    12,12,12,12,12,12,12,36,12,36,12,12, 12,36,12,12,12,12,12,36,12,36,12,12,
    12,36,12,12,12,12,12,12,12,12,12,12,
], dtype=np.int32)

ACCEPT, REJECT = 0, 12


def hoehrmann_decode(b: np.ndarray):
    """Scalar DFA decode.  Returns (codepoints list, ok)."""
    state = ACCEPT
    cp = 0
    out = []
    for byte in b:
        byte = int(byte)
        cls = _UTF8D[byte]
        cp = (byte & 0x3F) | (cp << 6) if state != ACCEPT else (
            (0xFF >> cls) & byte)
        state = _UTF8D[256 + state + cls]
        if state == ACCEPT:
            out.append(cp)
            cp = 0
        elif state == REJECT:
            return out, False
    return out, state == ACCEPT


def hoehrmann_utf8_to_utf16(b: np.ndarray):
    """Scalar transcode via the DFA.  Returns (uint16 array, ok)."""
    cps, ok = hoehrmann_decode(b)
    out = []
    for cp in cps:
        if cp < 0x10000:
            out.append(cp)
        else:
            v = cp - 0x10000
            out.append(0xD800 + (v >> 10))
            out.append(0xDC00 + (v & 0x3FF))
    return np.array(out, np.uint16), ok


def python_codecs_utf8_to_utf16(raw: bytes) -> bytes:
    """CPython codec machinery (ICU stand-in)."""
    return raw.decode("utf-8").encode("utf-16-le")


def python_codecs_utf16_to_utf8(raw: bytes) -> bytes:
    return raw.decode("utf-16-le").encode("utf-8")
