"""Mesh-sharded ragged transcode: one onepass launch per device shard.

The single-device ragged path (``repro.kernels.ragged_transcode``) runs
a whole packed batch as ONE grid launch — aggregate ingest is therefore
bounded by one device and one host->device link.  This module splits a
packed batch across the ``data`` axis of a 1-D device mesh with
``shard_map``: each shard runs the UNCHANGED ragged onepass launch on
its own tile-aligned sub-stream, and the per-fragment results are
gathered back with the same segment-reduction machinery the kernel's
per-document reduce uses, so the assembled result is bit-identical to
the single-device path (buffer, per-document counts, statuses).

Shard-cut rules (DESIGN.md §12):

  * The host-side splitter balances by BYTES, not document count: the
    k-th cut targets ``k * total_live / n_shards`` and snaps to the
    nearest document boundary of the ``core/packing`` row-offset vector.
  * A document larger than the shard chunk budget (default: the balanced
    per-shard target) cannot wait for a boundary — the cut lands inside
    it, walked back by the per-codec holdback rule of
    :func:`repro.core.stream.holdback_units` (``Codec.max_lookback``:
    3 for UTF-8, 1 for UTF-16, 0 for the fixed-width formats) so every
    fragment starts at a unit boundary and the per-fragment counts /
    statuses / replace-substitutions compose chunk-wise, exactly like
    the resumable stream chunks of DESIGN.md §10.
  * Every fragment is re-packed tile-aligned per shard (the kernels'
    packed-layout invariant), so fragment order — shard-major, then
    slot-major — IS global document order, and the dense global output
    is the fragment emissions concatenated in that order.

Strict-policy caveat (same as the streaming layer): for a document that
contains an error AND is split across shards, the speculative buffer
content AFTER the first error is launch-geometry-defined; counts and
statuses still compose exactly.  Documents left whole (the splitter
default for anything under the chunk budget) are bit-identical under
every policy.

``shard_map`` needs ``check_rep=False`` here: ``pallas_call`` has no
replication rule, and every output is genuinely per-shard anyway.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import packing
from repro.core import result as R
from repro.core import stream
from repro.testing import faults

TILE = packing.TILE

_IMAX = R.NO_ERR_SENTINEL


def _round_up(n: int, block: int = TILE) -> int:
    return -(-int(n) // block) * block


class ShardPlan(NamedTuple):
    """Host-side split of one packed batch into per-shard sub-streams.

    ``data``/``offsets``/``lengths`` are the per-shard packed layouts
    stacked on a leading shard axis (every shard shares one geometry so
    the ``shard_map`` body compiles once).  ``frag_doc``/``frag_base``
    map each per-shard document slot back to (global document, start
    offset within that document); padding slots carry ``frag_doc ==
    n_docs`` (one past the last document — the sentinel segment the
    gather drops).
    """

    n_shards: int
    n_docs: int
    data: np.ndarray       # [n_shards, shard_len]   codec dtype
    offsets: np.ndarray    # [n_shards, Bs+1] int32  tile-aligned starts
    lengths: np.ndarray    # [n_shards, Bs]   int32  fragment lengths
    frag_doc: np.ndarray   # [n_shards, Bs]   int32  global doc (n_docs=pad)
    frag_base: np.ndarray  # [n_shards, Bs]   int32  fragment start in doc

    @property
    def shard_len(self) -> int:
        return self.data.shape[1]

    @property
    def docs_per_shard(self) -> int:
        return self.lengths.shape[1]


def _normalize_cut(d: int, e: int, lengths: np.ndarray) -> tuple:
    """Canonical (doc, elem) cut: a cut at a document's live end is the
    next document's start, so boundary cuts compare equal regardless of
    which side produced them."""
    n_docs = lengths.shape[0]
    if d >= n_docs:
        return (n_docs, 0)
    e = int(min(max(e, 0), lengths[d]))
    if e > 0 and e == int(lengths[d]):
        return (d + 1, 0)
    return (int(d), e)


def plan_shards(data, offsets, lengths, n_shards: int, *,
                src: str = "utf8",
                chunk_budget: Optional[int] = None) -> ShardPlan:
    """Split a packed batch into ``n_shards`` tile-aligned sub-streams.

    Cuts are balanced by live bytes and land on document boundaries;
    documents larger than ``chunk_budget`` (default: the balanced
    per-shard target) are split mid-document with the per-codec holdback
    walk-back so the fragment boundary is a unit boundary.  Host-side
    only — the splitter needs concrete values.
    """
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    if isinstance(data, jax.core.Tracer) or \
            isinstance(offsets, jax.core.Tracer):
        raise TypeError(
            "plan_shards is a host-side splitter and needs concrete "
            "arrays, not tracers (call it outside jit)")
    data = np.asarray(data)
    offsets = np.asarray(offsets, np.int64)
    lengths = np.asarray(lengths, np.int64)
    n_docs = offsets.shape[0] - 1
    if n_docs < 1:
        raise ValueError("plan_shards: offsets must be [B+1] with B >= 1")
    live = np.cumsum(np.concatenate([[0], lengths]))
    total = int(live[-1])
    target = max(TILE, _round_up(-(-total // max(n_shards, 1))))
    budget = target if chunk_budget is None else int(chunk_budget)
    if budget < TILE:
        raise ValueError(f"chunk_budget must be >= {TILE}, got {budget}")

    # Cut points in (doc, elem-within-doc) space; cuts[k] starts shard k.
    cuts = [(0, 0)]
    for k in range(1, n_shards):
        g = (k * total) // n_shards           # ideal cut, in LIVE bytes
        dd = int(np.clip(np.searchsorted(live[1:], g, side="right"),
                         0, max(n_docs - 1, 0)))
        if n_docs and int(lengths[dd]) > budget:
            # Oversize document: cut inside it, walked back to a unit
            # boundary (the stream layer's holdback rule).
            e = int(g - live[dd])
            lo = int(offsets[dd])
            tail = data[lo + max(e - 4, 0): lo + e]
            e -= stream.holdback_units(src, tail)
            cut = _normalize_cut(dd, e, lengths)
        else:
            # Snap to the nearest document boundary (in live bytes).
            b = dd if (g - int(live[dd])) <= (int(live[dd + 1]) - g) \
                else dd + 1
            cut = _normalize_cut(b, 0, lengths)
        cuts.append(max(cut, cuts[-1]))
    cuts.append((n_docs, 0))

    # Fragment lists per shard: (global doc, base-within-doc, length).
    frags = []
    for k in range(n_shards):
        (d0, e0), (d1, e1) = cuts[k], cuts[k + 1]
        fl = []
        if (d0, e0) < (d1, e1):
            if d0 == d1:
                fl.append((d0, e0, e1 - e0))
            else:
                fl.append((d0, e0, int(lengths[d0]) - e0))
                for d in range(d0 + 1, d1):
                    fl.append((d, 0, int(lengths[d])))
                if e1 > 0:
                    fl.append((d1, 0, e1))
        frags.append(fl)

    bs = max(1, max(len(fl) for fl in frags))
    shard_len = max(TILE, max(
        sum(_round_up(n) for _, _, n in fl) for fl in frags))
    sh_data = np.zeros((n_shards, shard_len), data.dtype)
    sh_off = np.zeros((n_shards, bs + 1), np.int32)
    sh_len = np.zeros((n_shards, bs), np.int32)
    fr_doc = np.full((n_shards, bs), n_docs, np.int32)   # pad sentinel
    fr_base = np.zeros((n_shards, bs), np.int32)
    for k, fl in enumerate(frags):
        lo = 0
        for j, (d, base, n) in enumerate(fl):
            src_lo = int(offsets[d]) + base
            sh_data[k, lo: lo + n] = data[src_lo: src_lo + n]
            sh_off[k, j] = lo
            sh_len[k, j] = n
            fr_doc[k, j] = d
            fr_base[k, j] = base
            lo += _round_up(n)
        sh_off[k, len(fl):] = lo
    return ShardPlan(n_shards, n_docs, sh_data, sh_off, sh_len,
                     fr_doc, fr_base)


# ---------------------------------------------------------------------------
# shard_map execution: one UNCHANGED ragged onepass launch per shard.

# Jitted shard_map callables, keyed per (mesh devices, cell, policy,
# donate) — the ``_BATCH_CACHE`` LRU pattern (shapes re-key inside jit).
_CALL_CACHE: dict = {}
_CALL_CACHE_MAX = 16


def _cache_get(key, build):
    fn = _CALL_CACHE.get(key)
    if fn is None:
        fn = build()
        while len(_CALL_CACHE) >= _CALL_CACHE_MAX:
            _CALL_CACHE.pop(next(iter(_CALL_CACHE)))
        _CALL_CACHE[key] = fn
    else:
        _CALL_CACHE.pop(key)
        _CALL_CACHE[key] = fn
    return fn


def _mesh_key(mesh: Mesh) -> tuple:
    return tuple(d.id for d in mesh.devices.flat)


def sharded_call(mesh: Mesh, src: str, dst: str, validate: bool,
                 errors: str, interpret, *, donate: bool = False):
    """Jitted ``shard_map`` wrapper around the ragged onepass launch:
    ``(data, offsets, lengths)`` stacked per shard -> per-shard
    ``(buffer, out_offsets, counts, statuses)``.

    With ``donate=True`` the staged input buffers are donated to XLA
    (the double-buffered feeder's waves are single-use, so their device
    memory is reused for the outputs).
    """
    from repro.kernels import ragged_transcode as rt

    key = (_mesh_key(mesh), src, dst, bool(validate), errors,
           interpret, bool(donate))

    def build():
        def body(d, o, l):
            res = rt._ragged_onepass_impl(d[0], o[0], l[0], src, dst,
                                          validate, interpret, errors)
            return (res.buffer[None], res.offsets[None],
                    res.counts[None], res.statuses[None])

        # check_rep=False: pallas_call has no replication rule, and
        # every output here is genuinely per-shard.
        sm = shard_map(body, mesh=mesh,
                       in_specs=(P("data"), P("data"), P("data")),
                       out_specs=(P("data"),) * 4, check_rep=False)
        return jax.jit(sm, donate_argnums=(0, 1, 2) if donate else ())

    return _cache_get(key, build)


def sharded_scan_call(mesh: Mesh, src: str, dst: str, interpret):
    """Jitted ``shard_map`` wrapper around the ragged counting scan:
    per-shard ``(counts, statuses)`` — the ingress-boundary query."""
    from repro.kernels import ragged_transcode as rt

    key = (_mesh_key(mesh), "scan", src, dst, interpret)

    def build():
        def body(d, o, l):
            counts, statuses = rt._ragged_scan_impl(
                d[0], o[0], l[0], src, dst, interpret)
            return counts[None], statuses[None]

        sm = shard_map(body, mesh=mesh,
                       in_specs=(P("data"), P("data"), P("data")),
                       out_specs=(P("data"),) * 2, check_rep=False)
        return jax.jit(sm)

    return _cache_get(key, build)


# ---------------------------------------------------------------------------
# Gather: per-fragment results -> the single-device result, with the
# kernel's own segment-reduction machinery over the fragment -> document
# map.


def _doc_counts_statuses(plan: ShardPlan, counts, statuses, validate):
    """Fragment (counts, statuses) -> per-document, composing first-error
    offsets through each fragment's base (min over fragments = global
    first error, since fragments partition a document in order)."""
    n_docs = plan.n_docs
    fd = jnp.asarray(plan.frag_doc.reshape(-1))
    fb = jnp.asarray(plan.frag_base.reshape(-1))
    cf = jnp.asarray(counts).reshape(-1)
    # Padding slots (frag_doc == n_docs) reduce into the dropped
    # sentinel segment — segment_sum/min fills empty documents with
    # 0 / NO_ERR_SENTINEL exactly like the kernel's per-doc reduce.
    doc_counts = jax.ops.segment_sum(cf, fd, num_segments=n_docs + 1)[
        :n_docs].astype(jnp.int32)
    if validate:
        sf = jnp.asarray(statuses).reshape(-1)
        adj = jnp.where(sf < 0, _IMAX, sf + fb)
        first = jax.ops.segment_min(adj, fd, num_segments=n_docs + 1)[
            :n_docs]
        doc_statuses = jnp.where(first == _IMAX, R.STATUS_OK,
                                 first).astype(jnp.int32)
    else:
        doc_statuses = jnp.full((n_docs,), R.STATUS_OK, jnp.int32)
    return doc_counts, doc_statuses


def _gather_result(plan: ShardPlan, cap: int, dst_dtype, bufs, oos,
                   counts, statuses, validate) -> R.RaggedTranscodeResult:
    """Reassemble the dense global output: fragment order (shard-major,
    slot-major) is global document order, so the global stream is the
    fragment emissions concatenated — ONE searchsorted gather."""
    doc_counts, doc_statuses = _doc_counts_statuses(
        plan, counts, statuses, validate)
    out_offsets = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32),
         jnp.cumsum(doc_counts).astype(jnp.int32)])

    bufs = jnp.asarray(bufs)
    cf = jnp.asarray(counts).reshape(-1)
    bs = plan.docs_per_shard
    frag_ends = jnp.cumsum(cf)
    total = frag_ends[-1]
    frag_starts = frag_ends - cf
    # Local output start of each fragment inside its shard's dense
    # buffer: the per-shard out_offsets vector, last entry dropped.
    local = jnp.asarray(oos)[:, :bs].reshape(-1)
    i = jnp.arange(cap, dtype=jnp.int32)
    f = jnp.clip(jnp.searchsorted(frag_ends, i, side="right"),
                 0, cf.shape[0] - 1)
    src_idx = jnp.clip(local[f] + (i - frag_starts[f]),
                       0, bufs.shape[1] - 1)
    out = jnp.where(i < total, bufs[f // bs, src_idx],
                    jnp.zeros((), dst_dtype))
    return R.RaggedTranscodeResult(out, out_offsets, doc_counts,
                                   doc_statuses)


# ---------------------------------------------------------------------------
# Public entry points.


def _resolve_mesh(mesh: Optional[Mesh], n_shards: Optional[int]) -> Mesh:
    from repro.launch import mesh as launch_mesh
    if mesh is not None:
        if "data" not in mesh.axis_names:
            raise ValueError(
                f"sharded transcode needs a mesh with a 'data' axis, "
                f"got axes {mesh.axis_names}")
        return mesh
    return launch_mesh.make_transcode_mesh(n_shards)


def ragged_transcode_sharded(data, offsets, lengths, *,
                             src_format: str = "utf8",
                             dst_format: str = "utf16",
                             validate: bool = True,
                             errors: str = "strict",
                             n_shards: Optional[int] = None,
                             mesh: Optional[Mesh] = None,
                             chunk_budget: Optional[int] = None,
                             interpret=None) -> R.RaggedTranscodeResult:
    """Mesh-sharded ragged transcode, bit-identical to the single-device
    onepass path (module docstring: shard-cut rules and the strict
    split-document caveat).

    ``n_shards`` defaults to the mesh's data-axis size (or every host
    platform device when neither is given).
    """
    from repro.core import transcode as tc
    from repro.kernels import ragged_transcode as rt
    from repro.kernels import runtime
    from repro.kernels import stages

    R.check_errors_policy(errors)
    src = tc.normalize_format(src_format)
    dst = tc.normalize_format(dst_format)
    codec_s, codec_d, factor = stages.get_pair(src, dst)
    data, offsets, lengths = rt._as_packed(data, offsets, lengths,
                                           codec_s.dtype)
    mesh = _resolve_mesh(mesh, n_shards)
    n = int(mesh.shape["data"])
    plan = plan_shards(np.asarray(data), np.asarray(offsets),
                       np.asarray(lengths), n, src=src,
                       chunk_budget=chunk_budget)
    fn = sharded_call(mesh, src, dst, bool(validate), errors,
                      runtime.resolve_interpret(interpret))
    # Host-side chaos hook: fires per CALL (a cache-hot jitted
    # executable skips the kernel wrappers' trace-time hooks) — the
    # supervised-launch layer (core.recovery) retries/replans around it.
    faults.fire(faults.SHARD_LAUNCH)
    bufs, oos, counts, statuses = fn(plan.data, plan.offsets, plan.lengths)
    # Same capacity budget as the single-device launch on this data
    # buffer (factor x its tile span) — the bit-identity contract.
    cap = factor * max(1, -(-int(data.shape[0]) // TILE)) * TILE
    return _gather_result(plan, cap, codec_d.dtype,
                          np.asarray(bufs), np.asarray(oos),
                          np.asarray(counts), np.asarray(statuses),
                          bool(validate))


def scan_ragged_sharded(data, offsets, lengths, *,
                        src_format: str = "utf8",
                        dst_format: str = "utf16",
                        n_shards: Optional[int] = None,
                        mesh: Optional[Mesh] = None,
                        chunk_budget: Optional[int] = None,
                        interpret=None):
    """Mesh-sharded counting scan: per-document ``(counts, statuses)``,
    bit-identical to :func:`repro.core.transcode.ragged_scan`."""
    from repro.core import transcode as tc
    from repro.kernels import ragged_transcode as rt
    from repro.kernels import runtime
    from repro.kernels import stages

    src = tc.normalize_format(src_format)
    dst = tc.normalize_format(dst_format)
    codec_s, _codec_d, _f = stages.get_pair(src, dst)
    data, offsets, lengths = rt._as_packed(data, offsets, lengths,
                                           codec_s.dtype)
    mesh = _resolve_mesh(mesh, n_shards)
    n = int(mesh.shape["data"])
    plan = plan_shards(np.asarray(data), np.asarray(offsets),
                       np.asarray(lengths), n, src=src,
                       chunk_budget=chunk_budget)
    fn = sharded_scan_call(mesh, src, dst,
                           runtime.resolve_interpret(interpret))
    faults.fire(faults.SHARD_LAUNCH)   # per-call chaos hook (see above)
    counts, statuses = fn(plan.data, plan.offsets, plan.lengths)
    return _doc_counts_statuses(plan, np.asarray(counts),
                                np.asarray(statuses), True)
