"""Vectorized UTF-8 classification, validation and decoding.

This module is the block-parallel (TPU-native) adaptation of the paper's
UTF-8 machinery.  Where the CPU algorithm walks 12-byte windows guided by an
end-of-character bitset, we decode *every* byte position speculatively and
mask: each position is treated as if it were a lead byte, the (up to) three
following bytes are folded into a candidate code point, and per-position
validity masks select the real characters.  There is no loop-carried
dependence, so the whole computation is straight-line VPU arithmetic --
exactly what XLA:TPU and the Pallas kernels want.

All arithmetic is int32 (TPU vector lanes are 32-bit); byte arrays are uint8
in memory and widened on load, mirroring the paper's widening of bytes into
16/32-bit lanes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import tables as T


def _shift_right(x: jax.Array, n: int, fill: int = 0) -> jax.Array:
    """bytes[i - n] with `fill` for i < n  (previous bytes)."""
    if n == 0:
        return x
    if n >= x.shape[0]:
        return jnp.full_like(x, fill)
    return jnp.concatenate([jnp.full((n,), fill, x.dtype), x[:-n]])


def _shift_left(x: jax.Array, n: int, fill: int = 0) -> jax.Array:
    """bytes[i + n] with `fill` beyond the end  (next bytes)."""
    if n == 0:
        return x
    if n >= x.shape[0]:
        return jnp.full_like(x, fill)
    return jnp.concatenate([x[n:], jnp.full((n,), fill, x.dtype)])


def classify(b: jax.Array):
    """Per-byte structural classification of a UTF-8 stream.

    Args:
      b: int32 array of byte values in [0, 256).

    Returns dict with int32/bool arrays (all the same shape as ``b``):
      ``is_cont``  -- byte is a continuation (0b10xxxxxx)
      ``seq_len``  -- sequence length if this is a lead byte (1..4), else 0
      ``is_lead``  -- seq_len > 0
      ``bad_byte`` -- byte can never appear in UTF-8 (0xF8..0xFF)
    """
    is_cont = (b & 0xC0) == 0x80
    seq_len = jnp.take(jnp.asarray(T.LEAD_LENGTH_32), b >> 3)
    is_lead = seq_len > 0
    bad_byte = b >= 0xF8
    return {
        "is_cont": is_cont,
        "seq_len": seq_len,
        "is_lead": is_lead,
        "bad_byte": bad_byte,
    }


def validate_kl(b: jax.Array, n_valid=None) -> jax.Array:
    """Keiser-Lemire UTF-8 validation, bit-for-bit with the paper's §4.

    Three nibble-table lookups are ANDed to flag every two-byte structural
    error class, and the 3rd/4th continuation bytes are checked by comparing
    "must be a continuation here" (derived from bytes two and three back)
    against the TWO_CONTS bit.

    Args:
      b: int32 byte values.
      n_valid: optional scalar count of real bytes (the rest is padding);
        padding is replaced by ASCII zeros so it can never create errors.

    Returns a scalar bool: True iff the stream is valid UTF-8.
    """
    if n_valid is not None:
        idx = jnp.arange(b.shape[0])
        b = jnp.where(idx < n_valid, b, 0)

    prev1 = _shift_right(b, 1)
    prev2 = _shift_right(b, 2)
    prev3 = _shift_right(b, 3)

    sc = (
        jnp.take(jnp.asarray(T.BYTE_1_HIGH), prev1 >> 4)
        & jnp.take(jnp.asarray(T.BYTE_1_LOW), prev1 & 0xF)
        & jnp.take(jnp.asarray(T.BYTE_2_HIGH), b >> 4)
    )

    # Positions that *must* hold the 3rd byte of a 3/4-byte sequence or the
    # 4th byte of a 4-byte sequence.
    is_third = prev2 >= 0xE0
    is_fourth = prev3 >= 0xF0
    must_be_cont = (is_third | is_fourth).astype(jnp.int32) * T.TWO_CONTS
    err = sc ^ must_be_cont

    # A trailing truncated sequence is invalid: the last bytes may not begin
    # a multi-byte character that runs off the end.
    n = b.shape[0] if n_valid is None else n_valid
    idx = jnp.arange(b.shape[0])
    tail_lead = (
        ((b >= 0xC0) & (idx >= n - 1))
        | ((b >= 0xE0) & (idx >= n - 2))
        | ((b >= 0xF0) & (idx >= n - 3))
    )
    tail_lead = tail_lead & (idx < n)

    return (jnp.max(err, initial=0) == 0) & (~jnp.any(tail_lead))


def decode_speculative(b: jax.Array):
    """Decode every byte position of a UTF-8 stream as if it led a character.

    This is the heart of the block-parallel transcoder.  For each position we
    fold the next 0..3 continuation bytes into a candidate code point and
    compute structural + scalar-range validity.  Downstream consumers select
    positions where ``is_lead`` and compact with a cumulative sum (the TPU
    stand-in for the paper's pshufb compaction).

    Args:
      b: int32 array of byte values in [0, 256).

    Returns:
      cp:      int32 candidate code point at each position (valid where lead)
      is_lead: bool, position starts a character
      err:     scalar bool, stream is invalid UTF-8
    """
    c = classify(b)
    seq_len = c["seq_len"]
    is_cont = c["is_cont"]
    is_lead = c["is_lead"]

    b1 = _shift_left(b, 1)
    b2 = _shift_left(b, 2)
    b3 = _shift_left(b, 3)

    # Branch-free bit surgery (paper Figs. 2-4): assemble the candidate code
    # point for each possible sequence length, then select by seq_len.
    cp1 = b
    cp2 = ((b & 0x1F) << 6) | (b1 & 0x3F)
    cp3 = ((b & 0x0F) << 12) | ((b1 & 0x3F) << 6) | (b2 & 0x3F)
    cp4 = (
        ((b & 0x07) << 18)
        | ((b1 & 0x3F) << 12)
        | ((b2 & 0x3F) << 6)
        | (b3 & 0x3F)
    )
    cp = jnp.select(
        [seq_len == 1, seq_len == 2, seq_len == 3, seq_len == 4],
        [cp1, cp2, cp3, cp4],
        default=jnp.zeros_like(b),
    )

    # Structural validation, expressed as "expected continuation" bookkeeping
    # (equivalent to the Keiser-Lemire TWO_CONTS check, kept here so that the
    # decoder is self-validating even when used without validate_kl).
    exp_cont = (
        (_shift_right(seq_len, 1) >= 2)
        | (_shift_right(seq_len, 2) >= 3)
        | (_shift_right(seq_len, 3) >= 4)
    )
    struct_err = exp_cont != is_cont
    struct_err = struct_err | c["bad_byte"]

    # Scalar-range validation on decoded values (overlong / surrogate / max).
    min_cp = jnp.take(jnp.asarray(T.MIN_CP_FOR_LEN), seq_len)
    overlong = is_lead & (cp < min_cp)
    surrogate = is_lead & (cp >= 0xD800) & (cp < 0xE000)
    too_large = is_lead & (cp > 0x10FFFF)

    # A multi-byte lead too close to the end of the buffer is truncated.
    n = b.shape[0]
    idx = jnp.arange(n)
    truncated = is_lead & (idx + seq_len > n)

    err = (
        jnp.any(struct_err)
        | jnp.any(overlong)
        | jnp.any(surrogate)
        | jnp.any(too_large)
        | jnp.any(truncated)
    )
    return cp, is_lead, err


def count_chars(b: jax.Array) -> jax.Array:
    """Number of UTF-8 characters = number of non-continuation bytes."""
    return jnp.sum(((b & 0xC0) != 0x80).astype(jnp.int32))


def utf16_length(b: jax.Array) -> jax.Array:
    """UTF-16 code units needed by a UTF-8 stream (1 per char, 2 if 4-byte)."""
    is_lead = ((b & 0xC0) != 0x80).astype(jnp.int32)
    is_4b = (b >= 0xF0).astype(jnp.int32) * (b < 0xF8).astype(jnp.int32)
    return jnp.sum(is_lead + is_4b)
