"""Vectorized UTF-8 classification, validation and decoding.

This module is the block-parallel (TPU-native) adaptation of the paper's
UTF-8 machinery.  Where the CPU algorithm walks 12-byte windows guided by an
end-of-character bitset, we decode *every* byte position speculatively and
mask: each position is treated as if it were a lead byte, the (up to) three
following bytes are folded into a candidate code point, and per-position
validity masks select the real characters.  There is no loop-carried
dependence, so the whole computation is straight-line VPU arithmetic --
exactly what XLA:TPU and the Pallas kernels want.

All arithmetic is int32 (TPU vector lanes are 32-bit); byte arrays are uint8
in memory and widened on load, mirroring the paper's widening of bytes into
16/32-bit lanes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import tables as T


def _shift_right(x: jax.Array, n: int, fill: int = 0) -> jax.Array:
    """bytes[i - n] with `fill` for i < n  (previous bytes)."""
    if n == 0:
        return x
    if n >= x.shape[0]:
        return jnp.full_like(x, fill)
    return jnp.concatenate([jnp.full((n,), fill, x.dtype), x[:-n]])


def _shift_left(x: jax.Array, n: int, fill: int = 0) -> jax.Array:
    """bytes[i + n] with `fill` beyond the end  (next bytes)."""
    if n == 0:
        return x
    if n >= x.shape[0]:
        return jnp.full_like(x, fill)
    return jnp.concatenate([x[n:], jnp.full((n,), fill, x.dtype)])


def classify(b: jax.Array):
    """Per-byte structural classification of a UTF-8 stream.

    Args:
      b: int32 array of byte values in [0, 256).

    Returns dict with int32/bool arrays (all the same shape as ``b``):
      ``is_cont``  -- byte is a continuation (0b10xxxxxx)
      ``seq_len``  -- sequence length if this is a lead byte (1..4), else 0
      ``is_lead``  -- seq_len > 0
      ``bad_byte`` -- byte can never appear in UTF-8 (0xF8..0xFF)
    """
    is_cont = (b & 0xC0) == 0x80
    seq_len = jnp.take(jnp.asarray(T.LEAD_LENGTH_32), b >> 3)
    is_lead = seq_len > 0
    bad_byte = b >= 0xF8
    return {
        "is_cont": is_cont,
        "seq_len": seq_len,
        "is_lead": is_lead,
        "bad_byte": bad_byte,
    }


def validate_kl(b: jax.Array, n_valid=None) -> jax.Array:
    """Keiser-Lemire UTF-8 validation, bit-for-bit with the paper's §4.

    Three nibble-table lookups are ANDed to flag every two-byte structural
    error class, and the 3rd/4th continuation bytes are checked by comparing
    "must be a continuation here" (derived from bytes two and three back)
    against the TWO_CONTS bit.

    Args:
      b: int32 byte values.
      n_valid: optional scalar count of real bytes (the rest is padding);
        padding is replaced by ASCII zeros so it can never create errors.

    Returns a scalar bool: True iff the stream is valid UTF-8.
    """
    if n_valid is not None:
        idx = jnp.arange(b.shape[0])
        b = jnp.where(idx < n_valid, b, 0)

    prev1 = _shift_right(b, 1)
    prev2 = _shift_right(b, 2)
    prev3 = _shift_right(b, 3)

    sc = (
        jnp.take(jnp.asarray(T.BYTE_1_HIGH), prev1 >> 4)
        & jnp.take(jnp.asarray(T.BYTE_1_LOW), prev1 & 0xF)
        & jnp.take(jnp.asarray(T.BYTE_2_HIGH), b >> 4)
    )

    # Positions that *must* hold the 3rd byte of a 3/4-byte sequence or the
    # 4th byte of a 4-byte sequence.
    is_third = prev2 >= 0xE0
    is_fourth = prev3 >= 0xF0
    must_be_cont = (is_third | is_fourth).astype(jnp.int32) * T.TWO_CONTS
    err = sc ^ must_be_cont

    # A trailing truncated sequence is invalid: the last bytes may not begin
    # a multi-byte character that runs off the end.
    n = b.shape[0] if n_valid is None else n_valid
    idx = jnp.arange(b.shape[0])
    tail_lead = (
        ((b >= 0xC0) & (idx >= n - 1))
        | ((b >= 0xE0) & (idx >= n - 2))
        | ((b >= 0xF0) & (idx >= n - 3))
    )
    tail_lead = tail_lead & (idx < n)

    return (jnp.max(err, initial=0) == 0) & (~jnp.any(tail_lead))


def decode_speculative(b: jax.Array):
    """Decode every byte position of a UTF-8 stream as if it led a character.

    This is the heart of the block-parallel transcoder.  For each position we
    fold the next 0..3 continuation bytes into a candidate code point and
    compute structural + scalar-range validity.  Downstream consumers select
    positions where ``is_lead`` and compact with a cumulative sum (the TPU
    stand-in for the paper's pshufb compaction).

    Args:
      b: int32 array of byte values in [0, 256).

    Returns:
      cp:      int32 candidate code point at each position (valid where lead)
      is_lead: bool, position starts a character
      err:     scalar bool, stream is invalid UTF-8
    """
    c = classify(b)
    seq_len = c["seq_len"]
    is_cont = c["is_cont"]
    is_lead = c["is_lead"]

    b1 = _shift_left(b, 1)
    b2 = _shift_left(b, 2)
    b3 = _shift_left(b, 3)

    # Branch-free bit surgery (paper Figs. 2-4): assemble the candidate code
    # point for each possible sequence length, then select by seq_len.
    cp1 = b
    cp2 = ((b & 0x1F) << 6) | (b1 & 0x3F)
    cp3 = ((b & 0x0F) << 12) | ((b1 & 0x3F) << 6) | (b2 & 0x3F)
    cp4 = (
        ((b & 0x07) << 18)
        | ((b1 & 0x3F) << 12)
        | ((b2 & 0x3F) << 6)
        | (b3 & 0x3F)
    )
    cp = jnp.select(
        [seq_len == 1, seq_len == 2, seq_len == 3, seq_len == 4],
        [cp1, cp2, cp3, cp4],
        default=jnp.zeros_like(b),
    )

    # Structural validation, expressed as "expected continuation" bookkeeping
    # (equivalent to the Keiser-Lemire TWO_CONTS check, kept here so that the
    # decoder is self-validating even when used without validate_kl).
    exp_cont = (
        (_shift_right(seq_len, 1) >= 2)
        | (_shift_right(seq_len, 2) >= 3)
        | (_shift_right(seq_len, 3) >= 4)
    )
    struct_err = exp_cont != is_cont
    struct_err = struct_err | c["bad_byte"]

    # Scalar-range validation on decoded values (overlong / surrogate / max).
    min_cp = jnp.take(jnp.asarray(T.MIN_CP_FOR_LEN), seq_len)
    overlong = is_lead & (cp < min_cp)
    surrogate = is_lead & (cp >= 0xD800) & (cp < 0xE000)
    too_large = is_lead & (cp > 0x10FFFF)

    # A multi-byte lead too close to the end of the buffer is truncated.
    n = b.shape[0]
    idx = jnp.arange(n)
    truncated = is_lead & (idx + seq_len > n)

    err = (
        jnp.any(struct_err)
        | jnp.any(overlong)
        | jnp.any(surrogate)
        | jnp.any(too_large)
        | jnp.any(truncated)
    )
    return cp, is_lead, err


def count_chars(b: jax.Array) -> jax.Array:
    """Number of UTF-8 characters = number of non-continuation bytes."""
    return jnp.sum(((b & 0xC0) != 0x80).astype(jnp.int32))


# ---------------------------------------------------------------------------
# Maximal-subpart analysis (error location + replacement semantics).
#
# The W3C/Unicode "substitution of maximal subparts" rule — the one
# CPython's UTF-8 decoder implements — partitions any byte stream into
# units: each unit is either a complete valid character or a *maximal
# subpart* of an ill-formed sequence (the lead plus however many
# continuation bytes are valid for it, or a single invalid byte).  UTF-8
# is self-synchronizing, so whether a byte STARTS a unit depends only on
# the three preceding bytes — no serial resync walk is needed and the
# whole classification is straight-line VPU arithmetic, same as the
# speculative decode above.  This yields, branch-free:
#
#   * the first-error offset with Python ``UnicodeDecodeError.start``
#     semantics (errors="strict" status reporting), and
#   * the errors="replace" output: one U+FFFD per invalid unit start.


def _lead_len_strict(b):
    """Sequence length counting only *valid* lead byte values.

    Unlike :func:`classify`'s table (which gives 0xC0/0xC1 length 2 and is
    the speculative decoder's view), C0/C1 and F5..FF map to 0 here: they
    can never begin a well-formed sequence, so as units they are
    single-byte maximal subparts.
    """
    return jnp.where(b < 0x80, 1,
           jnp.where((b >= 0xC2) & (b < 0xE0), 2,
           jnp.where((b >= 0xE0) & (b < 0xF0), 3,
           jnp.where((b >= 0xF0) & (b < 0xF5), 4, 0))))


def _first_cont_range(lead):
    """Allowed [lo, hi] for the byte after ``lead`` (RFC 3629 table 3-7):
    E0 -> A0..BF, ED -> 80..9F, F0 -> 90..BF, F4 -> 80..8F, else 80..BF.
    The constrained second byte folds the overlong / surrogate / too-large
    checks into a plain range compare."""
    lo = jnp.where(lead == 0xE0, 0xA0, jnp.where(lead == 0xF0, 0x90, 0x80))
    hi = jnp.where(lead == 0xED, 0x9F, jnp.where(lead == 0xF4, 0x8F, 0xBF))
    return lo, hi


def analyze_subparts(b, nxt1, nxt2, nxt3, prv1, prv2, prv3):
    """Classify every position of a UTF-8 stream into maximal subparts.

    All seven arguments are int32 arrays of identical shape: the stream
    plus its three forward and three backward shifts (callers supply the
    shifts so the same body runs on whole arrays and on VMEM tiles with
    neighbour-tile context; out-of-stream positions must read as 0).

    Returns a dict of same-shape arrays:
      ``starts`` -- bool, position begins a unit (valid character OR
                    maximal subpart of an ill-formed sequence)
      ``valid``  -- bool, the unit beginning here is a complete valid
                    character
      ``cp``     -- int32 code point of the unit (U+FFFD at invalid
                    starts — the errors="replace" payload), 0 elsewhere
      ``units``  -- int32 UTF-16 code units the unit emits under
                    errors="replace" (0 at non-starts)
      ``err``    -- bool, unit start that is NOT a valid character: the
                    per-position error map whose first set index equals
                    Python's ``UnicodeDecodeError.start``.
    """
    L = _lead_len_strict(b)
    lo1, hi1 = _first_cont_range(b)
    c1ok = (nxt1 >= lo1) & (nxt1 <= hi1)
    c2ok = (nxt2 & 0xC0) == 0x80
    c3ok = (nxt3 & 0xC0) == 0x80
    valid = (
        (L == 1)
        | ((L == 2) & c1ok)
        | ((L == 3) & c1ok & c2ok)
        | ((L == 4) & c1ok & c2ok & c3ok)
    )

    # A position is CLAIMED (continues the unit of an earlier lead) iff a
    # valid lead 1..3 bytes back reaches it through valid continuations.
    # Only the second byte has a constrained range; 3rd/4th are 80..BF.
    lp1, lp2, lp3 = (_lead_len_strict(prv1), _lead_len_strict(prv2),
                     _lead_len_strict(prv3))
    p1lo, p1hi = _first_cont_range(prv1)
    p2lo, p2hi = _first_cont_range(prv2)
    p3lo, p3hi = _first_cont_range(prv3)
    is_cont = (b & 0xC0) == 0x80
    cont_p1 = (prv1 & 0xC0) == 0x80
    claimed = (
        ((lp1 >= 2) & (b >= p1lo) & (b <= p1hi))
        | ((lp2 >= 3) & (prv1 >= p2lo) & (prv1 <= p2hi) & is_cont)
        | ((lp3 == 4) & (prv2 >= p3lo) & (prv2 <= p3hi) & cont_p1 & is_cont)
    )
    starts = ~claimed
    valid = starts & valid

    # Decoded value at unit starts (paper Figs. 2-4 bit surgery); invalid
    # unit starts carry the replacement character.
    cp2 = ((b & 0x1F) << 6) | (nxt1 & 0x3F)
    cp3 = ((b & 0x0F) << 12) | ((nxt1 & 0x3F) << 6) | (nxt2 & 0x3F)
    cp4 = (
        ((b & 0x07) << 18)
        | ((nxt1 & 0x3F) << 12)
        | ((nxt2 & 0x3F) << 6)
        | (nxt3 & 0x3F)
    )
    cp = jnp.where(L <= 1, b, jnp.where(L == 2, cp2,
                                        jnp.where(L == 3, cp3, cp4)))
    cp = jnp.where(valid, cp, 0xFFFD)
    cp = jnp.where(starts, cp, 0)
    units = jnp.where(starts,
                      jnp.where(valid & (cp >= 0x10000), 2, 1), 0)
    return {
        "starts": starts,
        "valid": valid,
        "cp": cp,
        "units": units,
        "err": starts & ~valid,
    }


def analyze(b: jax.Array):
    """Whole-array :func:`analyze_subparts` (zero-filled shifts)."""
    return analyze_subparts(
        b,
        _shift_left(b, 1), _shift_left(b, 2), _shift_left(b, 3),
        _shift_right(b, 1), _shift_right(b, 2), _shift_right(b, 3),
    )


def first_error_index(b: jax.Array, n_valid=None) -> jax.Array:
    """int32 scalar: offset of the first invalid maximal subpart with
    Python ``UnicodeDecodeError.start`` semantics, or -1 when the stream
    (including a possibly truncated tail) is valid UTF-8."""
    from repro.core import result as R
    if n_valid is not None:
        idx = jnp.arange(b.shape[0])
        b = jnp.where(idx < n_valid, b, 0)
    n = b.shape[0] if n_valid is None else n_valid
    return R.first_error_status(analyze(b)["err"], n)


def utf16_length(b: jax.Array) -> jax.Array:
    """UTF-16 code units needed by a UTF-8 stream (1 per char, 2 if 4-byte)."""
    is_lead = ((b & 0xC0) != 0x80).astype(jnp.int32)
    is_4b = (b >= 0xF0).astype(jnp.int32) * (b < 0xF8).astype(jnp.int32)
    return jnp.sum(is_lead + is_4b)
