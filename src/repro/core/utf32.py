"""UTF-32 <-> UTF-8 encoding primitives (vectorized).

UTF-32 is the internal interchange format of the framework: the data
pipeline decodes UTF-8 to code points on device, models consume code points
(or bytes), and serving re-encodes.  Encoding to UTF-8 follows the paper's
§5 dataflow: per code point we compute its byte length (1..4) and emit four
candidate bytes; stream compaction (cumsum) replaces the pshufb compress.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def invalid_scalar(cp: jax.Array) -> jax.Array:
    """Code points no encoding may represent: surrogates, > U+10FFFF,
    negatives (garbage int32 lanes).  The single definition shared by the
    block-parallel matrix body and the UTF-32 decode stage."""
    return ((cp >= 0xD800) & (cp < 0xE000)) | (cp > 0x10FFFF) | (cp < 0)


def utf8_length_per_cp(cp: jax.Array) -> jax.Array:
    return (
        1
        + (cp >= 0x80).astype(jnp.int32)
        + (cp >= 0x800).astype(jnp.int32)
        + (cp >= 0x10000).astype(jnp.int32)
    )


def encode_utf8_candidates(cp: jax.Array):
    """Per code point, produce (length, bytes[4]) candidate UTF-8 bytes.

    ``bytes`` has shape (..., 4); entries beyond ``length`` are zero.  The
    bit layout mirrors paper Fig. 1 exactly (big-endian data bits, 10
    continuation prefixes).
    """
    L = utf8_length_per_cp(cp)

    c0 = cp & 0x3F          # lowest 6 bits
    c1 = (cp >> 6) & 0x3F
    c2 = (cp >> 12) & 0x3F
    c3 = (cp >> 18) & 0x07

    b_1 = jnp.stack([cp, jnp.zeros_like(cp), jnp.zeros_like(cp), jnp.zeros_like(cp)], -1)
    b_2 = jnp.stack([0xC0 | (cp >> 6), 0x80 | c0, jnp.zeros_like(cp), jnp.zeros_like(cp)], -1)
    b_3 = jnp.stack([0xE0 | (cp >> 12), 0x80 | c1, 0x80 | c0, jnp.zeros_like(cp)], -1)
    b_4 = jnp.stack([0xF0 | c3, 0x80 | c2, 0x80 | c1, 0x80 | c0], -1)

    Le = L[..., None]
    out = jnp.where(Le == 1, b_1, jnp.where(Le == 2, b_2, jnp.where(Le == 3, b_3, b_4)))
    # Per-position badness: callers mask by lead/valid positions before
    # reducing (a trailing low surrogate is not an error at a non-lead lane).
    bad = ((cp >= 0xD800) & (cp < 0xE000)) | (cp > 0x10FFFF) | (cp < 0)
    return L, out, bad
