"""Stream compaction: the TPU-native replacement for pshufb compress-store.

The paper compacts variable-length output with byte shuffles driven by
table-loaded masks.  TPUs have no lane-crossing byte shuffle, but a 1D
cumulative sum plus a scatter (or gather from precomputed source indices)
expresses the same "compress the valid lanes to the front" operation in a
way XLA lowers efficiently.  Both forms are provided:

  * ``compact``          -- scatter form (out[rank(i)] = x[i]); best when the
                            value array is wide.
  * ``compact_gather``   -- gather form (out[j] = x[select(j)]), built from a
                            stable sort over the mask; avoids scatters, which
                            some backends serialize.

Both are jit-safe: output capacity is static, the logical length is returned
as a scalar.

The fused two-pass pipeline (DESIGN.md §5) replaces the *global* cumsum +
scatter with hierarchical compaction: an intra-tile scan inside the Pallas
kernel (:func:`tile_exclusive_scan`) plus a tiny inter-tile scan over one
scalar per tile (:func:`tile_base_offsets`).  Only the two helpers below
ever see per-tile state; no full-capacity index array is materialized.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def compact(values: jax.Array, mask: jax.Array, capacity: int, fill=0):
    """Compress ``values[mask]`` to the front of a ``capacity``-sized buffer.

    Returns (out, count).  values may have trailing dims (compacted along
    axis 0).
    """
    mask_i = mask.astype(jnp.int32)
    rank = jnp.cumsum(mask_i) - 1
    count = rank[-1] + 1 if mask_i.shape[0] > 0 else jnp.int32(0)
    dest = jnp.where(mask, rank, capacity)  # invalid lanes -> dropped
    out_shape = (capacity,) + values.shape[1:]
    out = jnp.full(out_shape, fill, values.dtype)
    out = out.at[dest].set(values, mode="drop")
    return out, count


def compact_offsets(values: jax.Array, lengths: jax.Array, mask: jax.Array,
                    capacity: int, fill=0):
    """Variable-length compaction: lane i contributes ``lengths[i]`` items.

    ``values`` has shape (N, K) with K >= max(lengths); item j of lane i goes
    to offset ``start[i] + j`` where start is the exclusive cumsum of the
    masked lengths.  This is the §5 UTF-8 egress pattern (each code point
    emits 1..4 bytes).

    Returns (out, total).
    """
    n, k = values.shape
    eff_len = jnp.where(mask, lengths, 0)
    start = jnp.cumsum(eff_len) - eff_len
    total = start[-1] + eff_len[-1] if n > 0 else jnp.int32(0)
    j = jnp.arange(k)[None, :]
    dest = start[:, None] + j
    keep = mask[:, None] & (j < eff_len[:, None])
    dest = jnp.where(keep, dest, capacity)
    out = jnp.full((capacity,), fill, values.dtype)
    out = out.at[dest.reshape(-1)].set(values.reshape(-1), mode="drop")
    return out, total


def tile_exclusive_scan(x: jax.Array, rows: int = 8):
    """Flat exclusive prefix sum of a VMEM tile, as two short scans.

    ``x`` is a flat int32 tile (e.g. 1024 lanes) viewed as ``(rows, -1)``:
    a per-row inclusive cumsum along the lane axis plus a ``rows``-element
    scan of the row totals gives the row-major flat prefix — the TPU-native
    shape for an in-register scan (no 1D lane-crossing cumsum needed).

    Returns ``(exclusive, total)``: the flat exclusive prefix (same shape
    as ``x``) and the scalar tile total.  Runs inside Pallas kernels.
    """
    x2 = x.reshape(rows, -1)
    incl = jnp.cumsum(x2, axis=1)
    row_tot = incl[:, -1]
    row_off = (jnp.cumsum(row_tot) - row_tot)[:, None]
    flat_incl = (incl + row_off).reshape(x.shape)
    return flat_incl - x, jnp.sum(row_tot)


def tile_base_offsets(tile_totals: jax.Array):
    """Exclusive scan over per-tile totals -> (base_offsets, grand_total).

    This is the only inter-tile coordination the fused pipeline needs: an
    ``nblk``-element cumsum (one scalar per tile, not one per element).
    """
    base = jnp.cumsum(tile_totals) - tile_totals
    total = (base[-1] + tile_totals[-1]) if tile_totals.shape[0] > 0 \
        else jnp.int32(0)
    return base, total


def compact_gather(values: jax.Array, mask: jax.Array, capacity: int, fill=0):
    """Sort-based compaction (no scatter): stable-sort lanes by ~mask."""
    n = values.shape[0]
    key = jnp.where(mask, 0, 1).astype(jnp.int32)
    order = jnp.argsort(key, stable=True)
    gathered = values[order]
    count = jnp.sum(mask.astype(jnp.int32))
    if capacity <= n:
        out = gathered[:capacity]
    else:
        pad = jnp.full((capacity - n,) + values.shape[1:], fill, values.dtype)
        out = jnp.concatenate([gathered, pad], 0)
    idx = jnp.arange(capacity)
    out = jnp.where((idx < count).reshape((-1,) + (1,) * (out.ndim - 1)), out, fill)
    return out, count
