"""Lookup tables for SIMD-style Unicode transcoding (Lemire & Mula 2021).

All tables are tiny (<= a few KiB) by design -- the paper's central memory
argument is that transcoding tables must fit in the fastest cache level.  On
TPU the analogue is SMEM/VMEM residency: every table below is a small constant
array that XLA materialises next to the kernel.

Two table families live here:

1. The Keiser-Lemire three-nibble validation tables (`BYTE_1_HIGH`,
   `BYTE_1_LOW`, `BYTE_2_HIGH`) -- ported bit-for-bit from the paper's
   reference (simdjson/simdutf lineage).
2. The windowed-mode tables replacing the paper's 1024-entry bitset-keyed
   table: for every 12-bit end-of-character bitset we precompute how many
   bytes a window consumes, how many characters it contains and the start
   offset of each character (the TPU stand-in for the pshufb shuffle masks).
"""

from __future__ import annotations

import numpy as np

# ---------------------------------------------------------------------------
# Keiser-Lemire validation nibble tables.
# Error bit flags (one byte per class of structural error).
TOO_SHORT = 1 << 0       # lead byte followed by another lead byte
TOO_LONG = 1 << 1        # ASCII followed by a continuation byte
OVERLONG_3 = 1 << 2      # 0xE0 followed by a byte < 0xA0
SURROGATE = 1 << 4       # 0xED followed by a byte >= 0xA0
OVERLONG_2 = 1 << 5      # 0xC0/0xC1 lead (value < 0x80 encoded in 2 bytes)
TWO_CONTS = 1 << 7       # two continuation bytes in a row (also: carry bit)
TOO_LARGE = 1 << 3       # 0xF4 followed by a byte >= 0x90, or 0xF5..
TOO_LARGE_1000 = 1 << 6
OVERLONG_4 = 1 << 6      # 0xF0 followed by a byte < 0x90

_CARRY = TOO_SHORT | TOO_LONG | TWO_CONTS

BYTE_1_HIGH = np.array(
    [
        # 0x0_ .. 0x7_ : ASCII previous byte -> only TOO_LONG possible
        TOO_LONG, TOO_LONG, TOO_LONG, TOO_LONG,
        TOO_LONG, TOO_LONG, TOO_LONG, TOO_LONG,
        # 0x8_ .. 0xB_ : previous byte is a continuation
        TWO_CONTS, TWO_CONTS, TWO_CONTS, TWO_CONTS,
        # 0xC_ : 2-byte lead (0xC0/0xC1 are overlong)
        TOO_SHORT | OVERLONG_2,
        # 0xD_ : 2-byte lead
        TOO_SHORT,
        # 0xE_ : 3-byte lead
        TOO_SHORT | OVERLONG_3 | SURROGATE,
        # 0xF_ : 4-byte lead
        TOO_SHORT | TOO_LARGE | TOO_LARGE_1000 | OVERLONG_4,
    ],
    dtype=np.int32,
)

BYTE_1_LOW = np.array(
    [
        _CARRY | OVERLONG_3 | OVERLONG_2 | OVERLONG_4,   # 0
        _CARRY | OVERLONG_2,                             # 1
        _CARRY,                                          # 2
        _CARRY,                                          # 3
        _CARRY | TOO_LARGE,                              # 4
        _CARRY | TOO_LARGE | TOO_LARGE_1000,             # 5
        _CARRY | TOO_LARGE | TOO_LARGE_1000,             # 6
        _CARRY | TOO_LARGE | TOO_LARGE_1000,             # 7
        _CARRY | TOO_LARGE | TOO_LARGE_1000,             # 8
        _CARRY | TOO_LARGE | TOO_LARGE_1000,             # 9
        _CARRY | TOO_LARGE | TOO_LARGE_1000,             # A
        _CARRY | TOO_LARGE | TOO_LARGE_1000,             # B
        _CARRY | TOO_LARGE | TOO_LARGE_1000,             # C
        _CARRY | TOO_LARGE | TOO_LARGE_1000 | SURROGATE, # D
        _CARRY | TOO_LARGE | TOO_LARGE_1000,             # E
        _CARRY | TOO_LARGE | TOO_LARGE_1000,             # F
    ],
    dtype=np.int32,
)

BYTE_2_HIGH = np.array(
    [
        # 0x0_ .. 0x7_ : ASCII current byte -> previous lead was TOO_SHORT
        TOO_SHORT, TOO_SHORT, TOO_SHORT, TOO_SHORT,
        TOO_SHORT, TOO_SHORT, TOO_SHORT, TOO_SHORT,
        # 0x8_
        TOO_LONG | OVERLONG_2 | TWO_CONTS | OVERLONG_3 | TOO_LARGE_1000 | OVERLONG_4,
        # 0x9_
        TOO_LONG | OVERLONG_2 | TWO_CONTS | OVERLONG_3 | TOO_LARGE,
        # 0xA_ 0xB_
        TOO_LONG | OVERLONG_2 | TWO_CONTS | SURROGATE | TOO_LARGE,
        TOO_LONG | OVERLONG_2 | TWO_CONTS | SURROGATE | TOO_LARGE,
        # 0xC_ .. 0xF_ : current byte is a lead byte
        TOO_SHORT, TOO_SHORT, TOO_SHORT, TOO_SHORT,
    ],
    dtype=np.int32,
)

# ---------------------------------------------------------------------------
# Sequence-length classification from the lead byte's high 5 bits.
# Index = byte >> 3 (32 entries). 0 marks a continuation or invalid lead.
LEAD_LENGTH_32 = np.zeros(32, dtype=np.int32)
LEAD_LENGTH_32[0:16] = 1          # 0x00..0x7F ASCII
# 0x80..0xBF -> 0 (continuation)
LEAD_LENGTH_32[24:28] = 2         # 0xC0..0xDF
LEAD_LENGTH_32[28:30] = 3         # 0xE0..0xEF
LEAD_LENGTH_32[30] = 4            # 0xF0..0xF7
# 0xF8..0xFF -> 0 (invalid anywhere)

# Minimum code point for a sequence of length L (overlong check), 1-indexed.
MIN_CP_FOR_LEN = np.array([0, 0, 0x80, 0x800, 0x10000], dtype=np.int32)

# ---------------------------------------------------------------------------
# Windowed-mode tables (paper Algorithm 2/3).  Key = 12-bit end-of-character
# bitset of the next 12 input bytes (bit i set <=> byte i ends a character).
#
# For each key we choose the paper's case:
#   case 0: the first 6 characters each span 1-2 bytes       (Fig. 2)
#   case 1: the first 4 characters each span 1-3 bytes       (Fig. 3)
#   case 2: the first 2 characters span anything (1-4 bytes) (Fig. 4)
# and store: consumed byte count, number of characters, per-character start
# offsets and lengths (start/len of up to 6 characters, padded with zeros).
#
# Entries whose prefix cannot be parsed into whole characters (e.g. a window
# beginning mid-character) are marked invalid; the transcoder only reaches
# them on invalid input, which validation has already rejected.

WINDOW_KEY_BITS = 12
_N_KEYS = 1 << WINDOW_KEY_BITS


def _build_window_tables():
    consumed = np.zeros(_N_KEYS, dtype=np.int32)
    nchars = np.zeros(_N_KEYS, dtype=np.int32)
    case = np.zeros(_N_KEYS, dtype=np.int32)
    starts = np.zeros((_N_KEYS, 6), dtype=np.int32)
    lengths = np.zeros((_N_KEYS, 6), dtype=np.int32)
    valid = np.zeros(_N_KEYS, dtype=bool)

    for key in range(_N_KEYS):
        # Decode character boundaries from the bitset.  Byte i ends a char
        # iff bit i is set; characters are [prev_end+1 .. end].
        ends = [i for i in range(WINDOW_KEY_BITS) if (key >> i) & 1]
        chars = []
        prev = -1
        for e in ends:
            chars.append((prev + 1, e - prev))  # (start, length)
            prev = e
        if not chars:
            continue
        lens = [l for (_, l) in chars]
        if any(l > 4 for l in lens):
            continue
        # Pick the widest applicable case, mirroring Algorithm 2's order.
        if len(chars) >= 6 and all(l <= 2 for l in lens[:6]):
            c, n = 0, 6
        elif len(chars) >= 4 and all(l <= 3 for l in lens[:4]):
            c, n = 1, 4
        elif len(chars) >= 2:
            c, n = 2, 2
        else:
            # A single character in 12 bytes can only happen near the end of
            # the buffer; consume it alone.
            c, n = 2, 1
        sel = chars[:n]
        case[key] = c
        nchars[key] = n
        consumed[key] = sum(l for (_, l) in sel)
        for j, (s, l) in enumerate(sel):
            starts[key, j] = s
            lengths[key, j] = l
        valid[key] = True
    return consumed, nchars, case, starts, lengths, valid


(
    WINDOW_CONSUMED,
    WINDOW_NCHARS,
    WINDOW_CASE,
    WINDOW_STARTS,
    WINDOW_LENGTHS,
    WINDOW_VALID,
) = _build_window_tables()
