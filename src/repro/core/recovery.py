"""Supervised sharded launches: retry, watchdog, degraded-mesh replan.

PR 9 made the ragged transcode horizontal — and multiplied the ways a
batch can die.  A mesh launch can fail transiently (a flaky link, an
injected :class:`~repro.testing.faults.FaultInjected`), hang (a wedged
transfer or kernel that never returns), or fail *persistently* (a dead
device).  This module is the supervisor that turns all three into one
of exactly three outcomes, in order of preference:

  1. **retried success** — the launch is retried with exponential
     backoff (same mesh, same plan) up to ``RetryPolicy.max_retries``
     times;
  2. **degraded-but-bit-identical replan** — on persistent failure the
     batch is RE-PLANNED onto a degraded mesh (the first ``n-1``
     devices of the data axis, then ``n-2``, ... down to
     ``RetryPolicy.min_shards``).  :func:`repro.core.shard.plan_shards`
     applies the same document-boundary / holdback cut rules at every
     mesh size, and the PR-9 gather contract makes every size's
     reassembled result bit-identical to the single-device path — so a
     degraded mesh changes throughput, never bytes;
  3. **typed error** — when every mesh size down to ``min_shards`` has
     exhausted its retries, :class:`DegradedMeshExhausted` carries the
     full (mesh size, attempt, cause) trail.  No outcome is ever a
     silent hang or a lost batch.

Hangs are bounded by :func:`call_with_watchdog`: the launch runs on a
daemon worker thread while the supervisor polls an injectable clock;
past the deadline the worker is *abandoned* (Python threads cannot be
killed — the eventual result is dropped on the floor) and
:class:`WatchdogTimeout` feeds the same retry/replan ladder as an
ordinary launch failure.  The injectable clock is what makes hang tests
deterministic: a fake auto-advancing clock trips the watchdog without
real waiting.

The feeder (:mod:`repro.data.shard_feed`) reuses ``call_with_watchdog``
and :class:`WatchdogTimeout` for its per-wave bound; the serve engine's
circuit breaker (:mod:`repro.serve.engine`) is the third leg of the
fault-tolerance layer — see DESIGN.md §10.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, List, Optional, Tuple

import numpy as np

from jax.sharding import Mesh


class ShardFaultError(RuntimeError):
    """Base class for the supervised-launch layer's typed errors."""


class WatchdogTimeout(ShardFaultError):
    """A supervised call outlived its watchdog budget.  The runaway
    worker thread is abandoned (daemonized — it cannot block interpreter
    exit) and whatever it eventually produces is discarded."""

    def __init__(self, what: str, timeout_s: float):
        super().__init__(f"{what} exceeded its {timeout_s:g}s watchdog")
        self.what = what
        self.timeout_s = timeout_s


class DegradedMeshExhausted(ShardFaultError):
    """Every mesh size from the requested shard count down to
    ``min_shards`` failed all its attempts.  ``causes`` is the full
    attempt trail: ``[(n_shards, attempt_index, exception), ...]``."""

    def __init__(self, causes: List[Tuple[int, int, BaseException]]):
        self.causes = list(causes)
        sizes = sorted({n for n, _a, _e in self.causes}, reverse=True)
        last = self.causes[-1][2] if self.causes else None
        super().__init__(
            f"sharded launch failed at every mesh size {sizes}; "
            f"last cause: {last!r}")


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Supervision knobs for :func:`supervised_ragged_transcode`.

    ``max_retries`` attempts-after-the-first per mesh size, exponential
    backoff from ``backoff_base_s`` (0.0 = immediate, the chaos suite's
    setting).  ``watchdog_s=None`` disables the hang bound.  ``sleep``
    and ``clock`` are injectable so tests never wait on real time;
    ``poll_s`` is the real-time granularity of the watchdog's poll loop.
    """

    max_retries: int = 2
    backoff_base_s: float = 0.05
    watchdog_s: Optional[float] = None
    min_shards: int = 1
    sleep: Callable[[float], None] = time.sleep
    clock: Callable[[], float] = time.monotonic
    poll_s: float = 0.005


@dataclasses.dataclass
class SupervisionLog:
    """Optional out-param recording what the supervisor actually did:
    ``attempts`` is ``[(n_shards, attempt_index, outcome), ...]`` with
    outcome ``"ok"`` or the exception class name."""

    attempts: List[Tuple[int, int, str]] = dataclasses.field(
        default_factory=list)
    retries: int = 0
    replans: int = 0
    final_shards: Optional[int] = None


def call_with_watchdog(fn, timeout_s: Optional[float], *,
                       clock: Callable[[], float] = time.monotonic,
                       poll_s: float = 0.005,
                       what: str = "supervised call"):
    """Run ``fn()`` bounded by ``timeout_s`` on the injectable clock.

    ``timeout_s=None`` calls ``fn`` inline (no thread, no bound).
    Otherwise ``fn`` runs on a fresh daemon thread while this thread
    polls the clock every ``poll_s`` real seconds; when the clock passes
    the deadline first, :class:`WatchdogTimeout` is raised and the
    worker is abandoned.  Exceptions from ``fn`` re-raise here.
    """
    if timeout_s is None:
        return fn()
    box: dict = {}
    done = threading.Event()

    def _worker():
        try:
            box["result"] = fn()
        except BaseException as e:          # noqa: BLE001 — re-raised below
            box["error"] = e
        finally:
            done.set()

    t = threading.Thread(target=_worker, daemon=True,
                         name=f"watchdog:{what}")
    t.start()
    deadline = clock() + timeout_s
    while not done.is_set():
        if clock() >= deadline:
            raise WatchdogTimeout(what, timeout_s)
        done.wait(poll_s)
    if "error" in box:
        raise box["error"]
    return box["result"]


def degraded_mesh(mesh: Mesh, n: int) -> Mesh:
    """The degraded replan target: the first ``n`` devices of ``mesh``'s
    data axis, same axis name — a strict prefix, so a device that was
    shard k stays shard k for k < n."""
    devs = list(mesh.devices.flat)
    if not 1 <= n <= len(devs):
        raise ValueError(
            f"degraded mesh size must be in [1, {len(devs)}], got {n}")
    return Mesh(np.asarray(devs[:n]), ("data",))


def _supervise(run_at, mesh: Mesh, policy: RetryPolicy,
               log: Optional[SupervisionLog], what: str):
    """The retry/replan ladder shared by both supervised entry points:
    ``run_at(sub_mesh)`` is attempted ``max_retries + 1`` times per mesh
    size, walking n -> min_shards; first success wins."""
    n = int(mesh.shape["data"])
    if not 1 <= policy.min_shards <= n:
        raise ValueError(
            f"min_shards must be in [1, {n}], got {policy.min_shards}")
    causes: List[Tuple[int, int, BaseException]] = []
    for m in range(n, policy.min_shards - 1, -1):
        sub = mesh if m == n else degraded_mesh(mesh, m)
        if log is not None and m < n:
            log.replans += 1
        delay = policy.backoff_base_s
        for attempt in range(policy.max_retries + 1):
            try:
                out = call_with_watchdog(
                    lambda: run_at(sub), policy.watchdog_s,
                    clock=policy.clock, poll_s=policy.poll_s,
                    what=f"{what} ({m} shard(s))")
            except Exception as e:          # noqa: BLE001 — trail + ladder
                causes.append((m, attempt, e))
                if log is not None:
                    log.attempts.append((m, attempt, type(e).__name__))
                if attempt < policy.max_retries:
                    if log is not None:
                        log.retries += 1
                    if delay > 0.0:
                        policy.sleep(delay)
                    delay *= 2.0
            else:
                if log is not None:
                    log.attempts.append((m, attempt, "ok"))
                    log.final_shards = m
                return out
    raise DegradedMeshExhausted(causes)


def supervised_ragged_transcode(data, offsets, lengths, *,
                                src_format: str = "utf8",
                                dst_format: str = "utf16",
                                validate: bool = True,
                                errors: str = "strict",
                                n_shards: Optional[int] = None,
                                mesh: Optional[Mesh] = None,
                                chunk_budget: Optional[int] = None,
                                interpret=None,
                                policy: Optional[RetryPolicy] = None,
                                log: Optional[SupervisionLog] = None):
    """:func:`repro.core.shard.ragged_transcode_sharded` under the
    supervisor: retried with backoff, hang-bounded by the watchdog, and
    re-planned onto a degraded mesh on persistent failure.

    Each mesh size re-plans from scratch (same cut rules), so WHATEVER
    size succeeds returns the same bytes as the single-device path —
    degradation is invisible in the result.  Raises
    :class:`DegradedMeshExhausted` when every size fails.
    """
    from repro.core import shard

    policy = policy or RetryPolicy()
    full = shard._resolve_mesh(mesh, n_shards)

    def run_at(sub: Mesh):
        return shard.ragged_transcode_sharded(
            data, offsets, lengths, src_format=src_format,
            dst_format=dst_format, validate=validate, errors=errors,
            mesh=sub, chunk_budget=chunk_budget, interpret=interpret)

    return _supervise(run_at, full, policy, log, "sharded ragged launch")


def supervised_scan_ragged(data, offsets, lengths, *,
                           src_format: str = "utf8",
                           dst_format: str = "utf16",
                           n_shards: Optional[int] = None,
                           mesh: Optional[Mesh] = None,
                           chunk_budget: Optional[int] = None,
                           interpret=None,
                           policy: Optional[RetryPolicy] = None,
                           log: Optional[SupervisionLog] = None):
    """:func:`repro.core.shard.scan_ragged_sharded` under the same
    retry / watchdog / degraded-replan ladder."""
    from repro.core import shard

    policy = policy or RetryPolicy()
    full = shard._resolve_mesh(mesh, n_shards)

    def run_at(sub: Mesh):
        return shard.scan_ragged_sharded(
            data, offsets, lengths, src_format=src_format,
            dst_format=dst_format, mesh=sub, chunk_budget=chunk_budget,
            interpret=interpret)

    return _supervise(run_at, full, policy, log, "sharded ragged scan")
