"""Ragged document packing for single-launch batched transcoding.

The padded-vmap batch path maps the single-document transcoder over a
fixed-capacity ``[B, L]`` buffer: every document pays for ``L`` elements
of grid dispatch no matter how short it is, and a batch of skewed
lengths burns most of its tiles on padding.  The packed layout removes
that tax: documents are concatenated into ONE flat narrow-dtype buffer
and the fused count/write kernels run as a single grid launch over the
whole batch (``repro.kernels.ragged_transcode``), with per-tile scalars
segment-reduced per document afterwards.

Layout (the ``PackedDocs`` triple):

  * ``data``     -- flat narrow buffer (uint8 bytes / uint16 units).
    Document ``d`` occupies ``[offsets[d], offsets[d] + lengths[d])``;
    the slack up to ``offsets[d+1]`` is zero-filled.
  * ``offsets``  -- int32 ``[B+1]`` row-offset vector.  Every offset is
    **tile-aligned** (a multiple of the 1024-lane VMEM tile), so each
    grid tile belongs to exactly one document — the property that lets
    one kernel launch serve the whole batch with only per-tile scalar
    bookkeeping (no per-lane document ids).
  * ``lengths``  -- int32 ``[B]`` logical element counts.

A zero-length document occupies zero tiles (``offsets[d+1] ==
offsets[d]``) unless a fixed per-document tile span is requested
(``doc_tiles=``, used by the serving engine so every ingress wave shares
one compilation).

``tile_ownership`` computes the tile -> document map **on device**: a
``searchsorted`` over the offset vector, the per-tile document end, and
the same-document neighbour flags the kernels use to zero cross-document
byte inflow (a character must never claim bytes from the next document).
"""

from __future__ import annotations

from typing import NamedTuple, Sequence

import numpy as np

import jax
import jax.numpy as jnp

# One VMEM tile of the fused/ragged kernels: 8 sublanes x 128 lanes.
TILE = 1024


class PackedDocs(NamedTuple):
    """Host-side packed batch: (data, offsets, lengths) — see module doc."""

    data: np.ndarray      # flat narrow buffer, zero-filled slack
    offsets: np.ndarray   # int32 [B+1], tile-aligned starts
    lengths: np.ndarray   # int32 [B], logical element counts

    @property
    def n_docs(self) -> int:
        return self.offsets.shape[0] - 1


def _round_up(n: int, block: int) -> int:
    return -(-n // block) * block


def pack_documents(docs: Sequence, *, dtype=None, block: int = TILE,
                   doc_tiles: int | None = None,
                   pad_to_docs: int | None = None) -> PackedDocs:
    """Pack a list of documents into one tile-aligned flat buffer.

    Args:
      docs: sequence of 1-D arrays / ``bytes`` (UTF-8) — each becomes one
        packed document.  ``bytes`` are viewed as uint8.
      dtype: element dtype (default: inferred, uint8 for bytes).
      block: tile width each document start is aligned to.
      doc_tiles: if given, every document occupies exactly this many
        tiles (error if one is longer) — a fixed geometry, so batches of
        the same ``(B, doc_tiles)`` share one compilation.
      pad_to_docs: if given, append zero-length documents until the batch
        has this many rows (again for compilation reuse).

    Returns a :class:`PackedDocs`; zero-filled slack between documents.
    """
    arrs = []
    for k, d in enumerate(docs):
        if isinstance(d, (bytes, bytearray, memoryview)):
            d = np.frombuffer(bytes(d), np.uint8)
        a = np.asarray(d)
        if a.ndim != 1:
            raise ValueError(
                f"pack_documents: document {k} must be 1-D, got shape "
                f"{a.shape} (pack one row per document, not a batch)")
        if not np.issubdtype(a.dtype, np.integer):
            raise TypeError(
                f"pack_documents: document {k} must have an integer "
                f"dtype, got {a.dtype}")
        arrs.append(a)
    if dtype is None:
        dtype = arrs[0].dtype if arrs else np.uint8
    dtype = np.dtype(dtype)
    if not np.issubdtype(dtype, np.integer):
        raise TypeError(f"pack_documents: dtype must be an integer "
                        f"dtype, got {dtype}")
    info = np.iinfo(dtype)
    for k, a in enumerate(arrs):
        if a.dtype != dtype and a.size and (
                int(a.min()) < info.min or int(a.max()) > info.max):
            raise ValueError(
                f"pack_documents: document {k} has values outside "
                f"{dtype.name} range (min {int(a.min())}, max "
                f"{int(a.max())}) — a silent cast would corrupt it")
    if pad_to_docs is not None:
        if pad_to_docs < len(arrs):
            raise ValueError(
                f"pad_to_docs={pad_to_docs} < {len(arrs)} documents")
        arrs += [np.zeros(0, dtype)] * (pad_to_docs - len(arrs))

    lengths = np.asarray([a.shape[0] for a in arrs], np.int32)
    if doc_tiles is not None:
        if lengths.size and int(lengths.max()) > doc_tiles * block:
            raise ValueError(
                f"document of {int(lengths.max())} elements exceeds "
                f"doc_tiles={doc_tiles} ({doc_tiles * block} elements)")
        spans = np.full(len(arrs), doc_tiles * block, np.int64)
    else:
        spans = np.asarray([_round_up(int(n), block) for n in lengths],
                           np.int64)
    offsets = np.zeros(len(arrs) + 1, np.int32)
    np.cumsum(spans, out=offsets[1:])

    data = np.zeros(int(offsets[-1]), dtype)
    for a, off, n in zip(arrs, offsets[:-1], lengths):
        data[off: off + n] = a.astype(dtype, copy=False)
    return PackedDocs(data, offsets, lengths)


def bucket_boundaries(max_length: int, min_length: int = 8,
                      step: float = 1.5) -> tuple:
    """Length-bucket upper bounds, multiplicatively spaced (the
    tensor2tensor ``bucket_by_sequence_length`` boundary scheme).

    Returns an increasing tuple of inclusive upper bounds ending exactly
    at ``max_length``; a sequence of length ``L`` belongs to the first
    bucket whose bound is ``>= L`` (``bisect_left``).  The serve engine
    buckets its admission queues with this so prompts pad to their
    bucket's bound instead of the global maximum — padded prefill waste
    collapses and the compile cache holds one cell per bucket, not one
    per distinct length.
    """
    if max_length < 1:
        raise ValueError(f"max_length must be >= 1, got {max_length}")
    if step <= 1.0:
        raise ValueError(f"step must be > 1.0, got {step}")
    bounds = []
    x = max(1, min(min_length, max_length))
    while x < max_length:
        bounds.append(x)
        x = max(x + 1, int(x * step))
    bounds.append(max_length)
    return tuple(bounds)


def unpack_results(buffer, out_offsets, counts) -> list:
    """Split a dense ragged output back into per-document numpy arrays.

    ``buffer`` holds the documents' outputs back to back:
    document ``d`` occupies ``[out_offsets[d], out_offsets[d] +
    counts[d])``.  Slices are clamped to the buffer capacity (a
    speculative count on garbage input under ``errors="strict"`` can
    exceed it, exactly as the single-document transcoder's ``count`` can
    exceed its fixed capacity).
    """
    buffer = np.asarray(buffer)
    out_offsets = np.asarray(out_offsets)
    counts = np.asarray(counts)
    docs = []
    for d in range(counts.shape[0]):
        lo = int(out_offsets[d])
        hi = min(lo + int(counts[d]), buffer.shape[0])
        docs.append(buffer[lo: max(hi, lo)])
    return docs


def tile_ownership(offsets: jax.Array, lengths: jax.Array, nblk: int,
                   block: int = TILE):
    """Device-side tile -> document ownership map of a packed batch.

    Args:
      offsets: int32 [B+1] tile-aligned document starts.
      lengths: int32 [B] logical lengths.
      nblk: static tile count of the (padded) packed buffer.
      block: tile width.

    Returns ``(tile_doc, tile_end, same_prev, same_next)``:
      tile_doc  -- int32 [nblk], owning document of each tile (tiles past
                   the last document clamp to B-1; their ``tile_end``
                   precedes them, so no lane in them is ever live).
      tile_end  -- int32 [nblk], global end offset of the tile's document
                   (``offsets[doc] + lengths[doc]``): the live mask is
                   ``global_index < tile_end``.
      same_prev / same_next -- int32 [nblk] 0/1 flags: the neighbouring
                   tile belongs to the same document.  The kernels
                   multiply neighbour-tile inflow by these, so a
                   character can never claim bytes across a document
                   boundary (the packed analogue of the zero boundary
                   tiles of the single-document pipeline).
    """
    offsets = jnp.asarray(offsets, jnp.int32)
    lengths = jnp.asarray(lengths, jnp.int32)
    n_docs = offsets.shape[0] - 1
    tile_start = jnp.arange(nblk, dtype=jnp.int32) * block
    tile_doc = jnp.clip(
        jnp.searchsorted(offsets[1:], tile_start, side="right"),
        0, n_docs - 1).astype(jnp.int32)
    tile_end = (offsets[:-1] + lengths)[tile_doc]
    same = (tile_doc[1:] == tile_doc[:-1]).astype(jnp.int32)
    zero = jnp.zeros((1,), jnp.int32)
    same_prev = jnp.concatenate([zero, same])
    same_next = jnp.concatenate([same, zero])
    return tile_doc, tile_end, same_prev, same_next
