"""Public transcoding API (paper's contribution, as composable JAX ops).

All functions are shape-polymorphic in the *static* buffer capacity and take
an explicit ``n_valid`` scalar for the logical length, so they jit cleanly
and batch with ``vmap`` / shard with ``pjit``.  Outputs are a
:class:`repro.core.result.TranscodeResult` ``(buffer, count, status)``: a
fixed-capacity buffer, the number of meaningful elements, and an int32
simdutf-style status — -1 for a valid stream, else the input offset of the
first invalid maximal subpart, with Python ``UnicodeDecodeError.start``
semantics (bytes for UTF-8/Latin-1, code units for UTF-16, code points for
UTF-32).  For Latin-1 *egress* the status additionally reports the first
unencodable code point, at the offset of its source lead (CPython
``UnicodeEncodeError.start`` mapped to source elements).

The codec matrix (DESIGN.md §8): :func:`transcode` dispatches any
``(src_format, dst_format)`` pair over the ``utf8`` / ``utf16`` / ``utf32``
/ ``latin1`` formats — every pair runs through ONE generic decode×encode
composition per strategy (the stage driver of ``repro.kernels.stages`` on
the fused path, the shared speculative-decode + global-compaction body on
the block-parallel path).  Format names accept the codecs-module aliases
(``"utf-8"``, ``"utf-16-le"``, ``"utf-32-le"``, ``"latin-1"`` /
``"iso-8859-1"``).

Error policy (the ``errors=`` kwarg; full table in DESIGN.md §4):

  * ``"strict"``  (default) -- historical behavior: the buffer holds the
    speculative transcode and ``status`` reports where the stream broke;
    callers reject invalid input wholesale.
  * ``"replace"`` -- lossy ingestion: each maximal subpart of an
    ill-formed sequence (W3C / CPython substitution semantics) emits one
    U+FFFD — and each Latin-1-unencodable code point one ``?`` — and the
    transcode completes at full speed; ``status`` still reports the first
    substitution offset.

Strategies (the ``strategy=`` kwarg; full decision table in DESIGN.md §5):

  * ``onepass`` (matrix + per-doc default) -- single-launch Pallas
    pipeline (DESIGN.md §9): one read + one decode of the input, with
    the inter-tile output offsets carried as a scalar in SMEM across the
    sequential grid and a per-tile ASCII fast path.  Bit-identical to
    ``fused``.
  * ``fused``  -- two-pass Pallas pipeline (count launch + inter-launch
    cumsum + write launch) with hierarchical in-kernel compaction and
    narrow (uint8/uint16/uint32) I/O; validation is folded into the
    counting scan.  The kernel reference ``onepass`` is pinned against.
  * ``blockparallel``    -- speculative per-position decode + global XLA
    cumsum compaction; fully branch-free, pure-jnp (no Pallas), the
    portable beyond-paper form and the semantic reference.
  * ``windowed``         -- the paper-faithful Algorithm 2/3 structure
    (see ``repro.core.windowed``); serial window walk, the measured
    baseline.  UTF-8<->UTF-16 only, ``errors="strict"`` only.

The ASCII fast path of Algorithm 3 survives as a whole-chunk ``lax.cond``:
ASCII values are numerically identical in every matrix format, so
ASCII-pure chunks (the paper's Latin benchmark) reduce to a widening copy.
"""

from __future__ import annotations

import warnings

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import compaction, latin1 as l1mod, result as R
from repro.core import utf16 as u16mod, utf32 as u32mod, utf8 as u8mod
from repro.core.result import STATUS_OK, TranscodeResult  # noqa: F401  (re-export)


def _as_i32(x):
    return x.astype(jnp.int32)


def _n(x, n_valid):
    return x.shape[0] if n_valid is None else n_valid


_check_errors = R.check_errors_policy


def _check_input(x, what: str = "transcode"):
    """Reject wrong-dtype / wrong-rank inputs with a clear diagnosis
    instead of producing garbage downstream (lists are converted; jax
    arrays and tracers pass through untouched — a vmapped row is 1-D).
    """
    if not hasattr(x, "dtype"):
        x = np.asarray(x)
    if not jnp.issubdtype(x.dtype, jnp.integer):
        raise TypeError(
            f"{what}: input must have an integer dtype (narrow wire "
            f"dtype or int32), got {x.dtype}")
    if x.ndim != 1:
        raise ValueError(
            f"{what}: input must be 1-D (one document; use the ragged/"
            f"batched entry points for batches), got shape {x.shape}")
    return x


# Min-reduce of a per-position error map over the live region; the one
# definition lives next to the status semantics in core/result.py.
_first_error_status = R.first_error_status


# ---------------------------------------------------------------------------
# The codec matrix: formats, aliases and static capacity conventions.
# (``repro.kernels.stages`` imports these — the kernel registry and the
# public dispatch share one source of truth.)

FORMATS = ("utf8", "utf16", "utf32", "latin1")

_FORMAT_ALIASES = {
    "utf8": "utf8", "utf-8": "utf8",
    "utf16": "utf16", "utf-16": "utf16", "utf-16-le": "utf16",
    "utf16-le": "utf16", "utf16le": "utf16",
    "utf32": "utf32", "utf-32": "utf32", "utf-32-le": "utf32",
    "utf32-le": "utf32", "utf32le": "utf32",
    "latin1": "latin1", "latin-1": "latin1", "latin": "latin1",
    "iso-8859-1": "latin1", "iso8859-1": "latin1",
}

# Output capacity per input element for each (src, dst) pair: enough for
# every *valid* stream; speculative garbage beyond it drops at capacity
# in all strategies alike.
CAP_FACTOR = {
    ("utf8", "utf16"): 1, ("utf8", "utf32"): 1, ("utf8", "latin1"): 1,
    ("utf16", "utf8"): 3, ("utf16", "utf32"): 1, ("utf16", "latin1"): 1,
    ("utf32", "utf8"): 4, ("utf32", "utf16"): 2, ("utf32", "latin1"): 1,
    ("latin1", "utf8"): 2, ("latin1", "utf16"): 1, ("latin1", "utf32"): 1,
}

PAIRS = tuple(sorted(CAP_FACTOR))

# The strategy registry: every name `transcode` dispatches, in preference
# order.  `onepass` is the default (single launch, single decode);
# `fused` stays selectable as the two-pass kernel reference; the scan
# entry points accept the same names (onepass/fused share one counting
# kernel there).
STRATEGIES = ("onepass", "fused", "blockparallel", "windowed")

# The ragged (packed-batch) entry point additionally accepts "sharded":
# the packed stream split across a device mesh's data axis with one
# onepass launch per shard (repro.core.shard, DESIGN.md §12).
RAGGED_STRATEGIES = ("onepass", "fused", "sharded")

DEFAULT_STRATEGY = "onepass"

# The per-pair convenience wrappers below are DEPRECATED (DESIGN.md §11):
# the public surface is the four generic entry points (``transcode`` /
# ``scan`` / ``ragged_transcode`` / ``ragged_scan``) plus the streaming
# API.  Each name here is a one-line shim that emits a
# ``DeprecationWarning`` attributed to ITS CALLER (stacklevel past the
# shim), so CI can run with ``-W error::DeprecationWarning:repro`` and
# fail on internal use while external callers merely see the warning.
# The shims preserve their historical default strategies bit-for-bit.
DEPRECATED = (
    "utf8_to_utf16", "utf8_to_utf32", "utf8_to_latin1",
    "latin1_to_utf8", "latin1_to_utf16",
    "utf16_to_utf8", "utf16_to_utf32",
    "utf32_to_utf8", "utf32_to_utf16",
    "transcode_utf8_to_utf16", "transcode_utf16_to_utf8",
    "ragged_utf8_to_utf16", "ragged_utf16_to_utf8",
    "ragged_scan_utf8", "ragged_scan_utf16",
    "scan_utf8", "scan_utf16",
)


def _warn_deprecated(name: str, repl: str):
    warnings.warn(
        f"repro.core.transcode.{name}() is deprecated; use {repl}",
        DeprecationWarning, stacklevel=3)


def normalize_format(name: str) -> str:
    """Resolve a format name or codecs-style alias to its canonical name."""
    try:
        return _FORMAT_ALIASES[str(name).lower()]
    except KeyError:
        raise ValueError(
            f"unknown format {name!r}; supported: {list(FORMATS)} "
            f"(and codecs aliases like 'utf-16-le')")


def _check_pair(src: str, dst: str):
    if (src, dst) not in CAP_FACTOR:
        raise ValueError(
            f"unsupported format pair {src!r} -> {dst!r}; "
            f"supported pairs: {list(PAIRS)}")
    return CAP_FACTOR[(src, dst)]


# ---------------------------------------------------------------------------
# Validation

def validate_utf8(b, n_valid=None):
    """Scalar bool: is the byte stream valid UTF-8 (Keiser-Lemire)."""
    return u8mod.validate_kl(_as_i32(b), n_valid)


def validate_utf16(u, n_valid=None):
    return u16mod.validate(_as_i32(u), n_valid)


# ---------------------------------------------------------------------------
# Block-parallel matrix body: whole-array speculative decode + analysis
# per source format, candidate production per destination format, global
# XLA compaction.  This is the pure-jnp semantic reference every fused
# cell is pinned to bit-for-bit.


def _src_decode(src: str, x):
    """Speculative whole-array decode: ``(cp, lead_mask)``."""
    if src == "utf8":
        cp, is_lead, _err = u8mod.decode_speculative(x)
        return cp, is_lead
    if src == "utf16":
        cp, is_lead, _err = u16mod.decode_speculative(x)
        return cp, is_lead
    if src == "utf32":
        # Unrepresentable scalars substitute U+FFFD in the buffer even
        # under errors="strict" (status still locates the offender), so
        # the speculative output is a well-defined narrow value in every
        # strategy.
        return jnp.where(u32mod.invalid_scalar(x), 0xFFFD, x), \
            jnp.ones(x.shape, bool)
    # latin1: every byte is a code point.
    return x, jnp.ones(x.shape, bool)


def _src_analyze(src: str, x):
    """Whole-array maximal-subpart analysis: {starts, valid, cp, err}."""
    if src == "utf8":
        return u8mod.analyze(x)
    if src == "utf16":
        return u16mod.analyze(x)
    if src == "utf32":
        bad = u32mod.invalid_scalar(x)
        return {"starts": jnp.ones(x.shape, bool), "valid": ~bad,
                "cp": jnp.where(bad, 0xFFFD, x), "err": bad}
    ones = jnp.ones(x.shape, bool)
    return {"starts": ones, "valid": ones, "cp": x,
            "err": jnp.zeros(x.shape, bool)}


def _dst_encode(dst: str, cp):
    """Candidate production: ``(lengths, values[N, K], encode_bad)``."""
    if dst == "utf16":
        units, u0, u1, _bad = u16mod.encode_candidates(cp)
        return units, jnp.stack([u0, u1], -1), None
    if dst == "utf8":
        L, cand, _bad = u32mod.encode_utf8_candidates(cp)
        return L, cand, None
    if dst == "utf32":
        return jnp.ones_like(cp), cp[..., None], None
    L, byte, bad = l1mod.encode_candidates(cp)
    return L, byte[..., None], bad


def _blockparallel_pair(x, n_valid, src: str, dst: str, validate: bool,
                        errors: str, ascii_fastpath: bool = True):
    """Generic block-parallel (src, dst) transcode; see module docstring."""
    factor = _check_pair(src, dst)
    x = _mask_padding(_as_i32(x), n_valid)
    n = _n(x, n_valid)
    cap = factor * x.shape[0]
    idx = jnp.arange(x.shape[0])

    def general(x):
        need_analysis = validate or errors == "replace"
        a = _src_analyze(src, x) if need_analysis else None
        if errors == "replace":
            cp, mask = a["cp"], a["starts"] & (idx < n)
        else:
            cp, is_lead = _src_decode(src, x)
            mask = is_lead & (idx < n)
        lens, vals, enc_bad = _dst_encode(dst, cp)
        out, count = compaction.compact_offsets(vals, lens, mask, cap)
        if validate:
            err_map = a["err"]
            if enc_bad is not None:
                _l, _v, a_bad = _dst_encode(dst, a["cp"])
                err_map = err_map | (a_bad & a["starts"])
            status = _first_error_status(err_map, n)
        else:
            status = jnp.int32(STATUS_OK)
        return TranscodeResult(out, count, status)

    def ascii(x):
        # Paper Algorithm 3 fast path: ASCII values are numerically
        # identical in every matrix format — a widening copy.
        out = x if cap == x.shape[0] else jnp.concatenate(
            [x, jnp.zeros((cap - x.shape[0],), x.dtype)])
        return TranscodeResult(out, jnp.asarray(n, jnp.int32),
                               jnp.int32(STATUS_OK))

    if not ascii_fastpath:
        return general(x)
    # The lower bound matters: lanes are int32 here, so a garbage UTF-32
    # scalar like 0xFFFFFFFF wraps negative and would pass a bare
    # ``x < 0x80`` (the fused path compares in the unsigned narrow dtype
    # and needs no guard).
    return jax.lax.cond(jnp.all((x >= 0) & (x < 0x80)), ascii, general, x)


def _blockparallel_count(x, n_valid, src: str, dst: str):
    """Single-scan validation + capacity, pure jnp: ``(count, status)``."""
    _check_pair(src, dst)
    x = _mask_padding(_as_i32(x), n_valid)
    n = _n(x, n_valid)
    idx = jnp.arange(x.shape[0])
    cp, is_lead = _src_decode(src, x)
    lens, _vals, _bad = _dst_encode(dst, cp)
    count = jnp.sum(jnp.where(is_lead & (idx < n), lens, 0))
    a = _src_analyze(src, x)
    err_map = a["err"]
    _l, _v, a_bad = _dst_encode(dst, a["cp"])
    if a_bad is not None:
        err_map = err_map | (a_bad & a["starts"])
    return count, _first_error_status(err_map, n)


def scan_utf8(b, n_valid=None, *, strategy: str = DEFAULT_STRATEGY):
    """DEPRECATED shim: use :func:`scan` with ``dst_format="utf16"``."""
    _warn_deprecated("scan_utf8", 'scan(b, "utf16", src_format="utf8")')
    return scan(b, "utf16", src_format="utf8", n_valid=n_valid,
                strategy=strategy)


def scan_utf16(u, n_valid=None, *, strategy: str = DEFAULT_STRATEGY):
    """DEPRECATED shim: use :func:`scan` with ``dst_format="utf8"``."""
    _warn_deprecated("scan_utf16", 'scan(u, "utf8", src_format="utf16")')
    return scan(u, "utf8", src_format="utf16", n_valid=n_valid,
                strategy=strategy)


def scan(x, dst_format, *, src_format: str = "utf8", n_valid=None,
         strategy: str = DEFAULT_STRATEGY):
    """Single-scan validation + destination capacity for any matrix cell.

    One read of the input yields ``(count, status)``: the number of
    ``dst_format`` units a transcode would produce and the simdutf-style
    verdict (DESIGN.md §4) — the ingestion-boundary query.
    """
    x = _check_input(x, "scan")
    src = normalize_format(src_format)
    dst = normalize_format(dst_format)
    _check_pair(src, dst)
    if strategy in ("onepass", "fused"):
        # The counting pass is already single-launch/single-read — the
        # one-pass strategy's scan IS the fused scan (see
        # repro.kernels.onepass_transcode.scan_onepass).
        from repro.kernels import fused_transcode
        return fused_transcode.scan_fused(x, n_valid, src=src, dst=dst)
    if strategy != "blockparallel":
        raise ValueError(f"scan: unknown strategy {strategy!r}")
    return _blockparallel_count(x, n_valid, src, dst)


# ---------------------------------------------------------------------------
# UTF-8 -> UTF-32 / UTF-16


def _mask_padding(b, n_valid):
    if n_valid is None:
        return b
    idx = jnp.arange(b.shape[0])
    return jnp.where(idx < n_valid, b, 0)


def utf8_to_utf32(b, n_valid=None, validate: bool = True,
                  errors: str = "strict", *,
                  strategy: str = "blockparallel"):
    """DEPRECATED shim: use :func:`transcode` (``dst_format="utf32"``).
    Historical default strategy: ``blockparallel``."""
    _warn_deprecated("utf8_to_utf32",
                     'transcode(b, "utf32", src_format="utf8")')
    return transcode(b, "utf32", src_format="utf8", n_valid=n_valid,
                     strategy=strategy, validate=validate, errors=errors)


def utf8_to_utf16(b, n_valid=None, validate: bool = True,
                  ascii_fastpath: bool = True, errors: str = "strict"):
    """DEPRECATED shim: use :func:`transcode` with
    ``strategy="blockparallel"`` (this wrapper WAS the pure-jnp
    block-parallel reference cell)."""
    _warn_deprecated(
        "utf8_to_utf16",
        'transcode(b, "utf16", src_format="utf8", strategy="blockparallel")')
    if not ascii_fastpath:
        # The generic surface has no ascii_fastpath switch (it is a
        # kernel-level knob); keep the legacy escape hatch bit-exact.
        _check_errors(errors)
        return _blockparallel_pair(b, n_valid, "utf8", "utf16", validate,
                                   errors, ascii_fastpath=False)
    return transcode(b, "utf16", src_format="utf8", n_valid=n_valid,
                     strategy="blockparallel", validate=validate,
                     errors=errors)


def utf8_to_latin1(b, n_valid=None, validate: bool = True,
                   errors: str = "strict", *, strategy: str = "fused"):
    """DEPRECATED shim: use :func:`transcode` (``dst_format="latin1"``).
    Historical default strategy: ``fused``."""
    _warn_deprecated("utf8_to_latin1",
                     'transcode(b, "latin1", src_format="utf8")')
    return transcode(b, "latin1", src_format="utf8", n_valid=n_valid,
                     strategy=strategy, validate=validate, errors=errors)


def latin1_to_utf8(b, n_valid=None, validate: bool = True,
                   errors: str = "strict", *, strategy: str = "fused"):
    """DEPRECATED shim: use :func:`transcode` (``src_format="latin1"``).
    Historical default strategy: ``fused``."""
    _warn_deprecated("latin1_to_utf8",
                     'transcode(b, "utf8", src_format="latin1")')
    return transcode(b, "utf8", src_format="latin1", n_valid=n_valid,
                     strategy=strategy, validate=validate, errors=errors)


def latin1_to_utf16(b, n_valid=None, validate: bool = True,
                    errors: str = "strict", *, strategy: str = "fused"):
    """DEPRECATED shim: use :func:`transcode` (``src_format="latin1"``).
    Historical default strategy: ``fused``."""
    _warn_deprecated("latin1_to_utf16",
                     'transcode(b, "utf16", src_format="latin1")')
    return transcode(b, "utf16", src_format="latin1", n_valid=n_valid,
                     strategy=strategy, validate=validate, errors=errors)


# ---------------------------------------------------------------------------
# UTF-16 -> UTF-32 / UTF-8


def utf16_to_utf32(u, n_valid=None, validate: bool = True,
                   errors: str = "strict", *,
                   strategy: str = "blockparallel"):
    """DEPRECATED shim: use :func:`transcode` (``dst_format="utf32"``).
    Historical default strategy: ``blockparallel``."""
    _warn_deprecated("utf16_to_utf32",
                     'transcode(u, "utf32", src_format="utf16")')
    return transcode(u, "utf32", src_format="utf16", n_valid=n_valid,
                     strategy=strategy, validate=validate, errors=errors)


def utf16_to_utf8(u, n_valid=None, validate: bool = True,
                  ascii_fastpath: bool = True, errors: str = "strict"):
    """DEPRECATED shim: use :func:`transcode` with
    ``strategy="blockparallel"`` (this wrapper WAS the pure-jnp
    block-parallel reference cell)."""
    _warn_deprecated(
        "utf16_to_utf8",
        'transcode(u, "utf8", src_format="utf16", strategy="blockparallel")')
    if not ascii_fastpath:
        _check_errors(errors)
        return _blockparallel_pair(u, n_valid, "utf16", "utf8", validate,
                                   errors, ascii_fastpath=False)
    return transcode(u, "utf8", src_format="utf16", n_valid=n_valid,
                     strategy="blockparallel", validate=validate,
                     errors=errors)


# ---------------------------------------------------------------------------
# UTF-32 egress


def _invalid_scalar(cp):
    """Code points no encoding may represent: surrogates, > U+10FFFF,
    negatives.  (Single definition: ``repro.core.utf32.invalid_scalar``.)"""
    return u32mod.invalid_scalar(cp)


def utf32_to_utf8(cp, n_valid=None, validate: bool = True,
                  errors: str = "strict", *,
                  strategy: str = "blockparallel"):
    """DEPRECATED shim: use :func:`transcode` (``src_format="utf32"``).
    Historical default strategy: ``blockparallel``."""
    _warn_deprecated("utf32_to_utf8",
                     'transcode(cp, "utf8", src_format="utf32")')
    return transcode(cp, "utf8", src_format="utf32", n_valid=n_valid,
                     strategy=strategy, validate=validate, errors=errors)


def utf32_to_utf16(cp, n_valid=None, validate: bool = True,
                   errors: str = "strict", *,
                   strategy: str = "blockparallel"):
    """DEPRECATED shim: use :func:`transcode` (``src_format="utf32"``).
    Historical default strategy: ``blockparallel``."""
    _warn_deprecated("utf32_to_utf16",
                     'transcode(cp, "utf16", src_format="utf32")')
    return transcode(cp, "utf16", src_format="utf32", n_valid=n_valid,
                     strategy=strategy, validate=validate, errors=errors)


# ---------------------------------------------------------------------------
# Length counting (simdutf-style capacity queries)


def _mask_padding_cont(b, n_valid):
    """Mask padding with a continuation byte (counts as 0 characters)."""
    if n_valid is None:
        return b
    idx = jnp.arange(b.shape[0])
    return jnp.where(idx < n_valid, b, 0x80)


def utf16_length_from_utf8(b, n_valid=None):
    b = _mask_padding_cont(_as_i32(b), n_valid)
    return u8mod.utf16_length(b)


def utf8_length_from_utf16(u, n_valid=None):
    u = _as_i32(u)
    if n_valid is not None:
        idx = jnp.arange(u.shape[0])
        # 0xDC00 (lone low surrogate) contributes 2 bytes; use a masked sum
        # instead: zero units count 1 byte each, so subtract the padding.
        pad = jnp.sum((idx >= n_valid).astype(jnp.int32))
        u = jnp.where(idx < n_valid, u, 0)
        return u16mod.utf8_length(u) - pad
    return u16mod.utf8_length(u)


def count_utf8_chars(b, n_valid=None):
    b = _mask_padding_cont(_as_i32(b), n_valid)
    return u8mod.count_chars(b)


# ---------------------------------------------------------------------------
# Byte-level helpers (LE byte buffers <-> unit arrays).  All are explicit
# little-endian jnp byte math — no ``.view()`` / ``frombuffer`` host-
# endianness dependence anywhere on the wire path.


def utf16le_bytes_to_units(by):
    """UTF-16LE byte buffer -> int32 unit array (explicit LE byte math)."""
    by = _as_i32(by)
    if by.shape[0] % 2:
        raise ValueError(
            f"utf16le_bytes_to_units: odd byte length {by.shape[0]}")
    return by[0::2] | (by[1::2] << 8)


def units_to_utf16le_bytes(u):
    """int32/uint16 unit array -> UTF-16LE byte array (explicit LE)."""
    u = _as_i32(u)
    lo = u & 0xFF
    hi = (u >> 8) & 0xFF
    return jnp.stack([lo, hi], -1).reshape(-1)


def utf32le_bytes_to_cps(by):
    """UTF-32LE byte buffer -> int32 code-point array (explicit LE)."""
    by = _as_i32(by)
    if by.shape[0] % 4:
        raise ValueError(
            f"utf32le_bytes_to_cps: byte length {by.shape[0]} not a "
            f"multiple of 4")
    return (by[0::4] | (by[1::4] << 8) | (by[2::4] << 16)
            | (by[3::4] << 24))


def cps_to_utf32le_bytes(cp):
    """int32/uint32 code-point array -> UTF-32LE byte array (explicit LE)."""
    cp = _as_i32(cp)
    return jnp.stack([cp & 0xFF, (cp >> 8) & 0xFF, (cp >> 16) & 0xFF,
                      (cp >> 24) & 0xFF], -1).reshape(-1)


# ---------------------------------------------------------------------------
# Strategy dispatch (onepass = single-launch Pallas, fused = two-pass
# Pallas, windowed = paper-faithful; kernels imported lazily to avoid
# circular imports).  The STRATEGIES registry and DEFAULT_STRATEGY live
# next to the format registry above.

# The serial paper baseline exists for the paper's own two directions.
_WINDOWED_PAIRS = {("utf8", "utf16"), ("utf16", "utf8")}


def transcode(src, dst_format, *, src_format: str = "utf8", n_valid=None,
              strategy: str = DEFAULT_STRATEGY, validate: bool = True,
              errors: str = "strict"):
    """Strategy-dispatched transcode for any cell of the codec matrix.

    ``src`` is the input buffer (narrow dtype or int32); ``src_format`` /
    ``dst_format`` name any two distinct formats of ``FORMATS`` (codecs
    aliases accepted).  Returns a :class:`TranscodeResult` whose buffer
    capacity is ``CAP_FACTOR[(src, dst)] * len(src)``.  See the module
    docstring for strategy / ``errors=`` semantics.
    """
    _check_errors(errors)
    src = _check_input(src)
    s = normalize_format(src_format)
    d = normalize_format(dst_format)
    _check_pair(s, d)
    if strategy == "onepass":
        from repro.kernels import onepass_transcode
        return onepass_transcode.transcode_onepass(
            src, n_valid, src=s, dst=d, validate=validate, errors=errors)
    elif strategy == "fused":
        from repro.kernels import fused_transcode
        return fused_transcode.transcode_fused(
            src, n_valid, src=s, dst=d, validate=validate, errors=errors)
    elif strategy == "blockparallel":
        return _blockparallel_pair(src, n_valid, s, d, validate, errors)
    elif strategy == "windowed":
        if (s, d) not in _WINDOWED_PAIRS:
            raise ValueError(
                f"strategy='windowed' (the paper-faithful serial baseline) "
                f"supports utf8<->utf16 only, not {s!r} -> {d!r}")
        if errors != "strict":
            raise ValueError(
                "strategy='windowed' supports errors='strict' only "
                "(the serial baseline has no replacement path)")
        from repro.core import windowed
        if s == "utf8":
            return windowed.utf8_to_utf16_windowed(src, n_valid,
                                                   validate=validate)
        return windowed.utf16_to_utf8_windowed(src, n_valid,
                                               validate=validate)
    raise ValueError(
        f"unknown strategy: {strategy} (supported: {list(STRATEGIES)})")


def transcode_utf8_to_utf16(b, n_valid=None, *, strategy: str = DEFAULT_STRATEGY,
                            validate: bool = True, errors: str = "strict"):
    """DEPRECATED shim: use :func:`transcode` (``dst_format="utf16"``)."""
    _warn_deprecated("transcode_utf8_to_utf16",
                     'transcode(b, "utf16", src_format="utf8")')
    return transcode(b, "utf16", src_format="utf8", n_valid=n_valid,
                     strategy=strategy, validate=validate, errors=errors)


def transcode_utf16_to_utf8(u, n_valid=None, *, strategy: str = DEFAULT_STRATEGY,
                            validate: bool = True, errors: str = "strict"):
    """DEPRECATED shim: use :func:`transcode` (``dst_format="utf8"``)."""
    _warn_deprecated("transcode_utf16_to_utf8",
                     'transcode(u, "utf8", src_format="utf16")')
    return transcode(u, "utf8", src_format="utf16", n_valid=n_valid,
                     strategy=strategy, validate=validate, errors=errors)


# ---------------------------------------------------------------------------
# Ragged packed-batch entry points (one Pallas launch per batch).


def ragged_transcode(data, offsets, lengths, *, src_format: str = "utf8",
                     dst_format: str = "utf16", validate: bool = True,
                     errors: str = "strict",
                     strategy: str = DEFAULT_STRATEGY,
                     n_shards=None, shard_mesh=None, chunk_budget=None):
    """Ragged packed-batch transcode for any matrix cell: ONE launch per
    batch over a :func:`repro.core.packing.pack_documents` layout.

    Returns a :class:`repro.core.result.RaggedTranscodeResult` whose
    per-document slices are bit-identical to the single-document fused
    transcoder; ``errors=`` carries the usual strict/replace policy per
    document.  This is the padding-tax-free batch path (DESIGN.md §7) —
    the padded ``vmap`` form survives in ``repro.data.pipeline`` as the
    reference.  ``strategy="onepass"`` (default) is the single-pass
    launch with the segment scan carried in SMEM (DESIGN.md §9);
    ``strategy="fused"`` keeps the two-launch kernel reference;
    ``strategy="sharded"`` splits the packed batch across the data axis
    of a device mesh with one onepass launch per shard (DESIGN.md §12 —
    ``n_shards`` / ``shard_mesh`` / ``chunk_budget`` apply only there)
    and gathers a bit-identical result.
    """
    if strategy == "sharded":
        from repro.core import shard
        return shard.ragged_transcode_sharded(
            data, offsets, lengths, src_format=src_format,
            dst_format=dst_format, validate=validate, errors=errors,
            n_shards=n_shards, mesh=shard_mesh, chunk_budget=chunk_budget)
    if n_shards is not None or shard_mesh is not None:
        raise ValueError(
            "n_shards/shard_mesh require strategy='sharded'")
    # Single-device strategy validation lives in ONE layer (the kernel
    # dispatch below).
    from repro.kernels import ragged_transcode as rt
    return rt.transcode_ragged(
        data, offsets, lengths, src=normalize_format(src_format),
        dst=normalize_format(dst_format), validate=validate, errors=errors,
        strategy=strategy)


def ragged_scan(data, offsets, lengths, *, src_format: str = "utf8",
                dst_format: str = "utf16"):
    """Per-document single-scan validation + capacity: (counts, statuses)."""
    from repro.kernels import ragged_transcode as rt
    return rt.scan_ragged(
        data, offsets, lengths, src=normalize_format(src_format),
        dst=normalize_format(dst_format))


def ragged_utf8_to_utf16(data, offsets, lengths, *, validate: bool = True,
                         errors: str = "strict",
                         strategy: str = DEFAULT_STRATEGY):
    """DEPRECATED shim: use :func:`ragged_transcode`."""
    _warn_deprecated(
        "ragged_utf8_to_utf16",
        'ragged_transcode(data, offsets, lengths, src_format="utf8", '
        'dst_format="utf16")')
    return ragged_transcode(data, offsets, lengths, src_format="utf8",
                            dst_format="utf16", validate=validate,
                            errors=errors, strategy=strategy)


def ragged_utf16_to_utf8(data, offsets, lengths, *, validate: bool = True,
                         errors: str = "strict",
                         strategy: str = DEFAULT_STRATEGY):
    """DEPRECATED shim: use :func:`ragged_transcode`."""
    _warn_deprecated(
        "ragged_utf16_to_utf8",
        'ragged_transcode(data, offsets, lengths, src_format="utf16", '
        'dst_format="utf8")')
    return ragged_transcode(data, offsets, lengths, src_format="utf16",
                            dst_format="utf8", validate=validate,
                            errors=errors, strategy=strategy)


def ragged_scan_utf8(data, offsets, lengths):
    """DEPRECATED shim: use :func:`ragged_scan`."""
    _warn_deprecated(
        "ragged_scan_utf8",
        'ragged_scan(data, offsets, lengths, src_format="utf8", '
        'dst_format="utf16")')
    return ragged_scan(data, offsets, lengths, src_format="utf8",
                       dst_format="utf16")


def ragged_scan_utf16(data, offsets, lengths):
    """DEPRECATED shim: use :func:`ragged_scan`."""
    _warn_deprecated(
        "ragged_scan_utf16",
        'ragged_scan(data, offsets, lengths, src_format="utf16", '
        'dst_format="utf8")')
    return ragged_scan(data, offsets, lengths, src_format="utf16",
                       dst_format="utf8")


# ---------------------------------------------------------------------------
# Resumable streaming transcode (chunked input, whole-buffer-bit-exact
# results; DESIGN.md §10).  The implementation lives in
# ``repro.core.stream``; re-exported here so the streaming API rides the
# same import as the rest of the matrix.

from repro.core.stream import (  # noqa: E402,F401  (re-export)
    StreamState, finalize as stream_finalize, stream_init,
    transcode_stream, transcode_stream_chunk)
