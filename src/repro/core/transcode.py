"""Public transcoding API (paper's contribution, as composable JAX ops).

All functions are shape-polymorphic in the *static* buffer capacity and take
an explicit ``n_valid`` scalar for the logical length, so they jit cleanly
and batch with ``vmap`` / shard with ``pjit``.  Outputs are (buffer, count,
err): a fixed-capacity buffer, the number of meaningful elements, and a
validation flag.

Strategies (the ``strategy=`` kwarg of ``transcode_utf8_to_utf16`` /
``transcode_utf16_to_utf8``; full decision table in DESIGN.md §5):

  * ``fused`` (default)  -- two-pass Pallas pipeline with hierarchical
    in-kernel compaction and narrow (uint8/uint16) I/O; no full-capacity
    int32 intermediate ever reaches HBM.  The high-performance path
    (``repro.kernels.fused_transcode``).  Output buffers are narrow
    (uint16 units / uint8 bytes); ``buffer[:count]``, ``count`` and
    ``err`` are bit-identical to ``blockparallel``.
  * ``blockparallel``    -- speculative per-position decode + global XLA
    cumsum compaction; fully branch-free, pure-jnp (no Pallas), the
    portable beyond-paper form and the semantic reference.
  * ``windowed``         -- the paper-faithful Algorithm 2/3 structure
    (see ``repro.core.windowed``); serial window walk, the measured
    baseline.

The ASCII fast path of Algorithm 3 survives as a whole-chunk ``lax.cond``:
for ASCII-pure chunks (the paper's Latin benchmark) the entire decode is a
widening copy.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import compaction, utf16 as u16mod, utf32 as u32mod, utf8 as u8mod


def _as_i32(x):
    return x.astype(jnp.int32)


def _n(x, n_valid):
    return x.shape[0] if n_valid is None else n_valid


# ---------------------------------------------------------------------------
# Validation


def validate_utf8(b, n_valid=None):
    """Scalar bool: is the byte stream valid UTF-8 (Keiser-Lemire)."""
    return u8mod.validate_kl(_as_i32(b), n_valid)


def validate_utf16(u, n_valid=None):
    return u16mod.validate(_as_i32(u), n_valid)


# ---------------------------------------------------------------------------
# UTF-8 -> UTF-32 / UTF-16


def _mask_padding(b, n_valid):
    if n_valid is None:
        return b
    idx = jnp.arange(b.shape[0])
    return jnp.where(idx < n_valid, b, 0)


def utf8_to_utf32(b, n_valid=None, validate: bool = True):
    """Decode UTF-8 bytes to code points.

    Returns (cp_buffer[int32, capacity=len(b)], count, err).
    """
    b = _mask_padding(_as_i32(b), n_valid)
    n = _n(b, n_valid)
    cp, is_lead, dec_err = u8mod.decode_speculative(b)
    idx = jnp.arange(b.shape[0])
    mask = is_lead & (idx < n)
    out, count = compaction.compact(cp, mask, b.shape[0])
    err = dec_err if validate else jnp.bool_(False)
    if validate:
        err = err | ~u8mod.validate_kl(b, n_valid)
    return out, count, err


def utf8_to_utf16(b, n_valid=None, validate: bool = True,
                  ascii_fastpath: bool = True):
    """Transcode UTF-8 bytes to UTF-16 code units (little-endian values).

    Returns (u16_buffer[int32, capacity=len(b)], count, err).
    """
    b = _mask_padding(_as_i32(b), n_valid)
    n = _n(b, n_valid)
    cap = b.shape[0]
    idx = jnp.arange(cap)

    def general(b):
        cp, is_lead, dec_err = u8mod.decode_speculative(b)
        mask = is_lead & (idx < n)
        units, u0, u1, _bad = u16mod.encode_candidates(cp)
        vals = jnp.stack([u0, u1], -1)
        out, count = compaction.compact_offsets(vals, units, mask, cap)
        err = dec_err if validate else jnp.bool_(False)
        if validate:
            err = err | ~u8mod.validate_kl(b, None)
        return out, count, err

    def ascii(b):
        # Paper Algorithm 3 fast path: widening copy.
        return b, jnp.asarray(n, jnp.int32), jnp.bool_(False)

    if not ascii_fastpath:
        return general(b)
    all_ascii = jnp.all(b < 0x80)
    return jax.lax.cond(all_ascii, ascii, general, b)


# ---------------------------------------------------------------------------
# UTF-16 -> UTF-32 / UTF-8


def utf16_to_utf32(u, n_valid=None, validate: bool = True):
    u = _mask_padding(_as_i32(u), n_valid)
    n = _n(u, n_valid)
    cp, is_lead, err = u16mod.decode_speculative(u)
    idx = jnp.arange(u.shape[0])
    mask = is_lead & (idx < n)
    out, count = compaction.compact(cp, mask, u.shape[0])
    if not validate:
        err = jnp.bool_(False)
    return out, count, err


def utf16_to_utf8(u, n_valid=None, validate: bool = True,
                  ascii_fastpath: bool = True):
    """Transcode UTF-16 units to UTF-8 bytes.

    Returns (byte_buffer[int32, capacity=3*len(u)], count, err).
    """
    u = _mask_padding(_as_i32(u), n_valid)
    n = _n(u, n_valid)
    cap = 3 * u.shape[0]
    idx = jnp.arange(u.shape[0])

    def general(u):
        cp, is_lead, dec_err = u16mod.decode_speculative(u)
        mask = is_lead & (idx < n)
        L, cand, bad = u32mod.encode_utf8_candidates(cp)
        out, count = compaction.compact_offsets(cand, L, mask, cap)
        err = (dec_err | jnp.any(bad & mask)) if validate else jnp.bool_(False)
        return out, count, err

    def ascii(u):
        out = jnp.concatenate([u, jnp.zeros((cap - u.shape[0],), u.dtype)])
        return out, jnp.asarray(n, jnp.int32), jnp.bool_(False)

    if not ascii_fastpath:
        return general(u)
    all_ascii = jnp.all(u < 0x80)
    return jax.lax.cond(all_ascii, ascii, general, u)


# ---------------------------------------------------------------------------
# UTF-32 egress


def utf32_to_utf8(cp, n_valid=None, validate: bool = True):
    cp = _mask_padding(_as_i32(cp), n_valid)
    n = _n(cp, n_valid)
    cap = 4 * cp.shape[0]
    idx = jnp.arange(cp.shape[0])
    mask = idx < n
    L, cand, bad = u32mod.encode_utf8_candidates(cp)
    out, count = compaction.compact_offsets(cand, L, mask, cap)
    return out, count, (jnp.any(bad & mask) if validate else jnp.bool_(False))


def utf32_to_utf16(cp, n_valid=None, validate: bool = True):
    cp = _mask_padding(_as_i32(cp), n_valid)
    n = _n(cp, n_valid)
    cap = 2 * cp.shape[0]
    idx = jnp.arange(cp.shape[0])
    mask = idx < n
    units, u0, u1, bad = u16mod.encode_candidates(cp)
    vals = jnp.stack([u0, u1], -1)
    out, count = compaction.compact_offsets(vals, units, mask, cap)
    return out, count, (jnp.any(bad & mask) if validate else jnp.bool_(False))


# ---------------------------------------------------------------------------
# Length counting (simdutf-style capacity queries)


def _mask_padding_cont(b, n_valid):
    """Mask padding with a continuation byte (counts as 0 characters)."""
    if n_valid is None:
        return b
    idx = jnp.arange(b.shape[0])
    return jnp.where(idx < n_valid, b, 0x80)


def utf16_length_from_utf8(b, n_valid=None):
    b = _mask_padding_cont(_as_i32(b), n_valid)
    return u8mod.utf16_length(b)


def utf8_length_from_utf16(u, n_valid=None):
    u = _as_i32(u)
    if n_valid is not None:
        idx = jnp.arange(u.shape[0])
        # 0xDC00 (lone low surrogate) contributes 2 bytes; use a masked sum
        # instead: zero units count 1 byte each, so subtract the padding.
        pad = jnp.sum((idx >= n_valid).astype(jnp.int32))
        u = jnp.where(idx < n_valid, u, 0)
        return u16mod.utf8_length(u) - pad
    return u16mod.utf8_length(u)


def count_utf8_chars(b, n_valid=None):
    b = _mask_padding_cont(_as_i32(b), n_valid)
    return u8mod.count_chars(b)


# ---------------------------------------------------------------------------
# Byte-level helpers (UTF-16LE byte buffers <-> unit arrays)


def utf16le_bytes_to_units(by):
    by = _as_i32(by)
    return by[0::2] | (by[1::2] << 8)


def units_to_utf16le_bytes(u):
    u = _as_i32(u)
    lo = u & 0xFF
    hi = (u >> 8) & 0xFF
    return jnp.stack([lo, hi], -1).reshape(-1)


# ---------------------------------------------------------------------------
# Strategy dispatch (fused = Pallas two-pass, windowed = paper-faithful;
# both imported lazily to avoid circular imports).

DEFAULT_STRATEGY = "fused"


def transcode_utf8_to_utf16(b, n_valid=None, *, strategy: str = DEFAULT_STRATEGY,
                            validate: bool = True):
    """Strategy-dispatched UTF-8 -> UTF-16.  See module docstring."""
    if strategy == "fused":
        from repro.kernels import fused_transcode
        return fused_transcode.utf8_to_utf16_fused(b, n_valid,
                                                   validate=validate)
    elif strategy == "blockparallel":
        return utf8_to_utf16(b, n_valid, validate=validate)
    elif strategy == "windowed":
        from repro.core import windowed
        return windowed.utf8_to_utf16_windowed(b, n_valid, validate=validate)
    raise ValueError(f"unknown strategy: {strategy}")


def transcode_utf16_to_utf8(u, n_valid=None, *, strategy: str = DEFAULT_STRATEGY,
                            validate: bool = True):
    """Strategy-dispatched UTF-16 -> UTF-8.  See module docstring."""
    if strategy == "fused":
        from repro.kernels import fused_transcode
        return fused_transcode.utf16_to_utf8_fused(u, n_valid,
                                                   validate=validate)
    elif strategy == "blockparallel":
        return utf16_to_utf8(u, n_valid, validate=validate)
    elif strategy == "windowed":
        from repro.core import windowed
        return windowed.utf16_to_utf8_windowed(u, n_valid, validate=validate)
    raise ValueError(f"unknown strategy: {strategy}")
