"""Public transcoding API (paper's contribution, as composable JAX ops).

All functions are shape-polymorphic in the *static* buffer capacity and take
an explicit ``n_valid`` scalar for the logical length, so they jit cleanly
and batch with ``vmap`` / shard with ``pjit``.  Outputs are a
:class:`repro.core.result.TranscodeResult` ``(buffer, count, status)``: a
fixed-capacity buffer, the number of meaningful elements, and an int32
simdutf-style status — -1 for a valid stream, else the input offset of the
first invalid maximal subpart, with Python ``UnicodeDecodeError.start``
semantics (bytes for UTF-8, code units for UTF-16).

Error policy (the ``errors=`` kwarg; full table in DESIGN.md §4):

  * ``"strict"``  (default) -- historical behavior: the buffer holds the
    speculative transcode and ``status`` reports where the stream broke;
    callers reject invalid input wholesale.
  * ``"replace"`` -- lossy ingestion: each maximal subpart of an
    ill-formed sequence (W3C / CPython substitution semantics) emits one
    U+FFFD and the transcode completes at full speed; ``status`` still
    reports the first substitution offset.

Strategies (the ``strategy=`` kwarg of ``transcode_utf8_to_utf16`` /
``transcode_utf16_to_utf8``; full decision table in DESIGN.md §5):

  * ``fused`` (default)  -- two-pass Pallas pipeline with hierarchical
    in-kernel compaction and narrow (uint8/uint16) I/O; validation (the
    Keiser-Lemire nibble tables + the maximal-subpart error locator) is
    folded into the counting scan, so no standalone validation pass ever
    re-reads the input.  Output buffers are narrow (uint16 units / uint8
    bytes); ``buffer[:count]``, ``count`` and ``status`` are
    bit-identical to ``blockparallel``.
  * ``blockparallel``    -- speculative per-position decode + global XLA
    cumsum compaction; fully branch-free, pure-jnp (no Pallas), the
    portable beyond-paper form and the semantic reference.
  * ``windowed``         -- the paper-faithful Algorithm 2/3 structure
    (see ``repro.core.windowed``); serial window walk, the measured
    baseline.  Supports ``errors="strict"`` only.

The ASCII fast path of Algorithm 3 survives as a whole-chunk ``lax.cond``:
for ASCII-pure chunks (the paper's Latin benchmark) the entire decode is a
widening copy.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import compaction, result as R
from repro.core import utf16 as u16mod, utf32 as u32mod, utf8 as u8mod
from repro.core.result import STATUS_OK, TranscodeResult  # noqa: F401  (re-export)


def _as_i32(x):
    return x.astype(jnp.int32)


def _n(x, n_valid):
    return x.shape[0] if n_valid is None else n_valid


_check_errors = R.check_errors_policy


# Min-reduce of a per-position error map over the live region; the one
# definition lives next to the status semantics in core/result.py.
_first_error_status = R.first_error_status


# ---------------------------------------------------------------------------
# Validation


def validate_utf8(b, n_valid=None):
    """Scalar bool: is the byte stream valid UTF-8 (Keiser-Lemire)."""
    return u8mod.validate_kl(_as_i32(b), n_valid)


def validate_utf16(u, n_valid=None):
    return u16mod.validate(_as_i32(u), n_valid)


def scan_utf8(b, n_valid=None, *, strategy: str = "fused"):
    """Single-scan UTF-8 validation + UTF-16 capacity: ``(count, status)``.

    ``status`` is -1 for valid streams, else the byte offset of the first
    invalid maximal subpart (Python ``UnicodeDecodeError.start``);
    ``count`` is the UTF-16 code units a transcode would emit.  The fused
    strategy reads the input exactly once (the pipeline's counting pass
    with its folded validation); ``blockparallel`` is the pure-jnp
    reference with identical results.
    """
    if strategy == "fused":
        from repro.kernels import fused_transcode
        return fused_transcode.utf8_scan_fused(b, n_valid)
    if strategy != "blockparallel":
        raise ValueError(f"scan_utf8: unknown strategy {strategy!r}")
    b = _mask_padding(_as_i32(b), n_valid)
    n = _n(b, n_valid)
    idx = jnp.arange(b.shape[0])
    cp, is_lead, _dec_err = u8mod.decode_speculative(b)
    units, _u0, _u1, _bad = u16mod.encode_candidates(cp)
    count = jnp.sum(jnp.where(is_lead & (idx < n), units, 0))
    a = u8mod.analyze(b)
    return count, _first_error_status(a["err"], n)


def scan_utf16(u, n_valid=None, *, strategy: str = "fused"):
    """Single-scan UTF-16 validation + UTF-8 capacity: ``(count, status)``.

    ``status`` is -1 for valid streams, else the unit offset of the first
    unpaired surrogate half; ``count`` is the UTF-8 bytes a transcode
    would emit.
    """
    if strategy == "fused":
        from repro.kernels import fused_transcode
        return fused_transcode.utf16_scan_fused(u, n_valid)
    if strategy != "blockparallel":
        raise ValueError(f"scan_utf16: unknown strategy {strategy!r}")
    u = _mask_padding(_as_i32(u), n_valid)
    n = _n(u, n_valid)
    idx = jnp.arange(u.shape[0])
    cp, is_lead, _dec_err = u16mod.decode_speculative(u)
    L, _cand, _bad = u32mod.encode_utf8_candidates(cp)
    count = jnp.sum(jnp.where(is_lead & (idx < n), L, 0))
    a = u16mod.analyze(u)
    return count, _first_error_status(a["err"], n)


# ---------------------------------------------------------------------------
# UTF-8 -> UTF-32 / UTF-16


def _mask_padding(b, n_valid):
    if n_valid is None:
        return b
    idx = jnp.arange(b.shape[0])
    return jnp.where(idx < n_valid, b, 0)


def utf8_to_utf32(b, n_valid=None, validate: bool = True,
                  errors: str = "strict"):
    """Decode UTF-8 bytes to code points.

    Returns TranscodeResult(cp_buffer[int32, capacity=len(b)], count,
    status).
    """
    _check_errors(errors)
    b = _mask_padding(_as_i32(b), n_valid)
    n = _n(b, n_valid)
    idx = jnp.arange(b.shape[0])
    if errors == "replace":
        a = u8mod.analyze(b)
        mask = a["starts"] & (idx < n)
        out, count = compaction.compact(a["cp"], mask, b.shape[0])
        status = _first_error_status(a["err"], n) if validate else jnp.int32(STATUS_OK)
        return TranscodeResult(out, count, status)
    cp, is_lead, _dec_err = u8mod.decode_speculative(b)
    mask = is_lead & (idx < n)
    out, count = compaction.compact(cp, mask, b.shape[0])
    if validate:
        status = _first_error_status(u8mod.analyze(b)["err"], n)
    else:
        status = jnp.int32(STATUS_OK)
    return TranscodeResult(out, count, status)


def utf8_to_utf16(b, n_valid=None, validate: bool = True,
                  ascii_fastpath: bool = True, errors: str = "strict"):
    """Transcode UTF-8 bytes to UTF-16 code units (little-endian values).

    Returns TranscodeResult(u16_buffer[int32, capacity=len(b)], count,
    status).
    """
    _check_errors(errors)
    b = _mask_padding(_as_i32(b), n_valid)
    n = _n(b, n_valid)
    cap = b.shape[0]
    idx = jnp.arange(cap)

    def general(b):
        if errors == "replace" or validate:
            a = u8mod.analyze(b)
        if errors == "replace":
            cp, mask = a["cp"], a["starts"] & (idx < n)
        else:
            cp, is_lead, _dec_err = u8mod.decode_speculative(b)
            mask = is_lead & (idx < n)
        units, u0, u1, _bad = u16mod.encode_candidates(cp)
        vals = jnp.stack([u0, u1], -1)
        out, count = compaction.compact_offsets(vals, units, mask, cap)
        status = _first_error_status(a["err"], n) if validate else jnp.int32(STATUS_OK)
        return TranscodeResult(out, count, status)

    def ascii(b):
        # Paper Algorithm 3 fast path: widening copy.
        return TranscodeResult(b, jnp.asarray(n, jnp.int32),
                               jnp.int32(STATUS_OK))

    if not ascii_fastpath:
        return general(b)
    all_ascii = jnp.all(b < 0x80)
    return jax.lax.cond(all_ascii, ascii, general, b)


# ---------------------------------------------------------------------------
# UTF-16 -> UTF-32 / UTF-8


def utf16_to_utf32(u, n_valid=None, validate: bool = True,
                   errors: str = "strict"):
    _check_errors(errors)
    u = _mask_padding(_as_i32(u), n_valid)
    n = _n(u, n_valid)
    idx = jnp.arange(u.shape[0])
    if errors == "replace":
        a = u16mod.analyze(u)
        mask = a["starts"] & (idx < n)
        out, count = compaction.compact(a["cp"], mask, u.shape[0])
        status = _first_error_status(a["err"], n) if validate else jnp.int32(STATUS_OK)
        return TranscodeResult(out, count, status)
    cp, is_lead, _dec_err = u16mod.decode_speculative(u)
    mask = is_lead & (idx < n)
    out, count = compaction.compact(cp, mask, u.shape[0])
    if validate:
        status = _first_error_status(u16mod.analyze(u)["err"], n)
    else:
        status = jnp.int32(STATUS_OK)
    return TranscodeResult(out, count, status)


def utf16_to_utf8(u, n_valid=None, validate: bool = True,
                  ascii_fastpath: bool = True, errors: str = "strict"):
    """Transcode UTF-16 units to UTF-8 bytes.

    Returns TranscodeResult(byte_buffer[int32, capacity=3*len(u)], count,
    status).
    """
    _check_errors(errors)
    u = _mask_padding(_as_i32(u), n_valid)
    n = _n(u, n_valid)
    cap = 3 * u.shape[0]
    idx = jnp.arange(u.shape[0])

    def general(u):
        if errors == "replace" or validate:
            a = u16mod.analyze(u)
        if errors == "replace":
            cp, mask = a["cp"], a["starts"] & (idx < n)
        else:
            cp, is_lead, _dec_err = u16mod.decode_speculative(u)
            mask = is_lead & (idx < n)
        L, cand, _bad = u32mod.encode_utf8_candidates(cp)
        out, count = compaction.compact_offsets(cand, L, mask, cap)
        status = _first_error_status(a["err"], n) if validate else jnp.int32(STATUS_OK)
        return TranscodeResult(out, count, status)

    def ascii(u):
        out = jnp.concatenate([u, jnp.zeros((cap - u.shape[0],), u.dtype)])
        return TranscodeResult(out, jnp.asarray(n, jnp.int32),
                               jnp.int32(STATUS_OK))

    if not ascii_fastpath:
        return general(u)
    all_ascii = jnp.all(u < 0x80)
    return jax.lax.cond(all_ascii, ascii, general, u)


# ---------------------------------------------------------------------------
# UTF-32 egress


def _invalid_scalar(cp):
    """Code points no encoding may represent: surrogates, > U+10FFFF,
    negatives.  Checked pre-substitution so errors="replace" can swap in
    U+FFFD while status still reports the original offender."""
    return ((cp >= 0xD800) & (cp < 0xE000)) | (cp > 0x10FFFF) | (cp < 0)


def utf32_to_utf8(cp, n_valid=None, validate: bool = True,
                  errors: str = "strict"):
    _check_errors(errors)
    cp = _mask_padding(_as_i32(cp), n_valid)
    n = _n(cp, n_valid)
    cap = 4 * cp.shape[0]
    idx = jnp.arange(cp.shape[0])
    mask = idx < n
    bad = _invalid_scalar(cp)
    if errors == "replace":
        cp = jnp.where(bad, 0xFFFD, cp)
    L, cand, _bad = u32mod.encode_utf8_candidates(cp)
    out, count = compaction.compact_offsets(cand, L, mask, cap)
    status = _first_error_status(bad, n) if validate else jnp.int32(STATUS_OK)
    return TranscodeResult(out, count, status)


def utf32_to_utf16(cp, n_valid=None, validate: bool = True,
                   errors: str = "strict"):
    _check_errors(errors)
    cp = _mask_padding(_as_i32(cp), n_valid)
    n = _n(cp, n_valid)
    cap = 2 * cp.shape[0]
    idx = jnp.arange(cp.shape[0])
    mask = idx < n
    bad = _invalid_scalar(cp)
    if errors == "replace":
        cp = jnp.where(bad, 0xFFFD, cp)
    units, u0, u1, _bad = u16mod.encode_candidates(cp)
    vals = jnp.stack([u0, u1], -1)
    out, count = compaction.compact_offsets(vals, units, mask, cap)
    status = _first_error_status(bad, n) if validate else jnp.int32(STATUS_OK)
    return TranscodeResult(out, count, status)


# ---------------------------------------------------------------------------
# Length counting (simdutf-style capacity queries)


def _mask_padding_cont(b, n_valid):
    """Mask padding with a continuation byte (counts as 0 characters)."""
    if n_valid is None:
        return b
    idx = jnp.arange(b.shape[0])
    return jnp.where(idx < n_valid, b, 0x80)


def utf16_length_from_utf8(b, n_valid=None):
    b = _mask_padding_cont(_as_i32(b), n_valid)
    return u8mod.utf16_length(b)


def utf8_length_from_utf16(u, n_valid=None):
    u = _as_i32(u)
    if n_valid is not None:
        idx = jnp.arange(u.shape[0])
        # 0xDC00 (lone low surrogate) contributes 2 bytes; use a masked sum
        # instead: zero units count 1 byte each, so subtract the padding.
        pad = jnp.sum((idx >= n_valid).astype(jnp.int32))
        u = jnp.where(idx < n_valid, u, 0)
        return u16mod.utf8_length(u) - pad
    return u16mod.utf8_length(u)


def count_utf8_chars(b, n_valid=None):
    b = _mask_padding_cont(_as_i32(b), n_valid)
    return u8mod.count_chars(b)


# ---------------------------------------------------------------------------
# Byte-level helpers (UTF-16LE byte buffers <-> unit arrays)


def utf16le_bytes_to_units(by):
    by = _as_i32(by)
    return by[0::2] | (by[1::2] << 8)


def units_to_utf16le_bytes(u):
    u = _as_i32(u)
    lo = u & 0xFF
    hi = (u >> 8) & 0xFF
    return jnp.stack([lo, hi], -1).reshape(-1)


# ---------------------------------------------------------------------------
# Strategy dispatch (fused = Pallas two-pass, windowed = paper-faithful;
# both imported lazily to avoid circular imports).

DEFAULT_STRATEGY = "fused"


def transcode_utf8_to_utf16(b, n_valid=None, *, strategy: str = DEFAULT_STRATEGY,
                            validate: bool = True, errors: str = "strict"):
    """Strategy-dispatched UTF-8 -> UTF-16.  See module docstring."""
    if strategy == "fused":
        from repro.kernels import fused_transcode
        return fused_transcode.utf8_to_utf16_fused(b, n_valid,
                                                   validate=validate,
                                                   errors=errors)
    elif strategy == "blockparallel":
        return utf8_to_utf16(b, n_valid, validate=validate, errors=errors)
    elif strategy == "windowed":
        if errors != "strict":
            raise ValueError(
                "strategy='windowed' supports errors='strict' only "
                "(the serial baseline has no replacement path)")
        from repro.core import windowed
        return windowed.utf8_to_utf16_windowed(b, n_valid, validate=validate)
    raise ValueError(f"unknown strategy: {strategy}")


def ragged_utf8_to_utf16(data, offsets, lengths, *, validate: bool = True,
                         errors: str = "strict"):
    """Ragged packed-batch UTF-8 -> UTF-16: one Pallas launch per batch.

    ``(data, offsets, lengths)`` is the tile-aligned packed layout of
    :func:`repro.core.packing.pack_documents` (``offsets`` is the
    ``[B+1]`` row-offset vector).  Returns a
    :class:`repro.core.result.RaggedTranscodeResult` whose per-document
    slices are bit-identical to the single-document fused transcoder;
    ``errors=`` carries the usual strict/replace policy per document.
    This is the padding-tax-free batch path (DESIGN.md §7) — the padded
    ``vmap`` form survives in ``repro.data.pipeline`` as the reference.
    """
    from repro.kernels import ragged_transcode
    return ragged_transcode.utf8_to_utf16_ragged(
        data, offsets, lengths, validate=validate, errors=errors)


def ragged_utf16_to_utf8(data, offsets, lengths, *, validate: bool = True,
                         errors: str = "strict"):
    """Ragged packed-batch UTF-16 -> UTF-8 (see ``ragged_utf8_to_utf16``)."""
    from repro.kernels import ragged_transcode
    return ragged_transcode.utf16_to_utf8_ragged(
        data, offsets, lengths, validate=validate, errors=errors)


def ragged_scan_utf8(data, offsets, lengths):
    """Per-document single-scan validation + capacity: (counts, statuses).

    The ragged analogue of :func:`scan_utf8`: ONE counting-pass launch
    over a packed batch yields every document's UTF-16 capacity and
    first-error status (document-relative, Python
    ``UnicodeDecodeError.start`` semantics).  Serve ingress validates a
    whole wave of prompts with this single read.
    """
    from repro.kernels import ragged_transcode
    return ragged_transcode.utf8_scan_ragged(data, offsets, lengths)


def ragged_scan_utf16(data, offsets, lengths):
    """Per-document single-scan UTF-16 validation + UTF-8 capacity."""
    from repro.kernels import ragged_transcode
    return ragged_transcode.utf16_scan_ragged(data, offsets, lengths)


def transcode_utf16_to_utf8(u, n_valid=None, *, strategy: str = DEFAULT_STRATEGY,
                            validate: bool = True, errors: str = "strict"):
    """Strategy-dispatched UTF-16 -> UTF-8.  See module docstring."""
    if strategy == "fused":
        from repro.kernels import fused_transcode
        return fused_transcode.utf16_to_utf8_fused(u, n_valid,
                                                   validate=validate,
                                                   errors=errors)
    elif strategy == "blockparallel":
        return utf16_to_utf8(u, n_valid, validate=validate, errors=errors)
    elif strategy == "windowed":
        if errors != "strict":
            raise ValueError(
                "strategy='windowed' supports errors='strict' only "
                "(the serial baseline has no replacement path)")
        from repro.core import windowed
        return windowed.utf16_to_utf8_windowed(u, n_valid, validate=validate)
    raise ValueError(f"unknown strategy: {strategy}")
