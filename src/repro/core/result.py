"""simdutf-style transcode result: (buffer, count, status).

Every ``repro.core`` transcoder and the fused Pallas pipeline return a
:class:`TranscodeResult` — a NamedTuple (so it unpacks like the
historical 3-tuple and traverses as a jax pytree under
``jit``/``vmap``/``lax.cond``) whose third element is an int32
**status** instead of a bare validity bool.  (The legacy kernel path
``repro.kernels.ops`` still returns its historical ``(buffer, count,
bool-err)`` triple.)  Status semantics:

  * ``status == STATUS_OK`` (-1): the input was valid (or ``validate``
    was off) and ``buffer[:count]`` is the faithful transcode.
  * ``status >= 0``: the offset — in *input elements*: bytes for UTF-8,
    code units for UTF-16, code points for UTF-32 — of the first invalid
    maximal subpart, exactly where Python's ``bytes.decode`` reports
    ``UnicodeDecodeError.start``.  Under ``errors="strict"`` the buffer
    holds the speculative (reject-wholesale) output; under
    ``errors="replace"`` the buffer is still a complete, valid transcode
    with U+FFFD substituted per maximal subpart and ``status`` tells the
    caller where the first substitution happened.

This is the accelerator form of simdutf's ``result { error; count; }``:
one scan yields the transcode, the validity verdict *and* the error
location (arXiv:2111.08692 §"unicode at gigabytes per second" makes the
case for error-locating single-scan APIs at the ingestion boundary).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

STATUS_OK = -1

ERROR_POLICIES = ("strict", "replace")


def check_errors_policy(errors: str) -> None:
    """Validate an ``errors=`` kwarg (shared by every transcoder entry)."""
    if errors not in ERROR_POLICIES:
        raise ValueError(
            f"errors= must be one of {ERROR_POLICIES}: {errors!r}")

# Sentinel used while reducing per-tile first-error indices: any real
# offset is smaller, so min() over tiles recovers the global first error.
NO_ERR_SENTINEL = 2**31 - 1


class TranscodeResult(NamedTuple):
    """(buffer, count, status) — unpacks like the legacy 3-tuple."""

    buffer: jax.Array
    count: jax.Array    # int32: meaningful elements in ``buffer``
    status: jax.Array   # int32: STATUS_OK or first-error input offset

    @property
    def err(self) -> jax.Array:
        """Legacy validity flag: True iff the input stream was invalid."""
        return self.status >= 0

    @property
    def ok(self) -> jax.Array:
        return self.status < 0


class RaggedTranscodeResult(NamedTuple):
    """Per-batch result of a ragged packed transcode (one kernel launch).

    The per-document fields carry exactly the :class:`TranscodeResult`
    semantics, element-wise: document ``d``'s output occupies
    ``buffer[offsets[d] : offsets[d] + counts[d]]`` (a *dense* packed
    stream — no inter-document padding), ``counts[d]`` is its output
    element count and ``statuses[d]`` its int32 status (``STATUS_OK`` or
    the first-error offset *relative to the document's own start*, with
    Python ``UnicodeDecodeError.start`` semantics).
    """

    buffer: jax.Array    # dense packed output stream (uint16 / uint8)
    offsets: jax.Array   # int32 [B+1]: per-document output row offsets
    counts: jax.Array    # int32 [B]: per-document output element counts
    statuses: jax.Array  # int32 [B]: STATUS_OK or doc-relative offset

    @property
    def ok(self) -> jax.Array:
        return self.statuses < 0


def first_error_status(err_map, n):
    """Min-reduce a per-position error map into an int32 status.

    Only positions in the live region ``[0, n)`` count; returns
    ``STATUS_OK`` when the map is clean there.  The single definition of
    the reduce every strategy (blockparallel, windowed, the fused
    wrappers' per-tile variant) derives its status from.
    """
    idx = jnp.arange(err_map.shape[0])
    errpos = jnp.where(err_map & (idx < n), idx, NO_ERR_SENTINEL)
    return status_from_first(jnp.min(errpos, initial=NO_ERR_SENTINEL))


def status_from_first(first_index, err_any=None):
    """Fold a min-reduced first-error index (NO_ERR_SENTINEL = clean) and
    an optional independent error flag into one int32 status.

    ``err_any`` is a belt-and-braces flag from a second detector (the
    Keiser-Lemire nibble tables in the fused count pass): if it fires
    without a located position — the detectors are equivalent, so this
    should never happen — the status degrades to offset 0 rather than
    silently reporting a valid stream.
    """
    first = jnp.asarray(first_index, jnp.int32)
    located = first != NO_ERR_SENTINEL
    if err_any is None:
        return jnp.where(located, first, jnp.int32(STATUS_OK))
    flagged = located | err_any
    pos = jnp.where(located, first, jnp.int32(0))
    return jnp.where(flagged, pos, jnp.int32(STATUS_OK))
