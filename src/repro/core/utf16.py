"""Vectorized UTF-16 validation / decoding (to UTF-32) / encoding.

UTF-16 is the simpler side of the paper: outside surrogate pairs every code
unit is a whole character.  All functions operate on int32 arrays of 16-bit
code-unit values (little-endian decoding from bytes happens at the buffer
boundary, see ``transcode.py``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _shift_right(x, n, fill=0):
    if n == 0:
        return x
    if n >= x.shape[0]:
        return jnp.full_like(x, fill)
    return jnp.concatenate([jnp.full((n,), fill, x.dtype), x[:-n]])


def _shift_left(x, n, fill=0):
    if n == 0:
        return x
    if n >= x.shape[0]:
        return jnp.full_like(x, fill)
    return jnp.concatenate([x[n:], jnp.full((n,), fill, x.dtype)])


def classify(u: jax.Array):
    """Per-unit surrogate classification.  u: int32 values in [0, 2^16)."""
    top6 = u >> 10
    is_hi = top6 == 0x36  # 0xD800..0xDBFF
    is_lo = top6 == 0x37  # 0xDC00..0xDFFF
    return is_hi, is_lo


def validate(u: jax.Array, n_valid=None) -> jax.Array:
    """True iff ``u`` is valid UTF-16 (all surrogates correctly paired)."""
    if n_valid is not None:
        idx = jnp.arange(u.shape[0])
        u = jnp.where(idx < n_valid, u, 0)
        n = n_valid
    else:
        n = u.shape[0]
    is_hi, is_lo = classify(u)
    next_is_lo = _shift_left(is_lo, 1)
    prev_is_hi = _shift_right(is_hi, 1)
    # Every high surrogate must be followed by a low one and vice versa; a
    # high surrogate in the last position is truncated.
    idx = jnp.arange(u.shape[0])
    err = (is_hi & ~next_is_lo) | (is_lo & ~prev_is_hi) | (is_hi & (idx == n - 1))
    return ~jnp.any(err)


def decode_speculative(u: jax.Array):
    """Decode every unit position to a candidate code point.

    Returns (cp, is_lead, err): code points at lead positions (a low
    surrogate that completes a pair is not a lead), plus a validity flag.
    """
    is_hi, is_lo = classify(u)
    nxt = _shift_left(u, 1)
    next_is_lo = _shift_left(is_lo, 1)
    prev_is_hi = _shift_right(is_hi, 1)

    pair_cp = 0x10000 + ((u - 0xD800) << 10) + (nxt - 0xDC00)
    cp = jnp.where(is_hi, pair_cp, u)
    is_lead = ~(is_lo & prev_is_hi)

    idx = jnp.arange(u.shape[0])
    err = (
        (is_hi & ~next_is_lo)
        | (is_lo & ~prev_is_hi)
        | (is_hi & (idx == u.shape[0] - 1))
    )
    return cp, is_lead, jnp.any(err)


def encode_candidates(cp: jax.Array):
    """UTF-32 -> UTF-16: produce (units, u0, u1) per code point.

    ``units`` is 1 or 2; ``u0``/``u1`` are the code units (u1 meaningful only
    where units == 2).  Invalid code points (surrogate range, > 0x10FFFF)
    are reported via the third return value.
    """
    is_supp = cp >= 0x10000
    v = cp - 0x10000
    u0 = jnp.where(is_supp, 0xD800 + (v >> 10), cp)
    u1 = jnp.where(is_supp, 0xDC00 + (v & 0x3FF), 0)
    units = 1 + is_supp.astype(jnp.int32)
    # Per-position badness: callers mask by lead positions before reducing.
    bad = ((cp >= 0xD800) & (cp < 0xE000)) | (cp > 0x10FFFF) | (cp < 0)
    return units, u0, u1, bad


# ---------------------------------------------------------------------------
# Unit analysis (error location + replacement semantics).
#
# UTF-16's maximal-subpart story is one unit deep: every unpaired
# surrogate half is its own ill-formed unit and is replaced by a single
# U+FFFD; everything else is a valid unit (a BMP character or the high
# half of a pair, which consumes its low half).  Python's utf-16-le
# decoder reports errors at the byte offset of the unpaired half —
# ``unit_offset == UnicodeDecodeError.start // 2``.


def analyze_units(u, nxt1, prv1):
    """Classify every position of a UTF-16 unit stream.

    Arguments are int32 arrays of identical shape: the stream plus its
    one-unit forward and backward shifts (out-of-stream reads 0, which is
    a BMP character and can never pair).  Returns a dict:
      ``starts`` -- bool, position begins a unit (not a consumed low half)
      ``valid``  -- bool, unit is a valid character (BMP or full pair)
      ``cp``     -- int32 code point (U+FFFD at unpaired halves)
      ``err``    -- bool map of unpaired surrogate halves at unit starts
    """
    is_hi = (u >> 10) == 0x36
    is_lo = (u >> 10) == 0x37
    nxt_is_lo = (nxt1 >> 10) == 0x37
    prv_is_hi = (prv1 >> 10) == 0x36

    paired_hi = is_hi & nxt_is_lo
    consumed = is_lo & prv_is_hi        # low half claimed by the previous hi
    starts = ~consumed
    valid = starts & (~(is_hi | is_lo) | paired_hi)

    pair_cp = 0x10000 + ((u - 0xD800) << 10) + (nxt1 - 0xDC00)
    cp = jnp.where(paired_hi, pair_cp, u)
    cp = jnp.where(valid, cp, 0xFFFD)
    cp = jnp.where(starts, cp, 0)
    return {
        "starts": starts,
        "valid": valid,
        "cp": cp,
        "err": starts & ~valid,
    }


def analyze(u: jax.Array):
    """Whole-array :func:`analyze_units` (zero-filled shifts)."""
    return analyze_units(u, _shift_left(u, 1), _shift_right(u, 1))


def first_error_index(u: jax.Array, n_valid=None) -> jax.Array:
    """int32 scalar: unit offset of the first unpaired surrogate half
    (== Python's ``UnicodeDecodeError.start // 2`` for utf-16-le), or -1
    when the stream is valid UTF-16."""
    from repro.core import result as R
    if n_valid is not None:
        idx = jnp.arange(u.shape[0])
        u = jnp.where(idx < n_valid, u, 0)
    n = u.shape[0] if n_valid is None else n_valid
    return R.first_error_status(analyze(u)["err"], n)


def utf8_length(u: jax.Array) -> jax.Array:
    """UTF-8 bytes needed by a UTF-16 stream (paper §5 length classes)."""
    is_hi, is_lo = classify(u)
    ascii_ = (u < 0x80).astype(jnp.int32)
    two = ((u >= 0x80) & (u < 0x800)).astype(jnp.int32)
    three = ((u >= 0x800) & ~is_hi & ~is_lo).astype(jnp.int32)
    # A surrogate pair contributes 4 bytes; count 2 per surrogate unit.
    surr = (is_hi | is_lo).astype(jnp.int32)
    return jnp.sum(ascii_ + 2 * two + 3 * three + 2 * surr)
