"""Paper-faithful windowed transcoders (Lemire & Mula Algorithms 2, 3, 4).

This module preserves the *structure* of the paper's CPU algorithms:

UTF-8 -> UTF-16 (Algorithms 2 & 3)
  * outer loop over the input with a 64-byte **ASCII fast path** (one
    vector compare + reduce; widening copy when it hits);
  * otherwise an **end-of-character bitset** is computed from a vectorized
    "is continuation byte" compare, and the low 12 bits key a
    4096-entry table (``repro.core.tables.WINDOW_*``) giving the number of
    bytes consumed and the per-character (start, length) layout of the
    window — the TPU stand-in for the paper's shuffle-mask tables;
  * the window body applies the branch-free bit surgery of Figs. 2-4 to up
    to six characters at once and emits UTF-16 code units (including
    surrogate pairs).

UTF-16 -> UTF-8 (Algorithm 4)
  * loop over 8-unit registers, branching (``lax.switch``) on the maximal
    range class: ASCII / <=U+07FF / BMP-no-surrogates / surrogates-present;
  * each class has its own routine; the surrogate class may consume only 7
    units when the register ends with the first half of a pair.

The window walk is inherently serial (a ``lax.while_loop`` with a
data-dependent trip count), which is exactly why the block-parallel
strategy in ``repro.core.transcode`` exists: on TPU-class hardware the
serial walk is the measured baseline, the speculative whole-array decode is
the beyond-paper optimization.  See DESIGN.md §3 and EXPERIMENTS.md §Perf.

All functions mirror the public API shape:
``TranscodeResult(buffer, count, status)`` — the global validation pass
that seeds the walk (the paper fuses Keiser-Lemire per 64-byte block; over
a device-resident buffer one fused pass is equivalent) doubles as the
error locator, so ``status`` carries the first-error offset with the same
Python ``exc.start`` semantics as the other strategies.  The windowed
baseline supports ``errors="strict"`` only.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import result as R
from repro.core import tables as T
from repro.core import utf8 as u8mod
from repro.core import utf16 as u16mod

_WINDOW = 12
_BLOCK = 64


def _decode_char(b12: jax.Array, start: jax.Array, length: jax.Array):
    """Decode one UTF-8 character from a 12(+3 pad)-byte window.

    Branch-free bit surgery of paper Figs. 2-4, applied to the bytes
    ``b12[start:start+length]``.  Returns the code point (0 when length==0).
    """
    b0 = b12[start]
    b1 = b12[start + 1]
    b2 = b12[start + 2]
    b3 = b12[start + 3]
    cp1 = b0
    cp2 = ((b0 & 0x1F) << 6) | (b1 & 0x3F)
    cp3 = ((b0 & 0x0F) << 12) | ((b1 & 0x3F) << 6) | (b2 & 0x3F)
    cp4 = (
        ((b0 & 0x07) << 18)
        | ((b1 & 0x3F) << 12)
        | ((b2 & 0x3F) << 6)
        | (b3 & 0x3F)
    )
    return jnp.select(
        [length == 1, length == 2, length == 3, length == 4],
        [cp1, cp2, cp3, cp4],
        default=jnp.int32(0),
    )


def utf8_to_utf16_windowed(b, n_valid=None, validate: bool = True):
    """Algorithm 3 structure: 64-byte ASCII fast path + 12-byte table windows.

    Returns (u16_buffer[int32, capacity=len(b)+16], count, err).
    """
    b = b.astype(jnp.int32)
    cap_in = b.shape[0]
    n = jnp.asarray(cap_in if n_valid is None else n_valid, jnp.int32)
    idx = jnp.arange(cap_in)
    b = jnp.where(idx < n, b, 0)

    # Padded input so dynamic 64/16-byte loads never go out of bounds.
    b_pad = jnp.concatenate([b, jnp.zeros((_BLOCK,), jnp.int32)])
    # +80 slack so the 64-wide ASCII store and 12-wide window store are
    # always in bounds even for tiny inputs.
    cap_out = cap_in + 80
    out0 = jnp.zeros((cap_out,), jnp.int32)

    consumed_t = jnp.asarray(T.WINDOW_CONSUMED)
    nchars_t = jnp.asarray(T.WINDOW_NCHARS)
    starts_t = jnp.asarray(T.WINDOW_STARTS)
    lengths_t = jnp.asarray(T.WINDOW_LENGTHS)
    valid_t = jnp.asarray(T.WINDOW_VALID)

    # Global validation + error location (the paper fuses Keiser-Lemire per
    # 64-byte block; over a device-resident buffer a single fused pass is
    # equivalent — and the maximal-subpart locator rides along).
    status0 = u8mod.first_error_index(b, n_valid) if validate \
        else jnp.int32(R.STATUS_OK)
    err0 = (status0 >= 0) if validate else jnp.bool_(False)

    def window_body(state):
        p, q, out, err = state

        # --- Algorithm 3 ASCII fast path: 64 bytes at once. -------------
        blk = jax.lax.dynamic_slice(b_pad, (p,), (_BLOCK,))
        can64 = (p + _BLOCK) <= n
        all_ascii = jnp.all(blk < 0x80) & can64

        def ascii_path(_):
            new_out = jax.lax.dynamic_update_slice(out, blk, (q,))
            return p + _BLOCK, q + _BLOCK, new_out, err

        # --- Algorithm 2 window: 12 bytes, table-driven. ----------------
        def window_path(_):
            w = jax.lax.dynamic_slice(b_pad, (p,), (_WINDOW + 4,))
            # End-of-character bitset: byte i ends a char iff byte i+1 is
            # not a continuation byte (or is past the end of the stream).
            nxt = jax.lax.dynamic_slice(b_pad, (p + 1,), (_WINDOW,))
            past = (p + 1 + jnp.arange(_WINDOW)) >= n
            ends = ((nxt & 0xC0) != 0x80) | past
            key = jnp.sum(ends.astype(jnp.int32) << jnp.arange(_WINDOW))

            k = consumed_t[key]
            nch = nchars_t[key]
            ok = valid_t[key]

            # Decode up to six characters (paper cases: 6x<=2B / 4x<=3B /
            # 2x<=4B, all encoded in the precomputed layout tables).
            temp = jnp.zeros((_WINDOW,), jnp.int32)
            woff = jnp.int32(0)
            for j in range(6):
                live = j < nch
                cp = _decode_char(w, starts_t[key, j], lengths_t[key, j])
                is_supp = cp >= 0x10000
                v = cp - 0x10000
                u0 = jnp.where(is_supp, 0xD800 + (v >> 10), cp)
                u1 = jnp.where(is_supp, 0xDC00 + (v & 0x3FF), 0)
                units = jnp.where(live, 1 + is_supp.astype(jnp.int32), 0)
                temp = temp.at[woff].set(jnp.where(live, u0, temp[woff]))
                temp = temp.at[woff + 1].set(
                    jnp.where(live & is_supp, u1, temp[woff + 1])
                )
                woff = woff + units

            new_out = jax.lax.dynamic_update_slice(out, temp, (q,))
            # Restore any overwritten-but-unclaimed lanes? Not needed: lanes
            # past q+woff are rewritten by later windows or masked at the end.
            new_err = err | ~ok
            # Always make progress on malformed windows.
            k = jnp.maximum(k, 1)
            return p + k, q + woff, new_out, new_err

        return jax.lax.cond(all_ascii, ascii_path, window_path, None)

    def window_cond(state):
        p, q, out, err = state
        return (p + _WINDOW) <= n

    p, q, out, err = jax.lax.while_loop(
        window_cond, window_body, (jnp.int32(0), jnp.int32(0), out0, err0)
    )

    # --- Conventional tail (< 12 bytes), as in the paper. ----------------
    def tail_body(state):
        p, q, out, err = state
        w = jax.lax.dynamic_slice(b_pad, (p,), (4,))
        l = jnp.take(jnp.asarray(T.LEAD_LENGTH_32), w[0] >> 3)
        bad = l == 0
        l = jnp.maximum(l, 1)
        # Clamp at the end of the stream (truncated char = invalid, already
        # caught by validate_kl).
        l = jnp.minimum(l, n - p)
        cp = _decode_char(w, jnp.int32(0), l)
        is_supp = cp >= 0x10000
        v = cp - 0x10000
        u0 = jnp.where(is_supp, 0xD800 + (v >> 10), cp)
        u1 = jnp.where(is_supp, 0xDC00 + (v & 0x3FF), 0)
        temp = jnp.stack([u0, u1])
        new_out = jax.lax.dynamic_update_slice(out, temp, (q,))
        return p + l, q + 1 + is_supp.astype(jnp.int32), new_out, err | bad

    p, q, out, err = jax.lax.while_loop(
        lambda s: s[0] < n, tail_body, (p, q, out, err)
    )

    # Zero the unclaimed lanes so buffers compare deterministically.
    out = jnp.where(jnp.arange(cap_out) < q, out, 0)
    if not validate:
        return R.TranscodeResult(out, q, jnp.int32(R.STATUS_OK))
    # The walk's per-window flags are a subset of the located errors; if
    # they ever disagree, degrade to offset 0 rather than claiming valid.
    status = jnp.where(status0 >= 0, status0,
                       jnp.where(err, jnp.int32(0), jnp.int32(R.STATUS_OK)))
    return R.TranscodeResult(out, q, status)


# ---------------------------------------------------------------------------
# Algorithm 4: UTF-16 -> UTF-8, 8-unit registers, 4-way range branch.


def _encode_bmp(u8v: jax.Array):
    """Encode 8 BMP (non-surrogate) units to a 24-byte buffer + count.

    Shared body of Algorithm 4's case 2 and case 3 routines: per unit emit
    1-3 candidate bytes and compress (paper: pshufb mask from the 256-entry
    table; here: in-register offsets, the window is only 8 lanes wide).
    """
    L = (
        1
        + (u8v >= 0x80).astype(jnp.int32)
        + (u8v >= 0x800).astype(jnp.int32)
    )
    c0 = u8v & 0x3F
    c1 = (u8v >> 6) & 0x3F
    b1 = jnp.stack([u8v, jnp.zeros_like(u8v), jnp.zeros_like(u8v)], -1)
    b2 = jnp.stack([0xC0 | (u8v >> 6), 0x80 | c0, jnp.zeros_like(u8v)], -1)
    b3 = jnp.stack([0xE0 | (u8v >> 12), 0x80 | c1, 0x80 | c0], -1)
    Le = L[:, None]
    cand = jnp.where(Le == 1, b1, jnp.where(Le == 2, b2, b3))
    start = jnp.cumsum(L) - L
    jj = jnp.arange(3)[None, :]
    dest = start[:, None] + jj
    keep = jj < Le
    dest = jnp.where(keep, dest, 24)
    temp = jnp.zeros((24,), jnp.int32)
    temp = temp.at[dest.reshape(-1)].set(cand.reshape(-1), mode="drop")
    return temp, jnp.sum(L)


def utf16_to_utf8_windowed(u, n_valid=None, validate: bool = True):
    """Algorithm 4: branch per 8-unit register on the maximal range class.

    Returns (byte_buffer[int32, capacity=3*len(u)+24], count, err).
    """
    u = u.astype(jnp.int32)
    cap_in = u.shape[0]
    n = jnp.asarray(cap_in if n_valid is None else n_valid, jnp.int32)
    idx = jnp.arange(cap_in)
    u = jnp.where(idx < n, u, 0)

    u_pad = jnp.concatenate([u, jnp.zeros((8,), jnp.int32)])
    cap_out = 3 * cap_in + 24
    out0 = jnp.zeros((cap_out,), jnp.int32)

    def body(state):
        p, q, out, err = state
        reg = jax.lax.dynamic_slice(u_pad, (p,), (8,))
        in_range = (p + jnp.arange(8)) < n
        reg = jnp.where(in_range, reg, 0)

        is_hi = (reg >> 10) == 0x36
        is_lo = (reg >> 10) == 0x37
        has_surr = jnp.any(is_hi | is_lo)
        all_ascii = jnp.all(reg < 0x80)
        all_latin = jnp.all(reg < 0x800)
        case = jnp.where(
            all_ascii, 0, jnp.where(all_latin, 1, jnp.where(~has_surr, 2, 3))
        )

        def case_ascii(reg):
            temp = jnp.zeros((24,), jnp.int32).at[:8].set(reg)
            return temp, jnp.int32(8), jnp.int32(8), jnp.bool_(False)

        def case_latin(reg):
            temp, nb = _encode_bmp(reg)
            return temp, nb, jnp.int32(8), jnp.bool_(False)

        def case_bmp(reg):
            temp, nb = _encode_bmp(reg)
            return temp, nb, jnp.int32(8), jnp.bool_(False)

        def case_surrogate(reg):
            # Conventional path (paper: scalar fallback).  Vectorized over
            # the 8 lanes: decode pairs speculatively, mask trailing halves.
            hi = (reg >> 10) == 0x36
            lo = (reg >> 10) == 0x37
            nxt = jnp.concatenate([reg[1:], jnp.zeros((1,), jnp.int32)])
            nxt_lo = (nxt >> 10) == 0x37
            prv_hi = jnp.concatenate([jnp.zeros((1,), jnp.bool_), hi[:-1]])
            # Do not split a pair: if lane 7 is an unconsumed high surrogate,
            # stop the register at lane 7.
            take = jnp.where(hi[7] & ~prv_hi[7], 7, 8)
            lane = jnp.arange(8)
            live = lane < take
            is_lead = live & ~(lo & prv_hi)
            pair_cp = 0x10000 + ((reg - 0xD800) << 10) + (nxt - 0xDC00)
            cp = jnp.where(hi, pair_cp, reg)
            lerr = jnp.any(
                (live & hi & ~nxt_lo & (lane < take - 1))
                | (live & lo & ~prv_hi)
                | (is_lead & hi & (lane == take - 1))
            )
            L = (
                1
                + (cp >= 0x80).astype(jnp.int32)
                + (cp >= 0x800).astype(jnp.int32)
                + (cp >= 0x10000).astype(jnp.int32)
            )
            L = jnp.where(is_lead, L, 0)
            c0 = cp & 0x3F
            c1 = (cp >> 6) & 0x3F
            c2 = (cp >> 12) & 0x3F
            c3 = (cp >> 18) & 0x07
            z = jnp.zeros_like(cp)
            b1v = jnp.stack([cp, z, z, z], -1)
            b2v = jnp.stack([0xC0 | (cp >> 6), 0x80 | c0, z, z], -1)
            b3v = jnp.stack([0xE0 | (cp >> 12), 0x80 | c1, 0x80 | c0, z], -1)
            b4v = jnp.stack([0xF0 | c3, 0x80 | c2, 0x80 | c1, 0x80 | c0], -1)
            Le = L[:, None]
            cand = jnp.where(
                Le == 1, b1v, jnp.where(Le == 2, b2v, jnp.where(Le == 3, b3v, b4v))
            )
            start = jnp.cumsum(L) - L
            jj = jnp.arange(4)[None, :]
            dest = start[:, None] + jj
            keep = jj < Le
            dest = jnp.where(keep, dest, 24)
            temp = jnp.zeros((24,), jnp.int32)
            temp = temp.at[dest.reshape(-1)].set(cand.reshape(-1), mode="drop")
            return temp, jnp.sum(L), take, lerr

        temp, nb, k, lerr = jax.lax.switch(
            case, [case_ascii, case_latin, case_bmp, case_surrogate], reg
        )
        # Near the stream end the register may be partially filled: clamp the
        # consumed units and recount the bytes from the actually-live units.
        avail = n - p
        k = jnp.minimum(k, avail)
        # Recompute bytes written for the clamped prefix.
        unit_pos = jnp.arange(8)
        # per-unit byte contribution (surrogate halves: hi contributes 4,
        # lo contributes 0 when paired; unpaired handled by lerr/validate).
        hi_m = (reg >> 10) == 0x36
        lo_m = (reg >> 10) == 0x37
        per_unit = jnp.where(
            hi_m,
            4,
            jnp.where(
                lo_m,
                0,
                1 + (reg >= 0x80).astype(jnp.int32) + (reg >= 0x800).astype(jnp.int32),
            ),
        )
        live_units = unit_pos < k
        nb = jnp.sum(jnp.where(live_units, per_unit, 0))
        new_out = jax.lax.dynamic_update_slice(out, temp, (q,))
        return p + jnp.maximum(k, 1), q + nb, new_out, err | lerr

    status0 = u16mod.first_error_index(u, n_valid) if validate \
        else jnp.int32(R.STATUS_OK)
    err0 = (status0 >= 0) if validate else jnp.bool_(False)
    p, q, out, err = jax.lax.while_loop(
        lambda s: s[0] < n, body, (jnp.int32(0), jnp.int32(0), out0, err0)
    )
    out = jnp.where(jnp.arange(cap_out) < q, out, 0)
    if not validate:
        return R.TranscodeResult(out, q, jnp.int32(R.STATUS_OK))
    status = jnp.where(status0 >= 0, status0,
                       jnp.where(err, jnp.int32(0), jnp.int32(R.STATUS_OK)))
    return R.TranscodeResult(out, q, status)
