"""Resumable streaming transcode: chunked input, whole-buffer results.

The paper's motivating deployment is data arriving from disks and
networks — unbounded *streams*, not whole buffers.  This module threads
the single-pass kernel (``repro.kernels.onepass_transcode``, DESIGN.md
§9) across repeated launches with a tiny host-side carry, the
:class:`StreamState`, so that::

    st = stream_init("utf8", "utf16")
    for chunk in chunks:
        res, st = transcode_stream_chunk(st, chunk)
        consume(res.buffer[:res.count])
    tail, st = finalize(st)

is **bit-exact** against one whole-buffer transcode of
``concat(chunks)`` — same concatenated output buffer, same total count,
same final status — at EVERY chunk split point, including splits
mid-multibyte-sequence and mid-surrogate-pair.  (For a ``strict``
stream that contains errors, "bit-exact" covers the count, the sticky
status and the output up to the first error; the speculative content
AFTER an error is launch-geometry-defined — a dangling invalid lead
decodes against zero padding in a chunk launch but against its real
neighbors in the whole buffer — exactly as it is strategy-defined, not
CPython-defined, for the whole-buffer kernels.)

Chunk-boundary holdback (the correctness core, DESIGN.md §10): a chunk
may end inside a character.  Up to ``3`` trailing source units are held
back and prepended to the next chunk:

  * UTF-8 — walk back over at most 3 trailing bytes; if a lead byte
    (``>= 0xC0``) sits ``k`` bytes from the end and its sequence length
    exceeds ``k``, hold those ``k`` bytes.  Invalid leads (0xC0/0xC1,
    0xF5..0xFF) are held too: their *maximal subpart* (and hence their
    speculative decode) depends on the following bytes, which live in
    the next chunk.  A trailing continuation run with no such lead is
    never held — UTF-8 decoding is strictly forward-claiming, so bytes
    after a chunk boundary can never change the meaning of bytes before
    it unless a held lead claims across.
  * UTF-16 — hold a single trailing high surrogate (0xD800..0xDBFF):
    the only forward-claiming unit.
  * UTF-32 / Latin-1 — fixed-width, nothing to hold.

Because every effective sub-buffer therefore starts at a unit boundary
(never mid-claim), the kernel's speculative decode, maximal-subpart
analysis (CPython ``errors="replace"`` semantics) and counts all compose
chunk-wise, and per-chunk first-error offsets map to global stream
offsets by adding the chunk's base — the sticky first-error-wins fold
across chunks reproduces the whole-buffer status exactly.

Failure semantics: the error status is **sticky** (first error wins,
exactly like the kernel's SMEM carry across tiles); ``finalize`` flushes
a dangling incomplete tail through the same kernel, where it faults
(strict) or substitutes U+FFFD (replace) at its true global offset —
identical to what the whole-buffer path does with a truncated tail.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import numpy as np

from repro.core.result import STATUS_OK, TranscodeResult
from repro.testing import faults

# One VMEM tile of the kernels: effective sub-buffers are padded to a
# tile multiple so every chunk launch uses the same tile geometry (and
# chunk lengths below one tile share ONE compiled shape).
TILE = 1024

_DTYPES = {"utf8": np.uint8, "utf16": np.uint16, "utf32": np.uint32,
           "latin1": np.uint8}

# Cross-format maximum of the per-format holdback bounds (a UTF-8 4-byte
# lead at distance 3 from the end) — sizes ``StreamState.pending``.  The
# per-format bound is :func:`holdback_limit`, which mirrors the codec
# descriptors' ``max_lookback`` field (stages.Codec): 3 for UTF-8, 1 for
# UTF-16, 0 for the fixed-width formats.
MAX_HOLDBACK = 3


def holdback_limit(src: str) -> int:
    """Trailing units a chunk of format ``src`` can ever hold back —
    the codec's ``max_lookback`` (the same bound the kernels' per-tile
    class predicates check as boundary inflow)."""
    # Late import: core.stream is host-side glue; the codec registry
    # pulls in the kernel stack.
    from repro.kernels import stages
    return stages.get_codec(src).max_lookback


class StreamState(NamedTuple):
    """Host-side carry threaded across chunk launches.

    ==============  =======================================================
    field           meaning
    ==============  =======================================================
    ``src``/``dst`` canonical format names of the stream's matrix cell
    ``errors``      ``"strict"`` | ``"replace"`` (fixed at init)
    ``validate``    run fused validation (fixed at init)
    ``consumed``    global index of the first *pending* source unit — the
                    number of source units fully processed so far
    ``out_count``   total destination units emitted so far
    ``status``      sticky global status: ``STATUS_OK`` until the first
                    error/substitution, then its global input offset
    ``pending``     up to :data:`MAX_HOLDBACK` trailing source units held
                    back from the previous chunk (codec dtype)
    ``finished``    ``finalize`` ran; further chunks are an error
    ==============  =======================================================
    """

    src: str
    dst: str
    errors: str
    validate: bool
    consumed: int
    out_count: int
    status: int
    pending: np.ndarray
    finished: bool = False


def stream_init(src_format: str, dst_format: str, *,
                errors: str = "strict",
                validate: bool = True) -> StreamState:
    """Fresh :class:`StreamState` for one (src, dst) matrix cell."""
    # Late import: core.stream is host-side glue; the format registry
    # lives in core.transcode (which lazily imports the kernels).
    from repro.core import transcode as tc
    src = tc.normalize_format(src_format)
    dst = tc.normalize_format(dst_format)
    tc._check_pair(src, dst)
    from repro.core.result import check_errors_policy
    check_errors_policy(errors)
    return StreamState(src, dst, errors, bool(validate), 0, 0,
                       int(STATUS_OK), np.zeros(0, _DTYPES[src]), False)


def _as_units(chunk, src: str) -> np.ndarray:
    """Normalize one chunk to a 1-D codec-dtype array (with the same
    wrong-input diagnostics as ``core.transcode.transcode``)."""
    dt = _DTYPES[src]
    if isinstance(chunk, (bytes, bytearray, memoryview)):
        if dt != np.uint8:
            raise TypeError(
                f"stream chunks for src={src!r} must be unit arrays "
                f"(dtype {np.dtype(dt).name}), not raw bytes — split the "
                f"wire bytes into units first")
        return np.frombuffer(bytes(chunk), np.uint8)
    a = np.asarray(chunk)
    if a.ndim != 1:
        raise ValueError(
            f"stream chunk must be 1-D, got shape {a.shape}")
    if not np.issubdtype(a.dtype, np.integer):
        raise TypeError(
            f"stream chunk must have an integer dtype, got {a.dtype}")
    if a.dtype != dt:
        if a.size and (int(a.min()) < 0
                       or int(a.max()) > int(np.iinfo(dt).max)):
            raise ValueError(
                f"stream chunk values out of range for {src!r} "
                f"(dtype {np.dtype(dt).name})")
        a = a.astype(dt)
    return a


def _holdback(src: str, buf: np.ndarray) -> int:
    """Trailing units of ``buf`` that may still be claimed forward into
    the next chunk (see module docstring for the per-format rule)."""
    n = buf.shape[0]
    limit = holdback_limit(src)
    if limit == 0:
        return 0                               # utf32 / latin1: fixed width
    if src == "utf8":
        for k in range(1, min(limit, n) + 1):
            b = int(buf[n - k])
            if b < 0x80:
                return 0                       # ASCII: complete unit
            if b >= 0xC0:                      # lead at distance k
                need = 2 if b < 0xE0 else (3 if b < 0xF0 else 4)
                return k if need > k else 0
            # else continuation byte: keep walking back
        return 0
    # utf16 (limit == 1): only a trailing high surrogate is incomplete.
    if n and 0xD800 <= int(buf[n - 1]) <= 0xDBFF:
        return 1
    return 0


# Public name: the shard planner (``repro.core.shard``) reuses the SAME
# holdback rule for mid-document shard cuts — a cut point that cannot
# land on a document boundary must still land on a unit boundary so the
# per-shard launches compose chunk-wise (DESIGN.md §12).
def holdback_units(src: str, buf) -> int:
    """Trailing units of ``buf`` a cut after it would orphan — the
    per-codec ``max_lookback`` walk-back of :func:`_holdback`."""
    return _holdback(src, np.asarray(buf))


def _launch(state: StreamState, eff: np.ndarray) -> TranscodeResult:
    """One single-pass kernel launch over an effective sub-buffer
    (padded to a tile multiple so sub-tile chunks share one compile)."""
    from repro.core import transcode as tc
    from repro.kernels import onepass_transcode as op
    n = eff.shape[0]
    pad = -(-n // TILE) * TILE
    x = np.zeros(pad, eff.dtype)
    x[:n] = eff
    res = op.transcode_onepass(x, n, src=state.src, dst=state.dst,
                               validate=state.validate,
                               errors=state.errors)
    cap = tc.CAP_FACTOR[(state.src, state.dst)] * pad
    count = int(res.count)
    buf = np.asarray(res.buffer)[: min(count, cap)]
    return TranscodeResult(buf, np.int32(count), np.int32(res.status))


def transcode_stream_chunk(
        state: StreamState, chunk) -> Tuple[TranscodeResult, StreamState]:
    """Feed one chunk; returns ``(result, new_state)``.

    ``result.buffer[:result.count]`` is this chunk's emission (the next
    slice of the whole-buffer output); ``result.status`` is the stream's
    *sticky global* status after this chunk, so the latest result's
    status always equals what the whole-buffer transcode of everything
    fed so far (minus the held-back tail) would report.  The input
    chunk's trailing incomplete unit (up to :data:`MAX_HOLDBACK` source
    units) is held back into ``new_state.pending`` and processed with
    the next chunk — or by :func:`finalize`.
    """
    if state.finished:
        raise ValueError("transcode_stream_chunk: stream already finalized")
    chunk = faults.fire(faults.STREAM_CHUNK, _as_units(chunk, state.src))
    buf = np.concatenate([state.pending, chunk]) \
        if state.pending.size else chunk
    h = _holdback(state.src, buf)
    eff, pend = buf[: buf.shape[0] - h], buf[buf.shape[0] - h:]
    if eff.shape[0] == 0:
        empty = TranscodeResult(np.zeros(0, _DTYPES[state.dst]),
                                np.int32(0), np.int32(state.status))
        return empty, state._replace(pending=np.ascontiguousarray(pend))
    res = _launch(state, eff)
    rel = int(res.status)
    event = state.consumed + rel if rel >= 0 else STATUS_OK
    sticky = state.status if state.status >= 0 else event
    new = state._replace(
        consumed=state.consumed + int(eff.shape[0]),
        out_count=state.out_count + int(res.count),
        status=int(sticky),
        pending=np.ascontiguousarray(pend))
    return TranscodeResult(res.buffer, res.count, np.int32(sticky)), new


def finalize(state: StreamState) -> Tuple[TranscodeResult, StreamState]:
    """Flush the held-back tail and close the stream.

    A dangling incomplete sequence (e.g. a stream that *ends* mid
    multibyte character) is transcoded exactly as the whole-buffer path
    transcodes a truncated tail: under ``errors="strict"`` the sticky
    status picks up its global offset; under ``errors="replace"`` it
    emits U+FFFD.  Returns ``(tail_result, finished_state)``; calling
    again on a finished stream raises.
    """
    if state.finished:
        raise ValueError("finalize: stream already finalized")
    if state.pending.size == 0:
        res = TranscodeResult(np.zeros(0, _DTYPES[state.dst]),
                              np.int32(0), np.int32(state.status))
        return res, state._replace(finished=True)
    res = _launch(state, state.pending)
    rel = int(res.status)
    event = state.consumed + rel if rel >= 0 else STATUS_OK
    sticky = state.status if state.status >= 0 else event
    new = state._replace(
        consumed=state.consumed + int(state.pending.shape[0]),
        out_count=state.out_count + int(res.count),
        status=int(sticky),
        pending=np.zeros(0, _DTYPES[state.src]),
        finished=True)
    return TranscodeResult(res.buffer, res.count, np.int32(sticky)), new


def transcode_stream(chunks, *, src_format: str, dst_format: str,
                     errors: str = "strict", validate: bool = True,
                     state: Optional[StreamState] = None
                     ) -> Tuple[TranscodeResult, StreamState]:
    """Convenience driver: feed every chunk, finalize, and return the
    combined ``TranscodeResult`` (concatenated buffer, total count,
    final sticky status) plus the finished state."""
    st = stream_init(src_format, dst_format, errors=errors,
                     validate=validate) if state is None else state
    parts = []
    for c in chunks:
        res, st = transcode_stream_chunk(st, c)
        parts.append(np.asarray(res.buffer)[: int(res.count)])
    tail, st = finalize(st)
    parts.append(np.asarray(tail.buffer)[: int(tail.count)])
    out = np.concatenate(parts) if parts else np.zeros(0, _DTYPES[st.dst])
    return TranscodeResult(out, np.int32(st.out_count),
                           np.int32(st.status)), st
