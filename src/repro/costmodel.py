"""Jaxpr-level FLOP/byte cost model with exact loop trip counts.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body **once**
regardless of trip count, which silently drops ~n_layers x of the cost of
any scanned model (verified on this container; see EXPERIMENTS.md
§Dry-run).  This module walks the jaxpr instead, where ``scan`` carries a
static ``length`` — so layer loops, chunked-attention loops and
microbatch loops all multiply correctly, and the traced train_step
includes the backward pass plus rematerialised recompute explicitly.

Cost conventions (a roofline HBM-traffic model, not an op census):
  * dot_general: 2*M*N*K*batch FLOPs; bytes = A + B + out (the MXU
    operands that必 must move through HBM/VMEM);
  * gather/scatter/take: bytes = in + out (embedding lookups, KV writes);
  * elementwise / reductions: FLOPs = output (resp. input) element count;
    bytes = 0 — XLA fuses elementwise chains into neighbouring ops, so
    charging their bytes would double-count traffic;
  * scan: length x body cost; cond: max over branches; while: body
    counted once (flagged) — model code uses scan exclusively.
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np
from jax import core as jcore


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    unknown_while: int = 0

    def __add__(self, o):
        return Cost(self.flops + o.flops, self.bytes + o.bytes,
                    self.unknown_while + o.unknown_while)

    def __mul__(self, k):
        return Cost(self.flops * k, self.bytes * k, self.unknown_while)


def _size_bytes(aval) -> float:
    try:
        return float(np.prod(aval.shape)) * aval.dtype.itemsize
    except Exception:
        return 0.0


def _nelem(aval) -> float:
    try:
        return float(np.prod(aval.shape))
    except Exception:
        return 0.0


_MEMORY_PRIMS = {
    "gather", "scatter", "scatter-add", "scatter_add", "dynamic_slice",
    "dynamic_update_slice", "take", "sort",
}

_RECURSE_PARAM = ("jaxpr", "call_jaxpr")


def eqn_cost(eqn) -> Cost:
    prim = eqn.primitive.name

    if prim == "dot_general":
        dnums = eqn.params["dimension_numbers"]
        (lc, rc), (lb, rb) = dnums
        a, b = eqn.invars[0].aval, eqn.invars[1].aval
        batch = np.prod([a.shape[i] for i in lb], initial=1.0)
        contract = np.prod([a.shape[i] for i in lc], initial=1.0)
        m = np.prod([a.shape[i] for i in range(a.ndim)
                     if i not in lc and i not in lb], initial=1.0)
        n = np.prod([b.shape[i] for i in range(b.ndim)
                     if i not in rc and i not in rb], initial=1.0)
        flops = 2.0 * batch * m * n * contract
        byts = (_size_bytes(a) + _size_bytes(b)
                + sum(_size_bytes(v.aval) for v in eqn.outvars))
        return Cost(flops, byts)

    if prim == "scan":
        body = jaxpr_cost(eqn.params["jaxpr"])
        return body * int(eqn.params["length"])

    if prim == "while":
        body = jaxpr_cost(eqn.params["body_jaxpr"])
        body.unknown_while += 1
        return body

    if prim == "cond":
        branches = [jaxpr_cost(b) for b in eqn.params["branches"]]
        worst = max(branches, key=lambda c: c.flops + c.bytes)
        return worst

    if prim in ("custom_jvp_call", "custom_vjp_call",
                "custom_vjp_call_jaxpr", "remat2", "checkpoint", "pjit",
                "closed_call", "core_call", "xla_call", "custom_jvp_call_jaxpr"):
        for key in _RECURSE_PARAM:
            if key in eqn.params:
                return jaxpr_cost(eqn.params[key])
        # fun params style (custom_jvp with 'call_jaxpr' missing)
        return Cost()

    if prim == "pallas_call":
        # A Pallas kernel's HBM traffic is its operands + results — the
        # kernel body runs out of VMEM (this is the whole point of e.g.
        # the flash-attention kernel).  FLOPs still count from the body.
        inner = Cost()
        if "jaxpr" in eqn.params:
            inner = jaxpr_cost(eqn.params["jaxpr"])
        byts = (sum(_size_bytes(v.aval) for v in eqn.invars)
                + sum(_size_bytes(v.aval) for v in eqn.outvars))
        return Cost(inner.flops, byts)

    if prim in _MEMORY_PRIMS:
        byts = (sum(_size_bytes(v.aval) for v in eqn.invars)
                + sum(_size_bytes(v.aval) for v in eqn.outvars))
        return Cost(0.0, byts)

    if prim in ("reduce_sum", "reduce_max", "reduce_min", "reduce_prod",
                "reduce_and", "reduce_or", "argmax", "argmin",
                "reduce_precision", "cumsum", "cumlogsumexp", "cummax"):
        return Cost(sum(_nelem(v.aval) for v in eqn.invars), 0.0)

    # default: elementwise-ish — 1 flop per output element, fused bytes
    return Cost(sum(_nelem(v.aval) for v in eqn.outvars), 0.0)


def jaxpr_cost(closed) -> Cost:
    jaxpr = closed.jaxpr if hasattr(closed, "jaxpr") else closed
    total = Cost()
    for eqn in jaxpr.eqns:
        total = total + eqn_cost(eqn)
    return total


def fn_cost(fn, *args) -> Cost:
    """Trace ``fn`` with ShapeDtypeStruct args and cost its jaxpr."""
    closed = jax.make_jaxpr(fn)(*args)
    return jaxpr_cost(closed)
