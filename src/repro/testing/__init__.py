# Test-support layer: deterministic fault injection for the transcode
# stack (repro.testing.faults).  Production modules call the no-op
# ``faults.fire`` hook; only the chaos suite arms it.
