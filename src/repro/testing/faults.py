"""Deterministic fault injection for the transcode stack.

The robustness claims of the serving/streaming layer — every fault class
is either retried to success or surfaced as a typed error, with no hang
and no cross-request contamination — are only testable if faults can be
*produced* deterministically.  This module is that production line: the
kernel wrappers, the streaming API and the data pipeline each call
:func:`fire` at a named **fault point**; with no harness armed the call
is a no-op passthrough (one dict lookup on the hot path), and under
``with harness(Fault(...)):`` the registered faults trigger at exact
1-based call indices.

Fault kinds:

  * ``"error"``    -- raise (default :class:`FaultInjected`; any factory
    via ``exc=``) — a transient or permanent launch failure.
  * ``"latency"``  -- sleep ``latency_s`` then continue — a straggling
    launch; results must be unaffected.
  * ``"truncate"`` -- slice the payload to ``truncate_to`` elements — a
    short read / truncated chunk; downstream accounting must follow the
    truncated length, never the intended one.
  * ``"hang"``     -- sleep ``hang_s`` then continue — a wedged transfer
    or kernel.  Semantically the call never comes back on its own:
    pick ``hang_s`` comfortably past the watchdog under test, and the
    supervising layer (``core.recovery``, the feeder watchdog) must
    time out, abandon the call, and surface a typed error.

Fault points currently wired (grep for ``faults.fire``):

  ==================  ====================================================
  point               fires in
  ==================  ====================================================
  ``kernel.onepass``  ``onepass_transcode.transcode_onepass``
  ``kernel.fused``    ``fused_transcode.transcode_fused``
  ``kernel.scan``     ``fused_transcode.scan_fused``
  ``kernel.ragged``   ``ragged_transcode.transcode_ragged``
  ``kernel.ragged_scan``  ``ragged_transcode.scan_ragged``
  ``stream.chunk``    ``core.stream.transcode_stream_chunk`` (payload:
                      the incoming chunk — truncation-capable)
  ``pipeline.batch``  ``data.pipeline.batch_transcode``
  ``shard.launch``    ``core.shard.ragged_transcode_sharded`` /
                      ``scan_ragged_sharded`` — host-side, so it fires
                      per *call* even when the jitted executable is
                      cache-hot (kernel-wrapper points only fire at
                      trace time)
  ``feed.stage``      ``data.shard_feed.DoubleBufferedFeeder`` stage
                      thread (payload: the wave's host arrays)
  ``engine.probe``    ``serve.engine.Engine`` half-open breaker probe
                      launch — lets chaos tests fail the probe itself
  ==================  ====================================================

The harness is intentionally NOT thread-safe (a module-global active
harness): the chaos suite is single-threaded from the harness's point
of view — arming/disarming happens only on the test thread, and the
only cross-thread traffic is ``fire()`` calls from the feeder's stage
worker and the recovery watchdog's launch thread, which read the
module global without locking (benign under the GIL for the dict
bump + list append they perform).  The hook must stay free of locks on
the production path.
"""

from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Callable, Optional, Sequence, Tuple

import numpy as np

# Fault-point names (import these rather than retyping strings in tests).
KERNEL_ONEPASS = "kernel.onepass"
KERNEL_FUSED = "kernel.fused"
KERNEL_SCAN = "kernel.scan"
KERNEL_RAGGED = "kernel.ragged"
KERNEL_RAGGED_SCAN = "kernel.ragged_scan"
STREAM_CHUNK = "stream.chunk"
PIPELINE_BATCH = "pipeline.batch"
SHARD_LAUNCH = "shard.launch"
FEED_STAGE = "feed.stage"
ENGINE_PROBE = "engine.probe"

POINTS = (KERNEL_ONEPASS, KERNEL_FUSED, KERNEL_SCAN, KERNEL_RAGGED,
          KERNEL_RAGGED_SCAN, STREAM_CHUNK, PIPELINE_BATCH,
          SHARD_LAUNCH, FEED_STAGE, ENGINE_PROBE)


class FaultInjected(RuntimeError):
    """The default injected launch failure (transient unless re-raised on
    every retry)."""


@dataclasses.dataclass(frozen=True)
class Fault:
    """One deterministic fault: fire ``kind`` at ``point`` on the call
    indices in ``times`` (1-based; ``None`` = every call)."""

    point: str
    kind: str = "error"         # "error" | "latency" | "truncate" | "hang"
    times: Optional[Sequence[int]] = (1,)
    exc: Optional[Callable[[], BaseException]] = None
    latency_s: float = 0.0
    truncate_to: int = 0
    hang_s: float = 0.0

    def __post_init__(self):
        if self.kind not in ("error", "latency", "truncate", "hang"):
            raise ValueError(f"unknown fault kind: {self.kind!r}")

    def matches(self, call_index: int) -> bool:
        return self.times is None or call_index in tuple(self.times)


class Harness:
    """Armed fault set + per-point call/fire accounting."""

    def __init__(self, faults: Sequence[Fault]):
        self.faults = list(faults)
        self.calls: dict = {}       # point -> total calls observed
        self.fired: list = []       # (point, kind, call_index) log

    def fire(self, point: str, payload=None):
        idx = self.calls.get(point, 0) + 1
        self.calls[point] = idx
        for f in self.faults:
            if f.point != point or not f.matches(idx):
                continue
            self.fired.append((point, f.kind, idx))
            if f.kind == "latency":
                time.sleep(f.latency_s)
            elif f.kind == "hang":
                # A wedge, not a straggler: the sleep only bounds the
                # test's own runtime — the supervisor must have timed
                # out and abandoned this call long before it returns.
                time.sleep(f.hang_s)
            elif f.kind == "truncate":
                if payload is not None:
                    payload = payload[: f.truncate_to]
            else:
                raise (f.exc() if f.exc is not None
                       else FaultInjected(f"injected fault at {point} "
                                          f"(call #{idx})"))
        return payload

    def fires_at(self, point: str) -> int:
        """How many faults have fired at ``point`` so far."""
        return sum(1 for p, _k, _i in self.fired if p == point)


# The single active harness (None = production: fire() is a passthrough).
_ACTIVE: Optional[Harness] = None


def fire(point: str, payload=None):
    """Production hook: no-op passthrough unless a harness is armed."""
    h = _ACTIVE
    if h is None:
        return payload
    return h.fire(point, payload)


def active() -> Optional[Harness]:
    return _ACTIVE


@contextlib.contextmanager
def harness(*faults: Fault):
    """Arm ``faults`` for the dynamic extent of the ``with`` block.

    Nests correctly (the previous harness is restored on exit), yields
    the :class:`Harness` for call/fire-count assertions.
    """
    global _ACTIVE
    prev = _ACTIVE
    h = Harness(faults)
    _ACTIVE = h
    try:
        yield h
    finally:
        _ACTIVE = prev


# ---------------------------------------------------------------------------
# Adversarial input generation (satellite: capacity-overflow sentinels).

# Worst-case speculative garbage per source format: a flood of the unit
# whose speculative decode emits the most destination units.  Only two
# matrix cells can actually exceed their CAP_FACTOR capacity —
# (utf8, utf16): a 0xF0 flood speculatively decodes every byte as a
# 4-byte lead above U+FFFF (2 UTF-16 units per input byte > factor 1),
# and (utf16, utf8): a 0xDBFF flood folds every unit into a pair code
# point above U+FFFF (4 UTF-8 bytes per input unit > factor 3).  Every
# other cell's worst per-element emission is <= its factor.
_OVERFLOW_FLOOD = {
    "utf8": (0xF0, np.uint8),
    "utf16": (0xDBFF, np.uint16),
    "utf32": (0x0011_0000, np.uint32),   # > U+10FFFF: invalid scalar
    "latin1": (0xFF, np.uint8),          # always valid; max 2-byte UTF-8
}

# The (src, dst) cells where the flood's speculative count exceeds the
# CAP_FACTOR capacity (see the derivation above).
OVERFLOW_PAIRS = (("utf8", "utf16"), ("utf16", "utf8"))


def capacity_overflow_input(src: str, n: int) -> np.ndarray:
    """``n`` source units of the worst-case speculative garbage for
    ``src`` (see :data:`OVERFLOW_PAIRS` for the cells where this
    actually exceeds capacity)."""
    val, dt = _OVERFLOW_FLOOD[src]
    return np.full(n, val, dt)
