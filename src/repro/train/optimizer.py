"""AdamW from scratch (pytree states) + LR schedules + ZeRO-1 sharding.

Optimizer state is a pytree mirroring the params; ZeRO-1 is expressed as
*sharding specs* for that pytree (``zero1_specs``): first/second moments
are sharded along every axis the parameter is sharded on PLUS the data
axis where divisible, so state memory scales 1/N_chips.  XLA inserts the
all-gathers at the update — with pjit this is the standard
"sharded-optimizer" formulation.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1
    grad_clip: float = 1.0


def lr_schedule(cfg: AdamWConfig, step):
    """Linear warmup + cosine decay to min_lr_frac."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    t = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    t = jnp.clip(t, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * t))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params):
    """(m, v, count).  Moments in f32 regardless of param dtype."""
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"m": zeros,
            "v": jax.tree.map(jnp.copy, zeros),
            "count": jnp.int32(0)}


def clip_by_global_norm(grads, max_norm):
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), gnorm


def adamw_update(cfg: AdamWConfig, params, grads, state):
    """Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    count = state["count"] + 1
    lr = lr_schedule(cfg, count)
    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        step = mh / (jnp.sqrt(vh) + cfg.eps)
        # decoupled weight decay (no decay on norms/biases: ndim >= 2 only)
        if p.ndim >= 2:
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "count": count}, {
        "grad_norm": gnorm, "lr": lr}


def zero1_specs(params, param_specs, data_axes=("data",), axis_size=16):
    """ZeRO-1: moment sharding = param sharding with the first unsharded,
    divisible axis additionally sharded over the data axes.

    Shapes are consulted so we never claim an indivisible dimension
    (e.g. a (4, d_inner) conv kernel keeps dim 0 replicated).
    """
    def shard_more(p, spec):
        parts = list(spec) if spec is not None else [None] * p.ndim
        while len(parts) < p.ndim:
            parts.append(None)
        # the data axes may appear at most once in a spec: skip params
        # already FSDP-sharded by param_specs.
        used = set()
        for ax in parts:
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                used.add(a)
        if any(a in used for a in data_axes):
            return P(*parts)
        for i, ax in enumerate(parts):
            if ax is None and p.shape[i] % axis_size == 0 and p.shape[i] > 0:
                parts[i] = data_axes if len(data_axes) > 1 else data_axes[0]
                return P(*parts)
        return P(*parts)

    moments = jax.tree.map(
        shard_more, params, param_specs,
        is_leaf=lambda x: x is None)
    return {"m": moments, "v": moments, "count": P()}
