from repro.train import optimizer, grad, train_step, checkpoint, sharding  # noqa: F401
