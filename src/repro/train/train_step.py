"""Training step factory: chunked-CE loss + AdamW update.

The cross-entropy is computed **chunked over the sequence**: the model
returns final hidden states and the loss unembeds one sequence chunk at a
time inside a ``lax.scan``, so the (B, S, V) logits tensor is never
materialised.  For the train_4k shapes this cuts peak activation memory by
the full logits size (e.g. qwen2.5-32b: 4096 x 152064 x 4 B ~ 2.5 GiB per
batch row) at zero FLOP cost — a beyond-paper memory optimization recorded
in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import common as C
from repro.train import grad as G
from repro.train import optimizer as O

LOSS_CHUNK = 512


def chunked_ce_loss(embed_params, hidden, labels, chunk=LOSS_CHUNK):
    """Mean CE over labels >= 0, computed in sequence chunks.

    hidden: (B, S, D); labels: (B, S) int32 with -1 = no loss.
    """
    b, s, d = hidden.shape
    chunk = min(chunk, s)
    nchunk = s // chunk
    rem = s - nchunk * chunk

    def one(h, l):
        logits = C.unembed(embed_params, h)          # (B, c, V) f32
        lse = jax.nn.logsumexp(logits, -1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(l, 0)[..., None], -1)[..., 0]
        mask = (l >= 0).astype(jnp.float32)
        return jnp.sum((lse - gold) * mask), jnp.sum(mask)

    hc = hidden[:, : nchunk * chunk].reshape(b, nchunk, chunk, d)
    lc = labels[:, : nchunk * chunk].reshape(b, nchunk, chunk)

    def body(carry, xs):
        h, l = xs
        tl, tn = one(h, l)
        return (carry[0] + tl, carry[1] + tn), None

    (tot, n), _ = lax.scan(
        body, (jnp.float32(0), jnp.float32(0)),
        (jnp.moveaxis(hc, 1, 0), jnp.moveaxis(lc, 1, 0)))
    if rem:
        tl, tn = one(hidden[:, nchunk * chunk:], labels[:, nchunk * chunk:])
        tot, n = tot + tl, n + tn
    return tot / jnp.maximum(n, 1.0)


def make_loss_fn(model, family: str, aux_weight: float = 0.01):
    """Returns loss_fn(params, batch) -> (loss, metrics)."""

    def loss_fn(params, batch):
        if family == "encdec":
            logits, _, aux = model.apply(params, batch["frames"],
                                         batch["tokens"])
            lse = jax.nn.logsumexp(logits, -1)
            gold = jnp.take_along_axis(
                logits, jnp.maximum(batch["labels"], 0)[..., None], -1)[..., 0]
            mask = (batch["labels"] >= 0).astype(jnp.float32)
            ce = jnp.sum((lse - gold) * mask) / jnp.maximum(jnp.sum(mask), 1)
        else:
            lm = model.lm if family == "vlm" else model
            if family == "vlm":
                b, s = batch["tokens"].shape
                p = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
                pos = jnp.broadcast_to(p, (3, b, s))
            else:
                pos = None
            hidden, _, aux = lm.apply(params, batch["tokens"], pos=pos,
                                      logits=False)
            ce = chunked_ce_loss(params["embed"], hidden, batch["labels"])
        loss = ce + aux_weight * aux
        return loss, {"ce": ce, "aux": aux}

    return loss_fn


def make_train_step(model, family: str, opt_cfg: O.AdamWConfig,
                    n_micro: int = 1):
    """Returns step(params, opt_state, batch) -> (params, opt_state, metrics).

    Pure function of its inputs — jit/pjit it with the sharding specs from
    ``repro.train.sharding``.
    """
    loss_fn = make_loss_fn(model, family)

    def step(params, opt_state, batch):
        loss, grads, metrics = G.accumulate_microbatches(
            loss_fn, params, batch, n_micro)
        params, opt_state, opt_metrics = O.adamw_update(
            opt_cfg, params, grads, opt_state)
        return params, opt_state, {"loss": loss, **metrics, **opt_metrics}

    return step
