"""Fault-tolerant sharded checkpointing with atomic manifests.

Layout:  <dir>/step_<N>/
           manifest.json          — tree structure, leaf shapes/dtypes,
                                    shard layout, completion marker
           <leaf>.h<k>of<n>.npy   — host k's shard of the leaf

Properties (DESIGN.md §6 fault tolerance):
  * **atomic**: data is written to ``step_<N>.tmp`` and renamed only after
    every shard + manifest is on disk — a crash mid-save can never corrupt
    the latest valid checkpoint; ``latest_step`` only sees renamed dirs.
  * **sharded**: each host writes only its 1/n_hosts slice of every leaf
    (split along the largest divisible axis), so save bandwidth scales out.
  * **elastic restore**: ``restore`` reassembles from *any* shard layout —
    a checkpoint saved by 64 hosts restores onto 48; the target mesh never
    needs to match the source mesh.
"""

from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np


def _leaf_name(path) -> str:
    parts = []
    for e in path:
        k = getattr(e, "key", getattr(e, "name", getattr(e, "idx", None)))
        parts.append(str(k))
    return ".".join(parts)


def _split_axis(shape, n_hosts):
    for i, s in enumerate(shape):
        if s % n_hosts == 0 and s >= n_hosts:
            return i
    return -1  # replicate (every host writes host 0's copy check)


def save(ckpt_dir: str, step: int, tree, host_id: int = 0, n_hosts: int = 1):
    """Save ``tree`` (params/opt_state pytree) for this host's shard."""
    final = os.path.join(ckpt_dir, f"step_{step}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)

    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    manifest = {"step": step, "n_hosts": n_hosts, "leaves": {}}
    for path, leaf in leaves:
        name = _leaf_name(path)
        arr = np.asarray(leaf)
        ax = _split_axis(arr.shape, n_hosts)
        manifest["leaves"][name] = {
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
            "split_axis": ax,
        }
        if ax < 0:
            if host_id == 0:
                np.save(os.path.join(tmp, f"{name}.h0of1.npy"), arr)
        else:
            shard = np.split(arr, n_hosts, axis=ax)[host_id]
            np.save(os.path.join(tmp, f"{name}.h{host_id}of{n_hosts}.npy"),
                    shard)

    if host_id == 0:
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
    # Single-host: publish immediately.  Multi-host: the launcher barriers
    # across hosts and then calls ``publish`` exactly once.
    if n_hosts == 1 and host_id == 0:
        publish(ckpt_dir, step)
    return final


def publish(ckpt_dir: str, step: int):
    """Atomic rename step_<N>.tmp -> step_<N> after all hosts have saved."""
    final = os.path.join(ckpt_dir, f"step_{step}")
    tmp = final + ".tmp"
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, tree_like):
    """Rebuild the full pytree from whatever shard layout was saved.

    ``tree_like`` provides the pytree structure (its leaf values are
    ignored); works across host counts (elastic restore).
    """
    d = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    n_src = manifest["n_hosts"]

    def load(path, leaf):
        name = _leaf_name(path)
        meta = manifest["leaves"][name]
        ax = meta["split_axis"]
        if ax < 0:
            return np.load(os.path.join(d, f"{name}.h0of1.npy"))
        shards = [np.load(os.path.join(d, f"{name}.h{k}of{n_src}.npy"))
                  for k in range(n_src)]
        return np.concatenate(shards, axis=ax)

    return jax.tree_util.tree_map_with_path(load, tree_like)
