"""Parameter/activation sharding rules (Megatron TP + FSDP + EP).

``param_specs(params)`` walks the param pytree and assigns a
PartitionSpec per leaf from its *name* and *shape*:

  * column-parallel weights (wq/wk/wv/wi/wg/in_proj/...) — output dim on
    the tensor axis, input dim on the FSDP axes;
  * row-parallel weights (wo/out_proj/dt_proj) — input dim on the tensor
    axis, output dim on the FSDP axes;
  * embeddings — vocab on the tensor axis (vocab-parallel logits);
  * MoE experts — expert dim on the tensor axis when divisible
    (expert parallelism), otherwise hidden dim; FSDP on d_model;
  * stacked layer segments (leading scan axis) are never sharded.

Every assignment is divisibility-checked against the mesh, so one rule
set serves all 10 architectures on any mesh shape (the deepseek 64-expert
table shards over model=16; grok's 8 experts fall back to hidden-dim
sharding automatically).
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

# column-parallel: output (last) dim -> TP
_COLUMN = {"wq", "wk", "wv", "wi", "wg", "in_proj", "wa", "wx", "x_proj"}
# row-parallel: input (first of the trailing 2 dims) -> TP
_ROW = {"wo", "out_proj", "dt_proj"}
_REPLICATED = {"router", "scale", "lam", "D", "dt_bias", "conv_b",
               "bq", "bk", "bv", "conv_w", "A_log", "enc_pos"}


def _axis_size(mesh, axes):
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    return int(np.prod([mesh.shape[a] for a in axes]))


def leaf_spec(name: str, shape, mesh, tp="model", fsdp="data",
              stacked: bool = False):
    """PartitionSpec for one named parameter leaf."""
    tp_n = _axis_size(mesh, tp)
    fsdp_n = _axis_size(mesh, fsdp)
    nd = len(shape)
    off = 1 if stacked else 0       # leading layer-stack axis: replicated
    dims: list = [None] * nd
    body = shape[off:]

    def try_set(i, axes, n):
        if axes is None:
            return False
        if dims[off + i] is None and body[i] % n == 0 and body[i] >= n:
            dims[off + i] = axes
            return True
        return False

    if name in _REPLICATED:
        return P(*dims)

    if name == "table":              # (vocab, d_model)
        try_set(0, tp, tp_n)
        try_set(1, fsdp, fsdp_n)
        return P(*dims)

    if len(body) == 3 and name in ("wi", "wg", "wo"):   # MoE (e, d, f)/(e, f, d)
        if not try_set(0, tp, tp_n):                    # expert parallelism
            try_set(2 if name != "wo" else 1, tp, tp_n)  # else hidden dim
        # FSDP on d_model (dim 1 for wi/wg, dim 2 for wo)
        try_set(1 if name != "wo" else 2, fsdp, fsdp_n)
        return P(*dims)

    if len(body) == 2 and name in _COLUMN:
        try_set(1, tp, tp_n)
        try_set(0, fsdp, fsdp_n)
        return P(*dims)

    if len(body) == 2 and name in _ROW:
        try_set(0, tp, tp_n)
        try_set(1, fsdp, fsdp_n)
        return P(*dims)

    # generic fallback: shard the largest divisible dim on TP
    if len(body) >= 2:
        order = sorted(range(len(body)), key=lambda i: -body[i])
        for i in order:
            if try_set(i, tp, tp_n):
                break
        for i in order:
            if try_set(i, fsdp, fsdp_n):
                break
    return P(*dims)


def param_specs(params, mesh, tp="model", fsdp="data"):
    """Pytree of PartitionSpec congruent with ``params``."""
    def walk(path, leaf):
        name = None
        for entry in reversed(path):
            key = getattr(entry, "key", getattr(entry, "name", None))
            if isinstance(key, str):
                name = key
                break
        stacked = any(
            isinstance(getattr(e, "key", None), str)
            and (getattr(e, "key", "").startswith("seg")
                 or getattr(e, "key", "") in ("enc", "dec"))
            for e in path)
        return leaf_spec(name or "", leaf.shape, mesh, tp, fsdp, stacked)

    return jax.tree_util.tree_map_with_path(walk, params)


def state_specs(state_shapes, mesh, dp=("data",), tp="model"):
    """Sharding for decode-state pytrees (stacked KV caches / SSM states).

    Leaves look like (n_layers, B, cap, kv, hd) / (n_layers, B, d) /
    (n_layers, B): skip the layer-stack dim, shard the batch dim over DP
    when divisible (falling back to the sequence/cap dim — sequence
    parallelism for batch=1 long-context cells), and the widest remaining
    dim over TP.
    """
    dp_n = _axis_size(mesh, dp)
    tp_n = _axis_size(mesh, tp)
    dp_ax = dp if len(dp) > 1 else dp[0]

    def spec(leaf):
        shape = leaf.shape
        nd = len(shape)
        dims: list = [None] * nd
        if nd < 2:
            return P(*dims)
        # dim 0 is the layer stack; dim 1 is batch
        used_dp = False
        if shape[1] % dp_n == 0 and shape[1] >= dp_n:
            dims[1] = dp_ax
            used_dp = True
        body = list(range(2, nd))
        if not used_dp:
            for i in body:             # SP fallback: cache-length dim
                if shape[i] % dp_n == 0 and shape[i] >= dp_n:
                    dims[i] = dp_ax
                    used_dp = True
                    body.remove(i)
                    break
        # TP from the TRAILING dims (kv heads / head_dim): never the
        # cache-length dim 2 of a 5-D attention cache — the decode chunk
        # scan dynamic-slices along it and a TP shard there forces an
        # all-gather per chunk.  (4-D SSM states shard dim 2 = d_inner.)
        for i in reversed(body):
            if i == 2 and nd >= 5:
                continue
            if tp is not None and dims[i] is None \
                    and shape[i] % tp_n == 0 and shape[i] >= tp_n:
                dims[i] = tp
                break
        return P(*dims)

    return jax.tree.map(spec, state_shapes)


def batch_specs(kind: str, batch: int, mesh, dp=("data",)):
    """Activation/input sharding for a given step kind.

    Data parallelism over the batch when divisible; otherwise sequence
    parallelism (shard the sequence/cache-length axis) — the long_500k
    batch=1 cells rely on this.
    """
    dp_n = _axis_size(mesh, dp)
    dp_ax = dp if len(dp) > 1 else dp[0]
    if batch % dp_n == 0 and batch >= dp_n:
        return P(dp_ax, None)      # (B, S): shard batch
    return P(None, dp_ax)          # shard sequence instead (SP)
