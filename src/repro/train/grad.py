"""Distributed-optimization tricks: gradient compression + hierarchical
collectives + microbatch accumulation.

``compressed_psum``: int8-quantized all-reduce with **error feedback** —
the quantization residual is carried in optimizer-side state and added
back the next step, so the compression bias does not accumulate (Seide et
al. / EF-SGD).  Intended for the slow cross-pod (DCN) hop of a
hierarchical reduction: reduce-scatter intra-pod over ICI at full
precision, all-reduce the 1/N-sized shard across pods in int8, then
all-gather intra-pod.

These are ``shard_map``-level building blocks: they take explicit mesh
axis names.  The pjit training path lets XLA insert full-precision
reductions automatically; ``launch/train.py --grad-sync=compressed``
switches to the explicit path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax


def quantize_int8(x):
    """Per-tensor symmetric int8 quantization.  Returns (q, scale)."""
    x = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_psum(x, axis_name, err):
    """int8 all-reduce over ``axis_name`` with error feedback.

    The quantization scale is made **uniform across the axis** first
    (one scalar pmax), so the integer sum dequantizes exactly —
    per-device scales would make sum(q_i * s_i) != s * sum(q_i).

    Args:
      x: local f32 gradient shard.
      err: residual carried from the previous step (same shape).
    Returns (reduced, new_err).
    """
    x = x.astype(jnp.float32) + err
    amax = lax.pmax(jnp.max(jnp.abs(x)), axis_name)   # scalar wire cost
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    new_err = x - q.astype(jnp.float32) * scale       # quantization loss
    # int8 payload on the wire; widen for the accumulator
    total = lax.psum(q.astype(jnp.int32), axis_name)
    return total.astype(jnp.float32) * scale, new_err


def hierarchical_grad_sync(grads, err, *, ici_axis="data", dcn_axis="pod",
                           compress=True):
    """Hierarchical gradient reduction inside ``shard_map``.

    1. ``psum_scatter`` over the intra-pod ICI axis (full precision —
       ICI is fast, and scattering makes the cross-pod payload 1/N).
    2. all-reduce the shard across pods over DCN, int8 + error feedback.
    3. ``all_gather`` the result back over ICI.

    grads/err: congruent pytrees of f32 leaves.  Returns (grads, new_err).
    """
    def sync_leaf(g, e):
        g = g.astype(jnp.float32)
        flat = g.reshape(-1)
        n = lax.psum(1, ici_axis)
        pad = (-flat.shape[0]) % n
        if pad:
            flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
        shard = lax.psum_scatter(flat, ici_axis, scatter_dimension=0,
                                 tiled=True)
        if compress:
            shard, new_e = compressed_psum(shard, dcn_axis, e)
        else:
            shard, new_e = lax.psum(shard, dcn_axis), e
        full = lax.all_gather(shard, ici_axis, axis=0, tiled=True)
        if pad:
            full = full[:-pad]
        return full.reshape(g.shape), new_e

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err)
    out = [sync_leaf(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(treedef, [o[0] for o in out]),
            jax.tree.unflatten(treedef, [o[1] for o in out]))


def init_error_feedback(grads_like, *, ici_axis_size):
    """Residual buffers matching the post-scatter shard shapes."""
    def shard_shape(g):
        n = g.size
        n_pad = n + ((-n) % ici_axis_size)
        return jnp.zeros((n_pad // ici_axis_size,), jnp.float32)
    return jax.tree.map(shard_shape, grads_like)


def accumulate_microbatches(loss_fn, params, batch, n_micro: int):
    """Gradient accumulation over ``n_micro`` microbatches via scan.

    batch: pytree whose leaves have leading dim B = n_micro * b_micro.
    Returns (mean_loss, mean_grads, mean_metrics).
    """
    if n_micro == 1:
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return loss, grads, metrics

    def reshape(x):
        return x.reshape((n_micro, x.shape[0] // n_micro) + x.shape[1:])

    micro = jax.tree.map(reshape, batch)
    zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def body(carry, mb):
        acc_loss, acc_g = carry
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, mb)
        acc_g = jax.tree.map(lambda a, g: a + g.astype(jnp.float32),
                             acc_g, grads)
        return (acc_loss + loss, acc_g), metrics

    (tot_loss, tot_g), metrics = lax.scan(body, (jnp.float32(0), zero_g),
                                          micro)
    grads = jax.tree.map(lambda g: g / n_micro, tot_g)
    last_metrics = jax.tree.map(lambda m: m[-1], metrics)
    return tot_loss / n_micro, grads, last_metrics
