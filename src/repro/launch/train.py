"""Training launcher: mesh-parallel train loop with fault tolerance.

    PYTHONPATH=src python -m repro.launch.train --arch bytelm-100m \
        --steps 200 --batch 8 --seq 512 [--reduced] [--resume]

On this container it runs on the host devices (``make_host_mesh``); on a
real cluster the same code takes the production mesh — the step function,
sharding specs and checkpoint protocol are mesh-shape-agnostic.

Fault-tolerance loop (DESIGN.md §6):
  * checkpoint every ``--ckpt-every`` steps (sharded, atomic);
  * on start, ``--resume`` restores the latest step and the data pipeline
    ``skip_to``s the right global batch — a replacement host rejoins at a
    step boundary with no coordination;
  * SIGTERM-safe: the current step finishes, a checkpoint is written,
    then exit (preemption handling).
"""

from __future__ import annotations

import argparse
import signal
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.data import pipeline as pipemod
from repro.launch import mesh as meshmod
from repro.models import registry
from repro.train import checkpoint as CK
from repro.train import optimizer as O
from repro.train import sharding as SH
from repro.train import train_step as TS

_STOP = False


def _sigterm(*_):
    global _STOP
    _STOP = True


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="bytelm-100m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--micro", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    signal.signal(signal.SIGTERM, _sigterm)

    family, cfg, model = registry.get(args.arch, reduced=args.reduced)
    mesh = meshmod.make_host_mesh()
    dp = meshmod.dp_axes(mesh)
    print(f"mesh: {dict(mesh.shape)}  arch: {args.arch}"
          f"{' (reduced)' if args.reduced else ''}")

    params = model.init(jax.random.PRNGKey(0))
    opt_cfg = O.AdamWConfig(lr=args.lr, total_steps=args.steps,
                            warmup_steps=max(args.steps // 20, 5))
    opt_state = O.init_opt_state(params)

    pspecs = SH.param_specs(params, mesh, fsdp=dp)
    ospecs = O.zero1_specs(params, pspecs, data_axes=dp,
                           axis_size=int(np.prod([mesh.shape[a] for a in dp])))
    bspec = SH.batch_specs("train", args.batch, mesh, dp=dp)
    shardings = (
        jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                     is_leaf=lambda x: isinstance(x, P)),
        jax.tree.map(lambda s: NamedSharding(mesh, s), ospecs,
                     is_leaf=lambda x: isinstance(x, P)),
        {"tokens": NamedSharding(mesh, bspec),
         "labels": NamedSharding(mesh, bspec)},
    )

    step_fn = TS.make_train_step(model, family, opt_cfg, n_micro=args.micro)
    with mesh:
        jstep = jax.jit(step_fn, in_shardings=shardings,
                        donate_argnums=(0, 1))

        pipe = pipemod.TextPipeline(pipemod.PipelineConfig(
            seq_len=args.seq, global_batch=args.batch))
        start = 0
        if args.resume:
            last = CK.latest_step(args.ckpt_dir)
            if last is not None:
                tree = CK.restore(args.ckpt_dir, last,
                                  {"params": params, "opt": opt_state})
                params = jax.tree.map(jnp.asarray, tree["params"])
                opt_state = jax.tree.map(jnp.asarray, tree["opt"])
                start = last
                pipe.skip_to(last)
                print(f"resumed from step {last}")

        params = jax.device_put(params, shardings[0])
        opt_state = jax.device_put(opt_state, shardings[1])

        t0 = time.time()
        for step in range(start, args.steps):
            batch = pipe.next_batch()
            params, opt_state, metrics = jstep(params, opt_state, batch)
            if (step + 1) % args.log_every == 0:
                loss = float(metrics["loss"])
                dt = (time.time() - t0) / args.log_every
                tok_s = args.batch * args.seq / dt
                print(f"step {step+1:5d}  loss {loss:.4f}  "
                      f"gnorm {float(metrics['grad_norm']):.3f}  "
                      f"lr {float(metrics['lr']):.2e}  "
                      f"{tok_s:,.0f} tok/s", flush=True)
                t0 = time.time()
            if (step + 1) % args.ckpt_every == 0 or _STOP:
                CK.save(args.ckpt_dir, step + 1,
                        {"params": jax.device_get(params),
                         "opt": jax.device_get(opt_state)})
                if _STOP:
                    print("SIGTERM: checkpointed, exiting")
                    sys.exit(0)
    print("done")


if __name__ == "__main__":
    main()
