# NOTE: repro.launch.dryrun sets XLA_FLAGS at import; do not import it here.
from repro.launch import mesh  # noqa: F401
