"""Elastic scaling & failure handling.

At 1000+ node scale, chip/host failures are routine.  The recovery
protocol implemented here (and exercised in tests/test_distribution.py):

  1. a failure is detected (heartbeat timeout / NCCL-equivalent error —
     here: the caller reports ``failed`` chips);
  2. ``plan_remesh`` computes the largest valid (data, model) sub-mesh of
     the survivors — the TP axis is preserved (TP groups need complete
     ICI neighborhoods), the DP axis shrinks;
  3. every survivor restores the latest checkpoint — ``repro.train.
     checkpoint`` restores across host counts (elastic reshard), and the
     data pipeline ``skip_to``s the last completed step;
  4. the step function is re-jitted for the new mesh: sharding specs are
     *functions of the mesh*, so nothing else changes;
  5. the global batch is kept constant by raising gradient-accumulation
     microbatches (``micro_for``) — training math is unchanged, stragglers
     from degraded hosts are absorbed at step granularity.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

import jax

from repro.launch import mesh as meshmod


@dataclasses.dataclass
class RemeshPlan:
    data: int
    model: int
    n_chips: int
    n_micro: int          # microbatches to keep the global batch constant
    lost_fraction: float


def plan_remesh(old_shape, failed_chips: int, global_batch: int,
                base_micro: int = 1) -> Optional[RemeshPlan]:
    """Largest valid sub-mesh after ``failed_chips`` failures.

    Keeps the model axis intact (TP needs full groups); shrinks data.
    Returns None when fewer than one full TP group survives.
    """
    model = old_shape[-1]
    total = int(np.prod(old_shape))
    survivors = total - failed_chips
    new_data = survivors // model
    if new_data < 1:
        return None
    # keep global batch: scale microbatches by the DP shrink factor
    old_data = total // model
    scale = -(-old_data // new_data)  # ceil
    n_micro = base_micro * scale
    while global_batch % (new_data * n_micro) and n_micro < global_batch:
        n_micro += 1
    return RemeshPlan(data=new_data, model=model,
                      n_chips=new_data * model, n_micro=n_micro,
                      lost_fraction=failed_chips / total)


def make_mesh_from_plan(plan: RemeshPlan, devices=None):
    devices = devices if devices is not None else jax.devices()
    need = plan.data * plan.model
    dev = np.array(devices[:need]).reshape(plan.data, plan.model)
    from jax.sharding import Mesh
    return Mesh(dev, ("data", "model"))


def straggler_skip_plan(step: int, n_hosts: int, global_batch: int):
    """Deterministic host->slots assignment for step ``step``.

    A restarted host calls this to know exactly which documents it owes —
    the same rule the data pipeline uses, so no replay or coordination is
    required (the pipeline is a pure function of (seed, step, slot)).
    """
    return {h: [k for k in range(global_batch) if k % n_hosts == h]
            for h in range(n_hosts)}
