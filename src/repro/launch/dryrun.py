import os
os.environ["XLA_FLAGS"] = (os.environ.get("_REPRO_EXTRA_XLA", "") +
                           " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: AOT lower + compile every (arch x shape x mesh) cell.

The two lines above run before ANY other import (jax locks the device
count on first init): the dry-run — and only the dry-run — sees 512
placeholder host devices so ``jax.make_mesh`` can build the production
meshes:

    single-pod:  (16, 16)      axes (data, model)          256 chips
    multi-pod:   (2, 16, 16)   axes (pod, data, model)     512 chips

For each cell we build the step function (train_step / prefill / decode),
bind the sharding specs from ``repro.train.sharding``, lower with
ShapeDtypeStruct stand-ins (no allocation), compile, and record
``memory_analysis()`` + ``cost_analysis()`` + the three-term roofline
(``repro.roofline``).  A failure here (sharding mismatch, OOM at compile,
unsupported collective) is a bug in the framework.

Usage:
    python -m repro.launch.dryrun --arch granite-8b --shape train_4k
    python -m repro.launch.dryrun --all [--multipod] [--out results.json]
"""

import argparse
import json
import sys
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs as cfgmod
from repro import costmodel as CM
from repro import roofline as RL
from repro.configs import shapes as shp
from repro.launch import mesh as meshmod
from repro.models import registry
from repro.serve import kvcache, serve_step
from repro.train import optimizer as O
from repro.train import sharding as SH
from repro.train import train_step as TS


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(arch: str, shape_name: str):
    """ShapeDtypeStruct stand-ins for every model input of the cell."""
    family, cfg, model = registry.get(arch)
    s = shp.SHAPES[shape_name]
    seq, gb, kind = s["seq_len"], s["global_batch"], s["kind"]
    specs = {}
    if kind == "train":
        specs["tokens"] = _sds((gb, seq), jnp.int32)
        specs["labels"] = _sds((gb, seq), jnp.int32)
        if family == "encdec":
            specs["frames"] = _sds((gb, cfg.n_audio_frames, cfg.d_model),
                                   jnp.bfloat16)
    elif kind == "prefill":
        specs["tokens"] = _sds((gb, seq), jnp.int32)
        specs["lens"] = _sds((gb,), jnp.int32)
        if family == "encdec":
            specs["frames"] = _sds((gb, cfg.n_audio_frames, cfg.d_model),
                                   jnp.bfloat16)
    else:  # decode
        specs["tok"] = _sds((gb, 1), jnp.int32)
        specs["pos"] = _sds((gb,), jnp.int32)
        if family == "encdec":
            specs["frames"] = _sds((gb, cfg.n_audio_frames, cfg.d_model),
                                   jnp.bfloat16)
    return specs


def _shardings(tree, specs, mesh):
    return jax.tree.map(
        lambda spec: NamedSharding(mesh, spec), specs,
        is_leaf=lambda x: isinstance(x, P))


def build_cell(arch: str, shape_name: str, mesh, *, remat=True,
               layout: str = "tp", remat_policy: str = "full",
               loss_chunk=None):
    """Returns (fn, arg_shapes, in_shardings, model_flops).

    layout="tp":  Megatron TP over 'model' + FSDP over the data axes.
    layout="dp":  pure data parallelism over ALL axes (batch spans the
                  whole mesh, weights ZeRO-3 sharded over every axis and
                  re-gathered at use) — wins for models whose weights fit
                  per-chip, where TP activation all-reduces dominate.
    """
    import dataclasses
    family, cfg, model = registry.get(arch)
    if hasattr(cfg, "remat") and (not remat or remat_policy != "full"):
        kw = {"remat": remat}
        if hasattr(cfg, "remat_policy"):
            kw["remat_policy"] = remat_policy
        cfg = dataclasses.replace(cfg, **kw)
        model = registry.build(cfg)
    lm = getattr(model, "lm", model)
    s = shp.SHAPES[shape_name]
    seq, gb, kind = s["seq_len"], s["global_batch"], s["kind"]
    if layout == "dp":
        dp = meshmod.dp_axes(mesh) + ("model",)
        tp = None
    else:
        dp = meshmod.dp_axes(mesh)
        tp = "model"

    params_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    # Serving keeps params TP-resident (no FSDP): no optimizer states to
    # shard, and ZeRO-3 re-gather costs ~8 GB/device per decode token
    # (§Perf iteration 4).
    p_fsdp = dp if kind == "train" else None
    pspecs = SH.param_specs(params_shapes, mesh, tp=tp, fsdp=p_fsdp)
    n_params = RL.count_params(params_shapes)
    n_active = RL.active_params(cfg, n_params)

    if kind == "train":
        opt_cfg = O.AdamWConfig()
        step = TS.make_train_step(model, family, opt_cfg)
        opt_shapes = jax.eval_shape(O.init_opt_state, params_shapes)
        ospecs = O.zero1_specs(params_shapes, pspecs, data_axes=dp,
                               axis_size=int(np.prod(
                                   [mesh.shape[a] for a in dp])))
        bspec = SH.batch_specs(kind, gb, mesh, dp=dp)
        batch_shapes = input_specs(arch, shape_name)
        bspecs = {k: (bspec if v.ndim == 2 else P(bspec[0], None, None))
                  for k, v in batch_shapes.items()}
        fn = step
        args = (params_shapes, opt_shapes, batch_shapes)
        shardings = (_shardings(params_shapes, pspecs, mesh),
                     _shardings(opt_shapes, ospecs, mesh),
                     _shardings(batch_shapes, bspecs, mesh))
        model_flops = 6.0 * n_active * gb * seq
        return fn, args, shardings, model_flops

    if kind == "prefill":
        cap = kvcache.capacity_for(cfg, seq)
        if family == "encdec":
            pre, _ = serve_step.make_encdec_steps(model)
            ins = input_specs(arch, shape_name)

            def fn(params, frames, tokens):
                logits, state = pre(params, frames, tokens, cap)
                return logits

            bspec = SH.batch_specs(kind, gb, mesh, dp=dp)
            args = (params_shapes, ins["frames"], ins["tokens"])
            shardings = (_shardings(params_shapes, pspecs, mesh),
                         NamedSharding(mesh, P(bspec[0], None, None)),
                         NamedSharding(mesh, bspec))
            return fn, args, shardings, 2.0 * n_active * gb * seq

        prefill = serve_step.make_prefill(model, family)
        state_shapes = jax.eval_shape(lambda: lm.init_state(gb, cap))
        sspecs = SH.state_specs(state_shapes, mesh, dp=dp, tp=tp)
        ins = input_specs(arch, shape_name)
        bspec = SH.batch_specs(kind, gb, mesh, dp=dp)
        args = (params_shapes, ins["tokens"], ins["lens"], state_shapes)
        shardings = (_shardings(params_shapes, pspecs, mesh),
                     NamedSharding(mesh, bspec),
                     NamedSharding(mesh, P(bspec[0])),
                     _shardings(state_shapes, sspecs, mesh))
        return prefill, args, shardings, 2.0 * n_active * gb * seq

    # decode
    cap = kvcache.capacity_for(cfg, seq)
    if family == "encdec":
        _, dec = serve_step.make_encdec_steps(model)
        state_shapes = jax.eval_shape(
            lambda: model.init_state(
                model.init(jax.random.PRNGKey(0)),
                jnp.zeros((gb, cfg.n_audio_frames, cfg.d_model),
                          jnp.bfloat16), gb, cap))
        # init_state needs params: eval_shape the composite instead
        def mk_state(params, frames):
            return model.init_state(params, frames, gb, cap)
        state_shapes = jax.eval_shape(
            mk_state, params_shapes,
            _sds((gb, cfg.n_audio_frames, cfg.d_model), jnp.bfloat16))
        sspecs = SH.state_specs(state_shapes, mesh, dp=dp, tp=tp)

        def fn(params, tok, state):
            return dec(params, tok, state)

        bspec = SH.batch_specs("decode", gb, mesh, dp=dp)
        args = (params_shapes, _sds((gb, 1), jnp.int32), state_shapes)
        shardings = (_shardings(params_shapes, pspecs, mesh),
                     NamedSharding(mesh, bspec),
                     _shardings(state_shapes, sspecs, mesh))
        return fn, args, shardings, 2.0 * n_active * gb

    decode = serve_step.make_decode(model, family)
    state_shapes = jax.eval_shape(lambda: lm.init_state(gb, cap))
    sspecs = SH.state_specs(state_shapes, mesh, dp=dp, tp=tp)
    ins = input_specs(arch, shape_name)
    dp_n = int(np.prod([mesh.shape[a] for a in dp]))
    # single-token inputs: DP over batch when divisible, else replicated
    # (the cache still gets sequence-parallel sharding via state_specs).
    tok_spec = P(dp if len(dp) > 1 else dp[0], None) if gb % dp_n == 0 \
        else P(None, None)
    key_shape = jax.eval_shape(lambda: jax.random.PRNGKey(0))
    args = (params_shapes, ins["tok"], ins["pos"], state_shapes, key_shape)
    shardings = (_shardings(params_shapes, pspecs, mesh),
                 NamedSharding(mesh, tok_spec),
                 NamedSharding(mesh, P(tok_spec[0])),
                 _shardings(state_shapes, sspecs, mesh),
                 NamedSharding(mesh, P()))
    return decode, args, shardings, 2.0 * n_active * gb


def dryrun_cell(arch: str, shape_name: str, *, multi_pod=False, remat=True,
                opt=False, layout: str = "tp", remat_policy: str = "full",
                verbose=True):
    """Lower + compile one cell; returns the result record dict.

    opt=True enables the beyond-paper optimization set (shardctx weight
    re-gather constraints); opt=False is the recorded baseline.
    """
    import contextlib

    from repro.models import shardctx

    mesh = meshmod.make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    chips = int(np.prod(list(mesh.shape.values())))
    fn, args, shardings, model_flops = build_cell(
        arch, shape_name, mesh, remat=remat, layout=layout,
        remat_policy=remat_policy)

    tp_axis = None if layout == "dp" else "model"
    ctx = shardctx.use(tp_axis=tp_axis, tp_size=mesh.shape["model"]) \
        if opt else contextlib.nullcontext()
    with mesh, ctx:
        cost = CM.fn_cost(fn, *args)  # exact-trip-count flops/bytes (global)
        jitted = jax.jit(fn, in_shardings=shardings)
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        rl = RL.analyze(arch, shape_name, mesh_name, chips, compiled,
                        lowered, model_flops=model_flops, jaxpr_cost=cost)

    rec = rl.to_dict()
    rec["ok"] = True
    rec["remat"] = remat
    rec["variant"] = (f"opt-{layout}" if opt else "baseline")
    if mem is not None:
        for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                     "output_size_in_bytes", "generated_code_size_in_bytes"):
            rec[f"mem_{attr}"] = getattr(mem, attr, None)
    if verbose:
        print(f"[{arch} x {shape_name} x {mesh_name}] OK  "
              f"flops={rec['hlo_flops']:.3e} bytes={rec['hlo_bytes']:.3e} "
              f"coll={rec['coll_bytes']:.3e} bottleneck={rec['bottleneck']}")
        if mem is not None:
            print(f"  memory_analysis: temp={rec.get('mem_temp_size_in_bytes')} "
                  f"args={rec.get('mem_argument_size_in_bytes')}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--opt", action="store_true",
                    help="enable beyond-paper sharding optimizations")
    ap.add_argument("--layout", default="tp", choices=["tp", "dp"])
    ap.add_argument("--remat-policy", default="full",
                    choices=["full", "dots"])
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    arch_ids = [a for a in cfgmod.ARCH_IDS if a != "bytelm-100m"]
    if args.all:
        todo = [(a, s) for (a, s, run, _) in shp.cells(arch_ids) if run]
    else:
        todo = [(args.arch, args.shape)]

    meshes = [False, True] if (args.both_meshes or args.multipod and args.all) \
        else [args.multipod]

    results = []
    for arch, shape in todo:
        for mp in meshes:
            try:
                rec = dryrun_cell(arch, shape, multi_pod=mp,
                                  remat=not args.no_remat, opt=args.opt,
                                  layout=args.layout,
                                  remat_policy=args.remat_policy)
            except Exception as e:  # noqa: BLE001
                traceback.print_exc()
                rec = {"arch": arch, "shape": shape,
                       "mesh": "2x16x16" if mp else "16x16",
                       "ok": False, "error": f"{type(e).__name__}: {e}"}
            results.append(rec)

    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
    n_ok = sum(r["ok"] for r in results)
    print(f"\n{n_ok}/{len(results)} cells OK")
    sys.exit(0 if n_ok == len(results) else 1)


if __name__ == "__main__":
    main()
