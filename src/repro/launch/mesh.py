"""Production mesh construction.

``make_production_mesh`` is a function (not a module-level constant) so
importing this module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import, and everything else sees the real single-device platform.
"""

from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(n_devices=None, model: int = 2):
    """Small mesh over the real host devices (tests / examples)."""
    n = n_devices or len(jax.devices())
    model = min(model, n)
    data = n // model
    return jax.make_mesh((data, model), ("data", "model"))


def dp_axes(mesh):
    """Data-parallel axes: ('pod', 'data') when a pod axis exists."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def largest_submesh(shape, failed: int):
    """Elastic scaling helper: biggest (data, model) grid from the
    surviving chips after ``failed`` failures, keeping the model axis
    (TP requires full ICI groups, so we shrink the data axis)."""
    data, model = shape[-2], shape[-1]
    chips = int(np.prod(shape)) - failed
    new_data = chips // model
    return (new_data, model)
