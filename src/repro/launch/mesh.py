"""Production mesh construction.

``make_production_mesh`` is a function (not a module-level constant) so
importing this module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import, and everything else sees the real single-device platform.
"""

from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(n_devices=None, model: int = 2):
    """Small mesh over the real host devices (tests / examples)."""
    n = n_devices or len(jax.devices())
    model = min(model, n)
    data = n // model
    return jax.make_mesh((data, model), ("data", "model"))


def make_transcode_mesh(n_shards=None):
    """1-D data-only mesh for the sharded ragged transcode path
    (``repro.core.shard``): ``n_shards`` host-platform devices on one
    ``"data"`` axis — no model axis, so transcode tests/benches never
    drag in the training-mesh geometry."""
    devices = jax.devices()
    n = len(devices) if n_shards is None else int(n_shards)
    if n < 1:
        raise ValueError(f"n_shards must be >= 1, got {n}")
    if n > len(devices):
        raise ValueError(
            f"n_shards={n} exceeds the {len(devices)} available "
            f"device(s); set XLA_FLAGS=--xla_force_host_platform_"
            f"device_count=N for multi-shard runs on CPU")
    return jax.sharding.Mesh(np.asarray(devices[:n]), ("data",))


def dp_axes(mesh):
    """Data-parallel axes: ('pod', 'data') when a pod axis exists."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def largest_submesh(shape, failed: int):
    """Elastic scaling helper: biggest (data, model) grid from the
    surviving chips after ``failed`` failures, keeping the model axis
    (TP requires full ICI groups, so we shrink the data axis)."""
    data, model = shape[-2], shape[-1]
    chips = int(np.prod(shape)) - failed
    new_data = chips // model
    return (new_data, model)
