"""Serving launcher: batched request demo through the transcode boundary.

    PYTHONPATH=src python -m repro.launch.serve --arch bytelm-100m \
        --reduced --prompts "hello" "café 中文"

Loads (or inits) params, builds the Engine, serves a batch of UTF-8
prompts and prints UTF-8 and UTF-16LE responses — both egress encodings
exercise the paper's vectorized encoders.
"""

from __future__ import annotations

import argparse

import jax

from repro.models import registry
from repro.serve.engine import Engine, Request
from repro.train import checkpoint as CK


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="bytelm-100m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--prompts", nargs="*",
                    default=["hello world", "café 中文"])
    args = ap.parse_args(argv)

    family, cfg, model = registry.get(args.arch, reduced=args.reduced)
    params = model.init(jax.random.PRNGKey(0))
    if args.ckpt_dir:
        last = CK.latest_step(args.ckpt_dir)
        if last is not None:
            tree = CK.restore(args.ckpt_dir, last, {"params": params})
            params = tree["params"]
            print(f"loaded checkpoint step {last}")

    eng = Engine(model, cfg, family, params, max_new=args.max_new,
                 temperature=args.temperature)
    reqs = []
    for p in args.prompts:
        reqs.append(Request(p.encode("utf-8")))
        reqs.append(Request(p.encode("utf-8"), out_encoding="utf-16-le"))
    results = eng.serve(reqs)
    for r, res in zip(reqs, results):
        print(f"prompt={r.prompt_bytes!r} enc={r.out_encoding} ok={res.ok} "
              f"-> {res.text_bytes[:60]!r}{res.error}")


if __name__ == "__main__":
    main()
