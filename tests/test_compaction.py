"""Property tests for stream compaction (the pshufb replacement)."""

import numpy as np
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import compaction

SETTINGS = dict(max_examples=50, deadline=None)


@settings(**SETTINGS)
@given(st.lists(st.tuples(st.integers(-100, 100), st.booleans()),
                min_size=1, max_size=64))
def test_compact_matches_numpy(items):
    vals = np.array([v for v, _ in items], np.int32)
    mask = np.array([m for _, m in items], bool)
    out, cnt = compaction.compact(jnp.asarray(vals), jnp.asarray(mask),
                                  len(vals))
    want = vals[mask]
    assert int(cnt) == len(want)
    assert np.array_equal(np.asarray(out)[: len(want)], want)


@settings(**SETTINGS)
@given(st.lists(st.tuples(st.integers(-100, 100), st.booleans()),
                min_size=1, max_size=64))
def test_compact_gather_matches_scatter(items):
    vals = np.array([v for v, _ in items], np.int32)
    mask = np.array([m for _, m in items], bool)
    o1, c1 = compaction.compact(jnp.asarray(vals), jnp.asarray(mask),
                                len(vals))
    o2, c2 = compaction.compact_gather(jnp.asarray(vals), jnp.asarray(mask),
                                       len(vals))
    assert int(c1) == int(c2)
    assert np.array_equal(np.asarray(o1)[: int(c1)],
                          np.asarray(o2)[: int(c2)])


# ---------------------------------------------------------------------------
# Directed edge cases (satellite of the fused-pipeline PR): empty inputs,
# degenerate masks, exact-capacity and overflow buffers, tile-scan helpers.


def test_compact_all_false_mask():
    vals = jnp.arange(16, dtype=jnp.int32)
    out, cnt = compaction.compact(vals, jnp.zeros(16, bool), 16, fill=-7)
    assert int(cnt) == 0
    assert np.all(np.asarray(out) == -7)
    out, tot = compaction.compact_offsets(
        jnp.ones((16, 4), jnp.int32), jnp.full(16, 3, jnp.int32),
        jnp.zeros(16, bool), 8)
    assert int(tot) == 0


def test_compact_zero_length_input():
    out, cnt = compaction.compact(
        jnp.zeros((0,), jnp.int32), jnp.zeros((0,), bool), 4)
    assert int(cnt) == 0 and out.shape == (4,)
    out, tot = compaction.compact_offsets(
        jnp.zeros((0, 4), jnp.int32), jnp.zeros((0,), jnp.int32),
        jnp.zeros((0,), bool), 4)
    assert int(tot) == 0 and out.shape == (4,)


def test_compact_offsets_exact_capacity():
    vals = jnp.arange(12, dtype=jnp.int32).reshape(6, 2)
    lens = jnp.full(6, 2, jnp.int32)
    mask = jnp.ones(6, bool)
    out, tot = compaction.compact_offsets(vals, lens, mask, 12)
    assert int(tot) == 12
    assert np.array_equal(np.asarray(out), np.arange(12))


def test_compact_offsets_overflow_drops_tail():
    vals = jnp.arange(12, dtype=jnp.int32).reshape(6, 2)
    lens = jnp.full(6, 2, jnp.int32)
    mask = jnp.ones(6, bool)
    out, tot = compaction.compact_offsets(vals, lens, mask, 5)
    assert int(tot) == 12  # logical total; buffer truncates physically
    assert np.array_equal(np.asarray(out), np.arange(5))


def test_compact_overflow_drops_tail():
    vals = jnp.arange(10, dtype=jnp.int32)
    out, cnt = compaction.compact(vals, jnp.ones(10, bool), 4)
    assert int(cnt) == 10
    assert np.array_equal(np.asarray(out), np.arange(4))


def test_tile_exclusive_scan_matches_numpy():
    rng = np.random.default_rng(3)
    x = rng.integers(0, 5, 1024).astype(np.int32)
    excl, tot = compaction.tile_exclusive_scan(jnp.asarray(x), rows=8)
    want = np.cumsum(x) - x
    assert np.array_equal(np.asarray(excl), want)
    assert int(tot) == int(x.sum())
    # ragged row width + all-zero tile
    x = np.zeros(256, np.int32)
    excl, tot = compaction.tile_exclusive_scan(jnp.asarray(x), rows=4)
    assert int(tot) == 0 and np.all(np.asarray(excl) == 0)


def test_tile_base_offsets_matches_numpy():
    totals = jnp.asarray([3, 0, 7, 1], jnp.int32)
    base, total = compaction.tile_base_offsets(totals)
    assert np.array_equal(np.asarray(base), [0, 3, 3, 10])
    assert int(total) == 11


@settings(**SETTINGS)
@given(st.lists(st.tuples(st.integers(0, 255), st.integers(0, 4),
                          st.booleans()),
                min_size=1, max_size=48))
def test_compact_offsets_matches_numpy(items):
    n = len(items)
    k = 4
    vals = np.zeros((n, k), np.int32)
    lens = np.array([l for _, l, _ in items], np.int32)
    mask = np.array([m for _, _, m in items], bool)
    rng = np.random.default_rng(0)
    for i, (v, l, _) in enumerate(items):
        vals[i, :] = rng.integers(0, 256, k)
    cap = int((lens * mask).sum()) + 8
    out, total = compaction.compact_offsets(
        jnp.asarray(vals), jnp.asarray(lens), jnp.asarray(mask), cap)
    want = []
    for i in range(n):
        if mask[i]:
            want.extend(vals[i, : lens[i]])
    assert int(total) == len(want)
    assert np.array_equal(np.asarray(out)[: len(want)],
                          np.array(want, np.int32))
