"""Property tests for stream compaction (the pshufb replacement)."""

import numpy as np
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import compaction

SETTINGS = dict(max_examples=50, deadline=None)


@settings(**SETTINGS)
@given(st.lists(st.tuples(st.integers(-100, 100), st.booleans()),
                min_size=1, max_size=64))
def test_compact_matches_numpy(items):
    vals = np.array([v for v, _ in items], np.int32)
    mask = np.array([m for _, m in items], bool)
    out, cnt = compaction.compact(jnp.asarray(vals), jnp.asarray(mask),
                                  len(vals))
    want = vals[mask]
    assert int(cnt) == len(want)
    assert np.array_equal(np.asarray(out)[: len(want)], want)


@settings(**SETTINGS)
@given(st.lists(st.tuples(st.integers(-100, 100), st.booleans()),
                min_size=1, max_size=64))
def test_compact_gather_matches_scatter(items):
    vals = np.array([v for v, _ in items], np.int32)
    mask = np.array([m for _, m in items], bool)
    o1, c1 = compaction.compact(jnp.asarray(vals), jnp.asarray(mask),
                                len(vals))
    o2, c2 = compaction.compact_gather(jnp.asarray(vals), jnp.asarray(mask),
                                       len(vals))
    assert int(c1) == int(c2)
    assert np.array_equal(np.asarray(o1)[: int(c1)],
                          np.asarray(o2)[: int(c2)])


@settings(**SETTINGS)
@given(st.lists(st.tuples(st.integers(0, 255), st.integers(0, 4),
                          st.booleans()),
                min_size=1, max_size=48))
def test_compact_offsets_matches_numpy(items):
    n = len(items)
    k = 4
    vals = np.zeros((n, k), np.int32)
    lens = np.array([l for _, l, _ in items], np.int32)
    mask = np.array([m for _, _, m in items], bool)
    rng = np.random.default_rng(0)
    for i, (v, l, _) in enumerate(items):
        vals[i, :] = rng.integers(0, 256, k)
    cap = int((lens * mask).sum()) + 8
    out, total = compaction.compact_offsets(
        jnp.asarray(vals), jnp.asarray(lens), jnp.asarray(mask), cap)
    want = []
    for i in range(n):
        if mask[i]:
            want.extend(vals[i, : lens[i]])
    assert int(total) == len(want)
    assert np.array_equal(np.asarray(out)[: len(want)],
                          np.array(want, np.int32))
