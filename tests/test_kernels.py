"""Per-kernel validation: interpret=True execution vs pure-jnp oracle.

Sweeps shapes (tile-aligned and ragged) and content classes; integer
outputs must agree exactly (array_equal, not allclose).
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.data import synthetic
from repro.kernels import ops, ref
from repro.kernels import utf8_decode as kdec
from repro.kernels import utf8_validate as kval
from repro.kernels import utf16_encode as kenc

LANGS = ["latin", "arabic", "chinese", "emoji", "korean"]
SIZES = [1, 7, 127, 1024, 1025, 4096, 5000]


def _utf8(lang, n):
    b = synthetic.utf8_array(lang, n, seed=42)
    return b.astype(np.int32)


@pytest.mark.parametrize("lang", LANGS)
@pytest.mark.parametrize("size", SIZES)
def test_decode_kernel_vs_ref(lang, size):
    b = _utf8(lang, size)[:size]
    if len(b) == 0:
        return
    n = len(b)
    cp_k, lead_k, units_k, err_k = ops.decode_utf8(jnp.asarray(b), n)
    # kernel pads to tiles with zeros; a size that cuts mid-character is a
    # *truncation error* visible at the first padding byte — give the ref
    # the same 4 zero-padding bytes so semantics match exactly.
    b_pad = np.concatenate([b, np.zeros(4, np.int32)])
    cp_r, lead_r, units_r, err_r = ref.utf8_decode_ref(jnp.asarray(b_pad))
    assert np.array_equal(cp_k[:n], cp_r[:n])
    assert np.array_equal(lead_k[:n], lead_r[:n])
    assert np.array_equal(units_k[:n], units_r[:n])
    assert bool(err_k) == bool(err_r > 0)


@pytest.mark.parametrize("lang", LANGS)
@pytest.mark.parametrize("size", [64, 1024, 3000])
def test_validate_kernel_vs_ref(lang, size):
    b = _utf8(lang, size)[:size]
    # truncate to a character boundary so the stream stays valid
    end = len(b)
    while end > 0 and (b[end - 1] & 0xC0) == 0x80:
        end -= 1
    if end > 0 and b[end - 1] >= 0xC0:
        end -= 1
    b = b[:end]
    if len(b) == 0:
        return
    assert bool(ops.validate_utf8(jnp.asarray(b), len(b)))
    r = ref.utf8_validate_ref(jnp.asarray(b))
    assert int(r) == 0


@pytest.mark.parametrize("bad", [b"\xff", b"\xed\xa0\x80", b"\xc0\xaf",
                                 b"\x80", b"\xf5\x80\x80\x80"])
def test_validate_kernel_rejects(bad):
    b = np.zeros(2048, np.int32)  # spans >1 tile
    b[100: 100 + len(bad)] = np.frombuffer(bad, np.uint8)
    b[: 100] = 0x41
    assert not bool(ops.validate_utf8(jnp.asarray(b), 100 + len(bad)))


@pytest.mark.parametrize("lang", LANGS)
@pytest.mark.parametrize("size", [8, 1024, 1030, 4096])
def test_utf16_encode_kernel_vs_ref(lang, size):
    u = synthetic.utf16_units(lang, size, seed=7).astype(np.int32)[:size]
    if len(u) == 0:
        return
    out, cnt, err = ops.utf16_to_utf8(jnp.asarray(u), len(u))
    b0, b1, b2, b3, L, err_r = ref.utf16_encode_ref(jnp.asarray(u))
    # cross-check against python oracle
    s = u.astype(np.uint16).tobytes().decode("utf-16-le")
    want = np.frombuffer(s.encode("utf-8"), np.uint8)
    got = np.asarray(out)[: int(cnt)]
    assert np.array_equal(got, want)
    assert not bool(err)
    assert int(err_r) == 0


def test_kernel_transcode_cross_boundary_surrogate():
    """A surrogate pair straddling a 1024-byte tile boundary."""
    u = np.full(2048, 0x41, np.int32)
    u[1023] = 0xD83C
    u[1024] = 0xDF89
    out, cnt, err = ops.utf16_to_utf8(jnp.asarray(u), 2048)
    assert not bool(err)
    s = u.astype(np.uint16).tobytes().decode("utf-16-le")
    want = np.frombuffer(s.encode("utf-8"), np.uint8)
    assert np.array_equal(np.asarray(out)[: int(cnt)], want)


def test_kernel_decode_cross_boundary_char():
    """A 4-byte UTF-8 char straddling the tile boundary."""
    s = "A" * 1022 + "🎉" + "B" * 100
    b = np.frombuffer(s.encode("utf-8"), np.uint8).astype(np.int32)
    out, cnt, err = ops.utf8_to_utf16(jnp.asarray(b), len(b))
    want = np.frombuffer(s.encode("utf-16-le"), np.uint16)
    assert not bool(err)
    assert np.array_equal(np.asarray(out)[: int(cnt)], want)


def test_kernel_vs_core_blockparallel():
    """The Pallas path and the pure-XLA path agree everywhere."""
    from repro.core import transcode as tc
    for lang in LANGS:
        b = _utf8(lang, 2000)
        o1, c1, e1 = ops.utf8_to_utf16(jnp.asarray(b), len(b))
        o2, c2, status2 = tc.utf8_to_utf16(jnp.asarray(b), len(b))
        assert int(c1) == int(c2)
        assert np.array_equal(np.asarray(o1)[: int(c1)],
                              np.asarray(o2)[: int(c2)])
        # ops' legacy bool flag vs core's located status agree on validity
        assert bool(e1) == (int(status2) >= 0)
