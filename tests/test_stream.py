"""Resumable streaming transcode: bit-exactness vs the whole buffer.

The acceptance contract (DESIGN.md §10): feeding ANY chunking of a
source buffer through ``transcode_stream_chunk`` + ``finalize`` must
reproduce the whole-buffer single-pass transcode EXACTLY — concatenated
output buffer, total count, final sticky status — for every codec-matrix
cell, every ``errors=`` policy, and every split point, including splits
mid-multibyte-sequence and mid-surrogate-pair.

Chunk-size sweep per the issue: {1, 7, TILE, TILE+1, whole}.  Sub-tile
sizes run on short inputs (every launch pads to one tile, so the whole
sweep shares a compile); the tile-straddling sizes run on a
``TILE + 40``-unit input so the second launch actually crosses the tile
boundary.

Adversarial split-point tests walk EVERY boundary of a small multibyte
string (UTF-8) and a surrogate-pair string (UTF-16) — the mid-character
splits are the holdback rule's whole reason to exist.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import transcode as tc
from repro.core.stream import (MAX_HOLDBACK, TILE, finalize, stream_init,
                               transcode_stream, transcode_stream_chunk)
from repro.data import synthetic

_CODEC = {"utf8": "utf-8", "utf16": "utf-16-le", "utf32": "utf-32-le",
          "latin1": "latin-1"}
_WIRE_DT = {"utf8": np.dtype(np.uint8), "utf16": np.dtype("<u2"),
            "utf32": np.dtype("<u4"), "latin1": np.dtype(np.uint8)}

SMALL_SIZES = (1, 7)
TILE_SIZES = (TILE, TILE + 1, None)     # None = whole buffer in one chunk


def _source_units(src: str, n_chars: int, seed: int) -> np.ndarray:
    """Valid source units covering ASCII + multibyte for each format."""
    text = bytes(synthetic.utf8_array("arabic", n_chars, seed=seed)) \
        .decode("utf-8")
    if src == "latin1":
        text = "".join(c if ord(c) <= 0xFF else "é" for c in text)
    return np.frombuffer(text.encode(_CODEC[src]), _WIRE_DT[src]).copy()


def _dirty(src: str, units: np.ndarray, seed: int) -> np.ndarray:
    """Inject per-format invalid units (latin1 cannot be invalid)."""
    u = units.copy()
    rng = np.random.default_rng(seed)
    pos = rng.integers(0, len(u), 4)
    bad = {"utf8": 0xFF, "utf16": 0xD800, "utf32": 0x11_0000}.get(src)
    if bad is not None:
        u[pos] = bad
    return u


def _whole(src, dst, units, errors):
    """Whole-buffer single-pass reference (padded to a tile multiple,
    mirroring the stream's per-launch geometry)."""
    n = len(units)
    pad = max(TILE, -(-n // TILE) * TILE)
    buf = np.zeros(pad, _WIRE_DT[src])
    buf[:n] = units
    return tc.transcode(jnp.asarray(buf), dst, src_format=src, n_valid=n,
                        strategy="onepass", errors=errors)


def _stream(src, dst, units, chunk_size, errors):
    st = stream_init(src, dst, errors=errors)
    parts = []
    step = len(units) if chunk_size is None else chunk_size
    step = max(step, 1)
    for i in range(0, len(units), step):
        res, st = transcode_stream_chunk(st, units[i: i + step])
        parts.append(np.asarray(res.buffer)[: int(res.count)])
    res, st = finalize(st)
    parts.append(np.asarray(res.buffer)[: int(res.count)])
    out = np.concatenate(parts) if parts else np.zeros(0, _WIRE_DT[dst])
    return out, st


def _check_equal(src, dst, units, chunk_size, errors):
    ref = _whole(src, dst, units, errors)
    cap = tc.CAP_FACTOR[(src, dst)] * max(TILE, -(-len(units) // TILE)
                                          * TILE)
    out, st = _stream(src, dst, units, chunk_size, errors)
    assert st.out_count == int(ref.count), \
        f"{src}->{dst} chunk={chunk_size} {errors}: count"
    assert st.status == int(ref.status), \
        f"{src}->{dst} chunk={chunk_size} {errors}: status"
    if int(ref.count) > cap:         # whole-buffer output clipped
        return
    if errors == "strict" and int(ref.status) >= 0:
        # Strict stream with errors: the post-error SPECULATIVE content
        # is launch-geometry-defined (a dangling invalid lead decodes
        # against zero padding in a chunked launch but against its real
        # neighbors in the whole buffer), so only the pre-error output
        # is part of the contract — pinned against the CPython oracle.
        text = units[: int(ref.status)].tobytes().decode(_CODEC[src])
        exp = np.frombuffer(text.encode(_CODEC[dst]), _WIRE_DT[dst])
        np.testing.assert_array_equal(
            out[: len(exp)], exp,
            err_msg=f"{src}->{dst} chunk={chunk_size} strict: pre-error "
                    f"prefix")
        return
    ref_buf = np.asarray(ref.buffer)[: int(ref.count)]
    np.testing.assert_array_equal(
        out, ref_buf, err_msg=f"{src}->{dst} chunk={chunk_size} "
                              f"{errors}: buffer")


# ---------------------------------------------------------------------------
# Full matrix x errors x chunk-size acceptance sweep.


@pytest.mark.parametrize("src,dst", tc.PAIRS)
@pytest.mark.parametrize("errors", ["strict", "replace"])
def test_stream_matrix_small_chunks(src, dst, errors):
    units = _source_units(src, 24, seed=11)[:40]
    for chunk_size in SMALL_SIZES:
        _check_equal(src, dst, units, chunk_size, errors)


@pytest.mark.parametrize("src,dst", tc.PAIRS)
@pytest.mark.parametrize("errors", ["strict", "replace"])
def test_stream_matrix_tile_chunks(src, dst, errors):
    units = _source_units(src, TILE, seed=12)[: TILE + 40]
    for chunk_size in TILE_SIZES:
        _check_equal(src, dst, units, chunk_size, errors)


@pytest.mark.parametrize("src,dst", tc.PAIRS)
@pytest.mark.parametrize("errors", ["strict", "replace"])
def test_stream_matrix_dirty(src, dst, errors):
    """Invalid units (or unencodable chars for latin1 targets) at random
    positions: the sticky status and the replace output must still match
    the whole buffer at every chunk size."""
    units = _dirty(src, _source_units(src, 24, seed=13)[:40], seed=14)
    for chunk_size in SMALL_SIZES:
        _check_equal(src, dst, units, chunk_size, errors)


# ---------------------------------------------------------------------------
# Every split point of adversarial strings (the holdback rule itself).


def test_stream_utf8_every_split_point():
    # ASCII + 2-byte + 3-byte + 4-byte + ASCII: every i splits somewhere
    # interesting, including mid-sequence.
    b = "Aé世\U0001F600Z".encode("utf-8")
    units = np.frombuffer(b, np.uint8)
    ref = _whole("utf8", "utf16", units, "strict")
    for i in range(len(units) + 1):
        st = stream_init("utf8", "utf16")
        r1, st = transcode_stream_chunk(st, units[:i])
        r2, st = transcode_stream_chunk(st, units[i:])
        r3, st = finalize(st)
        out = np.concatenate([np.asarray(r.buffer)[: int(r.count)]
                              for r in (r1, r2, r3)])
        np.testing.assert_array_equal(
            out, np.asarray(ref.buffer)[: int(ref.count)],
            err_msg=f"split at {i}")
        assert st.out_count == int(ref.count)
        assert st.status == int(ref.status) == -1


def test_stream_utf16_every_split_point():
    # BMP char + surrogate pair + BMP char: split index 2 lands exactly
    # between the high and low surrogate.
    units = np.frombuffer("a\U0001F600z".encode("utf-16-le"),
                          np.dtype("<u2")).copy()
    ref = _whole("utf16", "utf8", units, "strict")
    for i in range(len(units) + 1):
        st = stream_init("utf16", "utf8")
        r1, st = transcode_stream_chunk(st, units[:i])
        r2, st = transcode_stream_chunk(st, units[i:])
        r3, st = finalize(st)
        out = np.concatenate([np.asarray(r.buffer)[: int(r.count)]
                              for r in (r1, r2, r3)])
        np.testing.assert_array_equal(
            out, np.asarray(ref.buffer)[: int(ref.count)],
            err_msg=f"split at {i}")
        assert st.status == -1


def test_stream_dangling_tail_strict_and_replace():
    """A stream that ENDS mid-character: finalize must fault (strict) or
    substitute (replace) at the tail's true global offset."""
    b = b"hi" + "世".encode("utf-8")[:2]          # truncated 3-byte
    units = np.frombuffer(b, np.uint8)
    for errors in ("strict", "replace"):
        ref = _whole("utf8", "utf16", units, errors)
        st = stream_init("utf8", "utf16", errors=errors)
        r1, st = transcode_stream_chunk(st, units)
        assert st.pending.size == 2          # tail held back
        assert st.status == -1               # no error YET
        r2, st = finalize(st)
        assert st.finished
        assert st.status == int(ref.status) == 2
        out = np.concatenate([np.asarray(r.buffer)[: int(r.count)]
                              for r in (r1, r2)])
        np.testing.assert_array_equal(
            out, np.asarray(ref.buffer)[: int(ref.count)])


def test_stream_empty_chunks_are_noops():
    units = np.frombuffer("é".encode("utf-8"), np.uint8)
    st = stream_init("utf8", "utf16")
    r, st = transcode_stream_chunk(st, np.zeros(0, np.uint8))
    assert int(r.count) == 0 and st.consumed == 0
    r, st = transcode_stream_chunk(st, units[:1])    # lead only: held
    assert int(r.count) == 0 and st.pending.size == 1
    r, st = transcode_stream_chunk(st, np.zeros(0, np.uint8))
    assert int(r.count) == 0 and st.pending.size == 1
    r, st = transcode_stream_chunk(st, units[1:])
    assert int(r.count) == 1
    _, st = finalize(st)
    assert st.out_count == 1 and st.status == -1


def test_stream_convenience_driver():
    units = _source_units("utf8", 32, seed=15)
    ref = _whole("utf8", "utf32", units, "strict")
    chunks = [units[i: i + 5] for i in range(0, len(units), 5)]
    res, st = transcode_stream(chunks, src_format="utf8",
                               dst_format="utf32")
    assert st.finished
    assert int(res.count) == int(ref.count)
    assert int(res.status) == int(ref.status)
    np.testing.assert_array_equal(
        np.asarray(res.buffer), np.asarray(ref.buffer)[: int(ref.count)])


def test_stream_after_finalize_raises():
    st = stream_init("utf8", "utf16")
    _, st = finalize(st)
    with pytest.raises(ValueError, match="finalized"):
        transcode_stream_chunk(st, np.zeros(1, np.uint8))
    with pytest.raises(ValueError, match="finalized"):
        finalize(st)


def test_stream_input_validation():
    st = stream_init("utf16", "utf8")
    with pytest.raises(TypeError, match="unit arrays"):
        transcode_stream_chunk(st, b"ab")       # bytes into a u16 stream
    with pytest.raises(ValueError, match="1-D"):
        transcode_stream_chunk(st, np.zeros((2, 2), np.uint16))
    with pytest.raises(TypeError, match="integer"):
        transcode_stream_chunk(st, np.zeros(4, np.float32))
    with pytest.raises(ValueError, match="out of range"):
        transcode_stream_chunk(st, np.array([0x1_0000], np.int64))
    with pytest.raises(ValueError, match="errors"):
        stream_init("utf8", "utf16", errors="ignore")
    with pytest.raises(ValueError, match="unsupported format pair"):
        stream_init("utf8", "utf8")             # not a matrix cell
    # bytes ARE accepted for byte-width sources.
    st8 = stream_init("utf8", "utf16")
    r, st8 = transcode_stream_chunk(st8, b"ok")
    assert int(r.count) == 2


def test_stream_holdback_never_exceeds_max():
    st = stream_init("utf8", "utf16")
    # Feed a 4-byte lead then continuations one at a time: pending must
    # stay <= MAX_HOLDBACK at every step.
    for b in "\U0001F600".encode("utf-8")[:-1]:
        _, st = transcode_stream_chunk(st, bytes([b]))
        assert st.pending.size <= MAX_HOLDBACK
