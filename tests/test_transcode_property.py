"""Property-based tests: the transcoding core vs Python's certified codec.

Python's str.encode/bytes.decode is the oracle.  Hypothesis generates
arbitrary Unicode strings (all planes) and adversarial byte mutations;
both strategies (blockparallel + windowed) and both directions must agree
byte-exactly with the oracle, and must flag every invalid input the
oracle rejects.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import transcode as tc

SETTINGS = dict(max_examples=60, deadline=None)

text = st.text(
    alphabet=st.characters(min_codepoint=0, max_codepoint=0x10FFFF,
                           exclude_categories=("Cs",)),  # no lone surrogates
    max_size=80)


def _u8(s):
    return np.frombuffer(s.encode("utf-8"), np.uint8).astype(np.int32)


def _u16(s):
    return np.frombuffer(s.encode("utf-16-le"), np.uint16).astype(np.int32)


def _pad(a, n=8):
    out = np.zeros(max(len(a), n), np.int32)
    out[: len(a)] = a
    return out


@settings(**SETTINGS)
@given(text, st.sampled_from(["blockparallel", "windowed"]))
def test_utf8_to_utf16_matches_python(s, strategy):
    b, u = _u8(s), _u16(s)
    out, cnt, status = tc.transcode_utf8_to_utf16(
        jnp.asarray(_pad(b)), len(b), strategy=strategy)
    assert int(status) == -1, s
    got = np.asarray(out)[: int(cnt)]
    assert np.array_equal(got, u), (s, got[:10], u[:10])


@settings(**SETTINGS)
@given(text, st.sampled_from(["blockparallel", "windowed"]))
def test_utf16_to_utf8_matches_python(s, strategy):
    b, u = _u8(s), _u16(s)
    out, cnt, status = tc.transcode_utf16_to_utf8(
        jnp.asarray(_pad(u)), len(u), strategy=strategy)
    assert int(status) == -1, s
    got = np.asarray(out)[: int(cnt)]
    assert np.array_equal(got, b), s


@settings(**SETTINGS)
@given(text)
def test_utf8_to_utf32_roundtrip(s):
    b = _u8(s)
    cps = np.array([ord(c) for c in s], np.int32)
    out, cnt, status = tc.utf8_to_utf32(jnp.asarray(_pad(b)), len(b))
    assert int(status) == -1
    assert np.array_equal(np.asarray(out)[: int(cnt)], cps)
    # egress back to utf-8
    out8, cnt8, status8 = tc.utf32_to_utf8(jnp.asarray(_pad(cps)), len(cps))
    assert int(status8) == -1
    assert np.array_equal(np.asarray(out8)[: int(cnt8)], b)


@settings(**SETTINGS)
@given(st.binary(max_size=64))
def test_validation_agrees_with_python(raw):
    """Arbitrary bytes: validate_utf8 == python's decodability."""
    try:
        raw.decode("utf-8")
        valid = True
    except UnicodeDecodeError:
        valid = False
    b = _pad(np.frombuffer(raw, np.uint8).astype(np.int32))
    got = bool(tc.validate_utf8(jnp.asarray(b), len(raw)))
    assert got == valid, raw


@settings(**SETTINGS)
@given(st.binary(max_size=48))
def test_invalid_bytes_flagged_by_transcoder(raw):
    """Arbitrary bytes: status == Python's UnicodeDecodeError.start."""
    try:
        raw.decode("utf-8")
        want = -1
    except UnicodeDecodeError as e:
        want = e.start
    b = _pad(np.frombuffer(raw, np.uint8).astype(np.int32))
    _, _, status = tc.utf8_to_utf16(jnp.asarray(b), len(raw))
    assert int(status) == want, raw


@settings(**SETTINGS)
@given(st.binary(max_size=48))
def test_replace_matches_python_utf8(raw):
    """Arbitrary bytes: errors='replace' output == Python's, byte-exact,
    and the fused single-scan status equals the blockparallel one."""
    want = np.frombuffer(
        raw.decode("utf-8", "replace").encode("utf-16-le"), np.uint16)
    cap = 128  # fixed capacity: all examples share one compilation
    buf = np.zeros(cap, np.uint8)
    buf[: len(raw)] = np.frombuffer(raw, np.uint8)
    out, cnt, status = tc.utf8_to_utf16(
        jnp.asarray(buf.astype(np.int32)), len(raw), errors="replace")
    got = np.asarray(out)[: int(cnt)].astype(np.uint16)
    assert np.array_equal(got, want), raw
    fout, fcnt, fstatus = tc.transcode_utf8_to_utf16(
        jnp.asarray(buf), len(raw), strategy="fused", errors="replace")
    assert int(fcnt) == int(cnt) and int(fstatus) == int(status), raw
    assert np.array_equal(np.asarray(fout)[: int(fcnt)], got), raw


@settings(**SETTINGS)
@given(st.lists(st.integers(0, 0xFFFF), max_size=40))
def test_replace_matches_python_utf16(units):
    raw = np.array(units, np.uint16)
    want = np.frombuffer(
        raw.tobytes().decode("utf-16-le", "replace").encode("utf-8"),
        np.uint8)
    cap = 64
    buf = np.zeros(cap, np.uint16)
    buf[: len(units)] = raw
    out, cnt, status = tc.utf16_to_utf8(
        jnp.asarray(buf.astype(np.int32)), len(units), errors="replace")
    got = np.asarray(out)[: int(cnt)].astype(np.uint8)
    assert np.array_equal(got, want), units
    fout, fcnt, fstatus = tc.transcode_utf16_to_utf8(
        jnp.asarray(buf), len(units), strategy="fused", errors="replace")
    assert int(fcnt) == int(cnt) and int(fstatus) == int(status), units
    assert np.array_equal(np.asarray(fout)[: int(fcnt)], got), units


@settings(**SETTINGS)
@given(st.lists(st.integers(0, 0xFFFF), max_size=40))
def test_utf16_validation_agrees_with_python(units):
    raw = np.array(units, np.uint16).tobytes()
    try:
        raw.decode("utf-16-le")
        valid = True
    except UnicodeDecodeError:
        valid = False
    u = _pad(np.array(units, np.int32))
    got = bool(tc.validate_utf16(jnp.asarray(u), len(units)))
    assert got == valid, units


@settings(**SETTINGS)
@given(text)
def test_length_counting(s):
    b, u = _u8(s), _u16(s)
    assert int(tc.utf16_length_from_utf8(jnp.asarray(_pad(b)), len(b))) == len(u)
    assert int(tc.utf8_length_from_utf16(jnp.asarray(_pad(u)), len(u))) == len(b)
    assert int(tc.count_utf8_chars(jnp.asarray(_pad(b)), len(b))) == len(s)
