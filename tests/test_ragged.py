"""Ragged packed-batch transcode: packing layout, device ownership map,
bit-identity with the per-document fused transcoder, batch entry points
and the bounded per-capacity vmap cache."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import packing
from repro.core import transcode as tc
from repro.data import pipeline, synthetic
from repro.kernels import fused_transcode as ft

TILE = packing.TILE


def _docs_mixed():
    """The adversarial batch shape: empty, all-ASCII, sub-tile,
    multi-tile and malformed documents in one ragged batch."""
    return [
        synthetic.utf8_array("latin", 200, seed=1),          # all-ASCII
        np.zeros(0, np.uint8),                               # empty
        synthetic.utf8_array("emoji", 700, seed=2),          # multi-tile
        synthetic.utf8_array("chinese", 1500, seed=3),       # multi-tile
        np.frombuffer(b"hi \xe4\xb8 there", np.uint8),       # malformed
        synthetic.utf8_array("arabic", 40, seed=4),          # sub-tile
    ]


# ---------------------------------------------------------------------------
# Packing layout


def test_pack_documents_layout():
    docs = _docs_mixed()
    pk = packing.pack_documents(docs)
    assert pk.n_docs == len(docs)
    assert pk.offsets[0] == 0
    assert np.all(pk.offsets % TILE == 0)          # tile-aligned starts
    assert np.all(np.diff(pk.offsets) >= 0)
    for d, doc in enumerate(docs):
        n = len(doc)
        assert pk.lengths[d] == n
        lo, hi = pk.offsets[d], pk.offsets[d + 1]
        assert hi - lo == -(-n // TILE) * TILE     # exact tile span
        assert np.array_equal(pk.data[lo: lo + n], np.asarray(doc))
        assert not pk.data[lo + n: hi].any()       # zero-filled slack


def test_pack_documents_fixed_geometry():
    docs = [b"ab", b""]
    pk = packing.pack_documents(docs, doc_tiles=2, pad_to_docs=4)
    assert pk.n_docs == 4
    assert np.array_equal(pk.offsets, np.arange(5) * 2 * TILE)
    assert np.array_equal(pk.lengths, [2, 0, 0, 0])
    with pytest.raises(ValueError):
        packing.pack_documents([np.zeros(TILE + 1, np.uint8)], doc_tiles=1)
    with pytest.raises(ValueError):
        packing.pack_documents(docs, pad_to_docs=1)


def test_pack_documents_bytes_and_dtype():
    pk = packing.pack_documents([b"abc"], dtype=np.uint8)
    assert pk.data.dtype == np.uint8 and pk.lengths[0] == 3
    pk16 = packing.pack_documents([np.array([0x41], np.uint16)])
    assert pk16.data.dtype == np.uint16


def test_unpack_results_clamps_to_capacity():
    buf = np.arange(8, dtype=np.uint16)
    docs = packing.unpack_results(buf, np.array([0, 4, 8]),
                                  np.array([4, 100]))
    assert np.array_equal(docs[0], [0, 1, 2, 3])
    assert np.array_equal(docs[1], [4, 5, 6, 7])   # clamped, no IndexError


# ---------------------------------------------------------------------------
# Device ownership map


def test_tile_ownership_map():
    # docs: 1 tile, EMPTY, 2 tiles, 1 tile  ->  offsets in tiles: 0,1,1,3,4
    offsets = np.array([0, 1, 1, 3, 4]) * TILE
    lengths = np.array([TILE, 0, TILE + 5, 7], np.int32)
    tile_doc, tile_end, same_prev, same_next = packing.tile_ownership(
        jnp.asarray(offsets), jnp.asarray(lengths), nblk=4, block=TILE)
    assert np.array_equal(tile_doc, [0, 2, 2, 3])  # empty doc owns no tile
    assert np.array_equal(tile_end,
                          [TILE, 2 * TILE + 5, 2 * TILE + 5, 3 * TILE + 7])
    # Neighbour flags: only the two tiles of doc 2 see each other.
    assert np.array_equal(same_prev, [0, 0, 1, 0])
    assert np.array_equal(same_next, [0, 1, 0, 0])


def test_tile_ownership_trailing_pad_tile_is_dead():
    # A pad tile past the last document clamps to the last doc but its
    # tile_end precedes it: no lane can be live.
    offsets = np.array([0, TILE])
    lengths = np.array([10], np.int32)
    tile_doc, tile_end, _, _ = packing.tile_ownership(
        jnp.asarray(offsets), jnp.asarray(lengths), nblk=2, block=TILE)
    assert int(tile_doc[1]) == 0
    assert int(tile_end[1]) == 10 < TILE  # every lane of tile 1 is dead


# ---------------------------------------------------------------------------
# Bit-identity with the per-document fused transcoder


def _assert_doc_equal(res, d, single, span):
    """Ragged doc d must reproduce the single-doc fused TranscodeResult:
    same count, same status, same buffer prefix (the single-doc buffer is
    capacity-clamped, so compare min(count, span) elements)."""
    assert int(res.counts[d]) == int(single.count), d
    assert int(res.statuses[d]) == int(single.status), d
    k = min(int(single.count), span)
    lo = int(res.offsets[d])
    got = np.asarray(res.buffer)[lo: lo + k]
    want = np.asarray(single.buffer)[:k]
    assert np.array_equal(got, want), d


@pytest.mark.parametrize("errors", ["strict", "replace"])
def test_ragged_utf8_matches_per_doc_fused(errors):
    docs = _docs_mixed()
    pk = packing.pack_documents(docs)
    res = tc.ragged_utf8_to_utf16(pk.data, pk.offsets, pk.lengths,
                                  errors=errors)
    # Dense output: offsets are the cumsum of counts.
    assert np.array_equal(np.asarray(res.offsets),
                          np.concatenate([[0], np.cumsum(res.counts)]))
    for d, doc in enumerate(docs):
        n = len(doc)
        buf = np.zeros(max(n, 1), np.uint8)
        buf[:n] = doc
        single = ft.utf8_to_utf16_fused(jnp.asarray(buf), n, errors=errors)
        _assert_doc_equal(res, d, single, max(n, 1))


@pytest.mark.parametrize("errors", ["strict", "replace"])
def test_ragged_utf16_matches_per_doc_fused(errors):
    docs = [
        synthetic.utf16_units("korean", 400, seed=1),
        np.zeros(0, np.uint16),
        # surrogate pair straddling the doc's own tile boundary
        np.concatenate([np.full(1023, 0xE000, np.uint16),
                        np.array([0xD800, 0xDC00], np.uint16),
                        np.full(50, 0x41, np.uint16)]),
        np.array([0x41, 0xD800, 0x42], np.uint16),   # lone surrogate
        synthetic.utf16_units("emoji", 700, seed=5),
    ]
    pk = packing.pack_documents(docs, dtype=np.uint16)
    res = tc.ragged_utf16_to_utf8(pk.data, pk.offsets, pk.lengths,
                                  errors=errors)
    for d, doc in enumerate(docs):
        n = len(doc)
        buf = np.zeros(max(n, 1), np.uint16)
        buf[:n] = doc
        single = ft.utf16_to_utf8_fused(jnp.asarray(buf), n, errors=errors)
        _assert_doc_equal(res, d, single, 3 * max(n, 1))


def test_ragged_scan_matches_strict_transcode():
    docs = _docs_mixed()
    pk = packing.pack_documents(docs)
    res = tc.ragged_utf8_to_utf16(pk.data, pk.offsets, pk.lengths)
    counts, statuses = tc.ragged_scan_utf8(pk.data, pk.offsets, pk.lengths)
    assert np.array_equal(np.asarray(counts), np.asarray(res.counts))
    assert np.array_equal(np.asarray(statuses), np.asarray(res.statuses))
    u16docs = [synthetic.utf16_units("latin", 100, seed=1),
               np.array([0xDC00], np.uint16)]
    pk16 = packing.pack_documents(u16docs, dtype=np.uint16)
    res16 = tc.ragged_utf16_to_utf8(pk16.data, pk16.offsets, pk16.lengths)
    c16, s16 = tc.ragged_scan_utf16(pk16.data, pk16.offsets, pk16.lengths)
    assert np.array_equal(np.asarray(c16), np.asarray(res16.counts))
    assert np.array_equal(np.asarray(s16), np.asarray(res16.statuses))


def test_ragged_garbage_beyond_length_is_masked():
    """Bytes past a document's logical length must not leak into its own
    or its neighbour's analysis (the packed analogue of n_valid)."""
    pk = packing.pack_documents([b"ok", b"fine"])
    data = np.asarray(pk.data).copy()
    data[2: TILE] = 0xFF            # garbage in doc 0's slack
    res = tc.ragged_utf8_to_utf16(jnp.asarray(data), pk.offsets, pk.lengths)
    assert np.array_equal(np.asarray(res.statuses), [-1, -1])
    assert np.array_equal(np.asarray(res.counts), [2, 4])


def test_ragged_rejects_malformed_batch_args():
    data = jnp.zeros((2 * TILE,), jnp.uint8)
    with pytest.raises(ValueError):
        tc.ragged_utf8_to_utf16(data, jnp.zeros((1,), jnp.int32),
                                jnp.zeros((0,), jnp.int32))
    with pytest.raises(ValueError):
        tc.ragged_utf8_to_utf16(data, jnp.asarray([0, TILE]),
                                jnp.asarray([5, 5]))
    with pytest.raises(ValueError):
        tc.ragged_utf8_to_utf16(data, jnp.asarray([0, TILE]),
                                jnp.asarray([5]), errors="ignore")
    # Layout invariants (silently wrong results otherwise): mid-tile
    # start, nonzero first offset, decreasing offsets, oversize length.
    with pytest.raises(ValueError):
        tc.ragged_utf8_to_utf16(data, jnp.asarray([0, 100, 2 * TILE]),
                                jnp.asarray([100, 1900]))
    with pytest.raises(ValueError):
        tc.ragged_utf8_to_utf16(data, jnp.asarray([TILE, 2 * TILE]),
                                jnp.asarray([5]))
    with pytest.raises(ValueError):
        tc.ragged_utf8_to_utf16(data, jnp.asarray([0, 2 * TILE, TILE]),
                                jnp.asarray([5, 5]))
    with pytest.raises(ValueError):
        tc.ragged_utf8_to_utf16(data, jnp.asarray([0, TILE, 2 * TILE]),
                                jnp.asarray([TILE + 1, 5]))
    # Truncated data buffer: trailing docs would silently read as empty.
    with pytest.raises(ValueError):
        tc.ragged_utf8_to_utf16(data[:TILE],
                                jnp.asarray([0, TILE, 2 * TILE]),
                                jnp.asarray([5, 50]))


def test_ragged_single_launch_per_pass_jaxpr():
    """The whole batch must transcode in ONE launch under the default
    (one-pass) strategy — and in ONE count + ONE write launch under the
    two-pass fused reference — vs one pair per document under vmap."""
    import jax
    from tests.test_fused_transcode import _pallas_eqns
    pk = packing.pack_documents(_docs_mixed())
    args = (jnp.asarray(pk.data), jnp.asarray(pk.offsets),
            jnp.asarray(pk.lengths))
    jaxpr = jax.make_jaxpr(
        lambda d, o, l: tc.ragged_utf8_to_utf16(d, o, l))(*args).jaxpr
    assert len(_pallas_eqns(jaxpr)) == 1      # one-pass, batch-wide
    jaxpr_fused = jax.make_jaxpr(
        lambda d, o, l: tc.ragged_utf8_to_utf16(
            d, o, l, strategy="fused"))(*args).jaxpr
    assert len(_pallas_eqns(jaxpr_fused)) == 2  # count + write, batch-wide
    jaxpr_scan = jax.make_jaxpr(
        lambda d, o, l: tc.ragged_scan_utf8(d, o, l))(*args).jaxpr
    assert len(_pallas_eqns(jaxpr_scan)) == 1  # count pass only


# ---------------------------------------------------------------------------
# Batch entry points (strategy="packed" vs the vmap reference)


def test_batch_entries_packed_equals_vmap():
    L = 1536
    docs = np.zeros((4, L), np.uint8)
    lens = []
    for i, lang in enumerate(["latin", "chinese", "emoji", "arabic"]):
        d = synthetic.utf8_array(lang, 300, seed=i)[:L]
        docs[i, : len(d)] = d
        lens.append(len(d))
    lens = np.asarray(lens, np.int32)
    pk = pipeline.batch_utf8_to_utf16(docs, lens)              # packed
    vm = pipeline.batch_utf8_to_utf16(docs, lens, strategy="vmap")
    assert pk.buffer.shape == vm.buffer.shape == (4, L)
    assert np.array_equal(np.asarray(pk.buffer), np.asarray(vm.buffer))
    assert np.array_equal(np.asarray(pk.count), np.asarray(vm.count))
    assert np.array_equal(np.asarray(pk.status), np.asarray(vm.status))

    units = np.zeros((2, 1024), np.uint16)
    ulens = []
    for i, lang in enumerate(["korean", "latin"]):
        d = synthetic.utf16_units(lang, 300, seed=i)[:1024]
        units[i, : len(d)] = d
        ulens.append(len(d))
    ulens = np.asarray(ulens, np.int32)
    pk = pipeline.batch_utf16_to_utf8(units, ulens)
    vm = pipeline.batch_utf16_to_utf8(units, ulens, strategy="vmap")
    assert pk.buffer.shape == vm.buffer.shape == (2, 3 * 1024)
    assert np.array_equal(np.asarray(pk.buffer), np.asarray(vm.buffer))
    assert np.array_equal(np.asarray(pk.count), np.asarray(vm.count))
    assert np.array_equal(np.asarray(pk.status), np.asarray(vm.status))


def test_batch_entries_replace_policy_threads_through():
    docs = np.zeros((2, 1024), np.uint8)
    docs[0, :3] = [0x61, 0xFF, 0x62]     # a <bad> b
    docs[1, :2] = [0xC3, 0xA9]           # é
    lens = np.asarray([3, 2], np.int32)
    res = pipeline.batch_utf8_to_utf16(docs, lens, errors="replace")
    want0 = np.frombuffer(
        b"a\xffb".decode("utf-8", "replace").encode("utf-16-le"), np.uint16)
    assert np.array_equal(np.asarray(res.buffer[0])[:3], want0)
    assert int(res.status[0]) == 1 and int(res.status[1]) == -1


# ---------------------------------------------------------------------------
# _BATCH_CACHE: keyed per-capacity, LRU-bounded


def test_batch_cache_keyed_per_capacity_and_bounded():
    pipeline._BATCH_CACHE.clear()
    f1 = pipeline._batched("utf8", "utf16", "fused", True, "strict", 1024)
    f2 = pipeline._batched("utf8", "utf16", "fused", True, "strict", 1024)
    assert f1 is f2                       # same capacity -> cached callable
    f3 = pipeline._batched("utf8", "utf16", "fused", True, "strict", 2048)
    assert f3 is not f1                   # capacity is part of the key
    assert len(pipeline._BATCH_CACHE) == 2
    for cap in range(3 * pipeline._BATCH_CACHE_MAX):
        pipeline._batched("utf8", "utf16", "fused", True, "strict", 4096 + cap)
    assert len(pipeline._BATCH_CACHE) <= pipeline._BATCH_CACHE_MAX


def test_batch_cache_lru_keeps_hot_entries():
    pipeline._BATCH_CACHE.clear()
    hot = pipeline._batched("utf8", "utf16", "fused", True, "strict", 1024)
    for cap in range(pipeline._BATCH_CACHE_MAX - 1):
        pipeline._batched("utf8", "utf16", "fused", True, "strict", 2048 + cap)
    # Touch the hot entry, then overflow: the hot entry must survive.
    assert pipeline._batched("utf8", "utf16", "fused", True, "strict", 1024) is hot
    pipeline._batched("utf8", "utf16", "fused", True, "strict", 9999)
    assert ("utf8", "utf16", "fused", True, "strict", 1024) in pipeline._BATCH_CACHE
