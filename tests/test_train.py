"""Training-stack tests: optimizer math, accumulation equivalence,
gradient compression, chunked loss."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import registry
from repro.train import grad as G
from repro.train import optimizer as O
from repro.train import train_step as TS


def test_loss_decreases_on_fixed_batch():
    fam, cfg, model = registry.get("bytelm-100m", reduced=True)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    opt_state = O.init_opt_state(params)
    step = jax.jit(TS.make_train_step(
        model, fam, O.AdamWConfig(lr=1e-3, total_steps=50, warmup_steps=1)))
    batch = {"tokens": jax.random.randint(key, (4, 64), 3, cfg.vocab),
             "labels": jax.random.randint(key, (4, 64), 3, cfg.vocab)}
    losses = []
    for _ in range(10):
        params, opt_state, m = step(params, opt_state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]


def test_microbatch_accumulation_equivalence():
    """n_micro=2 must give the same grads as n_micro=1 (up to fp error)."""
    fam, cfg, model = registry.get("bytelm-100m", reduced=True)
    key = jax.random.PRNGKey(1)
    params = model.init(key)
    loss_fn = TS.make_loss_fn(model, fam)
    batch = {"tokens": jax.random.randint(key, (4, 32), 3, cfg.vocab),
             "labels": jax.random.randint(key, (4, 32), 3, cfg.vocab)}
    l1, g1, _ = G.accumulate_microbatches(loss_fn, params, batch, 1)
    l2, g2, _ = G.accumulate_microbatches(loss_fn, params, batch, 2)
    # microbatch means of per-microbatch means equal the full mean only
    # when microbatches have equal token counts — true here
    assert abs(float(l1) - float(l2)) < 1e-4
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=1e-4, rtol=1e-3)


def test_grad_clip():
    g = {"a": jnp.full((10,), 100.0)}
    clipped, norm = O.clip_by_global_norm(g, 1.0)
    assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-5
    assert float(norm) > 100


def test_lr_schedule_shape():
    cfg = O.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                        min_lr_frac=0.1)
    lrs = [float(O.lr_schedule(cfg, jnp.int32(s))) for s in range(0, 101, 10)]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 1.0) < 1e-6          # end of warmup
    assert lrs[-1] == pytest.approx(0.1, abs=1e-3)  # cosine floor
    assert all(a >= b - 1e-9 for a, b in zip(lrs[1:], lrs[2:]))  # decay


def test_int8_quantization_error_feedback():
    """Error feedback must drive the *accumulated* quantization bias to
    zero: sum of dequantized values converges to sum of true values."""
    rng = np.random.default_rng(0)
    x = rng.normal(size=(256,)).astype(np.float32)
    err = jnp.zeros((256,))
    total_true, total_deq = np.zeros_like(x), np.zeros_like(x)
    for _ in range(50):
        carried = jnp.asarray(x) + err
        q, s = G.quantize_int8(carried)
        deq = G.dequantize_int8(q, s)
        err = carried - deq
        total_true += x
        total_deq += np.asarray(deq)
    # relative error of the running sum shrinks as 1/T
    rel = np.abs(total_deq - total_true).max() / np.abs(total_true).max()
    assert rel < 0.01


def test_chunked_ce_matches_direct():
    fam, cfg, model = registry.get("bytelm-100m", reduced=True)
    key = jax.random.PRNGKey(2)
    params = model.init(key)
    toks = jax.random.randint(key, (2, 48), 3, cfg.vocab)
    labels = toks.at[:, -5:].set(-1)
    hidden, _, _ = model.apply(params, toks, logits=False)
    chunked = TS.chunked_ce_loss(params["embed"], hidden, labels, chunk=16)
    # direct
    from repro.models import common as C
    logits = C.unembed(params["embed"], hidden)
    lse = jax.nn.logsumexp(logits, -1)
    gold = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[..., None],
                               -1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    direct = jnp.sum((lse - gold) * mask) / jnp.sum(mask)
    assert abs(float(chunked) - float(direct)) < 1e-4


def test_zero1_specs_divisibility():
    """ZeRO-1 must never claim an indivisible axis."""
    import os, subprocess, sys
    # needs a multi-device mesh: run in a subprocess with forced devices
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np
from jax.sharding import PartitionSpec as P
from repro.models import registry
from repro.train import optimizer as O, sharding as SH
mesh = jax.make_mesh((4, 2), ("data", "model"))
for arch in ["falcon-mamba-7b", "deepseek-moe-16b", "qwen3-8b"]:
    fam, cfg, model = registry.get(arch, reduced=True)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pspecs = SH.param_specs(params, mesh)
    ospecs = O.zero1_specs(params, pspecs, data_axes=("data",), axis_size=4)
    def check(p, s):
        for i, ax in enumerate(s):
            if ax is None: continue
            n = np.prod([mesh.shape[a] for a in (ax if isinstance(ax, tuple) else (ax,))])
            assert p.shape[i] % n == 0, (arch, p.shape, s)
    jax.tree.map(check, params, ospecs["m"], is_leaf=lambda x: x is None)
print("OK")
"""
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))), timeout=300)
    assert "OK" in r.stdout, r.stderr[-2000:]
