"""Distribution tests: sharded lower+compile on an 8-device host mesh,
shard_map gradient sync, elastic remesh planning.

Multi-device cases run in subprocesses (jax locks the device count at
first init; the main test process must keep seeing 1 device)."""

import os
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code, timeout=600):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=timeout, cwd=REPO)
    assert "PASS" in r.stdout, f"stdout={r.stdout[-1500:]}\nstderr={r.stderr[-2500:]}"


def test_mini_dryrun_train_8dev():
    """Reduced-config train_step lowers + compiles on a (4, 2) mesh."""
    _run("""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.models import registry
from repro.train import optimizer as O, sharding as SH, train_step as TS
mesh = jax.make_mesh((4, 2), ("data", "model"))
for arch in ["qwen3-8b", "grok-1-314b", "recurrentgemma-9b", "falcon-mamba-7b"]:
    fam, cfg, model = registry.get(arch, reduced=True)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    opt = jax.eval_shape(O.init_opt_state, params)
    pspecs = SH.param_specs(params, mesh)
    ospecs = O.zero1_specs(params, pspecs, axis_size=4)
    step = TS.make_train_step(model, fam, O.AdamWConfig())
    batch = {"tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32),
             "labels": jax.ShapeDtypeStruct((8, 64), jnp.int32)}
    sh = lambda t, s: jax.tree.map(lambda x: NamedSharding(mesh, x), s,
                                   is_leaf=lambda x: isinstance(x, P))
    with mesh:
        c = jax.jit(step, in_shardings=(sh(params, pspecs), sh(opt, ospecs),
            {"tokens": NamedSharding(mesh, P("data", None)),
             "labels": NamedSharding(mesh, P("data", None))})
        ).lower(params, opt, batch).compile()
    assert c.cost_analysis() is not None
print("PASS")
""")


def test_shardmap_hierarchical_grad_sync():
    """Compressed hierarchical all-reduce == plain mean all-reduce."""
    _run("""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.train import grad as G
mesh = jax.make_mesh((2, 4), ("pod", "data"))
g_local = jax.random.normal(jax.random.PRNGKey(0), (8, 64))  # per-device rows

def sync(g, err):
    gs, new_err = G.hierarchical_grad_sync(
        {"w": g}, {"w": err}, ici_axis="data", dcn_axis="pod", compress=True)
    return gs["w"], new_err["w"]

f = shard_map(sync, mesh=mesh,
              in_specs=(P(("pod", "data"), None), P(("pod", "data"), None)),
              out_specs=(P(("pod", "data"), None), P(("pod", "data"), None)))
err0 = jnp.zeros((8, 16))  # shard shape after psum_scatter: 64/4/... flat
# error buffers: per-device flat shard of g (8*64/4 = 128 elems) -> rows 8x16
out, new_err = f(g_local, err0)
# reference: full-precision psum over all 8 devices of each shard-row group
def ref_sync(g):
    return jax.lax.psum(g, ("pod", "data"))
rf = shard_map(ref_sync, mesh=mesh, in_specs=P(("pod", "data"), None),
               out_specs=P(("pod", "data"), None))
want = rf(g_local)
rel = float(jnp.max(jnp.abs(out - want)) / (jnp.max(jnp.abs(want)) + 1e-9))
assert rel < 0.02, rel   # int8 quantization error bound
print("PASS")
""")


def test_production_mesh_shapes():
    _run("""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
from repro.launch import mesh as M
m1 = M.make_production_mesh()
assert dict(m1.shape) == {"data": 16, "model": 16}
m2 = M.make_production_mesh(multi_pod=True)
assert dict(m2.shape) == {"pod": 2, "data": 16, "model": 16}
assert M.dp_axes(m2) == ("pod", "data")
print("PASS")
""")


def test_elastic_remesh_plan():
    from repro.launch import elastic
    plan = elastic.plan_remesh((16, 16), failed_chips=16, global_batch=256)
    assert plan.model == 16
    assert plan.data == 15
    assert plan.n_chips == 240
    # global batch preserved: divisible microbatching exists
    assert 256 % (plan.data * plan.n_micro) == 0 or plan.n_micro >= 1
    # catastrophic loss: fewer chips than one TP group
    assert elastic.plan_remesh((16, 16), failed_chips=255,
                               global_batch=256) is None


def test_straggler_skip_plan_partition():
    from repro.launch import elastic
    plan = elastic.straggler_skip_plan(0, 4, 16)
    all_slots = sorted(s for v in plan.values() for s in v)
    assert all_slots == list(range(16))
