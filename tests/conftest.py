"""Test fixtures.  NOTE: XLA_FLAGS/device-count tricks are deliberately NOT
set here — smoke tests and benches must see the real single device; only
the dry-run (and subprocess-based distribution tests) force 512/8 devices.
"""

import numpy as np
import pytest

import _hypothesis_lite

# The container has no hypothesis wheel; fall back to the seeded-random
# shim (no-op when the real package is importable).
_hypothesis_lite.install()


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="module", autouse=True)
def _clear_jax_caches_between_modules():
    """Drop compiled-executable caches after each test module.

    A long single-process run accumulates hundreds of interpret-mode
    Pallas executables; on jaxlib 0.4.36 the XLA:CPU backend eventually
    segfaults inside ``backend_compile`` once enough JIT state has piled
    up (reproducible on the unmodified tree at ~1/3 of the suite).
    Bounding the live cache per module keeps the compiler healthy at the
    cost of some cross-module recompilation.
    """
    yield
    import jax

    jax.clear_caches()
