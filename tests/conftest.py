"""Test fixtures.  NOTE: XLA_FLAGS/device-count tricks are deliberately NOT
set here — smoke tests and benches must see the real single device; only
the dry-run (and subprocess-based distribution tests) force 512/8 devices.
"""

import numpy as np
import pytest

import _hypothesis_lite

# The container has no hypothesis wheel; fall back to the seeded-random
# shim (no-op when the real package is importable).
_hypothesis_lite.install()


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
