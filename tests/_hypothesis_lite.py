"""Minimal, dependency-free stand-in for the ``hypothesis`` API surface
used by this test suite.

The container has no ``hypothesis`` wheel and nothing may be pip-installed,
so ``conftest.py`` installs this module into ``sys.modules["hypothesis"]``
when the real package is missing.  It implements seeded random property
testing with the same decorator shapes (``@settings`` / ``@given`` and the
``strategies`` combinators the tests import); no shrinking, no database.
Each test runs ``max_examples`` deterministic examples (seeded from the
test name), the first of which is the minimal draw from every strategy so
size-0 / value-min edge cases are always exercised.
"""

from __future__ import annotations

import random
import sys
import types
import unicodedata
import zlib


class _Strategy:
    def __init__(self, draw, minimal):
        self._draw = draw
        self._minimal = minimal

    def example(self, rng, minimal=False):
        return self._minimal(rng) if minimal else self._draw(rng)


def integers(min_value, max_value):
    return _Strategy(lambda rng: rng.randint(min_value, max_value),
                     lambda rng: min_value)


def booleans():
    return _Strategy(lambda rng: rng.random() < 0.5, lambda rng: False)


def sampled_from(options):
    options = list(options)
    return _Strategy(lambda rng: rng.choice(options),
                     lambda rng: rng.choice(options))


def tuples(*strategies):
    return _Strategy(
        lambda rng: tuple(s.example(rng) for s in strategies),
        lambda rng: tuple(s.example(rng, minimal=True) for s in strategies))


def lists(elements, min_size=0, max_size=10):
    def draw(rng):
        size = rng.randint(min_size, max_size)
        return [elements.example(rng) for _ in range(size)]
    return _Strategy(
        draw,
        lambda rng: [elements.example(rng, minimal=True)
                     for _ in range(min_size)])


def characters(min_codepoint=0, max_codepoint=0x10FFFF,
               exclude_categories=()):
    exclude = tuple(exclude_categories)

    def ok(cp):
        return not unicodedata.category(chr(cp)).startswith(exclude) \
            if exclude else True

    def draw(rng):
        # Weight toward the interesting encoding-length boundaries.
        bands = [(min_codepoint, min(0x7F, max_codepoint)),
                 (0x80, 0x7FF), (0x800, 0xFFFF), (0x10000, 0x10FFFF)]
        bands = [(lo, hi) for lo, hi in bands
                 if lo <= max_codepoint and hi >= min_codepoint]
        for _ in range(64):
            lo, hi = bands[rng.randrange(len(bands))]
            cp = rng.randint(max(lo, min_codepoint), min(hi, max_codepoint))
            if ok(cp):
                return chr(cp)
        return chr(min_codepoint)

    return _Strategy(draw, lambda rng: chr(min_codepoint))


def text(alphabet=None, max_size=20, min_size=0):
    alphabet = alphabet or characters()

    def draw(rng):
        size = rng.randint(min_size, max_size)
        return "".join(alphabet.example(rng) for _ in range(size))

    return _Strategy(draw, lambda rng: "" if min_size == 0 else
                     alphabet.example(rng, minimal=True) * min_size)


def binary(max_size=20, min_size=0):
    def draw(rng):
        size = rng.randint(min_size, max_size)
        return bytes(rng.randrange(256) for _ in range(size))
    return _Strategy(draw, lambda rng: b"\x00" * min_size)


def settings(**kwargs):
    max_examples = kwargs.get("max_examples", 100)

    def deco(fn):
        fn._lite_max_examples = max_examples
        return fn
    return deco


def given(*strategies):
    # NOTE: the wrapper deliberately exposes a bare (*args, **kwargs)
    # signature (no functools.wraps/__wrapped__) so pytest does not mistake
    # the property's drawn parameters for fixtures.
    def deco(fn):
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_lite_max_examples",
                        getattr(fn, "_lite_max_examples", 100))
            seed = zlib.crc32(fn.__qualname__.encode())
            rng = random.Random(seed)
            for i in range(n):
                drawn = tuple(s.example(rng, minimal=(i == 0))
                              for s in strategies)
                try:
                    fn(*args, *drawn, **kwargs)
                except Exception as e:  # noqa: BLE001 - re-raise with case
                    raise AssertionError(
                        f"property falsified on example {i}: {drawn!r}"
                    ) from e
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__module__ = fn.__module__
        wrapper.__doc__ = fn.__doc__
        wrapper._lite_max_examples = getattr(fn, "_lite_max_examples", 100)
        return wrapper
    return deco


def install():
    """Register this module as ``hypothesis`` if the real one is absent."""
    if "hypothesis" in sys.modules:
        return
    try:
        import hypothesis  # noqa: F401 - prefer the real package
        return
    except ImportError:
        pass
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    strategies = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "booleans", "sampled_from", "tuples", "lists",
                 "characters", "text", "binary"):
        setattr(strategies, name, globals()[name])
    mod.strategies = strategies
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strategies
