"""Directed edge cases from the paper's §3 validation rules."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import transcode as tc


def _check_invalid(raw: bytes):
    b = np.zeros(max(len(raw), 8), np.int32)
    b[: len(raw)] = np.frombuffer(raw, np.uint8)
    assert not bool(tc.validate_utf8(jnp.asarray(b), len(raw))), raw
    _, _, status = tc.utf8_to_utf16(jnp.asarray(b), len(raw))
    assert int(status) >= 0, raw
    # The located offset must agree with Python's exc.start.
    try:
        raw.decode("utf-8")
        raise AssertionError(f"python accepted {raw!r}")
    except UnicodeDecodeError as e:
        assert int(status) == e.start, (raw, int(status), e.start)


def _check_valid(raw: bytes):
    b = np.zeros(max(len(raw), 8), np.int32)
    b[: len(raw)] = np.frombuffer(raw, np.uint8)
    assert bool(tc.validate_utf8(jnp.asarray(b), len(raw))), raw


# paper rule 1: five MSBs never all ones
@pytest.mark.parametrize("lead", [0xF8, 0xFC, 0xFE, 0xFF])
def test_forbidden_lead_bytes(lead):
    _check_invalid(bytes([lead, 0x80, 0x80, 0x80, 0x80]))


# paper rule 2/3: continuation bookkeeping
def test_missing_continuation():
    _check_invalid(b"\xC3A")          # 2-byte lead + ASCII
    _check_invalid(b"\xE4\xB8A")      # 3-byte lead + 1 cont + ASCII
    _check_invalid(b"\xF0\x9F\x98A")  # 4-byte lead + 2 cont + ASCII


def test_stray_continuation():
    _check_invalid(b"\x80")
    _check_invalid(b"A\x80B")
    _check_invalid(b"\xC3\xA9\x80")   # valid 2-byte then stray cont


def test_truncated_at_end():
    _check_invalid(b"abc\xC3")
    _check_invalid(b"abc\xE4\xB8")
    _check_invalid(b"abc\xF0\x9F\x98")


# paper rule 4: overlong encodings
def test_overlong():
    _check_invalid(b"\xC0\xAF")           # '/' in 2 bytes
    _check_invalid(b"\xC1\xBF")
    _check_invalid(b"\xE0\x80\xAF")       # overlong 3-byte
    _check_invalid(b"\xE0\x9F\xBF")       # < U+0800
    _check_invalid(b"\xF0\x80\x80\xAF")   # overlong 4-byte
    _check_invalid(b"\xF0\x8F\xBF\xBF")   # < U+10000


# paper rule 5: beyond U+10FFFF
def test_too_large():
    _check_invalid(b"\xF4\x90\x80\x80")   # U+110000
    _check_invalid(b"\xF5\x80\x80\x80")
    _check_invalid(b"\xF7\xBF\xBF\xBF")


# paper rule 6: surrogate range U+D800..DFFF
def test_surrogates_in_utf8():
    _check_invalid(b"\xED\xA0\x80")       # U+D800
    _check_invalid(b"\xED\xBF\xBF")       # U+DFFF
    _check_valid(b"\xED\x9F\xBF")         # U+D7FF boundary: valid
    _check_valid(b"\xEE\x80\x80")         # U+E000 boundary: valid


def test_boundaries_valid():
    for cp in [0x7F, 0x80, 0x7FF, 0x800, 0xFFFF, 0x10000, 0x10FFFF]:
        _check_valid(chr(cp).encode("utf-8"))


def test_surrogate_pair_transcoding():
    s = "🎉"  # U+1F389 -> surrogate pair
    b = np.frombuffer(s.encode("utf-8"), np.uint8).astype(np.int32)
    u = np.frombuffer(s.encode("utf-16-le"), np.uint16).astype(np.int32)
    assert list(u) == [0xD83C, 0xDF89]
    out, cnt, status = tc.utf8_to_utf16(jnp.asarray(b), len(b))
    assert int(status) == -1
    assert np.array_equal(np.asarray(out)[: int(cnt)], u)
    out, cnt, status = tc.utf16_to_utf8(jnp.asarray(u), len(u))
    assert int(status) == -1
    assert np.array_equal(np.asarray(out)[: int(cnt)], b)


def test_unpaired_surrogates_utf16():
    for units in [[0xD800], [0xDC00], [0xD800, 0x41], [0x41, 0xDC00],
                  [0xDC00, 0xD800]]:
        u = np.zeros(8, np.int32)
        u[: len(units)] = units
        assert not bool(tc.validate_utf16(jnp.asarray(u), len(units))), units
        _, _, status = tc.utf16_to_utf8(jnp.asarray(u), len(units))
        try:
            np.array(units, np.uint16).tobytes().decode("utf-16-le")
            raise AssertionError(f"python accepted {units}")
        except UnicodeDecodeError as e:
            assert int(status) == e.start // 2, (units, int(status))


def test_ascii_fast_path_equivalence():
    s = ("the quick brown fox " * 20).encode()
    b = jnp.asarray(np.frombuffer(s, np.uint8).astype(np.int32))
    for fast in (True, False):
        out, cnt, status = tc.utf8_to_utf16(b, len(s), ascii_fastpath=fast)
        assert int(cnt) == len(s) and int(status) == -1
        assert np.array_equal(np.asarray(out)[: len(s)],
                              np.frombuffer(s, np.uint8))


def test_utf32_egress_status_and_replace():
    cps = np.array([0x41, 0xD800, 0x1F389, 0x110000, 0x42], np.int32)
    out, cnt, status = tc.utf32_to_utf8(jnp.asarray(cps), len(cps))
    assert int(status) == 1  # first bad code point (surrogate)
    out, cnt, status = tc.utf32_to_utf8(jnp.asarray(cps), len(cps),
                                        errors="replace")
    assert int(status) == 1
    want = "A�🎉�B".encode("utf-8")
    assert bytes(np.asarray(out)[: int(cnt)].astype(np.uint8)) == want
    out, cnt, status = tc.utf32_to_utf16(jnp.asarray(cps), len(cps),
                                         errors="replace")
    assert int(status) == 1
    want16 = np.frombuffer("A�🎉�B".encode("utf-16-le"), np.uint16)
    assert np.array_equal(np.asarray(out)[: int(cnt)].astype(np.uint16),
                          want16)
    clean = np.array([0x41, 0x1F389], np.int32)
    _, _, status = tc.utf32_to_utf16(jnp.asarray(clean), len(clean))
    assert int(status) == -1


def test_utf8_to_utf32_replace():
    raw = b"A\xc3A\xf0\x9f\x92\x96"
    b = np.frombuffer(raw, np.uint8).astype(np.int32)
    out, cnt, status = tc.utf8_to_utf32(jnp.asarray(b), len(b),
                                        errors="replace")
    want = [ord(c) for c in raw.decode("utf-8", "replace")]
    assert list(np.asarray(out)[: int(cnt)]) == want
    assert int(status) == 1


def test_utf16_to_utf32_replace():
    units = np.array([0x41, 0xDC00, 0xD83C, 0xDF89], np.int32)
    out, cnt, status = tc.utf16_to_utf32(jnp.asarray(units), len(units),
                                         errors="replace")
    want = [ord(c) for c in np.asarray(units, np.uint16).tobytes().decode(
        "utf-16-le", "replace")]
    assert list(np.asarray(out)[: int(cnt)]) == want
    assert int(status) == 1


def test_utf16le_byte_helpers():
    s = "héllo 🎉"
    raw = np.frombuffer(s.encode("utf-16-le"), np.uint8).astype(np.int32)
    units = tc.utf16le_bytes_to_units(jnp.asarray(raw))
    back = tc.units_to_utf16le_bytes(units)
    assert np.array_equal(np.asarray(back), raw)


# ---------------------------------------------------------------------------
# Input validation (robustness): wrong-dtype / wrong-rank inputs must
# raise a CLEAR error instead of silently flattening or truncating into
# garbage transcoding.


def test_transcode_rejects_float_dtype():
    with pytest.raises(TypeError, match="integer dtype"):
        tc.transcode(jnp.zeros(8, jnp.float32), "utf16",
                     src_format="utf8")


def test_transcode_rejects_2d_input():
    with pytest.raises(ValueError, match="1-D"):
        tc.transcode(jnp.zeros((2, 4), jnp.int32), "utf16",
                     src_format="utf8")


def test_scan_rejects_bad_inputs():
    with pytest.raises(TypeError, match="integer dtype"):
        tc.scan(jnp.zeros(8, jnp.float64), src_format="utf8",
                dst_format="utf16")
    with pytest.raises(ValueError, match="1-D"):
        tc.scan(jnp.zeros((4, 4), jnp.int32), src_format="utf8",
                dst_format="utf16")


def test_pack_documents_rejects_2d_doc():
    from repro.core import packing
    with pytest.raises(ValueError, match="one row per document"):
        packing.pack_documents([np.zeros((2, 3), np.uint8)],
                               dtype=np.uint8)


def test_pack_documents_rejects_float_doc():
    from repro.core import packing
    with pytest.raises(TypeError, match="integer dtype"):
        packing.pack_documents([np.zeros(3, np.float32)], dtype=np.uint8)


def test_pack_documents_rejects_lossy_cast():
    from repro.core import packing
    # A uint16 document with values above 255 must not silently truncate
    # into a uint8 pack.
    with pytest.raises(ValueError, match="corrupt"):
        packing.pack_documents([np.array([0x1F600 & 0xFFFF], np.uint16)],
                               dtype=np.uint8)
    # In-range values cast fine.
    pk = packing.pack_documents([np.array([65, 66], np.uint16)],
                                dtype=np.uint8)
    assert pk.data.dtype == np.uint8
