"""Flash-attention Pallas kernel vs the pure-jnp online-softmax oracle."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention
from repro.models.common import chunked_attention


def _ref(q, k, v, window):
    b, s, h, d = q.shape
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    return chunked_attention(q, k, v, pos, pos, window=window, chunk=128)


@pytest.mark.parametrize("seq,heads,dim", [(128, 2, 64), (256, 4, 128),
                                           (384, 1, 32)])
@pytest.mark.parametrize("window", [None, 128])
def test_flash_matches_oracle(seq, heads, dim, window):
    key = jax.random.PRNGKey(0)
    kq, kk, kv = jax.random.split(key, 3)
    b = 2
    q = jax.random.normal(kq, (b, seq, heads, dim), jnp.float32)
    k = jax.random.normal(kk, (b, seq, heads, dim), jnp.float32)
    v = jax.random.normal(kv, (b, seq, heads, dim), jnp.float32)
    got = flash_attention(q, k, v, window=window, bq=128, bk=128)
    want = _ref(q, k, v, window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=1e-4)


def test_flash_causality():
    """Future tokens must not influence the output."""
    key = jax.random.PRNGKey(1)
    b, s, h, d = 1, 256, 2, 64
    q = jax.random.normal(key, (b, s, h, d))
    k = jax.random.normal(jax.random.PRNGKey(2), (b, s, h, d))
    v = jax.random.normal(jax.random.PRNGKey(3), (b, s, h, d))
    base = flash_attention(q, k, v)
    # mutate the future relative to position 100
    k2 = k.at[:, 200:].set(9.9)
    v2 = v.at[:, 200:].set(9.9)
    out2 = flash_attention(q, k2, v2)
    np.testing.assert_allclose(np.asarray(base[:, :200]),
                               np.asarray(out2[:, :200]), atol=1e-6)


def test_flash_bf16():
    key = jax.random.PRNGKey(4)
    b, s, h, d = 1, 128, 2, 128
    q = jax.random.normal(key, (b, s, h, d)).astype(jnp.bfloat16)
    k = jax.random.normal(jax.random.PRNGKey(5), (b, s, h, d)).astype(jnp.bfloat16)
    v = jax.random.normal(jax.random.PRNGKey(6), (b, s, h, d)).astype(jnp.bfloat16)
    got = flash_attention(q, k, v)
    want = _ref(q, k, v, None)
    assert got.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=3e-2, rtol=3e-2)
