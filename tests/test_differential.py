"""Differential fuzz harness: every transcoder cell vs CPython codecs.

Seeded random + mutated corpora are checked **byte-exactly** against
CPython's ``codecs`` machinery across every
(direction x strategy x errors) cell — the single-launch ``onepass``
strategy (DESIGN.md §9) rides every sweep next to ``fused`` — including
the ragged packed-batch path (both launch strategies) with per-document
statuses:

  * valid streams: ``buffer[:count]`` must equal the CPython transcode
    bit for bit, ``status`` must be -1;
  * invalid streams under ``errors="strict"``: ``status`` must equal
    Python's ``UnicodeDecodeError.start`` (unit-relative for UTF-16),
    and every strategy must agree with the blockparallel reference on
    (buffer, count) — the speculative output is defined cross-strategy,
    not by CPython;
  * invalid streams under ``errors="replace"``: the output must equal
    CPython's ``errors="replace"`` transcode bit for bit (U+FFFD per
    maximal subpart) and ``status`` the first substitution offset.

The seed is fixed (override with ``REPRO_FUZZ_SEED``) so CI runs are
reproducible; the boundary-adversarial generators place truncated leads
and surrogate pairs so they straddle VMEM-tile boundaries AND packed
document boundaries, with empty and all-ASCII documents mixed into the
same ragged batch.

The ``parity`` tests are the interpret-vs-compiled gate: on CPU they pin
the Pallas interpreter kernels to the XLA-compiled blockparallel
reference; on a TPU backend the same tests additionally run the
Mosaic-compiled kernels against the interpreter.
"""

import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import packing
from repro.core import transcode as tc
from repro.data import synthetic
from repro.kernels import fused_transcode as ft
from repro.kernels import runtime

SEED = int(os.environ.get("REPRO_FUZZ_SEED", "20260801"))

CAP8 = 1536    # fixed single-doc capacities: one compilation per cell
CAP16 = 1280

LANGS = ["latin", "arabic", "chinese", "emoji", "korean", "hebrew"]

# Bytes that exercise every UTF-8 error class: continuations, C0/C1
# (never valid leads), constrained-second-byte leads (E0/ED/F0/F4), F5+.
ADVERSARIAL8 = np.array([0x41, 0x7F, 0x80, 0x9F, 0xA0, 0xBF, 0xC0, 0xC1,
                         0xC2, 0xDF, 0xE0, 0xED, 0xEE, 0xF0, 0xF4, 0xF5,
                         0xFF, 0x90, 0x8F, 0x20], np.uint8)


# ---------------------------------------------------------------------------
# CPython oracles


def _py8(raw: bytes):
    """(utf16_units, exc.start) for a UTF-8 stream (-1 when valid)."""
    try:
        return (np.frombuffer(raw.decode("utf-8").encode("utf-16-le"),
                              np.uint16), -1)
    except UnicodeDecodeError as e:
        return None, e.start


def _py8_replace(raw: bytes):
    return np.frombuffer(
        raw.decode("utf-8", "replace").encode("utf-16-le"), np.uint16)


def _py16(units: np.ndarray):
    """(utf8_bytes, exc.start // 2) for a UTF-16LE stream."""
    try:
        return (np.frombuffer(
            units.astype(np.uint16).tobytes().decode("utf-16-le")
            .encode("utf-8"), np.uint8), -1)
    except UnicodeDecodeError as e:
        return None, e.start // 2


def _py16_replace(units: np.ndarray):
    return np.frombuffer(
        units.astype(np.uint16).tobytes().decode("utf-16-le", "replace")
        .encode("utf-8"), np.uint8)


# ---------------------------------------------------------------------------
# Corpus generators


def _utf8_case(rng, trial, cap=CAP8):
    """One seeded UTF-8 stream: valid, mutated-valid, pure-random or
    adversarial-alphabet, in rotation."""
    buf = np.zeros(cap, np.uint8)
    kind = trial % 4
    if kind in (0, 1):
        b = synthetic.utf8_array(LANGS[trial % len(LANGS)], 400,
                                 seed=SEED + trial)[:cap]
        buf[: len(b)] = b
        n = len(b)
        if kind == 1:       # mutate 1-4 bytes of a valid stream
            k = int(rng.integers(1, 5))
            buf[rng.integers(0, max(n, 1), k)] = rng.integers(0, 256, k)
    elif kind == 2:
        n = int(rng.integers(1, cap))
        buf[:n] = rng.integers(0, 256, n)
    else:
        n = int(rng.integers(1, 96))
        buf[:n] = rng.choice(ADVERSARIAL8, n)
    return buf, n


def _utf16_case(rng, trial, cap=CAP16):
    buf = np.zeros(cap, np.uint16)
    kind = trial % 3
    if kind == 0:
        u = synthetic.utf16_units(LANGS[trial % len(LANGS)], 400,
                                  seed=SEED + trial)[:cap]
        buf[: len(u)] = u
        n = len(u)
    elif kind == 1:
        u = synthetic.utf16_units("emoji", 300, seed=SEED + trial)[:cap]
        buf[: len(u)] = u
        n = len(u)
        k = int(rng.integers(1, 4))   # surrogate-heavy corruption
        buf[rng.integers(0, max(n, 1), k)] = rng.integers(0xD800, 0xE000, k)
    else:
        n = int(rng.integers(1, cap))
        buf[:n] = rng.integers(0, 1 << 16, n)
    return buf, n


def boundary_documents8():
    """UTF-8 documents engineered so multi-byte characters and truncated
    leads straddle (a) the 1024-byte VMEM tile boundary inside one
    document and (b) the packed document boundary — plus empty and
    all-ASCII documents mixed in, per the ragged batch contract."""
    docs = []
    probes = [b"\xf0\x9f\x92\xa9", b"\xe4\xb8\xad", b"\xc3\xa9",
              b"\xf0\x9f\x92", b"\xe4\xb8", b"\xc3", b"\xed\xa0\x80"]
    tile = packing.TILE
    for k, probe in enumerate(probes):
        # (a) straddle this doc's own internal tile boundary
        pos = tile - 2 + (k % 4)
        doc = np.full(tile + 64, 0x41, np.uint8)
        doc[pos: pos + len(probe)] = np.frombuffer(probe, np.uint8)
        docs.append(doc)
        # (b) end the document EXACTLY at its tile boundary with the
        # probe's tail truncated by the document end: the next packed
        # document starts in the adjacent tile, and its leading bytes
        # must never complete this document's sequence.
        doc = np.full(tile, 0x41, np.uint8)
        doc[tile - len(probe):] = np.frombuffer(probe, np.uint8)
        docs.append(doc)
        # ...followed by a document that BEGINS with continuation bytes
        # (the exact bytes that would complete the truncated lead).
        docs.append(np.frombuffer(b"\xa9\x80\x80 tail", np.uint8))
    docs.append(np.zeros(0, np.uint8))                       # empty
    docs.append(np.full(200, 0x2E, np.uint8))                # all-ASCII
    docs.append(np.zeros(0, np.uint8))                       # empty again
    return docs


def boundary_documents16():
    """UTF-16 analogue: surrogate pairs straddling tile boundaries and
    lone high surrogates truncated at a document end whose packed
    neighbour starts with a low surrogate."""
    tile = packing.TILE
    docs = []
    # pair straddles the doc's internal tile boundary
    doc = np.full(tile + 32, 0x41, np.uint16)
    doc[tile - 1: tile + 1] = [0xD83C, 0xDF89]
    docs.append(doc)
    # doc ends at its tile boundary on a lone high surrogate...
    doc = np.full(tile, 0x41, np.uint16)
    doc[-1] = 0xD800
    docs.append(doc)
    # ...next doc starts with the low half that must NOT pair with it.
    docs.append(np.frombuffer(
        np.array([0xDC00, 0x42, 0x43], np.uint16).tobytes(),
        np.uint16))
    docs.append(np.zeros(0, np.uint16))                      # empty
    docs.append(np.full(100, 0x41, np.uint16))               # all-ASCII
    return docs


# ---------------------------------------------------------------------------
# Single-document cells: (strategy x errors) vs CPython


def _check8_strict(buf, n, strategy):
    want, want_pos = _py8(bytes(buf[:n]))
    x = jnp.asarray(buf if strategy in ("fused", "onepass")
                    else buf.astype(np.int32))
    out, cnt, status = tc.transcode_utf8_to_utf16(x, n, strategy=strategy)
    assert int(status) == want_pos
    got = np.asarray(out)[: min(int(cnt), out.shape[0])]
    if want_pos < 0:
        assert int(cnt) == len(want)
        assert np.array_equal(got, want)
    elif strategy != "windowed":
        # The speculative output on an invalid stream is defined
        # cross-strategy for the block-parallel family; the serial
        # windowed walker resynchronizes differently and only pins
        # ``status`` there.
        ref = tc.utf8_to_utf16(jnp.asarray(buf.astype(np.int32)), n)
        assert int(cnt) == int(ref.count)
        assert np.array_equal(got, np.asarray(ref.buffer)[: len(got)])


def _check8_replace(buf, n, strategy):
    want = _py8_replace(bytes(buf[:n]))
    _, want_pos = _py8(bytes(buf[:n]))
    x = jnp.asarray(buf if strategy in ("fused", "onepass")
                    else buf.astype(np.int32))
    out, cnt, status = tc.transcode_utf8_to_utf16(x, n, strategy=strategy,
                                                  errors="replace")
    assert int(status) == want_pos
    assert int(cnt) == len(want)
    assert np.array_equal(np.asarray(out)[: int(cnt)], want)


@pytest.mark.parametrize("strategy", ["onepass", "fused", "blockparallel"])
def test_differential_utf8_to_utf16(strategy):
    rng = np.random.default_rng(SEED)
    for trial in range(20):
        buf, n = _utf8_case(rng, trial)
        _check8_strict(buf, n, strategy)
        _check8_replace(buf, n, strategy)


def test_differential_utf8_to_utf16_windowed():
    """The serial paper baseline: strict-only cell of the matrix."""
    rng = np.random.default_rng(SEED + 1)
    for trial in range(8):
        buf, n = _utf8_case(rng, trial)
        _check8_strict(buf, n, "windowed")


def _check16_strict(buf, n, strategy):
    want, want_pos = _py16(buf[:n])
    x = jnp.asarray(buf if strategy in ("fused", "onepass")
                    else buf.astype(np.int32))
    out, cnt, status = tc.transcode_utf16_to_utf8(x, n, strategy=strategy)
    assert int(status) == want_pos
    got = np.asarray(out)[: min(int(cnt), out.shape[0])]
    if want_pos < 0:
        assert int(cnt) == len(want)
        assert np.array_equal(got, want)
    elif strategy != "windowed":
        ref = tc.utf16_to_utf8(jnp.asarray(buf.astype(np.int32)), n)
        assert int(cnt) == int(ref.count)
        assert np.array_equal(got, np.asarray(ref.buffer)[: len(got)])


def _check16_replace(buf, n, strategy):
    want = _py16_replace(buf[:n])
    _, want_pos = _py16(buf[:n])
    x = jnp.asarray(buf if strategy in ("fused", "onepass")
                    else buf.astype(np.int32))
    out, cnt, status = tc.transcode_utf16_to_utf8(x, n, strategy=strategy,
                                                  errors="replace")
    assert int(status) == want_pos
    assert int(cnt) == len(want)
    assert np.array_equal(np.asarray(out)[: int(cnt)], want)


@pytest.mark.parametrize("strategy", ["onepass", "fused", "blockparallel"])
def test_differential_utf16_to_utf8(strategy):
    rng = np.random.default_rng(SEED + 2)
    for trial in range(16):
        buf, n = _utf16_case(rng, trial)
        _check16_strict(buf, n, strategy)
        _check16_replace(buf, n, strategy)


def test_differential_utf16_to_utf8_windowed():
    rng = np.random.default_rng(SEED + 3)
    for trial in range(6):
        buf, n = _utf16_case(rng, trial)
        _check16_strict(buf, n, "windowed")


# ---------------------------------------------------------------------------
# Ragged packed-batch cells: per-document statuses vs CPython


def _check_ragged8(docs, errors, strategy="onepass"):
    pk = packing.pack_documents(docs, dtype=np.uint8)
    res = tc.ragged_utf8_to_utf16(pk.data, pk.offsets, pk.lengths,
                                  errors=errors, strategy=strategy)
    for d, doc in enumerate(docs):
        raw = bytes(np.asarray(doc, np.uint8))
        _, want_pos = _py8(raw)
        assert int(res.statuses[d]) == want_pos, d
        lo = int(res.offsets[d])
        got = np.asarray(res.buffer)[lo: lo + int(res.counts[d])]
        if errors == "replace":
            want = _py8_replace(raw)
            assert int(res.counts[d]) == len(want), d
            assert np.array_equal(got, want), d
        elif want_pos < 0:
            want, _ = _py8(raw)
            assert int(res.counts[d]) == len(want), d
            assert np.array_equal(got, want), d
        # Acceptance: bit-identical to the per-document fused transcoder
        # (buffer, count, status) on the fuzz corpus.  Capacity = the
        # doc's tile span, so single-doc compilations are shared.
        span = max(int(pk.offsets[d + 1] - pk.offsets[d]), 1)
        buf = np.zeros(span, np.uint8)
        buf[: len(raw)] = np.frombuffer(raw, np.uint8)
        single = ft.utf8_to_utf16_fused(jnp.asarray(buf), len(raw),
                                        errors=errors)
        assert int(res.counts[d]) == int(single.count), d
        assert int(res.statuses[d]) == int(single.status), d
        k = min(int(single.count), span)
        assert np.array_equal(got[:k], np.asarray(single.buffer)[:k]), d


def _check_ragged16(docs, errors, strategy="onepass"):
    pk = packing.pack_documents(docs, dtype=np.uint16)
    res = tc.ragged_utf16_to_utf8(pk.data, pk.offsets, pk.lengths,
                                  errors=errors, strategy=strategy)
    for d, doc in enumerate(docs):
        u = np.asarray(doc, np.uint16)
        _, want_pos = _py16(u)
        assert int(res.statuses[d]) == want_pos, d
        lo = int(res.offsets[d])
        got = np.asarray(res.buffer)[lo: lo + int(res.counts[d])]
        if errors == "replace":
            want = _py16_replace(u)
            assert int(res.counts[d]) == len(want), d
            assert np.array_equal(got, want), d
        elif want_pos < 0:
            want, _ = _py16(u)
            assert int(res.counts[d]) == len(want), d
            assert np.array_equal(got, want), d
        span = max(int(pk.offsets[d + 1] - pk.offsets[d]), 1)
        buf = np.zeros(span, np.uint16)
        buf[: len(u)] = u
        single = ft.utf16_to_utf8_fused(jnp.asarray(buf), len(u),
                                        errors=errors)
        assert int(res.counts[d]) == int(single.count), d
        assert int(res.statuses[d]) == int(single.status), d
        k = min(int(single.count), 3 * span)
        assert np.array_equal(got[:k], np.asarray(single.buffer)[:k]), d


@pytest.mark.parametrize("strategy", ["onepass", "fused"])
@pytest.mark.parametrize("errors", ["strict", "replace"])
def test_differential_ragged_utf8_fuzz(errors, strategy):
    rng = np.random.default_rng(SEED + 4)
    for batch in range(4):
        docs = []
        for t in range(6):
            buf, n = _utf8_case(rng, batch * 6 + t, cap=1400)
            docs.append(buf[:n])
        docs.insert(2, np.zeros(0, np.uint8))            # empty mixed in
        docs.insert(4, np.full(77, 0x41, np.uint8))      # all-ASCII
        _check_ragged8(docs, errors, strategy)


@pytest.mark.parametrize("strategy", ["onepass", "fused"])
@pytest.mark.parametrize("errors", ["strict", "replace"])
def test_differential_ragged_utf16_fuzz(errors, strategy):
    rng = np.random.default_rng(SEED + 5)
    for batch in range(3):
        docs = []
        for t in range(5):
            buf, n = _utf16_case(rng, batch * 5 + t, cap=1200)
            docs.append(buf[:n])
        docs.insert(1, np.zeros(0, np.uint16))
        _check_ragged16(docs, errors, strategy)


@pytest.mark.parametrize("strategy", ["onepass", "fused"])
@pytest.mark.parametrize("errors", ["strict", "replace"])
def test_differential_ragged_boundary_adversarial_utf8(errors, strategy):
    _check_ragged8(boundary_documents8(), errors, strategy)


@pytest.mark.parametrize("strategy", ["onepass", "fused"])
@pytest.mark.parametrize("errors", ["strict", "replace"])
def test_differential_ragged_boundary_adversarial_utf16(errors, strategy):
    _check_ragged16(boundary_documents16(), errors, strategy)


def test_boundary_probes_also_hit_single_doc_strategies():
    """The tile-straddling probes, replayed through every single-doc
    strategy (padding each doc to the shared fixed capacity)."""
    for doc in boundary_documents8():
        n = len(doc)
        if n == 0 or n > CAP8:
            continue
        buf = np.zeros(CAP8, np.uint8)
        buf[:n] = doc
        for strategy in ("onepass", "fused", "blockparallel"):
            _check8_strict(buf, n, strategy)
            _check8_replace(buf, n, strategy)


# ---------------------------------------------------------------------------
# Codec-matrix cells: every new (src, dst) pair vs CPython codecs.
#
# The oracle is CPython end to end: decode with the source codec, encode
# with the destination codec.  Status semantics: the first input-element
# offset where a substitution would occur — a decode error
# (``UnicodeDecodeError.start`` scaled to units) or, for Latin-1 egress,
# an unencodable code point (``UnicodeEncodeError.start`` mapped back to
# source elements through the strictly-decodable prefix).

_CODEC = {"utf8": "utf-8", "utf16": "utf-16-le", "utf32": "utf-32-le",
          "latin1": "latin-1"}
# Explicit little-endian dtypes: the oracle's wire form must not depend
# on host endianness.
_WIRE_DT = {"utf8": np.dtype(np.uint8), "utf16": np.dtype("<u2"),
            "utf32": np.dtype("<u4"), "latin1": np.dtype(np.uint8)}

MATRIX_NEW_PAIRS = [("utf8", "utf32"), ("utf32", "utf8"),
                    ("utf16", "utf32"), ("utf32", "utf16"),
                    ("latin1", "utf8"), ("utf8", "latin1")]


def _wire_bytes(src, arr):
    return np.ascontiguousarray(arr).astype(_WIRE_DT[src]).tobytes()


def _from_text(fmt, text):
    return np.frombuffer(text.encode(_CODEC[fmt]), _WIRE_DT[fmt])


def _expected_status(src, dst, arr):
    """Our status semantics from CPython oracles (see section comment)."""
    raw = _wire_bytes(src, arr)
    width = _WIRE_DT[src].itemsize
    try:
        text = raw.decode(_CODEC[src])
        dec_pos = -1
    except UnicodeDecodeError as e:
        text = raw[: e.start].decode(_CODEC[src])  # strictly-valid prefix
        dec_pos = e.start // width
    if dst == "latin1":
        for j, ch in enumerate(text):
            if ord(ch) > 0xFF:
                return len(text[:j].encode(_CODEC[src])) // width
    return dec_pos


CAPM = 1280   # fixed matrix-cell capacity: one compilation per cell


def _matrix_transcode(src, dst, arr, strategy, errors):
    buf = np.zeros(max(CAPM, len(arr)), _WIRE_DT[src])
    buf[: len(arr)] = arr
    x = jnp.asarray(buf) if strategy in ("fused", "onepass") \
        else jnp.asarray(buf.astype(np.int64).astype(np.int32))
    return tc.transcode(x, dst, src_format=src, n_valid=len(arr),
                        strategy=strategy, errors=errors)


def _check_matrix_cell(src, dst, arr, strategy):
    raw = _wire_bytes(src, arr)
    want_pos = _expected_status(src, dst, arr)

    # strict: byte-exact on valid streams, status always; the
    # speculative invalid-stream output is defined cross-strategy.
    out, cnt, status = _matrix_transcode(src, dst, arr, strategy, "strict")
    assert int(status) == want_pos, (src, dst, strategy, int(status))
    got = np.asarray(out)[: min(int(cnt), out.shape[0])]
    if want_pos < 0:
        want = _from_text(dst, raw.decode(_CODEC[src]))
        assert int(cnt) == len(want), (src, dst, strategy)
        assert np.array_equal(got.astype(np.int64), want), \
            (src, dst, strategy)
    else:
        ref = _matrix_transcode(src, dst, arr, "blockparallel", "strict")
        assert int(cnt) == int(ref.count), (src, dst, strategy)
        assert np.array_equal(
            got.astype(np.int64),
            np.asarray(ref.buffer)[: len(got)].astype(np.int64)), \
            (src, dst, strategy)

    # replace: byte-exact vs CPython's chained replace semantics.
    want = _from_text(dst, raw.decode(_CODEC[src], "replace")) \
        if dst != "latin1" else np.frombuffer(
            raw.decode(_CODEC[src], "replace")
            .encode("latin-1", "replace"), np.uint8)
    out, cnt, status = _matrix_transcode(src, dst, arr, strategy, "replace")
    assert int(status) == want_pos, (src, dst, strategy)
    assert int(cnt) == len(want), (src, dst, strategy)
    assert np.array_equal(
        np.asarray(out)[: int(cnt)].astype(np.int64), want), \
        (src, dst, strategy)


def _matrix_case(src, rng, trial, cap):
    """One seeded source buffer for a matrix-cell fuzz trial."""
    if src == "utf8":
        buf, n = _utf8_case(rng, trial, cap=cap)
        return buf[:n]
    if src == "utf16":
        buf, n = _utf16_case(rng, trial, cap=cap)
        return buf[:n]
    if src == "utf32":
        n = int(rng.integers(1, cap))
        kind = trial % 3
        if kind == 0:   # valid code points from a corpus
            text = bytes(synthetic.utf8_array(
                LANGS[trial % len(LANGS)], 400,
                seed=SEED + trial)).decode("utf-8")[:n]
            return np.array([ord(c) for c in text], np.uint32)
        cps = rng.integers(0, 0x110000, n).astype(np.uint32)
        if kind == 2:   # sprinkle surrogates / too-large / huge garbage
            k = int(rng.integers(1, 6))
            where = rng.integers(0, n, k)
            cps[where] = rng.choice(
                np.array([0xD800, 0xDFFF, 0x110000, 0xFFFFFFFF, 0xDC00],
                         np.uint32), k)
        return cps
    # latin1: any byte stream is valid
    n = int(rng.integers(1, cap))
    return rng.integers(0, 256, n).astype(np.uint8)


@pytest.mark.parametrize("src,dst", MATRIX_NEW_PAIRS)
@pytest.mark.parametrize("strategy", ["onepass", "fused", "blockparallel"])
def test_differential_matrix_cells(src, dst, strategy):
    rng = np.random.default_rng(SEED + 8)
    for trial in range(8):
        arr = _matrix_case(src, rng, trial, cap=CAPM)
        _check_matrix_cell(src, dst, arr, strategy)


def test_differential_matrix_boundary_adversarial():
    """Matrix cells with errors engineered to straddle the 1024-lane
    VMEM tile boundary (the cross-tile claimed-byte chain must agree
    with CPython at every offset, for every endpoint)."""
    probes8 = [b"\xf0\x9f\x92", b"\xc3", b"\xed\xa0\x80", b"\xc3\xa9"]
    for probe in probes8:
        for pos in (1021, 1022, 1023, 1024):
            buf = np.full(2048, 0x41, np.uint8)
            buf[pos: pos + len(probe)] = np.frombuffer(probe, np.uint8)
            for dst in ("utf32", "latin1"):
                _check_matrix_cell("utf8", dst, buf, "fused")
    # utf32 source: a bad scalar at the tile boundary
    for bad in (0xD800, 0x110000):
        cps = np.full(1100, 0x41, np.uint32)
        cps[1023] = bad
        for dst in ("utf8", "utf16"):
            _check_matrix_cell("utf32", dst, cps, "fused")
    # latin1 source: high bytes straddling the boundary widen to 2-byte
    # UTF-8 sequences across it
    b = np.full(1100, 0x41, np.uint8)
    b[1020:1028] = 0xE9
    _check_matrix_cell("latin1", "utf8", b, "fused")
    # utf8 -> latin1: an unencodable (but valid UTF-8) char at the
    # boundary must locate at its lead byte
    s = "A" * 1022 + "中" + "B" * 64
    arr = np.frombuffer(s.encode("utf-8"), np.uint8)
    _check_matrix_cell("utf8", "latin1", arr, "fused")


@pytest.mark.parametrize("src,dst", [("utf8", "utf32"), ("latin1", "utf8"),
                                     ("utf8", "latin1")])
@pytest.mark.parametrize("strategy", ["onepass", "fused"])
@pytest.mark.parametrize("errors", ["strict", "replace"])
def test_differential_matrix_ragged(src, dst, errors, strategy):
    """Ragged matrix cells: per-document parity with the single-document
    fused transcoder and with the CPython oracle."""
    rng = np.random.default_rng(SEED + 9)
    docs = [_matrix_case(src, rng, t, cap=1200) for t in range(5)]
    docs.insert(1, np.zeros(0, _WIRE_DT[src]))           # empty mixed in
    docs.insert(3, np.full(80, 0x41, _WIRE_DT[src]))     # all-ASCII
    pk = packing.pack_documents(docs, dtype=_WIRE_DT[src])
    res = tc.ragged_transcode(pk.data, pk.offsets, pk.lengths,
                              src_format=src, dst_format=dst,
                              errors=errors, strategy=strategy)
    factor = tc.CAP_FACTOR[(src, dst)]
    for d, doc in enumerate(docs):
        want_pos = _expected_status(src, dst, doc)
        assert int(res.statuses[d]) == want_pos, d
        lo = int(res.offsets[d])
        got = np.asarray(res.buffer)[lo: lo + int(res.counts[d])]
        span = max(int(pk.offsets[d + 1] - pk.offsets[d]), 1)
        buf = np.zeros(span, _WIRE_DT[src])
        buf[: len(doc)] = doc
        single = ft.transcode_fused(jnp.asarray(buf), len(doc), src=src,
                                    dst=dst, errors=errors)
        assert int(res.counts[d]) == int(single.count), d
        assert int(res.statuses[d]) == int(single.status), d
        k = min(int(single.count), factor * span)
        assert np.array_equal(got[:k], np.asarray(single.buffer)[:k]), d


@pytest.mark.parametrize("src,dst", MATRIX_NEW_PAIRS)
def test_parity_matrix_interpret_vs_compiled(src, dst):
    """Matrix cells: interpreter kernels vs the XLA-compiled
    blockparallel reference (and Mosaic vs interpreter on TPU)."""
    rng = np.random.default_rng(SEED + 10)
    for trial in range(4):
        arr = _matrix_case(src, rng, trial, cap=1280)
        interp = ft.transcode_fused(jnp.asarray(arr), len(arr), src=src,
                                    dst=dst, interpret=True)
        ref = _matrix_transcode(src, dst, arr, "blockparallel", "strict")
        assert int(interp.count) == int(ref.count), (src, dst, trial)
        assert int(interp.status) == int(ref.status), (src, dst, trial)
        k = int(interp.count)
        assert np.array_equal(
            np.asarray(interp.buffer)[:k].astype(np.int64),
            np.asarray(ref.buffer)[:k].astype(np.int64)), (src, dst, trial)
        if _on_tpu():   # pragma: no cover - TPU-only branch
            comp = ft.transcode_fused(jnp.asarray(arr), len(arr), src=src,
                                      dst=dst, interpret=False)
            assert int(comp.count) == int(interp.count)
            assert int(comp.status) == int(interp.status)
            assert np.array_equal(np.asarray(comp.buffer),
                                  np.asarray(interp.buffer))


# ---------------------------------------------------------------------------
# Interpret-vs-compiled parity (the CI parity job runs `-k parity`).
#
# On CPU there is no Mosaic: parity means the Pallas INTERPRETER kernels
# against the XLA-COMPILED blockparallel reference (both jitted).  On a
# TPU backend the same tests additionally pin the Mosaic-compiled
# kernels (interpret=False) to the interpreter (interpret=True).


def _on_tpu():
    return jax.default_backend() == "tpu"


def test_parity_resolution_matches_backend():
    assert runtime.resolve_interpret(None) == (not _on_tpu())


@pytest.mark.parametrize("errors", ["strict", "replace"])
def test_parity_utf8_interpret_vs_compiled(errors):
    rng = np.random.default_rng(SEED + 6)
    for trial in range(8):
        buf, n = _utf8_case(rng, trial)
        interp = ft.utf8_to_utf16_fused(jnp.asarray(buf), n, errors=errors,
                                        interpret=True)
        # Compiled reference: the pure-jnp strategy under jit.
        ref = tc.utf8_to_utf16(jnp.asarray(buf.astype(np.int32)), n,
                               errors=errors)
        assert int(interp.count) == int(ref.count), trial
        assert int(interp.status) == int(ref.status), trial
        k = min(int(interp.count), CAP8)
        assert np.array_equal(np.asarray(interp.buffer)[:k],
                              np.asarray(ref.buffer)[:k]), trial
        if _on_tpu():   # pragma: no cover - TPU-only branch
            comp = ft.utf8_to_utf16_fused(jnp.asarray(buf), n,
                                          errors=errors, interpret=False)
            assert int(comp.count) == int(interp.count)
            assert int(comp.status) == int(interp.status)
            assert np.array_equal(np.asarray(comp.buffer),
                                  np.asarray(interp.buffer))


@pytest.mark.parametrize("errors", ["strict", "replace"])
def test_parity_utf16_interpret_vs_compiled(errors):
    rng = np.random.default_rng(SEED + 7)
    for trial in range(6):
        buf, n = _utf16_case(rng, trial)
        interp = ft.utf16_to_utf8_fused(jnp.asarray(buf), n, errors=errors,
                                        interpret=True)
        ref = tc.utf16_to_utf8(jnp.asarray(buf.astype(np.int32)), n,
                               errors=errors)
        assert int(interp.count) == int(ref.count), trial
        assert int(interp.status) == int(ref.status), trial
        k = min(int(interp.count), 3 * CAP16)
        assert np.array_equal(np.asarray(interp.buffer)[:k],
                              np.asarray(ref.buffer)[:k]), trial
        if _on_tpu():   # pragma: no cover - TPU-only branch
            comp = ft.utf16_to_utf8_fused(jnp.asarray(buf), n,
                                          errors=errors, interpret=False)
            assert int(comp.count) == int(interp.count)
            assert int(comp.status) == int(interp.status)
            assert np.array_equal(np.asarray(comp.buffer),
                                  np.asarray(interp.buffer))


@pytest.mark.parametrize("errors", ["strict", "replace"])
def test_parity_onepass_interpret_vs_compiled(errors):
    """One-pass kernels (single launch, SMEM carry): interpreter vs the
    XLA-compiled blockparallel reference on CPU; on a TPU backend the
    same test additionally pins the Mosaic-compiled kernel — whose
    sequential-grid carry is the §9 correctness assumption — to the
    interpreter."""
    from repro.kernels import onepass_transcode as opk
    rng = np.random.default_rng(SEED + 11)
    for trial in range(8):
        buf, n = _utf8_case(rng, trial)
        interp = opk.utf8_to_utf16_onepass(jnp.asarray(buf), n,
                                           errors=errors, interpret=True)
        ref = tc.utf8_to_utf16(jnp.asarray(buf.astype(np.int32)), n,
                               errors=errors)
        assert int(interp.count) == int(ref.count), trial
        assert int(interp.status) == int(ref.status), trial
        k = min(int(interp.count), CAP8)
        assert np.array_equal(np.asarray(interp.buffer)[:k],
                              np.asarray(ref.buffer)[:k]), trial
        if _on_tpu():   # pragma: no cover - TPU-only branch
            comp = opk.utf8_to_utf16_onepass(jnp.asarray(buf), n,
                                             errors=errors,
                                             interpret=False)
            assert int(comp.count) == int(interp.count)
            assert int(comp.status) == int(interp.status)
            assert np.array_equal(np.asarray(comp.buffer),
                                  np.asarray(interp.buffer))


@pytest.mark.parametrize("src,dst", MATRIX_NEW_PAIRS)
def test_parity_onepass_matrix_interpret_vs_compiled(src, dst):
    """Matrix cells through the one-pass kernel: interpreter vs the
    compiled blockparallel reference (and Mosaic vs interpreter on
    TPU)."""
    from repro.kernels import onepass_transcode as opk
    rng = np.random.default_rng(SEED + 12)
    for trial in range(3):
        arr = _matrix_case(src, rng, trial, cap=1280)
        interp = opk.transcode_onepass(jnp.asarray(arr), len(arr), src=src,
                                       dst=dst, interpret=True)
        ref = _matrix_transcode(src, dst, arr, "blockparallel", "strict")
        assert int(interp.count) == int(ref.count), (src, dst, trial)
        assert int(interp.status) == int(ref.status), (src, dst, trial)
        k = int(interp.count)
        assert np.array_equal(
            np.asarray(interp.buffer)[:k].astype(np.int64),
            np.asarray(ref.buffer)[:k].astype(np.int64)), (src, dst, trial)
        if _on_tpu():   # pragma: no cover - TPU-only branch
            comp = opk.transcode_onepass(jnp.asarray(arr), len(arr),
                                         src=src, dst=dst, interpret=False)
            assert int(comp.count) == int(interp.count)
            assert int(comp.status) == int(interp.status)
            assert np.array_equal(np.asarray(comp.buffer),
                                  np.asarray(interp.buffer))


def test_parity_ragged_onepass_interpret_vs_compiled():
    """Ragged one-pass launch: interpreter vs the per-document compiled
    reference (and Mosaic vs interpreter on TPU)."""
    from repro.kernels import ragged_transcode as rt
    docs = boundary_documents8()
    pk = packing.pack_documents(docs, dtype=np.uint8)
    interp = rt.utf8_to_utf16_ragged(pk.data, pk.offsets, pk.lengths,
                                     interpret=True, strategy="onepass")
    for d, doc in enumerate(docs):
        n = len(doc)
        buf = np.zeros(max(n, 1), np.uint8)
        buf[:n] = doc
        ref = tc.utf8_to_utf16(jnp.asarray(buf.astype(np.int32)), n)
        assert int(interp.counts[d]) == int(ref.count), d
        assert int(interp.statuses[d]) == int(ref.status), d
    if _on_tpu():   # pragma: no cover - TPU-only branch
        comp = rt.utf8_to_utf16_ragged(pk.data, pk.offsets, pk.lengths,
                                       interpret=False, strategy="onepass")
        assert np.array_equal(np.asarray(comp.buffer),
                              np.asarray(interp.buffer))
        assert np.array_equal(np.asarray(comp.counts),
                              np.asarray(interp.counts))
        assert np.array_equal(np.asarray(comp.statuses),
                              np.asarray(interp.statuses))


def test_parity_ragged_interpret_vs_compiled():
    """Ragged packed path: interpreter kernels vs the per-document
    compiled reference, per document (and Mosaic vs interpreter on TPU)."""
    from repro.kernels import ragged_transcode as rt
    docs = boundary_documents8()
    pk = packing.pack_documents(docs, dtype=np.uint8)
    interp = rt.utf8_to_utf16_ragged(pk.data, pk.offsets, pk.lengths,
                                     interpret=True)
    for d, doc in enumerate(docs):
        n = len(doc)
        buf = np.zeros(max(n, 1), np.uint8)
        buf[:n] = doc
        ref = tc.utf8_to_utf16(jnp.asarray(buf.astype(np.int32)), n)
        assert int(interp.counts[d]) == int(ref.count), d
        assert int(interp.statuses[d]) == int(ref.status), d
    if _on_tpu():   # pragma: no cover - TPU-only branch
        comp = rt.utf8_to_utf16_ragged(pk.data, pk.offsets, pk.lengths,
                                       interpret=False)
        assert np.array_equal(np.asarray(comp.buffer),
                              np.asarray(interp.buffer))
        assert np.array_equal(np.asarray(comp.counts),
                              np.asarray(interp.counts))
        assert np.array_equal(np.asarray(comp.statuses),
                              np.asarray(interp.statuses))


# ---------------------------------------------------------------------------
# Streaming vs CPython's INCREMENTAL codecs (resumable transcode,
# DESIGN.md §10): the chunked stream at adversarial split points —
# mid-sequence, mid-surrogate-pair, empty chunks, 1-byte chunks — must
# reproduce what ``codecs.getincrementaldecoder`` sees chunk by chunk.


def _random_splits(rng, n, n_cuts):
    """Random cut points, with empties (duplicate cuts) mixed in."""
    cuts = np.sort(rng.integers(0, n + 1, n_cuts))
    bounds = np.concatenate([[0], cuts, [n]])
    return [(int(bounds[i]), int(bounds[i + 1]))
            for i in range(len(bounds) - 1)]


def test_stream_incremental_utf8_replace_fuzz():
    """utf8 -> utf16, errors="replace": each chunk's emission must equal
    the incremental decoder's per-chunk output, encoded to UTF-16LE —
    CPython's own holdback is the oracle for ours."""
    import codecs
    from repro.core import stream as cs
    rng = np.random.default_rng(SEED + 71)
    for trial in range(8):
        b = synthetic.utf8_array(LANGS[trial % len(LANGS)], 200,
                                 seed=SEED + trial).copy()
        bad = rng.integers(0, len(b), 6)
        b[bad] = rng.integers(0x80, 0x100, 6)       # random dirt
        st = cs.stream_init("utf8", "utf16", errors="replace")
        dec = codecs.getincrementaldecoder("utf-8")("replace")
        for lo, hi in _random_splits(rng, len(b), 6):
            res, st = cs.transcode_stream_chunk(st, b[lo:hi])
            want = np.frombuffer(
                dec.decode(b[lo:hi].tobytes()).encode("utf-16-le"),
                np.uint16)
            got = np.asarray(res.buffer)[: int(res.count)]
            np.testing.assert_array_equal(got, want, err_msg=f"t{trial}")
        res, st = cs.finalize(st)
        want = np.frombuffer(
            dec.decode(b"", final=True).encode("utf-16-le"), np.uint16)
        np.testing.assert_array_equal(
            np.asarray(res.buffer)[: int(res.count)], want)


def test_stream_incremental_utf16_replace_fuzz():
    """utf16 -> utf8 with surrogate pairs straddling random splits,
    including single-unit chunks."""
    import codecs
    from repro.core import stream as cs
    rng = np.random.default_rng(SEED + 72)
    for trial in range(8):
        u = synthetic.utf16_units("emoji", 120, seed=SEED + trial).copy()
        u[rng.integers(0, len(u), 3)] = 0xD800      # lone surrogates
        st = cs.stream_init("utf16", "utf8", errors="replace")
        dec = codecs.getincrementaldecoder("utf-16-le")("replace")
        splits = _random_splits(rng, len(u), 10) if trial % 2 else \
            [(i, i + 1) for i in range(len(u))]     # 1-unit chunks
        for lo, hi in splits:
            res, st = cs.transcode_stream_chunk(st, u[lo:hi])
            want = np.frombuffer(
                dec.decode(u[lo:hi].astype("<u2").tobytes())
                .encode("utf-8"), np.uint8)
            got = np.asarray(res.buffer)[: int(res.count)]
            np.testing.assert_array_equal(got, want, err_msg=f"t{trial}")
        res, _ = cs.finalize(st)
        want = np.frombuffer(
            dec.decode(b"", final=True).encode("utf-8"), np.uint8)
        np.testing.assert_array_equal(
            np.asarray(res.buffer)[: int(res.count)], want)


def test_stream_incremental_strict_status_fuzz():
    """errors="strict": the final sticky status must equal the
    whole-buffer ``UnicodeDecodeError.start`` regardless of chunking."""
    from repro.core import stream as cs
    rng = np.random.default_rng(SEED + 73)
    for trial in range(10):
        b = synthetic.utf8_array(LANGS[trial % len(LANGS)], 150,
                                 seed=SEED + trial).copy()
        k = int(rng.integers(1, 5))
        b[rng.integers(0, max(len(b), 1), k)] = rng.integers(0, 256, k)
        try:
            b.tobytes().decode("utf-8")
            want = -1
        except UnicodeDecodeError as e:
            want = e.start
        st = cs.stream_init("utf8", "utf16", errors="strict")
        for lo, hi in _random_splits(rng, len(b), 5):
            _, st = cs.transcode_stream_chunk(st, b[lo:hi])
        _, st = cs.finalize(st)
        assert st.status == want, (trial, st.status, want)
