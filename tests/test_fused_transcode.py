"""Fused two-pass pipeline: strategy equivalence, edge cases, and
HBM-traffic shape checks (narrow ingress, no full-capacity int32 between
decode and compaction)."""

import numpy as np
import pytest

import jax
import jax.core
import jax.numpy as jnp

from repro.core import transcode as tc
from repro.data import pipeline, synthetic
from repro.kernels import fused_transcode as ft
from repro.kernels import ops, runtime

LIPSUM_LANGS = ["arabic", "chinese", "emoji", "hebrew", "hindi",
                "japanese", "korean", "latin", "russian"]


def _utf8(lang, n_chars, seed=0):
    return synthetic.utf8_array(lang, n_chars, seed)


def _utf16(lang, n_chars, seed=0):
    return synthetic.utf16_units(lang, n_chars, seed)


def _unpack(res):
    out, cnt, status = res
    return np.asarray(out)[: int(cnt)], int(cnt), int(status)


def _py_err_start(raw: bytes):
    """Python's exc.start for a UTF-8 stream, -1 when valid."""
    try:
        raw.decode("utf-8")
        return -1
    except UnicodeDecodeError as e:
        return e.start


def _py_err_start16(units: np.ndarray):
    """Python's exc.start // 2 for a UTF-16LE stream, -1 when valid."""
    try:
        units.astype(np.uint16).tobytes().decode("utf-16-le")
        return -1
    except UnicodeDecodeError as e:
        return e.start // 2


# ---------------------------------------------------------------------------
# Equivalence on every benchmark corpus


@pytest.mark.parametrize("lang", LIPSUM_LANGS)
def test_fused_equals_blockparallel_and_windowed_utf8_to_utf16(lang):
    b = _utf8(lang, 1200, seed=11)
    n = len(b)
    got_f = _unpack(tc.transcode_utf8_to_utf16(
        jnp.asarray(b), n, strategy="fused"))
    got_b = _unpack(tc.transcode_utf8_to_utf16(
        jnp.asarray(b.astype(np.int32)), n, strategy="blockparallel"))
    got_w = _unpack(tc.transcode_utf8_to_utf16(
        jnp.asarray(b.astype(np.int32)), n, strategy="windowed"))
    assert got_f[1] == got_b[1] == got_w[1]
    assert np.array_equal(got_f[0], got_b[0])
    assert np.array_equal(got_f[0], got_w[0])
    assert got_f[2] == got_b[2] == got_w[2] == -1
    # python oracle
    want = np.frombuffer(bytes(b).decode("utf-8").encode("utf-16-le"),
                         np.uint16)
    assert np.array_equal(got_f[0], want)


@pytest.mark.parametrize("lang", LIPSUM_LANGS)
def test_fused_equals_blockparallel_and_windowed_utf16_to_utf8(lang):
    u = _utf16(lang, 1200, seed=11)
    n = len(u)
    got_f = _unpack(tc.transcode_utf16_to_utf8(
        jnp.asarray(u), n, strategy="fused"))
    got_b = _unpack(tc.transcode_utf16_to_utf8(
        jnp.asarray(u.astype(np.int32)), n, strategy="blockparallel"))
    got_w = _unpack(tc.transcode_utf16_to_utf8(
        jnp.asarray(u.astype(np.int32)), n, strategy="windowed"))
    assert got_f[1] == got_b[1] == got_w[1]
    assert np.array_equal(got_f[0], got_b[0])
    assert np.array_equal(got_f[0], got_w[0])
    assert got_f[2] == got_b[2] == got_w[2] == -1
    want = np.frombuffer(
        u.tobytes().decode("utf-16-le").encode("utf-8"), np.uint8)
    assert np.array_equal(got_f[0], want)


# ---------------------------------------------------------------------------
# Property test: random valid + mutated-invalid streams


def test_fused_equals_blockparallel_on_mutated_streams():
    rng = np.random.default_rng(7)
    langs = ["latin", "arabic", "chinese", "emoji"]
    fixed = 1536  # fixed buffer so all cases share one compilation
    for trial in range(24):
        b = _utf8(langs[trial % 4], 400, seed=trial)[:fixed]
        buf = np.zeros(fixed, np.uint8)
        buf[: len(b)] = b
        n = len(b)
        if trial % 3:  # two thirds of cases: corrupt 1-3 random bytes
            k = rng.integers(1, 4)
            buf[rng.integers(0, max(n, 1), k)] = rng.integers(0, 256, k)
        want_pos = _py_err_start(bytes(buf[:n]))
        got_f = _unpack(ft.utf8_to_utf16_fused(jnp.asarray(buf), n))
        got_b = _unpack(tc.utf8_to_utf16(
            jnp.asarray(buf.astype(np.int32)), n))
        assert got_f[1] == got_b[1], trial
        assert np.array_equal(got_f[0], got_b[0]), trial
        # single-scan status: fused == blockparallel == Python exc.start
        assert got_f[2] == got_b[2] == want_pos, trial


def test_fused_equals_blockparallel_on_mutated_utf16_streams():
    rng = np.random.default_rng(9)
    fixed = 1280
    for trial in range(16):
        u = _utf16(["latin", "emoji", "korean", "russian"][trial % 4],
                   400, seed=trial)[:fixed]
        buf = np.zeros(fixed, np.uint16)
        buf[: len(u)] = u
        n = len(u)
        if trial % 2:  # half the cases: corrupt 1-2 random units
            k = rng.integers(1, 3)
            buf[rng.integers(0, max(n, 1), k)] = \
                rng.integers(0, 1 << 16, k)
        want_pos = _py_err_start16(buf[:n])
        got_f = _unpack(ft.utf16_to_utf8_fused(jnp.asarray(buf), n))
        got_b = _unpack(tc.utf16_to_utf8(
            jnp.asarray(buf.astype(np.int32)), n))
        assert got_f[1] == got_b[1], trial
        assert np.array_equal(got_f[0], got_b[0]), trial
        assert got_f[2] == got_b[2] == want_pos, trial


# ---------------------------------------------------------------------------
# Edge cases


def test_fused_speculative_worst_case_stage_width():
    """Invalid input dense in 4-byte leads makes EVERY byte of a tile a
    speculative 2-unit lead (2*BLOCK units per tile) — the per-tile stage
    must absorb that or base offsets desynchronize from blockparallel."""
    b = np.concatenate([np.full(1024, 0xF4, np.uint8),
                        np.full(1024, 0xF1, np.uint8)])
    got_f = _unpack(ft.utf8_to_utf16_fused(jnp.asarray(b), len(b)))
    got_b = _unpack(tc.utf8_to_utf16(jnp.asarray(b.astype(np.int32)),
                                     len(b)))
    assert got_f[1] == got_b[1]
    assert np.array_equal(got_f[0], got_b[0])
    assert got_f[2] == got_b[2] == 0  # invalid from the first byte
    # UTF-16 side: every unit a speculative 3-byte lane (valid stream of
    # U+E000) exactly fills the 3*BLOCK stage.
    u = np.full(2048, 0xE000, np.uint16)
    got_f = _unpack(ft.utf16_to_utf8_fused(jnp.asarray(u), len(u)))
    got_b = _unpack(tc.utf16_to_utf8(jnp.asarray(u.astype(np.int32)),
                                     len(u)))
    assert got_f[1] == got_b[1] == 3 * 2048
    assert np.array_equal(got_f[0], got_b[0])
    # VALID input overflow: a surrogate pair straddling the tile boundary
    # gives tile 0 a 4-byte lane with no compensating 0-lane in-tile, so
    # its total is 3*BLOCK + 1 — one past the naive stage bound.
    u = np.concatenate([np.full(1023, 0xE000, np.uint16),
                        np.asarray([0xD800, 0xDC00], np.uint16),
                        np.full(1023, 0x41, np.uint16)])
    got_f = _unpack(ft.utf16_to_utf8_fused(jnp.asarray(u), len(u)))
    got_b = _unpack(tc.utf16_to_utf8(jnp.asarray(u.astype(np.int32)),
                                     len(u)))
    want = np.frombuffer(
        u.tobytes().decode("utf-16-le").encode("utf-8"), np.uint8)
    assert got_f[1] == got_b[1] == len(want)
    assert np.array_equal(got_f[0], want)
    assert np.array_equal(got_b[0], want)
    assert got_f[2] == got_b[2] == -1
    # and the unpaired-high-surrogate flood (mixed 3-byte/4-byte lanes)
    u = np.full(2048, 0xD800, np.uint16)
    got_f = _unpack(ft.utf16_to_utf8_fused(jnp.asarray(u), len(u)))
    got_b = _unpack(tc.utf16_to_utf8(jnp.asarray(u.astype(np.int32)),
                                     len(u)))
    assert got_f[1] == got_b[1]
    assert np.array_equal(got_f[0], got_b[0])
    assert got_f[2] == got_b[2] == 0


def test_fused_zero_length():
    out, cnt, status = ft.utf8_to_utf16_fused(jnp.zeros((0,), jnp.uint8), 0)
    assert out.shape == (0,) and int(cnt) == 0 and int(status) == -1
    out, cnt, status = ft.utf16_to_utf8_fused(jnp.zeros((0,), jnp.uint16), 0)
    assert out.shape == (0,) and int(cnt) == 0 and int(status) == -1


def test_fused_n_valid_zero():
    b = jnp.asarray(np.full(64, 0xFF, np.uint8))  # garbage beyond n
    out, cnt, status = ft.utf8_to_utf16_fused(b, 0)
    assert int(cnt) == 0 and int(status) == -1


def test_fused_tile_aligned_trailing_truncation():
    b = np.full(2048, 0x41, np.uint8)
    b[-1] = 0xC3  # lead byte truncated exactly at a tile boundary
    _, _, status = ft.utf8_to_utf16_fused(jnp.asarray(b), 2048)
    assert int(status) == 2047  # located at the truncated lead
    u = np.full(1024, 0x41, np.uint16)
    u[-1] = 0xD800  # lone high surrogate at the tile boundary
    _, _, status = ft.utf16_to_utf8_fused(jnp.asarray(u), 1024)
    assert int(status) == 1023


def test_fused_cross_tile_characters():
    s = "A" * 1022 + "🎉" + "B" * 100  # 4-byte char straddles the boundary
    b = np.frombuffer(s.encode("utf-8"), np.uint8)
    out, cnt, status = ft.utf8_to_utf16_fused(jnp.asarray(b), len(b))
    want = np.frombuffer(s.encode("utf-16-le"), np.uint16)
    assert int(status) == -1
    assert np.array_equal(np.asarray(out)[: int(cnt)], want)

    u = np.full(2048, 0x41, np.int32)
    u[1023], u[1024] = 0xD83C, 0xDF89  # pair straddles the boundary
    out, cnt, status = ft.utf16_to_utf8_fused(jnp.asarray(u), 2048)
    want = np.frombuffer(
        u.astype(np.uint16).tobytes().decode("utf-16-le").encode("utf-8"),
        np.uint8)
    assert int(status) == -1
    assert np.array_equal(np.asarray(out)[: int(cnt)], want)


def test_fused_ascii_fastpath_agrees_with_general():
    b = _utf8("latin", 500, seed=3)
    n = len(b)
    fast = _unpack(ft.utf8_to_utf16_fused(jnp.asarray(b), n))
    slow = _unpack(ft.utf8_to_utf16_fused(jnp.asarray(b), n,
                                          ascii_fastpath=False))
    assert fast[1] == slow[1] and fast[2] == slow[2]
    assert np.array_equal(fast[0], slow[0])


# ---------------------------------------------------------------------------
# HBM-traffic shape checks (acceptance: narrow ingress, nothing
# full-capacity int32 between decode and compaction)


def _iter_eqns(jaxpr, into_pallas=False):
    """All eqns of a jaxpr, recursing into sub-jaxprs (cond branches,
    pjit bodies, scans) but NOT into pallas_call kernel bodies unless
    asked: in-kernel VMEM ops are not HBM traffic."""
    for eqn in jaxpr.eqns:
        yield eqn
        if eqn.primitive.name == "pallas_call" and not into_pallas:
            continue
        for v in eqn.params.values():
            for sub in _sub_jaxprs(v):
                yield from _iter_eqns(sub, into_pallas)


def _sub_jaxprs(v):
    if isinstance(v, jax.core.ClosedJaxpr):
        yield v.jaxpr
    elif isinstance(v, jax.core.Jaxpr):
        yield v
    elif isinstance(v, (tuple, list)):
        for item in v:
            yield from _sub_jaxprs(item)


def _pallas_eqns(jaxpr):
    return [e for e in _iter_eqns(jaxpr) if e.primitive.name == "pallas_call"]


def test_fused_utf8_jaxpr_has_narrow_io_and_no_global_scatter():
    cap = 4096
    b = jnp.zeros((cap,), jnp.uint8)
    jaxpr = jax.make_jaxpr(
        lambda x: ft.utf8_to_utf16_fused(x, cap - 5, ascii_fastpath=False)
    )(b).jaxpr
    kernels = _pallas_eqns(jaxpr)
    assert len(kernels) == 2  # count pass + write pass
    for eqn in kernels:
        # Ingress <= 1 byte/element: every large operand is uint8.
        for v in eqn.invars:
            if v.aval.size >= cap:
                assert v.aval.dtype.itemsize == 1, (v.aval,)
        # Between decode and compaction nothing full-capacity and int32
        # leaves the kernel: outputs are per-tile scalars or narrow lanes.
        for v in eqn.outvars:
            assert v.aval.dtype.itemsize <= 2 or v.aval.size < cap // 256, \
                (v.aval,)
    # Global compaction is gone: no scatter outside the kernels.
    names = {e.primitive.name for e in _iter_eqns(jaxpr)}
    assert not any("scatter" in n for n in names), names


def test_fused_utf16_jaxpr_has_narrow_io_and_no_global_scatter():
    cap_in = 2048
    u = jnp.zeros((cap_in,), jnp.uint16)
    jaxpr = jax.make_jaxpr(
        lambda x: ft.utf16_to_utf8_fused(x, cap_in - 5, ascii_fastpath=False)
    )(u).jaxpr
    kernels = _pallas_eqns(jaxpr)
    assert len(kernels) == 2
    for eqn in kernels:
        for v in eqn.invars:
            if v.aval.size >= cap_in:
                assert v.aval.dtype.itemsize <= 2, (v.aval,)
        for v in eqn.outvars:
            assert v.aval.dtype.itemsize <= 2 or v.aval.size < cap_in // 256, \
                (v.aval,)
    names = {e.primitive.name for e in _iter_eqns(jaxpr)}
    assert not any("scatter" in n for n in names), names


def test_blockparallel_kernel_path_is_the_contrast():
    """The pre-fusion kernel path DOES ship full-capacity int32 decode
    outputs through HBM — the discriminating contrast for the test above."""
    cap = 4096
    b = jnp.zeros((cap,), jnp.int32)
    jaxpr = jax.make_jaxpr(
        lambda x: ops.utf8_to_utf16(x, cap - 5, validate=False))(b).jaxpr
    wide = [
        v for e in _pallas_eqns(jaxpr) for v in e.outvars
        if v.aval.dtype.itemsize == 4 and v.aval.size >= cap
    ]
    assert wide, "expected full-capacity int32 outputs on the legacy path"


# ---------------------------------------------------------------------------
# Validation fusion (acceptance): strategy="fused" with validation makes
# exactly ONE scan over the input bytes per pass — no standalone validate
# read — and the first_error_index matches Python's bytes.decode position.


def test_fused_validation_is_single_scan_jaxpr():
    """Validation must ride along with the count pass: turning validate on
    adds NO kernel launch and NO out-of-kernel read of the input bytes
    (the old standalone validate_kl pass showed up as full-capacity
    gathers outside pallas)."""
    cap = 4096
    b = jnp.zeros((cap,), jnp.uint8)
    jaxprs = {}
    for validate in (True, False):
        jaxprs[validate] = jax.make_jaxpr(
            lambda x, v=validate: ft.utf8_to_utf16_fused(
                x, cap - 5, validate=v, ascii_fastpath=False))(b).jaxpr
    for validate, jaxpr in jaxprs.items():
        kernels = _pallas_eqns(jaxpr)
        # count pass + write pass, nothing else — validation adds no scan.
        assert len(kernels) == 2, (validate, len(kernels))
        # No out-of-kernel gather touches a capacity-sized operand (the
        # nibble tables are 16-entry VMEM-resident kernel inputs).
        for eqn in _iter_eqns(jaxpr):
            if "gather" in eqn.primitive.name:
                assert all(v.aval.size < cap for v in eqn.invars), \
                    (validate, eqn)


def test_fused_scan_is_count_pass_only():
    """scan_utf8/scan_utf16: validation + capacity in ONE pallas call."""
    cap = 2048
    jaxpr = jax.make_jaxpr(
        lambda x: ft.utf8_scan_fused(x, cap - 3))(
            jnp.zeros((cap,), jnp.uint8)).jaxpr
    assert len(_pallas_eqns(jaxpr)) == 1
    jaxpr16 = jax.make_jaxpr(
        lambda x: ft.utf16_scan_fused(x, cap - 3))(
            jnp.zeros((cap,), jnp.uint16)).jaxpr
    assert len(_pallas_eqns(jaxpr16)) == 1


def test_first_error_index_matches_python_on_fuzzed_corpus():
    """Acceptance: status == Python UnicodeDecodeError.start across a
    fuzzed corpus (valid, mutated, and adversarial-alphabet streams)."""
    rng = np.random.default_rng(42)
    fixed = 1536
    adversarial = np.array([0x41, 0x80, 0x9F, 0xA0, 0xBF, 0xC0, 0xC2,
                            0xE0, 0xED, 0xEE, 0xF0, 0xF4, 0xF5, 0xFF,
                            0x90, 0x8F], np.uint8)
    for trial in range(30):
        buf = np.zeros(fixed, np.uint8)
        if trial % 3 == 0:
            b = _utf8(["emoji", "chinese", "hebrew"][(trial // 3) % 3], 400,
                      seed=trial)[:fixed]
            buf[: len(b)] = b
            n = len(b)
            k = rng.integers(0, 4)
            if k:
                buf[rng.integers(0, n, k)] = rng.integers(0, 256, k)
        elif trial % 3 == 1:
            n = int(rng.integers(1, fixed))
            buf[:n] = rng.integers(0, 256, n)
        else:
            n = int(rng.integers(1, 64))
            buf[:n] = rng.choice(adversarial, n)
        want = _py_err_start(bytes(buf[:n]))
        _, _, status = ft.utf8_to_utf16_fused(jnp.asarray(buf), n)
        assert int(status) == want, (trial, bytes(buf[:n])[:20])
        count, sstatus = ft.utf8_scan_fused(jnp.asarray(buf), n)
        assert int(sstatus) == want, trial
        bcount, bstatus = tc.scan_utf8(jnp.asarray(buf), n,
                                       strategy="blockparallel")
        assert int(sstatus) == int(bstatus) and int(count) == int(bcount)


def test_utf16_scan_status_matches_python():
    rng = np.random.default_rng(17)
    fixed = 1024
    for trial in range(12):
        buf = np.zeros(fixed, np.uint16)
        n = int(rng.integers(1, fixed))
        buf[:n] = rng.integers(0, 1 << 16, n)
        try:
            buf[:n].tobytes().decode("utf-16-le")
            want = -1
        except UnicodeDecodeError as e:
            want = e.start // 2
        count, status = ft.utf16_scan_fused(jnp.asarray(buf), n)
        assert int(status) == want, trial
        bcount, bstatus = tc.scan_utf16(jnp.asarray(buf), n,
                                        strategy="blockparallel")
        assert int(status) == int(bstatus) and int(count) == int(bcount)


# ---------------------------------------------------------------------------
# errors="replace": U+FFFD per maximal subpart, CPython semantics


def test_fused_replace_matches_python_utf8():
    rng = np.random.default_rng(5)
    fixed = 1536
    for trial in range(20):
        buf = np.zeros(fixed, np.uint8)
        if trial % 2:
            b = _utf8(["latin", "emoji", "arabic", "korean"][trial % 4],
                      400, seed=trial)[:fixed]
            buf[: len(b)] = b
            n = len(b)
            k = rng.integers(1, 5)
            buf[rng.integers(0, n, k)] = rng.integers(0, 256, k)
        else:
            n = int(rng.integers(1, fixed))
            buf[:n] = rng.integers(0, 256, n)
        want = np.frombuffer(
            bytes(buf[:n]).decode("utf-8", "replace").encode("utf-16-le"),
            np.uint16)
        got_f = _unpack(ft.utf8_to_utf16_fused(jnp.asarray(buf), n,
                                               errors="replace"))
        got_b = _unpack(tc.utf8_to_utf16(jnp.asarray(buf.astype(np.int32)),
                                         n, errors="replace"))
        assert np.array_equal(got_f[0], want), trial
        assert got_f[1] == got_b[1] == len(want), trial
        assert np.array_equal(got_b[0], want), trial
        assert got_f[2] == got_b[2] == _py_err_start(bytes(buf[:n])), trial


def test_fused_replace_matches_python_utf16():
    rng = np.random.default_rng(23)
    fixed = 1280
    for trial in range(16):
        buf = np.zeros(fixed, np.uint16)
        if trial % 2:
            u = _utf16(["latin", "emoji"][trial % 2], 400, seed=trial)[:fixed]
            buf[: len(u)] = u
            n = len(u)
            k = rng.integers(1, 4)
            # surrogate-heavy corruption: the interesting class here
            buf[rng.integers(0, n, k)] = rng.integers(0xD800, 0xE000, k)
        else:
            n = int(rng.integers(1, fixed))
            buf[:n] = rng.integers(0, 1 << 16, n)
        want = np.frombuffer(
            buf[:n].tobytes().decode("utf-16-le", "replace").encode("utf-8"),
            np.uint8)
        got_f = _unpack(ft.utf16_to_utf8_fused(jnp.asarray(buf), n,
                                               errors="replace"))
        got_b = _unpack(tc.utf16_to_utf8(jnp.asarray(buf.astype(np.int32)),
                                         n, errors="replace"))
        assert np.array_equal(got_f[0], want), trial
        assert got_f[1] == got_b[1] == len(want), trial
        assert np.array_equal(got_b[0], want), trial
        assert got_f[2] == got_b[2] == _py_err_start16(buf[:n]), trial


def test_error_location_and_replace_across_tile_boundary():
    """Maximal subparts straddling the 1024-byte tile boundary: the
    claimed-byte chain reads the previous tile, the continuation checks
    read the next — both must agree with Python at every offset."""
    probes = [b"\xf0\x9f\x92", b"\xe4\xb8", b"\xc3", b"\x80\x80",
              b"\xed\xa0\x80", b"\xf4\x90\x80\x80"]
    for probe in probes:
        for pos in (1019, 1021, 1022, 1023, 1024, 1025):
            buf = np.full(2048, 0x41, np.uint8)
            buf[pos: pos + len(probe)] = np.frombuffer(probe, np.uint8)
            raw = bytes(buf)
            _, _, status = ft.utf8_to_utf16_fused(jnp.asarray(buf), 2048)
            assert int(status) == _py_err_start(raw), (probe, pos)
            got = _unpack(ft.utf8_to_utf16_fused(jnp.asarray(buf), 2048,
                                                 errors="replace"))
            want = np.frombuffer(
                raw.decode("utf-8", "replace").encode("utf-16-le"),
                np.uint16)
            assert np.array_equal(got[0], want), (probe, pos)


def test_replace_on_valid_input_equals_strict():
    b = _utf8("japanese", 800, seed=9)
    n = len(b)
    strict = _unpack(ft.utf8_to_utf16_fused(jnp.asarray(b), n))
    rep = _unpack(ft.utf8_to_utf16_fused(jnp.asarray(b), n,
                                         errors="replace"))
    assert strict[1] == rep[1] and strict[2] == rep[2] == -1
    assert np.array_equal(strict[0], rep[0])


def test_unknown_errors_policy_rejected():
    b = jnp.zeros((8,), jnp.uint8)
    with pytest.raises(ValueError):
        ft.utf8_to_utf16_fused(b, 8, errors="ignore")
    with pytest.raises(ValueError):
        tc.transcode_utf8_to_utf16(b, 8, strategy="windowed",
                                   errors="replace")


# ---------------------------------------------------------------------------
# Batched entry + interpret auto-detection


def test_batched_entry_matches_per_doc():
    L = 1536
    langs = ["latin", "chinese", "emoji"]
    docs = np.zeros((3, L), np.uint8)
    lens = []
    for i, lang in enumerate(langs):
        d = _utf8(lang, 300, seed=i)[:L]
        docs[i, : len(d)] = d
        lens.append(len(d))
    lens = np.asarray(lens, np.int32)
    out, cnt, status = pipeline.batch_utf8_to_utf16(docs, lens)
    assert out.shape == (3, L)
    for i in range(3):
        o, c, s = ft.utf8_to_utf16_fused(jnp.asarray(docs[i]), int(lens[i]))
        assert int(cnt[i]) == int(c) and int(status[i]) == int(s)
        assert np.array_equal(np.asarray(out[i])[: int(c)],
                              np.asarray(o)[: int(c)])

    units = np.zeros((2, 1024), np.uint16)
    ulens = []
    for i, lang in enumerate(["korean", "latin"]):
        d = _utf16(lang, 300, seed=i)[:1024]
        units[i, : len(d)] = d
        ulens.append(len(d))
    out, cnt, status = pipeline.batch_utf16_to_utf8(units, np.asarray(ulens))
    assert out.shape == (2, 3 * 1024)
    for i in range(2):
        o, c, s = ft.utf16_to_utf8_fused(jnp.asarray(units[i]), ulens[i])
        assert int(cnt[i]) == int(c) and int(status[i]) == int(s)
        assert np.array_equal(np.asarray(out[i])[: int(c)],
                              np.asarray(o)[: int(c)])


def test_interpret_autodetect():
    # This container has no TPU: kernels must auto-select interpret mode
    # and still execute (interpret=None throughout the public wrappers).
    assert runtime.default_interpret() == (jax.default_backend() != "tpu")
    assert runtime.resolve_interpret(None) == runtime.default_interpret()
    assert runtime.resolve_interpret(False) is False
    b = np.frombuffer("héllo wörld".encode("utf-8"), np.uint8)
    assert bool(ops.validate_utf8(jnp.asarray(b.astype(np.int32)), len(b)))
    out, cnt, err = ops.utf8_to_utf16(jnp.asarray(b.astype(np.int32)), len(b))
    assert not bool(err)
