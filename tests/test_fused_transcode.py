"""Fused two-pass pipeline: strategy equivalence, edge cases, and
HBM-traffic shape checks (narrow ingress, no full-capacity int32 between
decode and compaction)."""

import numpy as np
import pytest

import jax
import jax.core
import jax.numpy as jnp

from repro.core import transcode as tc
from repro.data import pipeline, synthetic
from repro.kernels import fused_transcode as ft
from repro.kernels import ops, runtime

LIPSUM_LANGS = ["arabic", "chinese", "emoji", "hebrew", "hindi",
                "japanese", "korean", "latin", "russian"]


def _utf8(lang, n_chars, seed=0):
    return synthetic.utf8_array(lang, n_chars, seed)


def _utf16(lang, n_chars, seed=0):
    return synthetic.utf16_units(lang, n_chars, seed)


def _unpack(res):
    out, cnt, err = res
    return np.asarray(out)[: int(cnt)], int(cnt), bool(err)


# ---------------------------------------------------------------------------
# Equivalence on every benchmark corpus


@pytest.mark.parametrize("lang", LIPSUM_LANGS)
def test_fused_equals_blockparallel_and_windowed_utf8_to_utf16(lang):
    b = _utf8(lang, 1200, seed=11)
    n = len(b)
    got_f = _unpack(tc.transcode_utf8_to_utf16(
        jnp.asarray(b), n, strategy="fused"))
    got_b = _unpack(tc.transcode_utf8_to_utf16(
        jnp.asarray(b.astype(np.int32)), n, strategy="blockparallel"))
    got_w = _unpack(tc.transcode_utf8_to_utf16(
        jnp.asarray(b.astype(np.int32)), n, strategy="windowed"))
    assert got_f[1] == got_b[1] == got_w[1]
    assert np.array_equal(got_f[0], got_b[0])
    assert np.array_equal(got_f[0], got_w[0])
    assert got_f[2] == got_b[2] == got_w[2] is False
    # python oracle
    want = np.frombuffer(bytes(b).decode("utf-8").encode("utf-16-le"),
                         np.uint16)
    assert np.array_equal(got_f[0], want)


@pytest.mark.parametrize("lang", LIPSUM_LANGS)
def test_fused_equals_blockparallel_and_windowed_utf16_to_utf8(lang):
    u = _utf16(lang, 1200, seed=11)
    n = len(u)
    got_f = _unpack(tc.transcode_utf16_to_utf8(
        jnp.asarray(u), n, strategy="fused"))
    got_b = _unpack(tc.transcode_utf16_to_utf8(
        jnp.asarray(u.astype(np.int32)), n, strategy="blockparallel"))
    got_w = _unpack(tc.transcode_utf16_to_utf8(
        jnp.asarray(u.astype(np.int32)), n, strategy="windowed"))
    assert got_f[1] == got_b[1] == got_w[1]
    assert np.array_equal(got_f[0], got_b[0])
    assert np.array_equal(got_f[0], got_w[0])
    assert got_f[2] == got_b[2] == got_w[2] is False
    want = np.frombuffer(
        u.tobytes().decode("utf-16-le").encode("utf-8"), np.uint8)
    assert np.array_equal(got_f[0], want)


# ---------------------------------------------------------------------------
# Property test: random valid + mutated-invalid streams


def test_fused_equals_blockparallel_on_mutated_streams():
    rng = np.random.default_rng(7)
    langs = ["latin", "arabic", "chinese", "emoji"]
    fixed = 1536  # fixed buffer so all cases share one compilation
    for trial in range(24):
        b = _utf8(langs[trial % 4], 400, seed=trial)[:fixed]
        buf = np.zeros(fixed, np.uint8)
        buf[: len(b)] = b
        n = len(b)
        if trial % 3:  # two thirds of cases: corrupt 1-3 random bytes
            k = rng.integers(1, 4)
            buf[rng.integers(0, max(n, 1), k)] = rng.integers(0, 256, k)
        try:
            bytes(buf[:n]).decode("utf-8")
            valid = True
        except UnicodeDecodeError:
            valid = False
        got_f = _unpack(ft.utf8_to_utf16_fused(jnp.asarray(buf), n))
        got_b = _unpack(tc.utf8_to_utf16(
            jnp.asarray(buf.astype(np.int32)), n))
        assert got_f[1] == got_b[1], trial
        assert np.array_equal(got_f[0], got_b[0]), trial
        assert got_f[2] == got_b[2] == (not valid), trial


def test_fused_equals_blockparallel_on_mutated_utf16_streams():
    rng = np.random.default_rng(9)
    fixed = 1280
    for trial in range(16):
        u = _utf16(["latin", "emoji", "korean", "russian"][trial % 4],
                   400, seed=trial)[:fixed]
        buf = np.zeros(fixed, np.uint16)
        buf[: len(u)] = u
        n = len(u)
        if trial % 2:  # half the cases: corrupt 1-2 random units
            k = rng.integers(1, 3)
            buf[rng.integers(0, max(n, 1), k)] = \
                rng.integers(0, 1 << 16, k)
        try:
            buf[:n].tobytes().decode("utf-16-le")
            valid = True
        except UnicodeDecodeError:
            valid = False
        got_f = _unpack(ft.utf16_to_utf8_fused(jnp.asarray(buf), n))
        got_b = _unpack(tc.utf16_to_utf8(
            jnp.asarray(buf.astype(np.int32)), n))
        assert got_f[1] == got_b[1], trial
        assert np.array_equal(got_f[0], got_b[0]), trial
        assert got_f[2] == got_b[2] == (not valid), trial


# ---------------------------------------------------------------------------
# Edge cases


def test_fused_speculative_worst_case_stage_width():
    """Invalid input dense in 4-byte leads makes EVERY byte of a tile a
    speculative 2-unit lead (2*BLOCK units per tile) — the per-tile stage
    must absorb that or base offsets desynchronize from blockparallel."""
    b = np.concatenate([np.full(1024, 0xF4, np.uint8),
                        np.full(1024, 0xF1, np.uint8)])
    got_f = _unpack(ft.utf8_to_utf16_fused(jnp.asarray(b), len(b)))
    got_b = _unpack(tc.utf8_to_utf16(jnp.asarray(b.astype(np.int32)),
                                     len(b)))
    assert got_f[1] == got_b[1]
    assert np.array_equal(got_f[0], got_b[0])
    assert got_f[2] and got_b[2]
    # UTF-16 side: every unit a speculative 3-byte lane (valid stream of
    # U+E000) exactly fills the 3*BLOCK stage.
    u = np.full(2048, 0xE000, np.uint16)
    got_f = _unpack(ft.utf16_to_utf8_fused(jnp.asarray(u), len(u)))
    got_b = _unpack(tc.utf16_to_utf8(jnp.asarray(u.astype(np.int32)),
                                     len(u)))
    assert got_f[1] == got_b[1] == 3 * 2048
    assert np.array_equal(got_f[0], got_b[0])
    # VALID input overflow: a surrogate pair straddling the tile boundary
    # gives tile 0 a 4-byte lane with no compensating 0-lane in-tile, so
    # its total is 3*BLOCK + 1 — one past the naive stage bound.
    u = np.concatenate([np.full(1023, 0xE000, np.uint16),
                        np.asarray([0xD800, 0xDC00], np.uint16),
                        np.full(1023, 0x41, np.uint16)])
    got_f = _unpack(ft.utf16_to_utf8_fused(jnp.asarray(u), len(u)))
    got_b = _unpack(tc.utf16_to_utf8(jnp.asarray(u.astype(np.int32)),
                                     len(u)))
    want = np.frombuffer(
        u.tobytes().decode("utf-16-le").encode("utf-8"), np.uint8)
    assert got_f[1] == got_b[1] == len(want)
    assert np.array_equal(got_f[0], want)
    assert np.array_equal(got_b[0], want)
    assert not got_f[2] and not got_b[2]
    # and the unpaired-high-surrogate flood (mixed 3-byte/4-byte lanes)
    u = np.full(2048, 0xD800, np.uint16)
    got_f = _unpack(ft.utf16_to_utf8_fused(jnp.asarray(u), len(u)))
    got_b = _unpack(tc.utf16_to_utf8(jnp.asarray(u.astype(np.int32)),
                                     len(u)))
    assert got_f[1] == got_b[1]
    assert np.array_equal(got_f[0], got_b[0])
    assert got_f[2] and got_b[2]


def test_fused_zero_length():
    out, cnt, err = ft.utf8_to_utf16_fused(jnp.zeros((0,), jnp.uint8), 0)
    assert out.shape == (0,) and int(cnt) == 0 and not bool(err)
    out, cnt, err = ft.utf16_to_utf8_fused(jnp.zeros((0,), jnp.uint16), 0)
    assert out.shape == (0,) and int(cnt) == 0 and not bool(err)


def test_fused_n_valid_zero():
    b = jnp.asarray(np.full(64, 0xFF, np.uint8))  # garbage beyond n
    out, cnt, err = ft.utf8_to_utf16_fused(b, 0)
    assert int(cnt) == 0 and not bool(err)


def test_fused_tile_aligned_trailing_truncation():
    b = np.full(2048, 0x41, np.uint8)
    b[-1] = 0xC3  # lead byte truncated exactly at a tile boundary
    _, _, err = ft.utf8_to_utf16_fused(jnp.asarray(b), 2048)
    assert bool(err)
    u = np.full(1024, 0x41, np.uint16)
    u[-1] = 0xD800  # lone high surrogate at the tile boundary
    _, _, err = ft.utf16_to_utf8_fused(jnp.asarray(u), 1024)
    assert bool(err)


def test_fused_cross_tile_characters():
    s = "A" * 1022 + "🎉" + "B" * 100  # 4-byte char straddles the boundary
    b = np.frombuffer(s.encode("utf-8"), np.uint8)
    out, cnt, err = ft.utf8_to_utf16_fused(jnp.asarray(b), len(b))
    want = np.frombuffer(s.encode("utf-16-le"), np.uint16)
    assert not bool(err)
    assert np.array_equal(np.asarray(out)[: int(cnt)], want)

    u = np.full(2048, 0x41, np.int32)
    u[1023], u[1024] = 0xD83C, 0xDF89  # pair straddles the boundary
    out, cnt, err = ft.utf16_to_utf8_fused(jnp.asarray(u), 2048)
    want = np.frombuffer(
        u.astype(np.uint16).tobytes().decode("utf-16-le").encode("utf-8"),
        np.uint8)
    assert not bool(err)
    assert np.array_equal(np.asarray(out)[: int(cnt)], want)


def test_fused_ascii_fastpath_agrees_with_general():
    b = _utf8("latin", 500, seed=3)
    n = len(b)
    fast = _unpack(ft.utf8_to_utf16_fused(jnp.asarray(b), n))
    slow = _unpack(ft.utf8_to_utf16_fused(jnp.asarray(b), n,
                                          ascii_fastpath=False))
    assert fast[1] == slow[1] and fast[2] == slow[2]
    assert np.array_equal(fast[0], slow[0])


# ---------------------------------------------------------------------------
# HBM-traffic shape checks (acceptance: narrow ingress, nothing
# full-capacity int32 between decode and compaction)


def _iter_eqns(jaxpr, into_pallas=False):
    """All eqns of a jaxpr, recursing into sub-jaxprs (cond branches,
    pjit bodies, scans) but NOT into pallas_call kernel bodies unless
    asked: in-kernel VMEM ops are not HBM traffic."""
    for eqn in jaxpr.eqns:
        yield eqn
        if eqn.primitive.name == "pallas_call" and not into_pallas:
            continue
        for v in eqn.params.values():
            for sub in _sub_jaxprs(v):
                yield from _iter_eqns(sub, into_pallas)


def _sub_jaxprs(v):
    if isinstance(v, jax.core.ClosedJaxpr):
        yield v.jaxpr
    elif isinstance(v, jax.core.Jaxpr):
        yield v
    elif isinstance(v, (tuple, list)):
        for item in v:
            yield from _sub_jaxprs(item)


def _pallas_eqns(jaxpr):
    return [e for e in _iter_eqns(jaxpr) if e.primitive.name == "pallas_call"]


def test_fused_utf8_jaxpr_has_narrow_io_and_no_global_scatter():
    cap = 4096
    b = jnp.zeros((cap,), jnp.uint8)
    jaxpr = jax.make_jaxpr(
        lambda x: ft.utf8_to_utf16_fused(x, cap - 5, ascii_fastpath=False)
    )(b).jaxpr
    kernels = _pallas_eqns(jaxpr)
    assert len(kernels) == 2  # count pass + write pass
    for eqn in kernels:
        # Ingress <= 1 byte/element: every large operand is uint8.
        for v in eqn.invars:
            if v.aval.size >= cap:
                assert v.aval.dtype.itemsize == 1, (v.aval,)
        # Between decode and compaction nothing full-capacity and int32
        # leaves the kernel: outputs are per-tile scalars or narrow lanes.
        for v in eqn.outvars:
            assert v.aval.dtype.itemsize <= 2 or v.aval.size < cap // 256, \
                (v.aval,)
    # Global compaction is gone: no scatter outside the kernels.
    names = {e.primitive.name for e in _iter_eqns(jaxpr)}
    assert not any("scatter" in n for n in names), names


def test_fused_utf16_jaxpr_has_narrow_io_and_no_global_scatter():
    cap_in = 2048
    u = jnp.zeros((cap_in,), jnp.uint16)
    jaxpr = jax.make_jaxpr(
        lambda x: ft.utf16_to_utf8_fused(x, cap_in - 5, ascii_fastpath=False)
    )(u).jaxpr
    kernels = _pallas_eqns(jaxpr)
    assert len(kernels) == 2
    for eqn in kernels:
        for v in eqn.invars:
            if v.aval.size >= cap_in:
                assert v.aval.dtype.itemsize <= 2, (v.aval,)
        for v in eqn.outvars:
            assert v.aval.dtype.itemsize <= 2 or v.aval.size < cap_in // 256, \
                (v.aval,)
    names = {e.primitive.name for e in _iter_eqns(jaxpr)}
    assert not any("scatter" in n for n in names), names


def test_blockparallel_kernel_path_is_the_contrast():
    """The pre-fusion kernel path DOES ship full-capacity int32 decode
    outputs through HBM — the discriminating contrast for the test above."""
    cap = 4096
    b = jnp.zeros((cap,), jnp.int32)
    jaxpr = jax.make_jaxpr(
        lambda x: ops.utf8_to_utf16(x, cap - 5, validate=False))(b).jaxpr
    wide = [
        v for e in _pallas_eqns(jaxpr) for v in e.outvars
        if v.aval.dtype.itemsize == 4 and v.aval.size >= cap
    ]
    assert wide, "expected full-capacity int32 outputs on the legacy path"


# ---------------------------------------------------------------------------
# Batched entry + interpret auto-detection


def test_batched_entry_matches_per_doc():
    L = 1536
    langs = ["latin", "chinese", "emoji"]
    docs = np.zeros((3, L), np.uint8)
    lens = []
    for i, lang in enumerate(langs):
        d = _utf8(lang, 300, seed=i)[:L]
        docs[i, : len(d)] = d
        lens.append(len(d))
    lens = np.asarray(lens, np.int32)
    out, cnt, err = pipeline.batch_utf8_to_utf16(docs, lens)
    assert out.shape == (3, L)
    for i in range(3):
        o, c, e = ft.utf8_to_utf16_fused(jnp.asarray(docs[i]), int(lens[i]))
        assert int(cnt[i]) == int(c) and bool(err[i]) == bool(e)
        assert np.array_equal(np.asarray(out[i])[: int(c)],
                              np.asarray(o)[: int(c)])

    units = np.zeros((2, 1024), np.uint16)
    ulens = []
    for i, lang in enumerate(["korean", "latin"]):
        d = _utf16(lang, 300, seed=i)[:1024]
        units[i, : len(d)] = d
        ulens.append(len(d))
    out, cnt, err = pipeline.batch_utf16_to_utf8(units, np.asarray(ulens))
    assert out.shape == (2, 3 * 1024)
    for i in range(2):
        o, c, e = ft.utf16_to_utf8_fused(jnp.asarray(units[i]), ulens[i])
        assert int(cnt[i]) == int(c) and bool(err[i]) == bool(e)
        assert np.array_equal(np.asarray(out[i])[: int(c)],
                              np.asarray(o)[: int(c)])


def test_interpret_autodetect():
    # This container has no TPU: kernels must auto-select interpret mode
    # and still execute (interpret=None throughout the public wrappers).
    assert runtime.default_interpret() == (jax.default_backend() != "tpu")
    assert runtime.resolve_interpret(None) == runtime.default_interpret()
    assert runtime.resolve_interpret(False) is False
    b = np.frombuffer("héllo wörld".encode("utf-8"), np.uint8)
    assert bool(ops.validate_utf8(jnp.asarray(b.astype(np.int32)), len(b)))
    out, cnt, err = ops.utf8_to_utf16(jnp.asarray(b.astype(np.int32)), len(b))
    assert not bool(err)
