"""Serving engine: ingress validation, batched decode, egress encodings."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.models import registry
from repro.serve import kvcache
from repro.serve.engine import Engine, Request


@pytest.fixture(scope="module")
def engine():
    fam, cfg, model = registry.get("bytelm-100m", reduced=True)
    params = model.init(jax.random.PRNGKey(0))
    return Engine(model, cfg, fam, params, max_batch=4, max_prompt=64,
                  max_new=8)


def test_valid_prompts_served(engine):
    res = engine.serve([Request(b"hello"), Request("café 中".encode())])
    assert all(r.ok for r in res)


def test_invalid_utf8_rejected(engine):
    res = engine.serve([Request(b"\xff\xfe bad \x80")])
    assert not res[0].ok and "invalid" in res[0].error


def test_oversize_rejected(engine):
    res = engine.serve([Request(b"x" * 1000)])
    assert not res[0].ok


def test_utf16_egress_consistent(engine):
    """Same generation, both encodings: UTF-16 output must transcode back
    to the UTF-8 output (egress goes through the paper's encoder)."""
    r8 = engine.serve([Request(b"abc")])[0]
    r16 = engine.serve([Request(b"abc", out_encoding="utf-16-le")])[0]
    assert r8.ok and r16.ok
    if r8.text_bytes:
        try:
            s8 = r8.text_bytes.decode("utf-8")
            s16 = r16.text_bytes.decode("utf-16-le")
            assert s8 == s16
        except UnicodeDecodeError:
            pass  # untrained byte model may emit invalid sequences


def test_batch_equals_individual(engine):
    """Batched serving must give the same tokens as one-at-a-time."""
    prompts = [b"aa", b"bbbb", b"c"]
    batched = engine.serve([Request(p) for p in prompts])
    single = [engine.serve([Request(p)])[0] for p in prompts]
    for b, s in zip(batched, single):
        assert b.text_bytes == s.text_bytes


def test_ring_cache_wraps():
    """SWA ring cache: decoding past the window stays finite & bounded."""
    fam, cfg, model = registry.get("h2o-danube-1.8b", reduced=True)
    params = model.init(jax.random.PRNGKey(1))
    cap = kvcache.capacity_for(cfg, 1000)
    assert cap == cfg.window  # ring buffer, not full context
    state = kvcache.init_state(model, cfg, 1, 1000)
    from repro.serve import serve_step
    dec = jax.jit(serve_step.make_decode(model, fam))
    tok = jnp.array([[5]], jnp.int32)
    key = jax.random.PRNGKey(0)
    for pos in range(0, 40):  # window is 16 in reduced config
        nxt, logits, state = dec(params, tok, jnp.array([pos]), state, key)
        assert bool(jnp.isfinite(logits).all())
    # cache capacity never grew (check the 5-D stacked K/V leaves)
    kv_leaves = [l for l in jax.tree.leaves(state) if l.ndim == 5]
    assert kv_leaves and all(l.shape[2] == cfg.window for l in kv_leaves)


def test_state_bytes_planner():
    _, cfg_full, _ = registry.get("qwen2.5-32b")
    full = kvcache.state_bytes(cfg_full, batch=128, context_len=32768)
    assert full > 1e11   # ~1.1 TB global KV for decode_32k
    _, cfg_mamba, _ = registry.get("falcon-mamba-7b")
    m = kvcache.state_bytes(cfg_mamba, batch=1, context_len=524288)
    assert m < 1e9       # SSM state independent of context
