"""Serving engine: ingress validation, batched decode, egress encodings,
continuous-batching scheduler and the submit/poll surface."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import packing
from repro.models import registry
from repro.serve import kvcache
from repro.serve.engine import Engine, Request, ResultCode


@pytest.fixture(scope="module")
def engine():
    fam, cfg, model = registry.get("bytelm-100m", reduced=True)
    params = model.init(jax.random.PRNGKey(0))
    return Engine(model, cfg, fam, params, max_batch=4, max_prompt=64,
                  max_new=8)


def test_valid_prompts_served(engine):
    res = engine.serve([Request(b"hello"), Request("café 中".encode())])
    assert all(r.ok for r in res)


def test_invalid_utf8_rejected(engine):
    res = engine.serve([Request(b"\xff\xfe bad \x80")])
    assert not res[0].ok and "invalid" in res[0].error
    assert res[0].error_offset == 0  # 0xFF is the first bad byte


def test_truncated_multibyte_strict_reports_offset(engine):
    """errors='strict' (default): truncated sequences reject with the
    first-error offset, matching Python's UnicodeDecodeError.start."""
    for prompt in [b"hi \xe4\xb8", b"abc\xc3", b"xy\xf0\x9f\x98"]:
        try:
            prompt.decode("utf-8")
            raise AssertionError("expected invalid prompt")
        except UnicodeDecodeError as e:
            want = e.start
        res = engine.serve([Request(prompt)])[0]
        assert not res.ok and "invalid" in res.error
        assert res.error_offset == want, (prompt, res.error_offset, want)


def test_truncated_multibyte_replace_served(engine):
    """errors='replace': malformed prompts are sanitized (U+FFFD per
    maximal subpart) and served, with the substitution offset surfaced."""
    prompt = b"hi \xe4\xb8 there"
    res = engine.serve([Request(prompt, errors="replace")])[0]
    assert res.ok
    assert res.error_offset == 3
    assert res.sanitized_prompt == prompt.decode(
        "utf-8", "replace").encode("utf-8")
    assert b"\xef\xbf\xbd" in res.sanitized_prompt  # U+FFFD in output
    # A clean prompt under replace carries no substitution report.
    res = engine.serve([Request(b"clean", errors="replace")])[0]
    assert res.ok and res.error_offset == -1 and res.sanitized_prompt == b""


def test_lone_surrogate_utf16_strict_reports_offset(engine):
    units = np.array([0x41, 0xD800, 0x42], np.uint16)  # A, lone hi, B
    res = engine.serve([Request(units.tobytes(),
                                in_encoding="utf-16-le")])[0]
    assert not res.ok and "invalid" in res.error
    assert res.error_offset == 1  # unit offset, exc.start // 2
    # trailing lone surrogate (truncated pair)
    units = np.array([0x41, 0xD83C], np.uint16)
    res = engine.serve([Request(units.tobytes(),
                                in_encoding="utf-16-le")])[0]
    assert not res.ok and res.error_offset == 1


def test_lone_surrogate_utf16_replace_served(engine):
    units = np.array([0x41, 0xDC00, 0x42], np.uint16)  # lone low half
    res = engine.serve([Request(units.tobytes(), in_encoding="utf-16-le",
                                errors="replace")])[0]
    assert res.ok
    assert res.error_offset == 1
    want = units.tobytes().decode("utf-16-le", "replace").encode("utf-8")
    assert res.sanitized_prompt == want
    assert b"\xef\xbf\xbd" in res.sanitized_prompt


def test_valid_utf16_prompt_equals_utf8_prompt(engine):
    """A valid UTF-16LE prompt tokenizes identically to its UTF-8 twin
    (the fused transcode is the ingress tokenizer's source)."""
    s = "hé🎉"
    r8 = engine.serve([Request(s.encode("utf-8"))])[0]
    r16 = engine.serve([Request(s.encode("utf-16-le"),
                                in_encoding="utf-16-le")])[0]
    assert r8.ok and r16.ok
    assert r8.text_bytes == r16.text_bytes


def test_odd_utf16_byte_length_rejected(engine):
    res = engine.serve([Request(b"\x41\x00\x42", in_encoding="utf-16-le")])[0]
    assert not res.ok and "odd" in res.error


def test_oversize_rejected(engine):
    res = engine.serve([Request(b"x" * 1000)])
    assert not res[0].ok


def test_utf16_egress_consistent(engine):
    """Same generation, both encodings: UTF-16 output must transcode back
    to the UTF-8 output (egress goes through the paper's encoder)."""
    r8 = engine.serve([Request(b"abc")])[0]
    r16 = engine.serve([Request(b"abc", out_encoding="utf-16-le")])[0]
    assert r8.ok and r16.ok
    if r8.text_bytes:
        try:
            s8 = r8.text_bytes.decode("utf-8")
            s16 = r16.text_bytes.decode("utf-16-le")
            assert s8 == s16
        except UnicodeDecodeError:
            pass  # untrained byte model may emit invalid sequences


def test_batch_equals_individual(engine):
    """Batched serving must give the same tokens as one-at-a-time."""
    prompts = [b"aa", b"bbbb", b"c"]
    batched = engine.serve([Request(p) for p in prompts])
    single = [engine.serve([Request(p)])[0] for p in prompts]
    for b, s in zip(batched, single):
        assert b.text_bytes == s.text_bytes


def test_ring_cache_wraps():
    """SWA ring cache: decoding past the window stays finite & bounded."""
    fam, cfg, model = registry.get("h2o-danube-1.8b", reduced=True)
    params = model.init(jax.random.PRNGKey(1))
    cap = kvcache.capacity_for(cfg, 1000)
    assert cap == cfg.window  # ring buffer, not full context
    state = kvcache.init_state(model, cfg, 1, 1000)
    from repro.serve import serve_step
    dec = jax.jit(serve_step.make_decode(model, fam))
    tok = jnp.array([[5]], jnp.int32)
    key = jax.random.PRNGKey(0)
    for pos in range(0, 40):  # window is 16 in reduced config
        nxt, logits, state = dec(params, tok, jnp.array([pos]), state, key)
        assert bool(jnp.isfinite(logits).all())
    # cache capacity never grew (check the 5-D stacked K/V leaves)
    kv_leaves = [l for l in jax.tree.leaves(state) if l.ndim == 5]
    assert kv_leaves and all(l.shape[2] == cfg.window for l in kv_leaves)


def test_state_bytes_planner():
    _, cfg_full, _ = registry.get("qwen2.5-32b")
    full = kvcache.state_bytes(cfg_full, batch=128, context_len=32768)
    assert full > 1e11   # ~1.1 TB global KV for decode_32k
    _, cfg_mamba, _ = registry.get("falcon-mamba-7b")
    m = kvcache.state_bytes(cfg_mamba, batch=1, context_len=524288)
    assert m < 1e9       # SSM state independent of context


def test_matrix_utf32le_ingress(engine):
    """UTF-32LE prompts: validated/transcoded through the (utf32, utf8)
    matrix cell; identical tokens to the UTF-8 twin."""
    s = "hé🎉"
    r8 = engine.serve([Request(s.encode("utf-8"))])[0]
    r32 = engine.serve([Request(s.encode("utf-32-le"),
                                in_encoding="utf-32-le")])[0]
    assert r8.ok and r32.ok
    assert r8.text_bytes == r32.text_bytes
    # invalid scalar (lone surrogate) rejects with its code-point offset
    bad = np.array([0x41, 0xD800, 0x42], "<u4").tobytes()
    res = engine.serve([Request(bad, in_encoding="utf-32-le")])[0]
    assert not res.ok and "invalid" in res.error
    assert res.error_offset == 1
    # ...and serves sanitized under errors="replace"
    res = engine.serve([Request(bad, in_encoding="utf-32-le",
                                errors="replace")])[0]
    assert res.ok and res.error_offset == 1
    assert res.sanitized_prompt == "A�B".encode("utf-8")
    # ragged byte count rejects
    res = engine.serve([Request(b"\x41\x00\x00", in_encoding="utf-32-le")])[0]
    assert not res.ok and "multiple of 4" in res.error


def test_matrix_latin1_ingress(engine):
    """Latin-1 prompts can never be invalid; bytes >= 0x80 widen to
    2-byte UTF-8 sequences before tokenization."""
    s = "café ÿ"
    r8 = engine.serve([Request(s.encode("utf-8"))])[0]
    rl1 = engine.serve([Request(s.encode("latin-1"),
                                in_encoding="latin-1")])[0]
    assert r8.ok and rl1.ok
    assert r8.text_bytes == rl1.text_bytes
    # arbitrary bytes are a valid latin-1 prompt (incl. 0x80..0x9F)
    res = engine.serve([Request(bytes(range(1, 40)) + b"\x80\xff",
                                in_encoding="latin-1")])[0]
    assert res.ok and res.error_offset == -1


def _fresh_engine(**kw):
    fam, cfg, model = registry.get("bytelm-100m", reduced=True)
    params = model.init(jax.random.PRNGKey(0))
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_prompt", 64)
    kw.setdefault("max_new", 8)
    return Engine(model, cfg, fam, params, **kw)


def test_submit_poll_lifecycle(engine):
    t = engine.submit(Request(b"hello"))
    assert isinstance(t, int)
    assert engine.poll(t) is None          # queued, not yet drained
    engine.drain()
    res = engine.poll(t)
    assert res is not None and res.ok and res.code is ResultCode.OK
    assert engine.poll(t) is None          # poll consumes the result
    assert t in engine.latencies and engine.latencies[t] >= 0.0


def test_submit_invalid_settles_before_drain(engine):
    t = engine.submit(Request(b""))        # empty prompt: field check
    res = engine.poll(t)                   # no drain() needed
    assert res is not None and not res.ok
    assert res.code is ResultCode.REJECTED_INVALID


def test_serve_shim_matches_submit_poll(engine):
    prompts = [b"aa", b"bbbb", b"c"]
    shim = engine.serve([Request(p) for p in prompts])
    tickets = [engine.submit(Request(p)) for p in prompts]
    engine.drain()
    direct = [engine.poll(t) for t in tickets]
    for s, d in zip(shim, direct):
        assert s.ok and d.ok and s.text_bytes == d.text_bytes


def test_scheduler_param_validated():
    with pytest.raises(ValueError, match="scheduler"):
        _fresh_engine(scheduler="batch")


def test_bucket_boundaries():
    bounds = packing.bucket_boundaries(64)
    assert bounds == (8, 12, 18, 27, 40, 60, 64)
    assert bounds == tuple(sorted(set(bounds)))    # strictly increasing
    assert packing.bucket_boundaries(4) == (4,)
    assert packing.bucket_boundaries(9, min_length=8) == (8, 9)
    with pytest.raises(ValueError):
        packing.bucket_boundaries(0)
    with pytest.raises(ValueError):
        packing.bucket_boundaries(64, step=1.0)


def test_continuous_refill_mid_wave():
    """THE continuous-batching pin: with both slots taken and one request
    queued, the slot whose request finishes first must re-admit the
    queued request mid-wave, while its batch-mate is still decoding."""
    e = _fresh_engine(scheduler="continuous")
    ta = e.submit(Request(b"aaaa", max_new=2))     # finishes early
    tb = e.submit(Request(b"bbbb", max_new=8))     # decodes the tail
    tc_ = e.submit(Request(b"cccc", max_new=2))    # queued: both slots busy
    e.drain()
    assert all(e.poll(t).ok for t in (ta, tb, tc_))
    ev = {(kind, t): (slot, step)
          for kind, t, slot, step, _wall in e.events}
    assert ev[("admit", ta)][1] == ev[("admit", tb)][1] == 0
    finish_a = ev[("finish", ta)]
    finish_b = ev[("finish", tb)]
    admit_c = ev[("admit", tc_)]
    assert finish_a[1] < finish_b[1]               # a really is shorter
    # Mid-wave: c admitted BEFORE b finished, into a's freed slot.
    assert admit_c[1] < finish_b[1]
    assert admit_c[0] == finish_a[0]


def test_deadline_expiry_during_refill_ingress_frees_slot():
    """A queued request whose deadline expires BETWEEN its queue pop and
    its ingress completing (ingress is the slow path: retries, host
    fallback) must settle ``rejected_deadline`` from the post-ingress
    re-check WITHOUT consuming the freed slot — the slot goes to the
    next queued request, mid-wave, while the batch-mate still decodes."""
    now = [0.0]
    e = _fresh_engine(scheduler="continuous", clock=lambda: now[0],
                      sleep=lambda s: None)
    calls = [0]
    orig = e._ingress_chunk

    def slow_after_first(group, bound, take):
        calls[0] += 1
        if calls[0] > 1:
            now[0] += 5.0              # refill ingress "takes" 5s
        return orig(group, bound, take)

    e._ingress_chunk = slow_after_first
    ta = e.submit(Request(b"aaaa", max_new=2))    # frees its slot early
    tb = e.submit(Request(b"bbbb", max_new=8))    # still decoding then
    tc_ = e.submit(Request(b"cccc", max_new=2, deadline_s=2.0))
    td = e.submit(Request(b"dddd", max_new=2))    # should get a's slot
    e.drain()
    assert e.poll(ta).ok and e.poll(tb).ok and e.poll(td).ok
    rc = e.poll(tc_)
    assert not rc.ok and rc.code is ResultCode.REJECTED_DEADLINE
    assert e.counters["deadline"] == 1
    ev = {(kind, t): (slot, step)
          for kind, t, slot, step, _wall in e.events}
    assert ("admit", tc_) not in ev               # never took a slot
    reject_c = ev[("reject", tc_)]
    assert reject_c[0] == -1                      # slotless rejection
    finish_a, finish_b = ev[("finish", ta)], ev[("finish", tb)]
    admit_d = ev[("admit", td)]
    # Ordering pin: a frees its slot, c's pop+ingress expires it, then d
    # is admitted into THAT slot — all while b is still mid-decode.
    assert finish_a[1] <= reject_c[1] <= admit_d[1] < finish_b[1]
    assert admit_d[0] == finish_a[0]


def test_wave_scheduler_defers_refill():
    """The wave reference: the queued request is only admitted once the
    WHOLE wave drained — pinning that the schedulers actually differ."""
    e = _fresh_engine(scheduler="wave")
    ta = e.submit(Request(b"aaaa", max_new=2))
    tb = e.submit(Request(b"bbbb", max_new=8))
    tc_ = e.submit(Request(b"cccc", max_new=2))
    e.drain()
    assert all(e.poll(t).ok for t in (ta, tb, tc_))
    ev = {(kind, t): (slot, step)
          for kind, t, slot, step, _wall in e.events}
    assert ev[("admit", tc_)][1] >= ev[("finish", tb)][1]


def test_refilled_slot_inherits_nothing():
    """A request served through a refilled slot must generate the same
    tokens as the same request served alone — full-row state replacement
    leaves nothing of the predecessor behind."""
    alone = _fresh_engine(scheduler="continuous")
    want = alone.serve([Request(b"cccc", max_new=4)])[0]
    e = _fresh_engine(scheduler="continuous")
    res = e.serve([Request(b"aaaa", max_new=2),
                   Request(b"bbbb", max_new=8),
                   Request(b"cccc", max_new=4)])
    assert res[2].ok and res[2].text_bytes == want.text_bytes


def test_bucketed_prefill_shares_compile_cell():
    """Prompts in the same length bucket pad to the bucket bound: one
    prefill cell, not one per distinct prompt length."""
    e = _fresh_engine()
    res = e.serve([Request(b"abc"), Request(b"abcdefg")])   # both <= 8
    assert all(r.ok for r in res)
    prefill_cells = [k for k in e._cells if k[0] == "prefill"]
    assert prefill_cells == [("prefill", 8)]


def test_compile_cache_lru_bounded():
    e = _fresh_engine(compile_cache_size=2)
    res = e.serve([Request(b"ab"), Request(b"x" * 20), Request(b"y" * 35)])
    assert all(r.ok for r in res)
    assert len(e._cells) <= 2


def test_matrix_egress_encodings(engine):
    """Same generation in all four egress encodings: each wire form must
    decode back to the same text (latin-1 may substitute '?')."""
    res = {enc: engine.serve([Request(b"abc", out_encoding=enc)])[0]
           for enc in ("utf-8", "utf-16-le", "utf-32-le", "latin-1")}
    assert all(r.ok for r in res.values())
    if res["utf-8"].text_bytes:
        try:
            s8 = res["utf-8"].text_bytes.decode("utf-8")
        except UnicodeDecodeError:
            return  # untrained byte model may emit invalid sequences
        assert res["utf-16-le"].text_bytes.decode("utf-16-le") == s8
        assert res["utf-32-le"].text_bytes.decode("utf-32-le") == s8
        want_l1 = s8.encode("latin-1", "replace")
        assert res["latin-1"].text_bytes == want_l1
