"""API-contract suite for the public surface (DESIGN.md §11).

Pins the redesigned API shape itself, not behavior: everything in
``repro.__all__`` imports; the generic entry points keep their
keyword-only configuration knobs; every deprecated per-pair wrapper
warns exactly once and stays bit-identical to the generic call it
delegates to; and no ``src/`` module calls a deprecated name (the
CI tier-1 jobs additionally enforce that last one at runtime with
``-W error::DeprecationWarning:repro``).
"""

import inspect
import pathlib
import re
import warnings

import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro
from repro.core import packing, transcode as tc
from repro.serve import engine as eng

SRC = pathlib.Path(__file__).resolve().parents[1] / "src"

S = "héllo ωorld \U0001F600 ok"
B8 = jnp.asarray(np.frombuffer(S.encode("utf-8"), np.uint8)
                 .astype(np.int32))
U16 = jnp.asarray(np.frombuffer(S.encode("utf-16-le"), np.uint16)
                  .astype(np.int32))
CP32 = jnp.asarray(np.frombuffer(S.encode("utf-32-le"), np.uint32)
                   .astype(np.int32))
L1 = jnp.asarray(np.frombuffer("héllo".encode("latin-1"), np.uint8)
                 .astype(np.int32))
# Latin-1-encodable UTF-8 (every code point <= U+00FF).
B8L = jnp.asarray(np.frombuffer("héllo".encode("utf-8"), np.uint8)
                  .astype(np.int32))

_PK8 = packing.pack_documents([b"hi", "ωorld".encode("utf-8")])
_PK16 = packing.pack_documents(
    [np.frombuffer(s.encode("utf-16-le"), np.uint16) for s in ("hi", "ωo")])
RAGGED8 = (jnp.asarray(_PK8.data), jnp.asarray(_PK8.offsets),
           jnp.asarray(_PK8.lengths))
RAGGED16 = (jnp.asarray(_PK16.data), jnp.asarray(_PK16.offsets),
            jnp.asarray(_PK16.lengths))

# Every deprecated shim with the generic call it must match bit-for-bit
# (including each shim's HISTORICAL default strategy).
SHIM_CASES = {
    "utf8_to_utf16": ((B8,), lambda: tc.transcode(
        B8, "utf16", src_format="utf8", strategy="blockparallel")),
    "utf8_to_utf32": ((B8,), lambda: tc.transcode(
        B8, "utf32", src_format="utf8", strategy="blockparallel")),
    "utf8_to_latin1": ((B8L,), lambda: tc.transcode(
        B8L, "latin1", src_format="utf8", strategy="fused")),
    "latin1_to_utf8": ((L1,), lambda: tc.transcode(
        L1, "utf8", src_format="latin1", strategy="fused")),
    "latin1_to_utf16": ((L1,), lambda: tc.transcode(
        L1, "utf16", src_format="latin1", strategy="fused")),
    "utf16_to_utf8": ((U16,), lambda: tc.transcode(
        U16, "utf8", src_format="utf16", strategy="blockparallel")),
    "utf16_to_utf32": ((U16,), lambda: tc.transcode(
        U16, "utf32", src_format="utf16", strategy="blockparallel")),
    "utf32_to_utf8": ((CP32,), lambda: tc.transcode(
        CP32, "utf8", src_format="utf32", strategy="blockparallel")),
    "utf32_to_utf16": ((CP32,), lambda: tc.transcode(
        CP32, "utf16", src_format="utf32", strategy="blockparallel")),
    "transcode_utf8_to_utf16": ((B8,), lambda: tc.transcode(
        B8, "utf16", src_format="utf8")),
    "transcode_utf16_to_utf8": ((U16,), lambda: tc.transcode(
        U16, "utf8", src_format="utf16")),
    "scan_utf8": ((B8,), lambda: tc.scan(B8, "utf16", src_format="utf8")),
    "scan_utf16": ((U16,), lambda: tc.scan(U16, "utf8",
                                           src_format="utf16")),
    "ragged_utf8_to_utf16": (RAGGED8, lambda: tc.ragged_transcode(
        *RAGGED8, src_format="utf8", dst_format="utf16")),
    "ragged_utf16_to_utf8": (RAGGED16, lambda: tc.ragged_transcode(
        *RAGGED16, src_format="utf16", dst_format="utf8")),
    "ragged_scan_utf8": (RAGGED8, lambda: tc.ragged_scan(
        *RAGGED8, src_format="utf8", dst_format="utf16")),
    "ragged_scan_utf16": (RAGGED16, lambda: tc.ragged_scan(
        *RAGGED16, src_format="utf16", dst_format="utf8")),
}


def test_every_public_name_imports():
    for name in repro.__all__:
        assert getattr(repro, name) is not None, name
    assert set(repro.__all__) <= set(dir(repro))


def test_public_symbols_are_canonical_objects():
    # The lazy exports must BE the defining modules' objects, not copies.
    assert repro.transcode is tc.transcode
    assert repro.ragged_scan is tc.ragged_scan
    assert repro.Engine is eng.Engine
    assert repro.ResultCode is eng.ResultCode


def test_unknown_attribute_raises():
    with pytest.raises(AttributeError):
        repro.utf8_to_utf16  # per-pair wrappers are NOT public


@pytest.mark.parametrize("fn,kwonly", [
    (tc.transcode, {"src_format", "n_valid", "strategy", "validate",
                    "errors"}),
    (tc.scan, {"src_format", "n_valid", "strategy"}),
    (tc.ragged_transcode, {"src_format", "dst_format", "validate",
                           "errors", "strategy"}),
    (tc.ragged_scan, {"src_format", "dst_format"}),
])
def test_generic_entry_points_keyword_only(fn, kwonly):
    params = inspect.signature(fn).parameters
    for name in kwonly:
        assert params[name].kind is inspect.Parameter.KEYWORD_ONLY, \
            f"{fn.__name__}(..., {name}=) must be keyword-only"


def test_stream_entry_point_keyword_only():
    params = inspect.signature(repro.transcode_stream).parameters
    for name in ("src_format", "dst_format", "errors", "validate"):
        assert params[name].kind is inspect.Parameter.KEYWORD_ONLY, name


def test_deprecated_registry_is_complete():
    assert set(SHIM_CASES) == set(tc.DEPRECATED)


@pytest.mark.parametrize("name", sorted(SHIM_CASES))
def test_shim_warns_once_and_matches_generic(name):
    args, generic = SHIM_CASES[name]
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        got = getattr(tc, name)(*args)
    dep = [w for w in caught if issubclass(w.category, DeprecationWarning)]
    assert len(dep) == 1, f"{name}: expected exactly one warning, " \
                          f"got {[str(w.message) for w in dep]}"
    assert name in str(dep[0].message)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        want = generic()              # the generic path must NOT warn
    got_l, want_l = jax.tree.leaves(got), jax.tree.leaves(want)
    assert len(got_l) == len(want_l), name
    for g, w in zip(got_l, want_l):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w),
                                      err_msg=name)


def test_no_src_module_calls_deprecated_names():
    # Module-qualified calls/imports only: kernels/ops.py legitimately
    # defines same-named KERNEL entry points at a lower layer.
    names = "|".join(tc.DEPRECATED)
    call = re.compile(rf"\b(?:tc|transcode)\.({names})\s*\(")
    imp = re.compile(
        rf"from\s+repro\.core\.transcode\s+import\s+.*\b({names})\b")
    offenders = []
    for path in sorted(SRC.rglob("*.py")):
        if path.samefile(SRC / "repro" / "core" / "transcode.py"):
            continue                  # the shims' own definition site
        for i, line in enumerate(path.read_text().splitlines(), 1):
            if call.search(line) or imp.search(line):
                offenders.append(f"{path.relative_to(SRC)}:{i}: "
                                 f"{line.strip()}")
    assert not offenders, \
        "src/ modules must use the generic API:\n" + "\n".join(offenders)


def test_result_codes_are_enum_and_strings():
    assert issubclass(eng.ResultCode, str)
    assert eng.OK is eng.ResultCode.OK
    assert eng.ResultCode.OK == "ok"
    assert eng.ResultCode.REJECTED_OVERLOAD == "rejected_overload"
    assert str(eng.ResultCode.REJECTED_DEADLINE) == "rejected_deadline"
    assert f"{eng.ResultCode.FAILED_TRANSCODE}" == "failed_transcode"
    assert eng.Result(ok=True).code is eng.ResultCode.OK


def test_engine_surface_shape():
    # submit/poll/drain are the primary surface; serve is the shim.
    for name in ("submit", "poll", "drain", "serve"):
        assert callable(getattr(eng.Engine, name)), name
    params = inspect.signature(eng.Engine.__init__).parameters
    assert "scheduler" in params
    assert params["scheduler"].default == "continuous"
