"""Unit tests for scripts/bench_gate.py (it shipped untested in PR 2).

Covers regression detection (absolute mode), the machine-portable
relative (fused/blockparallel ratio) mode, missing-cell failures, and
malformed-baseline handling — a corrupt committed baseline must fail
with a diagnosable message and exit code 2, never a traceback.
"""

import importlib.util
import json
import pathlib

import pytest

_SCRIPT = (pathlib.Path(__file__).resolve().parents[1]
           / "scripts" / "bench_gate.py")
_spec = importlib.util.spec_from_file_location("bench_gate", _SCRIPT)
bench_gate = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(bench_gate)


def _report(cells):
    """cells: {(table, lang): {strategy: gchars_per_s}} -> bench JSON."""
    records = [
        {"table": t, "lang": lang, "strategy": s, "gchars_per_s": v}
        for (t, lang), by_s in cells.items() for s, v in by_s.items()
    ]
    return {"langs": [], "n_chars": 0, "mode": "smoke", "records": records}


def _write(tmp_path, name, obj):
    p = tmp_path / name
    p.write_text(obj if isinstance(obj, str) else json.dumps(obj))
    return str(p)


def _run(tmp_path, base, fresh, *extra):
    bp = _write(tmp_path, "base.json", base)
    fp = _write(tmp_path, "fresh.json", fresh)
    return bench_gate.main(["--fresh", fp, "--baseline", bp, *extra])


BASE = {("table5", "latin"): {"fused": 1.0, "blockparallel": 0.5},
        ("table6", "arabic"): {"fused": 2.0, "blockparallel": 1.0}}


def test_identical_runs_pass(tmp_path):
    r = _report(BASE)
    assert _run(tmp_path, r, r) == 0


def test_within_threshold_passes(tmp_path):
    fresh = {k: {s: v * 0.8 for s, v in d.items()} for k, d in BASE.items()}
    assert _run(tmp_path, _report(BASE), _report(fresh)) == 0


def test_regression_detected(tmp_path):
    fresh = {k: dict(d) for k, d in BASE.items()}
    fresh[("table5", "latin")]["fused"] = 0.5   # 2x slowdown > 30%
    assert _run(tmp_path, _report(BASE), _report(fresh)) == 1


def test_missing_cell_fails(tmp_path):
    fresh = {k: d for k, d in BASE.items() if k[0] != "table6"}
    assert _run(tmp_path, _report(BASE), _report(fresh)) == 1


def test_improvement_passes(tmp_path):
    fresh = {k: {s: v * 3.0 for s, v in d.items()} for k, d in BASE.items()}
    assert _run(tmp_path, _report(BASE), _report(fresh)) == 0


def test_relative_mode_ignores_uniform_machine_speed(tmp_path):
    """A uniformly 4x slower machine fails absolute mode but passes
    relative mode (the fused/blockparallel ratio is unchanged)."""
    fresh = {k: {s: v / 4 for s, v in d.items()} for k, d in BASE.items()}
    assert _run(tmp_path, _report(BASE), _report(fresh)) == 1
    assert _run(tmp_path, _report(BASE), _report(fresh),
                "--mode", "relative") == 0


def test_relative_mode_catches_eroded_ratio(tmp_path):
    """Relative mode goes red when only the fused advantage erodes."""
    fresh = {k: dict(d) for k, d in BASE.items()}
    fresh[("table6", "arabic")]["fused"] = 0.9   # ratio 2.0 -> 0.9
    assert _run(tmp_path, _report(BASE), _report(fresh),
                "--mode", "relative") == 1


def test_threshold_flag_respected(tmp_path):
    fresh = {k: {s: v * 0.55 for s, v in d.items()} for k, d in BASE.items()}
    assert _run(tmp_path, _report(BASE), _report(fresh)) == 1
    assert _run(tmp_path, _report(BASE), _report(fresh),
                "--threshold", "0.5") == 0


def test_baseline_without_gated_strategy_fails(tmp_path):
    base = {("table5", "latin"): {"blockparallel": 1.0}}
    assert _run(tmp_path, _report(base), _report(base)) == 1


@pytest.mark.parametrize("bad", [
    "not json at all{",
    {"no_records": True},
    {"records": {"not": "a list"}},
    {"records": ["not-an-object"]},
    {"records": [{"table": "t5", "lang": "latin"}]},          # missing keys
    {"records": [{"table": "t5", "lang": "latin",
                  "strategy": "fused", "gchars_per_s": "fast"}]},
])
def test_malformed_baseline_is_diagnosed(tmp_path, bad, capsys):
    fresh = _report(BASE)
    assert _run(tmp_path, bad, fresh) == bench_gate.EXIT_MALFORMED
    assert "malformed or unreadable" in capsys.readouterr().err


def test_malformed_fresh_is_diagnosed(tmp_path):
    assert _run(tmp_path, _report(BASE), "{]") == bench_gate.EXIT_MALFORMED


def test_binary_baseline_is_diagnosed(tmp_path):
    bp = tmp_path / "base.json"
    bp.write_bytes(b"\x80\x81\xfe\xff")   # non-UTF-8: UnicodeDecodeError
    fp = _write(tmp_path, "fresh.json", _report(BASE))
    rc = bench_gate.main(["--fresh", fp, "--baseline", str(bp)])
    assert rc == bench_gate.EXIT_MALFORMED


def test_unreadable_file_is_diagnosed(tmp_path):
    fp = _write(tmp_path, "fresh.json", _report(BASE))
    rc = bench_gate.main(
        ["--fresh", fp, "--baseline", str(tmp_path / "missing.json")])
    assert rc == bench_gate.EXIT_MALFORMED


# ---------------------------------------------------------------------------
# Schema-versioned table skipping (the "schema" key, bench-report v2+)


def _report_v(cells, schema=None):
    r = _report(cells)
    if schema is not None:
        r["schema"] = schema
    return r


def test_matrix_schema_newer_fresh_table_skipped(tmp_path, capsys):
    """A newer-schema fresh run introducing a new table (table_matrix)
    must pass against an older baseline, with a warning — shared tables
    still gate."""
    fresh = dict(BASE)
    fresh[("table_matrix", "utf8->utf32")] = {"fused": 1.0,
                                              "blockparallel": 0.5}
    assert _run(tmp_path, _report_v(BASE, 1), _report_v(fresh, 2)) == 0
    assert "skipping table 'table_matrix'" in capsys.readouterr().err


def test_matrix_schema_newer_baseline_table_skipped(tmp_path, capsys):
    """The mirror case: an older-schema fresh run (e.g. a long-lived
    branch) against a newer committed baseline warns-and-skips the
    baseline-only table instead of failing on missing cells."""
    base = dict(BASE)
    base[("table_matrix", "utf8->utf32")] = {"fused": 1.0,
                                             "blockparallel": 0.5}
    assert _run(tmp_path, _report_v(base, 2), _report_v(BASE, 1)) == 0
    assert "skipping table 'table_matrix'" in capsys.readouterr().err


def test_matrix_schema_shared_table_still_gates_across_versions(tmp_path):
    """Version skew never waives regressions in tables both sides know."""
    fresh = {k: dict(d) for k, d in BASE.items()}
    fresh[("table_matrix", "utf8->utf32")] = {"fused": 1.0,
                                              "blockparallel": 0.5}
    fresh[("table5", "latin")]["fused"] = 0.1   # real regression
    assert _run(tmp_path, _report_v(BASE, 1), _report_v(fresh, 2)) == 1


def test_matrix_schema_same_version_missing_cell_still_fails(tmp_path):
    """Without version skew, a dropped table is a regression, not a
    format evolution."""
    base = dict(BASE)
    base[("table_matrix", "utf8->utf32")] = {"fused": 1.0,
                                             "blockparallel": 0.5}
    assert _run(tmp_path, _report_v(base, 2), _report_v(BASE, 2)) == 1


def test_matrix_schema_must_be_positive_int(tmp_path, capsys):
    assert _run(tmp_path, _report_v(BASE, 0), _report_v(BASE, 2)) \
        == bench_gate.EXIT_MALFORMED
    bad = _report(BASE)
    bad["schema"] = "two"
    assert _run(tmp_path, bad, _report_v(BASE, 2)) \
        == bench_gate.EXIT_MALFORMED


def test_schema3_ascii_runs_table_and_onepass_column(tmp_path, capsys):
    """The v3 bump (ISSUE 5): a schema-3 fresh run adds the
    ``table_ascii_runs`` table and an ``onepass`` strategy column to the
    existing sweeps.  Against a schema-2 baseline the new TABLE is
    warned-and-skipped; the new strategy COLUMN inside shared tables is
    additive (the gate only reads its gated strategy) and must not
    affect the verdict either way."""
    fresh = {k: dict(d) for k, d in BASE.items()}
    for d in fresh.values():
        d["onepass"] = d["fused"] * 1.25         # new column, shared table
    fresh[("table_ascii_runs", "ascii+4spans")] = {
        "onepass": 3.0, "fused": 1.0, "blockparallel": 0.5}
    assert _run(tmp_path, _report_v(BASE, 2), _report_v(fresh, 3)) == 0
    assert "skipping table 'table_ascii_runs'" in capsys.readouterr().err
    # ...and a fused regression in a shared table still fails despite the
    # healthy new column.
    fresh[("table5", "latin")]["fused"] = 0.1
    assert _run(tmp_path, _report_v(BASE, 2), _report_v(fresh, 3)) == 1


def test_schema5_serve_table_gates_scheduler_pair(tmp_path, capsys):
    """The v5 bump: ``table_serve`` carries SCHEDULER columns, gated via
    the per-table strategy map (continuous gated against the wave
    reference) instead of the kernel-strategy pair.  Against a schema-4
    baseline the new table is warned-and-skipped; same-schema, a
    continuous-throughput regression fails, and relative mode gates the
    continuous/wave advantage ratio."""
    fresh = {k: dict(d) for k, d in BASE.items()}
    fresh[("table_serve", "rps")] = {"continuous": 90.0, "wave": 50.0}
    # Latency row: no gated key for this table -> reported, never gated.
    fresh[("table_serve", "latency")] = {
        "continuous_p99_ms": 400.0, "wave_p99_ms": 700.0}
    assert _run(tmp_path, _report_v(BASE, 4), _report_v(fresh, 5)) == 0
    assert "skipping table 'table_serve'" in capsys.readouterr().err
    assert _run(tmp_path, _report_v(fresh, 5), _report_v(fresh, 5)) == 0
    slow = {k: dict(d) for k, d in fresh.items()}
    slow[("table_serve", "rps")] = {"continuous": 40.0, "wave": 50.0}
    assert _run(tmp_path, _report_v(fresh, 5), _report_v(slow, 5)) == 1
    # Relative mode: same-machine speed cancels, the eroded
    # continuous/wave ratio (1.8 -> 0.8) still fails.
    uniform = {k: {s: v / 4 for s, v in d.items()} for k, d in slow.items()}
    assert _run(tmp_path, _report_v(fresh, 5), _report_v(uniform, 5),
                "--mode", "relative") == 1


def test_matrix_schema_disjoint_tables_never_pass_vacuously(tmp_path, capsys):
    """If schema skew leaves NO shared table, the gate must fail rather
    than pass with zero gated cells."""
    renamed = {("table_5", lang): d for (t, lang), d in BASE.items()}
    assert _run(tmp_path, _report_v(BASE, 2), _report_v(renamed, 3)) == 1
    assert "nothing gated" in capsys.readouterr().err


def test_schema6_cross_strategy_pairs(tmp_path):
    """The v6 bump (ISSUE 8): tables 5/6/9 gate cross-strategy pairs —
    onepass (the dispatch default) against blockparallel on every cell,
    and additionally against fused on table 6 — so a "default loses to
    its reference" regression fails the gate on its own, independent of
    the fused/blockparallel pair."""
    cells = {
        ("table5", "arabic"): {"onepass": 1.2, "fused": 0.8,
                               "blockparallel": 1.0},
        ("table6", "latin"): {"onepass": 3.0, "fused": 2.9,
                              "blockparallel": 1.0},
        ("table9", "arabic"): {"onepass": 1.5, "fused": 0.9,
                               "blockparallel": 1.0},
    }
    assert _run(tmp_path, _report_v(cells, 6), _report_v(cells, 6)) == 0
    # Absolute mode: an onepass-only regression fails even though every
    # fused cell holds.
    slow = {k: dict(d) for k, d in cells.items()}
    slow[("table5", "arabic")]["onepass"] = 0.5
    assert _run(tmp_path, _report_v(cells, 6), _report_v(slow, 6)) == 1
    # Relative mode: eroding ONLY the onepass/fused advantage on table6
    # (fused speeds up, onepass/blockparallel pair unchanged by uniform
    # machine-speed cancellation) fails via the (onepass, fused) pair.
    er = {k: dict(d) for k, d in cells.items()}
    er[("table6", "latin")]["fused"] = 6.0     # onepass/fused 1.03 -> 0.5
    assert _run(tmp_path, _report_v(cells, 6), _report_v(er, 6),
                "--mode", "relative") == 1


def test_schema5_vs_6_warn_and_skip(tmp_path, capsys):
    """v5 -> v6 version skew follows the standard rule: tables unique to
    one side warn-and-skip, shared tables still gate — including the new
    v6 cross-strategy pairs on cells both sides carry."""
    base5 = {("table5", "arabic"): {"onepass": 1.2, "fused": 0.8,
                                    "blockparallel": 1.0}}
    fresh6 = {k: dict(d) for k, d in base5.items()}
    fresh6[("table_future", "x")] = {"fused": 1.0, "blockparallel": 0.5}
    assert _run(tmp_path, _report_v(base5, 5), _report_v(fresh6, 6)) == 0
    assert "skipping table 'table_future'" in capsys.readouterr().err
    # The shared table's onepass pair still gates across the skew.
    fresh6[("table5", "arabic")]["onepass"] = 0.4
    assert _run(tmp_path, _report_v(base5, 5), _report_v(fresh6, 6)) == 1


def test_schema6_vs_7_shard_table_warn_and_skip(tmp_path, capsys):
    """The v7 bump: a schema-7 fresh run adds ``table_shard`` (mesh-
    sharded ragged vs the single-device onepass reference).  Against a
    schema-6 baseline the new table warns-and-skips; same-schema
    baselines gate its sharded/single pair like any other table (the
    transfer_hidden row's ``hidden@N`` keys match no gated strategy and
    are ignored by the gate)."""
    base6 = {("table5", "arabic"): {"onepass": 1.2, "fused": 0.8,
                                    "blockparallel": 1.0}}
    fresh7 = {k: dict(d) for k, d in base6.items()}
    fresh7[("table_shard", "arabic@4")] = {"sharded": 1.1, "single": 1.0}
    fresh7[("table_shard", "transfer_hidden")] = {"hidden@4": 0.9}
    assert _run(tmp_path, _report_v(base6, 6), _report_v(fresh7, 7)) == 0
    assert "skipping table 'table_shard'" in capsys.readouterr().err
    # Same-schema: the sharded cell gates against its own baseline.
    assert _run(tmp_path, _report_v(fresh7, 7), _report_v(fresh7, 7)) == 0
    slow = {k: dict(d) for k, d in fresh7.items()}
    slow[("table_shard", "arabic@4")]["sharded"] = 0.2
    assert _run(tmp_path, _report_v(fresh7, 7), _report_v(slow, 7)) == 1
    # Relative mode gates the sharded/single ratio across the pair.
    assert _run(tmp_path, _report_v(fresh7, 7), _report_v(slow, 7),
                "--mode", "relative") == 1


def test_schema4_stream_table(tmp_path, capsys):
    """The v4 bump: a schema-4 fresh run adds ``table_stream`` (chunked
    resumable streaming vs whole-buffer).  Its rows carry the gated
    ``fused`` column (whole-buffer reference timings), so against a
    schema-3 baseline the new table is warned-and-skipped, and against a
    schema-4 baseline it IS gated like any other table."""
    fresh = {k: dict(d) for k, d in BASE.items()}
    fresh[("table_stream", "arabic@1024")] = {
        "stream": 0.2, "onepass": 1.2, "fused": 1.0, "blockparallel": 0.5}
    assert _run(tmp_path, _report_v(BASE, 3), _report_v(fresh, 4)) == 0
    assert "skipping table 'table_stream'" in capsys.readouterr().err
    # Same-schema baselines gate the new table's fused column normally.
    assert _run(tmp_path, _report_v(fresh, 4), _report_v(fresh, 4)) == 0
    slow = {k: dict(d) for k, d in fresh.items()}
    slow[("table_stream", "arabic@1024")]["fused"] = 0.05
    assert _run(tmp_path, _report_v(fresh, 4), _report_v(slow, 4)) == 1
