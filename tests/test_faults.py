"""Chaos suite: deterministic fault injection against every robustness
path (ISSUE: every injected fault class must end in a retried success or
a TYPED error — never a hang, never silent corruption, never one
request's fault contaminating its wave-mates).

The harness (``repro.testing.faults``) arms faults by *point* name and
*call index*; unarmed, every hook is a no-op passthrough, which the
first test pins.  Serve-engine tests inject at the ragged-kernel hooks
the engine's ingress actually launches through and assert against a
clean-run baseline from the same engine.
"""

import os
import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import transcode as tc
from repro.core.stream import finalize, stream_init, transcode_stream_chunk
from repro.kernels import onepass_transcode as op
from repro.models import registry
from repro.serve import engine as eng
from repro.serve.engine import Engine, Request
from repro.testing import faults

# ---------------------------------------------------------------------------
# Harness mechanics.


def test_unarmed_hooks_are_noops():
    assert faults.active() is None
    payload = np.arange(5)
    assert faults.fire(faults.KERNEL_ONEPASS, payload) is payload
    assert faults.fire(faults.STREAM_CHUNK) is None


def test_harness_counts_and_times():
    boom = faults.Fault(faults.KERNEL_ONEPASS, times=(2,))
    with faults.harness(boom) as h:
        faults.fire(faults.KERNEL_ONEPASS)          # call 1: clean
        with pytest.raises(faults.FaultInjected):
            faults.fire(faults.KERNEL_ONEPASS)      # call 2: armed
        faults.fire(faults.KERNEL_ONEPASS)          # call 3: clean again
    assert h.calls[faults.KERNEL_ONEPASS] == 3
    assert h.fired == [(faults.KERNEL_ONEPASS, "error", 2)]
    assert faults.active() is None                  # restored on exit


def test_harness_nesting_restores_outer():
    outer = faults.Fault(faults.PIPELINE_BATCH, times=None)
    with faults.harness(outer) as ho:
        with faults.harness() as hi:                # inner: no faults
            faults.fire(faults.PIPELINE_BATCH)      # must NOT raise
        assert hi.calls[faults.PIPELINE_BATCH] == 1
        assert faults.active() is ho                # outer re-armed
        with pytest.raises(faults.FaultInjected):
            faults.fire(faults.PIPELINE_BATCH)


def test_truncate_and_latency_faults():
    tr = faults.Fault(faults.STREAM_CHUNK, kind="truncate", truncate_to=2)
    lat = faults.Fault(faults.PIPELINE_BATCH, kind="latency",
                       latency_s=0.01)
    with faults.harness(tr, lat) as h:
        out = faults.fire(faults.STREAM_CHUNK, np.arange(6))
        np.testing.assert_array_equal(out, [0, 1])
        t0 = time.monotonic()
        faults.fire(faults.PIPELINE_BATCH)
        assert time.monotonic() - t0 >= 0.01
    assert {k for k, _, _ in h.fired} == {faults.STREAM_CHUNK,
                                          faults.PIPELINE_BATCH}


def test_bad_fault_kind_rejected():
    with pytest.raises(ValueError):
        faults.Fault(faults.KERNEL_ONEPASS, kind="explode")


# ---------------------------------------------------------------------------
# Kernel wrappers: faults surface as exceptions, never hangs/corruption.


def test_kernel_fault_surfaces_and_recovers():
    x = jnp.asarray(np.frombuffer(b"hello", np.uint8))
    with faults.harness(faults.Fault(faults.KERNEL_ONEPASS)):
        with pytest.raises(faults.FaultInjected):
            op.transcode_onepass(x, src="utf8", dst="utf16")
    # The failure is stateless: the very next call is clean.
    res = op.transcode_onepass(x, src="utf8", dst="utf16")
    assert int(res.count) == 5 and int(res.status) == -1


def test_stream_truncation_fault_keeps_accounting_consistent():
    """A truncated chunk loses data but must never corrupt the stream:
    the state's counts stay consistent with what was ACTUALLY processed
    (the truncated stream equals a clean stream of the truncated data)."""
    data = np.frombuffer("héllo wörld".encode("utf-8"), np.uint8)
    tr = faults.Fault(faults.STREAM_CHUNK, kind="truncate", truncate_to=3,
                      times=(2,))
    st = stream_init("utf8", "utf16")
    parts = []
    with faults.harness(tr):
        for i in range(0, len(data), 5):
            r, st = transcode_stream_chunk(st, data[i: i + 5])
            parts.append(np.asarray(r.buffer)[: int(r.count)])
    r, st = finalize(st)
    parts.append(np.asarray(r.buffer)[: int(r.count)])
    # Oracle: the same stream minus the dropped tail of chunk 2.
    seen = np.concatenate([data[:5], data[5:8], data[10:]])
    st2 = stream_init("utf8", "utf16")
    parts2 = []
    for i in range(0, len(seen), 5):
        r2, st2 = transcode_stream_chunk(st2, seen[i: i + 5])
        parts2.append(np.asarray(r2.buffer)[: int(r2.count)])
    r2, st2 = finalize(st2)
    parts2.append(np.asarray(r2.buffer)[: int(r2.count)])
    assert st.out_count == st2.out_count
    assert st.status == st2.status
    np.testing.assert_array_equal(np.concatenate(parts),
                                  np.concatenate(parts2))


def test_latency_fault_leaves_results_identical():
    x = jnp.asarray(np.frombuffer("café".encode(), np.uint8))
    clean = op.transcode_onepass(x, src="utf8", dst="utf16")
    lat = faults.Fault(faults.KERNEL_ONEPASS, kind="latency",
                       latency_s=0.01, times=None)
    with faults.harness(lat) as h:
        slow = op.transcode_onepass(x, src="utf8", dst="utf16")
    assert h.fires_at(faults.KERNEL_ONEPASS)
    assert int(slow.count) == int(clean.count)
    assert int(slow.status) == int(clean.status)
    np.testing.assert_array_equal(np.asarray(slow.buffer),
                                  np.asarray(clean.buffer))


def test_pipeline_batch_fault_surfaces_and_recovers():
    from repro.data import pipeline
    docs = np.zeros((2, 8), np.uint8)
    docs[:, :5] = np.frombuffer(b"hello", np.uint8)
    lengths = np.array([5, 5], np.int32)
    with faults.harness(faults.Fault(faults.PIPELINE_BATCH)):
        with pytest.raises(faults.FaultInjected):
            pipeline.batch_transcode(docs, lengths)
    res = pipeline.batch_transcode(docs, lengths)
    assert list(np.asarray(res.count)) == [5, 5]


# ---------------------------------------------------------------------------
# Capacity-overflow sentinel (satellite): speculative garbage beyond
# CAP_FACTOR capacity drops at capacity with a non-(-1) status.


@pytest.mark.parametrize("src,dst", tc.PAIRS)
def test_capacity_overflow_drops_at_capacity(src, dst):
    n = 1024
    x = faults.capacity_overflow_input(src, n)
    res = tc.transcode(jnp.asarray(x), dst, src_format=src, n_valid=n,
                       strategy="onepass", errors="strict")
    cap = tc.CAP_FACTOR[(src, dst)] * n
    assert len(res.buffer) == cap            # output clipped AT capacity
    if (src, dst) in faults.OVERFLOW_PAIRS:
        # The flood's speculative unit count exceeds capacity — the
        # write must drop at cap, flagged by a real (>= 0) status.
        assert int(res.count) > cap
        assert int(res.status) >= 0
    elif src == "latin1":
        assert int(res.status) == -1         # latin1 is never invalid
        assert int(res.count) <= cap
    else:
        assert int(res.status) >= 0          # flood is invalid input
        assert int(res.count) <= cap


# ---------------------------------------------------------------------------
# Serve engine under injected faults.


@pytest.fixture(scope="module")
def served():
    fam, cfg, model = registry.get("bytelm-100m", reduced=True)
    params = model.init(jax.random.PRNGKey(0))
    return Engine(model, cfg, fam, params, max_batch=4, max_prompt=64,
                  max_new=8, backoff_base_s=0.0)


CLEAN = b"hello"
POISON = b"bad \xff byte"


def test_serve_transient_fault_retried_to_success(served):
    baseline = served.serve([Request(CLEAN)])[0]
    r0 = served.counters["retries"]
    # First ragged-scan launch fails once; the retry must succeed and
    # the result must be byte-identical to the clean run.
    with faults.harness(faults.Fault(faults.KERNEL_RAGGED_SCAN,
                                     times=(1,))):
        res = served.serve([Request(CLEAN)])[0]
    assert res.ok and res.code == eng.OK
    assert res.text_bytes == baseline.text_bytes
    assert served.counters["retries"] == r0 + 1


def test_serve_persistent_fault_degrades_to_host_fallback(served):
    baseline = served.serve([Request(CLEAN)])[0]
    f0 = served.counters["fallback"]
    # EVERY ragged launch fails: the wave must degrade per-document to
    # the host codecs path — clean prompts still serve (same bytes),
    # poison prompts get their typed per-document rejection with the
    # right offset, and neither contaminates the other.
    with faults.harness(
            faults.Fault(faults.KERNEL_RAGGED_SCAN, times=None),
            faults.Fault(faults.KERNEL_RAGGED, times=None),
            faults.Fault(faults.KERNEL_ONEPASS, times=None)):
        res = served.serve([Request(CLEAN), Request(POISON),
                            Request(POISON, errors="replace")])
    assert res[0].ok and res[0].text_bytes == baseline.text_bytes
    assert not res[1].ok and res[1].code == eng.REJECTED_INVALID
    assert res[1].error_offset == POISON.index(0xFF)
    assert res[2].ok
    assert res[2].sanitized_prompt == POISON.decode(
        "utf-8", "replace").encode("utf-8")
    assert served.counters["fallback"] >= f0 + 3
    assert served.counters["retries"] > 0


def test_serve_unit_group_fallback_matches_device_semantics(served):
    prompt16 = "héllo".encode("utf-16-le")
    lone = np.array([0xD800], "<u2").tobytes() + prompt16
    baseline = served.serve([Request(prompt16, in_encoding="utf-16-le")])[0]
    with faults.harness(faults.Fault(faults.KERNEL_RAGGED, times=None),
                        faults.Fault(faults.KERNEL_RAGGED_SCAN,
                                     times=None)):
        res = served.serve([
            Request(prompt16, in_encoding="utf-16-le"),
            Request(lone, in_encoding="utf-16-le"),
            Request(lone, in_encoding="utf-16-le", errors="replace")])
    assert res[0].ok and res[0].text_bytes == baseline.text_bytes
    assert not res[1].ok and res[1].code == eng.REJECTED_INVALID
    assert res[1].error_offset == 0          # unit-relative offset
    assert res[2].ok and res[2].sanitized_prompt.startswith(
        "�".encode("utf-8"))


def test_serve_poison_wave_isolation_device_path(served):
    """No faults armed: one poison document in a packed wave must
    degrade to ITS error only — wave-mates before and after serve."""
    res = served.serve([Request(CLEAN), Request(POISON), Request(b"world")])
    assert res[0].ok and res[2].ok
    assert not res[1].ok and res[1].code == eng.REJECTED_INVALID


def test_serve_bad_out_encoding_isolated(served):
    """Egress poison: an unknown out_encoding yields a typed
    per-document failure, not an exception that eats the wave."""
    res = served.serve([Request(CLEAN), Request(b"ok", out_encoding="ebcdic")])
    assert res[0].ok
    assert not res[1].ok and res[1].code == eng.FAILED_TRANSCODE
    assert "out_encoding" in res[1].error


def test_serve_overload_sheds_typed(served):
    n = served.queue_limit + 3
    res = served.serve([Request(CLEAN) for _ in range(n)])
    shed = [r for r in res if r.code == eng.REJECTED_OVERLOAD]
    assert len(shed) == 3
    assert all(not r.ok and "queue full" in r.error for r in shed)
    assert all(r.ok for r in res[: served.queue_limit])
    assert served.counters["shed"] >= 3


def test_serve_deadline_expiry_typed():
    fam, cfg, model = registry.get("bytelm-100m", reduced=True)
    params = model.init(jax.random.PRNGKey(0))
    now = [0.0]
    e = Engine(model, cfg, fam, params, max_batch=4, max_prompt=64,
               max_new=8, clock=lambda: now[0], sleep=lambda s: None)

    res = e.serve([Request(CLEAN, deadline_s=10.0)])[0]
    assert res.ok                            # generous deadline: serves

    orig = e._ingress_chunk

    def slow_ingress(group, bound, take):
        now[0] += 5.0                        # ingress "takes" 5s
        return orig(group, bound, take)

    e._ingress_chunk = slow_ingress
    res = e.serve([Request(CLEAN, deadline_s=1.0),
                   Request(CLEAN, deadline_s=60.0)])
    assert not res[0].ok and res[0].code == eng.REJECTED_DEADLINE
    assert res[1].ok
    assert e.counters["deadline"] == 1


# ---------------------------------------------------------------------------
# Fault-point registry completeness (satellite): every ``faults.POINTS``
# entry must have (a) a ``faults.fire()`` call site under ``src/repro``
# and (b) a test-reachable code path that actually drives a call through
# it — so a new kernel or subsystem can't ship a registry entry without
# chaos coverage (mirrors PR 7's ``tc.DEPRECATED`` completeness sweep).


def _point_constants():
    """point value -> module constant name (e.g. "kernel.onepass" ->
    "KERNEL_ONEPASS"), built from the module itself so a new POINTS
    entry is covered without editing this test."""
    names = {v: k for k, v in vars(faults).items()
             if k.isupper() and isinstance(v, str) and v in faults.POINTS}
    assert set(names) == set(faults.POINTS)
    return names


def test_every_fault_point_has_a_src_call_site():
    root = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src", "repro")
    blobs = []
    for dirpath, _dirs, files in os.walk(root):
        for f in files:
            if f.endswith(".py"):
                with open(os.path.join(dirpath, f)) as fh:
                    blobs.append(fh.read())
    text = "\n".join(blobs)
    for point, const in sorted(_point_constants().items()):
        assert f"faults.fire(faults.{const}" in text, (
            f"fault point {point!r} has no faults.fire(faults.{const}...) "
            f"call site under src/repro — a registry entry nothing can "
            f"inject into")


def _packed_docs():
    from repro.core import packing
    return packing.pack_documents(
        [np.frombuffer(b"hello", np.uint8),
         np.frombuffer(b"world!", np.uint8)], dtype=np.uint8)


def _x_onepass():
    op.transcode_onepass(jnp.asarray(np.frombuffer(b"hello", np.uint8)),
                         src="utf8", dst="utf16")


def _x_fused():
    from repro.kernels import fused_transcode as ft
    ft.transcode_fused(jnp.asarray(np.frombuffer(b"hello", np.uint8)),
                       src="utf8", dst="utf16")


def _x_scan():
    from repro.kernels import fused_transcode as ft
    ft.scan_fused(jnp.asarray(np.frombuffer(b"hello", np.uint8)),
                  src="utf8", dst="utf16")


def _x_ragged():
    from repro.kernels import ragged_transcode as rt
    p = _packed_docs()
    rt.transcode_ragged(p.data, p.offsets, p.lengths,
                        src="utf8", dst="utf16")


def _x_ragged_scan():
    from repro.kernels import ragged_transcode as rt
    p = _packed_docs()
    rt.scan_ragged(p.data, p.offsets, p.lengths, src="utf8", dst="utf16")


def _x_stream():
    st = stream_init("utf8", "utf16")
    transcode_stream_chunk(st, np.frombuffer(b"hello", np.uint8))


def _x_pipeline():
    from repro.data import pipeline
    docs = np.zeros((1, 8), np.uint8)
    docs[0, :5] = np.frombuffer(b"hello", np.uint8)
    pipeline.batch_transcode(docs, np.array([5], np.int32))


def _x_shard_launch():
    from repro.core import shard
    p = _packed_docs()
    shard.ragged_transcode_sharded(p.data, p.offsets, p.lengths,
                                   src_format="utf8", dst_format="utf16",
                                   n_shards=1)


def _x_feed_stage():
    from jax.sharding import Mesh
    from repro.data.shard_feed import DoubleBufferedFeeder
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    feeder = DoubleBufferedFeeder(mesh, stage_fn=lambda arrays: arrays)
    try:
        feeder.run([("w0",)], lambda *staged: staged)
    finally:
        feeder.close()


def _x_engine_probe():
    fam, cfg, model = registry.get("bytelm-100m", reduced=True)
    params = model.init(jax.random.PRNGKey(0))
    e = Engine(model, cfg, fam, params, max_batch=2, max_prompt=64,
               max_new=4, backoff_base_s=0.0, sleep=lambda s: None,
               breaker_threshold=1, breaker_cooldown_s=0.0)
    e.serve([Request(CLEAN)])            # pre-warm the utf-8 cells
    # Trip the breaker under a NESTED harness so the failure injection
    # is invisible to the outer (counting) harness.
    with faults.harness(faults.Fault(faults.KERNEL_RAGGED_SCAN,
                                     times=None)):
        e.serve([Request(CLEAN)])        # retries exhaust -> breaker opens
    e.serve([Request(CLEAN)])            # cooldown 0 -> half-open probe


_EXERCISERS = {
    faults.KERNEL_ONEPASS: _x_onepass,
    faults.KERNEL_FUSED: _x_fused,
    faults.KERNEL_SCAN: _x_scan,
    faults.KERNEL_RAGGED: _x_ragged,
    faults.KERNEL_RAGGED_SCAN: _x_ragged_scan,
    faults.STREAM_CHUNK: _x_stream,
    faults.PIPELINE_BATCH: _x_pipeline,
    faults.SHARD_LAUNCH: _x_shard_launch,
    faults.FEED_STAGE: _x_feed_stage,
    faults.ENGINE_PROBE: _x_engine_probe,
}


def test_exerciser_registry_covers_every_point():
    assert set(_EXERCISERS) == set(faults.POINTS), (
        "a new faults.POINTS entry needs an exerciser here — otherwise "
        "it can ship without any test able to reach its fire() call")


@pytest.mark.parametrize("point", faults.POINTS)
def test_every_fault_point_reachable_from_tests(point):
    with faults.harness() as h:          # no faults armed: count only
        _EXERCISERS[point]()
    assert h.calls.get(point, 0) >= 1, (
        f"exerciser for {point!r} never drove a call through its "
        f"fire() site")
