"""Single-pass pipeline tests (strategy="onepass", DESIGN.md §9).

Three claims are pinned here:

  1. **One launch.**  A one-pass transcode — single stream or a whole
     ragged packed batch — traces to exactly ONE ``pallas_call``: the
     SMEM offset carry replaced the count-launch / cumsum / write-launch
     split of the fused pipeline.
  2. **Bit identity.**  (buffer, count, status) are bit-identical to
     ``strategy="fused"`` across every matrix cell × ``errors=`` policy,
     including boundary-adversarial streams straddling VMEM tile and
     packed-document boundaries (the carry must advance by exactly the
     fused count pass's per-tile totals for the bases to agree).
  3. **Per-tile ASCII skip.**  Mixed ASCII/multibyte documents where only
     some tiles are non-ASCII stay correct (the skip may only fire on
     tiles whose boundary inflow is clean), including a pure-ASCII tile
     whose previous tile ends in lead/continuation bytes.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import packing
from repro.core import transcode as tc
from repro.data import synthetic
from repro.kernels import fused_transcode as ft
from repro.kernels import onepass_transcode as op
from repro.kernels import ragged_transcode as rt
from repro.kernels import stages

BLOCK = stages.BLOCK


# ---------------------------------------------------------------------------
# jaxpr helpers (shared shape with tests/test_fused_transcode.py)


def _iter_eqns(jaxpr):
    for eqn in jaxpr.eqns:
        yield eqn
        if eqn.primitive.name == "pallas_call":
            continue
        for v in eqn.params.values():
            for sub in _sub_jaxprs(v):
                yield from _iter_eqns(sub)


def _sub_jaxprs(v):
    if isinstance(v, jax.core.ClosedJaxpr):
        yield v.jaxpr
    elif isinstance(v, jax.core.Jaxpr):
        yield v
    elif isinstance(v, (tuple, list)):
        for item in v:
            yield from _sub_jaxprs(item)


def _pallas_eqns(jaxpr):
    return [e for e in _iter_eqns(jaxpr) if e.primitive.name == "pallas_call"]


# ---------------------------------------------------------------------------
# Claim 1: one launch.


@pytest.mark.parametrize("src,dst,dt", [("utf8", "utf16", jnp.uint8),
                                        ("utf16", "utf8", jnp.uint16),
                                        ("utf32", "utf8", jnp.uint32),
                                        ("latin1", "utf8", jnp.uint8)])
def test_onepass_traces_to_one_pallas_call(src, dst, dt):
    cap = 4096
    for fastpath in (True, False):
        jaxpr = jax.make_jaxpr(
            lambda x, s=src, d=dst, a=fastpath: op.transcode_onepass(
                x, cap - 5, src=s, dst=d, ascii_fastpath=a)
        )(jnp.zeros((cap,), dt)).jaxpr
        kernels = _pallas_eqns(jaxpr)
        assert len(kernels) == 1, (src, dst, fastpath, len(kernels))


def test_fused_still_traces_to_two_pallas_calls():
    """The two-launch reference stays two-launch (the contrast)."""
    cap = 4096
    jaxpr = jax.make_jaxpr(
        lambda x: ft.transcode_fused(x, cap - 5, src="utf8", dst="utf16",
                                     ascii_fastpath=False)
    )(jnp.zeros((cap,), jnp.uint8)).jaxpr
    assert len(_pallas_eqns(jaxpr)) == 2


def test_onepass_ragged_traces_to_one_pallas_call():
    docs = [np.full(1500, 0x41, np.uint8), np.full(700, 0x41, np.uint8)]
    pk = packing.pack_documents(docs, dtype=np.uint8)
    jaxpr = jax.make_jaxpr(
        lambda d, o, l: rt.transcode_ragged(d, o, l, src="utf8",
                                            dst="utf16",
                                            strategy="onepass")
    )(jnp.asarray(pk.data), jnp.asarray(pk.offsets),
      jnp.asarray(pk.lengths)).jaxpr
    assert len(_pallas_eqns(jaxpr)) == 1


def test_onepass_shares_the_generic_driver(monkeypatch):
    """Tracing a one-pass cell must go through the stages package's
    single ``onepass_tile`` body (itself composed of the same
    decode_once/count_decoded/stage_decoded primitives count_tile and
    write_stage wrap) — no per-pair kernel duplication."""
    from repro.kernels.stages import driver as sdrv
    calls = []
    real = sdrv.onepass_tile

    def spy(src, dst, *a, **k):
        calls.append((src.name, dst.name))
        return real(src, dst, *a, **k)

    monkeypatch.setattr(sdrv, "onepass_tile", spy)
    cap = 2048
    for src, dst, dt in (("utf8", "utf16", jnp.uint8),
                         ("utf32", "utf8", jnp.uint32)):
        jax.make_jaxpr(
            lambda x, s=src, d=dst: op.transcode_onepass(
                x, cap - 5, src=s, dst=d, ascii_fastpath=False)
        )(jnp.zeros((cap,), dt))
        assert (src, dst) in calls, (src, dst, calls)


# ---------------------------------------------------------------------------
# Claim 2: bit identity with the fused reference.


def _assert_identical(a, f, ctx):
    assert int(a.count) == int(f.count), ctx
    assert int(a.status) == int(f.status), ctx
    assert np.array_equal(np.asarray(a.buffer), np.asarray(f.buffer)), ctx


_GEN_HI = {1: 256, 2: 1 << 16, 4: 0x110000}


@pytest.mark.parametrize("src,dst", tc.PAIRS)
@pytest.mark.parametrize("errors", ["strict", "replace"])
def test_onepass_bit_identical_to_fused_all_cells(src, dst, errors):
    rng = np.random.default_rng(20260801)
    dt = stages.get_codec(src).dtype
    cap = 2 * BLOCK
    for trial in range(4):
        n = int(rng.integers(1, cap))
        arr = rng.integers(0, _GEN_HI[stages.get_codec(src).itemsize],
                           cap).astype(dt)
        a = op.transcode_onepass(jnp.asarray(arr), n, src=src, dst=dst,
                                 errors=errors)
        f = ft.transcode_fused(jnp.asarray(arr), n, src=src, dst=dst,
                               errors=errors)
        _assert_identical(a, f, (src, dst, errors, trial))


@pytest.mark.parametrize("errors", ["strict", "replace"])
def test_onepass_boundary_straddling_characters(errors):
    """Multi-byte characters and truncated leads at VMEM tile boundaries:
    the SMEM carry's base must agree with the fused cumsum at every tile,
    or outputs shear at exactly these positions."""
    probes = [b"\xf0\x9f\x92\xa9", b"\xe4\xb8\xad", b"\xc3\xa9",
              b"\xf0\x9f\x92", b"\xc3", b"\xed\xa0\x80"]
    for probe in probes:
        for pos in (BLOCK - 3, BLOCK - 2, BLOCK - 1, BLOCK, 2 * BLOCK - 1):
            buf = np.full(3 * BLOCK, 0x41, np.uint8)
            buf[pos: pos + len(probe)] = np.frombuffer(probe, np.uint8)
            a = op.utf8_to_utf16_onepass(jnp.asarray(buf), len(buf),
                                         errors=errors)
            f = ft.utf8_to_utf16_fused(jnp.asarray(buf), len(buf),
                                       errors=errors)
            _assert_identical(a, f, (probe, pos, errors))


@pytest.mark.parametrize("validate", [True, False])
def test_onepass_validate_flag_and_scan(validate):
    b = synthetic.utf8_array("arabic", 2000, seed=7)
    buf = np.zeros(8192, np.uint8)
    buf[: len(b)] = b
    a = op.utf8_to_utf16_onepass(jnp.asarray(buf), len(b),
                                 validate=validate)
    f = ft.utf8_to_utf16_fused(jnp.asarray(buf), len(b), validate=validate)
    _assert_identical(a, f, validate)
    # scan: the one-pass strategy shares the fused counting kernel.
    c1, s1 = tc.scan_utf8(jnp.asarray(buf), len(b), strategy="onepass")
    c2, s2 = tc.scan_utf8(jnp.asarray(buf), len(b), strategy="fused")
    assert int(c1) == int(c2) and int(s1) == int(s2)


@pytest.mark.parametrize("errors", ["strict", "replace"])
def test_onepass_ragged_bit_identical_to_fused(errors):
    rng = np.random.default_rng(20260801 + 1)
    docs = [synthetic.utf8_array(lang, n, seed=i) for i, (lang, n) in
            enumerate([("latin", 1500), ("chinese", 900), ("emoji", 40),
                       ("arabic", 2100), ("korean", 1024)])]
    docs.insert(1, np.zeros(0, np.uint8))                  # empty
    docs.insert(3, np.full(77, 0x41, np.uint8))            # all-ASCII
    mutated = docs[4].copy()
    mutated[rng.integers(0, len(mutated), 3)] = 0xFF       # invalid doc
    docs[4] = mutated
    pk = packing.pack_documents(docs, dtype=np.uint8)
    a = rt.transcode_ragged(pk.data, pk.offsets, pk.lengths, src="utf8",
                            dst="utf16", errors=errors, strategy="onepass")
    f = rt.transcode_ragged(pk.data, pk.offsets, pk.lengths, src="utf8",
                            dst="utf16", errors=errors, strategy="fused")
    assert np.array_equal(np.asarray(a.buffer), np.asarray(f.buffer))
    assert np.array_equal(np.asarray(a.offsets), np.asarray(f.offsets))
    assert np.array_equal(np.asarray(a.counts), np.asarray(f.counts))
    assert np.array_equal(np.asarray(a.statuses), np.asarray(f.statuses))


def test_onepass_ragged_doc_pack_boundaries():
    """Truncated leads ending EXACTLY at a packed document boundary whose
    neighbour starts with the completing continuation bytes: the carry +
    ownership resets must keep the documents independent."""
    tile = packing.TILE
    docs = []
    for probe in (b"\xf0\x9f\x92", b"\xc3", b"\xe4\xb8"):
        doc = np.full(tile, 0x41, np.uint8)
        doc[tile - len(probe):] = np.frombuffer(probe, np.uint8)
        docs.append(doc)
        docs.append(np.frombuffer(b"\xa9\x80\x80 tail", np.uint8))
    docs.append(np.zeros(0, np.uint8))
    pk = packing.pack_documents(docs, dtype=np.uint8)
    for errors in ("strict", "replace"):
        res = rt.transcode_ragged(pk.data, pk.offsets, pk.lengths,
                                  src="utf8", dst="utf16", errors=errors,
                                  strategy="onepass")
        for d, doc in enumerate(docs):
            span = max(int(pk.offsets[d + 1] - pk.offsets[d]), 1)
            buf = np.zeros(span, np.uint8)
            buf[: len(doc)] = doc
            single = ft.utf8_to_utf16_fused(jnp.asarray(buf), len(doc),
                                            errors=errors)
            assert int(res.counts[d]) == int(single.count), (d, errors)
            assert int(res.statuses[d]) == int(single.status), (d, errors)
            lo = int(res.offsets[d])
            got = np.asarray(res.buffer)[lo: lo + int(res.counts[d])]
            k = min(int(single.count), span)
            assert np.array_equal(got[:k],
                                  np.asarray(single.buffer)[:k]), (d, errors)


def test_onepass_zero_length_and_n_valid_zero():
    z = op.utf8_to_utf16_onepass(jnp.zeros((0,), jnp.uint8))
    assert int(z.count) == 0 and int(z.status) == -1
    b = synthetic.utf8_array("latin", 100, seed=0)
    buf = np.zeros(2048, np.uint8)
    buf[: len(b)] = b
    r = op.utf8_to_utf16_onepass(jnp.asarray(buf), 0)
    assert int(r.count) == 0 and int(r.status) == -1


# ---------------------------------------------------------------------------
# Claim 3: the per-tile ASCII skip.


def test_onepass_single_nonascii_tile():
    """A document where exactly ONE tile holds multibyte characters: the
    whole-buffer cond fails, the skip fires on every other tile, and the
    result is still bit-identical to fused and to the CPython oracle."""
    n = 8 * BLOCK
    buf = np.full(n, 0x61, np.uint8)
    cjk = "中文データ処理".encode("utf-8")
    pos = 3 * BLOCK + 100                    # interior of tile 3 only
    buf[pos: pos + len(cjk)] = np.frombuffer(cjk, np.uint8)
    a = op.utf8_to_utf16_onepass(jnp.asarray(buf), n)
    f = ft.utf8_to_utf16_fused(jnp.asarray(buf), n)
    _assert_identical(a, f, "single-nonascii-tile")
    want = np.frombuffer(bytes(buf).decode("utf-8").encode("utf-16-le"),
                         np.uint16)
    assert int(a.count) == len(want)
    assert np.array_equal(np.asarray(a.buffer)[: len(want)], want)


@pytest.mark.parametrize("tail", [b"\xc3", b"\xf0\x9f\x92", b"\x80",
                                  b"\xc3\xa9"])
def test_onepass_ascii_tile_after_multibyte_inflow(tail):
    """A pure-ASCII tile whose PREVIOUS tile ends in lead / continuation
    bytes (the boundary-inflow cases that must NOT take the skip): the
    conservative inflow guard sends the tile down the general path and
    the result stays bit-identical to fused — including the error
    located in the previous tile for the truncated leads."""
    for errors in ("strict", "replace"):
        buf = np.full(3 * BLOCK, 0x61, np.uint8)
        buf[BLOCK - len(tail): BLOCK] = np.frombuffer(tail, np.uint8)
        a = op.utf8_to_utf16_onepass(jnp.asarray(buf), len(buf),
                                     errors=errors)
        f = ft.utf8_to_utf16_fused(jnp.asarray(buf), len(buf),
                                   errors=errors)
        _assert_identical(a, f, (tail, errors))


def test_onepass_ascii_skip_on_off_equivalence():
    """ascii_fastpath=True (whole-buffer cond + per-tile skip) and False
    (general path for every tile) must agree bit for bit on mixed and on
    pure-ASCII buffers."""
    mixed = np.full(4 * BLOCK, 0x61, np.uint8)
    mixed[BLOCK + 5: BLOCK + 8] = np.frombuffer("中".encode("utf-8"),
                                                np.uint8)
    pure = np.full(4 * BLOCK, 0x41, np.uint8)
    for buf in (mixed, pure):
        for errors in ("strict", "replace"):
            on = op.utf8_to_utf16_onepass(jnp.asarray(buf), len(buf) - 9,
                                          errors=errors,
                                          ascii_fastpath=True)
            off = op.utf8_to_utf16_onepass(jnp.asarray(buf), len(buf) - 9,
                                           errors=errors,
                                           ascii_fastpath=False)
            _assert_identical(on, off, errors)


def test_onepass_ascii_skip_other_sources():
    """The skip is format-generic: UTF-16/UTF-32/Latin-1 sources with
    mostly-ASCII content and one contaminated tile."""
    cases = [
        ("utf16", "utf8", np.full(3 * BLOCK, 0x41, np.uint16)),
        ("utf32", "utf8", np.full(3 * BLOCK, 0x41, np.uint32)),
        ("latin1", "utf8", np.full(3 * BLOCK, 0x41, np.uint8)),
    ]
    cases[0][2][BLOCK + 3: BLOCK + 5] = [0xD83C, 0xDF89]   # surrogate pair
    cases[1][2][BLOCK + 3] = 0x1F389                        # astral cp
    cases[2][2][BLOCK + 3] = 0xE9                           # é high byte
    for src, dst, arr in cases:
        a = op.transcode_onepass(jnp.asarray(arr), len(arr), src=src,
                                 dst=dst)
        f = ft.transcode_fused(jnp.asarray(arr), len(arr), src=src,
                               dst=dst)
        _assert_identical(a, f, (src, dst))


def test_onepass_utf32_garbage_does_not_ride_the_skip():
    """int32-wrapped garbage (0xFFFFFFFF reads negative inside the
    kernel) must not pass the per-tile ASCII predicate."""
    arr = np.full(2 * BLOCK, 0x41, np.uint32)
    arr[BLOCK + 1] = 0xFFFFFFFF
    a = op.transcode_onepass(jnp.asarray(arr), len(arr), src="utf32",
                             dst="utf8")
    f = ft.transcode_fused(jnp.asarray(arr), len(arr), src="utf32",
                           dst="utf8")
    _assert_identical(a, f, "utf32-garbage")
    assert int(a.status) == BLOCK + 1


# ---------------------------------------------------------------------------
# Tile-class dispatch: the ≤2-byte class (DESIGN.md §9).


def test_onepass_two_byte_straddling_class_transitions():
    """2-byte sequences straddling every (ASCII | ≤2-byte | general)
    class transition: tile 0 is pure ASCII, tile 1 pure 2-byte (the
    ≤2-byte class), tile 2 holds 3-byte CJK (general), with a 2-byte
    character split across BOTH tile boundaries.  The per-tile classes
    differ but the compact output must stay bit-identical to fused."""
    buf = np.full(3 * BLOCK, 0x61, np.uint8)
    two = np.frombuffer("ب".encode("utf-8"), np.uint8)        # 0xD8 0xA8
    buf[BLOCK + 2: 2 * BLOCK - 2: 2] = two[0]
    buf[BLOCK + 3: 2 * BLOCK - 1: 2] = two[1]
    cjk = np.frombuffer("中".encode("utf-8"), np.uint8)
    buf[2 * BLOCK + 10: 2 * BLOCK + 13] = cjk
    # Straddle ASCII->class2: lead at BLOCK-1, continuation at BLOCK.
    buf[BLOCK - 1], buf[BLOCK] = two[0], two[1]
    # Straddle class2->general: lead at 2*BLOCK-1, continuation after.
    buf[2 * BLOCK - 1], buf[2 * BLOCK] = two[0], two[1]
    for errors in ("strict", "replace"):
        a = op.utf8_to_utf16_onepass(jnp.asarray(buf), len(buf),
                                     errors=errors)
        f = ft.utf8_to_utf16_fused(jnp.asarray(buf), len(buf),
                                   errors=errors)
        _assert_identical(a, f, errors)
    want = np.frombuffer(bytes(buf).decode("utf-8").encode("utf-16-le"),
                         np.uint16)
    assert int(a.count) == len(want)
    assert np.array_equal(np.asarray(a.buffer)[: len(want)], want)


@pytest.mark.parametrize("tail", [b"\xe4\xb8", b"\xf0\x9f\x92", b"\xe4",
                                  b"\xf0"])
def test_onepass_class2_tile_with_wide_lead_inflow(tail):
    """A tile of pure 2-byte content whose PREVIOUS tile ends in a
    truncated 3-/4-byte lead: the inflow window disqualifies the ≤2-byte
    class (its 1-lane claim logic cannot represent the wide lead's
    claim), so the general path must handle the boundary — bit-identical
    to fused, with the truncated lead's error located in its own tile."""
    two = np.frombuffer("ب".encode("utf-8"), np.uint8)
    for errors in ("strict", "replace"):
        buf = np.full(3 * BLOCK, 0x61, np.uint8)
        buf[BLOCK + 2: 2 * BLOCK - 2: 2] = two[0]
        buf[BLOCK + 3: 2 * BLOCK - 1: 2] = two[1]
        buf[BLOCK - len(tail): BLOCK] = np.frombuffer(tail, np.uint8)
        a = op.utf8_to_utf16_onepass(jnp.asarray(buf), len(buf),
                                     errors=errors)
        f = ft.utf8_to_utf16_fused(jnp.asarray(buf), len(buf),
                                   errors=errors)
        _assert_identical(a, f, (tail, errors))


def test_onepass_surrogate_flood_not_claimed_by_class2():
    """UTF-16 surrogate-flood garbage (every lane a lone or paired
    surrogate half) sits entirely OUTSIDE the ≤2-byte class predicate:
    the general path must classify it, and the first unpaired half's
    offset must match fused — with a clean ≤2-byte tile right after the
    flood taking the class without inheriting any claim."""
    rng = np.random.default_rng(20260809)
    arr = np.full(3 * BLOCK, 0x41, np.uint16)
    arr[BLOCK: 2 * BLOCK] = rng.integers(0xD800, 0xE000,
                                         BLOCK).astype(np.uint16)
    arr[2 * BLOCK:] = rng.integers(0x80, 0x800, BLOCK).astype(np.uint16)
    for errors in ("strict", "replace"):
        a = op.transcode_onepass(jnp.asarray(arr), len(arr), src="utf16",
                                 dst="utf8", errors=errors)
        f = ft.transcode_fused(jnp.asarray(arr), len(arr), src="utf16",
                               dst="utf8", errors=errors)
        _assert_identical(a, f, errors)


@pytest.mark.parametrize("src,dst", tc.PAIRS)
@pytest.mark.parametrize("errors", ["strict", "replace"])
def test_onepass_class_dispatch_on_off_bit_identity_fuzz(src, dst, errors):
    """Class-on (ascii_fastpath=True: three-way dispatch) vs class-off
    (False: general path only) bit-identity fuzz across all 12 cells ×
    errors policies, with values biased INTO the ≤2-byte class (plus
    out-of-class contamination at tile granularity) so the class-2
    branch actually fires and disagreements cannot hide in the general
    path."""
    rng = np.random.default_rng(20260808)
    codec = stages.get_codec(src)
    cap = 4 * BLOCK
    for trial in range(3):
        if codec.itemsize == 1:
            # Bytes below 0xE0: ASCII + 2-byte leads + continuations.
            arr = rng.integers(0, 0xE0, cap)
        else:
            arr = rng.integers(0, 0x800, cap)
        # Contaminate one tile with full-range garbage and one with pure
        # ASCII so all three classes appear in one buffer.
        arr[BLOCK: 2 * BLOCK] = rng.integers(
            0, _GEN_HI[codec.itemsize], BLOCK)
        arr[2 * BLOCK: 3 * BLOCK] = rng.integers(0x20, 0x7F, BLOCK)
        arr = arr.astype(codec.dtype)
        n = int(rng.integers(3 * BLOCK, cap))
        on = op.transcode_onepass(jnp.asarray(arr), n, src=src, dst=dst,
                                  errors=errors, ascii_fastpath=True)
        off = op.transcode_onepass(jnp.asarray(arr), n, src=src, dst=dst,
                                   errors=errors, ascii_fastpath=False)
        _assert_identical(on, off, (src, dst, errors, trial, "on/off"))
        f = ft.transcode_fused(jnp.asarray(arr), n, src=src, dst=dst,
                               errors=errors)
        _assert_identical(on, f, (src, dst, errors, trial, "vs-fused"))


# ---------------------------------------------------------------------------
# Dispatch plumbing.


def test_default_strategy_is_onepass():
    assert tc.DEFAULT_STRATEGY == "onepass"
    assert "onepass" in tc.STRATEGIES
    b = synthetic.utf8_array("arabic", 500, seed=3)
    d = tc.transcode_utf8_to_utf16(jnp.asarray(b), len(b))
    e = tc.transcode(jnp.asarray(b), "utf16", src_format="utf8",
                     n_valid=len(b), strategy="onepass")
    _assert_identical(d, e, "default-dispatch")


def test_ragged_strategy_rejects_unknown():
    docs = [np.full(10, 0x41, np.uint8)]
    pk = packing.pack_documents(docs, dtype=np.uint8)
    with pytest.raises(ValueError, match="strategy"):
        rt.transcode_ragged(pk.data, pk.offsets, pk.lengths, src="utf8",
                            dst="utf16", strategy="windowed")
    with pytest.raises(ValueError, match="strategy"):
        tc.ragged_transcode(pk.data, pk.offsets, pk.lengths,
                            strategy="blockparallel")
