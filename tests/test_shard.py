"""Mesh-sharded ragged transcode (DESIGN.md §12): the host-side shard
planner, the shard_map execution path, the bit-identity contract against
the single-device onepass launch, and the double-buffered feeder.

Multi-device cases either run in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (jax locks the
device count at first init; the main test process must keep seeing one
device) or are skipped unless the process already has >= 8 devices — the
CI ``shard`` job and ``scripts/check.sh --shard`` run the whole module
under the forced 8-device host platform, which un-skips the full fuzz.
"""

import os
import subprocess
import sys
import time

import numpy as np
import pytest

import jax

from repro.core import packing, shard
from repro.core import transcode as tc
from repro.data import shard_feed, synthetic
from repro.launch import mesh as launch_mesh

from tests.test_fused_transcode import _iter_eqns, _pallas_eqns

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

TILE = packing.TILE

LANGS = ("latin", "arabic", "chinese", "emoji")


def _run(code, timeout=600):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=timeout, cwd=REPO)
    assert "PASS" in r.stdout, \
        f"stdout={r.stdout[-1500:]}\nstderr={r.stderr[-2500:]}"


def _docs_for(src, n_docs, n_chars, seed):
    """Valid documents in ``src``'s narrow storage dtype, mixed langs."""
    rng = np.random.default_rng(seed)
    docs = []
    for i in range(n_docs):
        lang = LANGS[i % len(LANGS)]
        n = int(rng.integers(1, n_chars + 1))
        if src == "utf8":
            docs.append(synthetic.utf8_array(lang, n, seed=seed + i))
        elif src == "utf16":
            docs.append(synthetic.utf16_units(lang, n, seed=seed + i))
        elif src == "utf32":
            text = bytes(synthetic.utf8_array(
                lang, n, seed=seed + i)).decode("utf-8")
            docs.append(np.array([ord(c) for c in text], np.uint32))
        else:   # latin1: any byte stream is valid
            docs.append(rng.integers(0, 256, n).astype(np.uint8))
    return docs


def _assert_result_equal(ref, res, what=""):
    for name in ("buffer", "offsets", "counts", "statuses"):
        a = np.asarray(getattr(ref, name))
        b = np.asarray(getattr(res, name))
        assert a.shape == b.shape, (what, name, a.shape, b.shape)
        assert (a == b).all(), \
            (what, name, np.flatnonzero(a != b)[:8])


# ---------------------------------------------------------------------------
# Mesh helper.


def test_make_transcode_mesh_is_1d_data_only():
    m = launch_mesh.make_transcode_mesh(1)
    assert m.axis_names == ("data",)
    assert m.shape["data"] == 1
    # Default: every available device.
    assert launch_mesh.make_transcode_mesh().shape["data"] == \
        len(jax.devices())


def test_make_transcode_mesh_rejects_bad_counts():
    with pytest.raises(ValueError, match="n_shards"):
        launch_mesh.make_transcode_mesh(0)
    with pytest.raises(ValueError, match="exceeds"):
        launch_mesh.make_transcode_mesh(len(jax.devices()) + 1)


# ---------------------------------------------------------------------------
# Host-side shard planner.


def _pack(docs):
    return packing.pack_documents(docs)


def test_plan_equal_docs_split_on_boundaries():
    pk = _pack([synthetic.utf8_array("latin", 900, seed=i)
                for i in range(8)])
    plan = shard.plan_shards(pk.data, pk.offsets, pk.lengths, 4)
    assert plan.n_shards == 4 and plan.n_docs == 8
    # Two whole documents per shard, never split.
    assert (plan.frag_base == 0).all()
    assert plan.frag_doc.tolist() == [[0, 1], [2, 3], [4, 5], [6, 7]]
    assert (plan.lengths == np.asarray(pk.lengths)[plan.frag_doc]).all()


def test_plan_balances_bytes_not_doc_count():
    # One 6000-byte document plus six 1000-byte ones: a doc-count split
    # (3.5 docs each) would put ~9000 bytes on one shard; the byte-
    # balanced cut puts the big document (nearly) alone on shard 0.
    docs = [synthetic.utf8_array("latin", 6000, seed=0)] + \
           [synthetic.utf8_array("latin", 1000, seed=i) for i in range(6)]
    pk = _pack(docs)
    plan = shard.plan_shards(pk.data, pk.offsets, pk.lengths, 2)
    assert (plan.frag_base == 0).all()          # boundary cuts only
    loads = plan.lengths.sum(axis=1)
    total = int(np.asarray(pk.lengths).sum())
    # Each shard within one small document's length of the even split.
    assert abs(int(loads[0]) - total // 2) <= 1100, loads.tolist()
    assert 0 in plan.frag_doc[0]


def test_plan_oversize_doc_cut_lands_on_unit_boundary():
    # ~30k bytes of 3-byte CJK characters in ONE document: every shard
    # cut must fall inside it, and the holdback walk-back must park each
    # cut on a character boundary (fragment starts at a lead byte).
    doc = synthetic.utf8_array("chinese", 10000, seed=3)
    pk = _pack([doc])
    plan = shard.plan_shards(pk.data, pk.offsets, pk.lengths, 4)
    frags = [(int(d), int(b), int(n))
             for d, b, n in zip(plan.frag_doc.ravel(),
                                plan.frag_base.ravel(),
                                plan.lengths.ravel()) if d < plan.n_docs]
    assert len(frags) == 4 and all(d == 0 for d, _, _ in frags)
    assert sum(n for _, _, n in frags) == len(doc)
    for _, base, _ in frags[1:]:
        assert base > 0
        lead = int(doc[base])
        assert not (0x80 <= lead < 0xC0), \
            f"fragment starts mid-character at {base}: {lead:#x}"
    # Byte balance within a few characters of the ideal quarter.
    sizes = [n for _, _, n in frags]
    assert max(sizes) - min(sizes) <= 8, sizes


def test_plan_empty_docs_and_batch_smaller_than_shards():
    pk = _pack([np.zeros(0, np.uint8),
                synthetic.utf8_array("latin", 40, seed=1),
                np.zeros(0, np.uint8)])
    plan = shard.plan_shards(pk.data, pk.offsets, pk.lengths, 4)
    # Every document (including the empty ones) appears exactly once.
    live = plan.frag_doc[plan.frag_doc < plan.n_docs]
    assert sorted(live.tolist()) == [0, 1, 2]
    # The remaining slots are pure padding: sentinel ids, zero lengths.
    pad = plan.frag_doc >= plan.n_docs
    assert int(pad.sum()) == plan.frag_doc.size - 3
    assert (plan.lengths[pad] == 0).all()


def test_plan_rejects_bad_inputs():
    pk = _pack([synthetic.utf8_array("latin", 40, seed=1)])
    with pytest.raises(ValueError, match="n_shards"):
        shard.plan_shards(pk.data, pk.offsets, pk.lengths, 0)
    with pytest.raises(ValueError, match="chunk_budget"):
        shard.plan_shards(pk.data, pk.offsets, pk.lengths, 2,
                          chunk_budget=8)
    with pytest.raises(TypeError, match="host-side"):
        jax.jit(lambda d: shard.plan_shards(d, pk.offsets,
                                            pk.lengths, 2))(
            np.asarray(pk.data))


# ---------------------------------------------------------------------------
# Bit-identity on the in-process (single-device) path: a 1-shard mesh
# exercises the full plan -> shard_map -> gather pipeline.


@pytest.mark.parametrize("pair", [("utf8", "utf16"), ("utf16", "utf8"),
                                  ("latin1", "utf32")],
                         ids=lambda p: f"{p[0]}-{p[1]}")
@pytest.mark.parametrize("errors", ["strict", "replace"])
def test_sharded_one_shard_identity(pair, errors):
    src, dst = pair
    docs = _docs_for(src, n_docs=5, n_chars=400, seed=7)
    pk = _pack(docs)
    ref = tc.ragged_transcode(pk.data, pk.offsets, pk.lengths,
                              src_format=src, dst_format=dst,
                              errors=errors)
    res = tc.ragged_transcode(pk.data, pk.offsets, pk.lengths,
                              src_format=src, dst_format=dst,
                              errors=errors, strategy="sharded",
                              n_shards=1)
    _assert_result_equal(ref, res, f"{src}->{dst}/{errors}")


def test_sharded_scan_one_shard_identity():
    docs = [synthetic.utf8_array("arabic", 300, seed=i) for i in range(4)]
    docs.insert(2, np.zeros(0, np.uint8))
    pk = _pack(docs)
    c_ref, s_ref = tc.ragged_scan(pk.data, pk.offsets, pk.lengths,
                                  src_format="utf8", dst_format="utf16")
    c, s = shard.scan_ragged_sharded(pk.data, pk.offsets, pk.lengths,
                                     src_format="utf8",
                                     dst_format="utf16", n_shards=1)
    assert (np.asarray(c_ref) == np.asarray(c)).all()
    assert (np.asarray(s_ref) == np.asarray(s)).all()


def test_sharded_kwargs_require_sharded_strategy():
    pk = _pack([synthetic.utf8_array("latin", 40, seed=1)])
    with pytest.raises(ValueError, match="sharded"):
        tc.ragged_transcode(pk.data, pk.offsets, pk.lengths,
                            n_shards=2)


def test_sharded_rejects_mesh_without_data_axis():
    pk = _pack([synthetic.utf8_array("latin", 40, seed=1)])
    bad = jax.sharding.Mesh(np.asarray(jax.devices()[:1]), ("model",))
    with pytest.raises(ValueError, match="data"):
        tc.ragged_transcode(pk.data, pk.offsets, pk.lengths,
                            strategy="sharded", shard_mesh=bad)


# ---------------------------------------------------------------------------
# Launch-count pin: exactly ONE ragged onepass launch per shard per wave
# — the shard_map body contains one pallas_call, nothing more.


def test_sharded_jaxpr_one_launch_per_shard():
    pk = _pack([synthetic.utf8_array("arabic", 700, seed=i)
                for i in range(4)])
    mesh = launch_mesh.make_transcode_mesh(1)
    plan = shard.plan_shards(pk.data, pk.offsets, pk.lengths, 1)
    fn = shard.sharded_call(mesh, "utf8", "utf16", True, "strict", True)
    jaxpr = jax.make_jaxpr(fn)(plan.data, plan.offsets,
                               plan.lengths).jaxpr
    sm = [e for e in _iter_eqns(jaxpr)
          if "shard_map" in e.primitive.name]
    assert len(sm) == 1, "expected exactly one shard_map region"
    assert len(_pallas_eqns(jaxpr)) == 1, \
        "the shard_map body must hold exactly ONE ragged launch per shard"


# ---------------------------------------------------------------------------
# Double-buffered feeder: transfer overlaps compute; order preserved.


def test_shard_feed_overlap_hides_transfer():
    mesh = launch_mesh.make_transcode_mesh(1)
    stage_s, compute_s, waves = 0.02, 0.05, 4
    order = []

    def slow_stage(arrays):
        time.sleep(stage_s)
        order.append(("stage", arrays[0]))
        return arrays

    def launch(tag):
        time.sleep(compute_s)
        order.append(("launch", tag))
        return tag

    feeder = shard_feed.DoubleBufferedFeeder(mesh, stage_fn=slow_stage)
    with feeder:
        results, stats = feeder.run([(k,) for k in range(waves)], launch)
    assert results == list(range(waves))
    assert len(stats) == waves
    # Steady state: every 20ms stage hides behind a 50ms kernel, so the
    # residual stall must be a small fraction of the transfer time.
    frac = shard_feed.hidden_fraction(stats)
    assert frac >= 0.5, (frac, stats)
    # ONE staging worker keeps stages strictly in wave order.
    stages_seen = [t for kind, t in order if kind == "stage"]
    assert stages_seen == list(range(waves))


def test_shard_feed_empty_and_single_wave():
    mesh = launch_mesh.make_transcode_mesh(1)
    with shard_feed.DoubleBufferedFeeder(mesh) as feeder:
        results, stats = feeder.run([], lambda *a: a)
    assert results == [] and stats == []
    # A single wave has no steady state: hidden_fraction reports 0.
    with shard_feed.DoubleBufferedFeeder(
            mesh, stage_fn=lambda a: a) as f:
        results, stats = f.run([(np.arange(3),)], lambda x: x)
    assert len(results) == 1 and shard_feed.hidden_fraction(stats) == 0.0


def test_shard_feed_single_worker_double_buffer():
    # The staging pool must be ONE worker: two in-flight transfers would
    # be triple buffering and could reorder wave completion.
    mesh = launch_mesh.make_transcode_mesh(1)
    feeder = shard_feed.DoubleBufferedFeeder(mesh)
    assert feeder._pool._max_workers == 1
    feeder.close()


def test_run_sharded_waves_single_device_roundtrip():
    mesh = launch_mesh.make_transcode_mesh(1)
    docs = [synthetic.utf8_array("arabic", 900, seed=i) for i in range(6)]
    pk = _pack(docs)
    plans = [shard.plan_shards(pk.data, pk.offsets, pk.lengths, 1)
             for _ in range(3)]
    outs, stats = shard_feed.run_sharded_waves(
        mesh, plans, src="utf8", dst="utf16")
    assert len(outs) == 3 and len(stats) == 3
    ref = tc.ragged_transcode(pk.data, pk.offsets, pk.lengths,
                              src_format="utf8", dst_format="utf16")
    from repro.kernels import stages
    _cs, codec_d, factor = stages.get_pair("utf8", "utf16")
    cap = factor * max(1, -(-int(np.asarray(pk.data).shape[0]) // TILE)) \
        * TILE
    for bufs, oos, counts, statuses in outs:
        res = shard._gather_result(
            plans[0], cap, codec_d.dtype, np.asarray(bufs),
            np.asarray(oos), np.asarray(counts), np.asarray(statuses),
            True)
        _assert_result_equal(ref, res, "feeder wave")


# ---------------------------------------------------------------------------
# Multi-device coverage.  The subprocess smoke keeps tier-1 honest on a
# single-device box; the full fuzz below it un-skips under the CI shard
# job's forced 8-device host platform.


def test_sharded_8dev_subprocess_smoke():
    """Reduced multi-shard sweep in a forced-8-device subprocess:
    bit-identity across shard counts, the serve engine's sharded
    ingress, and the feeder's overlap accounting."""
    _run("""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax
assert jax.device_count() == 8
from repro.core import packing, shard, transcode as tc
from repro.data import shard_feed, synthetic
from repro.launch import mesh as lm

rng = np.random.default_rng(20260801)
langs = ["arabic", "latin", "chinese", "emoji"]
docs = [synthetic.utf8_array(langs[i % 4], int(rng.integers(1, 2500)),
                             seed=i) for i in range(11)]
docs[3] = np.zeros(0, np.uint8)
poison = synthetic.utf8_array("latin", 400, seed=99).copy()
poison[50] = 0xFF                       # poison doc, isolated to a shard
docs[7] = poison
pk = packing.pack_documents(docs)
for errors in ("strict", "replace"):
    ref = tc.ragged_transcode(pk.data, pk.offsets, pk.lengths,
                              src_format="utf8", dst_format="utf16",
                              errors=errors)
    for n in (2, 8):
        res = tc.ragged_transcode(pk.data, pk.offsets, pk.lengths,
                                  src_format="utf8", dst_format="utf16",
                                  errors=errors, strategy="sharded",
                                  n_shards=n)
        for name in ("buffer", "offsets", "counts", "statuses"):
            a = np.asarray(getattr(ref, name))
            b = np.asarray(getattr(res, name))
            assert (a == b).all(), (errors, n, name)
# utf16 -> utf8 cell across 4 shards
docs16 = [synthetic.utf16_units("emoji", 600, seed=i) for i in range(5)]
pk16 = packing.pack_documents(docs16)
ref = tc.ragged_transcode(pk16.data, pk16.offsets, pk16.lengths,
                          src_format="utf16", dst_format="utf8")
res = tc.ragged_transcode(pk16.data, pk16.offsets, pk16.lengths,
                          src_format="utf16", dst_format="utf8",
                          strategy="sharded", n_shards=4)
for name in ("buffer", "offsets", "counts", "statuses"):
    assert (np.asarray(getattr(ref, name)) ==
            np.asarray(getattr(res, name))).all(), name
# sharded scan
c_ref, s_ref = tc.ragged_scan(pk.data, pk.offsets, pk.lengths,
                              src_format="utf8", dst_format="utf16")
c, s = shard.scan_ragged_sharded(pk.data, pk.offsets, pk.lengths,
                                 src_format="utf8", dst_format="utf16",
                                 n_shards=4)
assert (np.asarray(c_ref) == np.asarray(c)).all()
assert (np.asarray(s_ref) == np.asarray(s)).all()
# engine ingress fans out across shards, results unchanged
from repro.models import registry
from repro.serve.engine import Engine, Request
fam, cfg, model = registry.get("bytelm-100m", reduced=True)
params = model.init(jax.random.PRNGKey(0))
e1 = Engine(model, cfg, fam, params, max_batch=4, max_prompt=64,
            max_new=4)
e2 = Engine(model, cfg, fam, params, max_batch=4, max_prompt=64,
            max_new=4, ingress_shards=2)
prompts = [Request(b"hello shard"), Request(b"bad \\xff\\x80 byte"),
           Request("caf\\u00e9 \\u4e2d".encode()),
           Request(b"dirty \\xe4\\xb8 tail", errors="replace")]
r1 = e1.serve(prompts)
r2 = e2.serve(prompts)
for a, b in zip(r1, r2):
    assert (a.ok, a.code, a.error, a.error_offset, a.text_bytes,
            a.sanitized_prompt) == \\
        (b.ok, b.code, b.error, b.error_offset, b.text_bytes,
         b.sanitized_prompt)
# unit-encoding ingress through the sharded path
u16 = "caf\\u00e9 \\U0001F600".encode("utf-16-le")
r3 = e2.serve([Request(u16, in_encoding="utf-16-le")])
assert r3[0].ok
# feeder stats come back sane on a real 4-shard mesh
mesh = lm.make_transcode_mesh(4)
plans = [shard.plan_shards(pk.data, pk.offsets, pk.lengths, 4)
         for _ in range(3)]
outs, stats = shard_feed.run_sharded_waves(mesh, plans, src="utf8",
                                           dst="utf16")
assert len(outs) == 3 and all(st.transfer_s >= 0 for st in stats)
print("PASS")
""", timeout=900)


_FULL_FUZZ_REASON = ("needs >= 8 devices (run under XLA_FLAGS="
                     "--xla_force_host_platform_device_count=8, e.g. "
                     "scripts/check.sh --shard or the CI shard job)")

_POISON = {"utf8": 0xFF, "utf16": 0xDC00, "utf32": 0x110000}


@pytest.mark.skipif(jax.device_count() < 8, reason=_FULL_FUZZ_REASON)
@pytest.mark.parametrize("pair", tc.PAIRS, ids=lambda p: f"{p[0]}-{p[1]}")
def test_sharded_full_matrix_fuzz_8dev(pair):
    """All 12 matrix cells x errors policies x shard counts {1, 2, 4, 8}:
    sharded == single-device onepass bit-for-bit, with an empty doc in
    the batch and a poison doc isolated to one shard."""
    src, dst = pair
    docs = _docs_for(src, n_docs=6, n_chars=300,
                     seed=20260801 + len(src) * 7 + len(dst))
    docs.insert(2, np.zeros_like(docs[0][:0]))   # empty doc
    if src in _POISON and len(docs[4]) > 10:     # latin1 can't be poison
        p = docs[4].copy()
        p[5] = _POISON[src]
        docs[4] = p
    pk = _pack(docs)
    for errors in ("strict", "replace"):
        ref = tc.ragged_transcode(pk.data, pk.offsets, pk.lengths,
                                  src_format=src, dst_format=dst,
                                  errors=errors)
        for n in (1, 2, 4, 8):
            res = tc.ragged_transcode(pk.data, pk.offsets, pk.lengths,
                                      src_format=src, dst_format=dst,
                                      errors=errors, strategy="sharded",
                                      n_shards=n)
            _assert_result_equal(ref, res,
                                 f"{src}->{dst}/{errors}/shards={n}")


@pytest.mark.skipif(jax.device_count() < 8, reason=_FULL_FUZZ_REASON)
def test_sharded_batch_smaller_than_shards_8dev():
    for n_docs in (1, 3):
        docs = [synthetic.utf8_array("emoji", 150 * (i + 1), seed=i)
                for i in range(n_docs)]
        pk = _pack(docs)
        ref = tc.ragged_transcode(pk.data, pk.offsets, pk.lengths,
                                  src_format="utf8", dst_format="utf16")
        res = tc.ragged_transcode(pk.data, pk.offsets, pk.lengths,
                                  src_format="utf8", dst_format="utf16",
                                  strategy="sharded", n_shards=8)
        _assert_result_equal(ref, res, f"n_docs={n_docs}")


@pytest.mark.skipif(jax.device_count() < 8, reason=_FULL_FUZZ_REASON)
def test_sharded_oversize_doc_split_valid_stream_8dev():
    """A valid oversize document split mid-stream by the holdback rule
    stays bit-identical under BOTH policies (the strict caveat applies
    only to split documents that contain errors)."""
    doc = synthetic.utf8_array("chinese", 12000, seed=11)
    pk = _pack([doc, synthetic.utf8_array("latin", 500, seed=1)])
    for errors in ("strict", "replace"):
        ref = tc.ragged_transcode(pk.data, pk.offsets, pk.lengths,
                                  src_format="utf8", dst_format="utf16",
                                  errors=errors)
        res = tc.ragged_transcode(pk.data, pk.offsets, pk.lengths,
                                  src_format="utf8", dst_format="utf16",
                                  errors=errors, strategy="sharded",
                                  n_shards=8)
        _assert_result_equal(ref, res, errors)
