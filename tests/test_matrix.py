"""Codec-matrix refactor: stage composition, generic-driver sharing,
derived stage widths, byte helpers, and the matrix surfaces of the data
pipeline and serving engine (DESIGN.md §8).

Named ``test_matrix`` so the CI matrix-parity job (``-k "matrix or
parity"``) picks the whole module up alongside the differential suite's
matrix cells.
"""

import numpy as np
import pytest

import jax
import jax.core
import jax.numpy as jnp

from repro.core import transcode as tc
from repro.data import pipeline, synthetic
from repro.kernels import fused_transcode as ft
from repro.kernels import stages
from repro.kernels.stages import driver as sdrv


# ---------------------------------------------------------------------------
# Registry / dispatch


def test_matrix_formats_and_aliases():
    assert tc.normalize_format("utf-8") == "utf8"
    assert tc.normalize_format("UTF-16-LE") == "utf16"
    assert tc.normalize_format("utf-32-le") == "utf32"
    assert tc.normalize_format("latin-1") == "latin1"
    assert tc.normalize_format("iso-8859-1") == "latin1"
    with pytest.raises(ValueError):
        tc.normalize_format("ebcdic")
    # every (src != dst) pair is a supported cell
    assert len(tc.PAIRS) == 12
    with pytest.raises(ValueError):
        tc.transcode(jnp.zeros(8, jnp.uint8), "utf8", src_format="utf8")


def test_matrix_registry_shares_cap_factors():
    """The kernel registry and the public dispatch must agree on the
    static capacity conventions (one source of truth)."""
    assert stages.CAP_FACTOR is tc.CAP_FACTOR
    for (s, d), f in tc.CAP_FACTOR.items():
        codec_s, codec_d, factor = stages.get_pair(s, d)
        assert factor == f
        assert codec_s.name == s and codec_d.name == d


def test_matrix_stage_widths_are_derived():
    """Stage windows come from the destination's unit length at the
    source's largest fabricable code point — including the surrogate-
    flood worst case that the old hand-sized UTF-16→UTF-8 bound missed."""
    u = stages
    assert stages.stage_units(u.UTF8, u.UTF16) == 2
    assert stages.stage_units(u.UTF8, u.UTF32) == 1
    assert stages.stage_units(u.UTF16, u.UTF8) == 4   # was 3 (+1) — bug
    assert stages.stage_units(u.UTF32, u.UTF8) == 4
    assert stages.stage_units(u.UTF32, u.UTF16) == 2
    assert stages.stage_units(u.LATIN1, u.UTF8) == 2
    for (s, d) in stages.PAIRS:
        assert stages.stage_width(*stages.get_pair(s, d)[:2]) \
            == stages.BLOCK * stages.stage_units(*stages.get_pair(s, d)[:2])


def test_matrix_stage_overflow_regression_surrogate_flood():
    """A tile of 0xDBFF units folds EVERY lane to a supplementary pair
    code point (4 speculative UTF-8 bytes each, 4*BLOCK per tile) — the
    old 3*BLOCK+1 stage silently dropped the tail and the fused output
    diverged from blockparallel.  Pin the fix."""
    for unit in (0xDBFF, 0xDBFF):
        u = np.full(2048, unit, np.uint16)
        f = ft.utf16_to_utf8_fused(jnp.asarray(u), len(u))
        b = tc.utf16_to_utf8(jnp.asarray(u.astype(np.int32)), len(u))
        assert int(f.count) == int(b.count)
        k = int(f.count)
        assert np.array_equal(np.asarray(f.buffer)[:k],
                              np.asarray(b.buffer)[:k].astype(np.uint8))
    # alternating DBFF/FFFF: 4-byte and 3-byte speculative lanes mixed
    u = np.tile(np.array([0xDBFF, 0xFFFF], np.uint16), 1024)
    f = ft.utf16_to_utf8_fused(jnp.asarray(u), len(u))
    b = tc.utf16_to_utf8(jnp.asarray(u.astype(np.int32)), len(u))
    assert int(f.count) == int(b.count)
    k = int(f.count)
    assert np.array_equal(np.asarray(f.buffer)[:k],
                          np.asarray(b.buffer)[:k].astype(np.uint8))


# ---------------------------------------------------------------------------
# ONE generic driver serves every cell (no per-pair kernel duplication).


def _iter_eqns(jaxpr):
    for eqn in jaxpr.eqns:
        yield eqn
        if eqn.primitive.name == "pallas_call":
            continue
        for v in eqn.params.values():
            for sub in _sub_jaxprs(v):
                yield from _iter_eqns(sub)


def _sub_jaxprs(v):
    if isinstance(v, jax.core.ClosedJaxpr):
        yield v.jaxpr
    elif isinstance(v, jax.core.Jaxpr):
        yield v
    elif isinstance(v, (tuple, list)):
        for item in v:
            yield from _sub_jaxprs(item)


def _pallas_eqns(jaxpr):
    return [e for e in _iter_eqns(jaxpr) if e.primitive.name == "pallas_call"]


def test_matrix_cells_share_one_generic_driver(monkeypatch):
    """Tracing ANY matrix cell must invoke the stages package's single
    ``count_tile``/``write_stage`` driver — no per-pair kernel bodies.
    UTF-8→UTF-16 (the classic cell) and UTF-8→UTF-32 / latin1→utf8 (new
    cells) are counted through the same monkeypatched entry points."""
    calls = {"count": [], "write": []}
    real_count, real_write = sdrv.count_tile, sdrv.write_stage

    def spy_count(src, dst, *a, **k):
        calls["count"].append((src.name, dst.name))
        return real_count(src, dst, *a, **k)

    def spy_write(src, dst, *a, **k):
        calls["write"].append((src.name, dst.name))
        return real_write(src, dst, *a, **k)

    monkeypatch.setattr(sdrv, "count_tile", spy_count)
    monkeypatch.setattr(sdrv, "write_stage", spy_write)

    cap = 2048
    for src, dst, dt in (("utf8", "utf16", jnp.uint8),
                         ("utf8", "utf32", jnp.uint8),
                         ("latin1", "utf8", jnp.uint8)):
        jax.make_jaxpr(
            lambda x, s=src, d=dst: ft.transcode_fused(
                x, cap - 5, src=s, dst=d, ascii_fastpath=False)
        )(jnp.zeros((cap,), dt))
        assert (src, dst) in calls["count"], (src, dst, calls["count"])
        assert (src, dst) in calls["write"], (src, dst, calls["write"])


@pytest.mark.parametrize("src,dst,dt", [("utf8", "utf16", jnp.uint8),
                                        ("utf8", "utf32", jnp.uint8),
                                        ("utf32", "utf8", jnp.uint32),
                                        ("latin1", "utf8", jnp.uint8)])
def test_matrix_jaxpr_two_passes_narrow_io(src, dst, dt):
    """Every fused matrix cell is the same two-launch shape (count pass +
    write pass, nothing else), with narrow-dtype large operands."""
    cap = 2048
    itemsize = stages.get_codec(src).itemsize
    jaxpr = jax.make_jaxpr(
        lambda x: ft.transcode_fused(x, cap - 5, src=src, dst=dst,
                                     ascii_fastpath=False)
    )(jnp.zeros((cap,), dt)).jaxpr
    kernels = _pallas_eqns(jaxpr)
    assert len(kernels) == 2, (src, dst, len(kernels))
    for eqn in kernels:
        for v in eqn.invars:
            if v.aval.size >= cap:
                assert v.aval.dtype.itemsize <= itemsize, (src, dst, v.aval)
    names = {e.primitive.name for e in _iter_eqns(jaxpr)}
    assert not any("scatter" in n for n in names), names


# ---------------------------------------------------------------------------
# Latin-1 semantics (the asymmetric corner of the matrix)


def test_matrix_latin1_roundtrip_and_substitution():
    t = "café ÿ þ £"
    l1 = np.frombuffer(t.encode("latin-1"), np.uint8)
    for strat in ("fused", "blockparallel"):
        r = tc.latin1_to_utf8(jnp.asarray(l1), len(l1), strategy=strat)
        assert int(r.status) == -1
        assert bytes(np.asarray(r.buffer)[: int(r.count)].astype(np.uint8)) \
            == t.encode("utf-8")
        r = tc.latin1_to_utf16(jnp.asarray(l1), len(l1), strategy=strat)
        assert np.array_equal(
            np.asarray(r.buffer)[: int(r.count)].astype(np.uint16),
            np.frombuffer(t.encode("utf-16-le"), np.uint16))
    # utf8 -> latin1 with an unencodable char: status at its lead byte,
    # replace output matches CPython's chained replace ('?')
    s = "ab 中 é"
    b = np.frombuffer(s.encode("utf-8"), np.uint8)
    want_pos = len("ab ".encode("utf-8"))
    for strat in ("fused", "blockparallel"):
        r = tc.utf8_to_latin1(jnp.asarray(b), len(b), strategy=strat)
        assert int(r.status) == want_pos, strat
        r = tc.utf8_to_latin1(jnp.asarray(b), len(b), errors="replace",
                              strategy=strat)
        assert int(r.status) == want_pos, strat
        assert bytes(np.asarray(r.buffer)[: int(r.count)].astype(np.uint8)) \
            == s.encode("latin-1", "replace"), strat


def test_matrix_utf32_strict_substitutes_but_locates():
    cps = np.array([0x41, 0xD800, 0x1F389, 0x110000, 0x42], np.uint32)
    for strat in ("fused", "blockparallel"):
        out, cnt, status = tc.utf32_to_utf8(jnp.asarray(cps), len(cps),
                                            strategy=strat)
        assert int(status) == 1, strat
        # the buffer is the replace-form output (well-defined narrow
        # values) even under strict; status lets callers reject.
        want = "A�🎉�B".encode("utf-8")
        assert bytes(np.asarray(out)[: int(cnt)].astype(np.uint8)) == want, \
            strat


def test_matrix_ascii_fastpath_rejects_wrapped_negative_utf32():
    """A garbage UTF-32 scalar (0xFFFFFFFF wraps to int32 -1) inside an
    otherwise-ASCII buffer must NOT ride the ASCII fast path: both
    strategies locate it and substitute U+FFFD (review regression)."""
    cps = np.array([0x41, 0xFFFFFFFF, 0x42], np.uint32)
    want = "A�B".encode("utf-8")
    for strat in ("fused", "blockparallel"):
        out, cnt, status = tc.utf32_to_utf8(jnp.asarray(cps), len(cps),
                                            strategy=strat)
        assert int(status) == 1, strat
        assert int(cnt) == len(want), strat
        assert bytes(np.asarray(out)[: int(cnt)].astype(np.uint8)) == want, \
            strat


def test_matrix_scan_counts_destination_units():
    s = "naïve 中文 🎉"
    b = np.frombuffer(s.encode("utf-8"), np.uint8)
    for strat in ("fused", "blockparallel"):
        cnt, status = tc.scan(jnp.asarray(b), "utf32", src_format="utf8",
                              n_valid=len(b), strategy=strat)
        assert int(status) == -1
        assert int(cnt) == len(s), strat
        cnt16, _ = tc.scan(jnp.asarray(b), "utf16", src_format="utf8",
                           n_valid=len(b), strategy=strat)
        assert int(cnt16) == len(s.encode("utf-16-le")) // 2, strat


# ---------------------------------------------------------------------------
# Endianness-explicit byte helpers


def test_matrix_le_byte_helpers_roundtrip():
    s = "héllo 🎉 中"
    raw16 = np.frombuffer(s.encode("utf-16-le"), np.uint8)
    units = tc.utf16le_bytes_to_units(jnp.asarray(raw16.astype(np.int32)))
    assert np.array_equal(np.asarray(units),
                          np.frombuffer(s.encode("utf-16-le"), "<u2")
                          .astype(np.int32))
    back = tc.units_to_utf16le_bytes(units)
    assert np.array_equal(np.asarray(back), raw16.astype(np.int32))

    raw32 = np.frombuffer(s.encode("utf-32-le"), np.uint8)
    cps = tc.utf32le_bytes_to_cps(jnp.asarray(raw32.astype(np.int32)))
    assert np.array_equal(np.asarray(cps),
                          np.array([ord(c) for c in s], np.int32))
    back = tc.cps_to_utf32le_bytes(cps)
    assert np.array_equal(np.asarray(back), raw32.astype(np.int32))


def test_matrix_le_byte_helpers_reject_ragged_length():
    with pytest.raises(ValueError):
        tc.utf16le_bytes_to_units(jnp.zeros(3, jnp.int32))
    with pytest.raises(ValueError):
        tc.utf32le_bytes_to_cps(jnp.zeros(6, jnp.int32))


# ---------------------------------------------------------------------------
# Pipeline: matrix batch entries + device-side codepoint emission


def test_matrix_pipeline_batch_transcode_utf32():
    L = 1536
    langs = ["latin", "chinese", "emoji"]
    docs = np.zeros((3, L), np.uint8)
    lens = []
    for i, lang in enumerate(langs):
        d = synthetic.utf8_array(lang, 300, seed=i)[:L]
        docs[i, : len(d)] = d
        lens.append(len(d))
    lens = np.asarray(lens, np.int32)
    for strategy in ("packed", "vmap"):
        res = pipeline.batch_transcode(docs, lens, in_encoding="utf8",
                                       out_encoding="utf32",
                                       strategy=strategy)
        assert res.buffer.shape == (3, L)
        for i in range(3):
            text = bytes(docs[i, : lens[i]]).decode("utf-8")
            assert int(res.status[i]) == -1, (strategy, i)
            assert int(res.count[i]) == len(text), (strategy, i)
            assert np.array_equal(
                np.asarray(res.buffer[i])[: len(text)].astype(np.int64),
                np.array([ord(c) for c in text], np.int64)), (strategy, i)


def test_matrix_pipeline_emits_codepoints_on_device():
    cfg = pipeline.PipelineConfig(seq_len=512, global_batch=2,
                                  emit="codepoints")
    p = pipeline.TextPipeline(cfg)
    batch = p.next_batch()
    assert "codepoints" in batch and "cp_counts" in batch
    assert batch["codepoints"].shape[0] == 2
    # cross-check one document against the host decode
    doc = p._doc_bytes(0, 0)
    text = bytes(doc).decode("utf-8")
    assert int(batch["cp_counts"][0]) == len(text)
    assert np.array_equal(
        np.asarray(batch["codepoints"][0])[: len(text)].astype(np.int64),
        np.array([ord(c) for c in text], np.int64))


def test_matrix_pipeline_rejects_unknown_pair():
    with pytest.raises(ValueError):
        pipeline.batch_transcode(np.zeros((1, 8), np.uint8),
                                 np.array([4], np.int32),
                                 in_encoding="utf8", out_encoding="utf8")
