"""Shard-level fault tolerance (DESIGN.md §10): the supervised sharded
launch (retry / watchdog / degraded-mesh replan), the hardened
double-buffered feeder, and the serve engine's circuit breaker.

Chaos contract under test: every injected fault class — shard launch
error, shard hang, stage-thread error, persistent device-path failure —
ends in a retried success, a degraded-but-BIT-IDENTICAL replan, or a
typed error.  Never a lost or orphaned wave, and a persistently-open
breaker launches nothing but probes.

Multi-device degraded-replan cases follow the test_shard convention:
skipped unless the process has >= 8 devices (the CI chaos job and
``scripts/check.sh --chaos`` re-run this module under
``XLA_FLAGS=--xla_force_host_platform_device_count=8``), plus an
always-run forced-8-device subprocess smoke.
"""

import threading
import time

import numpy as np
import pytest

import jax
from jax.sharding import Mesh

from repro.core import packing, recovery, shard
from repro.core import transcode as tc
from repro.data import shard_feed
from repro.models import registry
from repro.serve.engine import Engine, Request
from repro.testing import faults

from tests.test_shard import (_FULL_FUZZ_REASON, _assert_result_equal,
                              _docs_for, _run)

NOP = recovery.RetryPolicy(backoff_base_s=0.0)


def _packed(seed=20260801, n_docs=5, n_chars=200):
    docs = _docs_for("utf8", n_docs=n_docs, n_chars=n_chars, seed=seed)
    return packing.pack_documents(docs, dtype=np.uint8)


@pytest.fixture(scope="module")
def pk():
    return _packed()


@pytest.fixture(scope="module")
def ref(pk):
    return tc.ragged_transcode(pk.data, pk.offsets, pk.lengths,
                               src_format="utf8", dst_format="utf16")


@pytest.fixture(scope="module")
def lm():
    fam, cfg, model = registry.get("bytelm-100m", reduced=True)
    params = model.init(jax.random.PRNGKey(0))
    return fam, cfg, model, params


def _mk_engine(lm, **kw):
    fam, cfg, model, params = lm
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_prompt", 64)
    kw.setdefault("max_new", 4)
    kw.setdefault("backoff_base_s", 0.0)
    kw.setdefault("sleep", lambda s: None)
    return Engine(model, cfg, fam, params, **kw)


def _mesh1():
    return Mesh(np.array(jax.devices()[:1]), ("data",))


# ---------------------------------------------------------------------------
# The ``hang`` fault kind.


def test_hang_kind_sleeps_then_passes_payload_through():
    with faults.harness(faults.Fault(faults.SHARD_LAUNCH, kind="hang",
                                     hang_s=0.03)) as h:
        t0 = time.monotonic()
        out = faults.fire(faults.SHARD_LAUNCH, "payload")
        assert time.monotonic() - t0 >= 0.02
        assert out == "payload"
    assert h.fired == [(faults.SHARD_LAUNCH, "hang", 1)]


def test_bad_kind_still_rejected():
    with pytest.raises(ValueError):
        faults.Fault(faults.SHARD_LAUNCH, kind="wedge")


# ---------------------------------------------------------------------------
# call_with_watchdog.


def test_watchdog_none_runs_inline():
    here = threading.current_thread()
    seen = []
    out = recovery.call_with_watchdog(
        lambda: seen.append(threading.current_thread()) or 41, None)
    assert out == 41 and seen == [here]


def test_watchdog_returns_result_and_propagates_errors():
    assert recovery.call_with_watchdog(lambda: 7, 10.0) == 7

    def boom():
        raise KeyError("inner")

    with pytest.raises(KeyError):
        recovery.call_with_watchdog(boom, 10.0)


def test_watchdog_trips_on_hang_with_fake_clock():
    """A call gated on an Event never finishes on its own; the watchdog
    (driven by an auto-advancing fake clock, no real waiting) must
    abandon it and raise the typed timeout."""
    gate = threading.Event()
    ticks = [0.0]

    def clk():
        ticks[0] += 1.0
        return ticks[0]

    try:
        t0 = time.monotonic()
        with pytest.raises(recovery.WatchdogTimeout) as ei:
            recovery.call_with_watchdog(lambda: gate.wait(), 5.0,
                                        clock=clk, poll_s=0.001,
                                        what="gated call")
        assert time.monotonic() - t0 < 2.0      # no real 5s wait
        assert "gated call" in str(ei.value)
        assert ei.value.timeout_s == 5.0
    finally:
        gate.set()      # release the abandoned worker


# ---------------------------------------------------------------------------
# Supervised sharded launches (single-device: retry + watchdog + typed
# exhaustion; the degraded replan needs >= 2 devices, below).


def test_supervised_clean_matches_unsupervised(pk, ref):
    log = recovery.SupervisionLog()
    res = recovery.supervised_ragged_transcode(
        pk.data, pk.offsets, pk.lengths, n_shards=1, policy=NOP, log=log)
    _assert_result_equal(ref, res, "supervised clean")
    assert log.attempts == [(1, 0, "ok")]
    assert (log.retries, log.replans, log.final_shards) == (0, 0, 1)


def test_supervised_transient_fault_retried_bit_identical(pk, ref):
    with faults.harness(faults.Fault(faults.SHARD_LAUNCH,
                                     times=(1,))) as h:
        log = recovery.SupervisionLog()
        res = recovery.supervised_ragged_transcode(
            pk.data, pk.offsets, pk.lengths, n_shards=1, policy=NOP,
            log=log)
    assert h.fires_at(faults.SHARD_LAUNCH) == 1
    _assert_result_equal(ref, res, "supervised transient")
    assert log.retries == 1 and log.replans == 0
    assert log.attempts == [(1, 0, "FaultInjected"), (1, 1, "ok")]


def test_supervised_hang_watchdog_retried_bit_identical(pk, ref):
    """A hung launch (``hang`` fault past the watchdog) is abandoned and
    retried; the retry's result is bit-identical.  Real clock: a fake
    auto-advancing clock cannot tell a hung attempt from a healthy one.
    ``ref`` has pre-warmed the executable, so the healthy retry runs
    well inside the watchdog."""
    pol = recovery.RetryPolicy(backoff_base_s=0.0, watchdog_s=0.5,
                               poll_s=0.002)
    t0 = time.monotonic()
    with faults.harness(faults.Fault(faults.SHARD_LAUNCH, kind="hang",
                                     hang_s=2.0, times=(1,))):
        log = recovery.SupervisionLog()
        res = recovery.supervised_ragged_transcode(
            pk.data, pk.offsets, pk.lengths, n_shards=1, policy=pol,
            log=log)
    assert time.monotonic() - t0 < 1.8, "watchdog did not abandon the hang"
    _assert_result_equal(ref, res, "supervised hang")
    assert log.attempts[0] == (1, 0, "WatchdogTimeout")
    assert log.final_shards == 1
    # Let the abandoned worker wake and finish INSIDE this test rather
    # than racing a later module's cache clear.
    time.sleep(2.1)


def test_supervised_persistent_fault_typed_exhaustion(pk):
    with faults.harness(faults.Fault(faults.SHARD_LAUNCH, times=None)):
        with pytest.raises(recovery.DegradedMeshExhausted) as ei:
            recovery.supervised_ragged_transcode(
                pk.data, pk.offsets, pk.lengths, n_shards=1,
                policy=recovery.RetryPolicy(max_retries=2,
                                            backoff_base_s=0.0))
    causes = ei.value.causes
    assert [(n, a) for n, a, _e in causes] == [(1, 0), (1, 1), (1, 2)]
    assert all(isinstance(e, faults.FaultInjected) for _n, _a, e in causes)
    assert isinstance(ei.value, recovery.ShardFaultError)


def test_supervised_backoff_schedule_is_exponential(pk):
    slept = []
    pol = recovery.RetryPolicy(max_retries=3, backoff_base_s=0.05,
                               sleep=slept.append)
    with faults.harness(faults.Fault(faults.SHARD_LAUNCH, times=None)):
        with pytest.raises(recovery.DegradedMeshExhausted):
            recovery.supervised_ragged_transcode(
                pk.data, pk.offsets, pk.lengths, n_shards=1, policy=pol)
    assert slept == [0.05, 0.1, 0.2]


def test_supervised_min_shards_validated(pk):
    with pytest.raises(ValueError):
        recovery.supervised_ragged_transcode(
            pk.data, pk.offsets, pk.lengths, n_shards=1,
            policy=recovery.RetryPolicy(min_shards=2))


def test_supervised_scan_transient_retry(pk):
    want_c, want_s = tc.ragged_scan(pk.data, pk.offsets, pk.lengths,
                                    src_format="utf8", dst_format="utf16")
    with faults.harness(faults.Fault(faults.SHARD_LAUNCH, times=(1,))):
        got_c, got_s = recovery.supervised_scan_ragged(
            pk.data, pk.offsets, pk.lengths, n_shards=1, policy=NOP)
    assert np.array_equal(np.asarray(want_c), np.asarray(got_c))
    assert np.array_equal(np.asarray(want_s), np.asarray(got_s))


def test_degraded_mesh_is_device_prefix():
    full = _mesh1()
    sub = recovery.degraded_mesh(full, 1)
    assert sub.axis_names == ("data",)
    assert list(sub.devices.flat) == list(full.devices.flat)[:1]
    with pytest.raises(ValueError):
        recovery.degraded_mesh(full, 2)
    with pytest.raises(ValueError):
        recovery.degraded_mesh(full, 0)


# ---------------------------------------------------------------------------
# Degraded-mesh replan: >= 8 devices (CI chaos job) or subprocess.


@pytest.mark.skipif(jax.device_count() < 8, reason=_FULL_FUZZ_REASON)
def test_degraded_replan_bit_identical_8dev(pk, ref):
    """All attempts at 4 shards fail -> the supervisor re-plans onto 3
    devices, whose cut rules + gather make the result bit-identical to
    the single-device path.  The fault's call indices pin the shape:
    calls 1-3 are the 4-shard attempts, call 4 is the replan."""
    mesh = Mesh(np.array(jax.devices()[:4]), ("data",))
    with faults.harness(faults.Fault(faults.SHARD_LAUNCH,
                                     times=(1, 2, 3))) as h:
        log = recovery.SupervisionLog()
        res = recovery.supervised_ragged_transcode(
            pk.data, pk.offsets, pk.lengths, mesh=mesh,
            policy=recovery.RetryPolicy(max_retries=2, backoff_base_s=0.0),
            log=log)
    assert h.calls[faults.SHARD_LAUNCH] == 4
    _assert_result_equal(ref, res, "degraded replan")
    assert log.replans == 1 and log.final_shards == 3
    assert log.attempts[-1] == (3, 0, "ok")


@pytest.mark.skipif(jax.device_count() < 8, reason=_FULL_FUZZ_REASON)
def test_degraded_replan_exhausted_min_shards_8dev(pk):
    """min_shards bounds the degradation ladder: with every size failing,
    the typed exhaustion names sizes 4, 3, 2 — never 1."""
    mesh = Mesh(np.array(jax.devices()[:4]), ("data",))
    with faults.harness(faults.Fault(faults.SHARD_LAUNCH, times=None)):
        with pytest.raises(recovery.DegradedMeshExhausted) as ei:
            recovery.supervised_ragged_transcode(
                pk.data, pk.offsets, pk.lengths, mesh=mesh,
                policy=recovery.RetryPolicy(max_retries=0,
                                            backoff_base_s=0.0,
                                            min_shards=2))
    assert [n for n, _a, _e in ei.value.causes] == [4, 3, 2]


@pytest.mark.skipif(jax.device_count() < 8, reason=_FULL_FUZZ_REASON)
def test_degraded_scan_replan_bit_identical_8dev(pk):
    want_c, want_s = tc.ragged_scan(pk.data, pk.offsets, pk.lengths,
                                    src_format="utf8", dst_format="utf16")
    mesh = Mesh(np.array(jax.devices()[:2]), ("data",))
    with faults.harness(faults.Fault(faults.SHARD_LAUNCH, times=(1,))):
        got_c, got_s = recovery.supervised_scan_ragged(
            pk.data, pk.offsets, pk.lengths, mesh=mesh,
            policy=recovery.RetryPolicy(max_retries=0, backoff_base_s=0.0),
            log=(log := recovery.SupervisionLog()))
    assert log.replans == 1 and log.final_shards == 1
    assert np.array_equal(np.asarray(want_c), np.asarray(got_c))
    assert np.array_equal(np.asarray(want_s), np.asarray(got_s))


def test_degraded_replan_8dev_subprocess_smoke():
    """Always-run replan proof in a forced-8-device subprocess: persistent
    failure at 8 and 7 shards, success at 6 — bit-identical to the
    single-device reference, with the supervision log pinning the path."""
    _run("""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax
assert jax.device_count() == 8
from repro.core import packing, recovery, transcode as tc
from repro.data import synthetic
from repro.testing import faults

rng = np.random.default_rng(20260801)
langs = ["arabic", "latin", "chinese", "emoji"]
docs = [synthetic.utf8_array(langs[i % 4], int(rng.integers(1, 1200)),
                             seed=i) for i in range(9)]
poison = synthetic.utf8_array("latin", 300, seed=7).copy()
poison[40] = 0xFF
docs[4] = poison
pk = packing.pack_documents(docs)
ref = tc.ragged_transcode(pk.data, pk.offsets, pk.lengths,
                          src_format="utf8", dst_format="utf16")
# max_retries=1 -> two attempts per size; calls 1-2 fail at 8 shards,
# calls 3-4 fail at 7, call 5 succeeds at 6.
pol = recovery.RetryPolicy(max_retries=1, backoff_base_s=0.0)
with faults.harness(faults.Fault(faults.SHARD_LAUNCH,
                                 times=(1, 2, 3, 4))) as h:
    log = recovery.SupervisionLog()
    res = recovery.supervised_ragged_transcode(
        pk.data, pk.offsets, pk.lengths, n_shards=8, policy=pol, log=log)
assert h.calls[faults.SHARD_LAUNCH] == 5, h.calls
assert log.replans == 2 and log.final_shards == 6, log
assert log.retries == 2, log
for name in ("buffer", "offsets", "counts", "statuses"):
    a, b = np.asarray(getattr(ref, name)), np.asarray(getattr(res, name))
    assert a.shape == b.shape and (a == b).all(), name
print("PASS")
""")


# ---------------------------------------------------------------------------
# Hardened feeder: typed per-wave errors, isolation, watchdog, no
# orphaned futures.


def test_feeder_stage_error_typed_and_isolated():
    """A stage-thread exception becomes a typed WaveFailure in that
    wave's slot; every other wave still serves — zero lost waves."""
    def stage(arrays):
        if arrays[0] == "poison":
            raise RuntimeError("stage blew up")
        return arrays

    with shard_feed.DoubleBufferedFeeder(_mesh1(), stage_fn=stage) as f:
        waves = [("w0",), ("poison",), ("w2",), ("w3",)]
        res, stats = f.run(waves, lambda x: x.upper())
    assert len(res) == len(stats) == len(waves)          # nothing lost
    assert [r for r in res if not isinstance(r, shard_feed.WaveFailure)] \
        == ["W0", "W2", "W3"]
    bad = res[1]
    assert isinstance(bad, shard_feed.WaveFailure)
    assert (bad.wave, bad.phase) == (1, "stage")
    assert isinstance(bad.error, RuntimeError)
    assert "stage" in str(bad)


def test_feeder_launch_error_typed_and_isolated():
    def launch(x):
        if x == "boom":
            raise ValueError("kernel died")
        return x

    with shard_feed.DoubleBufferedFeeder(_mesh1(),
                                         stage_fn=lambda a: a) as f:
        res, _ = f.run([("ok0",), ("boom",), ("ok2",)], launch)
    assert res[0] == "ok0" and res[2] == "ok2"
    assert isinstance(res[1], shard_feed.WaveFailure)
    assert (res[1].wave, res[1].phase) == (1, "launch")


def test_feeder_launch_raise_does_not_orphan_future():
    """Satellite regression: with isolate=False a mid-loop launch raise
    propagates, but the already-submitted staging future for the NEXT
    wave must be drained/cancelled — close() returns promptly instead
    of blocking on orphaned work."""
    staged = []

    def stage(arrays):
        staged.append(arrays[0])
        return arrays

    f = shard_feed.DoubleBufferedFeeder(_mesh1(), stage_fn=stage,
                                        isolate=False)

    def launch(x):
        raise ValueError("die on wave 0")

    with pytest.raises(ValueError):
        f.run([("w0",), ("w1",), ("w2",)], launch)
    assert f._inflight is None          # drained in the finally
    t0 = time.monotonic()
    f.close()
    assert time.monotonic() - t0 < 1.0
    # The in-flight "w1" stage was either cancelled before it started or
    # consumed; "w2" was never submitted.  Either way: not orphaned.
    assert staged in (["w0"], ["w0", "w1"])


def test_feeder_waves_iterator_raise_does_not_orphan_future():
    def bad_waves():
        yield ("w0",)
        yield ("w1",)
        raise RuntimeError("iterator died")

    f = shard_feed.DoubleBufferedFeeder(_mesh1(), stage_fn=lambda a: a)
    with pytest.raises(RuntimeError):
        f.run(bad_waves(), lambda v: v)
    assert f._inflight is None
    t0 = time.monotonic()
    f.close()
    assert time.monotonic() - t0 < 1.0


def test_feeder_stage_hang_watchdog_isolates_and_respawns():
    """A HUNG stage (gated on an Event, fake clock) trips the watchdog,
    surfaces typed, and — because the one staging worker is wedged —
    the pool respawns so later waves still stage and serve."""
    gate = threading.Event()
    ticks = [0.0]

    def clk():
        ticks[0] += 0.5
        return ticks[0]

    def stage(arrays):
        if arrays[0] == "hang":
            gate.wait()
        return arrays

    try:
        f = shard_feed.DoubleBufferedFeeder(
            _mesh1(), stage_fn=stage, clock=clk, watchdog_s=30.0,
            poll_s=0.001)
        res, _ = f.run([("hang",), ("w1",), ("w2",)], lambda v: v)
        assert isinstance(res[0], shard_feed.WaveFailure)
        assert (res[0].wave, res[0].phase) == (0, "stage")
        assert isinstance(res[0].error, recovery.WatchdogTimeout)
        assert res[1] == "w1" and res[2] == "w2"
        t0 = time.monotonic()
        f.close(wait=False)             # escape hatch: no join on the hang
        assert time.monotonic() - t0 < 1.0
    finally:
        gate.set()                      # unblock the abandoned worker


def test_feeder_launch_hang_watchdog_typed():
    gate = threading.Event()
    ticks = [0.0]

    def clk():
        ticks[0] += 0.5
        return ticks[0]

    def launch(x):
        if x == "hang":
            gate.wait()
        return x

    try:
        with shard_feed.DoubleBufferedFeeder(
                _mesh1(), stage_fn=lambda a: a, clock=clk,
                watchdog_s=30.0, poll_s=0.001) as f:
            res, _ = f.run([("hang",), ("w1",)], launch)
        assert isinstance(res[0], shard_feed.WaveFailure)
        assert (res[0].wave, res[0].phase) == (0, "launch")
        assert isinstance(res[0].error, recovery.WatchdogTimeout)
        assert res[1] == "w1"
    finally:
        gate.set()


def test_feeder_feed_stage_fault_point(pk, ref):
    """The FEED_STAGE chaos hook fires in the stage thread on real
    sharded waves: the faulted wave fails typed, the clean wave's
    gathered result stays bit-identical."""
    mesh = _mesh1()
    plan = shard.plan_shards(np.asarray(pk.data), np.asarray(pk.offsets),
                             np.asarray(pk.lengths), 1, src="utf8")
    with faults.harness(faults.Fault(faults.FEED_STAGE, times=(1,))) as h:
        outs, stats = shard_feed.run_sharded_waves(
            mesh, [plan, plan], src="utf8", dst="utf16")
    assert h.calls[faults.FEED_STAGE] == 2
    assert len(outs) == len(stats) == 2
    assert isinstance(outs[0], shard_feed.WaveFailure)
    assert outs[0].phase == "stage"
    assert isinstance(outs[0].error, faults.FaultInjected)
    bufs, oos, counts, statuses = outs[1]
    from repro.kernels import stages
    _cs, codec_d, factor = stages.get_pair("utf8", "utf16")
    cap = factor * max(1, -(-int(np.asarray(pk.data).shape[0])
                            // packing.TILE)) * packing.TILE
    got = shard._gather_result(plan, cap, codec_d.dtype,
                               np.asarray(bufs), np.asarray(oos),
                               np.asarray(counts), np.asarray(statuses),
                               True)
    _assert_result_equal(ref, got, "post-fault wave")


def test_feeder_empty_waves_after_hardening():
    with shard_feed.DoubleBufferedFeeder(_mesh1(),
                                         stage_fn=lambda a: a) as f:
        assert f.run([], lambda v: v) == ([], [])


# ---------------------------------------------------------------------------
# Serve-engine circuit breaker.


def test_breaker_trips_open_and_skips_retry_storm(lm):
    """threshold consecutive chunk failures open the breaker; while open
    every chunk serves via the host fallback with ZERO device launches
    and ZERO retries — the storm the breaker exists to prevent."""
    e = _mk_engine(lm, max_retries=2, breaker_threshold=2,
                   breaker_cooldown_s=1e9)
    assert e.serve([Request(b"warm")])[0].ok
    with faults.harness(faults.Fault(faults.KERNEL_RAGGED_SCAN,
                                     times=None)) as h:
        assert e.serve([Request(b"f1")])[0].ok      # fallback, retries paid
        assert e.serve([Request(b"f2")])[0].ok      # second failure -> open
        assert e._breakers["utf-8"].state == "open"
        assert e.counters["breaker_open"] == 1
        retries_at_open = e.counters["retries"]
        calls_at_open = h.calls[faults.KERNEL_RAGGED_SCAN]
        for i in range(4):                          # open: no launches at all
            assert e.serve([Request(b"skip%d" % i)])[0].ok
        assert h.calls[faults.KERNEL_RAGGED_SCAN] == calls_at_open
        assert e.counters["retries"] == retries_at_open
        assert e.counters["breaker_skip"] >= 4
        assert e.counters["fallback"] >= 6


def test_breaker_open_event_in_drain_log(lm):
    e = _mk_engine(lm, max_retries=0, breaker_threshold=1)
    with faults.harness(faults.Fault(faults.KERNEL_RAGGED_SCAN,
                                     times=None)):
        assert e.serve([Request(b"x")])[0].ok
    kinds = [k for k, *_ in e.events]
    assert "breaker_open" in kinds
    k, group, slot, _step, _wall = \
        [ev for ev in e.events if ev[0] == "breaker_open"][0]
    assert group == "utf-8" and slot == -1


def test_breaker_half_open_probe_failure_reopens(lm):
    now = [0.0]
    e = _mk_engine(lm, max_retries=0, breaker_threshold=1,
                   breaker_cooldown_s=10.0, clock=lambda: now[0])
    with faults.harness(faults.Fault(faults.KERNEL_RAGGED_SCAN,
                                     times=None)) as h:
        assert e.serve([Request(b"trip")])[0].ok
        assert e._breakers["utf-8"].state == "open"
        now[0] += 10.0                              # cooldown up
        calls0 = h.calls[faults.KERNEL_RAGGED_SCAN]
        assert e.serve([Request(b"probe")])[0].ok   # probe fails
        assert h.calls[faults.KERNEL_RAGGED_SCAN] == calls0 + 1  # ONE launch
    assert e._breakers["utf-8"].state == "open"
    assert e.counters["breaker_probe"] == 1
    assert e.counters["breaker_half_open"] == 1
    assert e.counters["breaker_open"] == 2
    assert e.counters["retries"] == 0               # probes never retry


def test_breaker_recovers_via_successful_probe(lm):
    now = [0.0]
    e = _mk_engine(lm, max_retries=0, breaker_threshold=1,
                   breaker_cooldown_s=5.0, clock=lambda: now[0])
    assert e.serve([Request(b"warm")])[0].ok
    with faults.harness(faults.Fault(faults.KERNEL_RAGGED_SCAN,
                                     times=None)):
        assert e.serve([Request(b"trip")])[0].ok
    assert e._breakers["utf-8"].state == "open"
    now[0] += 5.0
    with faults.harness() as h:                      # fault gone; count calls
        r = e.serve([Request(b"recovered")])[0]
    assert r.ok and r.text_bytes is not None
    assert e._breakers["utf-8"].state == "closed"
    assert h.calls[faults.KERNEL_RAGGED_SCAN] == 1   # the probe carried it
    kinds = [k for k, *_ in e.events]
    assert kinds.index("breaker_half_open") < kinds.index("breaker_closed")
    assert e.counters["breaker_closed"] == 1
    # Fully closed again: subsequent chunks run the normal full path.
    assert e.serve([Request(b"steady")])[0].ok
    assert e._breakers["utf-8"].state == "closed"


def test_breaker_engine_probe_fault_point(lm):
    """The probe launch itself is a fault point: ENGINE_PROBE faults
    fail the probe before any kernel runs, re-opening the breaker."""
    e = _mk_engine(lm, max_retries=0, breaker_threshold=1,
                   breaker_cooldown_s=0.0)
    with faults.harness(faults.Fault(faults.KERNEL_RAGGED_SCAN,
                                     times=(1,))):
        assert e.serve([Request(b"trip")])[0].ok
    assert e._breakers["utf-8"].state == "open"
    with faults.harness(faults.Fault(faults.ENGINE_PROBE,
                                     times=(1,))) as h:
        assert e.serve([Request(b"probe")])[0].ok    # probe itself faulted
    assert h.fires_at(faults.ENGINE_PROBE) == 1
    assert e._breakers["utf-8"].state == "open"
    assert e.serve([Request(b"again")])[0].ok        # next probe heals
    assert e._breakers["utf-8"].state == "closed"


def test_breaker_groups_are_independent(lm):
    """A persistently-failing unit-encoding group opens ITS breaker;
    the utf-8 group stays closed and on the device path."""
    e = _mk_engine(lm, max_retries=0, breaker_threshold=1)
    p16 = "hi".encode("utf-16-le")
    assert e.serve([Request(b"warm")])[0].ok     # compile the scan cell
    with faults.harness(faults.Fault(faults.KERNEL_RAGGED,
                                     times=None)) as h:
        assert e.serve([Request(p16, in_encoding="utf-16-le")])[0].ok
        assert e._breakers["utf-16-le:strict"].state == "open"
        scans0 = h.calls.get(faults.KERNEL_RAGGED_SCAN, 0)
        assert e.serve([Request(b"utf8 fine")])[0].ok
        assert h.calls[faults.KERNEL_RAGGED_SCAN] == scans0 + 1  # device path
    assert e._breakers["utf-8"].state == "closed"


def test_breaker_open_covers_replace_sanitize_path(lm):
    """With the utf-8 breaker open, a dirty replace-mode prompt must not
    pay its own per-request retry storm: zero device launches, served
    via the host sanitize."""
    e = _mk_engine(lm, max_retries=2, breaker_threshold=1,
                   breaker_cooldown_s=1e9)
    with faults.harness(faults.Fault(faults.KERNEL_RAGGED_SCAN,
                                     times=None)) as h:
        assert e.serve([Request(b"trip")])[0].ok
        assert e._breakers["utf-8"].state == "open"
        retries0 = e.counters["retries"]
        r = e.serve([Request(b"bad \xff byte", errors="replace")])[0]
        assert r.ok
        assert r.sanitized_prompt == \
            b"bad \xff byte".decode("utf-8", "replace").encode("utf-8")
        assert e.counters["retries"] == retries0
        assert h.calls.get(faults.KERNEL_ONEPASS, 0) == 0
