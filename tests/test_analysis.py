"""Tests for the measurement stack itself: the jaxpr cost model and the
HLO collective parser.  These are the §Roofline sources of truth, so they
get the same scrutiny as the kernels (a wrong profiler silently corrupts
every §Perf decision — EXPERIMENTS.md lesson 4)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import lax

from repro import costmodel as CM
from repro import roofline as RL


# ---------------------------------------------------------------------------
# costmodel: exact FLOPs on known programs


def test_matmul_flops_exact():
    a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    c = CM.fn_cost(lambda x, y: x @ y, a, b)
    assert c.flops == 2 * 64 * 128 * 32
    assert c.bytes == 4 * (64 * 128 + 128 * 32 + 64 * 32)


def test_scan_multiplies_trip_count():
    a = jax.ShapeDtypeStruct((16, 16), jnp.float32)

    def f(x):
        def body(h, _):
            return h @ h, None
        y, _ = lax.scan(body, x, None, length=7)
        return y

    c = CM.fn_cost(f, a)
    assert c.flops >= 7 * 2 * 16 ** 3       # 7 iterations counted
    assert c.flops < 8 * 2 * 16 ** 3 + 1000


def test_batched_dot_general():
    a = jax.ShapeDtypeStruct((4, 8, 16), jnp.float32)
    b = jax.ShapeDtypeStruct((4, 16, 8), jnp.float32)
    c = CM.fn_cost(lambda x, y: jnp.einsum("bij,bjk->bik", x, y), a, b)
    assert c.flops == 4 * 2 * 8 * 16 * 8


def test_grad_includes_backward():
    a = jax.ShapeDtypeStruct((32, 32), jnp.float32)

    def loss(w):
        return jnp.sum((w @ w) ** 2)

    fwd = CM.fn_cost(loss, a)
    both = CM.fn_cost(jax.grad(loss), a)
    assert both.flops > 2 * fwd.flops    # backward ~2x forward for matmuls


def test_remat_recompute_counted():
    a = jax.ShapeDtypeStruct((32, 32), jnp.float32)

    def block(w):
        return jnp.sum(jnp.tanh(w @ w) @ w)

    plain = CM.fn_cost(jax.grad(block), a)
    rematted = CM.fn_cost(jax.grad(jax.checkpoint(block)), a)
    assert rematted.flops > plain.flops  # recompute shows up


# ---------------------------------------------------------------------------
# HLO collective parser


_FAKE_HLO = """
HloModule jit_f

%region_body (p: (s32[], f32[16,8])) -> (s32[], f32[16,8]) {
  %ag = f32[16,8]{1,0} all-gather(%x), dimensions={1}
  ROOT %t = tuple(...)
}

%region_cond (p: (s32[], f32[16,8])) -> pred[] {
  ROOT %lt = pred[] compare(%a, %b), direction=LT
}

ENTRY %main (a: f32[16,8]) -> f32[16,8] {
  %ar = f32[4,4]{1,0} all-reduce(%a), replica_groups={}
  %w = (s32[], f32[16,8]) while(%init), condition=%region_cond, body=%region_body, backend_config={"known_trip_count":{"n":"5"}}
  ROOT %r = f32[16,8] get-tuple-element(%w), index=1
}
"""


def test_collective_parser_trip_counts():
    out = RL.collective_bytes(_FAKE_HLO)
    # all-reduce in ENTRY: 4*4*4 = 64 B, counted once
    assert out["all-reduce"] == 64
    # all-gather inside the while body: 16*8*4 = 512 B x trip 5
    assert out["all-gather"] == 512 * 5
    assert out["_counts"]["all-gather"] == 5


def test_shape_bytes_tuple_and_dtypes():
    assert RL._shape_bytes("f32[10,10]") == 400
    assert RL._shape_bytes("bf16[8]") == 16
    assert RL._shape_bytes("(f32[4], s8[16])") == 16 + 16
    assert RL._shape_bytes("pred[]") == 0 or RL._shape_bytes("pred[]") == 1


def test_roofline_terms_and_fraction():
    rl = RL.Roofline(arch="x", shape="train_4k", mesh="16x16", chips=256,
                     hlo_flops=1e18, hlo_bytes=1e15, coll_bytes=1e14,
                     coll_detail={}, model_flops=5e17)
    # terms
    assert rl.t_compute == pytest.approx(1e18 / (256 * RL.PEAK_FLOPS))
    assert rl.t_memory == pytest.approx(1e15 / (256 * RL.HBM_BW))
    assert rl.t_collective == pytest.approx(1e14 / (256 * RL.ICI_BW))
    assert rl.bottleneck == "compute"
    # fraction: ideal/binding <= 1, equals model/hlo ratio here
    assert 0 < rl.roofline_fraction <= 1
    assert rl.roofline_fraction == pytest.approx(0.5)


def test_active_params_moe():
    from repro.models import registry
    _, cfg, _ = registry.get("grok-1-314b")
    import repro.roofline as R
    n = 314e9
    act = R.active_params(cfg, int(n))
    assert act < n * 0.4          # top-2 of 8 experts -> ~26% active
    _, dcfg, _ = registry.get("qwen3-8b")
    assert R.active_params(dcfg, 8_000_000_000) == 8_000_000_000
