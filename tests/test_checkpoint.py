"""Checkpoint protocol: roundtrip, elastic reshard, atomicity."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import registry
from repro.train import checkpoint as CK
from repro.train import optimizer as O


@pytest.fixture
def tree():
    fam, cfg, model = registry.get("bytelm-100m", reduced=True)
    params = model.init(jax.random.PRNGKey(0))
    return {"params": params, "opt": O.init_opt_state(params)}


def _trees_equal(a, b):
    return all(np.allclose(np.asarray(x, np.float32), np.asarray(y, np.float32))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def test_roundtrip(tree, tmp_path):
    CK.save(str(tmp_path), 5, tree)
    assert CK.latest_step(str(tmp_path)) == 5
    restored = CK.restore(str(tmp_path), 5, tree)
    assert _trees_equal(tree, restored)


def test_elastic_save4_restore_any(tree, tmp_path):
    for h in range(4):
        CK.save(str(tmp_path), 7, tree, host_id=h, n_hosts=4)
    CK.publish(str(tmp_path), 7)
    restored = CK.restore(str(tmp_path), 7, tree)
    assert _trees_equal(tree, restored)


def test_atomicity_crash_mid_save(tree, tmp_path):
    """A .tmp dir from a crashed save must be invisible to latest_step."""
    CK.save(str(tmp_path), 3, tree)
    # simulate a crash: partial save of step 4, never published
    CK.save(str(tmp_path), 4, tree, host_id=0, n_hosts=2)  # no publish
    assert CK.latest_step(str(tmp_path)) == 3
    restored = CK.restore(str(tmp_path), 3, tree)
    assert _trees_equal(tree, restored)


def test_overwrite_same_step(tree, tmp_path):
    CK.save(str(tmp_path), 5, tree)
    bumped = jax.tree.map(lambda x: x + 1 if x.dtype != jnp.int32 else x,
                          tree)
    CK.save(str(tmp_path), 5, bumped)
    restored = CK.restore(str(tmp_path), 5, tree)
    assert _trees_equal(bumped, restored)


def test_manifest_contents(tree, tmp_path):
    CK.save(str(tmp_path), 1, tree)
    with open(os.path.join(str(tmp_path), "step_1", "manifest.json")) as f:
        m = json.load(f)
    assert m["step"] == 1
    n_leaves = len(jax.tree.leaves(tree))
    assert len(m["leaves"]) == n_leaves
