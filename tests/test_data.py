"""Data pipeline: Table-4 profile fidelity, determinism, elastic sharding."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import transcode as tc
from repro.data import pipeline as P
from repro.data import synthetic
from repro.data.tokenizer import ByteTokenizer, CodepointTokenizer, N_SPECIAL


@pytest.mark.parametrize("lang,expect2,expect3",
                         [("arabic", 0.78, 0.0), ("chinese", 0.0, 0.99),
                          ("latin", 0.0, 0.0), ("korean", 0.01, 0.72)])
def test_profiles_match_table4(lang, expect2, expect3):
    b = synthetic.utf8_array(lang, 30000, seed=3)
    lead = (b & 0xC0) != 0x80
    nch = lead.sum()
    f2 = ((b >= 0xC0) & (b < 0xE0)).sum() / nch
    f3 = ((b >= 0xE0) & (b < 0xF0)).sum() / nch
    assert abs(f2 - expect2) < 0.02
    assert abs(f3 - expect3) < 0.02


@pytest.mark.parametrize("lang", list(synthetic.LANG_PROFILES))
def test_generated_utf8_is_valid(lang):
    b = synthetic.utf8_array(lang, 5000, seed=1).astype(np.int32)
    assert bool(tc.validate_utf8(jnp.asarray(b), len(b)))


def test_pipeline_deterministic():
    cfg = P.PipelineConfig(seq_len=128, global_batch=4)
    a = P.TextPipeline(cfg).next_batch()
    b = P.TextPipeline(cfg).next_batch()
    assert np.array_equal(a["tokens"], b["tokens"])


def test_pipeline_skip_ahead():
    cfg = P.PipelineConfig(seq_len=128, global_batch=4)
    p1 = P.TextPipeline(cfg)
    for _ in range(3):
        p1.next_batch()
    want = p1.next_batch()
    p2 = P.TextPipeline(cfg)
    p2.skip_to(3)
    got = p2.next_batch()
    assert np.array_equal(want["tokens"], got["tokens"])


def test_pipeline_elastic_host_invariance():
    """Global batch content is invariant to the host count."""
    cfg1 = P.PipelineConfig(seq_len=128, global_batch=4, n_hosts=1)
    full = P.TextPipeline(cfg1).next_batch()["tokens"]
    parts = []
    for h in range(2):
        cfg = P.PipelineConfig(seq_len=128, global_batch=4, n_hosts=2,
                               host_id=h)
        parts.append(P.TextPipeline(cfg).next_batch()["tokens"])
    combined = np.zeros_like(full)
    combined[0::2] = parts[0]   # host 0 owns slots 0, 2
    combined[1::2] = parts[1]
    assert np.array_equal(np.asarray(full), combined)


def test_pipeline_host_sharding_never_touches_other_hosts_docs(monkeypatch):
    """Host k's shard (which feeds device shard k on the sharded
    transcode path) must iterate ONLY its own global slots — the other
    hosts' documents are never materialized, not even to be skipped."""
    cfg = P.PipelineConfig(seq_len=128, global_batch=8, n_hosts=4,
                           host_id=1)
    pipe = P.TextPipeline(cfg)
    seen = []
    orig = P.TextPipeline._doc_bytes

    def spy(self, step, slot):
        seen.append((step, slot))
        return orig(self, step, slot)

    monkeypatch.setattr(P.TextPipeline, "_doc_bytes", spy)
    for _ in range(3):
        pipe.next_batch()
    assert seen, "spy never fired"
    for step, slot in seen:
        assert slot % cfg.n_hosts == cfg.host_id, \
            f"host {cfg.host_id} materialized foreign slot {slot}"
    # Exactly local_batch requests per step — no skip-by-materializing.
    assert len(seen) == 3 * pipe.local_batch
    # And the shard content still matches the single-host global batch.
    monkeypatch.setattr(P.TextPipeline, "_doc_bytes", orig)
    full = P.TextPipeline(P.PipelineConfig(
        seq_len=128, global_batch=8, n_hosts=1)).next_batch()["tokens"]
    mine = P.TextPipeline(cfg).next_batch()["tokens"]
    assert np.array_equal(np.asarray(full)[1::4], np.asarray(mine))


def test_labels_shifted_and_masked():
    cfg = P.PipelineConfig(seq_len=64, global_batch=1, langs=("latin",))
    b = P.TextPipeline(cfg).next_batch()
    toks, labs = np.asarray(b["tokens"][0]), np.asarray(b["labels"][0])
    # label at i == token at i+1 wherever loss is active
    active = labs >= 0
    assert (labs[active] == np.roll(toks, -1)[active]).all()
    assert (labs[-1] == -1) or (toks[-1] != 0)


def test_byte_tokenizer_roundtrip():
    tok = ByteTokenizer()
    b = jnp.asarray(np.frombuffer("héllo".encode(), np.uint8).astype(np.int32))
    ids = tok.encode(b)
    assert int(ids.min()) >= N_SPECIAL
    back = tok.decode(ids)
    assert np.array_equal(np.asarray(back), np.asarray(b))


def test_codepoint_tokenizer_in_range():
    tok = CodepointTokenizer(vocab_size=1000)
    cps = jnp.asarray([65, 0x4E2D, 0x1F389, 0x10FFFF])
    ids = tok.encode(cps)
    assert int(ids.min()) >= 0 and int(ids.max()) < 1000
