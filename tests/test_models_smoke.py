"""Per-architecture smoke tests: reduced config, 1 forward + 1 train step
on CPU, asserting output shapes and finite values (assignment deliverable
f), plus prefill/decode consistency for every LM family."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import registry
from repro.train import optimizer as O
from repro.train import train_step as TS

ARCHS = list(configs.ARCH_IDS)


def _batch(fam, cfg, key, B=2, S=32):
    toks = jax.random.randint(key, (B, S), 3, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    if fam == "encdec":
        batch["frames"] = jax.random.normal(
            key, (B, cfg.n_audio_frames, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    fam, cfg, model = registry.get(arch, reduced=True)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    B, S = 2, 32
    toks = jax.random.randint(key, (B, S), 3, cfg.vocab)
    if fam == "encdec":
        frames = jax.random.normal(key, (B, cfg.n_audio_frames, cfg.d_model))
        logits, _, aux = model.apply(params, frames, toks)
    elif fam == "vlm":
        logits, _, aux = model.apply_text(params, toks)
    else:
        logits, _, aux = model.apply(params, toks)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch):
    fam, cfg, model = registry.get(arch, reduced=True)
    key = jax.random.PRNGKey(1)
    params = model.init(key)
    opt_state = O.init_opt_state(params)
    step = jax.jit(TS.make_train_step(model, fam, O.AdamWConfig(
        total_steps=10, warmup_steps=1)))
    batch = _batch(fam, cfg, key)
    new_params, new_opt, metrics = step(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params must actually change
    changed = any(
        not np.allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params)))
    assert changed


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if configs.get_module(a).FAMILY != "encdec"])
def test_prefill_decode_matches_full_forward(arch):
    fam, cfg, model = registry.get(arch, reduced=True)
    lm = getattr(model, "lm", model)
    key = jax.random.PRNGKey(2)
    params = model.init(key)
    B, S = 2, 16
    toks = jax.random.randint(key, (B, S + 1), 3, cfg.vocab)
    if fam == "vlm":
        full, _, _ = model.apply_text(params, toks)
    else:
        full, _, _ = model.apply(params, toks)
    state = lm.init_state(B, 64)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    if fam == "vlm":
        pos = jnp.broadcast_to(pos, (3, B, S))
    _, state, _ = lm.apply(params, toks[:, :S], pos=pos, state=state)
    p1 = jnp.full((B, 1), S, jnp.int32)
    if fam == "vlm":
        p1 = jnp.broadcast_to(p1, (3, B, 1))
    step, state, _ = lm.apply(params, toks[:, S:], pos=p1, state=state)
    np.testing.assert_allclose(np.asarray(step[:, 0]),
                               np.asarray(full[:, S]), atol=2e-3)


def test_vlm_multimodal_forward():
    fam, cfg, model = registry.get("qwen2-vl-2b", reduced=True)
    key = jax.random.PRNGKey(3)
    params = model.init(key)
    B, P, T = 2, 16, 8
    patches = jax.random.normal(key, (B, P, cfg.d_model))
    toks = jax.random.randint(key, (B, T), 3, cfg.vocab)
    logits, _, _ = model.apply(params, patches, toks)
    assert logits.shape == (B, P + T, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())


def test_mrope_text_equals_rope():
    """With equal position streams M-RoPE must equal standard RoPE."""
    from repro.models import common as C
    key = jax.random.PRNGKey(4)
    x = jax.random.normal(key, (2, 8, 4, 16))
    pos = jnp.broadcast_to(jnp.arange(8), (2, 8))
    pos3 = jnp.broadcast_to(pos, (3, 2, 8))
    a = C.apply_rope(x, pos)
    b = C.apply_mrope(x, pos3, (2, 3, 3))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_swa_masks_distant_tokens():
    """Sliding-window attention must ignore tokens beyond the window."""
    from repro.models import common as C
    key = jax.random.PRNGKey(5)
    B, S, H, D = 1, 32, 2, 8
    q = jax.random.normal(key, (B, 1, H, D))
    k = jax.random.normal(key, (B, S, H, D))
    v = jax.random.normal(key, (B, S, H, D))
    qpos = jnp.full((B, 1), S - 1)
    kpos = jnp.broadcast_to(jnp.arange(S), (B, S))
    out_w = C.chunked_attention(q, k, v, qpos, kpos, window=4, chunk=8)
    # zero out everything outside the window: result must be identical
    keep = (S - 1 - np.arange(S)) < 4
    k2 = jnp.asarray(np.where(keep[None, :, None, None], np.asarray(k), 9.9))
    v2 = jnp.asarray(np.where(keep[None, :, None, None], np.asarray(v), 9.9))
    out_w2 = C.chunked_attention(q, k2, v2, qpos, kpos, window=4, chunk=8)
    np.testing.assert_allclose(np.asarray(out_w), np.asarray(out_w2),
                               atol=1e-5)


def test_moe_load_balance_aux_positive():
    fam, cfg, model = registry.get("deepseek-moe-16b", reduced=True)
    params = model.init(jax.random.PRNGKey(6))
    toks = jax.random.randint(jax.random.PRNGKey(7), (2, 16), 3, cfg.vocab)
    _, _, aux = model.apply(params, toks)
    assert float(aux) > 0
