"""End-to-end fault-tolerance test: train -> checkpoint -> kill -> resume.

Exercises the full launcher path (pipeline -> jitted step -> sharded
checkpoint -> elastic restore + skip-ahead) the way a preempted host
would experience it."""

import os
import shutil
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
CKPT = "/tmp/repro_e2e_ckpt_test"


def _run(args, timeout=600):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.train", *args],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=timeout)


def test_train_checkpoint_resume():
    shutil.rmtree(CKPT, ignore_errors=True)
    common = ["--arch", "bytelm-100m", "--reduced", "--batch", "2",
              "--seq", "64", "--ckpt-dir", CKPT, "--ckpt-every", "10",
              "--log-every", "5"]
    # phase 1: run 10 steps, checkpoint at 10
    r1 = _run(common + ["--steps", "10"])
    assert r1.returncode == 0, r1.stderr[-2000:]
    assert os.path.isdir(os.path.join(CKPT, "step_10"))

    # phase 2: resume to step 20 — must skip ahead, not restart
    r2 = _run(common + ["--steps", "20", "--resume"])
    assert r2.returncode == 0, r2.stderr[-2000:]
    assert "resumed from step 10" in r2.stdout
    assert os.path.isdir(os.path.join(CKPT, "step_20"))

    # phase 3: resuming at the final step is a no-op, not a crash
    r3 = _run(common + ["--steps", "20", "--resume"])
    assert r3.returncode == 0, r3.stderr[-2000:]
    shutil.rmtree(CKPT, ignore_errors=True)
