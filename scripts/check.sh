#!/usr/bin/env bash
# Fast regression gate: tier-1 tests + a 2-language transcode bench smoke
# (interpret-mode kernels) + the bench regression gate against the
# committed baseline.  Run from anywhere; exits non-zero on any test
# failure, bench crash, a bench JSON missing one of the three transcode
# strategies, or a >30% fused-throughput regression.
#
# -e: any failing command (pytest included) aborts the script with its
#     exit code — the gate cannot silently pass over a red suite.
# -u: unset variables are errors.
# -o pipefail: a failure anywhere in a pipeline is the pipeline's status.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# --chaos: run ONLY the robustness surface — the fault-injection chaos
# suite, every streaming test (module names test_faults/test_stream and
# the test_stream_* incremental fuzz in test_differential) and the
# shard-level fault-tolerance suite (test_recovery: supervised launches,
# feeder watchdog, serve circuit breaker) — with the fixed fuzz seed CI
# pins.  Fast inner loop for robustness work.  The degraded-mesh replan
# cases need >= 8 devices, so the recovery suite's multi-device half is
# re-run under the forced 8-device host platform in a FRESH process
# (XLA locks the device count at first jax init).
if [ "${1:-}" = "--chaos" ]; then
    REPRO_FUZZ_SEED="${REPRO_FUZZ_SEED:-20260801}" \
        python -m pytest tests -k "fault or stream or recovery" -q
    XLA_FLAGS="--xla_force_host_platform_device_count=8" \
        REPRO_FUZZ_SEED="${REPRO_FUZZ_SEED:-20260801}" \
        python -m pytest tests/test_recovery.py -k "8dev" -q
    exit $?
fi

# --serve: run ONLY the serving surface — the engine/serve tests plus a
# table_serve smoke asserting the continuous scheduler's win (higher
# req/s AND lower p99 than wave on the skewed trace).  Fast inner loop
# for scheduler work; CI runs this as its own job.
if [ "${1:-}" = "--serve" ]; then
    python -m pytest tests -k "serve or engine" -q
    python - <<'PY'
from benchmarks import transcode_bench as tb
rows = tb.table_serve(n_requests=24, reps=2)
rps = {k: v for k, v in rows[0].items() if k != "lang"}
lat = {k: v for k, v in rows[1].items() if k != "lang"}
print("table_serve smoke:", rps, lat)
assert rps["continuous"] > rps["wave"], \
    f"continuous does not beat wave on req/s: {rps}"
assert lat["continuous_p99_ms"] < lat["wave_p99_ms"], \
    f"continuous does not beat wave on p99 latency: {lat}"
print("serve smoke OK: continuous beats wave "
      f"({rps['continuous']/rps['wave']:.2f}x req/s)")
PY
    exit $?
fi

# --shard: run ONLY the sharding surface — the shard planner / shard_map
# bit-identity / feeder tests plus the pipeline host-sharding pin — under
# the forced 8-device host platform, which un-skips the full 12-cell
# sharded fuzz that single-device runs skip.  CI runs this as its own
# job.
if [ "${1:-}" = "--shard" ]; then
    XLA_FLAGS="--xla_force_host_platform_device_count=8" \
        REPRO_FUZZ_SEED="${REPRO_FUZZ_SEED:-20260801}" \
        python -m pytest tests -k "shard" -q
    exit $?
fi

# set -e would abort on a bare failing pytest too; capture and re-raise
# the exact code explicitly so a future edit can't swallow it.
pytest_rc=0
python -m pytest -x -q || pytest_rc=$?
if [ "$pytest_rc" -ne 0 ]; then
    echo "check.sh: pytest failed (rc=$pytest_rc)" >&2
    exit "$pytest_rc"
fi

# Fresh smoke run goes to a scratch file so the committed baseline
# (BENCH_transcode.json) stays intact for the gate comparison.
fresh="BENCH_fresh.json"
python -m benchmarks.run --smoke --out "$fresh"

python - "$fresh" <<'PY'
import json, sys
report = json.load(open(sys.argv[1]))
strategies = {r["strategy"] for r in report["records"]}
need = {"onepass", "fused", "blockparallel", "windowed(paper)",
        "continuous", "wave", "sharded"}
missing = need - strategies
assert not missing, f"bench JSON missing strategies: {missing}"
tables = {r["table"] for r in report["records"]}
assert {"table5", "table6", "table9", "table_stream",
        "table_serve", "table_shard"} <= tables, tables
assert "stream" in strategies, strategies
# Feeder acceptance: every committed transfer-hidden fraction must show
# at least half the host->device staging time overlapped with compute.
hidden = [r for r in report["records"]
          if r["table"] == "table_shard"
          and r["strategy"].startswith("hidden@")]
assert hidden, "table_shard is missing its transfer_hidden row"
bad = {r["strategy"]: r["gchars_per_s"] for r in hidden
       if r["gchars_per_s"] < 0.5}
assert not bad, f"feeder hid <50% of transfer time: {bad}"
print("bench smoke OK:", sorted(strategies), "across", sorted(tables))
PY

# Absolute mode assumes this machine matches the one that committed the
# baseline (true for the dev container that regenerates it each PR).  On
# a different box run with BENCH_GATE_MODE=relative, which gates the
# machine-portable fused/blockparallel speedup ratio instead (what CI
# uses).
python scripts/bench_gate.py --fresh "$fresh" \
    --baseline BENCH_transcode.json --mode "${BENCH_GATE_MODE:-absolute}"
