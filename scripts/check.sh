#!/usr/bin/env bash
# Fast regression gate: tier-1 tests + a 2-language transcode bench smoke
# (interpret-mode kernels).  Run from anywhere; exits non-zero on any
# test failure, bench crash, or a bench JSON missing one of the three
# transcode strategies.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -x -q

python -m benchmarks.run --smoke --out BENCH_transcode.json

python - <<'PY'
import json
report = json.load(open("BENCH_transcode.json"))
strategies = {r["strategy"] for r in report["records"]}
need = {"fused", "blockparallel", "windowed(paper)"}
missing = need - strategies
assert not missing, f"BENCH_transcode.json missing strategies: {missing}"
tables = {r["table"] for r in report["records"]}
assert {"table5", "table6", "table9"} <= tables, tables
print("bench smoke OK:", sorted(strategies), "across", sorted(tables))
PY
