#!/usr/bin/env python3
"""Bench regression gate: fresh --smoke run vs the committed baseline.

Usage:
    python scripts/bench_gate.py --fresh BENCH_fresh.json \
        [--baseline BENCH_transcode.json] [--threshold 0.30] \
        [--mode absolute|relative]

Compares the fused strategy per (table, lang) cell against the committed
``BENCH_transcode.json`` and fails (exit 1) when any cell regresses by
more than ``threshold`` (default 30% — wide enough to absorb timer
noise, tight enough to catch a real perf cliff).  Two modes:

  * ``absolute`` (default) — raw Gchars/s.  Only sound when the fresh
    run and the committed baseline come from the SAME machine; this is
    what ``scripts/check.sh`` uses locally.
  * ``relative`` — the fused/blockparallel speedup ratio per cell, so
    absolute machine speed cancels out (both strategies are measured in
    the same fresh run).  This is what CI uses: a GitHub-hosted runner
    can be arbitrarily slower than the dev box that committed the
    baseline without turning the job red, while a change that erodes the
    fused pipeline's advantage still fails.

Cells present in the baseline but missing from the fresh run fail the
gate outright (a silently dropped strategy is a regression, not a skip).
"""

from __future__ import annotations

import argparse
import json
import sys

GATED_STRATEGY = "fused"
REFERENCE_STRATEGY = "blockparallel"


def _cells(report: dict, mode: str) -> dict:
    raw = {}
    for rec in report["records"]:
        key = (rec["table"], rec["lang"])
        raw.setdefault(key, {})[rec["strategy"]] = rec["gchars_per_s"]
    out = {}
    for key, by_strategy in raw.items():
        if GATED_STRATEGY not in by_strategy:
            continue
        if mode == "relative":
            ref = by_strategy.get(REFERENCE_STRATEGY)
            if not ref:
                continue
            out[key] = by_strategy[GATED_STRATEGY] / ref
        else:
            out[key] = by_strategy[GATED_STRATEGY]
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fresh", required=True,
                    help="JSON written by a fresh `benchmarks.run --smoke`")
    ap.add_argument("--baseline", default="BENCH_transcode.json",
                    help="committed baseline JSON")
    ap.add_argument("--threshold", type=float, default=0.30,
                    help="max allowed fractional regression per cell")
    ap.add_argument("--mode", choices=("absolute", "relative"),
                    default="absolute",
                    help="absolute Gchars/s (same-machine baseline) or "
                         "fused/blockparallel ratio (machine-portable)")
    args = ap.parse_args(argv)

    with open(args.baseline) as f:
        base = _cells(json.load(f), args.mode)
    with open(args.fresh) as f:
        fresh = _cells(json.load(f), args.mode)

    if not base:
        print(f"bench gate: no '{GATED_STRATEGY}' records in baseline "
              f"{args.baseline}", file=sys.stderr)
        return 1

    failures = []
    unit = "Gchars/s" if args.mode == "absolute" else "x blockparallel"
    print(f"bench gate [{args.mode}]: {GATED_STRATEGY} vs {args.baseline} "
          f"(threshold {args.threshold:.0%}, cells in {unit})")
    print(f"{'table':10s} {'lang':10s} {'baseline':>10s} {'fresh':>10s} "
          f"{'ratio':>7s}")
    for key in sorted(base):
        table, lang = key
        b = base[key]
        f_ = fresh.get(key)
        if f_ is None:
            print(f"{table:10s} {lang:10s} {b:10.3f} {'MISSING':>10s}")
            failures.append(f"{table}/{lang}: missing from fresh run")
            continue
        ratio = f_ / b if b > 0 else float("inf")
        flag = "" if ratio >= 1.0 - args.threshold else "  << REGRESSION"
        print(f"{table:10s} {lang:10s} {b:10.3f} {f_:10.3f} "
              f"{ratio:7.2f}{flag}")
        if ratio < 1.0 - args.threshold:
            failures.append(
                f"{table}/{lang}: {b:.3f} -> {f_:.3f} {unit} "
                f"({ratio:.2f}x, limit {1.0 - args.threshold:.2f}x)")

    if failures:
        print("\nbench gate FAILED:", file=sys.stderr)
        for msg in failures:
            print(f"  {msg}", file=sys.stderr)
        return 1
    print(f"bench gate OK: {len(base)} cells within threshold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
