#!/usr/bin/env python3
"""Bench regression gate: fresh --smoke run vs the committed baseline.

Usage:
    python scripts/bench_gate.py --fresh BENCH_fresh.json \
        [--baseline BENCH_transcode.json] [--threshold 0.30] \
        [--mode absolute|relative]

Compares each table's gated strategy pairs per (table, lang) cell
against the committed ``BENCH_transcode.json`` and fails (exit 1) when
any cell regresses by more than ``threshold`` (default 30% — wide
enough to absorb timer noise, tight enough to catch a real perf cliff).
Most tables gate fused against blockparallel; tables 5/6/9 additionally
gate the default strategy (onepass) against blockparallel — and against
fused on table 6 — see ``TABLE_STRATEGIES``.  Two modes:

  * ``absolute`` (default) — raw Gchars/s.  Only sound when the fresh
    run and the committed baseline come from the SAME machine; this is
    what ``scripts/check.sh`` uses locally.
  * ``relative`` — the fused/blockparallel speedup ratio per cell, so
    absolute machine speed cancels out (both strategies are measured in
    the same fresh run).  This is what CI uses: a GitHub-hosted runner
    can be arbitrarily slower than the dev box that committed the
    baseline without turning the job red, while a change that erodes the
    fused pipeline's advantage still fails.

Cells present in the baseline but missing from the fresh run fail the
gate outright (a silently dropped strategy is a regression, not a skip)
— with one schema-versioned exception: reports carry a ``"schema"`` int
(absent = 1), and when the two reports disagree on it, whole TABLES
known to only one side are warned-and-skipped instead of failed.  That
lets a newer run introduce a new table (e.g. ``table_matrix``, schema 2)
without breaking against an older committed baseline, and an older
branch re-run against a newer baseline likewise — while a cell missing
from a table both sides know about still fails as a regression.

Exit codes: 0 = gate passed, 1 = regression / missing cells, 2 = a JSON
file is unreadable or malformed (never a traceback: a corrupt committed
baseline must fail CI with a diagnosable message).
"""

from __future__ import annotations

import argparse
import json
import sys

GATED_STRATEGY = "fused"
REFERENCE_STRATEGY = "blockparallel"
DEFAULT_PAIRS = [(GATED_STRATEGY, REFERENCE_STRATEGY)]
# Per-table list of (gated, reference) strategy pairs.  Most tables gate
# the fused pipeline against the block-parallel reference; the paper
# tables 5/6/9 additionally gate the DEFAULT strategy (onepass) against
# its references — blockparallel everywhere, plus the two-pass fused
# path on table 6 — so a "default loses to its own reference" regression
# (the multibyte-cell regression this repo shipped once) can never land
# silently again.  table_serve rows carry schedulers, not kernel
# strategies: its gated claim is that continuous batching beats
# (absolute) / keeps beating (relative) the wave scheduler.  table_shard
# gates the mesh-sharded ragged path against its single-device onepass
# reference measured in the same run (its transfer_hidden row carries
# ``hidden@N`` fraction keys, which match no gated strategy and are
# asserted by scripts/check.sh instead).
TABLE_STRATEGIES = {
    "table5": DEFAULT_PAIRS + [("onepass", "blockparallel")],
    "table6": DEFAULT_PAIRS + [("onepass", "blockparallel"),
                               ("onepass", "fused")],
    "table9": DEFAULT_PAIRS + [("onepass", "blockparallel")],
    "table_serve": [("continuous", "wave")],
    "table_shard": [("sharded", "single")],
}

EXIT_MALFORMED = 2


def _strategies(table: str) -> list:
    """List of (gated, reference) strategy pairs for a table."""
    return TABLE_STRATEGIES.get(table, DEFAULT_PAIRS)


class MalformedReport(ValueError):
    """A bench JSON that cannot be interpreted as (table, lang, strategy,
    gchars_per_s) records."""


def _schema(report) -> int:
    """Schema version of a bench report (absent = 1, the pre-versioned
    format)."""
    v = report.get("schema", 1) if isinstance(report, dict) else 1
    if not isinstance(v, int) or isinstance(v, bool) or v < 1:
        raise MalformedReport(f"'schema' is not a positive int: {v!r}")
    return v


def _cells(report, mode: str) -> dict:
    if not isinstance(report, dict) or \
            not isinstance(report.get("records"), list):
        raise MalformedReport("no 'records' list")
    raw = {}
    for rec in report["records"]:
        if not isinstance(rec, dict):
            raise MalformedReport(f"record is not an object: {rec!r}")
        try:
            key = (rec["table"], rec["lang"])
            strategy = rec["strategy"]
            speed = rec["gchars_per_s"]
        except KeyError as e:
            raise MalformedReport(f"record missing key {e}: {rec!r}")
        if not isinstance(speed, (int, float)) or isinstance(speed, bool):
            raise MalformedReport(
                f"gchars_per_s is not a number: {rec!r}")
        raw.setdefault(key, {})[strategy] = speed
    out = {}
    for key, by_strategy in raw.items():
        for gated, reference in _strategies(key[0]):
            if gated not in by_strategy:
                continue
            if mode == "relative":
                ref = by_strategy.get(reference)
                if not ref:
                    continue
                # One cell per pair: the same gated strategy can carry a
                # different reference per pair (onepass/blockparallel AND
                # onepass/fused on table6).
                out[key + (f"{gated}/{reference}",)] = \
                    by_strategy[gated] / ref
            else:
                # Absolute mode gates the gated strategy's raw speed; two
                # pairs sharing a gated strategy dedupe onto one cell.
                out[key + (gated,)] = by_strategy[gated]
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fresh", required=True,
                    help="JSON written by a fresh `benchmarks.run --smoke`")
    ap.add_argument("--baseline", default="BENCH_transcode.json",
                    help="committed baseline JSON")
    ap.add_argument("--threshold", type=float, default=0.30,
                    help="max allowed fractional regression per cell")
    ap.add_argument("--mode", choices=("absolute", "relative"),
                    default="absolute",
                    help="absolute Gchars/s (same-machine baseline) or "
                         "fused/blockparallel ratio (machine-portable)")
    args = ap.parse_args(argv)

    def load(path):
        try:
            with open(path) as f:
                report = json.load(f)
                return _schema(report), _cells(report, args.mode)
        # ValueError covers json.JSONDecodeError, UnicodeDecodeError
        # (binary baseline) and MalformedReport alike.
        except (OSError, ValueError) as e:
            print(f"bench gate: malformed or unreadable bench JSON "
                  f"{path}: {e}", file=sys.stderr)
            return None

    loaded_base = load(args.baseline)
    loaded_fresh = load(args.fresh)
    if loaded_base is None or loaded_fresh is None:
        return EXIT_MALFORMED
    base_schema, base = loaded_base
    fresh_schema, fresh = loaded_fresh

    if not base:
        print(f"bench gate: no gated-strategy records in baseline "
              f"{args.baseline}", file=sys.stderr)
        return 1

    # Schema-versioned table skipping: when the two reports come from
    # different schema versions, tables only one side knows about are a
    # format evolution, not a regression — warn and gate on the shared
    # tables only.  Same-schema missing cells still fail below.
    if base_schema != fresh_schema:
        base_tables = {k[0] for k in base}
        fresh_tables = {k[0] for k in fresh}
        for t in sorted(base_tables ^ fresh_tables):
            where = "baseline" if t in base_tables else "fresh run"
            print(f"bench gate: WARNING: skipping table '{t}' (only in "
                  f"the {where}; schema {base_schema} vs {fresh_schema})",
                  file=sys.stderr)
        shared = base_tables & fresh_tables
        base = {k: v for k, v in base.items() if k[0] in shared}
        fresh = {k: v for k, v in fresh.items() if k[0] in shared}
        if not base:
            # Version skew must never produce a vacuous pass: with no
            # shared table left, nothing was gated at all.
            print("bench gate: no tables shared between baseline and "
                  "fresh run after schema skipping — nothing gated",
                  file=sys.stderr)
            return 1

    failures = []
    unit = "Gchars/s" if args.mode == "absolute" else "x reference"
    print(f"bench gate [{args.mode}]: per-table strategy pairs vs "
          f"{args.baseline} (threshold {args.threshold:.0%}, cells in "
          f"{unit})")
    print(f"{'table':10s} {'lang':10s} {'pair':22s} {'baseline':>10s} "
          f"{'fresh':>10s} {'ratio':>7s}")
    for key in sorted(base):
        table, lang, tag = key
        b = base[key]
        f_ = fresh.get(key)
        if f_ is None:
            print(f"{table:10s} {lang:10s} {tag:22s} {b:10.3f} "
                  f"{'MISSING':>10s}")
            failures.append(f"{table}/{lang}/{tag}: missing from fresh run")
            continue
        ratio = f_ / b if b > 0 else float("inf")
        flag = "" if ratio >= 1.0 - args.threshold else "  << REGRESSION"
        print(f"{table:10s} {lang:10s} {tag:22s} {b:10.3f} {f_:10.3f} "
              f"{ratio:7.2f}{flag}")
        if ratio < 1.0 - args.threshold:
            failures.append(
                f"{table}/{lang}/{tag}: {b:.3f} -> {f_:.3f} {unit} "
                f"({ratio:.2f}x, limit {1.0 - args.threshold:.2f}x)")

    if failures:
        print("\nbench gate FAILED:", file=sys.stderr)
        for msg in failures:
            print(f"  {msg}", file=sys.stderr)
        return 1
    print(f"bench gate OK: {len(base)} cells within threshold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
