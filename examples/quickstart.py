"""Quickstart: the transcoding core as a library (paper's public API).

    PYTHONPATH=src python examples/quickstart.py

The supported surface is the GENERIC entry points (``repro.transcode`` /
``scan`` / ``ragged_transcode`` / ``ragged_scan``); the per-pair wrappers
are deprecated shims that warn (DESIGN.md §11).  Migration table:

    deprecated wrapper                  generic call
    ----------------------------------  --------------------------------
    transcode_utf8_to_utf16(b, n)       transcode(b, "utf16", src_format="utf8", n_valid=n)
    transcode_utf16_to_utf8(u, n)       transcode(u, "utf8", src_format="utf16", n_valid=n)
    utf8_to_utf16(b, n)                 transcode(b, "utf16", src_format="utf8", n_valid=n, strategy="blockparallel")
    utf16_to_utf8(u, n)                 transcode(u, "utf8", src_format="utf16", n_valid=n, strategy="blockparallel")
    utf8_to_utf32 / utf16_to_utf32      transcode(x, "utf32", src_format=..., strategy="blockparallel")
    utf32_to_utf8 / utf32_to_utf16      transcode(cp, ..., src_format="utf32", strategy="blockparallel")
    utf8_to_latin1 / latin1_to_*        transcode(x, ..., strategy="fused")
    scan_utf8(b, n)                     scan(b, "utf16", src_format="utf8", n_valid=n)
    scan_utf16(u, n)                    scan(u, "utf8", src_format="utf16", n_valid=n)
    ragged_utf8_to_utf16(d, o, l)       ragged_transcode(d, o, l, src_format="utf8", dst_format="utf16")
    ragged_utf16_to_utf8(d, o, l)       ragged_transcode(d, o, l, src_format="utf16", dst_format="utf8")
    ragged_scan_utf8 / ragged_scan_utf16  ragged_scan(d, o, l, src_format=..., dst_format=...)

(The ``strategy=`` column records each wrapper's historical default; the
generic default is ``"onepass"``.)
"""

import numpy as np

import jax.numpy as jnp

from repro.core import transcode as tc
from repro.kernels import ops as kops


def show(title, value):
    print(f"{title:<46s} {value}")


def main():
    s = "naïve 中文 🎉 — transcoding demo"
    utf8 = np.frombuffer(s.encode("utf-8"), np.uint8).astype(np.int32)
    utf16 = np.frombuffer(s.encode("utf-16-le"), np.uint16).astype(np.int32)

    # --- validation (Keiser-Lemire, vectorized) -------------------------
    show("validate_utf8(valid text)",
         bool(tc.validate_utf8(jnp.asarray(utf8), len(utf8))))
    bad = jnp.asarray(np.array([0xED, 0xA0, 0x80, 0, 0, 0, 0, 0], np.int32))
    show("validate_utf8(surrogate U+D800)", bool(tc.validate_utf8(bad, 3)))

    # --- UTF-8 -> UTF-16 (all strategies) -------------------------------
    # "onepass" (the default) is the single-launch pipeline: one read +
    # one decode of the input, inter-tile offsets carried in SMEM
    # (DESIGN.md §9); "fused" is the two-launch kernel reference it is
    # pinned bit-for-bit against.
    for strat in ("onepass", "fused", "blockparallel", "windowed"):
        out, cnt, err = tc.transcode(
            jnp.asarray(utf8), "utf16", src_format="utf8",
            n_valid=len(utf8), strategy=strat)
        got = np.asarray(out)[: int(cnt)].astype(np.uint16)
        ok = np.array_equal(got, utf16.astype(np.uint16))
        show(f"utf8->utf16 [{strat}] matches python", ok)

    # Explicit one-pass call on a mixed mostly-ASCII document: the
    # per-tile class dispatch (DESIGN.md §9) keeps clean tiles on the
    # ASCII copy path even though the buffer as a whole is not ASCII —
    # and tiles of dense 2-byte scripts (Arabic, Hebrew, Russian, ...)
    # take a narrowed ≤2-byte fast path: no 3-/4-byte candidate
    # assembly, half the staging window, uint16 intermediates.
    mixed = ("The quick brown fox. " * 120 + "速い茶色の狐。").encode("utf-8")
    out, cnt, status = tc.transcode(
        jnp.asarray(np.frombuffer(mixed, np.uint8)), "utf16",
        src_format="utf8", strategy="onepass")
    show("transcode(..., strategy='onepass') round-trips",
         bytes(np.asarray(out)[: int(cnt)].astype(np.uint16).tobytes())
         .decode("utf-16-le") == mixed.decode("utf-8"))

    # --- UTF-16 -> UTF-8 ------------------------------------------------
    out, cnt, err = tc.transcode(jnp.asarray(utf16), "utf8",
                                 src_format="utf16", n_valid=len(utf16))
    got = bytes(np.asarray(out)[: int(cnt)].astype(np.uint8))
    show("utf16->utf8 round-trips", got.decode("utf-8") == s)

    # --- Pallas kernel path (interpret=True on CPU, same API) -----------
    out, cnt, err = kops.utf8_to_utf16(jnp.asarray(utf8), len(utf8))
    got = np.asarray(out)[: int(cnt)].astype(np.uint16)
    show("Pallas kernel utf8->utf16 matches", np.array_equal(
        got, utf16.astype(np.uint16)))

    # --- error location + replacement (simdutf-style result) ------------
    broken = np.frombuffer("héllo".encode("utf-8"), np.uint8).copy()
    broken[1] = 0xFF  # corrupt the é lead byte
    count, status = tc.scan(jnp.asarray(broken), "utf16",
                            src_format="utf8", n_valid=len(broken))
    show("scan: first invalid byte offset", int(status))
    out, cnt, status = tc.transcode(
        jnp.asarray(broken), "utf16", src_format="utf8",
        n_valid=len(broken), errors="replace")
    fixed = np.asarray(out)[: int(cnt)].astype(np.uint16).tobytes()
    show("errors='replace' output", fixed.decode("utf-16-le"))

    # --- the codec matrix (DESIGN.md §8): any (src, dst) format pair ----
    legacy = "café ÿ £".encode("latin-1")   # a Latin-1 wire buffer
    out, cnt, status = tc.transcode(
        jnp.asarray(np.frombuffer(legacy, np.uint8)), "utf8",
        src_format="latin1")
    show("transcode(latin1 -> utf8) round-trips",
         bytes(np.asarray(out)[: int(cnt)].astype(np.uint8))
         == "café ÿ £".encode("utf-8"))
    out, cnt, status = tc.transcode(
        jnp.asarray(utf8), "utf32", src_format="utf8", n_valid=len(utf8),
        strategy="fused")
    show("utf8 -> utf32 code points (fused cell)",
         np.array_equal(np.asarray(out)[: int(cnt)].astype(np.int64),
                        np.array([ord(c) for c in s])))
    out, cnt, status = tc.transcode(
        jnp.asarray(utf8), "latin1", src_format="utf8", errors="replace")
    show("utf8 -> latin1 (replace: '?' for cp > U+00FF)",
         bytes(np.asarray(out)[: int(cnt)].astype(np.uint8)))

    # --- capacity planning (simdutf-style length queries) ---------------
    show("utf16 units needed",
         int(tc.utf16_length_from_utf8(jnp.asarray(utf8), len(utf8))))
    show("utf8 bytes needed",
         int(tc.utf8_length_from_utf16(jnp.asarray(utf16), len(utf16))))
    show("characters", int(tc.count_utf8_chars(jnp.asarray(utf8), len(utf8))))

    # --- mesh-sharded ragged batches (DESIGN.md §12) ---------------------
    # A packed batch split across the mesh "data" axis: each shard runs
    # the one-launch ragged kernel locally and the per-document results
    # gather back bit-identical to the single-device path.  n_shards=1
    # runs anywhere; on a multi-device host (or CPU with
    # XLA_FLAGS=--xla_force_host_platform_device_count=8) raise n_shards
    # — document-boundary cuts balance live bytes across shards.
    from repro.core import packing
    docs = [s.encode("utf-8"), b"second document", b"", b"third"]
    pk = packing.pack_documents(docs)
    res = tc.ragged_transcode(pk.data, pk.offsets, pk.lengths,
                              src_format="utf8", dst_format="utf16",
                              strategy="sharded", n_shards=1)
    ref = tc.ragged_transcode(pk.data, pk.offsets, pk.lengths,
                              src_format="utf8", dst_format="utf16")
    show("sharded == single-device (counts)",
         np.array_equal(np.asarray(res.counts), np.asarray(ref.counts)))
    show("sharded == single-device (buffer)",
         np.array_equal(np.asarray(res.buffer), np.asarray(ref.buffer)))

    # --- supervised launch (DESIGN.md §10: shard fault tolerance) --------
    # The same call under the retry / watchdog / degraded-mesh-replan
    # supervisor: transient failures retry with backoff, persistent ones
    # replan onto fewer devices (bit-identical result — same cut rules
    # at every mesh size), and only a fully exhausted ladder raises a
    # typed DegradedMeshExhausted.  See examples/serve_demo.py for the
    # serve engine's circuit breaker riding the same layer.
    from repro.core import recovery
    log = recovery.SupervisionLog()
    sup = recovery.supervised_ragged_transcode(
        pk.data, pk.offsets, pk.lengths, src_format="utf8",
        dst_format="utf16", n_shards=1, log=log)
    show("supervised == single-device (buffer)",
         np.array_equal(np.asarray(sup.buffer), np.asarray(ref.buffer)))
    show("supervision log", log.attempts)


if __name__ == "__main__":
    main()
