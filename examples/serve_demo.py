"""Serving demo: continuous batching behind the submit/poll surface.

Requests are admitted through ``Engine.submit`` (cheap validation +
length-bucketed queueing; invalid requests settle immediately),
``Engine.drain`` runs the slot-level continuous-batching loop (a slot
that finishes early is refilled mid-wave from the admission queue), and
``Engine.poll`` returns each settled result by ticket.  The legacy
batch-in/batch-out call is still available as the ``Engine.serve`` shim.

    PYTHONPATH=src python examples/serve_demo.py
"""

import jax

from repro.models import registry
from repro.serve.engine import Engine, Request


def main():
    fam, cfg, model = registry.get("bytelm-100m", reduced=True)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, cfg, fam, params, max_batch=2, max_prompt=64,
                 max_new=12)

    requests = [
        Request(b"hello framework", max_new=2),    # frees its slot early
        Request("café 中文".encode("utf-8")),       # decodes the full tail
        Request(b"\xff\xfeinvalid bytes\x80"),     # rejected at ingress
        Request(b"utf-16 client", out_encoding="utf-16-le"),
        Request(b"odd\x00!", in_encoding="utf-16-le"),  # bad field: odd
    ]
    tickets = [eng.submit(req) for req in requests]

    # Field-invalid requests settle AT submit — poll before any decode.
    # (The invalid-UTF-8 prompt above is different: its bytes are only
    # inspected by the packed ingress launch during drain.)
    early = eng.poll(tickets[4])
    print(f"settled at submit: {early.code} ({early.error})")

    eng.drain()
    for req, t in zip(requests, tickets):
        res = eng.poll(t)
        if res is None:
            continue                               # polled above
        body = res.text_bytes[:32] if res.ok else res.error
        print(f"[{res.code:>16}] {req.prompt_bytes[:24]!r:30} "
              f"({req.out_encoding}) -> {body!r}")

    # The drain's slot lifecycle: with max_batch=2 and three admitted
    # requests, the short request's slot re-admits the queued one
    # mid-wave — that admit's step precedes its batch-mate's finish.
    for kind, ticket, slot, step, _wall in eng.events:
        print(f"  step {step:3d}  {kind:>6}  ticket={ticket} slot={slot}")


if __name__ == "__main__":
    main()
