"""Serving demo: continuous batching behind the submit/poll surface.

Requests are admitted through ``Engine.submit`` (cheap validation +
length-bucketed queueing; invalid requests settle immediately),
``Engine.drain`` runs the slot-level continuous-batching loop (a slot
that finishes early is refilled mid-wave from the admission queue), and
``Engine.poll`` returns each settled result by ticket.  The legacy
batch-in/batch-out call is still available as the ``Engine.serve`` shim.

The second half trips the per-ingress-group circuit breaker (DESIGN.md
§10) with an injected failure storm and then lets it recover: open
(host fallback, zero device launches) -> half-open probe -> closed,
every transition auditable from ``Engine.events``.

    PYTHONPATH=src python examples/serve_demo.py
"""

import jax

from repro.models import registry
from repro.serve.engine import Engine, Request
from repro.testing import faults


def main():
    fam, cfg, model = registry.get("bytelm-100m", reduced=True)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, cfg, fam, params, max_batch=2, max_prompt=64,
                 max_new=12)

    requests = [
        Request(b"hello framework", max_new=2),    # frees its slot early
        Request("café 中文".encode("utf-8")),       # decodes the full tail
        Request(b"\xff\xfeinvalid bytes\x80"),     # rejected at ingress
        Request(b"utf-16 client", out_encoding="utf-16-le"),
        Request(b"odd\x00!", in_encoding="utf-16-le"),  # bad field: odd
    ]
    tickets = [eng.submit(req) for req in requests]

    # Field-invalid requests settle AT submit — poll before any decode.
    # (The invalid-UTF-8 prompt above is different: its bytes are only
    # inspected by the packed ingress launch during drain.)
    early = eng.poll(tickets[4])
    print(f"settled at submit: {early.code} ({early.error})")

    eng.drain()
    for req, t in zip(requests, tickets):
        res = eng.poll(t)
        if res is None:
            continue                               # polled above
        body = res.text_bytes[:32] if res.ok else res.error
        print(f"[{res.code:>16}] {req.prompt_bytes[:24]!r:30} "
              f"({req.out_encoding}) -> {body!r}")

    # The drain's slot lifecycle: with max_batch=2 and three admitted
    # requests, the short request's slot re-admits the queued one
    # mid-wave — that admit's step precedes its batch-mate's finish.
    for kind, ticket, slot, step, _wall in eng.events:
        print(f"  step {step:3d}  {kind:>6}  ticket={ticket} slot={slot}")

    breaker_demo()


def _breaker_events(eng):
    return [(kind, group, step) for kind, group, _slot, step, _wall
            in eng.events if kind.startswith("breaker_")]


def breaker_demo():
    """Trip the utf-8 ingress group's breaker, then watch it recover."""
    fam, cfg, model = registry.get("bytelm-100m", reduced=True)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, cfg, fam, params, max_batch=2, max_prompt=64,
                 max_new=4, backoff_base_s=0.0,
                 breaker_threshold=1, breaker_cooldown_s=0.0)
    eng.serve([Request(b"warm up")])           # compile the utf-8 cells

    # Failure storm: EVERY device ingress launch fails.  Retries exhaust
    # once, the breaker opens, and every later chunk routes straight to
    # the host fallback — the requests still serve.
    with faults.harness(faults.Fault(faults.KERNEL_RAGGED_SCAN,
                                     times=None)) as h:
        res = eng.serve([Request(b"served through the storm"),
                         Request(b"so is this one")])
    print("\nbreaker demo — storm drain "
          f"(all served: {all(r.ok for r in res)}, "
          f"device launches during storm: {h.calls.get('kernel.ragged_scan', 0)}):")
    for kind, group, step in _breaker_events(eng):
        print(f"  step {step:3d}  {kind:>18}  group={group}")

    # Storm over: the cooldown has elapsed, so the next drain's first
    # chunk is a half-open PROBE.  It succeeds and the breaker closes —
    # the group is back on the device path.
    res = eng.serve([Request(b"back to normal")])
    print(f"recovery drain (ok={res[0].ok}):")
    for kind, group, step in _breaker_events(eng):
        print(f"  step {step:3d}  {kind:>18}  group={group}")
    stats = {k: v for k, v in sorted(eng.counters.items())
             if k.startswith("breaker_")}
    print(f"breaker counters: {stats}")


if __name__ == "__main__":
    main()
