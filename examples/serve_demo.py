"""Serving demo: batched requests through the transcode boundary.

UTF-8 prompts are validated at ingress (invalid bytes rejected without
touching the model); responses are returned in UTF-8 or UTF-16LE via the
vectorized egress encoders.

    PYTHONPATH=src python examples/serve_demo.py
"""

import jax

from repro.models import registry
from repro.serve.engine import Engine, Request


def main():
    fam, cfg, model = registry.get("bytelm-100m", reduced=True)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, cfg, fam, params, max_batch=4, max_prompt=64,
                 max_new=12)

    requests = [
        Request(b"hello framework"),
        Request("café 中文".encode("utf-8")),
        Request(b"\xff\xfeinvalid bytes\x80"),               # rejected
        Request(b"utf-16 client", out_encoding="utf-16-le"),
    ]
    for req, res in zip(requests, eng.serve(requests)):
        status = "OK " if res.ok else "REJ"
        body = res.text_bytes[:32] if res.ok else res.error
        print(f"[{status}] {req.prompt_bytes[:24]!r:30} "
              f"({req.out_encoding}) -> {body!r}")


if __name__ == "__main__":
    main()
