"""End-to-end driver: train a byte-level LM on the UTF-8 ingest pipeline.

The paper's technique as a first-class framework feature: raw multilingual
UTF-8 bytes are validated + tokenized **on device** by the transcoding
core, packed by the pipeline, and consumed by the training loop with
checkpoint/restart.

    PYTHONPATH=src python examples/train_bytelm.py            # reduced, CPU
    PYTHONPATH=src python examples/train_bytelm.py --full     # 100M config

(--full trains the real 12L/768d bytelm-100m; on this CPU container use
the default reduced config — same code path, smaller dims.)
"""

import argparse
import sys

from repro.launch import train as trainmod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    args = ap.parse_args()

    steps = args.steps or (300 if args.full else 60)
    argv = ["--arch", "bytelm-100m", "--steps", str(steps),
            "--batch", "8", "--seq", "512" if args.full else "128",
            "--ckpt-every", "50", "--log-every", "10",
            "--ckpt-dir", "/tmp/repro_bytelm_ckpt"]
    if not args.full:
        argv.append("--reduced")
    trainmod.main(argv)


if __name__ == "__main__":
    main()
