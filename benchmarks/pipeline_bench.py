"""End-to-end ingest benchmark: raw UTF-8 bytes -> validated token batch.

Measures the paper's system-level claim in situ: the transcode/validate
stage of the training input pipeline must not bottleneck ingest.
"""

from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import transcode as tc
from repro.data import synthetic
from repro.data.tokenizer import ByteTokenizer


def ingest_bench(langs=("latin", "arabic", "chinese"), n_chars=1 << 15,
                 reps=8):
    tok = ByteTokenizer()

    @jax.jit
    def ingest(raw, n):
        ok = tc.validate_utf8(raw, n)
        return tok.encode(raw), ok

    rows = []
    for lang in langs:
        b = jnp.asarray(synthetic.utf8_array(lang, n_chars, 0).astype(np.int32))
        jax.block_until_ready(ingest(b, len(b)))
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(ingest(b, len(b)))
            best = min(best, time.perf_counter() - t0)
        rows.append({"lang": lang, "MB_per_s": len(b) / best / 1e6,
                     "gchars_per_s": n_chars / best / 1e9})
    return rows
