"""Sequential driver for the full dry-run sweep (40 cells x 2 meshes).

Each cell runs in a fresh subprocess (jax device-count isolation + crash
isolation); results accumulate in benchmarks/results/dryrun/*.json so the
sweep is restartable (existing results are skipped unless --force).
"""

import json
import os
import subprocess
import sys
import time

HERE = os.path.dirname(os.path.abspath(__file__))
REPO = os.path.dirname(HERE)
OUT = os.path.join(HERE, "results", "dryrun")


def cell_list():
    sys.path.insert(0, os.path.join(REPO, "src"))
    from repro import configs as cfgmod
    from repro.configs import shapes as shp
    archs = [a for a in cfgmod.ARCH_IDS if a != "bytelm-100m"]
    return shp.cells(archs)


def main():
    force = "--force" in sys.argv
    opt = "--opt" in sys.argv
    out_dir = OUT + ("_opt" if opt else "")
    os.makedirs(out_dir, exist_ok=True)
    cells = cell_list()
    todo = []
    for arch, shape, runnable, reason in cells:
        for mp in (False, True):
            mesh = "2x16x16" if mp else "16x16"
            fname = os.path.join(out_dir, f"{arch}__{shape}__{mesh}.json")
            if not runnable:
                with open(fname, "w") as f:
                    json.dump({"arch": arch, "shape": shape, "mesh": mesh,
                               "ok": True, "skipped": True,
                               "reason": reason}, f, indent=1)
                continue
            if os.path.exists(fname) and not force:
                with open(fname) as f:
                    if json.load(f).get("ok"):
                        continue
            todo.append((arch, shape, mp, fname))

    print(f"{len(todo)} cells to run", flush=True)
    for i, (arch, shape, mp, fname) in enumerate(todo):
        t0 = time.time()
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch, "--shape", shape, "--out", fname]
        if mp:
            cmd.append("--multipod")
        if opt:
            cmd.append("--opt")
        env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
        r = subprocess.run(cmd, env=env, capture_output=True, text=True,
                           timeout=3000)
        # dryrun --out writes a list; normalize to a single record
        try:
            with open(fname) as f:
                recs = json.load(f)
            if isinstance(recs, list):
                with open(fname, "w") as f:
                    json.dump(recs[0], f, indent=1)
            ok = recs[0]["ok"] if isinstance(recs, list) else recs["ok"]
        except Exception:
            ok = False
            with open(fname, "w") as f:
                json.dump({"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if mp else "16x16",
                           "ok": False,
                           "error": r.stderr[-2000:]}, f, indent=1)
        print(f"[{i+1}/{len(todo)}] {arch} x {shape} x "
              f"{'2x16x16' if mp else '16x16'}: "
              f"{'OK' if ok else 'FAIL'} ({time.time()-t0:.0f}s)", flush=True)


if __name__ == "__main__":
    main()
